"""Inference-time statistics (paper §IV): NLS fit, max-variance rule."""
import jax
import jax.numpy as jnp

from repro.core.uncertainty import (
    fit_g, max_covariance, max_variance, measure_profile, synth_samples,
)


def test_fit_recovers_g():
    freqs = jnp.linspace(0.1e9, 1.2e9, 12)
    w = 1.4214e9  # AlexNet full model GFLOPs
    g_true = 7.1
    times = w / (g_true * freqs)
    out = fit_g(freqs, times, w)
    assert abs(float(out.params[0]) - g_true) / g_true < 1e-9


def test_profile_pipeline_close_to_truth(rng):
    freqs = jnp.linspace(0.2e9, 0.8e9, 7)
    w, g_true, cv = 23.1e9, 307.0, 0.08
    samples = synth_samples(rng, freqs, w, g_true, cv=cv, num_samples=500)
    prof = measure_profile(freqs, samples, w)
    assert abs(float(prof.g_eff) - g_true) / g_true < 0.05
    # max-over-frequency variance should be ≈ (cv · slowest mean)²
    slow_mean = w / (g_true * float(freqs[0]))
    assert 0.3 * (cv * slow_mean) ** 2 < float(prof.v_loc) < 3.0 * (cv * slow_mean) ** 2


def test_max_variance_is_max():
    x = jnp.stack([jnp.array([1.0, 1.0, 1.0, 1.0]), jnp.array([0.0, 2.0, 0.0, 2.0])])
    assert float(max_variance(x)) == float(jnp.var(x[1], ddof=1))


def test_max_covariance_bounds_pairwise(rng):
    a = jax.random.normal(rng, (5, 200))
    b = 0.5 * a + 0.1 * jax.random.normal(jax.random.PRNGKey(1), (5, 200))
    w = float(max_covariance(a, b))
    per_freq = [float(jnp.cov(a[i], b[i])[0, 1]) for i in range(5)]
    assert abs(w - max(per_freq)) < 1e-6
