"""Absorbed-MLA decode ≡ non-absorbed decode (§Perf D1)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import mla as M


@pytest.mark.parametrize("h,dh,r,dr,w", [(4, 32, 64, 16, 24), (2, 64, 128, 32, 16),
                                         (8, 16, 32, 8, 40)])
def test_absorbed_equals_expanded(h, dh, r, dr, w, rng):
    d_model = 64
    p = M.mla_init(rng, d_model, h, dh, r, 0, dr, jnp.float32)
    cache0 = M.init_mla_cache(2, w, r, dr, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 1, d_model), jnp.float32)

    ca, cb = cache0, cache0
    for pos in range(6):
        xi = x * (pos + 1)
        oa, ca = M.mla_decode(p, xi, ca, jnp.int32(pos), num_heads=h, head_dim=dh,
                              rope_head_dim=dr, absorbed=True)
        ob, cb = M.mla_decode(p, xi, cb, jnp.int32(pos), num_heads=h, head_dim=dh,
                              rope_head_dim=dr, absorbed=False)
        np.testing.assert_allclose(np.asarray(oa), np.asarray(ob), atol=2e-5)
    for a, b in zip(jax.tree.leaves(ca), jax.tree.leaves(cb), strict=True):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_absorbed_ring_wrap(rng):
    """Equivalence must survive the ring-buffer wrap (pos ≥ W)."""
    h, dh, r, dr, w = 2, 16, 32, 8, 4
    p = M.mla_init(rng, 32, h, dh, r, 0, dr, jnp.float32)
    ca = cb = M.init_mla_cache(1, w, r, dr, jnp.float32)
    for pos in range(9):  # wraps twice
        xi = jax.random.normal(jax.random.PRNGKey(pos), (1, 1, 32), jnp.float32)
        oa, ca = M.mla_decode(p, xi, ca, jnp.int32(pos), num_heads=h, head_dim=dh,
                              rope_head_dim=dr, absorbed=True)
        ob, cb = M.mla_decode(p, xi, cb, jnp.int32(pos), num_heads=h, head_dim=dh,
                              rope_head_dim=dr, absorbed=False)
        np.testing.assert_allclose(np.asarray(oa), np.asarray(ob), atol=2e-5)
