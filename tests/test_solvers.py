"""Solver-layer unit tests (bisection, golden, LM, barrier IPM)."""
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.solvers import BarrierSpec, barrier_solve, bisect, golden_section
from repro.solvers.nls import fit_inverse_frequency, levenberg_marquardt


def test_bisect_root():
    r = bisect(lambda x: x * x - 2.0, 0.0, 2.0)
    assert abs(float(r) - np.sqrt(2)) < 1e-10


@settings(max_examples=20, deadline=None)
@given(st.floats(-3.0, 3.0))
def test_golden_quadratic(c):
    g = golden_section(lambda x: (x - c) ** 2, -5.0, 5.0)
    assert abs(float(g) - c) < 1e-6


def test_lm_fits_inverse_frequency():
    f = jnp.linspace(0.1e9, 1.2e9, 15)
    t = 0.35e9 / f
    res = fit_inverse_frequency(f, t)
    assert abs(float(res.params[0]) - 0.35e9) / 0.35e9 < 1e-6
    assert float(res.residual_norm_sq) < 1e-12


def test_lm_rosenbrock_converges():
    def resid(x):
        return jnp.array([10.0 * (x[1] - x[0] ** 2), 1.0 - x[0]])

    out = levenberg_marquardt(resid, jnp.array([-1.2, 1.0]), iters=200)
    assert np.allclose(np.asarray(out.params), [1.0, 1.0], atol=1e-6)


def test_ipm_matches_scipy():
    scipy = pytest.importorskip("scipy.optimize")
    # min x1^2 + 2 x2^2 + x1 x2  s.t. x1 + x2 = 1, x1 >= 0.1, x2 >= 0.1
    Q = np.array([[2.0, 1.0], [1.0, 4.0]])

    def f(x):
        return 0.5 * x @ Q @ x

    res = scipy.minimize(f, [0.5, 0.5], constraints=[{"type": "eq", "fun": lambda x: x.sum() - 1}],
                         bounds=[(0.1, None), (0.1, None)])
    spec = BarrierSpec(
        objective=lambda z: 0.5 * z @ jnp.asarray(Q) @ z,
        inequalities=lambda z: jnp.array([0.1 - z[0], 0.1 - z[1]]),
        eq_matrix=jnp.array([[1.0, 1.0]]),
        eq_rhs=jnp.array([1.0]),
    )
    out = barrier_solve(spec, jnp.array([0.5, 0.5]))
    assert np.allclose(np.asarray(out.z), res.x, atol=1e-6)
    assert float(out.max_violation) <= 1e-9


def test_ipm_active_inequality():
    spec = BarrierSpec(
        objective=lambda z: (z[0] + 2.0) ** 2,
        inequalities=lambda z: jnp.array([1.0 - z[0], z[0] - 50.0]),
    )
    out = barrier_solve(spec, jnp.array([5.0]))
    assert abs(float(out.z[0]) - 1.0) < 1e-6
