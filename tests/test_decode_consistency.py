"""Decode-path equivalence: step-by-step decode must match full-sequence
forward (ring-buffer caches, SSM recurrence vs chunked scan, MLA cache)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.models import transformer as T

ARCHS = ["tinyllama-1.1b", "mamba2-130m", "hymba-1.5b", "deepseek-v2-lite-16b",
         "stablelm-1.6b"]


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_prefill(arch, rng):
    import dataclasses

    cfg = get_config(arch, smoke=True)
    if cfg.moe:
        # capacity dropping is seq-length dependent; give ample capacity so
        # prefill (S tokens) and decode (1 token) route identically
        cfg = dataclasses.replace(cfg, moe_capacity_factor=16.0)
    params = T.init_params(cfg, rng)
    s = 16 if not (cfg.ssm or cfg.hybrid) else int(cfg.ssm_chunk)  # chunk-divisible
    tokens = jax.random.randint(jax.random.PRNGKey(1), (1, s), 0, cfg.vocab_size)

    # full forward logits at the last position
    batch = {"tokens": tokens}
    full = T.prefill_logits(params, cfg, batch)  # (1, 1, V)

    # token-by-token decode through the ring cache
    cache = T.init_decode_cache(cfg, 1, s + 4, dtype=jnp.float32)
    logits = None
    for pos in range(s):
        logits, cache = T.decode_step(params, cfg, tokens[:, pos:pos + 1], cache,
                                      jnp.int32(pos))
    np.testing.assert_allclose(np.asarray(full[0, -1]), np.asarray(logits[0, -1]),
                               atol=2e-3, rtol=2e-3)


def test_sliding_window_decode_drops_old_tokens(rng):
    """Tokens outside the model's receptive field (L layers × window W)
    must not affect the output of a windowed-cache decode."""
    cfg = get_config("tinyllama-1.1b", smoke=True)
    params = T.init_params(cfg, rng)
    w = 8
    n = 24
    rf = cfg.num_layers * w  # information propagates w per layer
    assert n > rf
    toks_a = jax.random.randint(jax.random.PRNGKey(1), (1, n), 0, cfg.vocab_size)
    toks_b = toks_a.at[:, :n - rf].set((toks_a[:, :n - rf] + 7) % cfg.vocab_size)

    def run(toks):
        cache = T.init_decode_cache(cfg, 1, w, dtype=jnp.float32)
        lg = None
        for pos in range(n):
            lg, cache = T.decode_step(params, cfg, toks[:, pos:pos + 1], cache,
                                      jnp.int32(pos))
        return np.asarray(lg)

    # identical last-w tokens ⇒ identical logits, despite different prefixes
    np.testing.assert_allclose(run(toks_a), run(toks_b), atol=1e-5)
