"""Optional-hypothesis shim for property-based tests.

On a bare jax-only environment (no ``hypothesis``; see
requirements-dev.txt) the ``@given`` tests skip cleanly instead of
breaking collection, while every plain test in the same module still
runs. Test modules use ``from _hyp import given, settings, st``.
"""
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    import pytest

    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """Stands in for ``strategies``: decorators are built at import
        time, so strategy constructors (and chained calls like
        ``.map``/``.filter``) must resolve even when skipped."""

        def __getattr__(self, name):
            return lambda *a, **k: self

    st = _AnyStrategy()

    def given(*args, **kwargs):
        return pytest.mark.skip(reason="hypothesis not installed")

    def settings(*args, **kwargs):
        return lambda f: f
