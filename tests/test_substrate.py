"""Data pipeline, optimizer, train loop, checkpointing."""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config
from repro.data.pipeline import DataConfig, SyntheticTokens, make_batch
from repro.models import transformer as T
from repro.train import checkpoint
from repro.train.loop import train
from repro.train.optimizer import AdamWConfig, apply_updates, init_state, lr_at


def test_data_deterministic_and_learnable():
    d = SyntheticTokens(DataConfig(vocab_size=512, seq_len=32, global_batch=4, seed=3))
    a, b = d.batch(7), d.batch(7)
    np.testing.assert_array_equal(a, b)
    c = d.batch(8)
    assert not np.array_equal(a, c)
    # Markov structure: bigram entropy is far below uniform
    big = d.batch(0)
    assert len(np.unique(big)) < 512


def test_lr_schedule_shape():
    cfg = AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=100)
    assert float(lr_at(cfg, 0)) < 2e-4
    assert abs(float(lr_at(cfg, 10)) - 1e-3) < 2e-4
    assert float(lr_at(cfg, 99)) < float(lr_at(cfg, 50))


def test_adamw_descends_quadratic():
    cfg = AdamWConfig(lr=0.1, warmup_steps=0, total_steps=100, weight_decay=0.0)
    params = {"w": jnp.array([5.0, -3.0])}
    state = init_state(cfg, params)
    for _ in range(60):
        grads = jax.tree.map(lambda p: 2 * p, params)
        params, state, _ = apply_updates(cfg, params, grads, state)
    assert float(jnp.abs(params["w"]).max()) < 0.5


def test_train_loop_reduces_loss():
    cfg = get_config("tinyllama-1.1b", smoke=True)
    _, _, hist = train(cfg, AdamWConfig(lr=2e-3, total_steps=60, warmup_steps=5),
                       60, global_batch=8, seq_len=64, log_every=5, log_fn=lambda *_: None)
    losses = [l for _, l in hist["loss"]]
    assert losses[-1] < losses[0] - 0.1, losses


def test_checkpoint_roundtrip(tmp_path, rng):
    cfg = get_config("mamba2-130m", smoke=True)
    params = T.init_params(cfg, rng)
    path = os.path.join(tmp_path, "ckpt")
    checkpoint.save(path, {"params": params}, step=42)
    like = {"params": jax.tree.map(jnp.zeros_like, params)}
    restored, step = checkpoint.load(path, like)
    assert step == 42
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored["params"]),
                    strict=True):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_modality_stub_batches():
    cfg = get_config("whisper-medium", smoke=True)
    d = SyntheticTokens(DataConfig(cfg.vocab_size, 32, 2, seed=0))
    b = make_batch(cfg, d, 0)
    assert b["frames"].shape == (2, 8, cfg.d_model)
    cfg = get_config("internvl2-2b", smoke=True)
    b = make_batch(cfg, SyntheticTokens(DataConfig(cfg.vocab_size, 32, 2, 0)), 0)
    assert b["patches"].shape == (2, cfg.num_patches, cfg.vision_dim)
