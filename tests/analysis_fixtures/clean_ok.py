"""Clean fixture: idiomatic trace discipline — MUST produce no findings."""
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np


@partial(jax.jit, static_argnames=("policy", "num_iters"))
def plan_clean(fleet, deadline, eps, policy, num_iters):
    if policy == "robust":  # static selector: fine to branch on
        sigma = jnp.sqrt((1.0 - eps) / jnp.maximum(eps, 1e-12))
    else:
        sigma = jnp.zeros_like(eps)
    m1 = fleet.shape[-1]  # shape projection is static
    idx = np.arange(m1)  # np on static shape metadata is fine
    margins = fleet - deadline[..., None] * sigma[..., None]
    best = jnp.argmin(jnp.where(idx[None, :] >= 0, margins, jnp.inf), axis=-1)
    for _ in range(num_iters):  # unrolled loop over a static budget
        best = jnp.minimum(best, m1 - 1)
    return jnp.where(margins.min() < 0, best, best + 1)


def host_report(result):
    # not jit-reachable: host casts are fine here
    return {"best": int(np.asarray(result).max())}
