"""Seeded violation: static/traced contract drift (TRC006)."""
from functools import partial

import jax

_STATICS = ("policy", "deadline")


@partial(jax.jit, static_argnames=_STATICS)
def plan_bad(fleet, deadline, policy):
    # `deadline` is a traced scenario knob by contract: marking it static
    # recompiles per value.
    return fleet, deadline, policy


@jax.jit
def solve_bad(x, policy):
    # `policy` is static by contract but not declared static here.
    return x, policy


@partial(jax.jit, static_argnames=("solver",))
def misnamed(x):
    # static name that is not a parameter at all
    return x
