"""Seeded violation: host materialization of traced values (TRC002)."""
import jax
import numpy as np


@jax.jit
def summarize(x):
    first = x[0].item()  # .item() syncs the device
    arr = np.asarray(x)  # silent host-numpy fallback
    return first + np.sum(arr)
