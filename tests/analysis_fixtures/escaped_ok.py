"""Escape-hatch fixture: justified suppressions silence findings;
an unjustified one is itself a finding (TRC000)."""
import jax
import jax.numpy as jnp


@jax.jit
def monitored(x):
    peak = float(jnp.max(x))  # analyze: ok(TRC001): debug tap, removed under jit in prod
    return x / peak


def shortcut(y):  # analyze: ok(TRC003)
    return y
