"""Seeded violation: host casts of traced values (TRC001).

MUST be flagged by TRC001 — the fixture regression-tests the analyzer.
"""
import jax
import jax.numpy as jnp


def _helper(y):
    # reached through the call graph from the jitted root below
    return float(y) * 2.0


@jax.jit
def energy(x):
    scale = float(x)  # direct host cast of a traced operand
    n = int(jnp.sum(x))  # cast of a jnp result
    return scale * n + _helper(x)
