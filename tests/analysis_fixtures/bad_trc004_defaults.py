"""Seeded violation: mutable / call defaults (TRC004)."""


class Config:
    pass


def accumulate(x, out=[]):  # mutable literal default
    out.append(x)
    return out


def configure(x, cfg=Config(), names={}):  # call default + dict literal
    return x, cfg, names
