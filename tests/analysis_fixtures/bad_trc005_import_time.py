"""Seeded violation: jnp computation at module import time (TRC005)."""
from typing import NamedTuple

import jax.numpy as jnp

GRID = jnp.linspace(0.0, 1.0, 128)  # device work at import


class Result(NamedTuple):
    value: jnp.ndarray = jnp.zeros(())  # class-body default runs at import


def lookup(i):
    return GRID[i]
