"""Seeded violation: Python control flow on traced values (TRC003)."""
import jax
import jax.numpy as jnp


@jax.jit
def clamp(x, lo):
    assert x.ndim == 1  # fine: shape projection is static
    if jnp.min(x) < lo:  # traced comparison driving a Python branch
        x = jnp.maximum(x, lo)
    while jnp.max(x) > 10.0:  # traced while
        x = x * 0.5
    return x if jnp.all(x > 0) else -x  # traced ternary
