"""Recompile-count regression tests (DESIGN.md §analysis).

Protocol, per entry point: a cold call must grow the underlying jit
cache (``_cache_size()``) by exactly 1, and a value-varied same-shaped
repeat inside a :class:`CompileCounter` must trigger zero XLA backend
compiles. A deliberately static-deadline variant pins ``> 1`` so the
counter itself is proven live, not vacuously zero.

Cache keys are (shapes, dtypes, statics), so these tests use a fleet
size and static knobs no other test file warms — the ``== 1`` pins stay
valid under a full-suite run in any order.
"""
from functools import partial

import jax
import jax.numpy as jnp
import pytest

from repro.analysis.jaxpr_audit import CompileCounter, tiny_fleet
from repro.core import api
from repro.core.api import Planner, PlannerConfig, Scenario
from repro.core.montecarlo import violation_report
from repro.core.planner import plan_fixed_partition
from repro.serve.closedloop import GuardConfig, run_closed_loop
from repro.serve.faults import straggler_burst
from repro.serve.guard import SentinelConfig

# pccp_iters=7 is used nowhere else in the suite: together with the
# batch shapes below it makes this file's jit-cache entries unique.
_CFG = PlannerConfig(policy="robust", outer_iters=2, pccp_iters=7)


@pytest.fixture(scope="module")
def fleet():
    return tiny_fleet(3)


@pytest.fixture(scope="module")
def planner():
    return Planner(_CFG)


def _run(planner, fleet, scenarios):
    return jax.block_until_ready(planner.plan_many(fleet, scenarios))


def test_plan_many_8_scenarios_compiles_once(fleet, planner):
    scs = [Scenario(0.15 + 0.01 * i, 0.02, 10e6) for i in range(8)]
    before = api.plan_many_jit._cache_size()
    _run(planner, fleet, scs)
    assert api.plan_many_jit._cache_size() - before == 1, \
        "8 zipped scenarios must be ONE compile, not 8"
    varied = [Scenario(0.21 - 0.005 * i, 0.03, 12e6) for i in range(8)]
    with CompileCounter() as c:
        _run(planner, fleet, varied)
    assert c.count == 0, "value-varied repeat must hit the cache"
    assert api.plan_many_jit._cache_size() - before == 1


def test_grid_3x3_compiles_once(fleet, planner):
    # K=9 is a new batch shape: exactly one more cache entry
    before = api.plan_many_jit._cache_size()
    jax.block_until_ready(
        planner.grid(fleet, [0.16, 0.18, 0.20], [0.01, 0.02, 0.05], 10e6))
    assert api.plan_many_jit._cache_size() - before == 1, \
        "a 3x3 sweep must be ONE compile, not 9"
    with CompileCounter() as c:
        jax.block_until_ready(
            planner.grid(fleet, [0.17, 0.19, 0.21], [0.02, 0.03, 0.04], 12e6))
    assert c.count == 0, "value-varied sweep must hit the cache"
    assert api.plan_many_jit._cache_size() - before == 1


def test_closed_loop_escalation_compiles_once():
    """One escalating serving run: the per-step MC probe and the
    price-rung replan each compile exactly once across all steps; a
    second run under a different fault draw recompiles nothing."""
    fleet = tiny_fleet(5)  # n=5: shapes no other test file warms
    sc = Scenario(0.25, 0.05, 10e6)
    guard = GuardConfig(sentinel=SentinelConfig(window=256, alpha=1e-3,
                                                min_count=32),
                        max_rung=1)  # price rung only: a closed ladder
    planner = Planner(_CFG)
    sched = straggler_burst(10, start=1, length=9, prob=0.5, extra_s=0.25)
    vr0 = violation_report._cache_size()
    pfp0 = plan_fixed_partition._cache_size()
    r1 = run_closed_loop(fleet, sc, sched, planner, jax.random.PRNGKey(3),
                         requests_per_step=48, guard=guard)
    assert r1.replans >= 1, "the drill must actually escalate"
    assert violation_report._cache_size() - vr0 == 1, \
        "10 steps of varying faults must reuse ONE compiled probe"
    assert plan_fixed_partition._cache_size() - pfp0 == 1, \
        "contingency build + price-rung replans share ONE compile"
    sched2 = straggler_burst(10, start=1, length=9, prob=0.6, extra_s=0.3)
    with CompileCounter() as c:
        r2 = run_closed_loop(fleet, sc, sched2, planner,
                             jax.random.PRNGKey(7), requests_per_step=48,
                             guard=guard)
    assert r2.replans >= 1
    assert c.count == 0, "a fresh fault draw must not recompile anything"


def test_per_node_capacity_is_traced_not_a_cache_key(fleet):
    """Multi-edge placement (DESIGN.md §placement): an (E,) capacity
    vector — and the (K, E) batch rows — are traced operands of the same
    compiled program; varying node budgets (including zeroing a node out,
    i.e. removing it) must not recompile."""
    # pccp_iters=8 is unique to this test: fresh cache entries
    planner = Planner(PlannerConfig(policy="robust", outer_iters=2,
                                    pccp_iters=8))
    caps0 = jnp.asarray([0.08, 0.05, 0.03])
    scs = [Scenario(0.15 + 0.01 * i, 0.02, 10e6, caps0) for i in range(4)]
    before = api.plan_many_jit._cache_size()
    _run(planner, fleet, scs)
    assert api.plan_many_jit._cache_size() - before == 1, \
        "4 scenarios sharing one (E,) capacity shape must be ONE compile"
    varied = [Scenario(0.16 + 0.01 * i, 0.03, 12e6,
                       jnp.asarray([0.06, 0.07, 0.0])) for i in range(4)]
    with CompileCounter() as c:
        _run(planner, fleet, varied)
    assert c.count == 0, \
        "value-varied node budgets (incl. an absent node) must hit the cache"
    assert api.plan_many_jit._cache_size() - before == 1


def test_static_deadline_variant_recompiles(fleet):
    """The anti-pattern TRC006 exists to catch: marking the deadline (a
    traced scenario knob) static recompiles per value — and proves the
    CompileCounter actually observes XLA backend compiles."""
    @partial(jax.jit, static_argnames=("deadline",))
    def bad_entry(fleet, m_sel, deadline):  # analyze: ok(TRC006): deliberate anti-pattern under test
        plan = plan_fixed_partition(fleet, m_sel, jnp.asarray(deadline),
                                    0.05, 10e6)
        return plan.total_energy

    m_sel = jnp.ones(fleet.num_devices, jnp.int32)
    with CompileCounter() as c:
        for d in (0.18, 0.20, 0.22):
            jax.block_until_ready(bad_entry(fleet, m_sel, deadline=d))
    assert bad_entry._cache_size() == 3, "one cache entry per deadline value"
    assert c.count > 1, "static deadline must recompile per value"


def test_plan_sharded_compiles_once_per_group_shape():
    """Group-sharded planning (``core.decompose``): the per-group
    programs compile once per distinct (chain width, lane bucket) shape
    — the two populations of a mixed fleet are two entries each — and a
    value-varied repeat (new scenario, new gains) triggers zero XLA
    backend compiles and grows no program cache."""
    from repro.configs.paper_tables import mixed_spec
    from repro.core import decompose

    # pccp_iters=9 is unique to this test: a fresh per-group program set
    # whose cache growth is exactly attributable to this file
    planner = Planner(PlannerConfig(policy="robust_exact", outer_iters=2,
                                    pccp_iters=9))
    spec = mixed_spec(10)  # 5 alexnet (9 pts) + 5 resnet152 (10 pts)
    before = decompose.program_cache_sizes()
    p1 = planner.plan_sharded(spec, Scenario(0.2, 0.04, 30e6),
                              key=jax.random.PRNGKey(0))
    jax.block_until_ready(p1.total_energy)
    after = decompose.program_cache_sizes()
    # programs whose inputs carry the chain-width axis: one compile per
    # distinct (M_g, n-bucket) shape — two populations, two entries
    for name in ("group_prep", "group_partition"):
        assert after[name] - before.get(name, 0) == 2, \
            f"{name}: one compile per distinct group shape, not per device"
    # the λ-probe programs only see the width-free AllocPrep lanes: both
    # populations share (S, n_bucket) here, so ONE program serves both
    for name in ("group_bsum", "group_solve"):
        assert after[name] - before.get(name, 0) == 1, \
            f"{name}: width-free lane shapes must share one program"
    with CompileCounter() as c:
        p2 = planner.plan_sharded(spec, Scenario(0.21, 0.05, 28e6),
                                  key=jax.random.PRNGKey(1))
        jax.block_until_ready(p2.total_energy)
    assert c.count == 0, "value-varied sharded repeat must hit the cache"
    assert decompose.program_cache_sizes() == after
