"""Trace-driven workload replay (DESIGN.md §robustness): seeded arrival
processes, the compiled epoch sampler, the guarded replay loop with
per-node faults + migration, regret-vs-oracle pairing, and the
engine-backed mode.

The incident fixture reproduces the ``bench_replay`` drill at test
scale: a per-node brownout on the node holding most of the plan's
devices, replayed unguarded / guarded / oracle over one shared trace
and key stream, so the A/B/or claims (unguarded exceeds ε, guarded
migrates and recovers, oracle bounds both) are pinned in CI.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.paper_tables import alexnet_fleet, mixed_spec
from repro.core import Planner, PlannerConfig, Scenario
from repro.core.resource import select_point
from repro.serve import replay as rp
from repro.serve.closedloop import GuardConfig
from repro.serve.faults import FaultState, brownout, identity_schedule, state_at
from repro.serve.guard import SentinelConfig

SC = Scenario(0.25, 0.05, 10e6)


@pytest.fixture(scope="module")
def fleet():
    return alexnet_fleet(jax.random.PRNGKey(0), 8)


@pytest.fixture(scope="module")
def planner():
    return Planner(PlannerConfig(policy="robust_exact", outer_iters=3,
                                 pccp_iters=6))


@pytest.fixture(scope="module")
def plan(fleet, planner):
    return planner.plan(fleet, SC)


# ---------------------------------------------------------------------------
# traces
# ---------------------------------------------------------------------------


def test_poisson_trace_deterministic_and_sorted():
    a = rp.poisson_trace(rate_per_epoch=32.0, epochs=10, epoch_s=1.0,
                         num_devices=4, seed=3)
    b = rp.poisson_trace(rate_per_epoch=32.0, epochs=10, epoch_s=1.0,
                         num_devices=4, seed=3)
    np.testing.assert_array_equal(a.arrival_s, b.arrival_s)
    np.testing.assert_array_equal(a.device_id, b.device_id)
    assert (np.diff(a.arrival_s) >= 0).all()
    assert a.device_id.min() >= 0 and a.device_id.max() < 4
    assert a.nominal_per_epoch == 32.0
    # a different seed moves the stream
    c = rp.poisson_trace(rate_per_epoch=32.0, epochs=10, epoch_s=1.0,
                         num_devices=4, seed=4)
    assert c.num_requests != a.num_requests \
        or not np.array_equal(a.arrival_s, c.arrival_s)


def test_trace_epoch_bounds_partition_and_capacity():
    tr = rp.poisson_trace(rate_per_epoch=20.0, epochs=12, epoch_s=0.5,
                          num_devices=3, seed=0)
    b = tr.epoch_bounds()
    assert b.shape == (13,) and b[0] == 0 and b[-1] == tr.num_requests
    assert (np.diff(b) >= 0).all()
    counts = np.diff(b)
    # each epoch's slice really holds that epoch's arrivals
    for t in range(12):
        sl = tr.arrival_s[b[t]:b[t + 1]]
        assert np.all(sl >= t * 0.5) and np.all(sl < (t + 1) * 0.5)
    assert tr.max_per_epoch == counts.max()
    cap = tr.capacity
    assert cap >= tr.max_per_epoch and cap & (cap - 1) == 0  # power of two


def test_diurnal_trace_peak_exceeds_trough():
    tr = rp.diurnal_trace(rate_per_epoch=100.0, epochs=20, epoch_s=1.0,
                          num_devices=4, seed=1, swing=0.9)
    counts = np.diff(tr.epoch_bounds())
    # one period over the horizon: sin > 0 on the first half
    assert counts[:10].sum() > counts[10:].sum()
    assert tr.nominal_per_epoch == 100.0  # the normalizer stays the mean rate
    with pytest.raises(ValueError, match="swing"):
        rp.diurnal_trace(rate_per_epoch=10.0, epochs=4, epoch_s=1.0,
                         num_devices=2, seed=0, swing=1.5)


def test_bursty_trace_bursts_exceed_calm_rate():
    tr = rp.bursty_trace(rate_per_epoch=30.0, epochs=60, epoch_s=1.0,
                         num_devices=4, seed=2, burst_factor=6.0,
                         p_enter=0.25, p_exit=0.3)
    counts = np.diff(tr.epoch_bounds())
    # the normalizer stays the CALM rate, so a burst genuinely congests
    assert tr.nominal_per_epoch == 30.0
    assert counts.max() > 3 * 30.0  # seeded: at least one real burst epoch


def test_population_mix_probabilities_and_validation():
    p = rp.population_mix([2, 3], [0.6, 0.4])
    assert p.shape == (5,)
    np.testing.assert_allclose(p.sum(), 1.0, rtol=1e-12)
    np.testing.assert_allclose(p[:2], 0.3)  # 0.6 spread over 2 devices
    np.testing.assert_allclose(p[2:], 0.4 / 3)
    # a zero-weight population receives no traffic
    q = rp.population_mix([1, 1], [1.0, 0.0])
    np.testing.assert_allclose(q, [1.0, 0.0])
    with pytest.raises(ValueError, match="counts"):
        rp.population_mix([0, 2], [0.5, 0.5])
    with pytest.raises(ValueError, match=">= 0"):
        rp.population_mix([1, 1], [0.5, -0.5])
    with pytest.raises(ValueError, match="positive weight"):
        rp.population_mix([1, 1], [0.0, 0.0])


# ---------------------------------------------------------------------------
# sample_epoch: the compiled request-granular ground truth
# ---------------------------------------------------------------------------


def _epoch_args(plan, n=8, capacity=16, key=0):
    dev = jnp.asarray(np.arange(capacity) % n, jnp.int32)
    valid = jnp.ones(capacity, bool)
    return dict(key=jax.random.PRNGKey(key), m_sel=plan.m_sel,
                alloc=plan.alloc, deadline=SC.deadline,
                device_ids=dev, valid=valid, rounds=2.0)


def test_sample_epoch_identity_faults_bit_identical_to_none(fleet, plan):
    """Same discipline as ``violation_report``: the identity state takes
    the faulted code path yet must not move a single bit."""
    kw = _epoch_args(plan)
    base = rp.sample_epoch(fleet=fleet, **kw)
    ident = rp.sample_epoch(fleet=fleet, faults=FaultState.identity(), **kw)
    for got, want in zip(ident, base, strict=True):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_sample_epoch_padding_and_counts(fleet, plan):
    kw = _epoch_args(plan)
    full = rp.sample_epoch(fleet=fleet, **kw)
    np.testing.assert_array_equal(
        np.asarray(full.count),
        np.bincount(np.asarray(kw["device_ids"]), minlength=8))
    # masking the tail removes exactly its contribution — same key, same
    # per-slot samples, so the valid mask is the only difference
    half = dict(kw, valid=jnp.asarray(np.arange(16) < 8))
    part = rp.sample_epoch(fleet=fleet, **half)
    assert float(part.count.sum()) == 8.0
    assert float(part.energy_j) < float(full.energy_j)
    assert np.all(np.asarray(part.obs_vm) <= np.asarray(full.obs_vm) + 1e-15)
    np.testing.assert_array_equal(np.asarray(part.total_s),
                                  np.asarray(full.total_s))


def test_sample_epoch_deadline_scores_requests(fleet, plan):
    kw = _epoch_args(plan)
    generous = rp.sample_epoch(fleet=fleet, **dict(kw, deadline=1e9))
    assert bool(np.asarray(generous.met).all())
    hopeless = rp.sample_epoch(fleet=fleet, **dict(kw, deadline=1e-9))
    assert not bool(np.asarray(hopeless.met).any())
    assert np.all(np.asarray(generous.total_s) > 0)


def test_sample_epoch_per_node_congestion_targets_faded_node(fleet, plan):
    """Shrinking ONE node's capacity must stretch only that node's
    devices: gamma moment-matching is scale-equivariant, so with a
    shared key the other nodes' samples are bit-identical."""
    kw = _epoch_args(plan)
    t_vm = np.asarray(select_point(fleet, plan.m_sel).t_vm)
    assert (t_vm > 0).any()
    offload_dev = int(np.argmax(t_vm))
    # put the most-offloading device alone on node 2, everyone else spread
    assignment = jnp.asarray(np.where(np.arange(8) == offload_dev, 2,
                                      np.arange(8) % 2), jnp.int32)
    roomy = jnp.asarray([1e9, 1e9, 1e9])
    choked = jnp.asarray([1e9, 1e9, 1e-4])
    a = rp.sample_epoch(fleet=fleet, edge_capacity_s=roomy,
                        assignment=assignment, **kw)
    b = rp.sample_epoch(fleet=fleet, edge_capacity_s=choked,
                        assignment=assignment, **kw)
    on_node = np.asarray(kw["device_ids"]) == offload_dev
    np.testing.assert_array_equal(np.asarray(a.total_s)[~on_node],
                                  np.asarray(b.total_s)[~on_node])
    assert np.all(np.asarray(b.total_s)[on_node]
                  > np.asarray(a.total_s)[on_node])


def test_sample_epoch_per_node_cap_requires_assignment(fleet, plan):
    kw = _epoch_args(plan)
    with pytest.raises(ValueError, match="assignment"):
        rp.sample_epoch(fleet=fleet, edge_capacity_s=jnp.asarray([1.0, 1.0]),
                        **kw)


def test_sample_epoch_one_program_across_varied_epochs(fleet, plan):
    """Value-varied epochs — different counts, devices, fault depths,
    rounds — must reuse ONE compiled program (the trace capacity is the
    only shape)."""
    sched = brownout(8, start=2, length=4, depth=0.3, node=1, num_nodes=3)
    assignment = jnp.asarray(np.arange(8) % 3, jnp.int32)
    caps = jnp.asarray([0.5, 0.4, 0.3])
    kw = _epoch_args(plan)
    rp.sample_epoch(fleet=fleet, edge_capacity_s=caps, faults=state_at(sched, 0),
                    assignment=assignment, **kw)
    cache0 = rp.sample_epoch._cache_size()
    varied = dict(kw, key=jax.random.PRNGKey(9),
                  device_ids=jnp.asarray(np.arange(16) % 5, jnp.int32),
                  valid=jnp.asarray(np.arange(16) < 11), rounds=7.0)
    rp.sample_epoch(fleet=fleet, edge_capacity_s=0.5 * caps,
                    faults=state_at(sched, 3), assignment=assignment, **varied)
    assert rp.sample_epoch._cache_size() == cache0


# ---------------------------------------------------------------------------
# the replay loop: quiet traces, the incident A/B, regret
# ---------------------------------------------------------------------------


def test_replay_identity_trace_sentinel_fp_rate(fleet, planner):
    """Satellite: on a long no-fault trace the guarded loop must stay
    quiet — the sentinel's per-window trip probability is ≤ α by the
    exact binomial tail, so over T=120 windows at α=1e-3 the expected
    trip count is 0.12 (seeded: exactly zero), and the ladder never
    acts on the healthy plan."""
    trace = rp.poisson_trace(rate_per_epoch=64.0, epochs=120, epoch_s=1.0,
                             num_devices=8, seed=11)
    r = rp.replay(fleet, SC, identity_schedule(120), planner, trace,
                  jax.random.PRNGKey(2), guarded=True)
    assert int(r.tripped.sum()) == 0
    assert r.replans == 0 and r.churn == 0 and r.migrations == 0
    assert r.final_window_rate <= SC.eps
    assert len(r.stats.deadline_flags) == trace.num_requests
    assert int(r.epoch_requests.sum()) == trace.num_requests


# -- the bench_replay incident at test scale --------------------------------

EPOCHS, FAULT_START = 32, 8
MN_SC = (0.2, 0.04, 30e6)  # deadline, eps, B — the bench_replay scenario


@pytest.fixture(scope="module")
def incident():
    fleet = mixed_spec(8).build(jax.random.PRNGKey(11))
    planner = Planner(PlannerConfig(policy="robust_exact", outer_iters=3,
                                    pccp_iters=6))
    slack = planner.plan(fleet, Scenario(*MN_SC))
    occ0 = float(select_point(fleet, slack.m_sel).t_vm.sum())
    caps = jnp.asarray((0.2, 0.1, 0.05)) * occ0
    sc = Scenario(*MN_SC, caps)
    p0 = planner.plan(fleet, sc)
    node = int(np.argmax(np.bincount(np.asarray(p0.assignment),
                                     minlength=3)))
    sched = brownout(EPOCHS, start=FAULT_START, length=EPOCHS - FAULT_START,
                     depth=0.03, node=node, num_nodes=3)
    trace = rp.poisson_trace(rate_per_epoch=96.0, epochs=EPOCHS, epoch_s=1.0,
                             num_devices=8, seed=7)
    guard = GuardConfig(sentinel=SentinelConfig(window=256, alpha=1e-3,
                                                min_count=48))
    key = jax.random.PRNGKey(5)
    runs = {
        "unguarded": rp.replay(fleet, sc, sched, planner, trace, key,
                               guarded=False, guard=guard),
        "guarded": rp.replay(fleet, sc, sched, planner, trace, key,
                             guarded=True, guard=guard),
        "oracle": rp.replay(fleet, sc, sched, planner, trace, key,
                            guard=guard, oracle=True),
    }
    return dict(runs=runs, trace=trace, node=node, eps=MN_SC[1])


def test_replay_unguarded_exceeds_eps(incident):
    ung = incident["runs"]["unguarded"]
    assert ung.final_window_rate > incident["eps"]
    assert ung.replans == 0 and ung.migrations == 0
    assert ung.migration_energy_j == 0.0 and ung.overhead_j.sum() == 0.0


def test_replay_guarded_migrates_and_recovers(incident):
    grd = incident["runs"]["guarded"]
    assert grd.final_window_rate <= incident["eps"]
    assert grd.replans >= 1 and bool(grd.tripped.any())
    # the per-node re-fit shrank the browned-out node's budget, so the
    # re-plan's hybrid allocator moved its devices — and paid for it
    assert grd.migrations > 0
    assert grd.migration_energy_j > 0.0
    np.testing.assert_allclose(grd.overhead_j.sum(), grd.migration_energy_j,
                               rtol=1e-12)
    assert grd.total_violations \
        < incident["runs"]["unguarded"].total_violations


def test_replay_oracle_bounds_and_regret(incident):
    grd = incident["runs"]["guarded"]
    orc = incident["runs"]["oracle"]
    # clairvoyant: re-planned at t=0 (identity) and at the fault onset
    assert orc.replans >= 2
    assert orc.total_violations <= grd.total_violations
    regret = rp.regret_curves(grd, orc)
    assert regret["violations"].shape == (EPOCHS,)
    assert regret["energy_j"].shape == (EPOCHS,)
    assert regret["final_violations"] \
        == grd.total_violations - orc.total_violations
    assert regret["final_violations"] >= 0
    np.testing.assert_allclose(regret["violations"][-1],
                               regret["final_violations"])


def test_regret_curves_reject_mismatched_horizons(incident):
    grd = incident["runs"]["guarded"]
    short = rp.ReplayResult(
        epoch_rate=np.zeros(3), window_rate=np.zeros(3),
        tripped=np.zeros(3, bool), rung=np.zeros(3, np.int32),
        energy_j=np.zeros(3), overhead_j=np.zeros(3),
        epoch_violations=np.zeros(3, np.int64),
        epoch_requests=np.zeros(3, np.int64),
        replans=0, churn=0, migrations=0, migration_energy_j=0.0)
    with pytest.raises(ValueError, match="horizon"):
        rp.regret_curves(grd, short)


def test_replay_telemetry_is_consistent(incident):
    """The engine-shaped outcome stream and the per-epoch logs must tell
    the same story: re-counting violations from the flags reproduces
    ``epoch_violations`` exactly."""
    trace = incident["trace"]
    r = incident["runs"]["unguarded"]
    assert int(r.epoch_requests.sum()) == trace.num_requests
    flags = np.asarray(r.stats.deadline_flags, bool)
    assert flags.shape == (trace.num_requests,)
    b = trace.epoch_bounds()
    for t in range(EPOCHS):
        miss = int((~flags[b[t]:b[t + 1]]).sum())
        assert miss == int(r.epoch_violations[t])
    served = r.epoch_requests > 0
    assert np.all(r.energy_j[served] > 0)
    assert np.all(np.isnan(r.epoch_rate[~served]))


# ---------------------------------------------------------------------------
# engine-backed replay (the real ServingEngine at smoke scale)
# ---------------------------------------------------------------------------


def test_replay_engine_drives_real_engine_and_refits():
    from repro.configs.registry import get_config
    from repro.models import transformer as T
    from repro.models.costmodel import block_chain_from_config

    cfg = get_config("tinyllama-1.1b", smoke=True)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    eng = rp.ServingEngine(cfg, params, max_batch=2, window=64)
    trace = rp.poisson_trace(rate_per_epoch=3.0, epochs=2, epoch_s=1.0,
                             num_devices=1, seed=1)
    assert trace.num_requests > 0
    chain = block_chain_from_config(cfg, seq_len=64)
    summary, sentinel, refit = rp.replay_engine(
        eng, trace, seed=0, deadline_s=30.0, prompt_tokens=4,
        max_new_tokens=3, eps=0.5, chain=chain)
    assert summary["requests_completed"] == trace.num_requests
    # every completion reached the sentinel through the window counts
    assert sentinel.counts[1] == trace.num_requests
    assert not sentinel.tripped()  # generous SLO: nothing missed
    assert summary["deadline_met_rate"] == 1.0
    # §IV online path: the measured decode mean anchored the edge tier
    assert refit is not None
    np.testing.assert_allclose(float(refit.t_vm[0]),
                               summary["decode_mean_s"], rtol=1e-6)
