"""MoE layer: routing, capacity dispatch, load-balance loss."""
import jax
import jax.numpy as jnp
import numpy as np
from _hyp import given, settings, st

from repro.models.moe import _capacity, moe_apply, moe_init


def _layer(rng, d=64, e=8, ff=128, shared=1):
    return moe_init(rng, d, e, ff, shared, 96, jnp.float32)


def test_capacity_dispatch_matches_dense_with_ample_capacity(rng):
    p = _layer(rng)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 64))
    oc, auxc = moe_apply(p, x, top_k=2, capacity_factor=8.0)
    od, auxd = moe_apply(p, x, top_k=2, dispatch="dense")
    np.testing.assert_allclose(np.asarray(oc), np.asarray(od), atol=1e-4)
    assert abs(float(auxc) - float(auxd)) < 1e-6


def test_dropping_under_tight_capacity_changes_some_tokens(rng):
    p = _layer(rng)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 64, 64))
    o_tight, _ = moe_apply(p, x, top_k=2, capacity_factor=0.5)
    o_ample, _ = moe_apply(p, x, top_k=2, capacity_factor=8.0)
    assert not np.allclose(np.asarray(o_tight), np.asarray(o_ample), atol=1e-5)
    assert bool(jnp.isfinite(o_tight).all())


def test_load_balance_loss_range(rng):
    """Aux loss is ≥ 1 (perfect balance → 1) for a softmax router."""
    p = _layer(rng)
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 64, 64))
    _, aux = moe_apply(p, x, top_k=2)
    assert 0.9 < float(aux) < 8.0


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 4096), st.integers(1, 8), st.integers(2, 64))
def test_capacity_formula(tokens, k, e):
    c = _capacity(tokens, k, e, 1.25)
    assert c >= 4
    assert c * e >= tokens * k  # 1.25 overprovision never loses pigeonhole room
