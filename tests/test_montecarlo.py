"""Moment matching of ``montecarlo._sample_matched`` (satellite task).

The planner's guarantee is distribution-free given (mean, variance), so
the Monte-Carlo validator must actually *hit* the requested moments for
every family it claims to sample. Gamma and lognormal match exactly by
construction; truncnorm is **approximate** — it clips a moment-matched
normal at zero, which biases the mean up and shrinks the variance, with
the bias growing with the coefficient of variation (documented here: at
cv ≤ 0.8 the relative mean bias is ≤ ~4%, E[max(X,0)] − μ =
σφ(μ/σ) − μΦ(−μ/σ) ≥ 0).

Property tests (hypothesis, via the ``_hyp`` shim) sweep (mean, cv)
with a *fixed* PRNG key, so every example is deterministic; plain
parametrized tests keep coverage when hypothesis is absent.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hyp import given, settings, st
from repro.core.montecarlo import _sample_matched

N_SAMPLES = 200_000
KEY = jax.random.PRNGKey(42)

MEANS = st.floats(min_value=1e-3, max_value=5.0)
CVS = st.floats(min_value=0.05, max_value=0.8)


def _draw(dist, mean, cv):
    var = (cv * mean) ** 2
    x = _sample_matched(KEY, dist, jnp.float64(mean), jnp.float64(var),
                        (N_SAMPLES,))
    return np.asarray(x), var


@pytest.mark.parametrize("dist", ["gamma", "lognormal"])
@given(mean=MEANS, cv=CVS)
@settings(max_examples=10, deadline=None)
def test_exact_families_match_both_moments(dist, mean, cv):
    x, var = _draw(dist, mean, cv)
    assert np.isfinite(x).all() and (x >= 0.0).all()
    np.testing.assert_allclose(x.mean(), mean, rtol=0.02)
    np.testing.assert_allclose(x.var(), var, rtol=0.12)


@given(mean=MEANS, cv=CVS)
@settings(max_examples=10, deadline=None)
def test_truncnorm_matches_approximately_with_positive_mean_bias(mean, cv):
    x, var = _draw("truncnorm", mean, cv)
    assert (x >= 0.0).all()
    sigma = np.sqrt(var)
    alpha = mean / sigma
    # analytic clipping bias of max(N(mean, var), 0)
    from math import erf, exp, pi, sqrt

    phi = exp(-0.5 * alpha**2) / sqrt(2 * pi)
    Phi_neg = 0.5 * (1.0 - erf(alpha / sqrt(2.0)))
    bias = sigma * phi - mean * Phi_neg
    assert bias >= 0.0
    se = sigma / np.sqrt(N_SAMPLES)
    assert abs(x.mean() - (mean + bias)) <= 6.0 * se  # matches *clipped* moments
    assert x.mean() >= mean - 6.0 * se  # bias never pulls the mean down
    assert abs(x.mean() - mean) <= 0.05 * mean + 6.0 * se  # ≤ ~4% at cv ≤ 0.8
    assert x.var() <= var * 1.05  # clipping only shrinks the variance


@pytest.mark.parametrize("dist", ["gamma", "lognormal", "truncnorm"])
def test_fixed_case_moments(dist):
    """Hypothesis-free smoke pin: one representative (mean, cv) per family."""
    x, var = _draw(dist, 0.15, 0.3)
    rtol_mean = 0.03 if dist == "truncnorm" else 0.01
    np.testing.assert_allclose(x.mean(), 0.15, rtol=rtol_mean)
    np.testing.assert_allclose(x.var(), var, rtol=0.15)


def test_unknown_dist_raises():
    with pytest.raises(ValueError, match="unknown dist"):
        _sample_matched(KEY, "cauchy", 1.0, 1.0, (8,))
