"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention
from repro.kernels.ops import flash_attention_bshd
from repro.kernels.ref import flash_attention_ref, rmsnorm_residual_ref, ssd_scan_ref
from repro.kernels.rmsnorm import rmsnorm_residual
from repro.kernels.ssd_scan import ssd_scan

TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


@pytest.mark.parametrize("b,hq,hkv,s,dh", [
    (2, 4, 2, 256, 64),
    (1, 2, 2, 128, 128),
    (1, 8, 1, 256, 64),
    (2, 4, 4, 384, 32),
])
@pytest.mark.parametrize("causal,window", [(True, 0), (True, 128), (False, 0)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(b, hq, hkv, s, dh, causal, window, dtype, rng):
    ks = jax.random.split(rng, 3)
    q = jax.random.normal(ks[0], (b, hq, s, dh), dtype)
    k = jax.random.normal(ks[1], (b, hkv, s, dh), dtype)
    v = jax.random.normal(ks[2], (b, hkv, s, dh), dtype)
    blk = min(128, s)
    out = flash_attention(q, k, v, causal=causal, window=window, blk_q=blk, blk_k=blk)
    ref = flash_attention_ref(q.astype(jnp.float32), k.astype(jnp.float32),
                              v.astype(jnp.float32), causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(ref),
                               atol=TOL[dtype], rtol=TOL[dtype])


@pytest.mark.parametrize("b,s,h,p,n,cs", [
    (2, 128, 4, 32, 16, 32),
    (1, 256, 2, 64, 128, 64),
    (2, 64, 8, 16, 8, 16),
    (1, 128, 3, 48, 32, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32])
def test_ssd_scan_sweep(b, s, h, p, n, cs, dtype, rng):
    ks = jax.random.split(rng, 5)
    x = jax.random.normal(ks[0], (b, s, h, p), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.5)
    bm = jax.random.normal(ks[3], (b, s, n))
    cm = jax.random.normal(ks[4], (b, s, n))
    out = ssd_scan(x, dt, a, bm, cm, chunk=cs)
    ref = ssd_scan_ref(x, dt, a, bm, cm, cs)
    scale = float(jnp.max(jnp.abs(ref))) + 1e-6
    np.testing.assert_allclose(np.asarray(out) / scale, np.asarray(ref) / scale, atol=1e-5)


@pytest.mark.parametrize("rows,d", [(64, 128), (100, 256), (3, 512), (1024, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_residual_sweep(rows, d, dtype, rng):
    ks = jax.random.split(rng, 3)
    x = jax.random.normal(ks[0], (rows, d), dtype)
    r = jax.random.normal(ks[1], (rows, d), dtype)
    sc = (jax.random.normal(ks[2], (d,)) * 0.1).astype(dtype)
    y, nr = rmsnorm_residual(x, r, sc)
    yr, nrr = rmsnorm_residual_ref(x, r, sc)
    np.testing.assert_allclose(np.asarray(y, np.float32), np.asarray(yr, np.float32),
                               atol=TOL[dtype])
    np.testing.assert_allclose(np.asarray(nr, np.float32), np.asarray(nrr, np.float32),
                               atol=TOL[dtype])


def test_bshd_wrapper_pads_odd_lengths(rng):
    q = jax.random.normal(rng, (2, 100, 4, 64))
    k = jax.random.normal(jax.random.PRNGKey(1), (2, 100, 2, 64))
    v = jax.random.normal(jax.random.PRNGKey(2), (2, 100, 2, 64))
    out = flash_attention_bshd(q, k, v)
    ref = flash_attention_ref(jnp.moveaxis(q, 1, 2), jnp.moveaxis(k, 1, 2),
                              jnp.moveaxis(v, 1, 2))
    ref = jnp.moveaxis(ref, 1, 2).reshape(2, 100, 256)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def test_flash_matches_model_attention(rng):
    """Kernel ↔ model-layer reference agreement (end-to-end wiring check)."""
    from repro.models.attention import causal_mask, sdpa

    b, s, h, hkv, dh = 1, 128, 4, 2, 64
    ks = jax.random.split(rng, 3)
    q = jax.random.normal(ks[0], (b, s, h, dh))
    k = jax.random.normal(ks[1], (b, s, hkv, dh))
    v = jax.random.normal(ks[2], (b, s, hkv, dh))
    model_out = sdpa(q, k, v, causal_mask(s))
    kern_out = flash_attention_bshd(q, k, v)
    np.testing.assert_allclose(np.asarray(model_out), np.asarray(kern_out), atol=2e-5)
