"""shard_map all-to-all MoE: correctness on a real multi-device mesh.

Runs in a subprocess because the 8-device host override must be set
before jax initializes (the main pytest process keeps 1 device).
"""
import os
import subprocess
import sys
import textwrap

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys; sys.path.insert(0, "src")
    import jax, jax.numpy as jnp
    from repro.models.moe import moe_init, moe_apply
    from repro.parallel import sharding as shd

    try:  # axis_types only exists on newer jax (>= 0.5)
        mesh = jax.make_mesh((2, 4), ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,) * 2)
    except (TypeError, AttributeError):
        mesh = jax.make_mesh((2, 4), ("data", "model"))
    shd.set_activation_mesh(mesh)
    key = jax.random.PRNGKey(0)
    ctx = jax.set_mesh(mesh) if hasattr(jax, "set_mesh") else mesh

    # E = 8 = 2*4 (full expert axes) and E = 4 (model-only)
    for e, shared in ((8, 1), (4, 0)):
        p = moe_init(key, 32, e, 64, shared, 48, jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, 32))
        with ctx:
            oa, _ = jax.jit(lambda p, x: moe_apply(
                p, x, top_k=2, capacity_factor=16.0, dispatch="a2a"))(p, x)
        od, _ = moe_apply(p, x, top_k=2, capacity_factor=16.0, dispatch="dense")
        err = float(jnp.abs(oa - od).max())
        assert err < 1e-4, (e, err)

        # The loss touches BOTH outputs: on jax 0.4.x a purely-unused aux
        # output gets a symbolic Zero cotangent that the shard_map pmean
        # transpose cannot handle ('Zero' has no attribute 'reshape').
        def loss(p):
            out, aux = jax.jit(lambda p, x: moe_apply(
                p, x, top_k=2, capacity_factor=16.0, dispatch="a2a"))(p, x)
            return jnp.sum(out ** 2) + 0.0 * aux
        with ctx:
            g = jax.grad(loss)(p)
        assert all(bool(jnp.isfinite(v).all()) for v in jax.tree.leaves(g)), e
    print("A2A_OK")
""")


def test_a2a_matches_dense_on_8_device_mesh():
    proc = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        capture_output=True, text=True, timeout=600,
        cwd=os.path.join(os.path.dirname(__file__), ".."),
        env=dict(os.environ, PYTHONPATH="src"),
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "A2A_OK" in proc.stdout
