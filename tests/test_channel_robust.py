"""Joint inference-time + channel-state uncertainty (paper footnote 2).

The paper assumes perfect CSI and notes the method "can be extended to
scenarios that jointly consider inference time and channel state
uncertainty" — this is that extension: the offload time inherits variance
from the fading channel (delta method), enters the ECR variance term, and
the planner's guarantee must survive lognormal channel draws.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.paper_tables import alexnet_fleet
from repro.core import plan, violation_report
from repro.core.channel import offload_time, offload_time_std, pathloss_gain

CV = 0.3  # 30% channel-gain jitter


def test_delta_method_matches_monte_carlo():
    d, b, p = 1.44e6, 1.0e6, 1.0
    h = pathloss_gain(150.0)
    std = float(offload_time_std(d, b, p, h, CV))
    rng = np.random.default_rng(0)
    s2 = np.log1p(CV**2)
    hs = float(h) * np.exp(rng.normal(-0.5 * s2, np.sqrt(s2), 200_000))
    ts = np.asarray(offload_time(d, b, p, jnp.asarray(hs)))
    assert abs(std - ts.std()) / ts.std() < 0.15  # delta method, small-cv


@pytest.fixture(scope="module")
def fleet():
    return alexnet_fleet(jax.random.PRNGKey(0), 6)


def test_channel_robust_plan_keeps_guarantee(fleet):
    p = plan(fleet, 0.2, 0.04, 10e6, policy="robust_exact", outer_iters=3,
             channel_cv=CV)
    assert bool(p.feasible.all())
    vr = violation_report(jax.random.PRNGKey(5), fleet, p.m_sel, p.alloc, 0.2,
                          num_samples=20000, var_scale=1.0, channel_cv=CV)
    assert float(vr.rate.max()) <= 0.04 + 0.005


def test_channel_oblivious_plan_pays_under_fading(fleet):
    """Ignoring channel uncertainty yields a cheaper plan whose margin is
    thinner under fading; the channel-robust plan costs more energy."""
    p0 = plan(fleet, 0.2, 0.04, 10e6, policy="robust_exact", outer_iters=3)
    p1 = plan(fleet, 0.2, 0.04, 10e6, policy="robust_exact", outer_iters=3,
              channel_cv=CV)
    assert float(p1.total_energy) >= float(p0.total_energy) - 1e-9
    v0 = violation_report(jax.random.PRNGKey(6), fleet, p0.m_sel, p0.alloc, 0.2,
                          num_samples=20000, var_scale=1.0, channel_cv=CV)
    v1 = violation_report(jax.random.PRNGKey(6), fleet, p1.m_sel, p1.alloc, 0.2,
                          num_samples=20000, var_scale=1.0, channel_cv=CV)
    # robust-to-channel plan never violates more than the oblivious one
    assert float(v1.rate.max()) <= float(v0.rate.max()) + 1e-9
    assert float(v1.rate.max()) <= 0.04 + 0.005
