"""Analyzer tier (DESIGN.md §analysis).

Three guarantees, each regression-tested:

1. every seeded-violation fixture under ``tests/analysis_fixtures/`` is
   flagged by exactly its intended rule (the analyzer itself cannot
   silently rot);
2. the repo's compiled surface is clean (zero findings) — every host-
   side escape carries a justified ``# analyze: ok`` annotation;
3. the jaxpr layer's graph checks hold on the real entry points: no
   callbacks, no weak types, contract dtypes, bounded constants, and
   stable pytree flattenings.
"""
from pathlib import Path

import pytest

from repro.analysis import contracts
from repro.analysis.astcheck import analyze_files, analyze_repo
from repro.analysis.rules import RULES, parse_suppressions

FIXTURES = Path(__file__).parent / "analysis_fixtures"


def _rules_for(name):
    fs = analyze_files([FIXTURES / name], surface=False)
    return fs, {f.rule for f in fs}


# --------------------------------------------------------------- layer 1


@pytest.mark.parametrize("name,rule", [
    ("bad_trc001_host_cast.py", "TRC001"),
    ("bad_trc002_materialize.py", "TRC002"),
    ("bad_trc003_branch.py", "TRC003"),
    ("bad_trc004_defaults.py", "TRC004"),
    ("bad_trc005_import_time.py", "TRC005"),
    ("bad_trc006_static_drift.py", "TRC006"),
])
def test_seeded_fixture_is_flagged_by_its_rule(name, rule):
    findings, rules = _rules_for(name)
    assert rules == {rule}, (
        f"{name} must be flagged by {rule} only, got {rules}: "
        + "; ".join(f.render() for f in findings))
    assert len(findings) >= 2, "each fixture seeds multiple violation sites"


def test_trc001_reaches_through_the_call_graph():
    findings, _ = _rules_for("bad_trc001_host_cast.py")
    assert any(f.func == "_helper" for f in findings), (
        "a helper called from a jitted root must be analyzed too")


def test_trc005_covers_class_bodies():
    findings, _ = _rules_for("bad_trc005_import_time.py")
    assert len(findings) == 2  # module-level GRID and the class-body default


def test_trc006_catches_all_three_drift_modes():
    findings, _ = _rules_for("bad_trc006_static_drift.py")
    msgs = " | ".join(f.message for f in findings)
    assert "traced scenario knob" in msgs  # traced marked static
    assert "not in static_argnames" in msgs  # static left traced
    assert "not a parameter" in msgs  # dead static name


def test_clean_fixture_has_no_findings():
    findings, _ = _rules_for("clean_ok.py")
    assert findings == []


def test_escape_hatch_suppresses_and_requires_reason():
    findings, rules = _rules_for("escaped_ok.py")
    assert "TRC001" not in rules, "justified ok() must suppress"
    assert rules == {"TRC000"}, "an ok() without a reason is a finding"


def test_suppression_parser():
    sup = parse_suppressions(
        "x = 1  # analyze: ok(TRC001): reasoned\n"
        "y = 2  # analyze: ok(TRC002,TRC003): multi\n"
        "z = 3  # analyze: ok(TRC004)\n")
    assert sup.allows(1, "TRC001") and not sup.allows(1, "TRC002")
    assert sup.allows(2, "TRC002") and sup.allows(2, "TRC003")
    assert not sup.allows(3, "TRC004") and sup.unjustified == [3]
    assert parse_suppressions("# analyze: skip-file: reference port\n").skip_file
    assert not parse_suppressions("# analyze: skip-file\n").skip_file


def test_def_level_suppression_covers_nested_defs(tmp_path):
    src = (
        "import jax\n"
        "@jax.jit\n"
        "def outer(x):  # analyze: ok(TRC001): fixture-wide justification\n"
        "    def inner(y):\n"
        "        return float(y)\n"
        "    return inner(x) + float(x)\n")
    p = tmp_path / "mod.py"
    p.write_text(src)
    assert analyze_files([p], surface=False) == []


def test_repo_surface_is_clean():
    findings = analyze_repo()
    assert findings == [], "repo must be analyzer-clean:\n" + "\n".join(
        f.render() for f in findings)


def test_every_rule_has_a_fixture_or_unit_test():
    covered = {"TRC000", "TRC001", "TRC002", "TRC003", "TRC004", "TRC005",
               "TRC006"}
    assert covered == set(RULES), "new rules need fixtures + tests"


def test_contract_name_sets_are_disjoint():
    overlap = contracts.TRACED_PARAM_NAMES & contracts.STATIC_PARAM_NAMES
    assert not overlap, f"a name cannot be both traced and static: {overlap}"


# --------------------------------------------------------------- layer 2


@pytest.fixture(scope="module")
def traced_entries():
    from repro.analysis.jaxpr_audit import _trace_entries

    return _trace_entries(n=3)


def test_entry_points_have_no_callbacks_or_dtype_leaks(traced_entries):
    from repro.analysis.jaxpr_audit import audit_jaxpr

    bad = []
    for name, closed in traced_entries:
        audit = audit_jaxpr(closed, entry=name)
        bad += [p.render() for p in audit.problems]
    assert bad == [], "\n".join(bad)


def test_const_budget_is_tight_enough_to_catch_a_fleet(traced_entries):
    import jax
    import jax.numpy as jnp

    from repro.analysis.jaxpr_audit import audit_jaxpr, tiny_fleet

    # the real entries stay well under budget...
    for name, closed in traced_entries:
        audit = audit_jaxpr(closed, entry=name)
        assert audit.const_bytes <= contracts.CONST_BYTE_BUDGET
    # ...and a deliberately-leaked profile table blows it
    leaked = jnp.zeros((256, 64), jnp.float64)  # a "fleet table" closure
    closed = jax.make_jaxpr(lambda x: (x[None, None] + leaked).sum())(1.0)
    audit = audit_jaxpr(closed, entry="leaky")
    assert any(p.kind == "const_budget" for p in audit.problems)
    del tiny_fleet  # imported for parity with run_audit; unused here


def test_pytree_contracts_match_reality():
    import jax

    from repro.analysis.jaxpr_audit import check_pytree_contract, tiny_fleet
    from repro.core.api import Planner, PlannerConfig, Scenario
    from repro.serve.faults import FaultState

    fleet = tiny_fleet(3)
    sc = Scenario(deadline=0.18, eps=0.02, B=10e6).normalized(3)
    plan = Planner(PlannerConfig(policy="robust")).plan(fleet, sc)
    for name, tree in [("Scenario", sc), ("Plan", plan),
                       ("Allocation", plan.alloc),
                       ("FaultState", FaultState.identity())]:
        probs = check_pytree_contract(name, tree)
        assert probs == [], "\n".join(p.render() for p in probs)
    del jax


def test_pytree_contract_detects_drift():
    from repro.analysis.jaxpr_audit import check_pytree_contract
    from repro.serve.faults import FaultState

    import jax.numpy as jnp

    drifted = FaultState.identity()._replace(
        cap_scale=jnp.asarray(1.0, jnp.float32))
    probs = check_pytree_contract("FaultState", drifted)
    assert any("cap_scale" in p.detail and "float32" in p.detail
               for p in probs)


def test_plan_dtypes_stable_across_policies():
    """The Plan pytree must flatten identically for every policy — the
    PCCP path's iteration counter regressed to int64 once (x64 default
    from jnp.where arithmetic) which made plans non-interchangeable."""
    from repro.analysis.jaxpr_audit import check_pytree_contract, tiny_fleet
    from repro.core.api import Planner, PlannerConfig, Scenario
    from repro.core.planner import available_policies

    fleet = tiny_fleet(3)
    sc = Scenario(deadline=0.18, eps=0.02, B=10e6).normalized(3)
    for policy in available_policies():
        plan = Planner(PlannerConfig(policy=policy)).plan(fleet, sc)
        probs = check_pytree_contract("Plan", plan)
        assert probs == [], f"policy {policy}: " + "\n".join(
            p.render() for p in probs)
