"""Algorithm 2 planner: paper claims as testable properties."""
import jax
import numpy as np
import pytest

from repro.configs.paper_tables import alexnet_fleet, resnet152_fleet
from repro.core import plan, plan_optimal, violation_report


@pytest.fixture(scope="module")
def fleet():
    return alexnet_fleet(jax.random.PRNGKey(0), 6)


@pytest.fixture(scope="module")
def plans(fleet):
    out = {}
    for pol in ("robust_exact", "worst_case", "gaussian"):
        out[pol] = plan(fleet, 0.2, 0.04, 10e6, policy=pol, outer_iters=4)
    out["optimal"] = plan_optimal(fleet, 0.2, 0.04, 10e6)
    return out


def test_all_feasible(plans):
    for name, p in plans.items():
        assert bool(p.feasible.all()), name


def test_robust_beats_worst_case_at_moderate_risk(fleet):
    pr = plan(fleet, 0.2, 0.08, 10e6, policy="robust_exact", outer_iters=4)
    pw = plan(fleet, 0.2, 0.08, 10e6, policy="worst_case", outer_iters=4)
    assert float(pr.total_energy) < float(pw.total_energy)


def test_optimal_lower_bound(plans):
    assert float(plans["optimal"].total_energy) <= float(plans["robust_exact"].total_energy) + 1e-9


def test_gaussian_cheaper_than_cantelli(plans):
    """Φ⁻¹(1-ε) < √((1-ε)/ε) ⇒ less conservative ⇒ cheaper or equal."""
    assert float(plans["gaussian"].total_energy) <= float(plans["robust_exact"].total_energy) + 1e-9


def test_energy_decreases_with_risk_level(fleet):
    es = [float(plan(fleet, 0.2, e, 10e6, policy="robust_exact", outer_iters=3).total_energy)
          for e in (0.02, 0.05, 0.1)]
    assert es[0] >= es[1] >= es[2]


def test_energy_decreases_with_deadline(fleet):
    es = [float(plan(fleet, d, 0.04, 10e6, policy="robust_exact", outer_iters=3).total_energy)
          for d in (0.18, 0.22, 0.28)]
    assert es[0] >= es[1] >= es[2]


@pytest.mark.parametrize("dist", ["gamma", "lognormal", "truncnorm"])
def test_violation_probability_below_risk(fleet, plans, dist):
    """Fig. 13c/14c: empirical violation ≤ ε for any matched distribution."""
    p = plans["robust_exact"]
    vr = violation_report(jax.random.PRNGKey(7), fleet, p.m_sel, p.alloc, 0.2,
                          dist=dist, num_samples=20000, var_scale=1.0)
    assert float(vr.rate.max()) <= 0.04 + 0.005, dist


def test_pccp_near_exact_and_stationary(fleet):
    """Fig. 12: PCCP is 'very close to optimal'. We assert (i) feasibility,
    (ii) a bounded gap to the exact per-device optimum, and (iii)
    stationarity — PCCP started AT the exact optimum stays there."""
    pe = plan(fleet, 0.2, 0.04, 10e6, policy="robust_exact", outer_iters=3)
    pp = plan(fleet, 0.2, 0.04, 10e6, policy="robust", outer_iters=3, pccp_iters=8)
    assert bool(pp.feasible.all())
    gap = (float(pp.total_energy) - float(pe.total_energy)) / float(pe.total_energy)
    assert gap <= 0.10, gap
    ps = plan(fleet, 0.2, 0.04, 10e6, policy="robust", outer_iters=3, pccp_iters=8,
              init_m=pe.m_sel, multi_start=False)
    assert np.array_equal(np.asarray(ps.m_sel), np.asarray(pe.m_sel))
    assert abs(float(ps.total_energy) - float(pe.total_energy)) < 1e-9


def test_resnet_scenario_end_to_end():
    fleet = resnet152_fleet(jax.random.PRNGKey(2), 6)
    p = plan(fleet, 0.12, 0.04, 30e6, policy="robust_exact", outer_iters=3)
    assert bool(p.feasible.all())
    vr = violation_report(jax.random.PRNGKey(3), fleet, p.m_sel, p.alloc, 0.12)
    assert float(vr.rate.max()) <= 0.04 + 0.005
