"""Property-based tests of the PCCP partitioning solver on random
synthetic instances (hypothesis)."""
import jax.numpy as jnp
import numpy as np
from _hyp import given, settings, st

from repro.core.ccp import sigma_cantelli
from repro.core.pccp import pccp_partition


def _random_instance(seed, n, m1):
    rng = np.random.default_rng(seed)
    e = rng.uniform(0.01, 1.0, (n, m1))
    t = rng.uniform(0.01, 0.15, (n, m1))
    v = rng.uniform(1e-6, 2e-4, (n, m1))
    return jnp.asarray(e), jnp.asarray(t), jnp.asarray(v)


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 10_000), st.integers(2, 5), st.integers(3, 8))
def test_pccp_feasible_and_near_exact(seed, n, m1):
    e, t, v = _random_instance(seed, n, m1)
    eps = jnp.full((n,), 0.05)
    sigma = sigma_cantelli(eps)
    margin = t + sigma[:, None] * jnp.sqrt(v)
    deadline = jnp.asarray(np.quantile(np.asarray(margin), 0.6, axis=1))  # some feasible
    x0 = jnp.ones((n, m1)) / m1
    res = pccp_partition(e, t, v, sigma, deadline, x0, num_iters=8)

    feas_mask = np.asarray(margin <= deadline[:, None] + 1e-9)
    any_feas = feas_mask.any(axis=1)
    m_sel = np.asarray(res.m_sel)
    # 1. whenever a feasible point exists, the chosen point is feasible
    for i in range(n):
        if any_feas[i]:
            assert feas_mask[i, m_sel[i]], (i, m_sel[i])
    # 2. relaxed x stays a distribution
    x = np.asarray(res.x_relaxed)
    assert np.allclose(x.sum(-1), 1.0, atol=1e-5)
    assert (x >= -1e-6).all() and (x <= 1 + 1e-6).all()
    # 3. chosen point exactly matches the per-device exact optimum
    e_np = np.asarray(e)
    for i in range(n):
        if any_feas[i]:
            best = np.where(feas_mask[i], e_np[i], np.inf).argmin()
            assert abs(e_np[i, m_sel[i]] - e_np[i, best]) < 1e-9, (i, m_sel[i], best)


@settings(max_examples=6, deadline=None)
@given(st.integers(0, 10_000))
def test_pccp_iteration_count_reported(seed):
    e, t, v = _random_instance(seed, 3, 5)
    eps = jnp.full((3,), 0.05)
    sigma = sigma_cantelli(eps)
    deadline = jnp.full((3,), 1.0)  # everything feasible
    x0 = jnp.ones((3, 5)) / 5
    res = pccp_partition(e, t, v, sigma, deadline, x0, num_iters=8)
    it = np.asarray(res.iters_to_converge)
    assert ((1 <= it) & (it <= 8)).all()
