"""Serving parameter-layout modes (§Perf A3/C3)."""
from types import SimpleNamespace


from repro.parallel import sharding as shd


class FakeKey:
    def __init__(self, key):
        self.key = key


def _mesh():
    return SimpleNamespace(shape={"data": 16, "model": 16},
                           axis_names=("data", "model"))


def _spec(names, shape, mesh):
    return tuple(shd._leaf_spec(tuple(FakeKey(n) for n in names), shape, mesh))


def test_resident_strips_pure_fsdp_only():
    m = _mesh()
    # in-proj (fsdp, model): resident keeps model, drops data
    spec = _spec(["layers", "attn", "wq"], (22, 2048, 4096), m)
    assert spec == (None, "data", "model")
    # simulate the strip logic via param_shardings' mode handling:
    fs = {"data"}
    stripped = tuple(None if (e is not None and (set(e) if isinstance(e, tuple) else {e}) <= fs)
                     else e for e in spec)
    assert stripped == (None, None, "model")


def test_expert_sharding_survives_resident():
    m = _mesh()
    spec = _spec(["layers", "ff", "w1"], (61, 256, 7168, 2048), m)
    assert spec[1] == ("data", "model")  # expert-parallel, not FSDP
    fs = {"data"}
    entry = spec[1]
    axes = set(entry)
    assert not (axes <= fs)  # resident mode must keep it


def test_expert_axis_candidates_multipod():
    m = SimpleNamespace(shape={"pod": 2, "data": 16, "model": 16},
                        axis_names=("pod", "data", "model"))
    cands = shd.expert_axis_candidates(m)
    assert ("data", "model") in cands  # pod-replicated expert parallelism
    assert cands[-1] == ("model",)
