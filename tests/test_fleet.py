"""Ragged heterogeneous fleets (DESIGN.md §fleet).

Pins the tentpole contracts of the multi-model Fleet core:

- **No-op mask invariant** — an all-valid mask/num_points is numerically
  invisible: planning a masked homogeneous fleet equals planning the same
  arrays with ``valid=None`` leaf-for-leaf (the golden seed plans stay
  pinned by ``test_plan_golden.py`` on top of this).
- **Builder layer** — ``FleetSpec`` composes ``DeviceSpec`` groups into a
  padded fleet; ``broadcast_fleet`` routes through it unchanged.
- **Masked partition enumeration** — at ragged ``M_n`` no entry point
  (exact enumeration, PCCP, optimal baseline) ever selects a padded
  point, and the exact step picks the cheapest *valid* feasible point.
- **One compiled program** — a mixed two-model fleet plans through
  ``Planner.plan`` / ``plan_many`` / ``grid``; mask/num_points are traced
  leaves, so same-shaped mixed fleets hit the jit cache.
- **Reference agreement** — ``planner_ref`` matches the fused path
  bit-exactly on a mixed fleet (acceptance criterion).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.paper_tables import (
    ALEXNET_PLATFORM,
    alexnet_chain,
    alexnet_fleet,
    mixed_fleet,
)
from repro.core import (
    DeviceSpec,
    Fleet,
    FleetSpec,
    Planner,
    PlannerConfig,
    Scenario,
    broadcast_fleet,
    pad_chain,
    violation_report,
)
from repro.core.blocks import Platform
from repro.core.planner import MASK_TIME_S, _point_tables, _exact_partition, plan_multi_jit
from repro.core.planner_ref import plan_reference
from repro.core.resource import allocate, select_point
from repro.core import ccp

B = 30e6
SC = Scenario(0.2, 0.04, B)


@pytest.fixture(scope="module")
def mixed():
    return mixed_fleet(jax.random.PRNGKey(1), 8)


# ---------------------------------------------------------------- builders

def test_fleet_spec_shapes_and_mask(mixed):
    assert mixed.num_devices == 8
    assert mixed.max_points == 10
    npts = np.asarray(mixed.num_points)
    assert npts.tolist() == [9, 9, 9, 9, 10, 10, 10, 10]
    valid = np.asarray(mixed.valid)
    for n in range(8):
        assert valid[n, : npts[n]].all() and not valid[n, npts[n]:].any()
    # padding repeats the terminal point (finite, physically plausible)
    d = np.asarray(mixed.chain.d_bits)
    assert d[0, 9] == d[0, 8]


def test_broadcast_fleet_routes_through_builder():
    chain = alexnet_chain()
    gains = jnp.asarray([1e-9, 2e-9, 3e-9])
    plat = Platform(kappa=ALEXNET_PLATFORM["kappa"],
                    f_min=ALEXNET_PLATFORM["f_min"],
                    f_max=ALEXNET_PLATFORM["f_max"])
    fl = broadcast_fleet(chain, plat, 1.0, gains)
    assert fl.num_devices == 3
    np.testing.assert_array_equal(np.asarray(fl.link.gain), np.asarray(gains))
    np.testing.assert_array_equal(
        np.asarray(fl.chain.w_flops),
        np.broadcast_to(np.asarray(chain.w_flops, np.float64), (3, 9)))
    assert np.asarray(fl.valid).all()
    assert np.asarray(fl.num_points).tolist() == [9, 9, 9]


def test_builder_validation_errors():
    chain = alexnet_chain()
    with pytest.raises(ValueError, match="at least one"):
        FleetSpec(())
    with pytest.raises(ValueError, match="count"):
        DeviceSpec(chain=chain, count=0)
    spec = FleetSpec((DeviceSpec(chain=chain, count=2),))
    with pytest.raises(ValueError, match="gains"):
        spec.build(gains=jnp.ones((3,)))
    with pytest.raises(ValueError, match="PRNG key"):
        spec.build()
    with pytest.raises(ValueError, match="pad"):
        pad_chain(chain, 5)


def test_group_slices_and_names(mixed):
    from repro.configs.paper_tables import mixed_spec

    spec = mixed_spec(8)
    assert spec.group_slices() == [(0, 4), (4, 8)]
    assert spec.device_names() == ["alexnet"] * 4 + ["resnet152"] * 4


# ------------------------------------------------- no-op mask invariant

def test_all_valid_mask_is_numerical_noop():
    """Planning with (all-ones valid, num_points) equals valid=None
    leaf-for-leaf — the invariant that keeps the seed goldens pinned."""
    masked = alexnet_fleet(jax.random.PRNGKey(0), 6)  # built via FleetSpec
    assert masked.valid is not None
    bare = Fleet(chain=masked.chain, platform=masked.platform,
                 link=masked.link)  # same arrays, no mask leaves
    for policy in ("robust_exact", "robust", "optimal"):
        planner = Planner(PlannerConfig(policy=policy, outer_iters=2,
                                        pccp_iters=4))
        pm, pb = planner.plan(masked, SC), planner.plan(bare, SC)
        for lm, lb in zip(jax.tree_util.tree_leaves(pm),
                          jax.tree_util.tree_leaves(pb), strict=True):
            np.testing.assert_array_equal(np.asarray(lm), np.asarray(lb))


# ------------------------------------------------- masked partition steps

def test_masked_tables_sentinel_values(mixed):
    m0 = jnp.minimum(jnp.full((8,), 9, jnp.int32), mixed.num_points - 1)
    al = allocate(mixed, m0, jnp.full((8,), 0.2), jnp.full((8,), 0.04), B)
    e, t, v = _point_tables(mixed, al.b, al.f)
    valid = np.asarray(mixed.valid)
    assert (np.asarray(t)[~valid] == MASK_TIME_S).all()
    assert (np.asarray(v)[~valid] == 0.0).all()
    assert np.isfinite(np.asarray(e)).all()  # finite — PCCP-safe


def test_exact_partition_never_selects_padding(mixed):
    """Masked argmin at ragged M_n: the chosen point is the cheapest valid
    feasible one, verified against a numpy enumeration over valid prefixes."""
    deadline = jnp.full((8,), 0.2)
    eps = jnp.full((8,), 0.04)
    m0 = jnp.minimum(jnp.full((8,), 9, jnp.int32), mixed.num_points - 1)
    al = allocate(mixed, m0, deadline, eps, B)
    e, t, v = _point_tables(mixed, al.b, al.f)
    sigma = ccp.SIGMA_FNS["cantelli"](eps)
    m_sel, feas = _exact_partition(e, t, v, sigma, deadline)
    m_np, npts = np.asarray(m_sel), np.asarray(mixed.num_points)
    assert (m_np < npts).all()
    margin = np.asarray(t) + np.asarray(sigma)[:, None] * np.sqrt(
        np.maximum(np.asarray(v), 0.0)) - np.asarray(deadline)[:, None]
    for n in range(8):
        ok = margin[n, : npts[n]] <= 1e-9
        if ok.any():
            want = np.flatnonzero(ok)[np.argmin(np.asarray(e)[n, : npts[n]][ok])]
            assert m_np[n] == want, n


def test_select_point_clamps_to_device_chain(mixed):
    """A gather at the padded width lands on the device's own terminal
    point, not the padding row."""
    sel = select_point(mixed, jnp.full((8,), 9, jnp.int32))
    want = np.asarray(mixed.chain.w_flops)[
        np.arange(8), np.asarray(mixed.num_points) - 1]
    np.testing.assert_array_equal(np.asarray(sel.w_flops), want)


# ------------------------------------------------- planning entry points

def test_mixed_fleet_plans_all_entry_points(mixed):
    planner = Planner(PlannerConfig(policy="robust_exact", outer_iters=3))
    npts = np.asarray(mixed.num_points)

    p = planner.plan(mixed, SC)
    assert (np.asarray(p.m_sel) < npts).all()
    assert bool(p.feasible.all())

    many = planner.plan_many(mixed, [SC, Scenario(0.25, 0.06, B)])
    assert many.m_sel.shape == (2, 8)
    assert (np.asarray(many.m_sel) < npts[None, :]).all()
    np.testing.assert_array_equal(np.asarray(many.m_sel[0]),
                                  np.asarray(p.m_sel))

    grid = planner.grid(mixed, (0.2, 0.25), 0.04, B)
    assert grid.m_sel.shape == (2, 1, 1, 8)
    assert (np.asarray(grid.m_sel) < npts).all()

    # per-device Monte-Carlo guarantee on the mixed population
    vr = violation_report(jax.random.PRNGKey(3), mixed, p.m_sel, p.alloc,
                          0.2, var_scale=1.0)
    assert float(vr.rate.max()) <= 0.04 + 0.005


@pytest.mark.parametrize("policy", ["robust_exact", "robust"])
def test_reference_matches_fused_on_mixed_fleet(mixed, policy):
    """Acceptance criterion: planner_ref agrees bit-exact with the fused
    path on a ragged fleet."""
    kw = dict(outer_iters=2, pccp_iters=4)
    planner = Planner(PlannerConfig(policy=policy, **kw))
    p = planner.plan(mixed, SC)
    r = plan_reference(mixed, 0.2, 0.04, B, policy=policy, **kw)
    np.testing.assert_array_equal(np.asarray(p.m_sel), np.asarray(r.m_sel))
    assert float(jnp.abs(p.total_energy - r.total_energy)) == 0.0
    np.testing.assert_array_equal(np.asarray(p.alloc.b), np.asarray(r.alloc.b))
    np.testing.assert_array_equal(np.asarray(p.alloc.f), np.asarray(r.alloc.f))
    np.testing.assert_array_equal(np.asarray(p.feasible), np.asarray(r.feasible))


def test_same_shape_mixed_fleets_hit_jit_cache(mixed):
    """mask/num_points are traced leaves, not cache keys: a second mixed
    fleet with the same padded shapes must not retrace."""
    planner = Planner(PlannerConfig(policy="robust_exact", outer_iters=2))
    planner.plan(mixed, SC)
    size = plan_multi_jit._cache_size()
    other = mixed_fleet(jax.random.PRNGKey(7), 8)  # new gains, same shapes
    planner.plan(other, SC)
    assert plan_multi_jit._cache_size() == size


def test_ragged_multi_start_clamps_per_device(mixed):
    """Explicit and spread starts stay inside each device's chain."""
    from repro.core.planner import initial_points

    m0, multi = initial_points(mixed, None, True)
    assert multi and m0.shape[1] == 8
    assert (np.asarray(m0) <= np.asarray(mixed.num_points) - 1).all()
    m0, _ = initial_points(mixed, 9, False)
    np.testing.assert_array_equal(np.asarray(m0),
                                  np.asarray(mixed.num_points) - 1)
