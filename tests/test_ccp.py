"""Chance-constraint reformulation tests (Theorem 1 / ECR)."""
import jax
import jax.numpy as jnp
import numpy as np
from _hyp import given, settings, st

from repro.core import ccp


def test_sigma_values():
    assert abs(float(ccp.sigma_cantelli(0.02)) - np.sqrt(0.98 / 0.02)) < 1e-12
    assert abs(float(ccp.sigma_gaussian(0.5))) < 1e-9
    assert float(ccp.sigma_gaussian(0.02)) < float(ccp.sigma_cantelli(0.02))


@settings(max_examples=15, deadline=None)
@given(st.floats(0.01, 0.3))
def test_sigma_monotone_decreasing_in_eps(eps):
    assert float(ccp.sigma_cantelli(eps)) > float(ccp.sigma_cantelli(eps + 0.05))


@settings(max_examples=10, deadline=None)
@given(
    st.floats(0.02, 0.2),
    st.floats(0.05, 0.5),
    st.floats(0.001, 0.05),
    st.integers(0, 1000),
)
def test_cantelli_guarantee_distribution_free(eps, mean, std, seed):
    """If the ECR margin is satisfied with equality, the violation
    probability must be ≤ ε for ANY distribution with that mean/var."""
    deadline = mean + float(ccp.sigma_cantelli(eps)) * std
    key = jax.random.PRNGKey(seed)
    n = 40000
    # gamma (right-skewed, worst-ish for upper tails among common families)
    k = mean**2 / std**2
    samples = jax.random.gamma(key, k, (n,)) * (std**2 / mean)
    viol = float(jnp.mean(samples > deadline))
    assert viol <= eps + 3.0 / np.sqrt(n), (viol, eps)


def test_margin_formula():
    m = ccp.deterministic_deadline_margin(0.1, 0.0001, 0.02, 0.2)
    expected = 0.1 + np.sqrt(0.98 / 0.02) * 0.01 - 0.2
    assert abs(float(m) - expected) < 1e-12
