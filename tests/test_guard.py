"""Violation sentinel, plan health, solver fail-soft, and the
closed-loop degradation ladder (DESIGN.md §robustness).

The fail-soft tests force a non-finite inner solve by wrapping the
compiled plan entry (monkeypatched at the ``api`` module, where
``Planner.plan`` resolves it) so each ladder rung — dense-solver retry,
incumbent fallback, degraded-with-warning — is exercised for real, not
simulated by hand-built plans.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.paper_tables import alexnet_fleet
from repro.core import (
    PLAN_DEGRADED,
    PLAN_FALLBACK_DENSE,
    PLAN_FALLBACK_INCUMBENT,
    PLAN_OK,
    Planner,
    PlannerConfig,
    Scenario,
    plan_fixed_partition,
    plan_health,
)
import repro.core.api as api
from repro.serve.closedloop import GuardConfig, run_closed_loop
from repro.serve.faults import straggler_burst, identity_schedule
from repro.serve.guard import (
    SentinelConfig,
    ViolationSentinel,
    binom_tail_pvalue,
    cantelli_pvalue,
    contingency_plans,
    inflated_eps,
    pick_contingency,
    plan_margin,
)

SC = Scenario(0.180, 0.02, 10e6)


@pytest.fixture(scope="module")
def fleet():
    return alexnet_fleet(jax.random.PRNGKey(0), 8)


@pytest.fixture(scope="module")
def planner():
    return Planner(PlannerConfig(policy="robust_exact", outer_iters=3,
                                 pccp_iters=6))


@pytest.fixture(scope="module")
def healthy(fleet, planner):
    return planner.plan(fleet, SC)


# ---------------------------------------------------------------------------
# tail tests
# ---------------------------------------------------------------------------


def test_binom_tail_matches_scipy():
    sf = pytest.importorskip("scipy.stats").binom.sf
    for k, n, eps in [(5, 100, 0.02), (1, 10, 0.05), (30, 500, 0.05),
                      (10, 10, 0.5), (2, 2048, 0.001)]:
        np.testing.assert_allclose(binom_tail_pvalue(k, n, eps),
                                   float(sf(k - 1, n, eps)), rtol=1e-10)


def test_binom_tail_edge_cases():
    assert binom_tail_pvalue(0, 100, 0.05) == 1.0
    assert binom_tail_pvalue(5, 0, 0.05) == 1.0
    assert binom_tail_pvalue(11, 10, 0.05) == 0.0
    assert binom_tail_pvalue(1, 10, 0.0) == 0.0
    assert binom_tail_pvalue(1, 10, 1.0) == 1.0


def test_cantelli_upper_bounds_exact_tail():
    for k, n, eps in [(10, 100, 0.05), (40, 200, 0.1), (5, 1000, 0.002)]:
        assert cantelli_pvalue(k, n, eps) >= binom_tail_pvalue(k, n, eps)


# ---------------------------------------------------------------------------
# sentinel
# ---------------------------------------------------------------------------


def test_sentinel_trips_on_genuine_shift():
    s = ViolationSentinel(0.05, SentinelConfig(window=512, alpha=1e-3,
                                               min_count=64))
    s.observe(40, 128)  # 31% observed vs ε = 5%
    assert s.tripped() and s.pvalue() < 1e-6


def test_sentinel_holds_at_nominal_rate():
    s = ViolationSentinel(0.05, SentinelConfig(window=512, alpha=1e-3,
                                               min_count=64))
    s.observe(26, 512)  # 5.1% — consistent with ε
    assert not s.tripped()


def test_sentinel_respects_min_count():
    s = ViolationSentinel(0.05, SentinelConfig(window=512, alpha=1e-3,
                                               min_count=64))
    s.observe(10, 10)  # catastrophic but tiny sample
    assert not s.tripped()


def test_sentinel_window_evicts_and_reset_clears():
    s = ViolationSentinel(0.05, SentinelConfig(window=100, alpha=1e-3,
                                               min_count=10))
    s.observe(50, 50)
    for _ in range(4):
        s.observe(0, 50)
    k, n = s.counts  # the 50-violation batch fell out of the window
    assert k == 0 and n <= 150
    s.observe(5, 10)
    s.reset()
    assert s.counts == (0, 0) and np.isnan(s.rate())


def test_sentinel_observe_outcomes_counts_met_flags():
    """The engine-shaped feed: per-request *met?* bools (exactly what
    ``EngineStats.deadline_flags`` windows hold) land as (k, n) counts."""
    s = ViolationSentinel(0.05, SentinelConfig(window=512, alpha=1e-3,
                                               min_count=4))
    s.observe_outcomes([False, False, False, False, True])
    assert s.counts == (4, 5)
    s.observe_outcomes([])  # empty window: a no-op, not a ValueError
    assert s.counts == (4, 5)
    assert s.tripped()  # 4/5 missed vs ε = 5%


def test_sentinel_false_positive_rate_bounded():
    """At the true rate ε the per-test trip probability is ≤ α by
    construction of the exact tail; check empirically over seeded
    windows (400 trials ⇒ P[>8 trips | α=1e-2] ≈ 2e-2... use 5σ)."""
    rng = np.random.default_rng(0)
    cfg = SentinelConfig(window=256, alpha=1e-2, min_count=256)
    trips = 0
    trials = 400
    for _ in range(trials):
        s = ViolationSentinel(0.05, cfg)
        s.observe(int(rng.binomial(256, 0.05)), 256)
        trips += int(s.tripped())
    bound = trials * cfg.alpha
    assert trips <= bound + 5 * np.sqrt(bound)


def test_sentinel_validation():
    with pytest.raises(ValueError, match="eps"):
        ViolationSentinel(0.0)
    with pytest.raises(ValueError, match="violations"):
        ViolationSentinel(0.05).observe(5, 2)
    with pytest.raises(ValueError, match="alpha"):
        SentinelConfig(alpha=1.5)
    with pytest.raises(ValueError, match="test"):
        SentinelConfig(test="bayes")


# ---------------------------------------------------------------------------
# plan health + fixed-partition + contingencies
# ---------------------------------------------------------------------------


def test_plan_health_verdicts(fleet, healthy):
    ok, reason = plan_health(healthy)
    assert ok, reason
    bad = healthy._replace(total_energy=jnp.asarray(jnp.nan))
    ok, reason = plan_health(bad)
    assert not ok and "finite" in reason
    degraded = healthy._replace(status=jnp.asarray(PLAN_DEGRADED, jnp.int32))
    assert not plan_health(degraded)[0]
    # fallback statuses are *healthy* — they already are the recovery
    fb = healthy._replace(status=jnp.asarray(PLAN_FALLBACK_DENSE, jnp.int32))
    assert plan_health(fb)[0]
    batched = jax.tree_util.tree_map(lambda x: jnp.stack([x, x]), healthy)
    with pytest.raises(ValueError, match="batched"):
        plan_health(batched)


def test_plan_fixed_partition_respects_m(fleet):
    m = jnp.full((8,), 3, jnp.int32)
    p = plan_fixed_partition(fleet, m, 0.25, 0.05, 10e6)
    np.testing.assert_array_equal(np.asarray(p.m_sel), np.asarray(m))
    assert int(p.status) in (PLAN_OK, PLAN_DEGRADED)
    # scalar m broadcasts and clamps to each device's own chain
    p8 = plan_fixed_partition(fleet, jnp.int32(10**6), 0.25, 0.05, 10e6)
    np.testing.assert_array_equal(
        np.asarray(p8.m_sel), np.asarray(fleet.points_per_device - 1))


def test_inflated_eps_properties():
    np.testing.assert_allclose(inflated_eps(0.05, 1.0), 0.05, rtol=1e-12)
    assert inflated_eps(0.05, 1.5) < 0.05  # more σ ⇒ rarer allowed misses
    assert 0.0 < inflated_eps(0.05, 3.0) < inflated_eps(0.05, 1.5)


def test_contingency_plans_shapes_and_pick(fleet, healthy):
    cont = contingency_plans(fleet, 0.25, 0.05, 10e6)
    np.testing.assert_array_equal(
        np.asarray(cont["local_only"].m_sel),
        np.asarray(fleet.points_per_device - 1))
    np.testing.assert_array_equal(np.asarray(cont["full_offload"].m_sel),
                                  np.zeros(8, np.int32))
    picked = pick_contingency(cont, fleet, 0.25, 0.05)
    # on the nominal fleet the smaller-margin candidate wins
    margins = {k: float(plan_margin(fleet, p, 0.25, 0.05))
               for k, p in cont.items()}
    best = min(margins, key=lambda k: (margins[k], k))
    np.testing.assert_array_equal(np.asarray(picked.m_sel),
                                  np.asarray(cont[best].m_sel))


def test_pick_contingency_keeps_incumbent_when_all_worse(fleet, healthy):
    """At a deadline where neither precomputed shape is feasible the
    incumbent must win — rung 3 never installs a known-worse plan."""
    cont = contingency_plans(fleet, SC.deadline, SC.eps, SC.B)
    assert not any(bool(np.all(np.asarray(p.feasible)))
                   for p in cont.values())
    picked = pick_contingency(cont, fleet, SC.deadline, SC.eps,
                              incumbent=healthy)
    np.testing.assert_array_equal(np.asarray(picked.m_sel),
                                  np.asarray(healthy.m_sel))


# ---------------------------------------------------------------------------
# solver fail-soft (forced non-finite inner solve)
# ---------------------------------------------------------------------------


def _poisoning_entry(real_entry, poison_solvers):
    """Wrap a compiled plan entry: solves whose static ``solver`` is in
    ``poison_solvers`` come back with a NaN energy (as if the inner
    barrier diverged); everything else is the real result."""
    def entry(fleet, d, e, b, cap, m0, **statics):
        p = real_entry(fleet, d, e, b, cap, m0, **statics)
        if statics["solver"] in poison_solvers:
            return p._replace(total_energy=p.total_energy * jnp.nan)
        return p
    return entry


def test_fail_soft_dense_retry(fleet, monkeypatch):
    monkeypatch.setattr(
        api, "plan_multi_jit",
        _poisoning_entry(api.plan_multi_jit, {"structured"}))
    planner = Planner(PlannerConfig(policy="robust_exact", outer_iters=3,
                                    pccp_iters=6))
    with pytest.warns(RuntimeWarning, match="dense"):
        p = planner.plan(fleet, SC)
    assert int(p.status) == PLAN_FALLBACK_DENSE
    assert np.isfinite(float(p.total_energy))


def test_fail_soft_incumbent_fallback(fleet, healthy, monkeypatch):
    monkeypatch.setattr(
        api, "plan_multi_jit",
        _poisoning_entry(api.plan_multi_jit, {"structured", "dense"}))
    planner = Planner(PlannerConfig(policy="robust_exact", outer_iters=3,
                                    pccp_iters=6))
    with pytest.warns(RuntimeWarning, match="incumbent"):
        p = planner.plan(fleet, SC, incumbent=healthy)
    assert int(p.status) == PLAN_FALLBACK_INCUMBENT
    np.testing.assert_array_equal(np.asarray(p.m_sel),
                                  np.asarray(healthy.m_sel))


def test_fail_soft_degraded_without_incumbent(fleet, monkeypatch):
    monkeypatch.setattr(
        api, "plan_multi_jit",
        _poisoning_entry(api.plan_multi_jit, {"structured", "dense"}))
    planner = Planner(PlannerConfig(policy="robust_exact", outer_iters=3,
                                    pccp_iters=6))
    with pytest.warns(RuntimeWarning, match="degraded"):
        p = planner.plan(fleet, SC)
    assert not np.isfinite(float(p.total_energy))


def test_fail_soft_off_and_on_identical_when_healthy(fleet):
    """A healthy solve must be returned unchanged: guard on/off plans are
    leaf-identical (the golden suite pins the guarded default, this pins
    the equivalence)."""
    mk = lambda fs: Planner(PlannerConfig(
        policy="robust_exact", outer_iters=3, pccp_iters=6, fail_soft=fs))
    a = mk(True).plan(fleet, SC)
    b = mk(False).plan(fleet, SC)
    for x, y in zip(jax.tree_util.tree_leaves(a),
                    jax.tree_util.tree_leaves(b), strict=True):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_fail_soft_skipped_under_tracing(fleet):
    """`plan` inside jit must not try host-side health checks."""
    planner = Planner(PlannerConfig(policy="robust_exact", outer_iters=2,
                                    pccp_iters=4))

    @jax.jit
    def traced(deadline):
        return planner.plan(fleet, Scenario(deadline, 0.02, 10e6)).total_energy

    assert np.isfinite(float(traced(jnp.asarray(0.18))))


def test_planner_hot_path_under_debug_nans(fleet):
    """The planner's compiled path must be NaN-free end to end — run it
    with jax_debug_nans armed (which raises on any NaN intermediate the
    moment it is produced)."""
    jax.config.update("jax_debug_nans", True)
    try:
        planner = Planner(PlannerConfig(policy="robust_exact", outer_iters=2,
                                        pccp_iters=4))
        p = planner.plan(fleet, Scenario(0.2, 0.05, 10e6))
        assert np.isfinite(float(p.total_energy))
    finally:
        jax.config.update("jax_debug_nans", False)


# ---------------------------------------------------------------------------
# closed loop
# ---------------------------------------------------------------------------


def test_closed_loop_quiet_schedule_never_acts(fleet, planner):
    r = run_closed_loop(fleet, Scenario(0.25, 0.05, 10e6),
                        identity_schedule(6), planner,
                        jax.random.PRNGKey(0), requests_per_step=32)
    assert r.replans == 0 and r.churn == 0
    assert r.first_trip_step is None
    assert not r.tripped.any()
    assert r.step_rate.shape == (6,) and r.rung.max() == 0


def test_closed_loop_guard_recovers_incident(fleet, planner):
    """A sustained straggler incident: unguarded stays in violation,
    the guarded ladder restores the window rate ≤ ε."""
    sched = straggler_burst(16, start=2, length=14, prob=0.5, extra_s=0.2)
    sc = Scenario(0.25, 0.05, 10e6)
    guard = GuardConfig(sentinel=SentinelConfig(window=512, alpha=1e-3,
                                                min_count=64))
    key = jax.random.PRNGKey(1)
    ung = run_closed_loop(fleet, sc, sched, planner, key,
                          requests_per_step=32, guarded=False, guard=guard)
    grd = run_closed_loop(fleet, sc, sched, planner, key,
                          requests_per_step=32, guarded=True, guard=guard)
    assert ung.final_window_rate > 0.05
    assert grd.final_window_rate <= 0.05
    assert grd.replans >= 1 and grd.first_trip_step is not None
    assert grd.recovery_steps is not None
