"""End-to-end system behaviour: the paper's technique driving two-tier
serving of zoo architectures, engine measurement feedback, and the
cost-model bridge."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.models import transformer as T
from repro.models.costmodel import block_chain_from_config, model_flops_per_token
from repro.serve.engine import Request, ServingEngine
from repro.serve.partitioned import TwoTierDeployment, measured_chain


def test_cost_model_matches_2n_for_dense():
    for arch in ("tinyllama-1.1b", "stablelm-1.6b", "minitron-4b"):
        cfg = get_config(arch)
        fl = model_flops_per_token(cfg, seq_len=512)
        n = T.param_count(cfg)
        assert 0.8 * 2 * n < fl < 1.6 * 2 * n, arch


def test_block_chain_structure():
    chain = block_chain_from_config(get_config("tinyllama-1.1b"), seq_len=256)
    w = np.asarray(chain.w_flops)
    assert (np.diff(w) > 0).all()  # cumulative work increases
    t_vm = np.asarray(chain.t_vm)
    assert (np.diff(t_vm) < 1e-12).all()  # edge share decreases
    assert float(chain.t_vm[-1]) == 0.0
    assert float(chain.w_flops[0]) == 0.0


@pytest.mark.parametrize("arch", ["internvl2-2b", "mamba2-130m", "deepseek-v2-lite-16b"])
def test_two_tier_deployment_plans_and_validates(arch):
    dep = TwoTierDeployment(get_config(arch), num_devices=5, deadline_s=2.0,
                            eps=0.05, bandwidth_hz=100e6)
    p, fleet = dep.plan()
    rep = dep.validate(p, fleet)
    assert rep["max_violation"] <= dep.eps + 0.01
    assert rep["total_energy_j"] >= 0.0
    assert bool(p.feasible.all())


def test_validate_scores_grid_cells_against_their_own_deadline():
    """A grid sweep's cells must be validated against their cell deadline,
    not silently against the deployment scalar (the old behaviour)."""
    from repro.core import plan_at

    dep = TwoTierDeployment(get_config("mamba2-130m"), num_devices=4,
                            deadline_s=2.0, eps=0.05, bandwidth_hz=100e6)
    deadlines = (0.5, 2.0)
    grid, fleet = dep.plan_grid(deadlines=deadlines, policy="robust_exact",
                                outer_iters=3)
    for i, d in enumerate(deadlines):
        p = plan_at(grid, i, 0, 0)
        rep = dep.validate(p, fleet, deadline=d)
        assert rep["max_violation"] <= dep.eps + 0.01, d
    # default arg keeps the old behaviour (deployment scalar)
    p = plan_at(grid, 1, 0, 0)
    assert dep.validate(p, fleet) == dep.validate(p, fleet, deadline=2.0)
    # per-device deadlines validate per device (Scenario leaves may be (N,))
    from repro.core import Scenario, scenario_at

    dls = jnp.linspace(1.0, 2.0, dep.num_devices)
    het, fleet = dep.plan_many([Scenario(dls, dep.eps, dep.bandwidth_hz)],
                               policy="robust_exact", outer_iters=3)
    rep = dep.validate(scenario_at(het, 0), fleet, deadline=dls)
    assert rep["max_violation"] <= dep.eps + 0.01


def test_serving_engine_batches_and_measures(rng):
    cfg = get_config("tinyllama-1.1b", smoke=True)
    params = T.init_params(cfg, rng)
    eng = ServingEngine(cfg, params, max_batch=3, window=64)
    reqs = [Request(uid=i, prompt=np.arange(4 + i) % cfg.vocab_size,
                    max_new_tokens=3, deadline_s=0.5 + 0.1 * i) for i in range(5)]
    done, stats = eng.run(reqs)
    assert len(done) == 5
    assert all(len(r.output) == 3 for r in done)
    assert stats["decode_mean_s"] > 0

    # engine measurements feed the planner's chain (paper §IV online path)
    chain = block_chain_from_config(cfg, seq_len=64)
    updated = measured_chain(chain, stats)
    assert float(updated.t_vm[0]) == pytest.approx(stats["decode_mean_s"], rel=1e-6)
    assert bool(jnp.all(updated.v_vm >= 0))


def test_deadline_aware_scheduling(rng):
    cfg = get_config("tinyllama-1.1b", smoke=True)
    params = T.init_params(cfg, rng)
    eng = ServingEngine(cfg, params, max_batch=2, window=32)
    reqs = [Request(uid=i, prompt=np.ones(3, np.int32), deadline_s=d)
            for i, d in enumerate([0.9, 0.1, 0.5])]
    groups = eng.schedule(reqs)
    assert [r.uid for r in groups[0]] == [1, 2]  # earliest deadlines first


def test_congested_edge_regime_robust_beats_worst_case():
    """DESIGN.md §edge: with a shared (contended) edge the planner prices
    VM occupancy — offloading exactly up to the capacity, keeping the rest
    on-device — and the robust policy still saves ≥20% energy vs the
    worst-case baseline under the same probabilistic deadline."""
    from repro.core.resource import select_point
    from repro.models.costmodel import TierProfile

    dep = TwoTierDeployment(
        get_config("tinyllama-1.1b"), num_devices=8, deadline_s=0.45,
        eps=0.05, bandwidth_hz=60e6, seq_len=512, dedicated_vm=False,
        device=TierProfile(flops_per_cycle=4000.0, cv=0.10, eff_jitter=0.10),
        edge=TierProfile(flops_per_cycle=8000.0, cv=0.08, eff_jitter=0.05,
                         clock_hz=1.5e9),
        f_max_hz=2.5e9,
    )
    p, fleet = dep.plan(policy="robust_exact")
    pw, _ = dep.plan(policy="worst_case")
    assert bool(p.feasible.all())
    # the capacity binds: the edge price is active, total occupancy fits
    # the budget, and the fleet splits into on-device and offload groups
    # (static N-scaling forced *everyone* local here)
    occ = float(select_point(fleet, p.m_sel).t_vm.sum())
    assert occ <= dep.edge_capacity() * (1 + 1e-9)
    assert float(p.alloc.mu) > 0.0
    assert int(p.m_sel.max()) > 0  # some work stays on-device
    assert int(p.m_sel.min()) == 0  # capacity headroom is actually used
    saving = (float(pw.total_energy) - float(p.total_energy)) / float(pw.total_energy)
    assert saving > 0.20, saving
    rep = dep.validate(p, fleet)  # congestion-aware MC ground truth
    assert rep["max_violation"] <= dep.eps + 0.01
