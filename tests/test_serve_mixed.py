"""Mixed-population two-tier deployments (DESIGN.md §fleet).

A 60/40 tinyllama-on-Jetson + mamba2-on-phone population sharing one
bandwidth budget plans as ONE ragged fleet in one compiled program, and
validates per device against the probabilistic deadline.
"""
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.core import Scenario
from repro.models.costmodel import PHONE_TIER
from repro.serve.partitioned import (
    MixedTwoTierDeployment,
    Population,
    TwoTierDeployment,
)


def _mixed(num_devices=5, **kw):
    return MixedTwoTierDeployment(
        populations=(
            Population(get_config("tinyllama-1.1b"), fraction=0.6,
                       name="tinyllama-jetson"),
            Population(get_config("mamba2-130m"), fraction=0.4,
                       device=PHONE_TIER, f_max_hz=1.0e9,
                       name="mamba2-phone"),
        ),
        num_devices=num_devices, bandwidth_hz=100e6, deadline_s=2.0,
        eps=0.05, **kw)


def test_counts_largest_remainder():
    assert _mixed(5).counts() == [3, 2]
    assert _mixed(10).counts() == [6, 4]
    assert _mixed(2).counts() == [1, 1]
    # fractions that don't divide evenly still sum to N
    dep = MixedTwoTierDeployment(
        populations=(Population(get_config("mamba2-130m"), fraction=1 / 3),
                     Population(get_config("mamba2-130m"), fraction=2 / 3)),
        num_devices=7)
    assert dep.counts() == [2, 5] and sum(dep.counts()) == 7
    # floors overshooting N: tiny fractions are kept at their 1-device
    # floor and the big group absorbs the decrement (regression: the
    # overshoot argmax must skip groups already at 1)
    cfg = get_config("mamba2-130m")
    dep = MixedTwoTierDeployment(
        populations=(Population(cfg, fraction=0.05),
                     Population(cfg, fraction=0.05),
                     Population(cfg, fraction=0.9)),
        num_devices=3)
    assert dep.counts() == [1, 1, 1]


def _counts_of(fractions, num_devices):
    cfg = get_config("mamba2-130m")
    dep = MixedTwoTierDeployment(
        populations=tuple(Population(cfg, fraction=f, name=f"p{i}")
                          for i, f in enumerate(fractions)),
        num_devices=num_devices)
    return dep.counts()


def test_counts_properties():
    """Property-style pinning of the apportionment: counts sum to
    ``num_devices``, every population keeps >= 1 device, and the result is
    permutation-equivariant when the fractional remainders are distinct."""
    import itertools
    import random

    rng = random.Random(4)
    for _ in range(25):
        k = rng.randint(1, 5)
        raw = [rng.uniform(0.05, 1.0) for _ in range(k)]
        fractions = [r / sum(raw) for r in raw]
        n = rng.randint(k, 4 * k)
        counts = _counts_of(fractions, n)
        assert sum(counts) == n, (fractions, n, counts)
        assert min(counts) >= 1

    # permutation equivariance (distinct remainders => no ties in play)
    fractions = [0.11, 0.26, 0.63]
    n = 13
    base = _counts_of(fractions, n)
    for perm in itertools.permutations(range(3)):
        permuted = _counts_of([fractions[i] for i in perm], n)
        assert permuted == [base[i] for i in perm], (perm, permuted, base)


def test_counts_remainder_ties_are_deterministic():
    """Equal remainders hand the extra device to the lower index —
    explicit, not an accident of sort stability."""
    assert _counts_of([0.25, 0.25, 0.25, 0.25], 6) == [2, 2, 1, 1]
    assert _counts_of([0.5, 0.5], 5) == [3, 2]


def test_mixed_fleet_is_ragged():
    dep = _mixed(5)
    fleet = dep.fleet()
    assert fleet.num_devices == 5
    assert np.asarray(fleet.num_points).shape == (5,)
    assert dep.spec().device_names() == (["tinyllama-jetson"] * 3
                                         + ["mamba2-phone"] * 2)
    # per-population platforms land on the right devices
    f_max = np.asarray(fleet.platform.f_max)
    assert (f_max[:3] == 1.4e9).all() and (f_max[3:] == 1.0e9).all()


def test_mixed_population_plans_and_validates_per_device():
    dep = _mixed(5)
    p, fleet = dep.plan(policy="robust_exact", outer_iters=3)
    assert bool(p.feasible.all())
    assert (np.asarray(p.m_sel) < np.asarray(fleet.num_points)).all()
    rep = dep.validate(p, fleet)
    assert rep["max_violation"] <= dep.eps + 0.01
    per = dep.validate_per_device(p, fleet)
    assert per["group"] == dep.spec().device_names()
    assert per["violation"].shape == (5,)
    assert per["ok"].all()  # MC violation ≤ ε on every device


def test_mixed_population_grid_and_zipped_sweeps():
    dep = _mixed(4)
    grid, fleet = dep.plan_grid(deadlines=(1.0, 2.0), policy="robust_exact",
                                outer_iters=2)
    assert grid.m_sel.shape == (2, 1, 1, 4)
    many, fleet = dep.plan_many(
        [dep.scenario(), Scenario(1.5, 0.05, dep.bandwidth_hz)],
        policy="robust_exact", outer_iters=2)
    assert many.m_sel.shape == (2, 4)
    assert (np.asarray(many.m_sel) < np.asarray(fleet.num_points)[None, :]).all()


def test_two_tier_still_routes_through_builder():
    """The homogeneous deployment now builds through FleetSpec — one
    group, all-valid mask — and plans exactly as before."""
    dep = TwoTierDeployment(get_config("mamba2-130m"), num_devices=4,
                            deadline_s=2.0, eps=0.05, bandwidth_hz=100e6)
    fleet = dep.fleet()
    assert np.asarray(fleet.valid).all()
    assert np.asarray(fleet.num_points).tolist() == [9] * 4
    assert dep.spec().group_slices() == [(0, 4)]


def test_shared_edge_is_priced_not_scaled():
    """``dedicated_vm=False`` now plans against the real capacity
    constraint (DESIGN.md §edge): the chain stays physical (no N×
    scaling), the scenario carries ``edge_capacity_s``, and the planned
    occupancy fits the budget."""
    from repro.core.resource import select_point

    dep = _mixed(5, dedicated_vm=False)
    assert dep.edge_capacity() == dep.deadline_s
    assert dep.scenario().edge_capacity_s == dep.deadline_s
    fleet = dep.fleet()
    # physical chain: identical to the dedicated-VM build
    ded = _mixed(5).fleet()
    np.testing.assert_array_equal(np.asarray(fleet.chain.t_vm),
                                  np.asarray(ded.chain.t_vm))
    p, fleet = dep.plan(policy="robust_exact", outer_iters=3)
    assert bool(p.feasible.all())
    occ = float(select_point(fleet, p.m_sel).t_vm.sum())
    assert occ <= dep.edge_capacity() * (1 + 1e-9)
    per = dep.validate_per_device(p, fleet)  # congestion-aware MC
    assert per["ok"].all()


def test_legacy_vm_scale_fallback_warns_and_scales():
    """The deprecated static N-scaling stays available for comparisons —
    behind an explicit flag and a DeprecationWarning."""
    dep = _mixed(5, dedicated_vm=False, legacy_vm_scale=True)
    assert dep.edge_capacity() == float("inf")
    with pytest.warns(DeprecationWarning, match="vm_time_scale"):
        fleet = dep.fleet()
    ded = _mixed(5).fleet()
    np.testing.assert_allclose(np.asarray(fleet.chain.t_vm),
                               5.0 * np.asarray(ded.chain.t_vm), rtol=1e-12)


def test_population_validation_errors():
    with pytest.raises(ValueError, match="fraction"):
        Population(get_config("mamba2-130m"), fraction=0.0)
    with pytest.raises(ValueError, match="sum to 1"):
        MixedTwoTierDeployment(
            populations=(Population(get_config("mamba2-130m"), fraction=0.7),),
            num_devices=4)
    with pytest.raises(ValueError, match="cannot host"):
        MixedTwoTierDeployment(
            populations=(Population(get_config("mamba2-130m"), fraction=0.5),
                         Population(get_config("mamba2-130m"), fraction=0.5)),
            num_devices=1)
