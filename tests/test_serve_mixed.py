"""Mixed-population two-tier deployments (DESIGN.md §fleet).

A 60/40 tinyllama-on-Jetson + mamba2-on-phone population sharing one
bandwidth budget plans as ONE ragged fleet in one compiled program, and
validates per device against the probabilistic deadline.
"""
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.core import Scenario
from repro.models.costmodel import PHONE_TIER
from repro.serve.partitioned import (
    MixedTwoTierDeployment,
    Population,
    TwoTierDeployment,
)


def _mixed(num_devices=5, **kw):
    return MixedTwoTierDeployment(
        populations=(
            Population(get_config("tinyllama-1.1b"), fraction=0.6,
                       name="tinyllama-jetson"),
            Population(get_config("mamba2-130m"), fraction=0.4,
                       device=PHONE_TIER, f_max_hz=1.0e9,
                       name="mamba2-phone"),
        ),
        num_devices=num_devices, bandwidth_hz=100e6, deadline_s=2.0,
        eps=0.05, **kw)


def test_counts_largest_remainder():
    assert _mixed(5).counts() == [3, 2]
    assert _mixed(10).counts() == [6, 4]
    assert _mixed(2).counts() == [1, 1]
    # fractions that don't divide evenly still sum to N
    dep = MixedTwoTierDeployment(
        populations=(Population(get_config("mamba2-130m"), fraction=1 / 3),
                     Population(get_config("mamba2-130m"), fraction=2 / 3)),
        num_devices=7)
    assert dep.counts() == [2, 5] and sum(dep.counts()) == 7
    # floors overshooting N: tiny fractions are kept at their 1-device
    # floor and the big group absorbs the decrement (regression: the
    # overshoot argmax must skip groups already at 1)
    cfg = get_config("mamba2-130m")
    dep = MixedTwoTierDeployment(
        populations=(Population(cfg, fraction=0.05),
                     Population(cfg, fraction=0.05),
                     Population(cfg, fraction=0.9)),
        num_devices=3)
    assert dep.counts() == [1, 1, 1]


def test_mixed_fleet_is_ragged():
    dep = _mixed(5)
    fleet = dep.fleet()
    assert fleet.num_devices == 5
    assert np.asarray(fleet.num_points).shape == (5,)
    assert dep.spec().device_names() == (["tinyllama-jetson"] * 3
                                         + ["mamba2-phone"] * 2)
    # per-population platforms land on the right devices
    f_max = np.asarray(fleet.platform.f_max)
    assert (f_max[:3] == 1.4e9).all() and (f_max[3:] == 1.0e9).all()


def test_mixed_population_plans_and_validates_per_device():
    dep = _mixed(5)
    p, fleet = dep.plan(policy="robust_exact", outer_iters=3)
    assert bool(p.feasible.all())
    assert (np.asarray(p.m_sel) < np.asarray(fleet.num_points)).all()
    rep = dep.validate(p, fleet)
    assert rep["max_violation"] <= dep.eps + 0.01
    per = dep.validate_per_device(p, fleet)
    assert per["group"] == dep.spec().device_names()
    assert per["violation"].shape == (5,)
    assert per["ok"].all()  # MC violation ≤ ε on every device


def test_mixed_population_grid_and_zipped_sweeps():
    dep = _mixed(4)
    grid, fleet = dep.plan_grid(deadlines=(1.0, 2.0), policy="robust_exact",
                                outer_iters=2)
    assert grid.m_sel.shape == (2, 1, 1, 4)
    many, fleet = dep.plan_many(
        [dep.scenario(), Scenario(1.5, 0.05, dep.bandwidth_hz)],
        policy="robust_exact", outer_iters=2)
    assert many.m_sel.shape == (2, 4)
    assert (np.asarray(many.m_sel) < np.asarray(fleet.num_points)[None, :]).all()


def test_two_tier_still_routes_through_builder():
    """The homogeneous deployment now builds through FleetSpec — one
    group, all-valid mask — and plans exactly as before."""
    dep = TwoTierDeployment(get_config("mamba2-130m"), num_devices=4,
                            deadline_s=2.0, eps=0.05, bandwidth_hz=100e6)
    fleet = dep.fleet()
    assert np.asarray(fleet.valid).all()
    assert np.asarray(fleet.num_points).tolist() == [9] * 4
    assert dep.spec().group_slices() == [(0, 4)]


def test_population_validation_errors():
    with pytest.raises(ValueError, match="fraction"):
        Population(get_config("mamba2-130m"), fraction=0.0)
    with pytest.raises(ValueError, match="sum to 1"):
        MixedTwoTierDeployment(
            populations=(Population(get_config("mamba2-130m"), fraction=0.7),),
            num_devices=4)
    with pytest.raises(ValueError, match="cannot host"):
        MixedTwoTierDeployment(
            populations=(Population(get_config("mamba2-130m"), fraction=0.5),
                         Population(get_config("mamba2-130m"), fraction=0.5)),
            num_devices=1)
