"""Fault injection (DESIGN.md §robustness): schedule constructors,
composition, the ``violation_report(faults=...)`` hook, and the
moment-matched heavy-tail samplers behind straggler bursts.

The load-bearing contract: ``faults=None`` and the identity
:class:`FaultState` are **bit-identical** to the pre-robustness MC
validator (same key splits, same sample streams), pinned here against a
recorded golden so fault plumbing can never drift the ground truth.
"""
import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.configs.paper_tables import alexnet_fleet
from repro.core import Planner, PlannerConfig, Scenario, violation_report
from repro.core.montecarlo import _sample_matched
from repro.serve.faults import (
    FaultState,
    apply_faults,
    brownout,
    channel_fade,
    compose,
    faulted_capacity,
    identity_schedule,
    moment_drift,
    node_failure,
    random_bursts,
    state_at,
    straggler_burst,
)

GOLDEN = json.loads(
    (pathlib.Path(__file__).parent / "golden" /
     "violation_report.json").read_text())


@pytest.fixture(scope="module")
def fleet():
    return alexnet_fleet(jax.random.PRNGKey(0), 12)


@pytest.fixture(scope="module")
def plan(fleet):
    planner = Planner(PlannerConfig(policy="robust_exact", outer_iters=3,
                                    pccp_iters=6))
    return planner.plan(fleet, Scenario(0.180, 0.02, 10e6))


def _vr(fleet, plan, faults=None, key=7, deadline=0.180, **kw):
    kw.setdefault("num_samples", 4000)
    return violation_report(jax.random.PRNGKey(key), fleet, plan.m_sel,
                            plan.alloc, deadline, faults=faults, **kw)


# ---------------------------------------------------------------------------
# schedules
# ---------------------------------------------------------------------------


def test_identity_schedule_and_state_at():
    s = identity_schedule(5)
    assert s.steps == 5
    st = state_at(s, 3)
    ident = FaultState.identity()
    for got, want in zip(st, ident, strict=True):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_moment_drift_ramps_then_holds():
    s = moment_drift(20, onset=4, vm_ramp=2.0, ramp_steps=8)
    vm = np.asarray(s.vm_mean_scale)
    assert vm[4] == 1.0  # ramp starts at onset
    np.testing.assert_allclose(vm[8], 2.0, rtol=1e-12)  # halfway
    np.testing.assert_allclose(vm[12:], 3.0, rtol=1e-12)  # plateau holds
    # variance defaults to the time-dilation model: scale²
    np.testing.assert_allclose(np.asarray(s.vm_var_scale), vm**2, rtol=1e-12)
    # untouched axes stay identity
    np.testing.assert_array_equal(np.asarray(s.gain_scale), np.ones(20))


def test_straggler_burst_window():
    s = straggler_burst(10, start=3, length=4, prob=0.25, extra_s=0.1)
    p = np.asarray(s.straggler_prob)
    assert p[2] == 0.0 and p[3] == 0.25 and p[6] == 0.25 and p[7] == 0.0
    assert np.asarray(s.straggler_extra_s)[5] == 0.1


def test_random_bursts_deterministic():
    k = jax.random.PRNGKey(3)
    a = random_bursts(64, k, burst_prob=0.2, length=3)
    b = random_bursts(64, k, burst_prob=0.2, length=3)
    np.testing.assert_array_equal(np.asarray(a.straggler_prob),
                                  np.asarray(b.straggler_prob))
    c = random_bursts(64, jax.random.PRNGKey(4), burst_prob=0.2, length=3)
    assert not np.array_equal(np.asarray(a.straggler_prob),
                              np.asarray(c.straggler_prob))
    # a start at t extends the episode over [t, t+length)
    p = np.asarray(a.straggler_prob)
    assert p.max() > 0  # 64 steps at burst_prob=0.2: ~1e-7 chance of none


def test_compose_multiplies_scales_and_unions_stragglers():
    T = 12
    s = compose(
        moment_drift(T, vm_ramp=1.0, ramp_steps=T - 1),  # ramp to 2.0
        channel_fade(T, start=2, length=3, depth=0.5),
        brownout(T, start=5, length=2, depth=0.25),
        straggler_burst(T, start=0, length=T, prob=0.3, extra_s=0.2),
        straggler_burst(T, start=6, length=2, prob=0.5, extra_s=0.1),
    )
    np.testing.assert_allclose(float(s.vm_mean_scale[-1]), 2.0, rtol=1e-12)
    assert float(s.gain_scale[3]) == 0.5 and float(s.gain_scale[0]) == 1.0
    assert float(s.cap_scale[5]) == 0.25
    # independent-event union at t=6: 1 - 0.7*0.5
    np.testing.assert_allclose(float(s.straggler_prob[6]), 0.65, rtol=1e-12)
    # probability-weighted extra mean: (0.3*0.2 + 0.5*0.1)/0.65
    np.testing.assert_allclose(float(s.straggler_extra_s[6]), 0.11 / 0.65,
                               rtol=1e-12)
    np.testing.assert_allclose(float(s.straggler_prob[3]), 0.3, rtol=1e-12)


def test_compose_rejects_mismatched_horizons():
    with pytest.raises(ValueError, match="share a horizon"):
        compose(identity_schedule(4), identity_schedule(5))


# ---------------------------------------------------------------------------
# per-node faults (DESIGN.md §placement)
# ---------------------------------------------------------------------------


def test_brownout_per_node_fades_one_column():
    s = brownout(6, start=2, length=3, depth=0.1, node=1, num_nodes=4)
    cap = np.asarray(s.cap_scale)
    assert cap.shape == (6, 4)
    np.testing.assert_allclose(cap[2:5, 1], 0.1)
    # every other (step, node) cell stays identity
    mask = np.ones_like(cap, bool)
    mask[2:5, 1] = False
    np.testing.assert_allclose(cap[mask], 1.0)
    with pytest.raises(ValueError, match="num_nodes"):
        brownout(6, start=0, length=2, depth=0.5, node=1)
    with pytest.raises(ValueError, match="node must lie"):
        brownout(6, start=0, length=2, depth=0.5, node=4, num_nodes=4)


def test_brownout_scalar_profile_unchanged_by_per_node_support():
    """node=None keeps the (T,) scalar profile — bit-identical to the
    pre-per-node path (scalar states broadcast in every consumer)."""
    s = brownout(6, start=1, length=2, depth=0.25)
    assert np.asarray(s.cap_scale).shape == (6,)
    st6 = state_at(s, 1)
    assert np.asarray(st6.cap_scale).shape == ()
    np.testing.assert_allclose(float(st6.cap_scale), 0.25)


def test_node_failure_zeroes_to_horizon():
    s = node_failure(8, node=2, num_nodes=3, start=5)
    cap = np.asarray(s.cap_scale)
    np.testing.assert_allclose(cap[5:, 2], 0.0)  # crash-stop, no recovery
    np.testing.assert_allclose(cap[:5, 2], 1.0)
    np.testing.assert_allclose(cap[:, :2], 1.0)
    # an (E,) state × an (E,) capacity: the failed node is ABSENT (cap 0)
    caps = faulted_capacity(jnp.asarray([0.5, 0.4, 0.3]), state_at(s, 6))
    np.testing.assert_allclose(np.asarray(caps), [0.5, 0.4, 0.0])


def test_compose_scalar_cap_broadcasts_over_per_node():
    """A whole-edge brownout fades ALL nodes of a per-node profile —
    in either compose order."""
    whole = brownout(6, start=0, length=6, depth=0.5)
    one = brownout(6, start=2, length=2, depth=0.1, node=0, num_nodes=3)
    for s in (compose(whole, one), compose(one, whole)):
        cap = np.asarray(s.cap_scale)
        assert cap.shape == (6, 3)
        np.testing.assert_allclose(cap[2:4, 0], 0.05)
        np.testing.assert_allclose(cap[2:4, 1:], 0.5)
        np.testing.assert_allclose(cap[0], 0.5)


def test_compose_rejects_node_count_mismatch():
    a = brownout(6, start=0, length=2, depth=0.5, node=0, num_nodes=3)
    b = brownout(6, start=0, length=2, depth=0.5, node=0, num_nodes=4)
    with pytest.raises(ValueError, match="node count"):
        compose(a, b)


def test_edge_scale_alias_tracks_cap_scale():
    st6 = FaultState.identity()._replace(cap_scale=jnp.asarray([0.5, 1.0]))
    np.testing.assert_array_equal(np.asarray(st6.edge_scale),
                                  np.asarray(st6.cap_scale))
    sched = brownout(4, start=0, length=2, depth=0.3, node=1, num_nodes=2)
    np.testing.assert_array_equal(np.asarray(sched.edge_scale),
                                  np.asarray(sched.cap_scale))


def test_state_at_clamps_to_boundary_states():
    """A replay that outruns its schedule holds the LAST fault regime
    (never a silently-reset identity); t < 0 clamps to the first."""
    s = brownout(5, start=3, length=2, depth=0.2, node=1, num_nodes=3)
    last = state_at(s, 4)
    for got, want in zip(state_at(s, 99), last, strict=True):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    first = state_at(s, 0)
    for got, want in zip(state_at(s, -7), first, strict=True):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    assert np.asarray(last.cap_scale).shape == (3,)
    np.testing.assert_allclose(np.asarray(last.cap_scale), [1.0, 0.2, 1.0])


# ---------------------------------------------------------------------------
# property tests (optional hypothesis; skip cleanly without it)
# ---------------------------------------------------------------------------

_T = 6


def _sched_from(vm, p, extra, depth):
    full = lambda v: jnp.full((_T,), v, jnp.float64)
    return identity_schedule(_T)._replace(
        vm_mean_scale=full(vm), vm_var_scale=full(vm) ** 2,
        straggler_prob=full(p), straggler_extra_s=full(extra),
        cap_scale=full(depth))


_leg = st.tuples(st.floats(0.5, 2.0), st.floats(0.0, 0.9),
                 st.floats(0.0, 0.5), st.floats(0.1, 1.0))


@settings(max_examples=25, deadline=None)
@given(a=_leg, b=_leg, c=_leg)
def test_compose_is_associative(a, b, c):
    """compose is associative on every leaf: scales multiply, straggler
    episodes union as independent events, and the probability-weighted
    extra telescopes to Σpᵢeᵢ / p regardless of grouping."""
    sa, sb, sc = (_sched_from(*x) for x in (a, b, c))
    left = compose(compose(sa, sb), sc)
    right = compose(sa, compose(sb, sc))
    for got, want in zip(left, right, strict=True):
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-9, atol=1e-12)


@settings(max_examples=50, deadline=None)
@given(t=st.integers(-100, 100), steps=st.integers(1, 12))
def test_state_at_clamping_property(t, steps):
    s = moment_drift(steps, vm_ramp=1.0)
    want = state_at(s, int(np.clip(t, 0, steps - 1)))
    for got, ref in zip(state_at(s, t), want, strict=True):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


# ---------------------------------------------------------------------------
# apply_faults
# ---------------------------------------------------------------------------


def test_apply_faults_identity_is_noop(fleet):
    out = apply_faults(fleet, FaultState.identity())
    for got, want in zip(jax.tree_util.tree_leaves(out),
                         jax.tree_util.tree_leaves(fleet), strict=True):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_apply_faults_scales_chain_and_link(fleet):
    st = FaultState.identity()._replace(
        vm_mean_scale=jnp.asarray(2.0), vm_var_scale=jnp.asarray(4.0),
        loc_mean_scale=jnp.asarray(1.5), loc_var_scale=jnp.asarray(2.25),
        gain_scale=jnp.asarray(0.5))
    out = apply_faults(fleet, st)
    np.testing.assert_allclose(np.asarray(out.chain.t_vm),
                               np.asarray(fleet.chain.t_vm) * 2.0)
    np.testing.assert_allclose(np.asarray(out.chain.v_vm),
                               np.asarray(fleet.chain.v_vm) * 4.0)
    np.testing.assert_allclose(np.asarray(out.chain.g_eff),
                               np.asarray(fleet.chain.g_eff) / 1.5)
    np.testing.assert_allclose(np.asarray(out.chain.v_loc),
                               np.asarray(fleet.chain.v_loc) * 2.25)
    np.testing.assert_allclose(np.asarray(out.link.gain),
                               np.asarray(fleet.link.gain) * 0.5)


def test_faulted_capacity():
    st = FaultState.identity()._replace(cap_scale=jnp.asarray(0.5))
    assert faulted_capacity(None, st) is None
    np.testing.assert_allclose(float(faulted_capacity(2.0, st)), 1.0)


# ---------------------------------------------------------------------------
# violation_report fault hook
# ---------------------------------------------------------------------------


def test_violation_report_none_pinned_to_golden(fleet, plan):
    """``faults=None`` reproduces the recorded pre-robustness ground
    truth exactly — fault plumbing must not perturb the no-fault path."""
    vr = _vr(fleet, plan)
    np.testing.assert_array_equal(np.asarray(vr.rate),
                                  np.asarray(GOLDEN["rate"]))
    np.testing.assert_allclose(np.asarray(vr.mean_time),
                               np.asarray(GOLDEN["mean_time"]), rtol=0, atol=0)
    np.testing.assert_allclose(np.asarray(vr.p95_time),
                               np.asarray(GOLDEN["p95_time"]), rtol=0, atol=0)


def test_identity_faults_bit_identical_to_none(fleet, plan):
    """The identity state takes the faulted code path (same program as a
    real fault) yet must not move a single bit: key derivation for the
    straggler stream is fold_in-based, never a re-split of ``key``."""
    base = _vr(fleet, plan, faults=None)
    ident = _vr(fleet, plan, faults=FaultState.identity())
    for got, want in zip(ident, base, strict=True):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_vm_drift_and_stragglers_raise_violation(fleet, plan):
    base = _vr(fleet, plan, deadline=0.150)
    drift = FaultState.identity()._replace(vm_mean_scale=jnp.asarray(4.0),
                                           vm_var_scale=jnp.asarray(16.0))
    strag = FaultState.identity()._replace(
        straggler_prob=jnp.asarray(0.5), straggler_extra_s=jnp.asarray(0.2))
    r_base = float(base.rate.max())
    assert float(_vr(fleet, plan, deadline=0.150, faults=drift).rate.max()) \
        > r_base
    assert float(_vr(fleet, plan, deadline=0.150, faults=strag).rate.max()) \
        > r_base


def test_straggler_extra_lands_in_vm_tier(fleet, plan):
    """Per-tier observed means: straggler extra must surface in
    ``mean_vm`` (the closed-loop re-fit attributes by tier) and leave
    the local tier untouched."""
    base = _vr(fleet, plan)
    strag = FaultState.identity()._replace(
        straggler_prob=jnp.asarray(0.5), straggler_extra_s=jnp.asarray(0.2))
    faulted = _vr(fleet, plan, faults=strag)
    np.testing.assert_array_equal(np.asarray(faulted.mean_local),
                                  np.asarray(base.mean_local))
    assert float(faulted.mean_vm.sum()) > float(base.mean_vm.sum())
    # mean_local + mean_vm never exceeds the total (t_off makes the gap)
    assert np.all(np.asarray(base.mean_local + base.mean_vm)
                  <= np.asarray(base.mean_time) + 1e-12)


def test_brownout_tightens_shared_edge(fleet, plan):
    """cap_scale < 1 shrinks the congestion budget: violations (or mean
    time) under a brownout dominate the un-faulted capacity run."""
    cap = 0.5
    base = _vr(fleet, plan, edge_capacity_s=cap)
    st = FaultState.identity()._replace(cap_scale=jnp.asarray(0.25))
    brown = _vr(fleet, plan, edge_capacity_s=cap, faults=st)
    assert float(brown.mean_time.sum()) >= float(base.mean_time.sum())


# ---------------------------------------------------------------------------
# heavy-tail samplers (straggler extras)
# ---------------------------------------------------------------------------

KEY = jax.random.PRNGKey(42)


@pytest.mark.parametrize("dist,cv,rtol_var", [
    ("pareto", 0.3, 0.15), ("pareto", 0.5, 0.25),
    ("weibull", 0.3, 0.12), ("weibull", 0.8, 0.12),
])
def test_heavy_tail_families_match_moments(dist, cv, rtol_var):
    mean = 0.15
    var = (cv * mean) ** 2
    x = np.asarray(_sample_matched(KEY, dist, jnp.float64(mean),
                                   jnp.float64(var), (200_000,)))
    assert np.isfinite(x).all() and (x > 0.0).all()
    np.testing.assert_allclose(x.mean(), mean, rtol=0.02)
    # Pareto's 4th moment diverges for α ≤ 4, so the sample-variance
    # estimator is itself heavy-tailed — hence the looser rtol there.
    np.testing.assert_allclose(x.var(), var, rtol=rtol_var)


def test_pareto_is_heavier_tailed_than_weibull():
    mean, cv = 0.1, 0.5
    var = (cv * mean) ** 2
    q = 0.9999
    xp = np.asarray(_sample_matched(KEY, "pareto", mean, var, (200_000,)))
    xw = np.asarray(_sample_matched(KEY, "weibull", mean, var, (200_000,)))
    assert np.quantile(xp, q) > np.quantile(xw, q)
