"""Structured barrier solver (DESIGN.md §solver): Woodbury solves,
closed-form derivatives, structured-vs-dense equivalence, convergence
gating, and the scale-aware regularization across the PCCP ρ-ramp.

Deterministic fixed-seed tests run everywhere; the ``@given`` variants
widen the same checks over random instances when hypothesis is installed
(CI), and skip cleanly otherwise (tests/_hyp.py).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core.ccp import sigma_cantelli
from repro.core.pccp import _inner_spec, pccp_partition
from repro.solvers.ipm import (
    structured_barrier,
    structured_grad,
    structured_hessian,
    structured_inequalities,
    woodbury_solve,
)


def _random_sdlr(seed, n=16, k=3, nrhs=2):
    """Random SPD diagonal + low-rank system (d, U, w, r)."""
    rng = np.random.default_rng(seed)
    d = rng.uniform(0.3, 5.0, n)
    U = rng.normal(size=(n, k))
    w = rng.uniform(0.05, 3.0, k)
    r = rng.normal(size=(n, nrhs))
    return (jnp.asarray(d), jnp.asarray(U), jnp.asarray(w), jnp.asarray(r))


def _check_woodbury(d, U, w, r):
    x = woodbury_solve(d, U, w, r)
    H = jnp.diag(d) + (U * w[None, :]) @ U.T
    ref = jax.scipy.linalg.cho_solve(jax.scipy.linalg.cho_factor(H), r)
    np.testing.assert_allclose(np.asarray(x), np.asarray(ref),
                               rtol=1e-9, atol=1e-11)


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_woodbury_matches_cho_solve(seed):
    _check_woodbury(*_random_sdlr(seed))


def test_woodbury_single_rhs_and_rank_zero():
    d, U, w, r = _random_sdlr(7)
    _check_woodbury(d, U, w, r[:, 0])  # (n,) RHS round-trips
    x = woodbury_solve(d, U[:, :0], w[:0], r)  # k = 0: pure diagonal
    np.testing.assert_allclose(np.asarray(x), np.asarray(r / d[:, None]))


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10_000), st.integers(2, 40), st.integers(1, 6))
def test_woodbury_property(seed, n, k):
    _check_woodbury(*_random_sdlr(seed, n=n, k=k))


def _random_inner_spec(seed, m1=7):
    """A PCCP inner problem (36) on a random instance, with its strictly
    feasible start — the exact spec the planner's hot loop solves."""
    rng = np.random.default_rng(seed)
    e = jnp.asarray(rng.uniform(0.01, 1.0, m1))
    t = jnp.asarray(rng.uniform(0.01, 0.15, m1))
    v = jnp.asarray(rng.uniform(1e-6, 2e-4, m1))
    sigma = sigma_cantelli(jnp.asarray(0.05))
    deadline = jnp.asarray(float(np.quantile(
        np.asarray(t + sigma * jnp.sqrt(v)), 0.6)))
    x_prev = jnp.asarray(rng.dirichlet(np.ones(m1)))
    y_prev = jnp.sqrt(jnp.dot(v, x_prev**2))
    rho = float(rng.uniform(1.0, 50.0))
    return _inner_spec(e, t, v, sigma, deadline, rho, x_prev, y_prev)


def _check_grad_hess(seed, t):
    spec, z0 = _random_inner_spec(seed)
    assert float(jnp.max(structured_inequalities(spec, z0))) < 0.0
    g = structured_grad(spec, z0, t)
    g_ad = jax.grad(lambda z: structured_barrier(spec, z, t))(z0)
    np.testing.assert_allclose(np.asarray(g), np.asarray(g_ad),
                               rtol=1e-9, atol=1e-9)
    H = structured_hessian(spec, z0, t)
    H_ad = jax.hessian(lambda z: structured_barrier(spec, z, t))(z0)
    scale = float(jnp.max(jnp.abs(H_ad)))
    np.testing.assert_allclose(np.asarray(H), np.asarray(H_ad),
                               rtol=1e-9, atol=1e-12 * scale)


@pytest.mark.parametrize("seed,t", [(0, 1.0), (1, 123.0), (2, 3e5)])
def test_structured_grad_hess_match_autodiff(seed, t):
    _check_grad_hess(seed, t)


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10_000), st.floats(1.0, 1e6))
def test_structured_grad_hess_property(seed, t):
    _check_grad_hess(seed, t)


def _random_tables(seed, n, m1):
    rng = np.random.default_rng(seed)
    e = jnp.asarray(rng.uniform(0.01, 1.0, (n, m1)))
    t = jnp.asarray(rng.uniform(0.01, 0.15, (n, m1)))
    v = jnp.asarray(rng.uniform(1e-6, 2e-4, (n, m1)))
    sigma = sigma_cantelli(jnp.full((n,), 0.05))
    deadline = jnp.asarray(
        np.quantile(np.asarray(t + sigma[:, None] * jnp.sqrt(v)), 0.6, axis=1))
    return e, t, v, sigma, deadline


def _check_structured_matches_dense(seed, n=6, m1=8, **kw):
    e, t, v, sigma, deadline = _random_tables(seed, n, m1)
    x0 = jnp.ones((n, m1)) / m1
    rs = pccp_partition(e, t, v, sigma, deadline, x0, solver="structured", **kw)
    rd = pccp_partition(e, t, v, sigma, deadline, x0, solver="dense", **kw)
    np.testing.assert_array_equal(np.asarray(rs.m_sel), np.asarray(rd.m_sel))
    assert bool(jnp.all(jnp.isfinite(rs.x_relaxed)))
    np.testing.assert_allclose(np.asarray(rs.x_relaxed),
                               np.asarray(rd.x_relaxed), atol=1e-6)


@pytest.mark.parametrize("seed", [0, 5])
def test_pccp_structured_matches_dense(seed):
    _check_structured_matches_dense(seed, num_iters=6)


@settings(max_examples=5, deadline=None)
@given(st.integers(0, 10_000), st.integers(2, 5), st.integers(3, 8))
def test_pccp_structured_matches_dense_property(seed, n, m1):
    _check_structured_matches_dense(seed, n=n, m1=m1, num_iters=6)


@pytest.mark.parametrize("solver", ["structured", "dense"])
def test_rho_ramp_conditioning_at_rho_max(solver):
    """Regression: with the penalty ramped to rho_max = 1e5 (12 PCCP
    iterations: 5·3¹¹ > 1e5) the scale-aware Tikhonov keeps both solver
    paths conditioned — identical selections, finite relaxed x, and a
    valid distribution (the fixed reg=1e-10 was inert at this scale)."""
    e, t, v, sigma, deadline = _random_tables(3, 8, 9)
    x0 = jnp.ones((8, 9)) / 9
    res = pccp_partition(e, t, v, sigma, deadline, x0, num_iters=12,
                         rho_max=1e5, solver=solver)
    assert bool(jnp.all(jnp.isfinite(res.x_relaxed)))
    np.testing.assert_allclose(np.asarray(res.x_relaxed.sum(-1)), 1.0,
                               atol=1e-5)
    # both paths agree at the extreme of the ramp
    other = pccp_partition(e, t, v, sigma, deadline, x0, num_iters=12,
                           rho_max=1e5,
                           solver="dense" if solver == "structured" else "structured")
    np.testing.assert_array_equal(np.asarray(res.m_sel), np.asarray(other.m_sel))


def test_gated_pccp_matches_scan_selection():
    """The while_loop outer PCCP stops at the Algorithm-1 rule; on a
    converged instance it selects the same points as the fixed-trip scan
    and reports the same iteration counts, with +inf in the step-norm
    rows it never executed."""
    e, t, v, sigma, deadline = _random_tables(11, 10, 8)
    x0 = jnp.ones((10, 8)) / 8
    scan = pccp_partition(e, t, v, sigma, deadline, x0, num_iters=8)
    gate = pccp_partition(e, t, v, sigma, deadline, x0, num_iters=8, gated=True)
    np.testing.assert_array_equal(np.asarray(scan.m_sel), np.asarray(gate.m_sel))
    np.testing.assert_array_equal(np.asarray(scan.iters_to_converge),
                                  np.asarray(gate.iters_to_converge))
    assert (1 <= np.asarray(gate.iters_to_converge)).all()
    # rows past the early exit are marked unvisited
    k_stop = int(np.asarray(gate.iters_to_converge).max())
    assert np.isfinite(np.asarray(gate.step_norms[:k_stop])).all()
    assert np.isinf(np.asarray(gate.step_norms[k_stop:])).all()


def test_gated_pccp_under_vmap():
    """The gated while_loop composes with vmap (zipped scenario batches):
    batched results equal the per-instance gated runs."""
    e, t, v, sigma, deadline = _random_tables(13, 4, 6)
    x0 = jnp.ones((4, 6)) / 6
    deadlines = jnp.stack([deadline, deadline * 1.2])

    run = lambda d: pccp_partition(e, t, v, sigma, d, x0, num_iters=6,
                                   gated=True)
    batched = jax.vmap(run)(deadlines)
    for k in range(2):
        single = run(deadlines[k])
        np.testing.assert_array_equal(np.asarray(batched.m_sel[k]),
                                      np.asarray(single.m_sel))
