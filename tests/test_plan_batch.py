"""Batched scenario-grid planning + fused-planner cache behaviour."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.paper_tables import alexnet_fleet
from repro.core import plan, plan_at, plan_grid
from repro.core import planner
from repro.core.planner_ref import plan_reference

DEADLINES = (0.18, 0.20, 0.22)
EPSS = (0.02, 0.04, 0.06)
B = 10e6


@pytest.fixture(scope="module")
def fleet():
    return alexnet_fleet(jax.random.PRNGKey(0), 6)


def test_plan_grid_matches_per_scenario_plan(fleet):
    """(a) 3×3 deadline×ε grid == per-scenario plan() calls."""
    grid = plan_grid(fleet, DEADLINES, EPSS, B, policy="robust_exact", outer_iters=3)
    assert grid.m_sel.shape == (3, 3, 1, fleet.num_devices)
    for i, d in enumerate(DEADLINES):
        for j, eps in enumerate(EPSS):
            p = plan(fleet, d, eps, B, policy="robust_exact", outer_iters=3)
            cell = plan_at(grid, i, j, 0)
            np.testing.assert_array_equal(np.asarray(cell.m_sel), np.asarray(p.m_sel))
            np.testing.assert_allclose(
                float(cell.total_energy), float(p.total_energy), rtol=1e-12)
            np.testing.assert_array_equal(
                np.asarray(cell.feasible), np.asarray(p.feasible))


def test_plan_grid_bandwidth_axis(fleet):
    grid = plan_grid(fleet, 0.2, 0.04, (8e6, 10e6), policy="robust_exact",
                     outer_iters=3)
    assert grid.total_energy.shape == (1, 1, 2)
    for k, b in enumerate((8e6, 10e6)):
        p = plan(fleet, 0.2, 0.04, b, policy="robust_exact", outer_iters=3)
        np.testing.assert_allclose(
            float(grid.total_energy[0, 0, k]), float(p.total_energy), rtol=1e-12)


def test_multi_start_vmap_matches_sequential_min(fleet):
    """(b) the traced feasibility-then-energy argmin picks the same plan as
    the seed's sequential ``min(plans, key=score)``."""
    for d in (0.17, 0.2, 0.24):
        p = plan(fleet, d, 0.04, B, policy="robust_exact", outer_iters=3)
        r = plan_reference(fleet, d, 0.04, B, policy="robust_exact", outer_iters=3)
        np.testing.assert_array_equal(np.asarray(p.m_sel), np.asarray(r.m_sel))
        assert float(jnp.abs(p.total_energy - r.total_energy)) == 0.0


def test_same_shape_fleet_hits_jit_cache(fleet):
    """(c) a second plan() on a same-shaped fleet must not retrace."""
    other = alexnet_fleet(jax.random.PRNGKey(99), 6)
    kw = dict(policy="robust_exact", outer_iters=3)
    plan(fleet, 0.2, 0.04, B, **kw)
    size = planner.plan_multi_jit._cache_size()
    plan(other, 0.21, 0.05, 12e6, **kw)  # new fleet, new scenario scalars
    assert planner.plan_multi_jit._cache_size() == size

    plan(fleet, 0.2, 0.04, B, multi_start=False, **kw)
    size = planner.plan_single_jit._cache_size()
    plan(other, 0.21, 0.05, 12e6, multi_start=False, **kw)
    assert planner.plan_single_jit._cache_size() == size


def test_plan_grid_scenario_scalars_hit_jit_cache(fleet):
    """Grid planning is sugar over the zipped plan_many jit entry; new
    scenario values (same shapes) must not retrace it."""
    from repro.core import api
    kw = dict(policy="robust_exact", outer_iters=3)
    plan_grid(fleet, DEADLINES, EPSS, B, **kw)
    size = api.plan_many_jit._cache_size()
    plan_grid(fleet, (0.19, 0.21, 0.23), (0.03, 0.05, 0.07), 12e6, **kw)
    assert api.plan_many_jit._cache_size() == size
