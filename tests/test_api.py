"""First-class Scenario/Planner API (DESIGN.md §api).

Pins the tentpole contracts:

- ``plan_many`` over K heterogeneous *zipped* scenarios (mixed scalar and
  per-device ``(N,)`` deadlines/eps) equals K independent ``plan()``
  calls leaf-for-leaf;
- ``"optimal"`` dispatched through the Policy registry matches
  ``plan_optimal`` and is grid/batch-dispatchable (the old grid path
  rejected it);
- statics-vs-traced: new scenario values never retrace the batched entry;
- the satellite error paths (``init_m`` bounds, ``plan_at`` shape/bounds,
  unknown policies, malformed scenario batches) raise actionable errors.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.paper_tables import alexnet_fleet
from repro.core import (
    Planner,
    PlannerConfig,
    Policy,
    Scenario,
    api,
    available_policies,
    get_policy,
    plan,
    plan_at,
    plan_grid,
    plan_optimal,
    scenario_at,
)

B = 10e6


@pytest.fixture(scope="module")
def fleet():
    return alexnet_fleet(jax.random.PRNGKey(0), 6)


def assert_plans_equal(a, b, rtol=0.0):
    """Leaf-for-leaf Plan comparison (exact ints/bools, rtol floats)."""
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb, strict=True):
        x, y = np.asarray(x), np.asarray(y)
        if x.dtype.kind in "fc" and rtol > 0.0:
            np.testing.assert_allclose(x, y, rtol=rtol, atol=0.0)
        else:
            np.testing.assert_array_equal(x, y)


#: K=4 heterogeneous zipped scenarios: fleet-wide scalars, per-device
#: (N,) deadlines, and per-device (N,) risk levels — the workload shape
#: cartesian grids cannot represent.
def hetero_scenarios(n):
    return [
        Scenario(0.18, 0.02, B),
        Scenario(0.22, 0.06, 8e6),
        Scenario(jnp.linspace(0.17, 0.25, n), 0.04, B),
        Scenario(0.20, jnp.asarray([0.02, 0.03, 0.04, 0.05, 0.06, 0.08][:n]), 12e6),
    ]


def test_plan_many_matches_sequential_plan(fleet):
    planner = Planner(PlannerConfig(policy="robust_exact", outer_iters=3))
    scenarios = hetero_scenarios(fleet.num_devices)
    many = planner.plan_many(fleet, scenarios)
    assert many.m_sel.shape == (len(scenarios), fleet.num_devices)
    for k, sc in enumerate(scenarios):
        assert_plans_equal(scenario_at(many, k), planner.plan(fleet, sc),
                           rtol=1e-10)


def test_plan_many_robust_pccp_policy(fleet):
    """The paper's PCCP path batches identically to per-scenario calls."""
    planner = Planner(PlannerConfig(policy="robust", outer_iters=2,
                                    pccp_iters=4))
    scenarios = hetero_scenarios(fleet.num_devices)[1:3]  # keep it cheap
    many = planner.plan_many(fleet, scenarios)
    for k, sc in enumerate(scenarios):
        assert_plans_equal(scenario_at(many, k), planner.plan(fleet, sc),
                           rtol=1e-10)


def test_plan_many_prestacked_scenario(fleet):
    """A pre-stacked Scenario (leading K axis on every leaf) is accepted."""
    planner = Planner(PlannerConfig(policy="robust_exact", outer_iters=3))
    stacked = Scenario(jnp.asarray([0.18, 0.20, 0.22]), 0.04,
                       jnp.full((3,), B))
    many = planner.plan_many(fleet, stacked)
    for k, d in enumerate((0.18, 0.20, 0.22)):
        assert_plans_equal(scenario_at(many, k),
                           planner.plan(fleet, Scenario(d, 0.04, B)),
                           rtol=1e-10)


def test_optimal_via_registry_matches_plan_optimal(fleet):
    p_reg = Planner(PlannerConfig(policy="optimal")).plan(
        fleet, Scenario(0.2, 0.04, B))
    p_fn = plan_optimal(fleet, 0.2, 0.04, B)
    np.testing.assert_array_equal(np.asarray(p_reg.m_sel), np.asarray(p_fn.m_sel))
    np.testing.assert_array_equal(np.asarray(p_reg.feasible),
                                  np.asarray(p_fn.feasible))
    np.testing.assert_allclose(float(p_reg.total_energy),
                               float(p_fn.total_energy), rtol=1e-8)


def test_optimal_is_batch_dispatchable(fleet):
    """New capability: the old plan_grid rejected "optimal" outright."""
    deadlines = (0.18, 0.22)
    grid = plan_grid(fleet, deadlines, 0.04, B, policy="optimal")
    assert grid.total_energy.shape == (2, 1, 1)
    for i, d in enumerate(deadlines):
        ref = plan_optimal(fleet, d, 0.04, B)
        cell = plan_at(grid, i, 0, 0)
        np.testing.assert_array_equal(np.asarray(cell.m_sel),
                                      np.asarray(ref.m_sel))
        np.testing.assert_allclose(float(cell.total_energy),
                                   float(ref.total_energy), rtol=1e-8)


def test_grid_is_sugar_over_plan_many(fleet):
    planner = Planner(PlannerConfig(policy="robust_exact", outer_iters=3))
    deadlines, epss = (0.18, 0.22), (0.02, 0.06)
    grid = planner.grid(fleet, deadlines, epss, B)
    zipped = planner.plan_many(
        fleet, [Scenario(d, e, B) for d in deadlines for e in epss])
    for i in range(2):
        for j in range(2):
            assert_plans_equal(plan_at(grid, i, j, 0),
                               scenario_at(zipped, 2 * i + j))


def test_plan_many_new_values_hit_jit_cache(fleet):
    planner = Planner(PlannerConfig(policy="robust_exact", outer_iters=3))
    planner.plan_many(fleet, hetero_scenarios(fleet.num_devices))
    size = api.plan_many_jit._cache_size()
    shifted = [s._replace(deadline=s.deadline + 0.01)
               for s in hetero_scenarios(fleet.num_devices)]
    planner.plan_many(fleet, shifted)
    assert api.plan_many_jit._cache_size() == size


def test_policy_registry_contents():
    assert set(available_policies()) >= {
        "robust", "robust_exact", "gaussian", "worst_case", "optimal"}
    pol = get_policy("worst_case")
    assert pol.sigma_model == "hard" and pol.ub_k > 0.0
    assert get_policy(pol) is pol  # Policy instances pass through
    assert get_policy("optimal").solve is not None


def test_custom_policy_registers_and_plans(fleet):
    """New policies are a register_policy call — no _alternation edits."""
    from repro.core.planner import exact_partition_step, register_policy

    name = "gaussian_test_variant"
    if name not in available_policies():
        register_policy(Policy(name, sigma_model="gaussian",
                               partition=exact_partition_step))
    p = Planner(PlannerConfig(policy=name, outer_iters=3)).plan(
        fleet, Scenario(0.2, 0.04, B))
    ref = plan(fleet, 0.2, 0.04, B, policy="gaussian", outer_iters=3)
    assert_plans_equal(p, ref)


def test_unknown_policy_raises():
    with pytest.raises(ValueError, match="unknown policy"):
        PlannerConfig(policy="does_not_exist")


def test_invalid_iters_raise():
    with pytest.raises(ValueError, match="outer_iters"):
        PlannerConfig(outer_iters=0)
    with pytest.raises(ValueError, match="pccp_iters"):
        PlannerConfig(pccp_iters=0)


def test_init_m_bounds_validated(fleet):
    m_max = fleet.max_points - 1
    for bad in (-1, m_max + 1, 99):
        with pytest.raises(ValueError, match="init_m"):
            plan(fleet, 0.2, 0.04, B, init_m=bad, multi_start=False)
    # boundary values are fine
    plan(fleet, 0.2, 0.04, B, policy="robust_exact", outer_iters=1,
         init_m=m_max, multi_start=False)
    plan(fleet, 0.2, 0.04, B, policy="robust_exact", outer_iters=1,
         init_m=0, multi_start=False)


def test_plan_at_validates_shape_and_bounds(fleet):
    single = plan(fleet, 0.2, 0.04, B, policy="robust_exact", outer_iters=3)
    with pytest.raises(ValueError, match="grid Plan"):
        plan_at(single, 0)
    grid = plan_grid(fleet, (0.18, 0.22), 0.04, B, policy="robust_exact",
                     outer_iters=3)
    with pytest.raises(IndexError, match="out of range"):
        plan_at(grid, 5, 0, 0)
    with pytest.raises(IndexError, match="out of range"):
        plan_at(grid, 0, 0, 3)
    zipped = Planner(PlannerConfig(policy="robust_exact", outer_iters=3)
                     ).plan_many(fleet, [Scenario(0.2, 0.04, B)])
    with pytest.raises(ValueError, match="scenario_at"):
        plan_at(zipped, 0)
    with pytest.raises(IndexError, match="out of range"):
        scenario_at(zipped, 2)


def test_malformed_scenario_batches_raise(fleet):
    planner = Planner(PlannerConfig(policy="robust_exact"))
    with pytest.raises(ValueError, match="at least one"):
        planner.plan_many(fleet, [])
    with pytest.raises(ValueError, match="leading"):
        planner.plan_many(fleet, Scenario(0.2, 0.04, B))  # B not (K,)
    with pytest.raises(ValueError, match="deadline"):
        planner.plan_many(fleet, Scenario(jnp.zeros((5,)) + 0.2, 0.04,
                                          jnp.full((3,), B)))
    with pytest.raises(ValueError, match="deadline"):  # K ok, N wrong
        planner.plan_many(fleet, Scenario(
            jnp.full((3, fleet.num_devices + 1), 0.2), 0.04, jnp.full((3,), B)))
    with pytest.raises(ValueError, match="per-device"):  # wrong-width leaf
        planner.plan_many(fleet, [Scenario(jnp.full((2,), 0.2), 0.04, B)])
    with pytest.raises(ValueError, match="scalar"):  # non-scalar budget
        planner.plan(fleet, Scenario(0.2, 0.04, jnp.full((2,), B)))


def test_solve_policy_rejects_warm_starts(fleet):
    """init_m has no effect on solve-override policies — loud, not silent."""
    with pytest.raises(ValueError, match="no alternation"):
        Planner(PlannerConfig(policy="optimal")).plan(
            fleet, Scenario(0.2, 0.04, B), init_m=3)
    with pytest.raises(ValueError, match="no alternation"):
        Planner(PlannerConfig(policy="optimal", init_m=3)).plan(
            fleet, Scenario(0.2, 0.04, B))


def test_size_one_arrays_broadcast_like_scalars(fleet):
    """Legacy plan() accepted shape-(1,) deadline/eps; the API must too."""
    planner = Planner(PlannerConfig(policy="robust_exact", outer_iters=3))
    a = planner.plan(fleet, Scenario(jnp.asarray([0.2]), jnp.asarray([0.04]),
                                     jnp.asarray([B])))
    b = planner.plan(fleet, Scenario(0.2, 0.04, B))
    assert_plans_equal(a, b)


def test_legacy_wrappers_warn_deprecation(fleet):
    with pytest.warns(DeprecationWarning, match="core.plan is deprecated"):
        plan(fleet, 0.2, 0.04, B, policy="robust_exact", outer_iters=1,
             multi_start=False)
    with pytest.warns(DeprecationWarning, match="plan_grid is deprecated"):
        plan_grid(fleet, 0.2, 0.04, B, policy="robust_exact", outer_iters=1,
                  multi_start=False)


def test_traced_init_m_still_works(fleet):
    """Bounds checking must not concretize traced warm starts."""
    planner = Planner(PlannerConfig(policy="robust_exact", outer_iters=2,
                                    multi_start=False))
    sc = Scenario(0.2, 0.04, B)

    @jax.jit
    def warm(m0):
        return planner.plan(fleet, sc, init_m=m0).total_energy

    e_traced = float(warm(jnp.full((fleet.num_devices,), 4, jnp.int32)))
    e_direct = float(planner.plan(fleet, sc, init_m=4).total_energy)
    np.testing.assert_allclose(e_traced, e_direct, rtol=1e-10)
