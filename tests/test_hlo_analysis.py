"""Loop-aware HLO analyzer: verify against a known scanned program."""
import jax
import jax.numpy as jnp

from repro.launch.hlo_analysis import analyze, split_computations


def _scanned_matmul(n_layers: int, d: int):
    def step(x, w):
        return jnp.tanh(x @ w), None

    def fn(x, ws):
        y, _ = jax.lax.scan(step, x, ws)
        return y.sum()

    x = jax.ShapeDtypeStruct((8, d), jnp.float32)
    ws = jax.ShapeDtypeStruct((n_layers, d, d), jnp.float32)
    return jax.jit(fn).lower(x, ws).compile()


def test_trip_count_and_flops():
    L, D = 7, 64
    compiled = _scanned_matmul(L, D)
    cost = analyze(compiled.as_text())
    assert L in cost.trip_counts
    expected = 2 * 8 * D * D * L  # 2·M·K·N per layer × L layers
    assert 0.9 * expected <= cost.flops <= 1.6 * expected, (cost.flops, expected)
    # XLA's own cost analysis undercounts the loop body (the reason this
    # module exists): it must be ≈ L× below ours.
    ca = compiled.cost_analysis()  # list-of-dicts on jax 0.4.x, dict on 0.5+
    xla = (ca[0] if isinstance(ca, (list, tuple)) else ca)["flops"]
    assert cost.flops > 2.0 * xla


def test_split_computations_finds_entry():
    compiled = _scanned_matmul(3, 16)
    comps = split_computations(compiled.as_text())
    assert "__entry__" in comps
    assert len(comps) >= 3  # entry + cond + body at least


def test_no_collectives_single_device():
    compiled = _scanned_matmul(3, 16)
    cost = analyze(compiled.as_text())
    assert cost.collective_bytes == {}
