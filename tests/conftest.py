import jax
import pytest

# NOTE: no XLA_FLAGS here — smoke tests and benches must see 1 device
# (the 512-device override lives only in launch/dryrun.py).


@pytest.fixture
def rng():
    return jax.random.PRNGKey(0)
