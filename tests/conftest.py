import jax
import pytest

# NOTE: no XLA_FLAGS here — smoke tests and benches must see 1 device
# (the 512-device override lives only in launch/dryrun.py).

# Property-based tests import hypothesis through tests/_hyp.py, which
# degrades to per-test skips when hypothesis is absent (bare jax-only
# env) — plain tests in the same modules still collect and run.


@pytest.fixture
def rng():
    return jax.random.PRNGKey(0)
