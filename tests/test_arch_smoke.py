"""Per-architecture smoke tests (deliverable f): reduced config, one
forward + one train step on CPU, asserting shapes and no NaNs."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs.registry import ARCH_IDS, get_config
from repro.data.pipeline import DataConfig, SyntheticTokens, make_batch
from repro.models import transformer as T
from repro.train.loop import make_train_step
from repro.train.optimizer import AdamWConfig, init_state

B, S = 2, 64


def _batch(cfg):
    data = SyntheticTokens(DataConfig(cfg.vocab_size, S, B, seed=1))
    return make_batch(cfg, data, 0)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_and_train_step(arch, rng):
    cfg = get_config(arch, smoke=True)
    assert cfg.num_layers <= 2 and cfg.d_model <= 512
    if cfg.moe:
        assert cfg.num_experts <= 4
    params = T.init_params(cfg, rng)
    batch = _batch(cfg)

    loss, metrics = T.loss_fn(params, cfg, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss))

    step = make_train_step(cfg, AdamWConfig(lr=1e-3, total_steps=10), donate=False)
    opt_state = init_state(AdamWConfig(lr=1e-3, total_steps=10), params)
    new_params, _, m2 = step(params, opt_state, batch)
    assert bool(jnp.isfinite(m2["loss"]))
    # parameters actually moved
    moved = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))), params, new_params)
    assert max(jax.tree.leaves(moved)) > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_and_decode_shapes(arch, rng):
    cfg = get_config(arch, smoke=True)
    params = T.init_params(cfg, rng)
    batch = _batch(cfg)
    logits = T.prefill_logits(params, cfg, batch)
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())

    cache = T.init_decode_cache(cfg, B, 32, enc_len=S // 4, dtype=jnp.float32)
    lg, cache2 = T.decode_step(params, cfg, jnp.ones((B, 1), jnp.int32), cache, jnp.int32(0))
    assert lg.shape == (B, 1, cfg.vocab_size)
    assert bool(jnp.isfinite(lg).all())
    # cache leaves keep their shapes
    for a, b in zip(jax.tree.leaves(cache), jax.tree.leaves(cache2), strict=True):
        assert a.shape == b.shape
