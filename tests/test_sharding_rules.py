"""Sharding-rule unit tests against a fake 16×16 (and 2×16×16) mesh."""
from types import SimpleNamespace

import jax
import pytest

from repro.configs.registry import get_config
from repro.models.transformer import abstract_params
from repro.parallel import sharding as shd


class FakeKey:
    def __init__(self, key):
        self.key = key


def _mesh(multi=False):
    if multi:
        return SimpleNamespace(shape={"pod": 2, "data": 16, "model": 16},
                               axis_names=("pod", "data", "model"))
    return SimpleNamespace(shape={"data": 16, "model": 16},
                           axis_names=("data", "model"))


def _spec(names, shape, mesh):
    path = tuple(FakeKey(n) for n in names)
    return tuple(shd._leaf_spec(path, shape, mesh))


def test_in_proj_rule():
    m = _mesh()
    assert _spec(["layers", "attn", "wq"], (22, 2048, 4096), m) == (None, "data", "model")


def test_out_proj_rule():
    m = _mesh()
    assert _spec(["layers", "attn", "wo"], (22, 4096, 2048), m) == (None, "model", "data")


def test_non_divisible_left_replicated():
    m = _mesh()
    # 25 heads × 64 = 1600 ✓ divisible; but a 4-dim that isn't stays None
    assert _spec(["layers", "attn", "wk"], (22, 1600, 100), m) == (None, "data", None)


def test_expert_parallel_full():
    m = _mesh()
    # 256 experts = 16·16 → expert dim over (data, model)
    spec = _spec(["layers", "ff", "w1"], (61, 256, 7168, 2048), m)
    assert spec == (None, ("data", "model"), None, None)


def test_expert_parallel_model_only():
    m = _mesh()
    # 64 experts → model axis on E; inner dims stay whole (the a2a path
    # needs resident whole experts — §Perf B2)
    spec = _spec(["layers", "ff", "w1"], (27, 64, 2048, 1408), m)
    assert spec == (None, "model", None, None)


def test_multipod_fsdp_axes():
    m = _mesh(multi=True)
    spec = _spec(["layers", "attn", "wq"], (22, 2048, 4096), m)
    assert spec == (None, ("pod", "data"), "model")


def test_embed_rule():
    m = _mesh()
    assert _spec(["embed"], (102400, 2048), m) == ("model", "data")


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "deepseek-v3-671b", "hymba-1.5b"])
def test_full_tree_specs_build(arch):
    """Every leaf of every arch gets a valid spec (divisibility respected)."""
    cfg = get_config(arch)
    tree = abstract_params(cfg)
    m = _mesh()

    def check(path, leaf):
        spec = _spec([getattr(p, "key", "") for p in path], leaf.shape, m)
        shape = leaf.shape
        # strict=False: specs may be shorter than the rank (trailing dims replicated)
        for dim, ax in zip(shape[len(shape) - len(spec):] if len(spec) < len(shape) else shape, spec, strict=False):
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            size = 1
            for a in axes:
                size *= m.shape[a]
            assert dim % size == 0, (path, shape, spec)

    jax.tree_util.tree_map_with_path(check, tree)
