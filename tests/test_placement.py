"""Multi-edge placement (DESIGN.md §placement).

Pins the tentpole contracts of ``core.placement``:

- **E=1 reduction**: a one-node capacity vector is leaf-identical to the
  scalar shared edge, for every planner policy — which is what keeps the
  golden-pinned scalar plans (and PR 4's edge pins) valid under the new
  placement layer;
- **assignment invariants**: every registered strategy places each
  device on exactly one *present* node (0-capacity ⇒ absent),
  deterministically, and the numpy host mirror replays the traced
  strategy bit-for-bit (the contract ``core.decompose`` relies on);
- **capacity enforcement**: planned E>1 plans satisfy the per-node
  occupancy rows at the returned per-node prices, and the duality-gap
  certificate is non-negative;
- **Cantelli edge rows**: ``edge_eps`` reduces exactly to the mean
  occupancy row at zero VM variance and strictly tightens otherwise;
- **Hybrid vs Balanced**: the migration pass never loads the scarcest
  node worse than Balanced (property-tested).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hyp import given, settings, st
from repro.configs.paper_tables import alexnet_fleet
from repro.core import Planner, PlannerConfig, Scenario, allocate
from repro.core import placement
from repro.core.placement import (
    assign_devices,
    assign_devices_host,
    available_assignments,
    node_loads,
    plan_duality_gap,
)
from repro.core.resource import select_point

D, B, EPS = 0.40, 10e6, 0.02
N = 10

STRATEGIES = available_assignments()


@pytest.fixture(scope="module")
def fleet():
    return alexnet_fleet(jax.random.PRNGKey(0), N)


def occupancy(fleet, m_sel) -> float:
    return float(select_point(fleet, m_sel).t_vm.sum())


@pytest.fixture(scope="module")
def slack_occ(fleet):
    p0 = Planner(PlannerConfig(policy="robust_exact", outer_iters=3)).plan(
        fleet, Scenario(D, EPS, B))
    return occupancy(fleet, p0.m_sel)


def assert_plans_equal(a, b):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb, strict=True):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ------------------------------------------------------------ E=1 reduction


@pytest.mark.parametrize("policy", ["robust_exact", "robust", "optimal"])
def test_one_node_vector_is_leaf_identical_to_scalar(fleet, slack_occ, policy):
    """(1,) capacity vectors ARE the scalar edge — every policy, every
    leaf (including the all-zeros assignment stamp)."""
    planner = Planner(PlannerConfig(policy=policy, outer_iters=3,
                                    pccp_iters=4))
    cap = 0.6 * slack_occ
    p_scalar = planner.plan(fleet, Scenario(D, EPS, B, cap))
    p_vec = planner.plan(fleet, Scenario(D, EPS, B, jnp.asarray([cap])))
    assert_plans_equal(p_scalar, p_vec)


@pytest.mark.parametrize("strategy", STRATEGIES)
def test_one_node_assignment_is_all_zeros(strategy):
    occ = jnp.linspace(0.01, 0.1, 7)
    a = assign_devices(occ, jnp.asarray([0.5]), strategy)
    np.testing.assert_array_equal(np.asarray(a), np.zeros(7, np.int32))


# ----------------------------------------------------- assignment invariants

_RNG = np.random.default_rng(0)
_CASES = [
    (_RNG.uniform(0.01, 0.2, size=9), np.array([0.5, 0.3, 0.2])),
    (_RNG.uniform(0.01, 0.2, size=9), np.array([np.inf, 0.2, 0.1])),
    (_RNG.uniform(0.01, 0.2, size=9), np.array([0.0, 0.4, 0.0, 0.4])),
    (_RNG.uniform(0.01, 0.2, size=12), np.array([np.inf, np.inf])),
    (np.full(6, 0.05), np.array([0.1, 0.0, 1.0])),
]


@pytest.mark.parametrize("strategy", STRATEGIES)
@pytest.mark.parametrize("case", range(len(_CASES)))
def test_assignment_invariants(strategy, case):
    """One present node per device, deterministic, host ≡ traced."""
    occ, caps = _CASES[case]
    a = np.asarray(assign_devices(occ, caps, strategy))
    assert a.shape == occ.shape and a.dtype == np.int32
    assert np.all((a >= 0) & (a < caps.shape[0]))
    # 0-capacity nodes are absent: NO strategy may place on them
    assert np.all(caps[a] > 0.0), (strategy, a, caps)
    # deterministic
    np.testing.assert_array_equal(
        a, np.asarray(assign_devices(occ, caps, strategy)))
    # host mirror is bit-identical (the decompose host-loop contract)
    np.testing.assert_array_equal(
        a, assign_devices_host(occ, caps, strategy))


def test_round_robin_cycles_present_nodes_only():
    a = np.asarray(assign_devices(np.full(6, 0.1),
                                  np.array([0.5, 0.0, 0.5]), "round_robin"))
    np.testing.assert_array_equal(a, [0, 2, 0, 2, 0, 2])


def test_unknown_strategy_raises():
    with pytest.raises(ValueError, match="unknown assignment strategy"):
        assign_devices(np.ones(3), np.ones(2), "nope")
    with pytest.raises(ValueError, match="unknown assignment strategy"):
        assign_devices_host(np.ones(3), np.ones(2), "nope")


@given(occ=st.lists(st.floats(0.001, 10.0), min_size=1, max_size=16),
       caps=st.lists(st.floats(0.0, 5.0), min_size=2, max_size=5))
@settings(max_examples=60, deadline=None)
def test_hybrid_never_loads_scarcest_node_worse_than_balanced(occ, caps):
    """The migration pass only ever *removes* load from the scarcest
    present node — so for every input Hybrid fragments it no worse than
    Balanced (the structural guarantee in ``_assign_hybrid``)."""
    occ = np.asarray(occ, np.float64)
    caps = np.asarray(caps, np.float64)
    if not np.any(caps > 0.0):
        caps[0] = 1.0
    ceff = np.where(np.isfinite(caps), caps, placement._CAP_BIG)
    e_star = int(np.argmin(np.where(caps > 0.0, ceff, np.inf)))
    load = lambda strat: float(np.sum(
        occ[assign_devices_host(occ, caps, strat) == e_star]))
    assert load("hybrid") <= load("balanced") + 1e-12


@given(occ=st.lists(st.floats(0.001, 10.0), min_size=1, max_size=16),
       caps=st.lists(st.floats(0.0, 5.0), min_size=2, max_size=5),
       strat=st.sampled_from(list(STRATEGIES)))
@settings(max_examples=60, deadline=None)
def test_host_traced_bit_identity_property(occ, caps, strat):
    occ = np.asarray(occ, np.float64)
    caps = np.asarray(caps, np.float64)
    if not np.any(caps > 0.0):
        caps[0] = 1.0
    np.testing.assert_array_equal(
        np.asarray(assign_devices(occ, caps, strat)),
        assign_devices_host(occ, caps, strat))


# ------------------------------------------------------ planned E>1 plans


def test_planned_assignment_respects_per_node_capacity(fleet, slack_occ):
    caps = jnp.asarray([0.5, 0.35, 0.25]) * slack_occ
    p = Planner(PlannerConfig(policy="robust_exact", outer_iters=3)).plan(
        fleet, Scenario(D, EPS, B, caps))
    assert bool(np.asarray(p.feasible).all())
    a = np.asarray(p.assignment)
    assert a.shape == (N,)
    occ_e = np.asarray(node_loads(select_point(fleet, p.m_sel).t_vm,
                                  p.assignment, 3))
    assert np.all(occ_e <= np.asarray(caps) * (1 + 1e-9)), (occ_e, caps)
    # the price is a per-node vector now
    assert np.asarray(p.alloc.mu).shape == (3,)


def test_duality_gap_certificate(fleet, slack_occ):
    caps = jnp.asarray([0.5, 0.35, 0.25]) * slack_occ
    p = Planner(PlannerConfig(policy="robust_exact", outer_iters=3)).plan(
        fleet, Scenario(D, EPS, B, caps))
    gap = float(plan_duality_gap(fleet, p, D, EPS, caps))
    assert np.isfinite(gap)
    assert gap >= -1e-8  # primal ≥ dual lower bound, always
    # the bound is meaningful: within the primal's own scale
    assert gap <= float(p.total_energy)


@pytest.mark.parametrize("strategy", STRATEGIES)
def test_policy_assign_strategy_is_selectable(fleet, slack_occ, strategy):
    """Unregistered Policy instances select the allocator family member;
    every member yields a valid (feasible-or-flagged) plan."""
    from repro.core.planner import get_policy
    import dataclasses

    pol = dataclasses.replace(get_policy("robust_exact"), assign=strategy)
    caps = jnp.asarray([0.6, 0.4, 0.3]) * slack_occ
    p = Planner(PlannerConfig(policy=pol, outer_iters=3)).plan(
        fleet, Scenario(D, EPS, B, caps))
    a = np.asarray(p.assignment)
    assert np.all((a >= 0) & (a < 3))
    if bool(np.asarray(p.feasible).all()):
        occ_e = np.asarray(node_loads(select_point(fleet, p.m_sel).t_vm,
                                      p.assignment, 3))
        assert np.all(occ_e <= np.asarray(caps) * (1 + 1e-9))


def test_grid_with_per_node_rows_and_absent_node(fleet, slack_occ):
    """(K, E) capacity rows are a traced grid axis; a 0 entry marks the
    node absent in that row — node-count what-ifs on one program."""
    c = 0.4 * slack_occ
    rows = jnp.asarray([[c, c, c], [1.5 * c, 1.5 * c, 0.0]])
    planner = Planner(PlannerConfig(policy="robust_exact", outer_iters=3))
    grid = planner.grid(fleet, D, EPS, B, edge_capacities=rows)
    assert grid.total_energy.shape == (1, 1, 1, 2)
    a_absent = np.asarray(grid.assignment)[0, 0, 0, 1]
    assert np.all(a_absent != 2), "absent node must never be assigned"
    # each row matches its single-scenario plan leaf-for-leaf
    for k in range(2):
        cell = jax.tree_util.tree_map(lambda x: x[0, 0, 0, k], grid)
        single = planner.plan(fleet, Scenario(D, EPS, B, rows[k]))
        assert_plans_equal(cell, single)


# --------------------------------------------------------- Cantelli rows


def test_cantelli_reduces_to_mean_row_at_zero_variance(fleet, slack_occ):
    """σ_vm = 0 ⇒ the chance-constrained occupancy row IS the mean row —
    every Allocation leaf identical."""
    chain0 = fleet.chain._replace(v_vm=jnp.zeros_like(fleet.chain.v_vm))
    fleet0 = fleet._replace(chain=chain0)
    m = jnp.full((N,), 4, jnp.int32)
    caps = jnp.asarray([0.6, 0.4, 0.3]) * slack_occ
    a = assign_devices(select_point(fleet0, m).t_vm, caps, "hybrid")
    mean = allocate(fleet0, m, D, EPS, B, edge_capacity_s=caps, assignment=a)
    cc = allocate(fleet0, m, D, EPS, B, edge_capacity_s=caps, assignment=a,
                  edge_eps=0.1)
    assert_plans_equal(mean, cc)


def test_cantelli_row_tightens_with_variance(fleet):
    """With real VM variance the Cantelli row charges σ_e·√(Σ v_vm) extra:
    a capacity between the mean and the chance-constrained occupancy is
    feasible under the mean row and rejected under ε_edge."""
    m = jnp.full((N,), 4, jnp.int32)
    sel = select_point(fleet, m)
    occ, var = float(sel.t_vm.sum()), float(sel.v_vm.sum())
    assert var > 0.0
    sig = placement.edge_sigma(0.05)
    cap = occ + 0.5 * sig * np.sqrt(var)  # between mean and Cantelli
    mean = allocate(fleet, m, D, EPS, B, edge_capacity_s=cap)
    cc = allocate(fleet, m, D, EPS, B, edge_capacity_s=cap, edge_eps=0.05)
    assert bool(np.asarray(mean.feasible).all())
    assert not bool(np.asarray(cc.feasible).any())


def test_edge_sigma_validation():
    assert placement.edge_sigma(None) == 0.0
    np.testing.assert_allclose(placement.edge_sigma(0.5), 1.0)
    with pytest.raises(ValueError, match="edge_eps"):
        placement.edge_sigma(1.5)
    with pytest.raises(ValueError, match="edge_eps"):
        PlannerConfig(edge_eps=0.0)
