"""x64-island guard (DESIGN.md §analysis).

The planner is a float64 precision island: ``repro.core`` /
``repro.solvers`` enable x64 once, at import, and nothing else touches
the flag. This tier pins the three ways that contract can rot:

1. a package OUTSIDE the island (kernels, models, parallel, data,
   train) starts importing the island and silently flips x64 for
   unrelated accelerator code;
2. an entry point starts mutating the flag at CALL time (per-call
   ``config.update`` is a cross-cutting side effect and a recompile
   source);
3. plan leaves drift off the declared dtypes — float leaves must be
   exactly float64 (the island deliberately deviates from a float32
   serving convention; see DESIGN.md §analysis), counters int32,
   flags bool, and nothing weakly typed.
"""
import os
import subprocess
import sys
from pathlib import Path

import pytest

SRC = Path(__file__).parent.parent / "src"

_ISLAND_INITS = {SRC / "repro" / "core" / "__init__.py",
                 SRC / "repro" / "solvers" / "__init__.py"}


def _x64_after(imports: str) -> bool:
    code = (f"import {imports}\n"
            "import jax\n"
            "print(int(bool(jax.config.jax_enable_x64)))\n")
    # inherit the environment: XLA's platform probing hangs without it
    env = dict(os.environ, PYTHONPATH=str(SRC))
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, check=True)
    return bool(int(out.stdout.strip().splitlines()[-1]))


@pytest.mark.parametrize("pkg", ["repro.kernels", "repro.models",
                                 "repro.parallel", "repro.data",
                                 "repro.train"])
def test_non_island_import_leaves_x64_off(pkg):
    assert not _x64_after(pkg), (
        f"importing {pkg} must not enable x64 — it has started importing "
        "the repro.core/repro.solvers precision island")


def test_island_import_enables_x64_once():
    assert _x64_after("repro.core")
    assert _x64_after("repro.solvers")


def test_no_call_time_flag_mutation_in_source():
    """Only the two island ``__init__`` files may touch the flag."""
    offenders = []
    for p in SRC.rglob("*.py"):
        if p in _ISLAND_INITS:
            continue
        if "jax_enable_x64" in p.read_text():
            offenders.append(str(p.relative_to(SRC)))
    assert offenders == [], (
        "x64 flag touched outside the island __init__ files: "
        f"{offenders}")


def test_entry_points_do_not_flip_the_flag_at_call_time():
    import jax

    from repro.analysis.jaxpr_audit import tiny_fleet
    from repro.core.api import Planner, PlannerConfig, Scenario
    from repro.core.montecarlo import violation_report

    flag = bool(jax.config.jax_enable_x64)
    fleet = tiny_fleet(3)
    sc = Scenario(deadline=0.18, eps=0.02, B=10e6)
    planner = Planner(PlannerConfig(policy="robust"))
    plan = planner.plan(fleet, sc)
    violation_report(jax.random.PRNGKey(0), fleet, plan.m_sel, plan.alloc,
                     sc.normalized(3).deadline, num_samples=128)
    planner.plan_many(fleet, [sc, sc._replace(deadline=0.2)])
    assert bool(jax.config.jax_enable_x64) == flag


def test_plan_leaves_hold_declared_dtypes():
    """Every Plan/Allocation leaf: float64 / int32 / bool, never weak.

    (The issue tracker's float32 wording is adapted here: this repo's
    planner is an x64 island by design — goldens pin float64 at 1e-8 —
    so the guard pins the declared float64 contract instead.)
    """
    import jax

    from repro.analysis.jaxpr_audit import tiny_fleet
    from repro.core.api import Planner, PlannerConfig, Scenario

    fleet = tiny_fleet(3)
    plan = Planner(PlannerConfig(policy="robust")).plan(
        fleet, Scenario(deadline=0.18, eps=0.02, B=10e6))
    for path, leaf in jax.tree_util.tree_flatten_with_path(plan)[0]:
        name = jax.tree_util.keystr(path)
        dt = str(leaf.dtype)
        assert dt in ("float64", "int32", "bool"), f"{name}: {dt}"
        assert not getattr(leaf, "weak_type", False), f"{name} is weak"
        if leaf.dtype.kind == "i":
            assert dt == "int32", f"{name}: counters are int32, got {dt}"
