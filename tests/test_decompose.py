"""Group-decomposed planning (``core.decompose``) vs the monolithic planner.

The decomposition is exact, not approximate: devices couple only through
the scalar prices (λ for Σ b ≤ B, μ for Σ t̄_vm ≤ C_edge), the per-group
programs run the same per-device math as the monolithic program at the
same prices, and the host-level price loops replicate the traced
log-space bracket/bisection searches in float64 — so every Plan leaf
must agree leaf-wise with ``Planner.plan`` at tight tolerance, under
slack AND binding edge capacity, for alternating and exact policies.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.paper_tables import mixed_spec
from repro.core.api import Planner, PlannerConfig, Scenario
from repro.core.decompose import bucket_size, build_groups
from repro.parallel.sharding import planner_mesh

N = 8  # 4 alexnet (9 points) + 4 resnet152 (10 points): genuinely ragged
SC = Scenario(0.2, 0.04, 30e6)
KEY = jax.random.PRNGKey(11)


@pytest.fixture(scope="module")
def spec():
    return mixed_spec(N)


@pytest.fixture(scope="module")
def gains(spec):
    return spec.sample_gains(KEY)


@pytest.fixture(scope="module")
def fleet(spec, gains):
    return spec.build(gains=gains)


def _assert_plans_match(shard, mono, rtol=1e-6):
    """Leaf-wise Plan comparison: identical treedefs, shapes and dtypes,
    floats within rtol, ints/bools exact.

    ``pccp_iters`` is shape-checked only: it is a convergence
    *diagnostic*, and the native-width group program legitimately
    converges in fewer gated iterations than the monolithic program,
    whose cross-group padding columns drag the convergence test."""
    flat_s, tdef_s = jax.tree_util.tree_flatten_with_path(shard)
    flat_m, tdef_m = jax.tree_util.tree_flatten_with_path(mono)
    assert tdef_s == tdef_m
    for (path, a), (_, b) in zip(flat_s, flat_m, strict=True):
        a, b = np.asarray(a), np.asarray(b)
        assert a.shape == b.shape and a.dtype == b.dtype, path
        if "pccp_iters" in jax.tree_util.keystr(path):
            continue
        if np.issubdtype(a.dtype, np.floating):
            np.testing.assert_allclose(a, b, rtol=rtol, atol=1e-12,
                                       err_msg=jax.tree_util.keystr(path))
        else:
            np.testing.assert_array_equal(a, b,
                                          err_msg=jax.tree_util.keystr(path))


def _parity(spec, fleet, gains, sc, **cfg):
    planner = Planner(PlannerConfig(**cfg))
    mono = planner.plan(fleet, sc)
    shard = planner.plan_sharded(spec, sc, gains=gains)
    _assert_plans_match(shard, mono)
    return mono, shard


def _occupancy(fleet, m_sel):
    return float(jnp.sum(
        jnp.take_along_axis(fleet.chain.t_vm, m_sel[:, None], -1)))


def test_parity_robust_exact_slack_edge(spec, fleet, gains):
    """No edge capacity: exact-partition alternation, multi-start."""
    _parity(spec, fleet, gains, SC, policy="robust_exact", outer_iters=3)


def test_parity_robust_exact_binding_edge_cap(spec, fleet, gains):
    """Edge cap at 30 % of the slack plan's occupancy — far below what
    the unconstrained plan books, so the μ pricing loop must genuinely
    reshape the partition on both paths (and still agree leaf-wise)."""
    slack = Planner(PlannerConfig(policy="robust_exact",
                                  outer_iters=3)).plan(fleet, SC)
    cap = 0.3 * _occupancy(fleet, slack.m_sel)
    mono, shard = _parity(spec, fleet, gains, SC, policy="robust_exact",
                          outer_iters=3, edge_capacity_s=cap)
    assert _occupancy(fleet, slack.m_sel) > cap  # cap binds by construction
    assert _occupancy(fleet, shard.m_sel) <= cap * (1 + 1e-9)
    assert bool(np.asarray(shard.feasible).all())


def test_parity_pccp_policy(spec, fleet, gains):
    """The inexact (PCCP surrogate) policy decomposes identically — the
    per-group partition program runs the same solver iterations."""
    _parity(spec, fleet, gains, SC, policy="robust", outer_iters=2,
            pccp_iters=4)


def test_parity_optimal_slack_and_binding(spec, fleet, gains):
    """The exhaustive policy (λ-search over per-point exact solves with a
    nested μ clearing per probe) decomposes too; under a binding cap the
    recorded μ must be strictly positive and still match."""
    slack_mono, _ = _parity(spec, fleet, gains, SC, policy="optimal")
    cap = 0.7 * _occupancy(fleet, slack_mono.m_sel)
    _, shard = _parity(spec, fleet, gains, SC, policy="optimal",
                       edge_capacity_s=cap)
    assert float(shard.alloc.mu) > 0.0
    assert _occupancy(fleet, shard.m_sel) <= cap * (1 + 1e-9)


def test_parity_per_node_capacity_vector(spec, fleet, gains):
    """Per-node (E,) capacity rows (DESIGN.md §placement): the sharded
    host loop replays the heuristic assignment bit-for-bit and clears
    each node's μ_e with the same bracket arithmetic — plans agree
    leaf-wise (assignment exactly, prices within rtol) under genuinely
    binding per-node capacities."""
    slack = Planner(PlannerConfig(policy="robust_exact",
                                  outer_iters=3)).plan(fleet, SC)
    occ0 = _occupancy(fleet, slack.m_sel)
    caps = jnp.asarray([0.3, 0.2, 0.1]) * occ0  # Σ = 0.6× slack: binds
    mono, shard = _parity(spec, fleet, gains, SC._replace(edge_capacity_s=caps),
                          policy="robust_exact", outer_iters=3)
    assert np.asarray(shard.alloc.mu).shape == (3,)
    assert bool(np.asarray(shard.feasible).all())
    # the cap genuinely reshaped the plan (the final *recorded* μ may
    # read 0 — at the alternation's fixed point the price is
    # internalized in the (b, f) allocation, cf. tests/test_edge.py)
    assert float(shard.total_energy) > float(slack.total_energy)
    from repro.core.placement import node_loads
    occ_e = np.asarray(node_loads(
        jnp.take_along_axis(fleet.chain.t_vm, shard.m_sel[:, None], -1)[:, 0],
        shard.assignment, 3))
    assert np.all(occ_e <= np.asarray(caps) * (1 + 1e-9)), (occ_e, caps)


def test_sharded_rejects_unsupported_vector_paths(spec, gains):
    """The exact solve-override path is monolithic-only under a capacity
    vector, and the Cantelli edge row is not wired into the host loop —
    both must refuse loudly, not silently fall back to scalar."""
    caps = (0.05, 0.03, 0.02)
    with pytest.raises(NotImplementedError):
        Planner(PlannerConfig(policy="optimal", edge_capacity_s=caps)
                ).plan_sharded(spec, SC, gains=gains)
    with pytest.raises(NotImplementedError):
        Planner(PlannerConfig(policy="robust_exact", edge_eps=0.1)
                ).plan_sharded(spec, SC, gains=gains)


def test_parity_scalar_init_m(spec, fleet, gains):
    """Scalar warm starts resolve per group exactly as on the padded
    fleet (clamped to each group's own chain width)."""
    planner = Planner(PlannerConfig(policy="robust_exact", outer_iters=2,
                                    multi_start=False))
    mono = planner.plan(fleet, SC, init_m=3)
    shard = planner.plan_sharded(spec, SC, gains=gains, init_m=3)
    _assert_plans_match(shard, mono)


def test_init_m_error_paths(spec, gains):
    planner = Planner(PlannerConfig(policy="robust_exact", outer_iters=2))
    with pytest.raises(TypeError, match="scalar init_m"):
        planner.plan_sharded(spec, SC, gains=gains,
                             init_m=np.full(N, 3, np.int32))
    with pytest.raises(ValueError, match="init_m must lie in"):
        planner.plan_sharded(spec, SC, gains=gains, init_m=99)
    with pytest.raises(ValueError, match="no alternation"):
        Planner(PlannerConfig(policy="optimal")).plan_sharded(
            spec, SC, gains=gains, init_m=3)


def test_key_matches_monolithic_build(spec):
    """Planning by key (not explicit gains) must agree with the
    monolithic path built from the same key — ``spec.sample_gains(key)``
    is the same draw ``spec.build(key)`` bakes into the fleet."""
    planner = Planner(PlannerConfig(policy="robust_exact", outer_iters=2,
                                    multi_start=False))
    mono = planner.plan(spec.build(KEY), SC)
    shard = planner.plan_sharded(spec, SC, key=KEY)
    _assert_plans_match(shard, mono)


def test_group_bandwidth_sums_within_budget(spec, gains):
    """Property: at every bandwidth level — slack through starved — the
    per-group bandwidth totals (what each compiled program books against
    the shared budget) sum to ≤ B, and pad lanes book nothing: the real
    lanes' total equals the Plan's total."""
    planner = Planner(PlannerConfig(policy="robust_exact", outer_iters=2,
                                    multi_start=False))
    for B in (30e6, 8e6, 2e6):
        p = planner.plan_sharded(spec, Scenario(0.2, 0.04, B), gains=gains)
        b = np.asarray(p.alloc.b)
        per_group = [float(b[start:stop].sum())
                     for start, stop in spec.group_slices()]
        assert sum(per_group) <= B * (1 + 1e-9), (B, per_group)
        assert all(g > 0.0 for g in per_group)


def test_bucket_size_policy():
    # small groups compile at their exact width
    for n in (1, 2, 7, 16):
        assert bucket_size(n) == n
    # large groups round up to a power-of-two quantum ~n/16: waste ≤ 1/8
    for n in (17, 100, 1000, 12345, 10**5):
        n_pad = bucket_size(n)
        assert n_pad >= n
        assert (n_pad - n) / n <= 0.125 + 1e-12
    # growth hits a bounded number of distinct shapes, not one per count
    assert len({bucket_size(n) for n in range(1000, 2000)}) < 40
    # mesh-size multiples are respected on top of the quantum
    for mult in (1, 2, 4, 8):
        for n in (3, 17, 1000):
            assert bucket_size(n, mult) % mult == 0
            assert bucket_size(n, mult) >= n


def test_build_groups_native_width_and_masks(spec, gains):
    groups = build_groups(spec, gains, planner_mesh())
    assert [g.name for g in groups] == [gs.name for gs in spec.groups]
    g_np = np.asarray(gains)
    for g, gs, (start, stop) in zip(groups, spec.groups,
                                    spec.group_slices(), strict=True):
        # native table width: the group's own chain, no cross-group pad
        assert g.fleet.chain.t_vm.shape == (g.n_pad, gs.chain.num_points)
        assert g.fleet.num_devices == g.n_pad == bucket_size(gs.count)
        assert (g.n, g.start, g.stop) == (gs.count, start, stop)
        # real lanes carry the fleet-order gains slice; mask covers them
        np.testing.assert_array_equal(
            np.asarray(g.fleet.link.gain)[:g.n], g_np[start:stop])
        np.testing.assert_array_equal(np.asarray(g.w),
                                      (np.arange(g.n_pad) < g.n) * 1.0)


def test_build_groups_rejects_wrong_gains_shape(spec):
    with pytest.raises(ValueError, match="gains must be"):
        build_groups(spec, np.ones(N + 1), planner_mesh())
