"""Shared-edge capacity coupling (DESIGN.md §edge).

Pins the tentpole contracts:

- ``edge_capacity_s = ∞`` (or unset) is a numerical no-op — plans are
  leaf-identical to the uncoupled planner (which itself is golden-pinned
  against ``tests/golden/seed_plans.json``);
- with a binding capacity the (λ, μ) two-price search satisfies
  Σ t̄_vm(m_n) ≤ C_edge with an active price μ > 0, energy monotone in
  the capacity, and the alternation policies land on the same plans;
- ``allocate`` matches the extended ``allocate_ipm`` joint solve at the
  capped optimum (rtol 1e-6), and rejects capacity-violating partitions;
- the capacity is a traced ``Scenario`` leaf: sweeps batch through
  ``plan_many``/``grid`` (with a fourth grid axis) without recompiling;
- the Monte-Carlo ground truth models the shared edge as a
  processor-sharing accelerator (times stretch by max(1, Σ t̄_vm/C)).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.paper_tables import alexnet_fleet
from repro.core import (
    Planner,
    PlannerConfig,
    Scenario,
    allocate,
    allocate_ipm,
    plan_optimal,
    scenario_at,
    violation_report,
)
from repro.core.resource import select_point

#: loose-deadline AlexNet scenario: full-local is feasible for every
#: device, so the edge price has room to move work on-device (at the
#: paper's D = 0.18 the minimum-occupancy feasible point is already the
#: unpriced optimum and any tighter capacity is simply infeasible)
D, B, EPS = 0.40, 10e6, 0.02
N = 12


@pytest.fixture(scope="module")
def fleet():
    return alexnet_fleet(jax.random.PRNGKey(0), N)


def occupancy(fleet, m_sel) -> float:
    return float(select_point(fleet, m_sel).t_vm.sum())


def assert_plans_equal(a, b):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb, strict=True):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------- no-op


@pytest.mark.parametrize("policy", ["robust_exact", "robust", "optimal"])
def test_infinite_capacity_is_leaf_identical_noop(fleet, policy):
    """A traced ∞ capacity must not perturb a single leaf — this is what
    keeps the golden-pinned uncoupled plans valid under the new path."""
    planner = Planner(PlannerConfig(policy=policy, outer_iters=3,
                                    pccp_iters=4))
    p_unset = planner.plan(fleet, Scenario(0.18, EPS, B))
    p_inf = planner.plan(fleet, Scenario(0.18, EPS, B, jnp.inf))
    assert_plans_equal(p_unset, p_inf)


def test_slack_capacity_price_is_zero(fleet):
    """Complementary slackness: a non-binding capacity costs nothing."""
    p0 = plan_optimal(fleet, D, EPS, B)
    cap = 2.0 * occupancy(fleet, p0.m_sel)
    p = plan_optimal(fleet, D, EPS, B, edge_capacity_s=cap)
    assert float(p.alloc.mu) == 0.0
    assert_plans_equal(p0, p)


# ---------------------------------------------------------- binding cap


def test_binding_capacity_two_price_search(fleet):
    p0 = plan_optimal(fleet, D, EPS, B)
    occ0 = occupancy(fleet, p0.m_sel)
    last_e = float(p0.total_energy)
    for frac in (0.9, 0.6, 0.3):
        cap = occ0 * frac
        p = plan_optimal(fleet, D, EPS, B, edge_capacity_s=cap)
        occ = occupancy(fleet, p.m_sel)
        assert occ <= cap * (1 + 1e-9), (frac, occ, cap)
        assert bool(p.feasible.all())
        assert float(p.alloc.mu) > 0.0  # the price is active
        assert float(p.total_energy) >= last_e - 1e-12  # tighter cap costs
        last_e = float(p.total_energy)


def test_alternation_policy_respects_capacity_and_matches_optimal(fleet):
    p0 = plan_optimal(fleet, D, EPS, B)
    cap = occupancy(fleet, p0.m_sel) * 0.6
    popt = plan_optimal(fleet, D, EPS, B, edge_capacity_s=cap)
    palt = Planner(PlannerConfig(policy="robust_exact", outer_iters=3)).plan(
        fleet, Scenario(D, EPS, B, cap))
    assert occupancy(fleet, palt.m_sel) <= cap * (1 + 1e-9)
    assert bool(palt.feasible.all())
    # (no μ > 0 assertion here: at the alternation's fixed point the
    # price is internalized in the (b, f) allocation — devices moved
    # on-device get minimal bandwidth, which keeps the unpriced argmin
    # at their local point, so the final clearing price can read 0)
    np.testing.assert_allclose(float(palt.total_energy),
                               float(popt.total_energy), rtol=1e-6)


def test_infeasible_capacity_flags(fleet):
    """A capacity below the minimum feasible occupancy cannot be priced
    out — the planner must say so instead of silently violating it."""
    # at the paper deadline full-local is infeasible, so occupancy cannot
    # go below Σ t̄_vm at the minimum-occupancy feasible points
    p0 = plan_optimal(fleet, 0.18, EPS, B)
    cap = occupancy(fleet, p0.m_sel) * 0.5
    p = plan_optimal(fleet, 0.18, EPS, B, edge_capacity_s=cap)
    assert not bool(p.feasible.all())


# ------------------------------------------------------- allocate / IPM


def test_allocate_matches_ipm_with_binding_capacity(fleet):
    """Acceptance: at the capped optimum, the dual allocation equals the
    paper-faithful joint IPM solve with the occupancy row active."""
    p0 = plan_optimal(fleet, D, EPS, B)
    cap = occupancy(fleet, p0.m_sel) * 0.6
    p = plan_optimal(fleet, D, EPS, B, edge_capacity_s=cap)
    a = allocate(fleet, p.m_sel, D, EPS, B, edge_capacity_s=cap)
    ai = allocate_ipm(fleet, p.m_sel, jnp.full((N,), D), jnp.full((N,), EPS),
                      B, edge_capacity_s=cap)
    assert bool(a.feasible.all())
    ea, eb = float(a.energy.sum()), float(ai.energy.sum())
    np.testing.assert_allclose(ea, eb, rtol=1e-6)


def test_allocate_flags_capacity_violation(fleet):
    # m=4 keeps the uplink demand inside B so only the capacity differs
    # between the two calls (m=0/1 would be bandwidth-infeasible at N=12)
    m = jnp.full((N,), 4, jnp.int32)
    occ = occupancy(fleet, m)
    ok = allocate(fleet, m, D, EPS, B, edge_capacity_s=occ * 2.0)
    assert bool(ok.feasible.all())
    a = allocate(fleet, m, D, EPS, B, edge_capacity_s=occ * 0.5)
    assert not bool(a.feasible.any())


def test_allocate_ipm_rejects_violated_capacity(fleet):
    m = jnp.full((N,), 1, jnp.int32)
    cap = occupancy(fleet, m) * 0.5
    with pytest.raises(ValueError, match="capacity"):
        allocate_ipm(fleet, m, jnp.full((N,), D), jnp.full((N,), EPS), B,
                     edge_capacity_s=cap)


# ------------------------------------------------------- batched sweeps


def test_capacity_sweep_zipped_and_grid(fleet):
    planner = Planner(PlannerConfig(policy="robust_exact", outer_iters=3))
    p0 = planner.plan(fleet, Scenario(D, EPS, B))
    cap = occupancy(fleet, p0.m_sel) * 0.6

    scenarios = [Scenario(D, EPS, B), Scenario(D, EPS, B, cap)]
    many = planner.plan_many(fleet, scenarios)
    for k, sc in enumerate(scenarios):
        assert_plans_equal(scenario_at(many, k), planner.plan(fleet, sc))

    grid = planner.grid(fleet, D, EPS, B,
                        edge_capacities=(jnp.inf, cap))
    assert grid.total_energy.shape == (1, 1, 1, 2)
    cell = jax.tree_util.tree_map(lambda x: x[0, 0, 0, 1], grid)
    assert_plans_equal(cell, planner.plan(fleet, Scenario(D, EPS, B, cap)))
    # without the axis the grid keeps its 3-axis contract
    g3 = planner.grid(fleet, (D,), EPS, B)
    assert g3.total_energy.shape == (1, 1, 1)


def test_capacity_is_traced_not_a_cache_key(fleet):
    from repro.core import api

    planner = Planner(PlannerConfig(policy="robust_exact", outer_iters=2))
    planner.plan_many(fleet, [Scenario(D, EPS, B, 0.004)])
    size = api.plan_many_jit._cache_size()
    planner.plan_many(fleet, [Scenario(D, EPS, B, 0.002)])
    planner.plan_many(fleet, [Scenario(D, EPS, B, jnp.inf)])
    assert api.plan_many_jit._cache_size() == size


def test_config_default_capacity_applies_when_scenario_unset(fleet):
    cap = 0.004
    explicit = Planner(PlannerConfig(policy="robust_exact", outer_iters=2)
                       ).plan(fleet, Scenario(D, EPS, B, cap))
    defaulted = Planner(PlannerConfig(policy="robust_exact", outer_iters=2,
                                      edge_capacity_s=cap)
                        ).plan(fleet, Scenario(D, EPS, B))
    assert_plans_equal(explicit, defaulted)
    # the scenario leaf wins over the config default
    overridden = Planner(PlannerConfig(policy="robust_exact", outer_iters=2,
                                       edge_capacity_s=cap * 100)
                         ).plan(fleet, Scenario(D, EPS, B, cap))
    assert_plans_equal(explicit, overridden)


def test_scenario_capacity_validation(fleet):
    # (E,) per-node vectors are valid since DESIGN.md §placement; only
    # >=2-D capacity shapes are rejected at normalization
    with pytest.raises(ValueError, match="edge_capacity_s"):
        Scenario(D, EPS, B, jnp.full((2, 3), 0.1)).normalized(N)
    with pytest.raises(ValueError, match="edge_capacity_s"):
        PlannerConfig(edge_capacity_s=0.0)
    with pytest.raises(ValueError, match="edge_capacity_s"):
        PlannerConfig(edge_capacity_s=(0.0, 0.0))


# ------------------------------------------------------- MC ground truth


def test_mc_congestion_model(fleet):
    planner = Planner(PlannerConfig(policy="robust_exact", outer_iters=3))
    p = planner.plan(fleet, Scenario(D, EPS, B))
    occ = occupancy(fleet, p.m_sel)
    key = jax.random.PRNGKey(7)
    dl = jnp.full((N,), D)
    base = violation_report(key, fleet, p.m_sel, p.alloc, dl)
    under = violation_report(key, fleet, p.m_sel, p.alloc, dl,
                             edge_capacity_s=occ * 2.0)
    # capacity above the demand: identical samples, identical rates
    np.testing.assert_array_equal(np.asarray(base.rate), np.asarray(under.rate))
    over = violation_report(key, fleet, p.m_sel, p.alloc, dl,
                            edge_capacity_s=occ / 8.0)
    # overload stretches VM times -> latency and violations can only grow
    assert float(over.mean_time.sum()) > float(base.mean_time.sum())
    assert float(over.rate.max()) >= float(base.rate.max())


def test_capped_plan_survives_congestion_mc(fleet):
    """End-to-end acceptance shape: a plan made under a binding capacity
    keeps its probabilistic deadline guarantee under the congestion-aware
    ground truth (Σ occ ≤ C ⇒ no stretch)."""
    p0 = plan_optimal(fleet, D, EPS, B)
    cap = occupancy(fleet, p0.m_sel) * 0.6
    p = plan_optimal(fleet, D, EPS, B, edge_capacity_s=cap)
    vr = violation_report(jax.random.PRNGKey(3), fleet, p.m_sel, p.alloc,
                          jnp.full((N,), D), edge_capacity_s=cap)
    assert float(vr.rate.max()) <= EPS + 0.01
