"""ServingEngine batching: deterministic deadline-tie scheduling."""
import numpy as np

from repro.configs.registry import get_config
from repro.serve.engine import Request, ServingEngine


def _engine(max_batch=2):
    return ServingEngine(get_config("tinyllama-1.1b", smoke=True), params=None,
                         max_batch=max_batch)


def _req(uid, deadline):
    return Request(uid=uid, prompt=np.zeros(4, np.int32), deadline_s=deadline)


def test_schedule_breaks_deadline_ties_by_uid():
    eng = _engine()
    reqs = [_req(u, 0.5) for u in (3, 1, 2, 0)]
    batches = eng.schedule(reqs)
    assert [[r.uid for r in b] for b in batches] == [[0, 1], [2, 3]]


def test_schedule_is_arrival_order_independent():
    """Batch composition must be a function of queue contents only —
    the old sort by deadline alone kept insertion order on ties."""
    eng = _engine()
    reqs = [_req(0, 0.5), _req(1, 0.2), _req(2, 0.5), _req(3, 0.2)]
    want = [[r.uid for r in b] for b in eng.schedule(reqs)]
    assert want == [[1, 3], [0, 2]]  # EDF first, uid on ties
    for perm in ([3, 2, 1, 0], [2, 0, 3, 1], [1, 3, 0, 2]):
        shuffled = [reqs[i] for i in perm]
        assert [[r.uid for r in b] for b in eng.schedule(shuffled)] == want


def test_schedule_edf_order_dominates_uid():
    eng = _engine(max_batch=3)
    reqs = [_req(0, 0.9), _req(1, 0.1), _req(2, 0.9), _req(3, 0.1)]
    batches = eng.schedule(reqs)
    assert [[r.uid for r in b] for b in batches] == [[1, 3, 0], [2]]
