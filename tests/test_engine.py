"""ServingEngine batching, stats, input validation, and the
``measured_chain`` re-fit hook (DESIGN.md §robustness satellites)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.core.blocks import BlockChain
from repro.serve.engine import EngineStats, Request, ServingEngine
from repro.serve.partitioned import measured_chain


def _engine(max_batch=2):
    return ServingEngine(get_config("tinyllama-1.1b", smoke=True), params=None,
                         max_batch=max_batch)


def _req(uid, deadline, arrival=0.0):
    return Request(uid=uid, prompt=np.zeros(4, np.int32), deadline_s=deadline,
                   arrival_s=arrival)


def test_schedule_breaks_deadline_ties_by_uid():
    eng = _engine()
    reqs = [_req(u, 0.5) for u in (3, 1, 2, 0)]
    batches = eng.schedule(reqs)
    assert [[r.uid for r in b] for b in batches] == [[0, 1], [2, 3]]


def test_schedule_is_arrival_order_independent():
    """Batch composition must be a function of queue contents only —
    the old sort by deadline alone kept insertion order on ties."""
    eng = _engine()
    reqs = [_req(0, 0.5), _req(1, 0.2), _req(2, 0.5), _req(3, 0.2)]
    want = [[r.uid for r in b] for b in eng.schedule(reqs)]
    assert want == [[1, 3], [0, 2]]  # EDF first, uid on ties
    for perm in ([3, 2, 1, 0], [2, 0, 3, 1], [1, 3, 0, 2]):
        shuffled = [reqs[i] for i in perm]
        assert [[r.uid for r in b] for b in eng.schedule(shuffled)] == want


def test_schedule_burst_fifo_regression():
    """A replayed burst of equal-deadline requests: arrival time breaks
    the tie BEFORE uid, so early arrivals are never starved behind later
    ones that happen to carry smaller uids (the old sort key was
    (deadline, uid) — this burst is its counterexample)."""
    eng = _engine()
    reqs = [_req(9, 0.5, 0.00), _req(7, 0.5, 0.01),
            _req(5, 0.5, 0.02), _req(3, 0.5, 0.03)]
    batches = eng.schedule(reqs)
    assert [[r.uid for r in b] for b in batches] == [[9, 7], [5, 3]]
    # uid still decides equal (deadline, arrival) pairs
    reqs = [_req(4, 0.5, 0.01), _req(2, 0.5, 0.01), _req(8, 0.5, 0.00)]
    assert [[r.uid for r in b] for b in eng.schedule(reqs)] == [[8, 2], [4]]
    # ...and deadline still dominates arrival
    reqs = [_req(0, 0.9, 0.00), _req(1, 0.1, 0.05)]
    assert [[r.uid for r in b] for b in eng.schedule(reqs)] == [[1, 0]]


def test_schedule_edf_order_dominates_uid():
    eng = _engine(max_batch=3)
    reqs = [_req(0, 0.9), _req(1, 0.1), _req(2, 0.9), _req(3, 0.1)]
    batches = eng.schedule(reqs)
    assert [[r.uid for r in b] for b in batches] == [[1, 3, 0], [2]]


# ---------------------------------------------------------------------------
# stats: per-request outcomes + summary semantics
# ---------------------------------------------------------------------------


def test_record_completion_scores_deadline():
    st = EngineStats()
    st.record_completion(0, 0.4, 0.5)  # met
    st.record_completion(1, 0.7, 0.5)  # missed
    st.record_completion(2, 0.5, 0.5)  # boundary counts as met
    assert st.request_uids == [0, 1, 2]
    assert st.deadline_flags == [True, False, True]
    s = st.summary()
    assert s["requests_completed"] == 3
    np.testing.assert_allclose(s["deadline_met_rate"], 2 / 3)


def test_summary_completion_percentiles():
    st = EngineStats()
    times = [0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8, 0.9, 1.0]
    for uid, t in enumerate(times):
        st.record_completion(uid, t, 0.55)
    s = st.summary()
    np.testing.assert_allclose(s["completion_p50_s"],
                               np.percentile(times, 50.0))
    np.testing.assert_allclose(s["completion_p95_s"],
                               np.percentile(times, 95.0))
    np.testing.assert_allclose(s["completion_p99_s"],
                               np.percentile(times, 99.0))
    assert s["deadline_violations"] == 5  # 0.6 … 1.0 missed
    # empty stats: percentiles are NaN, never a fake zero
    e = EngineStats().summary()
    assert np.isnan(e["completion_p50_s"]) and np.isnan(e["completion_p99_s"])


def test_window_counts_cover_only_the_current_window():
    """mark_window starts a fresh observation window — the sentinel feed
    (window_counts) sees completions after the most recent mark only,
    while the cumulative summary keeps the whole stream."""
    st = EngineStats()
    st.record_completion(0, 0.9, 0.5)  # missed, pre-window
    st.mark_window()
    assert st.window_counts() == (0, 0)
    st.record_completion(1, 0.4, 0.5)  # met
    st.record_completion(2, 0.8, 0.5)  # missed
    assert st.window_counts() == (1, 2)
    s = st.summary()
    assert s["window_violations"] == 1 and s["window_requests"] == 2
    assert s["deadline_violations"] == 2  # cumulative keeps the first miss
    st.mark_window()
    assert st.window_counts() == (0, 0)


def test_summary_empty_reports_nan_not_zero():
    """The old summary reported 0.0 mean/variance for ≤1 decode samples —
    a fake zero-variance chain a re-fit would happily ingest. Empty must
    be NaN + explicit sample counts."""
    s = EngineStats().summary()
    assert s["decode_samples"] == 0 and s["prefill_samples"] == 0
    assert np.isnan(s["decode_mean_s"]) and np.isnan(s["decode_var_s2"])
    assert np.isnan(s["prefill_mean_s"]) and np.isnan(s["deadline_met_rate"])


def test_summary_drops_warmup_decode_step():
    st = EngineStats()
    st.decode_times = [10.0, 0.5, 0.7]  # first step = jit dispatch
    s = st.summary()
    assert s["decode_samples"] == 2
    np.testing.assert_allclose(s["decode_mean_s"], 0.6)
    # a single decode step is ALL warmup: no steady-state samples yet
    st.decode_times = [10.0]
    assert st.summary()["decode_samples"] == 0
    assert np.isnan(st.summary()["decode_mean_s"])


# ---------------------------------------------------------------------------
# input validation
# ---------------------------------------------------------------------------


def test_run_rejects_empty_queue():
    with pytest.raises(ValueError, match="empty request queue"):
        _engine().run([])


def test_run_rejects_bad_requests():
    eng = _engine()
    bad_tokens = Request(uid=7, prompt=np.zeros(4, np.int32), max_new_tokens=0)
    with pytest.raises(ValueError, match="request 7.*max_new_tokens"):
        eng.run([bad_tokens])
    empty = Request(uid=8, prompt=np.zeros(0, np.int32))
    with pytest.raises(ValueError, match="request 8.*empty prompt"):
        eng.run([empty])
    long = Request(uid=9, prompt=np.zeros(eng.window + 1, np.int32))
    with pytest.raises(ValueError, match="request 9.*exceeds the engine"):
        eng.run([long])


# ---------------------------------------------------------------------------
# measured_chain re-fit hook
# ---------------------------------------------------------------------------


def _chain(t_vm):
    t_vm = jnp.asarray(t_vm, jnp.float64)
    ones = jnp.ones_like(t_vm)
    return BlockChain(d_bits=ones * 8e6, w_flops=ones * 1e9, g_eff=ones * 1e9,
                      v_loc=ones * 1e-4, t_vm=t_vm, v_vm=0.01 * t_vm**2)


def test_measured_chain_single_and_ragged_shapes():
    stats = {"decode_mean_s": 0.02, "decode_var_s2": 1e-6}
    single = _chain([0.05, 0.03, 0.01, 0.0])
    out = measured_chain(single, stats)
    assert out.t_vm.shape == single.t_vm.shape
    np.testing.assert_allclose(float(out.t_vm[0]), 0.02)
    # batched/ragged fleet chain: each device anchors on its OWN m=0
    # entry, not the first row's
    fleet_chain = _chain([[0.05, 0.03, 0.0], [0.10, 0.04, 0.0]])
    out2 = measured_chain(fleet_chain, stats)
    assert out2.t_vm.shape == (2, 3)
    np.testing.assert_allclose(np.asarray(out2.t_vm[:, 0]), [0.02, 0.02])
    # relative shape within each device is preserved
    np.testing.assert_allclose(float(out2.t_vm[0, 1] / out2.t_vm[0, 0]),
                               0.03 / 0.05)
    np.testing.assert_allclose(float(out2.t_vm[1, 1] / out2.t_vm[1, 0]),
                               0.04 / 0.10)


def test_measured_chain_idempotent():
    stats = {"decode_mean_s": 0.02, "decode_var_s2": 1e-6}
    base = _chain([[0.05, 0.03, 0.0], [0.10, 0.04, 0.0]])
    once = measured_chain(base, stats)
    twice = measured_chain(once, stats)
    np.testing.assert_allclose(np.asarray(twice.t_vm), np.asarray(once.t_vm),
                               rtol=1e-12)
    np.testing.assert_allclose(np.asarray(twice.v_vm), np.asarray(once.v_vm),
                               rtol=1e-12)


def test_measured_chain_rejects_empty_stats():
    base = _chain([0.05, 0.03, 0.0])
    nan = float("nan")
    with pytest.raises(ValueError, match="decode_mean_s"):
        measured_chain(base, {"decode_mean_s": nan, "decode_var_s2": nan})
    with pytest.raises(ValueError, match="decode_mean_s"):
        measured_chain(base, {"decode_mean_s": 0.0, "decode_var_s2": 1e-6})
    with pytest.raises(ValueError, match="decode_var_s2"):
        measured_chain(base, {"decode_mean_s": 0.02, "decode_var_s2": nan})
    with pytest.raises(ValueError, match="decode_mean_s"):
        measured_chain(base, {})
