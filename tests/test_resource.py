"""Resource-allocation subproblem: dual solver vs paper-faithful IPM."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.paper_tables import alexnet_fleet, resnet152_fleet
from repro.core import allocate, allocate_ipm
from repro.core.resource import deadline_budget, select_point
from repro.core import channel, energy


@pytest.fixture(scope="module")
def fleet():
    return alexnet_fleet(jax.random.PRNGKey(0), 6)


def test_bandwidth_budget_respected(fleet):
    m = jnp.full((6,), 7, jnp.int32)
    a = allocate(fleet, m, 0.2, 0.02, 10e6)
    assert float(jnp.sum(a.b)) <= 10e6 * (1 + 1e-9)
    assert bool(jnp.all(a.b > 0))
    assert bool(jnp.all((a.f >= fleet.platform.f_min - 1) & (a.f <= fleet.platform.f_max + 1)))


def test_deadline_met_in_expectation_with_margin(fleet):
    m = jnp.full((6,), 7, jnp.int32)
    a = allocate(fleet, m, 0.2, 0.02, 10e6)
    sel = select_point(fleet, m)
    t = (
        energy.mean_local_time(sel.w_flops, sel.g_eff, a.f)
        + channel.offload_time(sel.d_bits, a.b, fleet.link.p_tx, fleet.link.gain)
    )
    budget = deadline_budget(sel, jnp.full((6,), 0.2), jnp.full((6,), 0.02))
    assert bool(jnp.all(t <= budget + 1e-9))


def test_dual_matches_interior_point(fleet):
    """Strong duality: the dual-decomposition optimum equals the paper's
    joint IPM optimum (within solver tolerance)."""
    m = jnp.full((6,), 7, jnp.int32)
    a = allocate(fleet, m, 0.2, 0.02, 10e6)
    b = allocate_ipm(fleet, m, jnp.full((6,), 0.2), jnp.full((6,), 0.02), 10e6)
    ea, eb = float(jnp.sum(a.energy)), float(jnp.sum(b.energy))
    assert abs(ea - eb) / max(ea, 1e-12) < 5e-3, (ea, eb)
    # IPM can only be >= (dual gives the true optimum; IPM feasible)
    assert eb >= ea - 1e-6


def test_energy_monotone_in_deadline(fleet):
    m = jnp.full((6,), 7, jnp.int32)
    es = []
    for d in (0.16, 0.2, 0.26):
        a = allocate(fleet, m, d, 0.02, 10e6)
        es.append(float(jnp.sum(a.energy)))
    assert es[0] >= es[1] >= es[2]


def test_infeasible_point_flagged():
    fleet = resnet152_fleet(jax.random.PRNGKey(1), 4)
    m = jnp.full((4,), 9, jnp.int32)  # full local
    a = allocate(fleet, m, 0.001, 0.02, 30e6)  # 1 ms deadline: impossible
    assert not bool(jnp.any(a.feasible))


def test_feasible_flag_consistent_with_returned_bandwidth(fleet):
    """Regression: the final Σb ≤ B rescale shrinks b (lengthening t_off);
    ``feasible`` must be rechecked against the *returned* (b, f), not the
    pre-rescale solution. Tight B makes the price active so the rescale
    actually fires."""
    m = jnp.full((6,), 7, jnp.int32)
    for B in (2e6, 5e6, 10e6):
        a = allocate(fleet, m, 0.2, 0.02, B)
        sel = select_point(fleet, m)
        t = (
            energy.mean_local_time(sel.w_flops, sel.g_eff, a.f)
            + channel.offload_time(sel.d_bits, a.b, fleet.link.p_tx, fleet.link.gain)
        )
        budget = deadline_budget(sel, jnp.full((6,), 0.2), jnp.full((6,), 0.02))
        ok = np.asarray(t <= budget + 1e-9)
        assert np.array_equal(np.asarray(a.feasible), np.asarray(a.feasible) & ok)


def test_dual_bracket_expands_beyond_seed_range(fleet):
    """Regression (ISSUE 4): the seed's hard-coded bisection bracket
    pinned λ at 10² on extreme bandwidth-starved scenarios and silently
    masked the unmet budget behind the rescale. With a huge deadline and
    a few-dozen-Hz budget the true market-clearing price is ≫ 10²: the
    expanded bracket must find it, clear Σb ≤ B by *pricing* (not by
    rescaling), and still match the joint IPM optimum."""
    m = jnp.full((6,), 7, jnp.int32)
    D, B = 2000.0, 36.0
    a = allocate(fleet, m, D, 0.02, B)
    assert float(a.lam) > 100.0  # beyond the seed bracket top
    assert float(jnp.sum(a.b)) <= B * (1 + 1e-9)
    assert bool(a.feasible.all())
    ai = allocate_ipm(fleet, m, jnp.full((6,), D), jnp.full((6,), 0.02), B)
    ea, eb = float(jnp.sum(a.energy)), float(jnp.sum(ai.energy))
    assert abs(ea - eb) / max(ea, 1e-12) < 5e-3, (ea, eb)


def test_rescale_respects_feasibility_floor():
    """Unit contract of the post-bisection rescale: devices are never
    pushed below their λ-invariant floor while the floors fit in B (the
    shortfall moves to unclamped devices), and Σb comes out ≤ B."""
    from repro.core.resource import _rescale_with_floor

    b = jnp.asarray([10.0, 10.0, 2.0])
    b_lo = jnp.asarray([1.0, 1.0, 1.9])
    out = np.asarray(_rescale_with_floor(b, b_lo, 11.0))
    assert out[2] == 1.9  # clamped at its floor, not at 2*(11/22)=1.0
    np.testing.assert_allclose(out.sum(), 11.0, rtol=1e-12)
    assert out[0] == out[1] and out[0] < 10.0 * (11.0 / 22.0) + 1e-12

    # no device dips below its floor -> bit-exactly the plain rescale
    b = jnp.asarray([8.0, 4.0])
    b_lo = jnp.asarray([1.0, 1.0])
    out = np.asarray(_rescale_with_floor(b, b_lo, 6.0))
    np.testing.assert_array_equal(out, np.asarray(b * (6.0 / jnp.sum(b))))

    # floors that overrun B fall back to the plain rescale (Σb <= B is the
    # hard constraint; the deadline recheck flags the casualties)
    b = jnp.asarray([5.0, 5.0])
    b_lo = jnp.asarray([4.0, 4.0])
    out = np.asarray(_rescale_with_floor(b, b_lo, 6.0))
    np.testing.assert_array_equal(out, np.asarray(b * (6.0 / jnp.sum(b))))


def test_deadline_recheck_flags_shrunken_bandwidth(fleet):
    """Unit check of the recheck predicate: halving an exactly-binding b
    must flip the deadline check to False."""
    from repro.core.resource import _deadline_ok
    m = jnp.full((6,), 7, jnp.int32)
    a = allocate(fleet, m, 0.2, 0.02, 10e6)
    sel = select_point(fleet, m)
    budget = deadline_budget(sel, jnp.full((6,), 0.2), jnp.full((6,), 0.02))
    sigma = jnp.zeros((6,))
    v_base = jnp.zeros((6,))
    ok_full = _deadline_ok(a.b, a.f, sel, budget, fleet.link.p_tx,
                           fleet.link.gain, sigma, v_base)
    assert bool(jnp.all(ok_full == a.feasible)) or bool(jnp.all(ok_full))
    ok_half = _deadline_ok(0.5 * a.b, a.f, sel, budget, fleet.link.p_tx,
                           fleet.link.gain, sigma, v_base)
    # the allocator drives (b, f) onto the deadline, so halving b must
    # violate it wherever the constraint was active
    assert not bool(jnp.all(ok_half))


def test_bracket_warm_start_value_identical(fleet):
    """``allocate_with_bracket`` threads the λ-bracket top across repeated
    solves (the Algorithm-2 alternation and the group-sharded planner's
    price loop both carry it). Reuse must be value-IDENTICAL to a cold
    start — not merely close — because the warm expansion snaps to the
    same log-price grid the cold walk uses and contracts to the same
    canonical top, whether the prior bracket is far too high, spot-on,
    or far too low for the new scenario."""
    from repro.core.resource import allocate_with_bracket

    m = jnp.full((6,), 7, jnp.int32)
    # a bandwidth-starved scenario whose clearing price sits far up the
    # grid (λ > 100: beyond the pre-expansion seed bracket)
    starved, hi_starved = allocate_with_bracket(fleet, m, 2000.0, 0.02, 36.0)
    assert float(starved.lam) > 100.0
    cold, hi_cold = allocate_with_bracket(fleet, m, 0.2, 0.02, 10e6)
    assert float(hi_starved) > float(hi_cold)

    def assert_identical(a, b):
        for la, lb in zip(jax.tree_util.tree_leaves(a),
                          jax.tree_util.tree_leaves(b)):
            np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))

    # over-wide prior (starved bracket) on the easy scenario: contracts
    # back to the cold top, bit-identical allocation
    warm, hi_warm = allocate_with_bracket(fleet, m, 0.2, 0.02, 10e6,
                                          prior_log_hi=hi_starved)
    assert float(hi_warm) == float(hi_cold)
    assert_identical(warm, cold)
    # under-wide prior (easy bracket) on the starved scenario: re-expands
    # to the starved top, bit-identical allocation
    warm2, hi_warm2 = allocate_with_bracket(fleet, m, 2000.0, 0.02, 36.0,
                                            prior_log_hi=hi_cold)
    assert float(hi_warm2) == float(hi_starved)
    assert_identical(warm2, starved)
