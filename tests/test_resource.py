"""Resource-allocation subproblem: dual solver vs paper-faithful IPM."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.paper_tables import alexnet_fleet, resnet152_fleet
from repro.core import allocate, allocate_ipm
from repro.core.resource import deadline_budget, select_point
from repro.core import channel, energy


@pytest.fixture(scope="module")
def fleet():
    return alexnet_fleet(jax.random.PRNGKey(0), 6)


def test_bandwidth_budget_respected(fleet):
    m = jnp.full((6,), 7, jnp.int32)
    a = allocate(fleet, m, 0.2, 0.02, 10e6)
    assert float(jnp.sum(a.b)) <= 10e6 * (1 + 1e-9)
    assert bool(jnp.all(a.b > 0))
    assert bool(jnp.all((a.f >= fleet.platform.f_min - 1) & (a.f <= fleet.platform.f_max + 1)))


def test_deadline_met_in_expectation_with_margin(fleet):
    m = jnp.full((6,), 7, jnp.int32)
    a = allocate(fleet, m, 0.2, 0.02, 10e6)
    sel = select_point(fleet, m)
    t = (
        energy.mean_local_time(sel.w_flops, sel.g_eff, a.f)
        + channel.offload_time(sel.d_bits, a.b, fleet.link.p_tx, fleet.link.gain)
    )
    budget = deadline_budget(sel, jnp.full((6,), 0.2), jnp.full((6,), 0.02))
    assert bool(jnp.all(t <= budget + 1e-9))


def test_dual_matches_interior_point(fleet):
    """Strong duality: the dual-decomposition optimum equals the paper's
    joint IPM optimum (within solver tolerance)."""
    m = jnp.full((6,), 7, jnp.int32)
    a = allocate(fleet, m, 0.2, 0.02, 10e6)
    b = allocate_ipm(fleet, m, jnp.full((6,), 0.2), jnp.full((6,), 0.02), 10e6)
    ea, eb = float(jnp.sum(a.energy)), float(jnp.sum(b.energy))
    assert abs(ea - eb) / max(ea, 1e-12) < 5e-3, (ea, eb)
    # IPM can only be >= (dual gives the true optimum; IPM feasible)
    assert eb >= ea - 1e-6


def test_energy_monotone_in_deadline(fleet):
    m = jnp.full((6,), 7, jnp.int32)
    es = []
    for d in (0.16, 0.2, 0.26):
        a = allocate(fleet, m, d, 0.02, 10e6)
        es.append(float(jnp.sum(a.energy)))
    assert es[0] >= es[1] >= es[2]


def test_infeasible_point_flagged():
    fleet = resnet152_fleet(jax.random.PRNGKey(1), 4)
    m = jnp.full((4,), 9, jnp.int32)  # full local
    a = allocate(fleet, m, 0.001, 0.02, 30e6)  # 1 ms deadline: impossible
    assert not bool(jnp.any(a.feasible))
