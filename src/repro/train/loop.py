"""Training loop: jit'd train_step + host loop with checkpointing."""
from __future__ import annotations

import time
from typing import Any, Dict, Optional, Tuple

import jax

from repro.configs.base import ModelConfig
from repro.data.pipeline import DataConfig, SyntheticTokens, make_batch
from repro.models import transformer as T
from repro.train import checkpoint
from repro.train.optimizer import AdamWConfig, AdamWState, apply_updates, init_state


def make_train_step(cfg: ModelConfig, opt: AdamWConfig, remat: bool = False,
                    donate: bool = True):
    """Returns jit'd (params, opt_state, batch) → (params, opt_state, metrics)."""

    def train_step(params, opt_state: AdamWState, batch: Dict):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: T.loss_fn(p, cfg, batch, remat=remat), has_aux=True
        )(params)
        params, opt_state, opt_metrics = apply_updates(opt, params, grads, opt_state)
        metrics = dict(metrics, loss=loss, **opt_metrics)
        return params, opt_state, metrics

    kw = dict(donate_argnums=(0, 1)) if donate else {}
    return jax.jit(train_step, **kw)


def train(
    cfg: ModelConfig,
    opt: AdamWConfig,
    num_steps: int,
    *,
    global_batch: int = 8,
    seq_len: int = 128,
    seed: int = 0,
    ckpt_dir: Optional[str] = None,
    ckpt_every: int = 0,
    log_every: int = 10,
    remat: bool = False,
    log_fn=print,
) -> Tuple[Any, AdamWState, Dict]:
    """End-to-end host training loop on the synthetic pipeline."""
    key = jax.random.PRNGKey(seed)
    params = T.init_params(cfg, key)
    opt_state = init_state(opt, params)
    step_fn = make_train_step(cfg, opt, remat=remat)
    data = SyntheticTokens(DataConfig(cfg.vocab_size, seq_len, global_batch, seed))

    history = {"loss": [], "step_time": []}
    t_last = time.perf_counter()
    for step in range(num_steps):
        batch = make_batch(cfg, data, step)
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if log_every and (step % log_every == 0 or step == num_steps - 1):
            loss = float(metrics["loss"])
            dt = time.perf_counter() - t_last
            t_last = time.perf_counter()
            history["loss"].append((step, loss))
            history["step_time"].append(dt / max(log_every, 1))
            log_fn(f"step {step:5d} loss {loss:.4f} lr {float(metrics['lr']):.2e} "
                   f"gnorm {float(metrics['grad_norm']):.2f}")
        if ckpt_dir and ckpt_every and step and step % ckpt_every == 0:
            checkpoint.save(ckpt_dir, {"params": params}, step=step)
    if ckpt_dir:
        checkpoint.save(ckpt_dir, {"params": params}, step=num_steps)
    return params, opt_state, history
