"""Pytree checkpointing: msgpack tree structure + raw npz tensor payload."""
from __future__ import annotations

import os
from typing import Any, Tuple

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _flatten_with_paths(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return {jax.tree_util.keystr(path): np.asarray(leaf) for path, leaf in flat}


def save(path: str, tree: Any, step: int = 0) -> None:
    os.makedirs(path, exist_ok=True)
    leaves = _flatten_with_paths(tree)
    np.savez(os.path.join(path, "tensors.npz"), **leaves)
    treedef = jax.tree_util.tree_structure(tree)
    meta = {"step": step, "treedef": str(treedef), "keys": list(leaves)}
    with open(os.path.join(path, "meta.msgpack"), "wb") as f:
        f.write(msgpack.packb(meta))


def load(path: str, like: Any) -> Tuple[Any, int]:
    """Restore into the structure of ``like`` (keys must match)."""
    with open(os.path.join(path, "meta.msgpack"), "rb") as f:
        meta = msgpack.unpackb(f.read())
    data = np.load(os.path.join(path, "tensors.npz"))
    flat_like = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for pathk, leaf in flat_like[0]:
        key = jax.tree_util.keystr(pathk)
        arr = jnp.asarray(data[key]).astype(leaf.dtype)
        assert arr.shape == leaf.shape, (key, arr.shape, leaf.shape)
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(flat_like[1], leaves), int(meta["step"])
