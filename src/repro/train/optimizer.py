"""AdamW + schedules, written against plain pytrees (no optax offline)."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    grad_clip: float = 1.0
    moment_dtype: Any = jnp.float32  # bf16 to halve optimizer memory


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: Any
    nu: Any


def lr_at(cfg: AdamWConfig, step):
    """Linear warmup → cosine decay to 10%."""
    warm = cfg.lr * (step + 1) / max(cfg.warmup_steps, 1)
    frac = jnp.clip((step - cfg.warmup_steps) / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.lr * (0.1 + 0.9 * 0.5 * (1.0 + jnp.cos(jnp.pi * frac)))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def init_state(cfg: AdamWConfig, params) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, cfg.moment_dtype)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree.map(zeros, params),
        nu=jax.tree.map(zeros, params),
    )


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)))


def apply_updates(cfg: AdamWConfig, params, grads, state: AdamWState):
    step = state.step + 1
    lr = lr_at(cfg, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu32 = mu.astype(jnp.float32) * cfg.b1 + g * (1 - cfg.b1)
        nu32 = nu.astype(jnp.float32) * cfg.b2 + g * g * (1 - cfg.b2)
        mu_hat = mu32 / (1 - cfg.b1**step)
        nu_hat = nu32 / (1 - cfg.b2**step)
        delta = mu_hat / (jnp.sqrt(nu_hat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        new_p = p.astype(jnp.float32) - lr * delta
        return new_p.astype(p.dtype), mu32.astype(cfg.moment_dtype), nu32.astype(cfg.moment_dtype)

    out = jax.tree.map(upd, params, grads, state.mu, state.nu)
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    new_mu = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    new_nu = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
    return new_params, AdamWState(step=step, mu=new_mu, nu=new_nu), {"lr": lr, "grad_norm": gnorm}
