"""Flash attention (online softmax) as a Pallas TPU kernel.

TPU-native design decisions (DESIGN.md §4):
- BlockSpec tiling: queries in (BLK_Q, Dh) VMEM tiles, K/V streamed in
  (BLK_K, Dh) tiles along the innermost grid axis; running max/denominator
  and the output accumulator live in VMEM scratch across the K sweep.
- Tile sizes default to 128 — MXU-aligned (128×128 systolic array) and
  a multiple of the (8,128) vreg tile for f32.
- GQA folds query-head groups onto KV heads via the K/V index_map, so no
  repeated KV materialization in HBM.
- Causal + sliding-window masking is applied per tile; fully-masked tiles
  write nothing (the mask zeroes their contribution).

Validated in interpret mode against ``ref.flash_attention_ref`` over
shape/dtype sweeps (tests/test_kernels.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                 scale: float, causal: bool, window: int, blk_q: int, blk_k: int,
                 num_k_blocks: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)  # (blk_q, dh)
    k = k_ref[0, 0].astype(jnp.float32)  # (blk_k, dh)
    v = v_ref[0, 0].astype(jnp.float32)

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # (blk_q, blk_k)

    rows = qi * blk_q + jax.lax.broadcasted_iota(jnp.int32, (blk_q, blk_k), 0)
    cols = ki * blk_k + jax.lax.broadcasted_iota(jnp.int32, (blk_q, blk_k), 1)
    mask = jnp.ones_like(s, dtype=jnp.bool_)
    if causal:
        mask &= cols <= rows
    if window > 0:
        mask &= cols > rows - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jnp.dot(
        p, v, preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new

    @pl.when(ki == num_k_blocks - 1)
    def _flush():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "blk_q", "blk_k", "interpret"),
)
def flash_attention(
    q, k, v, *, causal: bool = True, window: int = 0,
    blk_q: int = 128, blk_k: int = 128, interpret: bool = True,
):
    """q: (B, Hq, S, Dh), k/v: (B, Hkv, S, Dh) → (B, Hq, S, Dh).

    S must be a multiple of the block sizes (pad upstream in ops.py).
    ``interpret=True`` executes on CPU for validation; on TPU pass False.
    """
    to32 = lambda t: t.astype(jnp.float32) if t.dtype == jnp.float64 else t
    q, k, v = map(to32, (q, k, v))
    b, hq, s, dh = q.shape
    hkv = k.shape[1]
    assert hq % hkv == 0, (hq, hkv)
    group = hq // hkv
    assert s % blk_q == 0 and s % blk_k == 0, (s, blk_q, blk_k)
    nq, nk = s // blk_q, s // blk_k
    scale = dh**-0.5

    kernel = functools.partial(
        _attn_kernel, scale=scale, causal=causal, window=window,
        blk_q=blk_q, blk_k=blk_k, num_k_blocks=nk,
    )
    return pl.pallas_call(
        kernel,
        grid=(b, hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, blk_q, dh), lambda b_, h, qi, ki: (b_, h, qi, 0)),
            pl.BlockSpec((1, 1, blk_k, dh), lambda b_, h, qi, ki: (b_, h // group, ki, 0)),
            pl.BlockSpec((1, 1, blk_k, dh), lambda b_, h, qi, ki: (b_, h // group, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, blk_q, dh), lambda b_, h, qi, ki: (b_, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((blk_q, dh), jnp.float32),
            pltpu.VMEM((blk_q,), jnp.float32),
            pltpu.VMEM((blk_q,), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
