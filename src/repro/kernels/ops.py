"""Jit'd public wrappers over the Pallas kernels.

These handle layout adaptation (the model uses (B, S, H, Dh); the kernels
use (B, H, S, Dh)), sequence padding to block multiples, and the
CPU-vs-TPU dispatch (``interpret=True`` executes the kernel body on CPU
for validation; on a real TPU pass ``interpret=False``).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention
from repro.kernels.rmsnorm import rmsnorm_residual
from repro.kernels.ssd_scan import ssd_scan

__all__ = ["flash_attention_bshd", "ssd_scan", "rmsnorm_residual", "flash_attention"]


@partial(jax.jit, static_argnames=("causal", "window", "interpret"))
def flash_attention_bshd(q, k, v, *, causal: bool = True, window: int = 0,
                         interpret: bool = True):
    """Model-layout wrapper: q (B,S,Hq,Dh), k/v (B,S,Hkv,Dh) → (B,S,Hq·Dh).

    Pads S to a 128 multiple (padded keys are masked out by causality for
    suffix padding; for non-causal use explicit masking upstream).
    """
    b, s, hq, dh = q.shape
    blk = min(128, max(16, 1 << (s - 1).bit_length() if s < 128 else 128))
    pad = (-s) % blk
    qt = jnp.moveaxis(q, 1, 2)
    kt = jnp.moveaxis(k, 1, 2)
    vt = jnp.moveaxis(v, 1, 2)
    if pad:
        qt = jnp.pad(qt, ((0, 0), (0, 0), (0, pad), (0, 0)))
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pad), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pad), (0, 0)))
    out = flash_attention(qt, kt, vt, causal=causal, window=window,
                          blk_q=blk, blk_k=blk, interpret=interpret)
    out = out[:, :, :s]
    return jnp.moveaxis(out, 1, 2).reshape(b, s, hq * dh)
