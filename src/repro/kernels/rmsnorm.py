"""Fused residual-add + RMSNorm Pallas kernel.

The pre-norm transformer applies (residual add → RMSNorm) twice per layer;
fusing them keeps the activation in VMEM and halves HBM round-trips for a
purely memory-bound op. Rows are tiled (BLK_ROWS, D) — D stays whole so
the reduction is a single in-register pass; BLK_ROWS×D is sized well under
VMEM (default 256×8192 f32 = 8 MB).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, res_ref, scale_ref, y_ref, new_res_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    r = res_ref[...].astype(jnp.float32)
    h = x + r
    var = jnp.mean(h * h, axis=-1, keepdims=True)
    y = h * jax.lax.rsqrt(var + eps) * (1.0 + scale_ref[...].astype(jnp.float32))
    y_ref[...] = y.astype(y_ref.dtype)
    new_res_ref[...] = h.astype(new_res_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "blk_rows", "interpret"))
def rmsnorm_residual(x, res, scale, *, eps: float = 1e-5, blk_rows: int = 256,
                     interpret: bool = True):
    """x/res: (..., D) → (normed, new_residual). Rows padded to blk_rows."""
    orig_shape = x.shape
    d = x.shape[-1]
    xt = x.reshape(-1, d)
    rt = res.reshape(-1, d)
    rows = xt.shape[0]
    pad = (-rows) % blk_rows
    if pad:
        xt = jnp.pad(xt, ((0, pad), (0, 0)))
        rt = jnp.pad(rt, ((0, pad), (0, 0)))
    n = xt.shape[0] // blk_rows

    y, new_res = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(n,),
        in_specs=[
            pl.BlockSpec((blk_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((blk_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((blk_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((blk_rows, d), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(xt.shape, x.dtype),
            jax.ShapeDtypeStruct(xt.shape, x.dtype),
        ],
        interpret=interpret,
    )(xt, rt, scale)
    if pad:
        y, new_res = y[:rows], new_res[:rows]
    return y.reshape(orig_shape), new_res.reshape(orig_shape)
