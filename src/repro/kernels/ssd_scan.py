"""Mamba-2 SSD chunked scan as a Pallas TPU kernel.

TPU-native design (DESIGN.md §4): the sequential recurrence is recast in
its state-space-dual form — per chunk, the output is an (cs × cs) masked
"attention" matmul (MXU work) plus a rank-N state contribution; the
(P × N) inter-chunk state is carried in VMEM scratch across the chunk
grid axis (TPU grids iterate sequentially, so scratch acts as the scan
carry). All chunk matmuls are f32 on the MXU.

Grid: (B, H, num_chunks) — chunks innermost so the carry is correct.
Validated in interpret mode vs ``ref.ssd_scan_ref`` (= models.ssm oracle).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, state_ref, *, chunk: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0, 0, 0].astype(jnp.float32)  # (cs, P)
    dt = dt_ref[0, 0, 0].astype(jnp.float32)  # (cs,)
    a = a_ref[0].astype(jnp.float32)  # scalar decay rate (negative)
    b = b_ref[0, 0, 0].astype(jnp.float32)  # (cs, N)
    c = c_ref[0, 0, 0].astype(jnp.float32)  # (cs, N)

    da = dt * a  # (cs,)
    cum = jnp.cumsum(da)  # (cs,)

    # Intra-chunk dual form: L[i,j] = exp(cum_i - cum_j) for j ≤ i.
    li = cum[:, None] - cum[None, :]
    tri = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0) >= jax.lax.broadcasted_iota(
        jnp.int32, (chunk, chunk), 1
    )
    decay = jnp.where(tri, jnp.exp(li), 0.0)
    cb = jnp.dot(c, b.T, preferred_element_type=jnp.float32)  # (cs, cs)
    m = cb * decay * dt[None, :]
    y = jnp.dot(m, x, preferred_element_type=jnp.float32)  # (cs, P)

    # Inter-chunk: contribution of the carried state.
    state = state_ref[...]  # (P, N)
    y += jnp.exp(cum)[:, None] * jnp.dot(c, state.T, preferred_element_type=jnp.float32)

    # Update carry: state ← state·exp(Σda) + Σ_j exp(cum_end − cum_j)·dt_j·x_j ⊗ B_j
    w = (jnp.exp(cum[-1] - cum) * dt)[:, None] * x  # (cs, P)
    state_new = state * jnp.exp(cum[-1]) + jnp.dot(w.T, b, preferred_element_type=jnp.float32)
    state_ref[...] = state_new

    y_ref[0, 0, 0] = y.astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, a, b_mat, c_mat, *, chunk: int = 128, interpret: bool = True):
    """x: (B,S,H,P); dt: (B,S,H); a: (H,); b/c: (B,S,N) → y: (B,S,H,P).

    Matches ``repro.models.ssm.ssd_reference`` (single B/C group).
    """
    bsz, s, h, p = x.shape
    n = b_mat.shape[-1]
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    # kernel computes in f32; f64 inputs (x64 mode) are downcast here
    to32 = lambda t: t.astype(jnp.float32) if t.dtype == jnp.float64 else t
    x, dt, a, b_mat, c_mat = map(to32, (x, dt, a, b_mat, c_mat))

    # layout: (B, H, nc, cs, ·) for per-(batch, head) chunk streaming
    xh = jnp.moveaxis(x, 2, 1).reshape(bsz, h, nc, chunk, p)
    dth = jnp.moveaxis(dt, 2, 1).reshape(bsz, h, nc, chunk)
    bh = b_mat.reshape(bsz, 1, nc, chunk, n)
    ch = c_mat.reshape(bsz, 1, nc, chunk, n)

    y = pl.pallas_call(
        functools.partial(_ssd_kernel, chunk=chunk),
        grid=(bsz, h, nc),
        in_specs=[
            pl.BlockSpec((1, 1, 1, chunk, p), lambda b_, h_, c_: (b_, h_, c_, 0, 0)),
            pl.BlockSpec((1, 1, 1, chunk), lambda b_, h_, c_: (b_, h_, c_, 0)),
            pl.BlockSpec((1,), lambda b_, h_, c_: (h_,)),
            pl.BlockSpec((1, 1, 1, chunk, n), lambda b_, h_, c_: (b_, 0, c_, 0, 0)),
            pl.BlockSpec((1, 1, 1, chunk, n), lambda b_, h_, c_: (b_, 0, c_, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, chunk, p), lambda b_, h_, c_: (b_, h_, c_, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, h, nc, chunk, p), x.dtype),
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(xh, dth, a, bh, ch)
    return jnp.moveaxis(y.reshape(bsz, h, s, p), 1, 2)
