"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q, k, v, *, causal: bool = True, window: int = 0, scale=None):
    """q: (B, Hq, S, Dh); k/v: (B, Hkv, S, Dh). GQA by head folding."""
    b, hq, s, dh = q.shape
    hkv = k.shape[1]
    group = hq // hkv
    scale = dh**-0.5 if scale is None else scale
    kk = jnp.repeat(k, group, axis=1)
    vv = jnp.repeat(v, group, axis=1)
    logits = jnp.einsum("bhsd,bhtd->bhst", q, kk).astype(jnp.float32) * scale
    i = jnp.arange(s)[:, None]
    j = jnp.arange(s)[None, :]
    mask = jnp.ones((s, s), bool)
    if causal:
        mask &= j <= i
    if window > 0:
        mask &= j > i - window
    logits = jnp.where(mask, logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhst,bhtd->bhsd", w.astype(vv.dtype), vv)


def ssd_scan_ref(x, dt, a, b_mat, c_mat, chunk: int):
    """Delegates to the model-layer chunked SSD oracle (same math)."""
    from repro.models.ssm import ssd_reference

    return ssd_reference(x, dt, a, b_mat, c_mat, chunk)[0]


def rmsnorm_residual_ref(x, res, scale, eps: float = 1e-5):
    """Fused y = rmsnorm(x + res) and new residual (x + res).

    The residual add happens in f32 (matching the kernel, which keeps the
    tile in f32 VMEM) — adding in bf16 first loses a rounding step.
    """
    h = x.astype(jnp.float32) + res.astype(jnp.float32)
    var = jnp.mean(h * h, axis=-1, keepdims=True)
    y = h * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return y.astype(x.dtype), h.astype(x.dtype)
