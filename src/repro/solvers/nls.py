"""Levenberg–Marquardt nonlinear least squares (pure JAX).

Used to fit the paper's mean-inference-time model  t̄(f) = w / (g · f)
(eq. (10)) — and any other small regression — from measured data, exactly
as Section IV-A fits Fig. 6 with "the nonlinear least squares method".
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


class LMResult(NamedTuple):
    params: jnp.ndarray
    residual_norm_sq: jnp.ndarray  # squared 2-norm of residuals (paper's metric)
    iterations: jnp.ndarray


def levenberg_marquardt(
    residual_fn: Callable[[jnp.ndarray], jnp.ndarray],
    x0: jnp.ndarray,
    iters: int = 60,
    lam0: float = 1e-3,
    lam_up: float = 10.0,
    lam_down: float = 0.5,
) -> LMResult:
    """Minimize ``0.5 * ||residual_fn(x)||^2`` with LM damping.

    Fixed-iteration trust-region-flavoured LM: a step is accepted when it
    decreases the residual norm, otherwise the damping is increased and the
    step rejected. Jit- and vmap-safe.
    """
    x0 = jnp.asarray(x0, dtype=jnp.float64)

    def loss(x):
        r = residual_fn(x)
        return 0.5 * jnp.sum(r * r)

    def body(_, state):
        x, lam, f_x = state
        r = residual_fn(x)
        J = jax.jacfwd(residual_fn)(x)
        g = J.T @ r
        H = J.T @ J + lam * jnp.eye(x.shape[0], dtype=x.dtype)
        step = jnp.linalg.solve(H, -g)
        x_new = x + step
        f_new = loss(x_new)
        accept = f_new < f_x
        x = jnp.where(accept, x_new, x)
        f_x = jnp.where(accept, f_new, f_x)
        lam = jnp.where(accept, lam * lam_down, lam * lam_up)
        lam = jnp.clip(lam, 1e-12, 1e12)
        return x, lam, f_x

    x, _, f_x = jax.lax.fori_loop(
        0, iters, body, (x0, jnp.asarray(lam0, jnp.float64), loss(x0))
    )
    return LMResult(params=x, residual_norm_sq=2.0 * f_x, iterations=jnp.asarray(iters))


def fit_inverse_frequency(freqs: jnp.ndarray, times: jnp.ndarray) -> LMResult:
    """Fit the paper's model  t̄ = a / f  (a = w/g) to (frequency, time) data.

    Returns a 1-parameter LM fit. ``w`` (GFLOPs) is known from the model's
    cost table, so ``g = w / a``.
    """
    freqs = jnp.asarray(freqs, jnp.float64)
    times = jnp.asarray(times, jnp.float64)

    def residual(params):
        (a,) = params
        return a / freqs - times

    # init from the median of t*f (exact if the model holds).
    a0 = jnp.median(times * freqs)
    return levenberg_marquardt(residual, jnp.array([a0]))
