"""Scalar root-finding and 1-D minimization, vmap-friendly.

Both routines use fixed iteration counts (``lax.fori_loop``) so they can be
jitted, vmapped and nested inside other solvers without dynamic shapes.
"""
from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp

_INV_PHI = 0.6180339887498949  # 1/phi
_INV_PHI2 = 0.3819660112501051  # 1/phi^2


def bisect(fn: Callable, lo, hi, iters: int = 80, endpoint: str = "mid"):
    """Find a root of ``fn`` on [lo, hi] by bisection.

    Assumes ``fn(lo)`` and ``fn(hi)`` bracket a root (sign change). If they
    do not, the result converges to one of the endpoints, which is the
    correct behaviour for the monotone complementarity searches we use it
    for (e.g. a Lagrange-multiplier price that is 0 at an inactive
    constraint).

    ``endpoint`` selects what is returned from the final bracket:
    ``"mid"`` (default) the midpoint; ``"hi"`` the upper end — which, for
    a *decreasing step function* such as a discrete market-clearing
    excess, is guaranteed to sit on the ``fn ≤ 0`` side whenever the
    initial ``hi`` does (the midpoint can land on either side of the
    jump).
    """
    if endpoint not in ("mid", "hi"):
        raise ValueError(f"endpoint must be 'mid' or 'hi', got {endpoint!r}")
    lo = jnp.asarray(lo, dtype=jnp.float64)
    hi = jnp.asarray(hi, dtype=jnp.float64)
    f_lo = fn(lo)

    def body(_, state):
        lo, hi, f_lo = state
        mid = 0.5 * (lo + hi)
        f_mid = fn(mid)
        go_right = jnp.sign(f_mid) == jnp.sign(f_lo)
        new_lo = jnp.where(go_right, mid, lo)
        new_f_lo = jnp.where(go_right, f_mid, f_lo)
        new_hi = jnp.where(go_right, hi, mid)
        return new_lo, new_hi, new_f_lo

    lo, hi, _ = jax.lax.fori_loop(0, iters, body, (lo, hi, f_lo))
    return hi if endpoint == "hi" else 0.5 * (lo + hi)


def golden_section(fn: Callable, lo, hi, iters: int = 72):
    """Minimize a (quasi-)convex scalar ``fn`` on [lo, hi].

    Returns the argmin. 72 iterations shrink the bracket by
    ~phi^-72 ≈ 1e-15, i.e. to float64 resolution for O(1) intervals.
    """
    lo = jnp.asarray(lo, dtype=jnp.float64)
    hi = jnp.asarray(hi, dtype=jnp.float64)
    a, b = lo, hi
    h = b - a
    c = a + _INV_PHI2 * h
    d = a + _INV_PHI * h
    fc, fd = fn(c), fn(d)

    def body(_, state):
        a, b, c, d, fc, fd = state
        shrink_right = fc < fd
        new_b = jnp.where(shrink_right, d, b)
        new_a = jnp.where(shrink_right, a, c)
        h = new_b - new_a
        new_c = new_a + _INV_PHI2 * h
        new_d = new_a + _INV_PHI * h
        # Only one of (c, d) needs re-evaluation per iteration in the
        # classic scheme; recomputing both keeps the state static-shaped
        # and fn is cheap in our uses (closed-form energy expressions).
        return new_a, new_b, new_c, new_d, fn(new_c), fn(new_d)

    a, b, c, d, fc, fd = jax.lax.fori_loop(0, iters, body, (a, b, c, d, fc, fd))
    return 0.5 * (a + b)


@partial(jax.jit, static_argnames=("fn", "grid"))
def minimize_grid_then_golden(fn: Callable, lo, hi, grid: int = 64):
    """Global-ish 1-D minimization: coarse grid to localize, then golden.

    Useful when ``fn`` is only piecewise-convex (e.g. clipped frequency
    requirement inside an energy expression).
    """
    lo = jnp.asarray(lo, dtype=jnp.float64)
    hi = jnp.asarray(hi, dtype=jnp.float64)
    xs = jnp.linspace(lo, hi, grid)
    vals = jax.vmap(fn)(xs)
    i = jnp.argmin(vals)
    cell = (hi - lo) / (grid - 1)
    a = jnp.clip(xs[i] - cell, lo, hi)
    b = jnp.clip(xs[i] + cell, lo, hi)
    return golden_section(fn, a, b)
