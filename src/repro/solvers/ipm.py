"""Equality-constrained log-barrier interior-point method (pure JAX).

This is the workhorse behind both paper subproblems:

* the resource-allocation problem (23) — convex, solved to optimality
  (the paper prescribes "an interior point (IPT) algorithm"), and
* the inner convex approximations (36) of the PCCP loop (Algorithm 1).

Two solve paths share the barrier/Newton/line-search skeleton:

- ``barrier_solve`` on a :class:`BarrierSpec` — the **dense autodiff**
  path: ``jax.hessian`` of the barrier plus a dense Cholesky KKT
  elimination per Newton step. Fully generic (any smooth convex
  ``inequalities`` callable); this is what ``resource.allocate_ipm``
  needs, whose deadline rows are non-affine in the bandwidth (t_off =
  d/R(b) with a log-rate). Kept as the A/B reference for the PCCP.
- ``structured_barrier_solve`` on a :class:`StructuredSpec` — the
  **structure-exploiting** path for programs of the exact family the
  PCCP inner problem (36) belongs to: affine constraints plus a few
  diagonal-quadratic rows, ``fi(z) = C z + c0 + q(z)``. Gradient and
  Hessian are closed-form (no autodiff jaxpr blow-up at compile time),
  the Hessian is solved in O(n) by pair elimination + Sherman–Morrison–
  Woodbury on its ``D + U S Uᵀ`` decomposition (no O(n³) Cholesky), and
  the backtracking line search updates all candidates analytically from
  one precomputed ``C dz`` matvec (DESIGN.md §solver).

Design notes
------------
- Fixed iteration *bounds* everywhere (``lax.fori_loop`` /
  ``lax.while_loop`` with a trip cap) so the solvers jit once and vmap
  across devices/problems. ``gate_tol`` enables a Newton-decrement early
  exit: λ²/2 below a tolerance relative to the current barrier value
  means the remaining steps cannot move the iterate, so the stage stops
  (under ``vmap`` the batched while_loop keeps stepping until every lane
  is done — the exit saves wall-clock only when the whole batch
  converges, which is the common case late in the barrier ramp).
- Newton steps solve the KKT system  [H Aᵀ; A 0] [dz; ν] = [-∇φ; 0]
  with **scale-aware** Tikhonov regularization on H (relative to
  ``max(diag H)`` — the PCCP's ρ-penalty ramp scales the barrier Hessian
  over ~6 orders of magnitude, where any fixed absolute jitter is either
  inert or dominant); equality feasibility (A z = b) is maintained
  exactly from a feasible start.
- Backtracking line search enforces *strict* inequality feasibility before
  evaluating the barrier (log of a non-positive argument is NaN and NaN
  comparisons would silently accept bad steps — we check explicitly).
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

#: Backtracking candidates s = 2⁻ᵏ, k < _LS_CANDIDATES. The smallest step
#: tried is 2⁻²³ ≈ 1e-7 — steps below that make no numerical progress on
#: the float64 barrier, and each extra candidate costs a batched function
#: evaluation in the planner's hot loop.
_LS_CANDIDATES = 24

#: Scale-aware Tikhonov: H + reg·I with reg = _REG_REL · max(diag H).
#: The barrier Hessian's scale ramps with the barrier parameter t and the
#: PCCP penalty ρ (diag entries span ~1 → 1e12 across a solve); a
#: *relative* jitter keeps the conditioning of the regularized system
#: constant across the ramp, where the seed's fixed ``reg = 1e-10`` was
#: dominant early and inert late.
_REG_REL = 1e-12

#: Newton-decrement gate: a stage stops once λ²/2 ≤ gate · (1 + |φ|).
#: λ²/2 bounds the remaining decrease of the self-concordant barrier, so
#: at 1e-13 relative the remaining steps are numerical noise.
_GATE_TOL = 1e-13


class BarrierSpec(NamedTuple):
    """A smooth convex program: min f0(z) s.t. fi(z) <= 0, A z = b."""

    objective: Callable[[jnp.ndarray], jnp.ndarray]
    inequalities: Callable[[jnp.ndarray], jnp.ndarray]
    eq_matrix: Optional[jnp.ndarray] = None  # (p, n)
    eq_rhs: Optional[jnp.ndarray] = None  # (p,)


class StructuredSpec(NamedTuple):
    """A linear program with affine + diagonal-quadratic inequalities:

        min c_obj·z   s.t.   fi(z) = C z + c0 + q(z) ≤ 0,   a·z = a_rhs,

    where ``q`` adds ``z·(quad_diag[k] ⊙ z)`` (a *diagonal* PSD quadratic)
    to row ``quad_rows[k]`` — the DC rows (36c)/(36d) of the PCCP inner
    problem are exactly this shape.

    The last six fields are **static structure metadata** (concrete numpy
    index arrays, fixed by the constraint layout — never traced values).
    They classify the rows of the constraint Jacobian ``G`` (= ``C`` plus
    the quadratic gradient corrections) for the closed-form Hessian

        H = Σ_i G_i G_iᵀ / u_i² + Σ_k (2/u_k) diag(quad_diag[k]),  u = −fi:

    - ``diag_rows``/``diag_cols`` — rows with a single nonzero (box and
      positivity rows): pure diagonal contributions.
    - ``pair_rows``/``pair_x``/``pair_elim`` — rows with exactly two
      nonzeros, at ``(pair_x[i], pair_elim[i])``: 2×2 blocks. Each
      ``pair_elim`` column may appear ONLY in its pair row and in diag
      rows (and must be absent from ``eq_vec``), so it is eliminated
      analytically by one Schur step.
    - ``dense_rows`` — everything else: the low-rank ``U S Uᵀ`` part,
      solved by Sherman–Morrison–Woodbury with a
      ``len(dense_rows)²``-sized inner system.

    The quadratic rows' Hessian corrections are diagonal by construction,
    but their Jacobian rows (``C`` row + ``2 q ⊙ z``) are not — every
    ``quad_rows`` entry must therefore also appear in ``dense_rows``
    (validated at trace time).
    """

    c_obj: jnp.ndarray  # (n,)
    C: jnp.ndarray  # (m, n)
    c0: jnp.ndarray  # (m,)
    quad_diag: jnp.ndarray  # (k_q, n) diagonal PSD coefficients
    eq_vec: Optional[jnp.ndarray] = None  # (n,) single equality row
    eq_rhs: Optional[jnp.ndarray] = None  # scalar
    # -- static structure metadata (concrete numpy, not traced) --
    quad_rows: np.ndarray = np.zeros((0,), np.int64)  # (k_q,)
    diag_rows: np.ndarray = np.zeros((0,), np.int64)
    diag_cols: np.ndarray = np.zeros((0,), np.int64)
    pair_rows: np.ndarray = np.zeros((0,), np.int64)
    pair_x: np.ndarray = np.zeros((0,), np.int64)
    pair_elim: np.ndarray = np.zeros((0,), np.int64)
    dense_rows: np.ndarray = np.zeros((0,), np.int64)


class BarrierResult(NamedTuple):
    z: jnp.ndarray
    objective: jnp.ndarray
    max_violation: jnp.ndarray  # max fi(z); <= 0 means feasible
    duality_gap_bound: jnp.ndarray  # m / t at the final barrier stage
    #: fail-soft flag (DESIGN.md §robustness): False when the returned
    #: iterate or objective went non-finite — the line searches reject
    #: NaN/∞ *candidates* (a NaN Armijo comparison is False, so the step
    #: is refused and the stage stops at the incumbent), but a poisoned
    #: *input* spec can still surface here. Callers treat ok=False as
    #: "discard this solve", not "crash".
    ok: jnp.ndarray = jnp.bool_(True)  # analyze: ok(TRC005): tiny scalar NamedTuple default; concrete bool stamp is the contract


# ---------------------------------------------------------------------------
# Structured-path building blocks (closed-form, no autodiff)
# ---------------------------------------------------------------------------


def structured_inequalities(spec: StructuredSpec, z: jnp.ndarray) -> jnp.ndarray:
    """fi(z) = C z + c0 + q(z) — one matvec plus the quadratic rows."""
    fi = spec.C @ z + spec.c0
    if spec.quad_rows.size:
        qz = jnp.sum(spec.quad_diag * (z * z)[None, :], axis=-1)
        fi = fi.at[spec.quad_rows].add(qz)
    return fi


def structured_objective(spec: StructuredSpec, z: jnp.ndarray) -> jnp.ndarray:
    return jnp.dot(spec.c_obj, z)


def structured_barrier(spec: StructuredSpec, z: jnp.ndarray, t) -> jnp.ndarray:
    """φ(z) = t·c_obj·z − Σ log(−fi). Reference implementation: the
    closed-form gradient/Hessian below are property-tested against
    ``jax.grad``/``jax.hessian`` of this function."""
    fi = structured_inequalities(spec, z)
    return t * structured_objective(spec, z) - jnp.sum(jnp.log(-fi))


def _structured_parts(spec: StructuredSpec, z: jnp.ndarray, t):  # analyze: ok(TRC002): StructuredSpec index metadata is concrete numpy by construction (trace-time shapes)
    """Closed-form barrier derivatives, decomposed by row class.

    Returns ``(fi, g, d, h, U, wd)`` with the Hessian of φ as

        H = diag(d) + Σ_i h_i (e_{xᵢ} e_{eᵢ}ᵀ + e_{eᵢ} e_{xᵢ}ᵀ) + U diag(wd) Uᵀ

    where (xᵢ, eᵢ) = (pair_x[i], pair_elim[i]); ``d`` already contains the
    pair rows' own diagonal entries, so only the off-diagonal couplings
    ``h`` ride separately.
    """
    # Static invariant (checked here, at trace time, so every entry point
    # — solver, grad, Hessian — enforces it): a quadratic row's Jacobian
    # is dense-ish (C row + 2 q⊙z), so it MUST be classified dense —
    # listing it as a diag/pair row would silently drop its G_i G_iᵀ/u²
    # outer product from the Hessian.
    if not np.isin(spec.quad_rows, spec.dense_rows).all():
        raise ValueError(
            "StructuredSpec: every quad_rows entry must also be listed in "
            f"dense_rows (quad_rows={spec.quad_rows.tolist()}, "
            f"dense_rows={spec.dense_rows.tolist()})")
    fi = structured_inequalities(spec, z)
    winv = -1.0 / fi  # 1/u, u = −fi > 0 at a strictly feasible iterate
    w2 = winv * winv

    # gradient: t·c_obj + Gᵀ(1/u); quadratic rows add 2(q⊙z)/u_row
    g = t * spec.c_obj + spec.C.T @ winv
    if spec.quad_rows.size:
        g = g + jnp.sum(
            (2.0 * winv[spec.quad_rows])[:, None] * spec.quad_diag, axis=0) * z

    # diagonal: single-nonzero rows + the quadratic rows' ∇²fi terms
    d = jnp.zeros_like(z)
    if spec.diag_rows.size:
        dr, dc = spec.diag_rows, spec.diag_cols
        d = d.at[dc].add(w2[dr] * spec.C[dr, dc] ** 2)
    if spec.quad_rows.size:
        d = d + jnp.sum(
            (2.0 * winv[spec.quad_rows])[:, None] * spec.quad_diag, axis=0)

    # pair rows: diagonal entries into d, off-diagonal couplings into h
    pr, px, pe = spec.pair_rows, spec.pair_x, spec.pair_elim
    if pr.size:
        a, b = spec.C[pr, px], spec.C[pr, pe]
        wp = w2[pr]
        d = d.at[px].add(wp * a * a).at[pe].add(wp * b * b)
        h = wp * a * b
    else:
        h = jnp.zeros((0,), z.dtype)

    # dense rows: Jacobian rows (with quadratic gradient corrections) → U
    Gd = spec.C[spec.dense_rows]
    for k, row in enumerate(spec.quad_rows):
        j = np.nonzero(spec.dense_rows == row)[0]
        if j.size:  # quadratic row that is also dense (the PCCP case)
            Gd = Gd.at[int(j[0])].add(2.0 * spec.quad_diag[k] * z)
    U = Gd.T  # (n, k_d)
    wd = w2[spec.dense_rows]
    return fi, g, d, h, U, wd


def structured_grad(spec: StructuredSpec, z: jnp.ndarray, t) -> jnp.ndarray:
    """Closed-form ∇φ (property-tested against ``jax.grad``)."""
    _, g, *_ = _structured_parts(spec, z, t)
    return g


def structured_hessian(spec: StructuredSpec, z: jnp.ndarray, t) -> jnp.ndarray:
    """Densely assembled ∇²φ from the structured parts (tests only —
    the solver never materializes this matrix)."""
    _, _, d, h, U, wd = _structured_parts(spec, z, t)
    H = jnp.diag(d) + (U * wd[None, :]) @ U.T
    if spec.pair_rows.size:
        px, pe = spec.pair_x, spec.pair_elim
        H = H.at[px, pe].add(h).at[pe, px].add(h)
    return H


def woodbury_solve(d: jnp.ndarray, U: jnp.ndarray, w: jnp.ndarray,
                   r: jnp.ndarray) -> jnp.ndarray:
    """Solve ``(diag(d) + U diag(w) Uᵀ) x = r`` by Sherman–Morrison–Woodbury.

    ``d`` (n,) must be strictly positive and ``w`` (k,) positive (an SPD
    diagonal + low-rank system — the regularized structured barrier
    Hessian after pair elimination). ``r`` is ``(n,)`` or ``(n, nrhs)``.
    The inner system is k×k — O(n·k) work instead of an O(n³) Cholesky.
    """
    rhs = r[:, None] if r.ndim == 1 else r
    dinv = 1.0 / d
    y0 = dinv[:, None] * rhs
    if U.shape[1]:
        M = jnp.diag(1.0 / w) + U.T @ (dinv[:, None] * U)
        y = y0 - dinv[:, None] * (U @ jnp.linalg.solve(M, U.T @ y0))
    else:
        y = y0
    return y[:, 0] if r.ndim == 1 else y


def _structured_kkt_solve(spec: StructuredSpec, d, h, U, wd, g, reg_rel):
    """One Newton direction: solve H dz = −g on {a·dz = 0} via pair
    elimination + Woodbury, with scale-aware Tikhonov on the diagonal."""
    px, pe = spec.pair_x, spec.pair_elim
    diag_full = d + jnp.sum(U * U * wd[None, :], axis=-1)
    d = d + reg_rel * jnp.maximum(jnp.max(diag_full), 1.0)

    if pe.size:
        d_elim = d[pe]
        hdg = h / d_elim
        d_eff = d.at[px].add(-h * hdg)
    else:
        d_eff = d

    def solve(r):  # r: (n, nrhs); pair columns eliminated, then Woodbury
        if pe.size:
            r_core = r.at[px].add(-hdg[:, None] * r[pe]).at[pe].set(0.0)
        else:
            r_core = r
        y = woodbury_solve(d_eff, U, wd, r_core)
        if pe.size:
            y = y.at[pe].set((r[pe] - h[:, None] * y[px]) / d_elim[:, None])
        return y

    if spec.eq_vec is None:
        return solve(-g[:, None])[:, 0]
    sol = solve(jnp.stack([-g, spec.eq_vec], axis=1))
    v, wa = sol[:, 0], sol[:, 1]
    nu = jnp.dot(spec.eq_vec, v) / jnp.dot(spec.eq_vec, wa)
    return v - nu * wa


def _structured_newton_steps(spec: StructuredSpec, z, t, iters, reg_rel,
                             ls_iters, gate_tol):
    """Gated Newton loop on the structured barrier at parameter ``t``."""
    ss = jnp.asarray(0.5, z.dtype) ** jnp.arange(ls_iters, dtype=z.dtype)
    qr = spec.quad_rows

    def body(state):
        i, z, _ = state
        fi, g, d, h, U, wd = _structured_parts(spec, z, t)
        dz = _structured_kkt_solve(spec, d, h, U, wd, g, reg_rel)

        obj0 = jnp.dot(spec.c_obj, z)
        phi0 = t * obj0 - jnp.sum(jnp.log(-fi))
        slope = jnp.vdot(g, dz)
        # Newton decrement λ² = −g·dz bounds the remaining decrease of the
        # self-concordant barrier by λ²/2 — once that is noise relative to
        # φ, further steps cannot move the iterate.
        converged = -0.5 * slope <= gate_tol * (1.0 + jnp.abs(phi0))

        # Analytic batched line search: fi(z + s dz) is an O(m) update per
        # candidate from ONE precomputed matvec C dz — the quadratic rows
        # shift by s·lin + s²·qq in closed form. No re-assembly, no
        # re-matvec per candidate.
        Cdz = spec.C @ dz
        fi_s = fi[None, :] + ss[:, None] * Cdz[None, :]
        if qr.size:
            lin = 2.0 * jnp.sum(spec.quad_diag * (z * dz)[None, :], axis=-1)
            qq = jnp.sum(spec.quad_diag * (dz * dz)[None, :], axis=-1)
            fi_s = fi_s.at[:, qr].add(
                ss[:, None] * lin[None, :] + (ss * ss)[:, None] * qq[None, :])
        obj_s = t * (obj0 + ss * jnp.dot(spec.c_obj, dz))
        phi_s = obj_s - jnp.sum(jnp.log(-fi_s), axis=-1)
        ok = (
            jnp.all(fi_s < -1e-14, axis=-1)
            & jnp.isfinite(phi_s)
            & (phi_s <= phi0 + 0.25 * ss * slope)
        )
        found = jnp.any(ok)
        step = jnp.where(found, ss[jnp.argmax(ok)], jnp.asarray(0.0, z.dtype))
        z_new = jnp.where(converged | ~found, z, z + step * dz)
        # ~found leaves z unchanged, so iterating again would recompute the
        # exact same rejected step — stopping is equivalent and free.
        return i + 1, z_new, converged | ~found

    def cond(state):
        i, _, done = state
        return (i < iters) & ~done

    _, z, _ = jax.lax.while_loop(cond, body, (jnp.asarray(0), z, False))
    return z


def structured_barrier_solve(
    spec: StructuredSpec,
    z0: jnp.ndarray,
    t0: float = 1.0,
    mu: float = 12.0,
    outer_iters: int = 14,
    newton_iters: int = 18,
    reg_rel: float = _REG_REL,
    ls_iters: int = _LS_CANDIDATES,
    gate_tol: float = _GATE_TOL,
) -> BarrierResult:
    """Solve a :class:`StructuredSpec` from a strictly feasible ``z0``.

    Same barrier schedule semantics as :func:`barrier_solve`; every
    Newton step costs O(m·n) matvecs plus an O(n) KKT solve instead of an
    autodiff Hessian plus an O(n³) Cholesky.
    """
    z0 = jnp.asarray(z0, jnp.float64)
    m = spec.c0.shape[0]

    def stage(z, t):
        z = _structured_newton_steps(
            spec, z, t, newton_iters, reg_rel, ls_iters, gate_tol)
        return z, None

    ts = t0 * mu ** jnp.arange(outer_iters, dtype=jnp.float64)
    z, _ = jax.lax.scan(stage, z0, ts)
    fi = structured_inequalities(spec, z)
    objective = structured_objective(spec, z)
    return BarrierResult(
        z=z,
        objective=objective,
        max_violation=jnp.max(fi),
        duality_gap_bound=m / ts[-1],
        ok=jnp.all(jnp.isfinite(z)) & jnp.isfinite(objective),
    )


# ---------------------------------------------------------------------------
# Dense autodiff path (generic inequalities; A/B reference for the PCCP)
# ---------------------------------------------------------------------------


def _newton_steps(
    phi: Callable,
    ineq: Callable,
    A: Optional[jnp.ndarray],
    z: jnp.ndarray,
    iters: int,
    reg_rel: float,
    ls_iters: int = _LS_CANDIDATES,
    gate_tol: Optional[float] = None,
):
    n = z.shape[0]

    def step(z):
        g = jax.grad(phi)(z)
        H = jax.hessian(phi)(z)
        # Scale-aware Tikhonov: relative to max(diag H), so the dense and
        # structured paths stay conditioned identically across the PCCP
        # ρ-ramp (a fixed absolute reg is dominant early, inert late).
        H = H + (reg_rel * jnp.maximum(jnp.max(jnp.diag(H)), 1.0)) * jnp.eye(
            n, dtype=z.dtype)
        # H is SPD (barrier Hessian of a convex program + Tikhonov), so the
        # KKT system is solved by block elimination on one Cholesky factor:
        #   dz = v − W ν,  ν = (A W)⁻¹ A v,  H v = −g,  H W = Aᵀ.
        # One dpotrf on (n, n) replaces the (n+p)² LU — measurably faster
        # for the small batched systems the vmapped PCCP solves consist of.
        if A is not None:
            c = jax.scipy.linalg.cho_factor(H)
            vw = jax.scipy.linalg.cho_solve(
                c, jnp.concatenate([-g[:, None], A.T], axis=1))
            v, W = vw[:, 0], vw[:, 1:]
            nu = jnp.linalg.solve(A @ W, A @ v)
            dz = v - W @ nu
        else:
            c = jax.scipy.linalg.cho_factor(H)
            dz = jax.scipy.linalg.cho_solve(c, -g)

        # Backtracking with explicit strict-feasibility + finiteness checks.
        # The classic loop halves s until the first acceptable step; with a
        # fixed trip count the candidates are independent, so we batch them
        # in ONE vmapped evaluation (same accepted step — the largest
        # acceptable s — but an ls_iters× shorter sequential dependency
        # chain inside the vmapped PCCP inner solves).
        phi0 = phi(z)
        slope = jnp.vdot(g, dz)
        ss = jnp.asarray(0.5, z.dtype) ** jnp.arange(ls_iters, dtype=z.dtype)

        def try_step(s):
            z_try = z + s * dz
            feas = jnp.all(ineq(z_try) < -1e-14)
            phi_try = phi(z_try)
            return feas & jnp.isfinite(phi_try) & (phi_try <= phi0 + 0.25 * s * slope)

        ok = jax.vmap(try_step)(ss)
        found = jnp.any(ok)
        step = jnp.where(found, ss[jnp.argmax(ok)], jnp.asarray(0.0, z.dtype))
        # If no feasible improving step exists we are at (numerical) optimum.
        return jnp.where(found, z + step * dz, z), phi0, slope, found

    if gate_tol is None:  # fixed-trip legacy path (bit-exact)
        def body(_, z):
            z_new, _, _, _ = step(z)
            return z_new

        return jax.lax.fori_loop(0, iters, body, z)

    def body(state):
        i, z, _ = state
        z_new, phi0, slope, found = step(z)
        converged = -0.5 * slope <= gate_tol * (1.0 + jnp.abs(phi0))
        return i + 1, jnp.where(converged, z, z_new), converged | ~found

    def cond(state):
        i, _, done = state
        return (i < iters) & ~done

    _, z, _ = jax.lax.while_loop(cond, body, (jnp.asarray(0), z, False))
    return z


def barrier_solve(
    spec: BarrierSpec,
    z0: jnp.ndarray,
    t0: float = 1.0,
    mu: float = 12.0,
    outer_iters: int = 14,
    newton_iters: int = 18,
    reg_rel: float = _REG_REL,
    ls_iters: int = _LS_CANDIDATES,
    gate_tol: Optional[float] = None,
) -> BarrierResult:
    """Solve ``spec`` starting from a strictly feasible ``z0``.

    With the defaults the final barrier parameter is t0 * mu**13 ≈ 1e14, so
    the suboptimality bound m/t is far below solver noise for our m ≈ 30.

    ``gate_tol`` (None = fixed trip counts, the bit-exact legacy
    behaviour) enables the Newton-decrement early exit per barrier stage.
    """
    z0 = jnp.asarray(z0, jnp.float64)
    m = spec.inequalities(z0).shape[0]
    A = spec.eq_matrix

    def stage(carry, t):
        z = carry

        def phi(zz):
            fi = spec.inequalities(zz)
            return t * spec.objective(zz) - jnp.sum(jnp.log(-fi))

        z = _newton_steps(phi, spec.inequalities, A, z, newton_iters, reg_rel,
                          ls_iters, gate_tol)
        return z, None

    ts = t0 * mu ** jnp.arange(outer_iters, dtype=jnp.float64)
    z, _ = jax.lax.scan(stage, z0, ts)
    fi = spec.inequalities(z)
    objective = spec.objective(z)
    return BarrierResult(
        z=z,
        objective=objective,
        max_violation=jnp.max(fi),
        duality_gap_bound=m / ts[-1],
        ok=jnp.all(jnp.isfinite(z)) & jnp.isfinite(objective),
    )
