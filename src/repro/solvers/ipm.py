"""Equality-constrained log-barrier interior-point method (pure JAX).

This is the workhorse behind both paper subproblems:

* the resource-allocation problem (23) — convex, solved to optimality
  (the paper prescribes "an interior point (IPT) algorithm"), and
* the inner convex approximations (36) of the PCCP loop (Algorithm 1).

Design notes
------------
- Fixed iteration counts everywhere (``lax.fori_loop`` / masked updates)
  so the solver jits once and vmaps across devices/problems.
- Newton steps solve the KKT system  [H Aᵀ; A 0] [dz; ν] = [-∇φ; 0]
  with Tikhonov regularization on H; equality feasibility (A z = b) is
  maintained exactly from a feasible start.
- Backtracking line search enforces *strict* inequality feasibility before
  evaluating the barrier (log of a non-positive argument is NaN and NaN
  comparisons would silently accept bad steps — we check explicitly).
"""
from __future__ import annotations

from functools import partial
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class BarrierSpec(NamedTuple):
    """A smooth convex program: min f0(z) s.t. fi(z) <= 0, A z = b."""

    objective: Callable[[jnp.ndarray], jnp.ndarray]
    inequalities: Callable[[jnp.ndarray], jnp.ndarray]
    eq_matrix: Optional[jnp.ndarray] = None  # (p, n)
    eq_rhs: Optional[jnp.ndarray] = None  # (p,)


class BarrierResult(NamedTuple):
    z: jnp.ndarray
    objective: jnp.ndarray
    max_violation: jnp.ndarray  # max fi(z); <= 0 means feasible
    duality_gap_bound: jnp.ndarray  # m / t at the final barrier stage


def _newton_steps(
    phi: Callable,
    ineq: Callable,
    A: Optional[jnp.ndarray],
    z: jnp.ndarray,
    iters: int,
    reg: float,
):
    n = z.shape[0]

    def body(_, z):
        g = jax.grad(phi)(z)
        H = jax.hessian(phi)(z)
        H = H + reg * jnp.eye(n, dtype=z.dtype)
        if A is not None:
            p = A.shape[0]
            kkt = jnp.block(
                [[H, A.T], [A, jnp.zeros((p, p), dtype=z.dtype)]]
            )
            rhs = jnp.concatenate([-g, jnp.zeros((p,), dtype=z.dtype)])
            sol = jnp.linalg.solve(kkt, rhs)
            dz = sol[:n]
        else:
            dz = jnp.linalg.solve(H, -g)

        # Backtracking with explicit strict-feasibility + finiteness checks.
        phi0 = phi(z)
        slope = jnp.vdot(g, dz)

        def ls_body(_, state):
            s, best_s, found = state
            z_try = z + s * dz
            feas = jnp.all(ineq(z_try) < -1e-14)
            phi_try = phi(z_try)
            ok = feas & jnp.isfinite(phi_try) & (phi_try <= phi0 + 0.25 * s * slope)
            best_s = jnp.where(ok & ~found, s, best_s)
            found = found | ok
            return s * 0.5, best_s, found

        _, step, found = jax.lax.fori_loop(
            0, 40, ls_body, (jnp.asarray(1.0, z.dtype), jnp.asarray(0.0, z.dtype), False)
        )
        z_new = z + step * dz
        # If no feasible improving step exists we are at (numerical) optimum.
        return jnp.where(found, z_new, z)

    return jax.lax.fori_loop(0, iters, body, z)


def barrier_solve(
    spec: BarrierSpec,
    z0: jnp.ndarray,
    t0: float = 1.0,
    mu: float = 12.0,
    outer_iters: int = 14,
    newton_iters: int = 18,
    reg: float = 1e-10,
) -> BarrierResult:
    """Solve ``spec`` starting from a strictly feasible ``z0``.

    With the defaults the final barrier parameter is t0 * mu**13 ≈ 1e14, so
    the suboptimality bound m/t is far below solver noise for our m ≈ 30.
    """
    z0 = jnp.asarray(z0, jnp.float64)
    m = spec.inequalities(z0).shape[0]
    A = spec.eq_matrix

    def stage(carry, t):
        z = carry

        def phi(zz):
            fi = spec.inequalities(zz)
            return t * spec.objective(zz) - jnp.sum(jnp.log(-fi))

        z = _newton_steps(phi, spec.inequalities, A, z, newton_iters, reg)
        return z, None

    ts = t0 * mu ** jnp.arange(outer_iters, dtype=jnp.float64)
    z, _ = jax.lax.scan(stage, z0, ts)
    fi = spec.inequalities(z)
    return BarrierResult(
        z=z,
        objective=spec.objective(z),
        max_violation=jnp.max(fi),
        duality_gap_bound=m / ts[-1],
    )
