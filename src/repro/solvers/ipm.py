"""Equality-constrained log-barrier interior-point method (pure JAX).

This is the workhorse behind both paper subproblems:

* the resource-allocation problem (23) — convex, solved to optimality
  (the paper prescribes "an interior point (IPT) algorithm"), and
* the inner convex approximations (36) of the PCCP loop (Algorithm 1).

Design notes
------------
- Fixed iteration counts everywhere (``lax.fori_loop`` / masked updates)
  so the solver jits once and vmaps across devices/problems.
- Newton steps solve the KKT system  [H Aᵀ; A 0] [dz; ν] = [-∇φ; 0]
  with Tikhonov regularization on H; equality feasibility (A z = b) is
  maintained exactly from a feasible start.
- Backtracking line search enforces *strict* inequality feasibility before
  evaluating the barrier (log of a non-positive argument is NaN and NaN
  comparisons would silently accept bad steps — we check explicitly).
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

#: Backtracking candidates s = 2⁻ᵏ, k < _LS_CANDIDATES. The smallest step
#: tried is 2⁻²³ ≈ 1e-7 — steps below that make no numerical progress on
#: the float64 barrier, and each extra candidate costs a batched function
#: evaluation in the planner's hot loop.
_LS_CANDIDATES = 24


class BarrierSpec(NamedTuple):
    """A smooth convex program: min f0(z) s.t. fi(z) <= 0, A z = b."""

    objective: Callable[[jnp.ndarray], jnp.ndarray]
    inequalities: Callable[[jnp.ndarray], jnp.ndarray]
    eq_matrix: Optional[jnp.ndarray] = None  # (p, n)
    eq_rhs: Optional[jnp.ndarray] = None  # (p,)


class BarrierResult(NamedTuple):
    z: jnp.ndarray
    objective: jnp.ndarray
    max_violation: jnp.ndarray  # max fi(z); <= 0 means feasible
    duality_gap_bound: jnp.ndarray  # m / t at the final barrier stage


def _newton_steps(
    phi: Callable,
    ineq: Callable,
    A: Optional[jnp.ndarray],
    z: jnp.ndarray,
    iters: int,
    reg: float,
    ls_iters: int = _LS_CANDIDATES,
):
    n = z.shape[0]

    def body(_, z):
        g = jax.grad(phi)(z)
        H = jax.hessian(phi)(z)
        H = H + reg * jnp.eye(n, dtype=z.dtype)
        # H is SPD (barrier Hessian of a convex program + Tikhonov), so the
        # KKT system is solved by block elimination on one Cholesky factor:
        #   dz = v − W ν,  ν = (A W)⁻¹ A v,  H v = −g,  H W = Aᵀ.
        # One dpotrf on (n, n) replaces the (n+p)² LU — measurably faster
        # for the small batched systems the vmapped PCCP solves consist of.
        if A is not None:
            p = A.shape[0]
            c = jax.scipy.linalg.cho_factor(H)
            vw = jax.scipy.linalg.cho_solve(
                c, jnp.concatenate([-g[:, None], A.T], axis=1))
            v, W = vw[:, 0], vw[:, 1:]
            nu = jnp.linalg.solve(A @ W, A @ v)
            dz = v - W @ nu
        else:
            c = jax.scipy.linalg.cho_factor(H)
            dz = jax.scipy.linalg.cho_solve(c, -g)

        # Backtracking with explicit strict-feasibility + finiteness checks.
        # The classic loop halves s until the first acceptable step; with a
        # fixed trip count the candidates are independent, so we batch them
        # in ONE vmapped evaluation (same accepted step — the largest
        # acceptable s — but an ls_iters× shorter sequential dependency
        # chain inside the vmapped PCCP inner solves).
        phi0 = phi(z)
        slope = jnp.vdot(g, dz)
        ss = jnp.asarray(0.5, z.dtype) ** jnp.arange(ls_iters, dtype=z.dtype)

        def try_step(s):
            z_try = z + s * dz
            feas = jnp.all(ineq(z_try) < -1e-14)
            phi_try = phi(z_try)
            return feas & jnp.isfinite(phi_try) & (phi_try <= phi0 + 0.25 * s * slope)

        ok = jax.vmap(try_step)(ss)
        found = jnp.any(ok)
        step = jnp.where(found, ss[jnp.argmax(ok)], jnp.asarray(0.0, z.dtype))
        z_new = z + step * dz
        # If no feasible improving step exists we are at (numerical) optimum.
        return jnp.where(found, z_new, z)

    return jax.lax.fori_loop(0, iters, body, z)


def barrier_solve(
    spec: BarrierSpec,
    z0: jnp.ndarray,
    t0: float = 1.0,
    mu: float = 12.0,
    outer_iters: int = 14,
    newton_iters: int = 18,
    reg: float = 1e-10,
    ls_iters: int = _LS_CANDIDATES,
) -> BarrierResult:
    """Solve ``spec`` starting from a strictly feasible ``z0``.

    With the defaults the final barrier parameter is t0 * mu**13 ≈ 1e14, so
    the suboptimality bound m/t is far below solver noise for our m ≈ 30.
    """
    z0 = jnp.asarray(z0, jnp.float64)
    m = spec.inequalities(z0).shape[0]
    A = spec.eq_matrix

    def stage(carry, t):
        z = carry

        def phi(zz):
            fi = spec.inequalities(zz)
            return t * spec.objective(zz) - jnp.sum(jnp.log(-fi))

        z = _newton_steps(phi, spec.inequalities, A, z, newton_iters, reg, ls_iters)
        return z, None

    ts = t0 * mu ** jnp.arange(outer_iters, dtype=jnp.float64)
    z, _ = jax.lax.scan(stage, z0, ts)
    fi = spec.inequalities(z)
    return BarrierResult(
        z=z,
        objective=spec.objective(z),
        max_violation=jnp.max(fi),
        duality_gap_bound=m / ts[-1],
    )
