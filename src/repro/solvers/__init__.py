"""JAX-native numerical solvers used by the robust planner.

Everything in this package is pure-JAX (jit/vmap friendly) and runs in
float64 — the chance-constrained subproblems mix quantities spanning many
orders of magnitude (Hz, W, J, s), so we enable x64 on import. Model code
elsewhere in `repro` declares its dtypes explicitly (bf16/f32) and is not
affected beyond defaults.
"""
import jax

jax.config.update("jax_enable_x64", True)

from repro.solvers.scalar import bisect, golden_section  # noqa: E402,F401
from repro.solvers.nls import levenberg_marquardt  # noqa: E402,F401
from repro.solvers.ipm import (  # noqa: E402,F401
    BarrierSpec,
    StructuredSpec,
    barrier_solve,
    structured_barrier_solve,
    woodbury_solve,
)

__all__ = [
    "bisect",
    "golden_section",
    "levenberg_marquardt",
    "barrier_solve",
    "BarrierSpec",
    "StructuredSpec",
    "structured_barrier_solve",
    "woodbury_solve",
]
