"""Sharding rules: parameter / batch / cache PartitionSpecs per mesh.

Scheme (DESIGN.md §6): FSDP along the data axes (("pod","data") when the
pod axis exists, else ("data",)) + tensor parallel along "model".

- in-projections  (wq/wk/wv/w1/w3/in_proj/lora ups/lm_head):  (fsdp, model)
- out-projections (wo/w2/out_proj):                            (model, fsdp)
- embedding (V, D): (model, fsdp) — vocab on model keeps logits sharded.
- MoE expert weights (E, ·, ·): expert-parallel — E over (fsdp+model) when
  divisible (DeepSeek-V3: 256 = 16·16), else E over model with the wide
  inner dim over fsdp.
- Every dim is sharded only if divisible by the axis size; otherwise left
  replicated (hymba's 25 heads, mamba's odd in_proj width stay safe).

Caches: batch over fsdp when divisible; for batch-1 long-context decode
the cache length axis takes the fsdp axes instead (sequence-parallel KV).
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

IN_PROJ = {"wq", "wk", "wv", "w1", "w3", "in_proj", "wdq", "wuq", "wdkv", "wkr",
           "wuk", "wuv", "lm_head", "proj"}
OUT_PROJ = {"wo", "w2", "out_proj"}
STACKED = {"layers", "enc_layers"}

# ---------------------------------------------------------------------------
# Activation sharding constraints. The launch layer installs the mesh here;
# model code calls ``constrain`` with symbolic axes and the helper applies
# only the divisible ones. With no mesh installed (CPU smoke tests) it is a
# no-op, so model code never needs to know whether it is distributed.
# ---------------------------------------------------------------------------

_ACTIVATION_MESH = None


def set_activation_mesh(mesh) -> None:
    global _ACTIVATION_MESH
    _ACTIVATION_MESH = mesh


def activation_mesh():
    return _ACTIVATION_MESH


def constrain(x, spec):
    """Best-effort with_sharding_constraint.

    ``spec``: per-dim entries in {None, "fsdp", "model"}. "fsdp" expands to
    ("pod","data") when a pod axis exists. If several dims ask for "model",
    only the first divisible one gets it (first-fit); non-divisible dims
    are silently left replicated.
    """
    mesh = _ACTIVATION_MESH
    if mesh is None:
        return x
    fs = fsdp_axes(mesh)
    fsdp = fs if len(fs) > 1 else fs[0]
    model_used = False
    out = []
    # strict=False: a spec shorter than the rank replicates trailing dims
    for dim, ax in zip(x.shape, spec, strict=False):
        if ax == "fsdp" and dim % _size(mesh, fs) == 0 and dim >= _size(mesh, fs):
            out.append(fsdp)
        elif ax == "model" and not model_used and dim % mesh.shape["model"] == 0 \
                and dim >= mesh.shape["model"]:
            out.append("model")
            model_used = True
        else:
            out.append(None)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*out)))


def constrain_expert(x):
    """Expert-parallel constraint for (E, capacity, D) MoE blocks: E over
    the widest divisible combination of (fsdp+model) > model > fsdp."""
    mesh = _ACTIVATION_MESH
    if mesh is None:
        return x
    fs = fsdp_axes(mesh)
    e = x.shape[0]
    for axes in (tuple(fs) + ("model",), ("model",), fs):
        sz = _size(mesh, axes)
        if sz > 1 and e % sz == 0 and e >= sz:
            entry = axes if len(axes) > 1 else axes[0]
            spec = [entry] + [None] * (x.ndim - 1)
            return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*spec)))
    return x


def fsdp_axes(mesh) -> Tuple[str, ...]:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def expert_axis_candidates(mesh) -> Tuple[Tuple[str, ...], ...]:
    """Expert-parallel axis groupings to try, widest first."""
    fs = fsdp_axes(mesh)
    cands = [tuple(fs) + ("model",)]
    if "pod" in mesh.axis_names:
        cands.append(("data", "model"))
    cands.append(("model",))
    return tuple(cands)


def _size(mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        return mesh.shape[axes]
    return int(np.prod([mesh.shape[a] for a in axes]))


def _ok(dim: int, mesh, axes) -> bool:
    s = _size(mesh, axes)
    return s > 1 and dim % s == 0


def _leaf_spec(path, shape, mesh) -> P:
    names = [p.key for p in path if hasattr(p, "key")]
    name = names[-1] if names else ""
    fs = fsdp_axes(mesh)
    fsdp = fs if len(fs) > 1 else fs[0]
    stacked = any(n in STACKED for n in names)
    dims = list(shape[1:]) if stacked else list(shape)
    lead = [None] if stacked else []

    def guard(spec_entries):
        out = []
        # strict=False: short specs leave trailing dims replicated
        for dim, ax in zip(dims, spec_entries, strict=False):
            out.append(ax if ax is not None and _ok(dim, mesh, ax) else None)
        return P(*(lead + out))

    is_moe_w = "ff" in names and name in ("w1", "w2", "w3") and len(dims) == 3
    if is_moe_w:
        # Expert-parallel only (no inner-dim FSDP): the a2a dispatch path
        # needs whole experts resident. Widest divisible expert grouping
        # wins; on multi-pod meshes experts may shard (data×model) with the
        # pod axis replicating (DeepSeek-V3: 256 = 16·16).
        e = dims[0]
        for axes in expert_axis_candidates(mesh):
            if _ok(e, mesh, axes):
                entry = axes if len(axes) > 1 else axes[0]
                return P(*(lead + [entry, None, None]))
        return P(*(lead + [None, None, None]))

    if name == "embed":
        return guard(["model", fsdp])
    if name == "router":
        return guard([fsdp, None])
    if name == "conv_w":
        return guard([None, "model"])
    if len(dims) == 2 and name in IN_PROJ:
        return guard([fsdp, "model"])
    if len(dims) == 2 and name in OUT_PROJ:
        return guard(["model", fsdp])
    if len(dims) == 2:
        return guard([None, "model"])
    if len(dims) == 3:  # e.g. vlm projector variants
        return guard([None, fsdp, "model"])
    return P(*(lead + [None] * len(dims)))


def param_shardings(abstract_params: Any, mesh, fsdp: bool = True,
                    mode: str = None) -> Any:
    """Parameter shardings by mode (§Perf serving-layout options):

    - "fsdp" (training default): FSDP over data axes + tensor over model.
    - "resident": drop pure-FSDP factors — weights stay model/expert-
      sharded, no per-step parameter all-gather. Entries combining fsdp
      axes WITH the model axis (expert parallelism) are kept: those are
      layout shards, not FSDP.
    - "replicated": full weight replication (small models, prefill) —
      zero parameter collectives; expert sharding is still kept so MoE
      stacks that cannot replicate keep working.
    """
    if mode is None:
        mode = "fsdp" if fsdp else "resident"
    fs = set(fsdp_axes(mesh))

    def strip(spec: P, drop_model: bool) -> P:
        out = []
        for entry in spec:
            if entry is None:
                out.append(None)
                continue
            axes = set(entry) if isinstance(entry, tuple) else {entry}
            if axes <= fs:
                out.append(None)  # pure FSDP factor
            elif drop_model and axes == {"model"}:
                out.append(None)
            else:
                out.append(entry)  # tensor/expert shards
        return P(*out)

    def f(path, leaf):
        spec = _leaf_spec(path, leaf.shape, mesh)
        if mode == "resident":
            spec = strip(spec, drop_model=False)
        elif mode == "replicated":
            names = [getattr(p, "key", "") for p in path]
            is_expert = "ff" in names and len(leaf.shape) >= 3
            spec = spec if is_expert else strip(spec, drop_model=True)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(f, abstract_params)


def replicated(mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


def planner_mesh(devices=None):
    """1-D ``("devices",)`` mesh for the group-sharded planner
    (``core.decompose``): fleet *lane* axes — per-device chains, gains,
    allocation vectors — shard across it, scalar prices replicate.

    Distinct from the model-parameter meshes above: the planner's data
    parallelism is over *fleet devices* (rows of the per-group tables),
    not over model weights, so it gets its own axis name and no
    fsdp/model structure. On a single-device host this is a size-1 mesh
    and ``shard_map`` degenerates to an identity wrapper — same trace,
    same values.
    """
    from jax.sharding import Mesh

    if devices is None:
        devices = jax.local_devices()
    return Mesh(np.asarray(devices), ("devices",))


def batch_sharding(mesh, batch_dim: int, ndim: int) -> NamedSharding:
    fs = fsdp_axes(mesh)
    fsdp = fs if len(fs) > 1 else fs[0]
    spec = [None] * ndim
    if batch_dim % _size(mesh, fs) == 0:
        spec[0] = fsdp
    return NamedSharding(mesh, P(*spec))


def batch_shardings(mesh, batch_abstract: Any) -> Any:
    return jax.tree.map(
        lambda l: batch_sharding(mesh, l.shape[0], l.ndim), batch_abstract
    )


def cache_shardings(mesh, cache_abstract: Any) -> Any:
    """Caches: leaves stacked over L. (L, B, W/T, heads?, dh?) or SSM states."""
    fs = fsdp_axes(mesh)
    fsdp = fs if len(fs) > 1 else fs[0]
    fsdp_sz = _size(mesh, fs)
    model_sz = mesh.shape["model"]

    def f(path, leaf):
        shape = leaf.shape
        spec = [None] * leaf.ndim  # dim 0 = layers, never sharded
        if leaf.ndim >= 3:
            b, length = shape[1], shape[2]
            if b % fsdp_sz == 0 and b >= fsdp_sz:
                spec[1] = fsdp
            elif length % fsdp_sz == 0:
                spec[2] = fsdp  # sequence-parallel cache (batch-1 long ctx)
            # Shard the HEADS dim over model when divisible. Never shard the
            # trailing feature dim: a sharded head_dim turns every decode
            # step into a full-cache re-gather (measured: §Perf iteration A1).
            if leaf.ndim >= 5 and shape[3] % model_sz == 0 and shape[3] >= model_sz:
                spec[3] = "model"
            elif spec[2] is None and length % model_sz == 0 and length >= model_sz:
                spec[2] = "model"  # sequence-parallel KV over the model axis
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(f, cache_abstract)
