import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) combo.

The two lines above MUST stay first: jax locks the device count on first
initialization. Everything below is ordinary imports.

Per combo this produces: compile success, per-device memory analysis,
HLO FLOPs/bytes (cost_analysis), and per-type collective bytes parsed from
the partitioned HLO — the §Roofline inputs.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch tinyllama-1.1b \
      --shape train_4k [--multi-pod] [--out results/x.json]
"""
import argparse  # noqa: E402
import json  # noqa: E402
import re  # noqa: E402
import time  # noqa: E402
from typing import Dict, Optional  # noqa: E402

import jax  # noqa: E402

from repro.configs.base import INPUT_SHAPES  # noqa: E402
from repro.configs.registry import ARCH_IDS, get_config  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch import steps as S  # noqa: E402
from repro.parallel import sharding as shd  # noqa: E402

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
}
_COLL_RE = re.compile(
    r"=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\])\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-device collective bytes by op type from partitioned HLO.

    all-reduce is weighted 2× (ring: reduce-scatter + all-gather phases);
    others count their (already per-device) output buffer once.
    """
    out: Dict[str, float] = {}
    for type_str, op in _COLL_RE.findall(hlo_text):
        nbytes = _type_bytes(type_str)
        if op == "all-reduce":
            nbytes *= 2
        out[op] = out.get(op, 0.0) + float(nbytes)
    return out


def should_skip(arch: str, shape_name: str) -> Optional[str]:
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    if cfg.encoder_decoder and shape.name == "long_500k":
        return ("enc-dec full-attention decoder has no 500k-decode analogue "
                "(DESIGN.md §5) — skipped")
    return None


def run_one(arch: str, shape_name: str, multi_pod: bool = False,
            verbose: bool = True, param_fsdp: bool = True,
            param_mode: str = None, microbatches: int = 1) -> Dict:
    shape = INPUT_SHAPES[shape_name]
    mesh_name = "2x16x16" if multi_pod else "16x16"
    rec: Dict = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                 "param_fsdp": param_fsdp, "param_mode": param_mode,
                 "microbatches": microbatches}
    skip = should_skip(arch, shape_name)
    if skip:
        rec["status"] = "skipped"
        rec["reason"] = skip
        return rec

    cfg = get_config(arch)
    mesh = make_production_mesh(multi_pod=multi_pod)
    # Activation constraints stay ON in every mode: without them GSPMD
    # drops the batch sharding at scan boundaries and replicates compute
    # (§Perf iteration C4, refuted — 16x flops, 2.4 TB all-reduce).
    shd.set_activation_mesh(mesh)
    n_dev = mesh.size
    t0 = time.time()

    with jax.set_mesh(mesh) if hasattr(jax, "set_mesh") else mesh:
        if shape.kind == "train":
            step, opt = S.build_train_step(cfg, microbatches=microbatches)
            params, opt_state = S.abstract_state(cfg, opt)
            batch = S.batch_specs(cfg, shape)
            p_sh = shd.param_shardings(params, mesh)
            # opt-state shardings mirror params; the step scalar is replicated
            from repro.train.optimizer import AdamWState
            o_sh = AdamWState(
                step=shd.replicated(mesh),
                mu=shd.param_shardings(params, mesh),
                nu=shd.param_shardings(params, mesh),
            )
            b_sh = shd.batch_shardings(mesh, batch)
            lowered = jax.jit(
                step, in_shardings=(p_sh, o_sh, b_sh), donate_argnums=(0, 1)
            ).lower(params, opt_state, batch)
        elif shape.kind == "prefill":
            step = S.build_prefill_step(cfg)
            params = S.abstract_state(cfg, S.build_train_step(cfg)[1])[0]
            batch = S.batch_specs(cfg, shape)
            p_sh = shd.param_shardings(params, mesh, fsdp=param_fsdp, mode=param_mode)
            b_sh = shd.batch_shardings(mesh, batch)
            lowered = jax.jit(step, in_shardings=(p_sh, b_sh)).lower(params, batch)
        else:  # decode
            step = S.build_decode_step(cfg)
            params = S.abstract_state(cfg, S.build_train_step(cfg)[1])[0]
            tokens, cache, pos = S.decode_specs(cfg, shape)
            p_sh = shd.param_shardings(params, mesh, fsdp=param_fsdp, mode=param_mode)
            t_sh = shd.batch_sharding(mesh, tokens.shape[0], 2)
            c_sh = shd.cache_shardings(mesh, cache)
            lowered = jax.jit(
                step, in_shardings=(p_sh, t_sh, c_sh, shd.replicated(mesh)),
                donate_argnums=(2,),
            ).lower(params, tokens, cache, pos)

        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    from repro.launch.hlo_analysis import analyze

    hlo = analyze(compiled.as_text())
    coll = hlo.collective_bytes
    rec.update(
        hlo_loop_aware_flops_per_dev=hlo.flops,
        hlo_loop_aware_dot_bytes_per_dev=hlo.dot_bytes,
        hlo_while_trip_counts=hlo.trip_counts,
        status="ok",
        lower_s=round(t_lower, 2),
        compile_s=round(t_compile, 2),
        num_devices=n_dev,
        arg_bytes_per_dev=getattr(ma, "argument_size_in_bytes", None),
        temp_bytes_per_dev=getattr(ma, "temp_size_in_bytes", None),
        out_bytes_per_dev=getattr(ma, "output_size_in_bytes", None),
        alias_bytes_per_dev=getattr(ma, "alias_size_in_bytes", None),
        hlo_flops_per_dev=float(ca.get("flops", -1.0)),
        hlo_bytes_per_dev=float(ca.get("bytes accessed", -1.0)),
        collective_bytes_per_dev=coll,
        model_flops_total=S.model_flops_estimate(cfg, shape),
    )
    if verbose:
        hbm = (rec["arg_bytes_per_dev"] + rec["temp_bytes_per_dev"]
               + rec["out_bytes_per_dev"] - rec["alias_bytes_per_dev"]) / 2**30
        print(f"[{arch} × {shape_name} × {mesh_name}] compile {t_compile:.1f}s "
              f"~{hbm:.2f} GiB/dev, {hlo.flops/1e12:.3f} TFLOP/dev (loop-aware), "
              f"coll={ {k: round(v/2**20, 1) for k, v in coll.items()} } MiB")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(ARCH_IDS))
    ap.add_argument("--shape", required=True, choices=list(INPUT_SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--no-param-fsdp", action="store_true",
                    help="serve-mode weights: model/expert sharding only")
    ap.add_argument("--param-mode", default=None,
                    choices=("fsdp", "resident", "replicated"))
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    rec = run_one(args.arch, args.shape, args.multi_pod,
                  param_fsdp=not args.no_param_fsdp,
                  param_mode=args.param_mode,
                  microbatches=args.microbatches)
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(rec, f, indent=2)
    print(json.dumps({k: v for k, v in rec.items() if k != "reason"}, default=str))


if __name__ == "__main__":
    main()
