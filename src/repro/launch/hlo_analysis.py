"""Loop-aware cost extraction from partitioned HLO text.

XLA's ``compiled.cost_analysis()`` counts a ``while`` body ONCE, so a
scan-over-layers program under-reports FLOPs/bytes/collectives by ~L×.
This module re-derives loop-aware totals directly from the HLO text:

1. split the module into computations,
2. build the call graph (calls= / to_apply= / while condition+body),
3. give every computation an execution multiplier (while bodies get the
   trip count parsed from their condition's loop-bound constant),
4. sum per-computation dot-FLOPs, dot traffic bytes and collective bytes
   weighted by the multipliers.

Dot FLOPs: 2 · |output| · Π(contracting dims of lhs). Collectives:
all-reduce weighted 2× (ring reduce-scatter + all-gather phases); others
count their per-device output buffer once. Elementwise traffic is not
counted (matmul + collective traffic dominates at these shapes).
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, List, NamedTuple, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_COMP_HEADER = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*->.*\{\s*$")
_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+?)\s+([a-z][\w\-]*)\((.*)$"
)
_SHAPE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


class Instr(NamedTuple):
    name: str
    type_str: str
    op: str
    rest: str


def _shape_of(type_str: str) -> Tuple[str, List[int]]:
    m = _SHAPE.search(type_str)
    if not m:
        return "", []
    dims = [int(d) for d in m.group(2).split(",") if d]
    return m.group(1), dims


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims_s in _SHAPE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims_s.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def split_computations(txt: str) -> Dict[str, List[str]]:
    comps: Dict[str, List[str]] = {}
    cur = None
    entry = None
    for line in txt.splitlines():
        m = _COMP_HEADER.match(line)
        if m and ("->" in line):
            cur = m.group(1)
            comps[cur] = []
            if line.startswith("ENTRY"):
                entry = cur
            continue
        if line.startswith("}"):
            cur = None
            continue
        if cur is not None:
            comps[cur].append(line)
    if entry is not None:
        comps["__entry__"] = comps[entry]
    return comps


def parse_instr(line: str) -> Instr | None:
    m = _INSTR.match(line)
    if not m:
        return None
    return Instr(*m.groups())


class HLOCost(NamedTuple):
    flops: float
    dot_bytes: float
    collective_bytes: Dict[str, float]
    num_whiles: int
    trip_counts: List[int]


def analyze(txt: str, default_trip: int = 1) -> HLOCost:
    comps = split_computations(txt)
    shapes: Dict[str, str] = {}
    per_comp_instrs: Dict[str, List[Instr]] = {}
    for cname, lines in comps.items():
        if cname == "__entry__":
            continue
        ins = []
        for line in lines:
            i = parse_instr(line)
            if i:
                ins.append(i)
                shapes[i.name] = i.type_str
        per_comp_instrs[cname] = ins

    # --- call graph + multipliers -------------------------------------
    entry = None
    for cname, lines in comps.items():
        if cname != "__entry__" and comps.get("__entry__") is lines:
            entry = cname
    if entry is None:  # fall back: the computation named like main
        entry = next((c for c in comps if "main" in c), next(iter(per_comp_instrs)))

    def trip_of(cond_name: str) -> int:
        ints = [int(x) for line in comps.get(cond_name, [])
                for x in re.findall(r"constant\((\d+)\)", line)]
        return max(ints) if ints else default_trip

    mult: Dict[str, float] = defaultdict(float)
    mult[entry] = 1.0
    order = [entry]
    seen = {entry}
    trips: List[int] = []
    nwhile = 0
    idx = 0
    while idx < len(order):
        cname = idx_comp = order[idx]
        idx += 1
        m = mult[cname]
        for i in per_comp_instrs.get(cname, []):
            refs: List[Tuple[str, float]] = []
            wm = re.search(r"condition=%?([\w.\-]+), body=%?([\w.\-]+)", i.rest)
            if i.op == "while" and wm:
                t = trip_of(wm.group(1))
                trips.append(t)
                nwhile += 1
                refs.append((wm.group(2), m * t))
                refs.append((wm.group(1), m))
            for attr in ("calls", "to_apply"):
                for cm in re.finditer(attr + r"=%?([\w.\-]+)", i.rest):
                    refs.append((cm.group(1), m))
            for rname, rmult in refs:
                if rname not in per_comp_instrs:
                    continue
                mult[rname] += rmult
                if rname not in seen:
                    seen.add(rname)
                    order.append(rname)

    # --- cost accumulation ---------------------------------------------
    flops = 0.0
    dot_bytes = 0.0
    coll: Dict[str, float] = defaultdict(float)
    for cname, instrs in per_comp_instrs.items():
        m = mult.get(cname, 0.0)
        if m <= 0.0:
            continue
        for i in instrs:
            if i.op == "dot":
                _, out_dims = _shape_of(i.type_str)
                out_elems = 1
                for d in out_dims:
                    out_elems *= d
                # Newer XLA prints operands with inline types —
                # ``dot(f32[8,64]{1,0} %lhs, ...)`` — so take the lhs shape
                # from the inline type when present, else by name lookup.
                lhs = re.match(
                    r"\s*(?:([a-z0-9]+\[[0-9,]*\])(?:\{[0-9,]*\})?\s+)?"
                    r"%?([\w.\-]+)", i.rest)
                cdims = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", i.rest)
                contract = 1
                if lhs and cdims:
                    lhs_type = lhs.group(1) or shapes.get(lhs.group(2), "")
                    _, ldims = _shape_of(lhs_type)
                    for ax in cdims.group(1).split(","):
                        if ax and int(ax) < len(ldims):
                            contract *= ldims[int(ax)]
                flops += m * 2.0 * out_elems * contract
                opbytes = _type_bytes(i.type_str)
                for opn in re.findall(r"%([\w.\-]+)", i.rest.split(")")[0]):
                    opbytes += _type_bytes(shapes.get(opn, ""))
                dot_bytes += m * opbytes
            elif i.op in _COLLECTIVES:
                nbytes = _type_bytes(i.type_str)
                if i.op == "all-reduce":
                    nbytes *= 2
                coll[i.op] += m * nbytes
    return HLOCost(
        flops=flops, dot_bytes=dot_bytes, collective_bytes=dict(coll),
        num_whiles=nwhile, trip_counts=sorted(set(trips)),
    )
