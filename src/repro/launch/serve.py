"""Serving CLI: batched requests through the engine, then a robust
two-tier partitioning plan fed by the engine's measured statistics.

  PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
      --smoke --requests 8 --new-tokens 8
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import ARCH_IDS, get_config
from repro.core.api import Scenario
from repro.models import transformer as T
from repro.serve.engine import Request, ServingEngine
from repro.serve.partitioned import TwoTierDeployment


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(ARCH_IDS))
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--deadline", type=float, default=1.0)
    ap.add_argument("--eps", type=float, default=0.05)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, params, max_batch=args.max_batch, window=256)
    rng = np.random.default_rng(0)
    reqs = [
        Request(uid=i, prompt=rng.integers(0, cfg.vocab_size, size=rng.integers(4, 16)),
                max_new_tokens=args.new_tokens,
                deadline_s=float(rng.uniform(0.2, 1.0)))
        for i in range(args.requests)
    ]
    done, stats = eng.run(reqs)
    print(f"served {len(done)} requests; decode mean "
          f"{stats['decode_mean_s']*1e3:.2f} ms var {stats['decode_var_s2']:.2e} s²")

    dep = TwoTierDeployment(get_config(args.arch), num_devices=8,
                            deadline_s=args.deadline, eps=args.eps,
                            bandwidth_hz=100e6)
    plan, fleet = dep.plan()
    rep = dep.validate(plan, fleet)
    print("two-tier robust plan per device:", list(map(int, plan.m_sel)))
    print({k: round(v, 5) for k, v in rep.items()})

    # Heterogeneous per-device SLOs: each device inherits a deadline from
    # the request population it serves (Scenario leaves may be (N,)), and
    # the plan is validated against those per-device deadlines.
    dls = jnp.asarray(np.resize([r.deadline_s for r in reqs], dep.num_devices),
                      jnp.float64)
    het = dep.planner().plan(fleet, Scenario(dls, args.eps, dep.bandwidth_hz))
    rep = dep.validate(het, fleet, deadline=dls)
    print("per-device SLO plan:", list(map(int, het.m_sel)),
          f"(deadlines {np.round(np.asarray(dls), 2).tolist()})")
    print({k: round(v, 5) for k, v in rep.items()})


if __name__ == "__main__":
    main()
