"""Production meshes.

Kept as functions (never module-level constants) so importing this module
never touches jax device state — smoke tests see 1 device; only
``dryrun.py`` forces 512 host devices via XLA_FLAGS before any import.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips/pod; multi_pod adds a leading 2-pod axis (512)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )


def make_host_mesh():
    """Single-device mesh for CPU smoke runs (1×1)."""
    return jax.make_mesh(
        (1, 1), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2,
    )
