"""Step functions + abstract input specs for lowering/dry-runs and drivers."""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import InputShape, ModelConfig
from repro.models import transformer as T
from repro.train.optimizer import AdamWConfig, apply_updates, init_state


def build_train_step(cfg: ModelConfig, opt: Optional[AdamWConfig] = None, remat: bool = True,
                     microbatches: int = 1):
    """Train step; ``microbatches > 1`` adds gradient accumulation
    (scan over microbatches) — activation/remat-carry memory scales with
    the microbatch size while collective bytes stay constant (§Perf B3).
    """
    opt = opt or AdamWConfig(moment_dtype=jnp.bfloat16)

    def grads_of(params, batch):
        return jax.value_and_grad(
            lambda p: T.loss_fn(p, cfg, batch, remat=remat), has_aux=True
        )(params)

    def train_step(params, opt_state, batch):
        if microbatches == 1:
            (loss, _), grads = grads_of(params, batch)
        else:
            mb = jax.tree.map(
                lambda x: x.reshape((microbatches, x.shape[0] // microbatches)
                                    + x.shape[1:]), batch)

            def body(carry, b):
                acc, loss_sum = carry
                (loss, _), g = grads_of(params, b)
                acc = jax.tree.map(jnp.add, acc, g)
                return (acc, loss_sum + loss), None

            zero = jax.tree.map(jnp.zeros_like, params)
            (gsum, loss_sum), _ = jax.lax.scan(
                body, (zero, jnp.zeros((), jnp.float32)), mb)
            grads = jax.tree.map(lambda g: g / microbatches, gsum)
            loss = loss_sum / microbatches
        params, opt_state, _ = apply_updates(opt, params, grads, opt_state)
        return params, opt_state, loss

    return train_step, opt


def build_prefill_step(cfg: ModelConfig):
    def prefill_step(params, batch):
        return T.prefill_logits(params, cfg, batch)

    return prefill_step


def build_decode_step(cfg: ModelConfig):
    def serve_step(params, tokens, cache, pos):
        return T.decode_step(params, cfg, tokens, cache, pos)

    return serve_step


# --------------------------------------------------------------------------
# abstract specs (ShapeDtypeStruct only — never allocates)
# --------------------------------------------------------------------------

def enc_len_for(cfg: ModelConfig, seq_len: int) -> int:
    return min(max(seq_len // 4, 1), 1500)


def batch_specs(cfg: ModelConfig, shape: InputShape, act_dtype=jnp.bfloat16) -> Dict[str, jax.ShapeDtypeStruct]:
    b, s = shape.global_batch, shape.seq_len
    sds = jax.ShapeDtypeStruct
    batch = {"tokens": sds((b, s), jnp.int32)}
    if shape.kind == "train":
        batch["labels"] = sds((b, s), jnp.int32)
    if cfg.audio_stub:
        batch["frames"] = sds((b, enc_len_for(cfg, s), cfg.d_model), act_dtype)
    if cfg.vlm_stub:
        batch["patches"] = sds((b, cfg.num_patches, cfg.vision_dim), act_dtype)
    return batch


def decode_window(cfg: ModelConfig, shape: InputShape) -> int:
    """KV-cache width for decode shapes.

    ``shape.window`` (long_500k → 8192) bounds the attention cache: dense
    archs run long-context decode via sliding-window attention; hybrid's
    attention half is natively windowed; SSM needs no KV cache at all.
    """
    if shape.window:
        return min(shape.window, shape.seq_len)
    return shape.seq_len


def decode_specs(cfg: ModelConfig, shape: InputShape, cache_dtype=jnp.bfloat16):
    b = shape.global_batch
    w = decode_window(cfg, shape)
    sds = jax.ShapeDtypeStruct
    tokens = sds((b, 1), jnp.int32)
    cache = jax.eval_shape(
        lambda: T.init_decode_cache(
            cfg, b, w, enc_len=enc_len_for(cfg, shape.seq_len), dtype=cache_dtype
        )
    )
    pos = sds((), jnp.int32)
    return tokens, cache, pos


def abstract_state(cfg: ModelConfig, opt: AdamWConfig, param_dtype=jnp.bfloat16):
    params = T.abstract_params(cfg, dtype=param_dtype)
    opt_state = jax.eval_shape(lambda: init_state(opt, params))
    return params, opt_state


def model_flops_estimate(cfg: ModelConfig, shape: InputShape) -> float:
    """MODEL_FLOPS: 6·N·D train, 2·N·D prefill/decode (N = active params)."""
    from repro.models.transformer import active_param_count

    n = active_param_count(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch  # decode: one token per sequence
