"""Fan-out driver: run every (arch × shape × mesh) dry-run in subprocesses.

Each combo gets its own process because the 512-device XLA_FLAGS must be
set before jax initializes. Results land in results/dryrun/*.json plus a
combined results/dryrun/summary.json.

Usage: PYTHONPATH=src python -m repro.launch.dryrun_all \
           [--jobs 6] [--mesh single|multi|both] [--arch ...] [--shape ...]
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time
from concurrent.futures import ThreadPoolExecutor, as_completed

from repro.configs.base import INPUT_SHAPES
from repro.configs.registry import ARCH_IDS

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "results", "dryrun")


def run_combo(arch: str, shape: str, multi_pod: bool, timeout: int = 3600) -> dict:
    mesh = "2x16x16" if multi_pod else "16x16"
    out = os.path.abspath(os.path.join(RESULTS_DIR, f"{arch}__{shape}__{mesh}.json"))
    if os.path.exists(out):
        with open(out) as f:
            return json.load(f)
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
           "--shape", shape, "--out", out]
    if multi_pod:
        cmd.append("--multi-pod")
    env = dict(os.environ, PYTHONPATH="src")
    t0 = time.time()
    try:
        proc = subprocess.run(cmd, capture_output=True, text=True, timeout=timeout,
                              cwd=os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "..", "..")),
                              env=env)
        if proc.returncode != 0:
            rec = {"arch": arch, "shape": shape, "mesh": mesh, "status": "error",
                   "stderr": proc.stderr[-2000:], "wall_s": round(time.time() - t0, 1)}
            with open(out, "w") as f:
                json.dump(rec, f, indent=2)
            return rec
        with open(out) as f:
            return json.load(f)
    except subprocess.TimeoutExpired:
        rec = {"arch": arch, "shape": shape, "mesh": mesh, "status": "timeout",
               "wall_s": timeout}
        with open(out, "w") as f:
            json.dump(rec, f, indent=2)
        return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--jobs", type=int, default=6)
    ap.add_argument("--mesh", choices=("single", "multi", "both"), default="both")
    ap.add_argument("--arch", nargs="*", default=list(ARCH_IDS))
    ap.add_argument("--shape", nargs="*", default=list(INPUT_SHAPES))
    ap.add_argument("--timeout", type=int, default=3600)
    args = ap.parse_args()

    os.makedirs(RESULTS_DIR, exist_ok=True)
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    combos = [(a, s, m) for a in args.arch for s in args.shape for m in meshes]
    print(f"{len(combos)} combos, {args.jobs} workers")
    results = []
    t0 = time.time()
    with ThreadPoolExecutor(max_workers=args.jobs) as ex:
        futs = {ex.submit(run_combo, a, s, m, args.timeout): (a, s, m) for a, s, m in combos}
        for fut in as_completed(futs):
            a, s, m = futs[fut]
            rec = fut.result()
            results.append(rec)
            print(f"[{len(results)}/{len(combos)}] {a} × {s} × "
                  f"{'2x16x16' if m else '16x16'} → {rec['status']} "
                  f"({time.time()-t0:.0f}s elapsed)", flush=True)
    with open(os.path.join(RESULTS_DIR, "summary.json"), "w") as f:
        json.dump(results, f, indent=2)
    bad = [r for r in results if r["status"] not in ("ok", "skipped")]
    print(f"done: {len(results)-len(bad)} ok/skipped, {len(bad)} failed")
    for r in bad:
        print("FAILED:", r["arch"], r["shape"], r["mesh"], r.get("stderr", "")[-300:])


if __name__ == "__main__":
    main()
