"""Training CLI.

Local (CPU) real training on a reduced config:
  PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
      --smoke --steps 100 --batch 8 --seq 128

Full configs are exercised via the dry-run (see repro.launch.dryrun);
this entry point never forces a device count.
"""
from __future__ import annotations

import argparse

import jax

from repro.configs.registry import ARCH_IDS, get_config
from repro.train.loop import train
from repro.train.optimizer import AdamWConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(ARCH_IDS))
    ap.add_argument("--smoke", action="store_true", help="reduced config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--remat", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    opt = AdamWConfig(lr=args.lr, total_steps=args.steps,
                      warmup_steps=max(args.steps // 20, 1))
    print(f"training {cfg.name}: {cfg.num_layers}L d={cfg.d_model} on "
          f"{len(jax.devices())} device(s)")
    _, _, hist = train(cfg, opt, args.steps, global_batch=args.batch,
                       seq_len=args.seq, ckpt_dir=args.ckpt_dir,
                       ckpt_every=max(args.steps // 2, 1), remat=args.remat)
    first, last = hist["loss"][0][1], hist["loss"][-1][1]
    print(f"loss {first:.4f} → {last:.4f} over {args.steps} steps")


if __name__ == "__main__":
    main()
