"""Step-driven closed-loop serving harness (DESIGN.md §robustness).

Each step draws ``requests_per_step`` Monte-Carlo requests per device
from the *faulted* ground truth (``violation_report(faults=...)`` at the
step's :class:`~repro.serve.faults.FaultSchedule` state), feeds the
deadline outcomes to the :class:`~repro.serve.guard.ViolationSentinel`,
and — when guarded — climbs the graceful-degradation ladder on a trip:

1. **price step** — re-clear the λ/μ prices at the incumbent partition
   against re-fit moments (``plan_fixed_partition``; one allocation
   solve, no PCCP);
2. **warm re-plan** — ``Planner.plan(init_m=incumbent, incumbent=...)``
   on the re-fit fleet (full solve, warm-started; the solver fail-soft
   net is armed via ``incumbent``);
3. **contingency** — select (never solve) the better of the
   precomputed local-only / full-offload plans.

The controller only sees *observables*: deadline outcomes and measured
per-tier latencies (what a partitioned stack records on each tier —
``ViolationReport.mean_local`` / ``mean_vm`` here, ``EngineStats`` in a
real engine). It never peeks at the fault schedule — moment re-fit is an
EWMA per-tier observed/predicted time-scale estimate folded into the
chain via ``apply_faults``, the same hook ``measured_chain`` serves.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.api import Planner, Scenario
from repro.core.blocks import Fleet
from repro.core.montecarlo import violation_report
from repro.core.placement import assignment_churn
from repro.core.planner import Plan, plan_fixed_partition
from repro.core.resource import select_point
from repro.core import channel, energy
from repro.serve.faults import FaultSchedule, FaultState, apply_faults, state_at
from repro.serve.guard import (
    SentinelConfig,
    ViolationSentinel,
    contingency_plans,
    pick_contingency,
)

__all__ = ["GuardConfig", "ClosedLoopResult", "run_closed_loop",
           "RUNG_NONE", "RUNG_PRICE", "RUNG_REPLAN", "RUNG_CONTINGENCY"]

RUNG_NONE = 0
RUNG_PRICE = 1
RUNG_REPLAN = 2
RUNG_CONTINGENCY = 3


@dataclass(frozen=True)
class GuardConfig:
    """Ladder/estimator knobs. ``sentinel`` is the trip test;
    ``sigma_inflation`` sizes the contingency plans' safety margin;
    ``ewma`` is the moment re-fit smoothing; ``max_rung`` caps the
    escalation (each trip climbs one rung, a clean window resets to
    the price rung)."""

    sentinel: SentinelConfig = field(default_factory=SentinelConfig)
    sigma_inflation: float = 1.5
    ewma: float = 0.5
    max_rung: int = RUNG_CONTINGENCY
    #: minimum steps between ladder actions — bounds plan churn when the
    #: fault outruns the ladder (each install resets the sentinel, so
    #: without a cooldown a sustained fault re-trips every step)
    cooldown: int = 2


@dataclass
class ClosedLoopResult:
    """Per-step telemetry plus the headline scalars."""

    step_rate: np.ndarray  # (T,) fleet-mean violation rate per step
    window_rate: np.ndarray  # (T,) sentinel's sliding-window rate
    tripped: np.ndarray  # (T,) bool — sentinel inconsistent with ε
    rung: np.ndarray  # (T,) ladder rung active after the step
    energy: np.ndarray  # (T,) planned energy of the installed plan
    replans: int  # plan installations (ladder actions)
    churn: int  # Σ hamming(m_sel) over installations
    first_trip_step: Optional[int]
    recovery_steps: Optional[int]  # first trip → window back ≤ ε
    #: Σ devices whose node changed over installations (multi-node only)
    migrations: int = 0

    @property
    def peak_window_rate(self) -> float:
        w = self.window_rate[~np.isnan(self.window_rate)]
        return float(w.max()) if w.size else float("nan")

    @property
    def final_window_rate(self) -> float:
        w = self.window_rate[~np.isnan(self.window_rate)]
        return float(w[-1]) if w.size else float("nan")


def _predicted_components(fleet: Fleet, plan: Plan):  # analyze: ok(TRC002): feeds the host-side controller; np is the boundary by design
    """(t_loc, t_off, t_vm) per device predicted by the *nominal* fleet."""
    sel = select_point(fleet, plan.m_sel)
    t_loc = energy.mean_local_time(sel.w_flops, sel.g_eff, plan.alloc.f)
    t_off = channel.offload_time(sel.d_bits, plan.alloc.b, fleet.link.p_tx,
                                 fleet.link.gain)
    return np.asarray(t_loc), np.asarray(t_off), np.asarray(sel.t_vm)


def _refit_scales(loc_hat: float, vm_hat: float, t_loc_pred, t_vm_pred,  # analyze: ok(TRC002,TRC003): host EWMA over already-materialized telemetry
                  obs_local, obs_vm, ewma: float):
    """Per-tier moment re-fit from observables only: each tier's scale
    is the EWMA of observed/predicted mean time *on that tier* (summed
    over devices — a fleet-level ratio, robust to a single tiny
    predictor). A tier the current plan does not exercise is *held*, not
    decayed — the controller must not forget that the shared tier is
    slow just because it stopped using it. Straggler and congestion
    extras land in the measured VM time, so they surface as VM-tier
    dilation — the direction the re-planner should move away from."""
    def step(prev, num, den):
        if den <= 1e-9:
            return prev  # tier unobserved under this plan: hold
        return min(max((1.0 - ewma) * prev + ewma * num / den, 0.1), 1e3)

    return (step(loc_hat, float(np.sum(obs_local)), float(np.sum(t_loc_pred))),
            step(vm_hat, float(np.sum(obs_vm)), float(np.sum(t_vm_pred))))


def _refit_state(loc_hat: float, vm_hat: float) -> FaultState:
    """The re-fit as a FaultState (variances follow the time-dilation
    model, scale²) — fed to ``apply_faults`` to build the fleet the
    ladder re-plans against."""
    a = jnp.asarray(loc_hat, jnp.float64)
    s = jnp.asarray(vm_hat, jnp.float64)
    return FaultState.identity()._replace(
        loc_mean_scale=a, loc_var_scale=a**2,
        vm_mean_scale=s, vm_var_scale=s**2)


def _refit_node_scales(cap_hat, t_vm_pred, obs_vm, assignment,  # analyze: ok(TRC002,TRC003): host EWMA over already-materialized telemetry
                       num_nodes: int, ewma: float):
    """Observable-only per-node capacity re-fit (DESIGN.md §robustness).

    Each node's dilation ratio r_e = Σ_{n: a_n=e} obs_vm / Σ pred_vm
    mixes two causes the controller must separate: a *tier-common* VM
    slowdown (co-tenant drift — the scalar ``vm_hat``'s job) and a
    *node-local* capacity fade (brownout/failure — this estimator's
    job). The least-dilated exercised node is taken as the tier
    baseline, so only the **relative** dilation r_e / min_r is
    attributed to node e's capacity: ŝ_e ← EWMA(min_r / r_e), clamped
    to (1e-3, 1]. Unexercised nodes (no devices assigned, or the plan
    keeps their t_vm at 0) are *held* — the controller must not forget
    a node is degraded just because it migrated everything off it;
    recovery is observed only by re-exercising the node. With E = 1
    there is no relative signal and the estimate stays 1 (the scalar
    ``vm_hat`` already owns whole-edge dilation).
    """
    pred = np.zeros(num_nodes)
    obs = np.zeros(num_nodes)
    np.add.at(pred, assignment, np.asarray(t_vm_pred, float))
    np.add.at(obs, assignment, np.asarray(obs_vm, float))
    exercised = pred > 1e-9
    out = np.array(cap_hat, float)
    if int(exercised.sum()) < 2:
        return out  # no cross-node baseline to compare against
    r = np.where(exercised, obs / np.maximum(pred, 1e-12), np.inf)
    base = float(np.min(r[exercised]))
    for e in range(num_nodes):
        if exercised[e]:
            tgt = min(max(base / max(r[e], 1e-12), 1e-3), 1.0)
            out[e] = (1.0 - ewma) * out[e] + ewma * tgt
    return out


def run_closed_loop(  # analyze: ok(TRC001,TRC002,TRC003): host serving loop; the jit boundary is violation_report/plan_fixed_partition inside
    fleet: Fleet,
    scenario: Scenario,
    schedule: FaultSchedule,
    planner: Planner,
    key,
    *,
    requests_per_step: int = 64,
    guarded: bool = True,
    guard: Optional[GuardConfig] = None,
    dist: str = "gamma",
) -> ClosedLoopResult:
    """Drive ``schedule.steps`` steps of faulted serving; see module doc."""
    if guard is None:
        guard = GuardConfig()
    sc = Scenario(*scenario).normalized(fleet.num_devices)
    n = fleet.num_devices
    eps_scalar = float(np.asarray(sc.eps).mean())
    cap_np = np.asarray(sc.edge_capacity_s)
    multi_node = cap_np.ndim == 1  # per-node capacities (DESIGN.md §placement)
    cap_arg = None if np.all(np.isinf(cap_np)) else sc.edge_capacity_s

    plan = planner.plan(fleet, sc)
    contingencies = contingency_plans(
        fleet, sc.deadline, sc.eps, sc.B, cap_arg,
        sigma_inflation=guard.sigma_inflation) if guarded else {}
    sentinel = ViolationSentinel(eps_scalar, guard.sentinel)

    loc_hat = vm_hat = 1.0  # per-tier time-scale estimates (re-fit moments)
    # per-node capacity-scale estimates (multi-node edge only): the
    # ladder re-plans against caps × ĉ, so a degraded node looks small
    # to the allocator and the hybrid strategy migrates its devices
    cap_hat = np.ones(cap_np.shape[0]) if multi_node else None
    rung = RUNG_NONE
    last_action = -(10**9)
    replans = churn = migrations = 0
    first_trip: Optional[int] = None
    recovery: Optional[int] = None

    steps = schedule.steps
    step_rate = np.zeros(steps)
    window_rate = np.full(steps, np.nan)
    tripped_log = np.zeros(steps, bool)
    rung_log = np.zeros(steps, np.int32)
    energy_log = np.zeros(steps)

    for t in range(steps):
        state = state_at(schedule, t)
        vr = violation_report(
            jax.random.fold_in(key, t), fleet, plan.m_sel, plan.alloc,
            sc.deadline, dist=dist, num_samples=requests_per_step,
            edge_capacity_s=cap_arg, faults=state,
            assignment=plan.assignment if multi_node else None)
        rates = np.asarray(vr.rate)
        k = int(round(float(rates.sum()) * requests_per_step))
        sentinel.observe(k, requests_per_step * n)

        # observable-only moment re-fit (never peeks at `state`)
        t_loc, _t_off, t_vm = _predicted_components(fleet, plan)
        loc_hat, vm_hat = _refit_scales(
            loc_hat, vm_hat, t_loc, t_vm,
            np.asarray(vr.mean_local, float), np.asarray(vr.mean_vm, float),
            guard.ewma)
        if multi_node:
            cap_hat = _refit_node_scales(
                cap_hat, t_vm, np.asarray(vr.mean_vm, float),
                np.asarray(plan.assignment), cap_np.shape[0], guard.ewma)

        trip = sentinel.tripped()
        step_rate[t] = float(rates.mean())
        window_rate[t] = sentinel.rate()
        tripped_log[t] = trip
        if trip and first_trip is None:
            first_trip = t

        if guarded and trip and t - last_action >= guard.cooldown:
            last_action = t
            rung = min(rung + 1, guard.max_rung)
            fleet_hat = apply_faults(fleet, _refit_state(loc_hat, vm_hat))
            if multi_node:
                # re-plan against the re-fit capacities: a degraded node
                # looks small, so the allocator migrates its devices
                cap_fit = sc.edge_capacity_s * jnp.asarray(cap_hat)
                sc_fit = sc._replace(edge_capacity_s=cap_fit)
            else:
                cap_fit, sc_fit = cap_arg, sc
            if rung == RUNG_PRICE:
                new = plan_fixed_partition(
                    fleet_hat, plan.m_sel, sc.deadline, sc.eps, sc.B, cap_fit)
            elif rung == RUNG_REPLAN:
                new = planner.plan(fleet_hat, sc_fit, init_m=plan.m_sel,
                                   incumbent=plan)
            else:
                new = pick_contingency(contingencies, fleet_hat, sc.deadline,
                                       sc.eps, incumbent=plan)
            churn += int(np.sum(np.asarray(new.m_sel) != np.asarray(plan.m_sel)))
            if multi_node:
                migrations += int(assignment_churn(plan.assignment,
                                                   new.assignment))
            replans += 1
            plan = new
            sentinel.reset()  # the new plan starts with a clean record
        elif rung > RUNG_NONE and not trip and \
                sentinel.counts[1] >= guard.sentinel.min_count:
            # a full clean window de-escalates: the next trip starts the
            # ladder from the cheap rung again
            rung = RUNG_NONE

        if first_trip is not None and recovery is None and t > first_trip \
                and sentinel.counts[1] >= guard.sentinel.min_count \
                and sentinel.rate() <= eps_scalar:
            recovery = t - first_trip

        rung_log[t] = rung
        energy_log[t] = float(plan.total_energy)

    return ClosedLoopResult(
        step_rate=step_rate, window_rate=window_rate, tripped=tripped_log,
        rung=rung_log, energy=energy_log, replans=replans, churn=churn,
        first_trip_step=first_trip, recovery_steps=recovery,
        migrations=migrations)
