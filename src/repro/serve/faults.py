"""Composable, seeded fault injection for the serving loop (DESIGN.md
§robustness).

The planner's guarantee P{T ≤ D} ≥ 1−ε holds *for the moments it was
planned against*. This module makes the ways those moments go stale
first-class, so the closed-loop harness and the MC validator can be
driven through reproducible incidents:

- **moment drift** — slow time-varying scaling of the mean/variance of
  local and VM block times (thermal throttling, co-tenant load creep);
- **straggler bursts** — episodes where a fraction of VM executions pick
  up a heavy-tailed (moment-matched Pareto) extra latency (the Fig. 1/5
  spikes of the paper, but *clustered in time*);
- **channel fades** — multiplicative dips in the uplink gain;
- **edge-capacity brownouts** — the shared accelerator's VM-time budget
  shrinks for a window (maintenance, preemption by a higher tier);
- **per-node faults** (DESIGN.md §placement) — on a multi-node edge the
  capacity scale generalizes from a scalar to an ``(E,)`` vector:
  ``brownout(node=e)`` fades ONE node's budget and :func:`node_failure`
  zeroes it outright (capacity 0 ⇒ absent node, so the placement layer
  must migrate that node's devices). Scalar profiles stay the default
  and are bit-identical to the pre-per-node code paths.

Everything is a pure pytree of traced leaves:

- :class:`FaultState` — the fault intensities at ONE step (what
  ``montecarlo.violation_report(faults=...)`` consumes);
- :class:`FaultSchedule` — per-step dense profiles over a horizon of T
  steps (every leaf is ``(T,)``), built by the constructors below and
  combined with :func:`compose`. ``random_bursts`` is seeded by an
  explicit PRNG key, so a schedule is deterministic given ``(args, key)``.

Layering: ``core.montecarlo`` duck-types the :class:`FaultState` fields
(it never imports this module), so ``serve → core`` stays one-way.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.blocks import Fleet

__all__ = [
    "FaultState", "FaultSchedule", "identity_schedule", "moment_drift",
    "straggler_burst", "random_bursts", "channel_fade", "brownout",
    "node_failure", "compose", "state_at", "apply_faults",
    "faulted_capacity",
]


class FaultState(NamedTuple):
    """Fault intensities at one step. All leaves are scalars (or ``(N,)``
    per-device arrays — every consumer broadcasts).

    Scales multiply the *nominal* quantity; the identity state (all
    scales 1, straggler probability 0) is a bit-exact no-op in
    ``violation_report`` and :func:`apply_faults`.
    """

    loc_mean_scale: jnp.ndarray  # × mean local block time (via 1/g_eff)
    loc_var_scale: jnp.ndarray   # × local time variance
    vm_mean_scale: jnp.ndarray   # × mean VM time
    vm_var_scale: jnp.ndarray    # × VM time variance
    gain_scale: jnp.ndarray      # × uplink channel gain (fade < 1)
    #: × shared-edge capacity (brownout < 1). Scalar for the single
    #: shared edge; an ``(E,)`` vector fades per NODE on a multi-node
    #: edge (DESIGN.md §placement) — 0 marks a failed/absent node.
    cap_scale: jnp.ndarray
    straggler_prob: jnp.ndarray  # P{a VM execution straggles}
    straggler_extra_s: jnp.ndarray  # mean extra latency of a straggler
    straggler_cv: jnp.ndarray    # cv of the (Pareto) straggler extra

    @classmethod
    def identity(cls) -> "FaultState":
        one = jnp.asarray(1.0, jnp.float64)
        zero = jnp.asarray(0.0, jnp.float64)
        return cls(one, one, one, one, one, one, zero, zero, one)

    @property
    def edge_scale(self) -> jnp.ndarray:
        """Alias for :attr:`cap_scale` — the edge-capacity fade, scalar
        or per-node ``(E,)``."""
        return self.cap_scale


class FaultSchedule(NamedTuple):
    """A :class:`FaultState` per step: every leaf is a dense ``(T,)``
    profile. Index with :func:`state_at`; combine with :func:`compose`."""

    loc_mean_scale: jnp.ndarray
    loc_var_scale: jnp.ndarray
    vm_mean_scale: jnp.ndarray
    vm_var_scale: jnp.ndarray
    gain_scale: jnp.ndarray
    cap_scale: jnp.ndarray
    straggler_prob: jnp.ndarray
    straggler_extra_s: jnp.ndarray
    straggler_cv: jnp.ndarray

    @property
    def steps(self) -> int:
        return self.vm_mean_scale.shape[0]

    @property
    def edge_scale(self) -> jnp.ndarray:
        """Alias for :attr:`cap_scale` — ``(T,)`` for the single shared
        edge, ``(T, E)`` for per-node fades."""
        return self.cap_scale


def _full(steps: int, value: float) -> jnp.ndarray:
    return jnp.full((steps,), value, jnp.float64)


def identity_schedule(steps: int) -> FaultSchedule:
    """The no-fault schedule: every step is the identity state."""
    one, zero = _full(steps, 1.0), _full(steps, 0.0)
    return FaultSchedule(one, one, one, one, one, one, zero, zero,
                         _full(steps, 1.0))


def _window(steps: int, start: int, length: int) -> jnp.ndarray:
    t = jnp.arange(steps)
    return (t >= start) & (t < start + length)


def moment_drift(steps: int, *, onset: int = 0, vm_ramp: float = 0.0,
                 loc_ramp: float = 0.0, vm_var_ramp: float = None,
                 loc_var_ramp: float = None,
                 ramp_steps: int = None) -> FaultSchedule:
    """Linear moment drift: the mean scale ramps from 1 at ``onset`` to
    ``1 + ramp`` over ``ramp_steps`` steps (default: the rest of the
    horizon) and then *holds* — a plateau models sustained degradation
    (thermal throttling, a co-tenant that stays). Variance ramps default
    to the time-dilation model (var scale = mean scale², i.e. the
    *relative* dispersion is preserved while everything slows down)."""
    t = jnp.arange(steps, dtype=jnp.float64)
    span = max(steps - 1 - onset, 1) if ramp_steps is None else max(ramp_steps, 1)
    frac = jnp.clip((t - onset) / span, 0.0, 1.0)
    vm_mean = 1.0 + vm_ramp * frac
    loc_mean = 1.0 + loc_ramp * frac
    vm_var = vm_mean**2 if vm_var_ramp is None else 1.0 + vm_var_ramp * frac
    loc_var = loc_mean**2 if loc_var_ramp is None else 1.0 + loc_var_ramp * frac
    base = identity_schedule(steps)
    return base._replace(vm_mean_scale=vm_mean, vm_var_scale=vm_var,
                         loc_mean_scale=loc_mean, loc_var_scale=loc_var)


def straggler_burst(steps: int, *, start: int, length: int, prob: float,
                    extra_s: float, cv: float = 1.0) -> FaultSchedule:
    """A straggler episode: inside ``[start, start+length)`` each VM
    execution independently picks up a heavy-tailed extra latency with
    probability ``prob`` (mean ``extra_s``, coefficient of variation
    ``cv``, moment-matched Pareto)."""
    w = _window(steps, start, length)
    base = identity_schedule(steps)
    return base._replace(
        straggler_prob=jnp.where(w, prob, 0.0),
        straggler_extra_s=jnp.where(w, extra_s, 0.0),
        straggler_cv=jnp.where(w, cv, 1.0),
    )


def random_bursts(steps: int, key, *, burst_prob: float = 0.05,
                  length: int = 4, prob: float = 0.3, extra_s: float = 0.2,
                  cv: float = 1.0) -> FaultSchedule:
    """Seeded straggler episodes: each step starts a ``length``-step
    burst with probability ``burst_prob``. Deterministic given ``key``."""
    starts = jax.random.bernoulli(key, burst_prob, (steps,))
    active = jnp.convolve(starts.astype(jnp.float64),
                          jnp.ones((length,), jnp.float64))[:steps] > 0
    base = identity_schedule(steps)
    return base._replace(
        straggler_prob=jnp.where(active, prob, 0.0),
        straggler_extra_s=jnp.where(active, extra_s, 0.0),
        straggler_cv=jnp.where(active, cv, 1.0),
    )


def channel_fade(steps: int, *, start: int, length: int,
                 depth: float) -> FaultSchedule:
    """Uplink gain dips to ``depth`` × nominal inside the window."""
    w = _window(steps, start, length)
    return identity_schedule(steps)._replace(
        gain_scale=jnp.where(w, depth, 1.0))


def brownout(steps: int, *, start: int, length: int, depth: float,
             node: int = None, num_nodes: int = None) -> FaultSchedule:
    """Shared-edge capacity shrinks to ``depth`` × nominal in the window.

    ``node=None`` (default) fades the single shared-edge budget — the
    scalar ``(T,)`` profile, bit-identical to the pre-per-node path.
    ``node=e`` (with ``num_nodes=E``) fades only node ``e`` of a
    multi-node edge: ``cap_scale`` becomes ``(T, E)``, columns other
    than ``e`` stay 1, and :func:`state_at` yields ``(E,)`` states that
    multiply elementwise into an ``(E,)`` ``Scenario.edge_capacity_s``.
    """
    w = _window(steps, start, length)
    if node is None:
        return identity_schedule(steps)._replace(
            cap_scale=jnp.where(w, depth, 1.0))
    if num_nodes is None:
        raise ValueError("brownout(node=...) needs num_nodes=E")
    if not 0 <= node < num_nodes:
        raise ValueError(
            f"node must lie in [0, {num_nodes}), got {node}")
    col = jnp.arange(num_nodes) == node
    cap = jnp.where(w[:, None] & col[None, :], depth, 1.0)
    return identity_schedule(steps)._replace(cap_scale=cap)


def node_failure(steps: int, *, node: int, num_nodes: int, start: int,
                 length: int = None) -> FaultSchedule:
    """Hard failure of one edge node: its capacity drops to **0** —
    the placement layer's absent-node convention (DESIGN.md §placement),
    so every device assigned there congests unboundedly until the
    ladder migrates it. ``length=None`` fails the node for the rest of
    the horizon (crash-stop, no recovery)."""
    if length is None:
        length = steps - start
    return brownout(steps, start=start, length=length, depth=0.0,
                    node=node, num_nodes=num_nodes)


def _compose_caps(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Multiply capacity profiles, unioning per-node windows: a scalar
    ``(T,)`` profile broadcasts over every node of a ``(T, E)`` one (a
    whole-edge brownout fades ALL nodes), and two ``(T, E)`` profiles
    must agree on E."""
    if a.ndim == b.ndim:
        if a.ndim == 2 and a.shape[1] != b.shape[1]:
            raise ValueError(
                f"per-node cap profiles must share a node count: "
                f"{a.shape[1]} != {b.shape[1]}")
        return a * b
    if a.ndim < b.ndim:
        a = a[:, None]
    else:
        b = b[:, None]
    return a * b


def compose(*schedules: FaultSchedule) -> FaultSchedule:
    """Combine schedules: scales multiply; straggler episodes combine as
    independent events (p = 1 − Π(1−pᵢ)) with the probability-weighted
    mean extra and the max cv. Capacity profiles union per node: a
    scalar profile fades every node of an ``(E,)``-wide one."""
    if not schedules:
        raise ValueError("compose needs at least one schedule")
    steps = schedules[0].steps
    for s in schedules[1:]:
        if s.steps != steps:
            raise ValueError(
                f"schedules must share a horizon: {s.steps} != {steps}")
    out = schedules[0]
    for s in schedules[1:]:
        p = 1.0 - (1.0 - out.straggler_prob) * (1.0 - s.straggler_prob)
        weight = out.straggler_prob * out.straggler_extra_s \
            + s.straggler_prob * s.straggler_extra_s
        extra = jnp.where(p > 0, weight / jnp.maximum(p, 1e-12), 0.0)
        out = FaultSchedule(
            loc_mean_scale=out.loc_mean_scale * s.loc_mean_scale,
            loc_var_scale=out.loc_var_scale * s.loc_var_scale,
            vm_mean_scale=out.vm_mean_scale * s.vm_mean_scale,
            vm_var_scale=out.vm_var_scale * s.vm_var_scale,
            gain_scale=out.gain_scale * s.gain_scale,
            cap_scale=_compose_caps(out.cap_scale, s.cap_scale),
            straggler_prob=p,
            straggler_extra_s=extra,
            straggler_cv=jnp.maximum(out.straggler_cv, s.straggler_cv),
        )
    return out


def state_at(schedule: FaultSchedule, t) -> FaultState:
    """The :class:`FaultState` at step ``t`` (``t`` may be traced).

    Out-of-range steps clamp to the boundary states (jax gather
    semantics): ``t >= steps`` holds the final state — so a replay that
    outruns its schedule serves under the last fault regime, never a
    silently-reset identity — and ``t < 0`` is the first state.
    """
    t = jnp.clip(jnp.asarray(t), 0, schedule.steps - 1)
    return FaultState(*(jnp.asarray(leaf)[t] for leaf in schedule))


def apply_faults(fleet: Fleet, state: FaultState) -> Fleet:
    """The *ground-truth* fleet under ``state``: moment scales folded into
    the chain (mean local time scales via 1/g_eff, exactly as the MC
    sampler applies them) and the fade into the link gain. Stragglers and
    brownouts are runtime effects, not chain moments — they stay in the
    sampler/capacity. The identity state is a numerical no-op.

    Also the re-fit hook for the degradation ladder: feed an *estimated*
    state to get the fleet the re-planner should plan against.
    """
    c = fleet.chain
    chain = c._replace(
        t_vm=c.t_vm * state.vm_mean_scale,
        v_vm=c.v_vm * state.vm_var_scale,
        g_eff=c.g_eff / jnp.maximum(state.loc_mean_scale, 1e-12),
        v_loc=c.v_loc * state.loc_var_scale,
    )
    link = fleet.link._replace(gain=fleet.link.gain * state.gain_scale)
    return fleet._replace(chain=chain, link=link)


def faulted_capacity(edge_capacity_s, state: FaultState):
    """Shared-edge capacity under a brownout (``None`` stays ``None``).
    Per-node: an ``(E,)`` capacity vector × an ``(E,)`` (or scalar)
    ``cap_scale`` fades node-wise; a faded-to-0 node is *absent* by the
    placement convention."""
    if edge_capacity_s is None:
        return None
    return jnp.asarray(edge_capacity_s, jnp.float64) * state.cap_scale
