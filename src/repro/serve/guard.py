"""Violation sentinel + graceful-degradation support (DESIGN.md
§robustness).

The planner promises P{T ≤ D} ≥ 1−ε *for the moments it planned
against*. :class:`ViolationSentinel` watches the per-request deadline
outcome stream (from ``EngineStats`` or the MC closed-loop harness) and
flags when the empirical violation rate is *statistically inconsistent*
with ε — a one-sided exact binomial tail test over a sliding window, so
a handful of unlucky requests under a healthy plan does not trip it
(false-positive rate ≤ ``alpha`` per test by construction), while a
genuine moment shift trips within a window.

On a trip the degradation ladder escalates (``serve.closedloop`` runs
it): price-step re-allocation at the incumbent partition
(``core.plan_fixed_partition``) → warm-started full re-plan with re-fit
moments → precomputed contingency plans (:func:`contingency_plans` —
local-only and full-offload, solved *at plan time* with inflated σ, so
the last rung needs zero runtime solves).
"""
from __future__ import annotations

import math
from collections import deque
from dataclasses import dataclass
from typing import Dict, Optional

import jax.numpy as jnp

from repro.core import ccp, channel, energy
from repro.core.blocks import Fleet
from repro.core.planner import Plan, plan_fixed_partition, plan_health
from repro.core.resource import select_point

__all__ = [
    "SentinelConfig", "ViolationSentinel", "binom_tail_pvalue",
    "cantelli_pvalue", "contingency_plans", "inflated_eps", "plan_margin",
    "pick_contingency", "plan_health",
]


def binom_tail_pvalue(k: int, n: int, eps: float) -> float:
    """Exact one-sided tail P[Bin(n, ε) ≥ k] via log-Γ (host-side).

    The sentinel's test statistic: the probability of seeing ``k`` or
    more violations in ``n`` requests *if the plan were healthy* (true
    violation probability ≤ ε). Small p-value ⇒ the observed rate is
    inconsistent with the guarantee.
    """
    if n <= 0 or k <= 0:
        return 1.0
    if k > n:
        return 0.0
    if eps <= 0.0:
        return 0.0
    if eps >= 1.0:
        return 1.0
    log_eps, log_1m = math.log(eps), math.log1p(-eps)
    lgn = math.lgamma(n + 1)
    total = 0.0
    for i in range(k, n + 1):
        total += math.exp(lgn - math.lgamma(i + 1) - math.lgamma(n - i + 1)
                          + i * log_eps + (n - i) * log_1m)
    return min(total, 1.0)


def cantelli_pvalue(k: int, n: int, eps: float) -> float:
    """Cantelli (one-sided Chebyshev) bound on P[Bin(n, ε)/n ≥ k/n] — a
    distribution-light alternative to the exact tail, loose but O(1)."""
    if n <= 0 or k <= 0:
        return 1.0
    t = k / n - eps
    if t <= 0.0:
        return 1.0
    var = eps * (1.0 - eps) / n
    return var / (var + t * t)


@dataclass(frozen=True)
class SentinelConfig:
    """``window``: outcomes kept (a sliding count, oldest batches
    evicted whole); ``alpha``: per-test false-positive rate;
    ``min_count``: don't test before this many outcomes (tiny samples
    make the exact tail trigger-happy at small ε); ``test``:
    ``"binomial"`` (exact) or ``"cantelli"`` (bound)."""

    window: int = 2048
    alpha: float = 1e-3
    min_count: int = 128
    test: str = "binomial"

    def __post_init__(self):
        if self.window < 1:
            raise ValueError("window must be >= 1")
        if not 0.0 < self.alpha < 1.0:
            raise ValueError("alpha must be in (0, 1)")
        if self.test not in ("binomial", "cantelli"):
            raise ValueError(f"unknown sentinel test {self.test!r}")


class ViolationSentinel:
    """Sliding-window monitor over per-request deadline outcomes."""

    def __init__(self, eps: float, config: Optional[SentinelConfig] = None):
        if not 0.0 < eps < 1.0:
            raise ValueError(f"eps must be in (0, 1), got {eps}")
        self.eps = float(eps)
        self.config = config if config is not None else SentinelConfig()
        self._batches: deque = deque()  # (violations, total) pairs
        self._k = 0
        self._n = 0

    def observe(self, violations: int, total: int = 1) -> None:  # analyze: ok(TRC003): sentinel counts are host python ints by contract
        """Feed a batch of outcomes (``violations`` of ``total`` requests
        missed their deadline)."""
        if total < 0 or not 0 <= violations <= total:
            raise ValueError(
                f"need 0 <= violations <= total, got {violations}/{total}")
        self._batches.append((violations, total))
        self._k += violations
        self._n += total
        while self._n - self._batches[0][1] >= self.config.window:
            k0, n0 = self._batches.popleft()
            self._k -= k0
            self._n -= n0

    def observe_outcomes(self, met_flags) -> None:
        """Feed a batch of per-request deadline outcomes as *met?* bools
        — the shape ``EngineStats.deadline_flags`` (and each replay
        window of it) records. An empty batch is a no-op."""
        flags = [bool(f) for f in met_flags]
        if flags:
            self.observe(sum(1 for f in flags if not f), len(flags))

    @property
    def counts(self):
        return self._k, self._n

    def rate(self) -> float:
        return self._k / self._n if self._n else float("nan")

    def pvalue(self) -> float:
        test = (binom_tail_pvalue if self.config.test == "binomial"
                else cantelli_pvalue)
        return test(self._k, self._n, self.eps)

    def tripped(self) -> bool:
        if self._n < self.config.min_count:
            return False
        return self.pvalue() < self.config.alpha

    def reset(self) -> None:
        """Forget the window (call after installing a new plan, so the
        old plan's violations don't indict the new one)."""
        self._batches.clear()
        self._k = 0
        self._n = 0


# ---------------------------------------------------------------------------
# Degradation-ladder building blocks
# ---------------------------------------------------------------------------


def inflated_eps(eps, sigma_inflation: float):
    """ε′ whose Cantelli σ is ``sigma_inflation`` × the nominal one:
    σ(ε) = √((1−ε)/ε) ⇒ ε′ = 1/(1 + inflation²·(1−ε)/ε). Contingency
    plans solved at ε′ keep a deliberate safety margin over the SLO."""
    s2 = sigma_inflation**2 * (1.0 - eps) / eps
    return 1.0 / (1.0 + s2)


def contingency_plans(fleet: Fleet, deadline, eps, B, edge_capacity_s=None,
                      sigma_inflation: float = 1.5) -> Dict[str, Plan]:
    """The ladder's last rung, precomputed at plan time: ``local_only``
    (m = M_n — no offload, immune to edge/channel faults) and
    ``full_offload`` (m = 0 — no local compute, immune to device-side
    drift), each allocated with σ inflated by ``sigma_inflation`` so
    they keep slack when moments have already shifted. Zero runtime
    solves: on a trip the better of the two is *selected*, not solved.
    """
    eps_c = inflated_eps(jnp.asarray(eps, jnp.float64), sigma_inflation)
    local_m = fleet.points_per_device - 1
    return {
        "local_only": plan_fixed_partition(
            fleet, local_m, deadline, eps_c, B, edge_capacity_s),
        "full_offload": plan_fixed_partition(
            fleet, jnp.zeros((fleet.num_devices,), jnp.int32), deadline,
            eps_c, B, edge_capacity_s),
    }


def plan_margin(fleet: Fleet, plan: Plan, deadline, eps,
                sigma_model: str = "cantelli") -> jnp.ndarray:
    """Worst-device deadline margin of ``plan`` evaluated on ``fleet``
    (closed form — no solves). Evaluate a precomputed plan against a
    *re-fit* fleet to pick the contingency that degrades least."""
    sel = select_point(fleet, plan.m_sel)
    t_mean = (
        energy.mean_local_time(sel.w_flops, sel.g_eff, plan.alloc.f)
        + channel.offload_time(sel.d_bits, plan.alloc.b, fleet.link.p_tx,
                               fleet.link.gain)
        + sel.t_vm
    )
    margins = ccp.deterministic_deadline_margin(
        t_mean, sel.v_loc + sel.v_vm, eps, deadline, sigma_model)
    return jnp.max(margins)


def pick_contingency(plans: Dict[str, Plan], fleet: Fleet, deadline,
                     eps, incumbent: Optional[Plan] = None) -> Plan:
    """Select the candidate with the smallest worst-device margin on the
    (re-fit) ``fleet`` — pure evaluation, no solver in the loop. The
    ``incumbent`` competes under the same test: when every precomputed
    shape degrades *more* than the current plan (e.g. the fleet cannot
    serve local-only within the deadline at all), the right contingency
    is to keep what we have, not to install a known-worse plan."""
    candidates = dict(plans)
    if incumbent is not None:
        candidates["incumbent"] = incumbent
    scored = {name: float(plan_margin(fleet, p, deadline, eps))  # analyze: ok(TRC001): host selection over a handful of precomputed plans
              for name, p in candidates.items()}
    best = min(scored, key=lambda name: (scored[name], name))
    return candidates[best]
