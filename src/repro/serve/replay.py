"""Trace-driven workload replay (DESIGN.md §robustness).

The step-driven closed loop (``serve.closedloop``) validates the ladder
against hand-picked incidents; this module replays *traffic*. A seeded
:class:`Trace` — Poisson, diurnal, or bursty arrivals over a fleet, with
per-population job mixes — is served epoch by epoch through the same
controller stack: every request's ground-truth latency is sampled from
the faulted moment model (request-granular mirror of
``montecarlo.violation_report``), completions stream into
:class:`~repro.serve.engine.EngineStats`, the binomial-tail sentinel
watches the per-epoch windows, and on a trip the degradation ladder
escalates exactly as in the step harness (price step → warm re-plan →
contingency).

What the replay adds over the step harness:

- **event-driven load** — per-epoch request counts follow the arrival
  process, so shared-edge congestion tracks *demand*, not one
  request/device/round: a burst congests, a lull relaxes;
- **per-node faults + migration** — on a multi-node edge the
  observable-only per-node capacity re-fit
  (``closedloop._refit_node_scales``) shrinks a degraded node's
  estimated budget, so the ladder's re-plan re-runs the ``hybrid``
  allocator and *migrates* that node's devices; churn and the energy of
  each migration (one extra upload of the offload payload,
  t_off·p_tx) are metered;
- **regret vs oracle** — :func:`replay` with ``oracle=True`` re-plans
  each epoch against the *true* faulted fleet and capacity (it reads
  the schedule the controller never sees); :func:`regret_curves` turns
  a paired (actual, oracle) run into cumulative energy/violation regret
  per epoch;
- **engine-backed mode** — :func:`replay_engine` drives the *real*
  :class:`~repro.serve.engine.ServingEngine` through a trace, window
  per epoch, and re-profiles the edge-tier chain from observed decode
  completions via ``partitioned.measured_chain`` (the §IV online path),
  which is exactly the measurement the EWMA re-fit consumes.

Queueing is out of scope: a request's latency is its *service* time
under the epoch's fault state and congestion level, scored against the
scenario SLO — the same contract the planner's guarantee covers.

One compiled program serves the whole trace: per-epoch request batches
are padded to the trace's static ``capacity`` (power-of-two bucket of
the max per-epoch arrivals) with a traced ``valid`` mask and traced
``device_ids``, so value-varied epochs — different counts, different
devices, different fault states — never recompile
(``replay_recompile_drill`` in ``make analyze`` pins this).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import channel, energy
from repro.core.api import Planner, Scenario
from repro.core.blocks import Fleet
from repro.core.montecarlo import _sample_matched
from repro.core.placement import assignment_churn, migration_energy
from repro.core.planner import plan_fixed_partition
from repro.core.resource import Allocation, select_point
from repro.serve.closedloop import (
    GuardConfig,
    RUNG_NONE,
    RUNG_PRICE,
    RUNG_REPLAN,
    _predicted_components,
    _refit_node_scales,
    _refit_scales,
    _refit_state,
)
from repro.serve.engine import EngineStats, Request, ServingEngine
from repro.serve.faults import (
    FaultSchedule,
    apply_faults,
    faulted_capacity,
    state_at,
)
from repro.serve.guard import ViolationSentinel, contingency_plans, pick_contingency
from repro.serve.partitioned import measured_chain

__all__ = [
    "Trace", "poisson_trace", "diurnal_trace", "bursty_trace",
    "population_mix", "EpochSample", "sample_epoch", "ReplayResult",
    "replay", "regret_curves", "replay_engine",
]


# ---------------------------------------------------------------------------
# Traces: seeded arrival processes (host-side numpy — trace *construction*
# is data prep, not compiled work; the replay consumes it in static-shape
# padded slices)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Trace:
    """A reproducible request trace over a fleet.

    ``arrival_s`` is sorted; ``device_id[r]`` is the device request ``r``
    lands on (the job mix — per-population weights — is folded in at
    construction). ``nominal_per_epoch`` is the *design-rate* mean
    arrivals per epoch: the congestion normalizer, so an epoch at
    nominal load congests the shared edge exactly as one
    request/device/round does in ``violation_report``.
    """

    kind: str
    epoch_s: float
    epochs: int
    nominal_per_epoch: float
    arrival_s: np.ndarray  # (R,) float64, sorted
    device_id: np.ndarray  # (R,) int32

    @property
    def num_requests(self) -> int:
        return int(self.arrival_s.shape[0])

    def epoch_bounds(self) -> np.ndarray:
        """(epochs+1,) request-index offsets of each epoch's slice."""
        edges = np.arange(self.epochs + 1) * self.epoch_s
        return np.searchsorted(self.arrival_s, edges, side="left")

    @property
    def max_per_epoch(self) -> int:
        b = self.epoch_bounds()
        return int(np.max(b[1:] - b[:-1])) if self.epochs else 0

    @property
    def capacity(self) -> int:
        """Static padded batch width: the power-of-two bucket of the max
        per-epoch arrival count — ONE compiled epoch program per trace."""
        return 1 << max(self.max_per_epoch - 1, 0).bit_length()


def population_mix(pop_counts, pop_weights) -> np.ndarray:
    """Per-device sampling probabilities from a per-population job mix.

    ``pop_weights[g]`` is the share of *traffic* population ``g``
    receives (normalized here); inside a population the load spreads
    uniformly over its ``pop_counts[g]`` devices. Device order follows
    the fleet-builder convention: populations concatenated in order.
    """
    probs = []
    for c, w in zip(pop_counts, pop_weights, strict=True):
        if c <= 0:
            raise ValueError(f"population counts must be positive, got {c}")
        if w < 0:
            raise ValueError(f"mix weights must be >= 0, got {w}")
        probs += [w / c] * c
    p = np.asarray(probs, float)
    total = p.sum()
    if total <= 0:
        raise ValueError("job mix needs at least one positive weight")
    return p / total


def _materialize(kind: str, lam: np.ndarray, epoch_s: float,
                 num_devices: int, rng, device_weights,
                 nominal: float) -> Trace:
    counts = rng.poisson(np.maximum(lam, 0.0))
    chunks, devs = [], []
    for t, c in enumerate(counts):
        if c == 0:
            continue
        chunks.append(t * epoch_s + np.sort(rng.uniform(0.0, epoch_s, int(c))))
        devs.append(rng.choice(num_devices, size=int(c), p=device_weights))
    arrival = np.concatenate(chunks) if chunks else np.zeros((0,))
    device = (np.concatenate(devs) if devs else np.zeros((0,))).astype(np.int32)
    return Trace(kind=kind, epoch_s=float(epoch_s), epochs=len(counts),
                 nominal_per_epoch=float(nominal),
                 arrival_s=arrival, device_id=device)


def poisson_trace(*, rate_per_epoch: float, epochs: int, epoch_s: float,
                  num_devices: int, seed: int,
                  device_weights=None) -> Trace:
    """Homogeneous Poisson arrivals: ``rate_per_epoch`` mean requests per
    epoch across the fleet, deterministic given ``seed``."""
    rng = np.random.default_rng(seed)
    lam = np.full(epochs, float(rate_per_epoch))
    return _materialize("poisson", lam, epoch_s, num_devices, rng,
                        device_weights, rate_per_epoch)


def diurnal_trace(*, rate_per_epoch: float, epochs: int, epoch_s: float,
                  num_devices: int, seed: int, swing: float = 0.6,
                  period_epochs: Optional[int] = None,
                  device_weights=None) -> Trace:
    """Sinusoidally modulated Poisson arrivals: λ_t = λ·(1 + swing·
    sin(2πt/period)) — the day/night cycle, one period over the horizon
    by default. ``nominal_per_epoch`` stays the mean λ."""
    if not 0.0 <= swing <= 1.0:
        raise ValueError(f"swing must lie in [0, 1], got {swing}")
    rng = np.random.default_rng(seed)
    period = epochs if period_epochs is None else period_epochs
    t = np.arange(epochs, dtype=float)
    lam = rate_per_epoch * (1.0 + swing * np.sin(2.0 * np.pi * t / max(period, 1)))
    return _materialize("diurnal", lam, epoch_s, num_devices, rng,
                        device_weights, rate_per_epoch)


def bursty_trace(*, rate_per_epoch: float, epochs: int, epoch_s: float,
                 num_devices: int, seed: int, burst_factor: float = 4.0,
                 p_enter: float = 0.1, p_exit: float = 0.4,
                 device_weights=None) -> Trace:
    """Markov-modulated Poisson arrivals: a 2-state chain (calm/burst)
    flips with ``p_enter``/``p_exit`` per epoch; the burst state
    multiplies the rate by ``burst_factor``. ``nominal_per_epoch`` stays
    the *calm* rate, so a burst genuinely congests the shared edge."""
    rng = np.random.default_rng(seed)
    lam = np.empty(epochs)
    burst = False
    for t in range(epochs):
        burst = (rng.random() < p_enter) if not burst \
            else not (rng.random() < p_exit)
        lam[t] = rate_per_epoch * (burst_factor if burst else 1.0)
    return _materialize("bursty", lam, epoch_s, num_devices, rng,
                        device_weights, rate_per_epoch)


# ---------------------------------------------------------------------------
# The compiled epoch: request-granular faulted ground truth
# ---------------------------------------------------------------------------


class EpochSample(NamedTuple):
    """One epoch's sampled ground truth (padded to the trace capacity)."""

    total_s: jnp.ndarray  # (R,) per-request end-to-end latency
    met: jnp.ndarray      # (R,) bool — deadline met (padded slots: don't read)
    energy_j: jnp.ndarray  # scalar — Σ planned per-request energy served
    obs_local: jnp.ndarray  # (N,) Σ sampled local time per device
    obs_vm: jnp.ndarray     # (N,) Σ sampled VM time (incl. extras) per device
    count: jnp.ndarray      # (N,) requests served per device


@partial(jax.jit, static_argnames=("dist",))
def sample_epoch(
    key,
    fleet: Fleet,
    m_sel: jnp.ndarray,
    alloc: Allocation,
    deadline: jnp.ndarray,
    device_ids: jnp.ndarray,
    valid: jnp.ndarray,
    rounds,
    dist: str = "gamma",
    var_scale: float = 0.8,
    edge_capacity_s=None,
    faults=None,
    assignment=None,
) -> EpochSample:
    """Sample one epoch of request latencies from the faulted ground
    truth — the request-granular mirror of ``violation_report``.

    ``device_ids``/``valid`` are the epoch's padded request batch
    (traced, static ``(R,)`` capacity — value-varied epochs share one
    program). Per-device moments are faulted exactly as the MC
    validator faults them; shared-edge congestion is **demand-driven**:
    node e's occupancy is Σ over this epoch's requests of t̄_vm,
    normalized by ``rounds`` (the design-rate requests/device/epoch), so
    nominal load reproduces ``violation_report``'s slow factor and a
    burst stretches it. Per-device observed tier sums come back for the
    EWMA re-fit — the same observables a partitioned stack measures.
    """
    sel = select_point(fleet, m_sel)
    gain = fleet.link.gain
    if faults is not None:
        sel = sel._replace(
            t_vm=sel.t_vm * faults.vm_mean_scale,
            v_vm=sel.v_vm * faults.vm_var_scale,
            g_eff=sel.g_eff / jnp.maximum(faults.loc_mean_scale, 1e-12),
            v_loc=sel.v_loc * faults.loc_var_scale,
        )
        gain = gain * faults.gain_scale
    n = m_sel.shape[0]
    dev = jnp.asarray(device_ids, jnp.int32)
    v = jnp.asarray(valid)
    vf = v.astype(jnp.float64)
    count = jax.ops.segment_sum(vf, dev, num_segments=n)

    if edge_capacity_s is not None:
        cap = jnp.asarray(edge_capacity_s, jnp.float64)
        if faults is not None:
            cap = cap * faults.cap_scale
        demand = count * sel.t_vm / jnp.maximum(rounds, 1e-9)
        if cap.ndim == 0:
            slow = jnp.maximum(1.0, jnp.sum(demand) / jnp.maximum(cap, 1e-30))
        else:
            if assignment is None:
                raise ValueError(
                    "a per-node edge_capacity_s vector needs the plan's "
                    "device→node assignment (pass assignment=plan.assignment)")
            a = jnp.asarray(assignment, jnp.int32)
            occ_e = jax.ops.segment_sum(demand, a, num_segments=cap.shape[0])
            slow_e = jnp.maximum(1.0, occ_e / jnp.maximum(cap, 1e-30))
            slow = slow_e[a]
        sel = sel._replace(t_vm=sel.t_vm * slow, v_vm=sel.v_vm * slow**2)

    mean_loc = energy.mean_local_time(sel.w_flops, sel.g_eff, alloc.f)
    t_off = channel.offload_time(sel.d_bits, alloc.b, fleet.link.p_tx, gain)
    shape = dev.shape
    k_loc, k_vm = jax.random.split(key, 2)
    t_loc_r = jnp.where(
        sel.w_flops[dev] > 0,
        _sample_matched(k_loc, dist, mean_loc[dev],
                        var_scale * sel.v_loc[dev], shape),
        0.0,
    )
    t_vm_r = jnp.where(
        sel.t_vm[dev] > 0,
        _sample_matched(k_vm, dist, sel.t_vm[dev],
                        var_scale * sel.v_vm[dev], shape),
        0.0,
    )
    if faults is not None:
        # Straggler bursts, keyed exactly as violation_report keys them
        # (fold_in 0x57) so the fault taxonomy stays one seeded family.
        k_hit, k_extra = jax.random.split(jax.random.fold_in(key, 0x57), 2)
        p_straggle = jnp.clip(faults.straggler_prob, 0.0, 1.0)
        hit = jax.random.bernoulli(k_hit, p_straggle, shape)
        extra_mean = jnp.maximum(faults.straggler_extra_s, 1e-9)
        extra_var = (jnp.maximum(faults.straggler_cv, 1e-3) * extra_mean) ** 2
        extra = _sample_matched(k_extra, "pareto", extra_mean, extra_var, shape)
        t_vm_r = t_vm_r + jnp.where(hit & (sel.t_vm[dev] > 0), extra, 0.0)

    total = t_loc_r + t_off[dev] + t_vm_r
    deadline = jnp.broadcast_to(jnp.asarray(deadline, jnp.float64), (n,))
    e_req = alloc.e_loc + alloc.e_off
    return EpochSample(
        total_s=total,
        met=total <= deadline[dev],
        energy_j=jnp.sum(vf * e_req[dev]),
        obs_local=jax.ops.segment_sum(t_loc_r * vf, dev, num_segments=n),
        obs_vm=jax.ops.segment_sum(t_vm_r * vf, dev, num_segments=n),
        count=count,
    )


# ---------------------------------------------------------------------------
# The replay loop
# ---------------------------------------------------------------------------


@dataclass
class ReplayResult:
    """Per-epoch telemetry plus the ladder/migration headline scalars."""

    epoch_rate: np.ndarray  # (T,) epoch violation rate (NaN when idle)
    window_rate: np.ndarray  # (T,) sentinel sliding-window rate
    tripped: np.ndarray  # (T,) bool
    rung: np.ndarray  # (T,) ladder rung after the epoch
    energy_j: np.ndarray  # (T,) serving energy actually spent per epoch
    overhead_j: np.ndarray  # (T,) migration energy charged per epoch
    epoch_violations: np.ndarray  # (T,) int
    epoch_requests: np.ndarray  # (T,) int
    replans: int
    churn: int  # Σ hamming(m_sel) over installations
    migrations: int  # Σ devices whose node changed over installations
    migration_energy_j: float
    stats: EngineStats = field(default_factory=EngineStats)

    @property
    def final_window_rate(self) -> float:
        w = self.window_rate[~np.isnan(self.window_rate)]
        return float(w[-1]) if w.size else float("nan")

    @property
    def total_energy_j(self) -> float:
        """Serving + migration energy over the whole trace."""
        return float(self.energy_j.sum() + self.overhead_j.sum())

    @property
    def total_violations(self) -> int:
        return int(self.epoch_violations.sum())


def _record_epoch(stats: EngineStats, uid0: int, totals, met):  # analyze: ok(TRC001): host telemetry append; operands are materialized np slices
    """Bulk-append one epoch's completions to the engine-shaped outcome
    stream (same invariants as ``record_completion``; the met flags were
    already scored in-trace against the per-device SLO)."""
    stats.request_uids.extend(range(uid0, uid0 + len(totals)))
    stats.completion_times.extend(float(x) for x in totals)
    stats.deadline_flags.extend(bool(m) for m in met)


def _padded_batch(trace: Trace, bounds, t: int, capacity: int):  # analyze: ok(TRC001,TRC002): host trace slicing — the padded batch is built on host, consumed traced
    lo, hi = int(bounds[t]), int(bounds[t + 1])
    dev = np.zeros(capacity, np.int32)
    dev[: hi - lo] = trace.device_id[lo:hi]
    valid = np.zeros(capacity, bool)
    valid[: hi - lo] = True
    return dev, valid, hi - lo


def replay(  # analyze: ok(TRC001,TRC002,TRC003): host serving loop; the jit boundary is sample_epoch/plan_fixed_partition inside
    fleet: Fleet,
    scenario: Scenario,
    schedule: FaultSchedule,
    planner: Planner,
    trace: Trace,
    key,
    *,
    guarded: bool = True,
    guard: Optional[GuardConfig] = None,
    dist: str = "gamma",
    oracle: bool = False,
) -> ReplayResult:
    """Serve ``trace`` epoch by epoch under ``schedule``; see module doc.

    ``guarded=False`` freezes the initial plan (the A/B baseline);
    ``oracle=True`` replaces the sentinel+ladder with schedule-aware
    re-planning — each time the fault state changes, the oracle plans
    against the *true* faulted fleet and capacity (``apply_faults`` +
    ``faulted_capacity``), paying the same migration costs. An oracle
    run shares the trace and sample keys with the actual run, so
    :func:`regret_curves` is a paired comparison.
    """
    if guard is None:
        guard = GuardConfig()
    sc = Scenario(*scenario).normalized(fleet.num_devices)
    n = fleet.num_devices
    eps_scalar = float(np.asarray(sc.eps).mean())
    cap_np = np.asarray(sc.edge_capacity_s)
    multi_node = cap_np.ndim == 1
    cap_arg = None if np.all(np.isinf(cap_np)) else sc.edge_capacity_s
    rounds = max(trace.nominal_per_epoch / max(n, 1), 1e-9)
    capacity = trace.capacity
    bounds = trace.epoch_bounds()

    plan = planner.plan(fleet, sc)
    contingencies = contingency_plans(
        fleet, sc.deadline, sc.eps, sc.B, cap_arg,
        sigma_inflation=guard.sigma_inflation) if guarded and not oracle else {}
    sentinel = ViolationSentinel(eps_scalar, guard.sentinel)
    stats = EngineStats()

    loc_hat = vm_hat = 1.0
    cap_hat = np.ones(cap_np.shape[0]) if multi_node else None
    rung = RUNG_NONE
    last_action = -(10**9)
    replans = churn = migrations = 0
    mig_energy = 0.0
    last_oracle_state = None

    T = trace.epochs
    epoch_rate = np.full(T, np.nan)
    window_rate = np.full(T, np.nan)
    tripped_log = np.zeros(T, bool)
    rung_log = np.zeros(T, np.int32)
    energy_log = np.zeros(T)
    overhead_log = np.zeros(T)
    viol_log = np.zeros(T, np.int64)
    req_log = np.zeros(T, np.int64)

    def _install(new, t):
        nonlocal plan, replans, churn, migrations, mig_energy
        churn += int(np.sum(np.asarray(new.m_sel) != np.asarray(plan.m_sel)))
        if multi_node:
            moved = int(assignment_churn(plan.assignment, new.assignment))
            migrations += moved
            if moved:
                # re-establishing a migrated session re-uploads the
                # offload payload once at the incumbent partition
                _tl, t_off, _tv = _predicted_components(fleet, plan)
                e_mig = t_off * np.asarray(fleet.link.p_tx, float)
                delta = float(migration_energy(
                    plan.assignment, new.assignment, e_mig))
                mig_energy += delta
                overhead_log[t] += delta
        replans += 1
        plan = new

    for t in range(T):
        state = state_at(schedule, t)
        if oracle:
            # schedule-aware: re-plan whenever the true fault state moves
            leaves = [np.asarray(x) for x in state]
            if last_oracle_state is None or not all(
                    np.array_equal(a, b)
                    for a, b in zip(leaves, last_oracle_state, strict=True)):
                fleet_t = apply_faults(fleet, state)
                cap_t = faulted_capacity(sc.edge_capacity_s, state)
                new = planner.plan(fleet_t, sc._replace(edge_capacity_s=cap_t))
                _install(new, t)
                last_oracle_state = leaves

        dev, valid, served = _padded_batch(trace, bounds, t, capacity)
        stats.mark_window()
        if served:
            ep = sample_epoch(
                jax.random.fold_in(key, t), fleet, plan.m_sel, plan.alloc,
                sc.deadline, jnp.asarray(dev), jnp.asarray(valid),
                rounds, dist=dist, edge_capacity_s=cap_arg, faults=state,
                assignment=plan.assignment if multi_node else None)
            tot = np.asarray(ep.total_s)[:served]
            met = np.asarray(ep.met)[:served]
            _record_epoch(stats, int(bounds[t]), tot, met)
            energy_log[t] = float(ep.energy_j)
            viol_log[t] = int(served - met.sum())
            req_log[t] = served
            epoch_rate[t] = float(viol_log[t]) / served

            k, nn = stats.window_counts()
            sentinel.observe(k, nn)

            # observable-only re-fit: predicted tier sums weighted by the
            # epoch's per-device demand, so idle devices don't bias it
            t_loc_p, _t_off_p, t_vm_p = _predicted_components(fleet, plan)
            cnt = np.asarray(ep.count, float)
            loc_hat, vm_hat = _refit_scales(
                loc_hat, vm_hat, cnt * t_loc_p, cnt * t_vm_p,
                np.asarray(ep.obs_local, float), np.asarray(ep.obs_vm, float),
                guard.ewma)
            if multi_node:
                cap_hat = _refit_node_scales(
                    cap_hat, cnt * t_vm_p, np.asarray(ep.obs_vm, float),
                    np.asarray(plan.assignment), cap_np.shape[0], guard.ewma)

        trip = sentinel.tripped()
        window_rate[t] = sentinel.rate()
        tripped_log[t] = trip

        if guarded and not oracle and trip \
                and t - last_action >= guard.cooldown:
            last_action = t
            rung = min(rung + 1, guard.max_rung)
            fleet_hat = apply_faults(fleet, _refit_state(loc_hat, vm_hat))
            if multi_node:
                cap_fit = sc.edge_capacity_s * jnp.asarray(cap_hat)
                sc_fit = sc._replace(edge_capacity_s=cap_fit)
            else:
                cap_fit, sc_fit = cap_arg, sc
            if rung == RUNG_PRICE:
                new = plan_fixed_partition(
                    fleet_hat, plan.m_sel, sc.deadline, sc.eps, sc.B, cap_fit)
            elif rung == RUNG_REPLAN:
                new = planner.plan(fleet_hat, sc_fit, init_m=plan.m_sel,
                                   incumbent=plan)
            else:
                new = pick_contingency(contingencies, fleet_hat, sc.deadline,
                                       sc.eps, incumbent=plan)
            _install(new, t)
            sentinel.reset()
        elif rung > RUNG_NONE and not trip and \
                sentinel.counts[1] >= guard.sentinel.min_count:
            rung = RUNG_NONE

        rung_log[t] = rung

    return ReplayResult(
        epoch_rate=epoch_rate, window_rate=window_rate, tripped=tripped_log,
        rung=rung_log, energy_j=energy_log, overhead_j=overhead_log,
        epoch_violations=viol_log, epoch_requests=req_log,
        replans=replans, churn=churn, migrations=migrations,
        migration_energy_j=mig_energy, stats=stats)


def regret_curves(actual: ReplayResult, oracle: ReplayResult) -> dict:  # analyze: ok(TRC002): post-hoc accounting over materialized per-epoch logs
    """Cumulative regret of the controller against a schedule-aware
    oracle, per epoch: energy (serving + migration overhead, J) and
    deadline violations. Positive regret = the controller paid more /
    violated more than a clairvoyant re-planner on the *same* trace and
    sample stream; the violation curve is what the ladder's reaction
    time costs, the energy curve what its caution costs."""
    if actual.energy_j.shape != oracle.energy_j.shape:
        raise ValueError(
            f"paired runs must share a horizon: {actual.energy_j.shape} "
            f"!= {oracle.energy_j.shape}")
    de = (actual.energy_j + actual.overhead_j) \
        - (oracle.energy_j + oracle.overhead_j)
    dv = actual.epoch_violations - oracle.epoch_violations
    return {
        "energy_j": np.cumsum(de),
        "violations": np.cumsum(dv),
        "final_energy_j": float(np.sum(de)),
        "final_violations": int(np.sum(dv)),
    }


# ---------------------------------------------------------------------------
# Engine-backed replay (the real ServingEngine, smoke scale)
# ---------------------------------------------------------------------------


def replay_engine(  # analyze: ok(TRC001,TRC002,TRC003): host serving loop around the real engine; jit lives inside ServingEngine
    engine: ServingEngine,
    trace: Trace,
    *,
    seed: int = 0,
    deadline_s: float = 1.0,
    prompt_tokens: int = 8,
    max_new_tokens: int = 4,
    eps: float = 0.05,
    sentinel: Optional[ViolationSentinel] = None,
    chain=None,
):
    """Drive the *real* :class:`ServingEngine` through ``trace``.

    Each epoch's arrivals become :class:`Request` objects (arrival time
    stamped — the FIFO tie-break in ``schedule`` sees it), served with
    ``engine.run``; ``EngineStats`` windows are marked per epoch and fed
    to the sentinel as deadline outcomes. When ``chain`` (a
    ``BlockChain``) is given and the engine has observed at least one
    warm decode step, the measured decode moments are folded back via
    ``measured_chain`` — the §IV online re-profiling that the EWMA
    re-fit consumes on the next plan.

    Returns ``(summary, sentinel, refit_chain)`` — ``refit_chain`` is
    ``None`` until enough completions have been observed.
    """
    rng = np.random.default_rng(seed)
    if sentinel is None:
        sentinel = ViolationSentinel(eps)
    bounds = trace.epoch_bounds()
    vocab = int(engine.cfg.vocab_size)
    for t in range(trace.epochs):
        lo, hi = int(bounds[t]), int(bounds[t + 1])
        if hi == lo:
            continue
        queue = [
            Request(
                uid=r,
                prompt=rng.integers(0, vocab, prompt_tokens).astype(np.int32),
                max_new_tokens=max_new_tokens,
                deadline_s=deadline_s,
                arrival_s=float(trace.arrival_s[r]),
            )
            for r in range(lo, hi)
        ]
        engine.stats.mark_window()
        engine.run(queue)
        sentinel.observe(*engine.stats.window_counts())
    summary = engine.stats.summary()
    refit = None
    if chain is not None and summary["decode_samples"] >= 1:
        refit = measured_chain(chain, summary)
    return summary, sentinel, refit
