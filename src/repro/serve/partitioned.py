"""Two-tier partitioned serving — the paper's technique as a framework
feature.

A weak "device" tier (DVFS-scalable, battery-powered) and a strong "edge"
tier serve model inference. For a population of devices (heterogeneous
radio links, and — since the ragged-fleet refactor — heterogeneous
*models and platforms*), the robust planner picks per-device:

  * the partition point m (how many transformer blocks run on-device),
  * the device clock f, and the uplink bandwidth share b,

minimizing total device energy subject to P{latency ≤ D} ≥ 1−ε with only
(mean, variance) knowledge of block times — uncertain inference time is a
measured reality on shared serving tiers (batching jitter, stragglers).

Two deployment shapes share one planning surface
(:class:`_DeploymentBase`):

- :class:`TwoTierDeployment` — one model on one device class (the
  paper's setting), now built through the ``FleetSpec`` builder.
- :class:`MixedTwoTierDeployment` — a mixed population
  (:class:`Population` fractions of different models × tiers, e.g. 60%
  tinyllama on Jetson-class + 40% mamba2 on phone-class) sharing ONE
  bandwidth budget B; the planner solves the whole ragged fleet in one
  compiled program, and Monte-Carlo validation reports per device.

Planning goes through the first-class Scenario/Planner API
(``repro.core.api``): ``plan`` is the deployment's default scenario,
``plan_grid`` a cartesian SLO sweep, and ``plan_many`` a zipped batch of
arbitrary scenarios (heterogeneous per-device deadlines/risk levels) in
one compiled program. All registry policies — including ``"optimal"`` —
dispatch through every entry point.

The per-block (FLOPs, boundary bytes) come from ``models.costmodel``; the
(mean, variance) time statistics either from the analytic tier profiles or
from ``ServingEngine`` measurements (``measured_chain``).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import violation_report
from repro.core.api import Planner, PlannerConfig, Scenario
from repro.core.blocks import BlockChain, Fleet
from repro.core.fleet import DeviceSpec, FleetSpec
from repro.models.costmodel import DEVICE_TIER, EDGE_TIER, PHONE_TIER, TierProfile

__all__ = [
    "TwoTierDeployment", "MixedTwoTierDeployment", "Population",
    "measured_chain", "PHONE_TIER",
]


class _DeploymentBase:
    """Shared planning/validation surface over ``self.spec()``.

    Subclasses provide ``spec() -> FleetSpec`` plus the scenario scalars
    (``deadline_s``, ``eps``, ``bandwidth_hz``, ``seed``) and the
    shared-edge fields (``dedicated_vm``, ``edge_capacity_s``,
    ``legacy_vm_scale``).
    """

    def spec(self) -> FleetSpec:  # pragma: no cover - interface
        raise NotImplementedError

    def edge_capacity(self):
        """Shared-edge VM-time budget per round (seconds; DESIGN.md §edge).

        ``inf`` for dedicated VMs (the paper's §III-B assumption) and for
        the deprecated static N-scaling fallback (whose contention model
        is baked into the chain instead). A shared edge without an
        explicit ``edge_capacity_s`` defaults to ``deadline_s``: one
        accelerator can hand out at most a round's worth of VM time per
        round.

        Multi-node edges (DESIGN.md §placement) return a per-node ``(E,)``
        numpy vector instead of a float: either ``edge_capacity_s`` is
        itself a sequence of per-node capacities, or ``edge_nodes`` > 1
        splits the scalar budget into that many equal nodes.
        """
        cap = self.edge_capacity_s
        if cap is not None and np.ndim(cap) == 1:
            vec = np.asarray(cap, np.float64)
            return float(vec[0]) if vec.size == 1 else vec
        if cap is not None:
            cap = float(cap)
        elif self.dedicated_vm or self.legacy_vm_scale:
            cap = float("inf")
        else:
            cap = float(self.deadline_s)
        nodes = int(getattr(self, "edge_nodes", 1))
        if nodes > 1 and np.isfinite(cap):
            return np.full(nodes, cap / nodes)
        return cap

    def device_names(self) -> list:
        """(N,) population label per device. Subclasses override with a
        chain-free implementation — the default builds the full spec,
        which runs the analytic cost model per group."""
        return self.spec().device_names()

    def fleet(self) -> Fleet:
        """The deployment's (possibly ragged) padded fleet."""
        return self.spec().build(jax.random.PRNGKey(self.seed))

    def scenario(self) -> Scenario:
        """The deployment's configured default scenario."""
        cap = self.edge_capacity()
        return Scenario(self.deadline_s, self.eps, self.bandwidth_hz,
                        None if np.all(np.isinf(cap)) else cap)

    def planner(self, policy: str = "robust_exact", **kw) -> Planner:
        """A ``Planner`` for this deployment (kw → ``PlannerConfig``).

        The deployment's edge capacity rides in as the config *default*,
        so grid/batch sweeps that build their own scenarios still price
        the shared edge (a per-scenario ``edge_capacity_s`` wins). The
        deployment's ``solver`` field (DESIGN.md §solver) rides in the
        same way; a ``solver=`` keyword wins.
        """
        cap = self.edge_capacity()
        if not np.all(np.isinf(cap)):
            if np.ndim(cap):  # per-node vector → hashable config tuple
                cap = tuple(float(c) for c in cap)
            kw.setdefault("edge_capacity_s", cap)
        kw.setdefault("solver", getattr(self, "solver", "structured"))
        return Planner(PlannerConfig(policy=policy, **kw))

    def plan(self, policy: str = "robust_exact", **kw):
        """Plan the deployment's default scenario."""
        fleet = self.fleet()
        return self.planner(policy, **kw).plan(fleet, self.scenario()), fleet

    def plan_grid(self, deadlines=None, epss=None, Bs=None,
                  policy: str = "robust_exact", **kw):
        """Plan a deadline×ε×B scenario grid in one compiled program.

        Axes default to the deployment's configured scalars; pass any
        combination of sweeps (e.g. SLO tiers as ``deadlines``, per-tenant
        risk levels as ``epss``) — the returned ``Plan`` has leading axes
        (len(deadlines), len(epss), len(Bs)).
        """
        fleet = self.fleet()
        plans = self.planner(policy, **kw).grid(
            fleet,
            self.deadline_s if deadlines is None else deadlines,
            self.eps if epss is None else epss,
            self.bandwidth_hz if Bs is None else Bs,
        )
        return plans, fleet

    def plan_many(self, scenarios: Union[Scenario, Sequence[Scenario]],
                  policy: str = "robust_exact", **kw):
        """Plan K zipped scenarios (arbitrary mixes — heterogeneous
        per-device SLOs, what-if bandwidths) as one compiled program.
        Returns a ``Plan`` with leading axis K on every leaf."""
        fleet = self.fleet()
        return self.planner(policy, **kw).plan_many(fleet, scenarios), fleet

    def validate(self, p, fleet, key=None, dist: str = "gamma",  # analyze: ok(TRC001): host acceptance report (floats for humans/JSON)
                 deadline=None) -> Dict[str, float]:
        """Monte-Carlo validation of a plan against its own scenario.

        ``deadline`` (scalar or per-device ``(N,)``) defaults to the
        deployment's configured scalar — pass the cell's deadline when
        validating plans from a grid/batch sweep, otherwise the report
        would silently score every cell against ``self.deadline_s``.
        """
        vr, _ = self._mc_report(p, fleet, key, dist, deadline)
        return {
            "total_energy_j": float(p.total_energy),
            "max_violation": float(vr.rate.max()),
            "eps": self.eps,
            "mean_latency_s": float(vr.mean_time.mean()),
            "p95_latency_s": float(vr.p95_time.max()),
        }

    def validate_per_device(self, p, fleet, key=None, dist: str = "gamma",
                            deadline=None) -> Dict[str, object]:
        """Per-device Monte-Carlo validation (mixed populations report
        each device against its own deadline and model group).

        Returns arrays of length N: ``violation`` (empirical P{T > D_n}),
        ``mean_latency_s``, ``p95_latency_s``, ``m`` (partition points),
        ``group`` (population name per device) and ``ok`` (violation ≤ ε).
        """
        vr, _ = self._mc_report(p, fleet, key, dist, deadline)
        return {
            "group": list(self.device_names()),
            "m": np.asarray(p.m_sel).tolist(),
            "violation": np.asarray(vr.rate),
            "mean_latency_s": np.asarray(vr.mean_time),
            "p95_latency_s": np.asarray(vr.p95_time),
            "ok": np.asarray(vr.rate <= self.eps),
        }

    def _mc_report(self, p, fleet, key, dist, deadline):
        key = jax.random.PRNGKey(self.seed + 1) if key is None else key
        deadline = self.deadline_s if deadline is None else deadline
        deadline = jnp.broadcast_to(jnp.asarray(deadline, jnp.float64),
                                    (fleet.num_devices,))
        cap = self.edge_capacity()
        if np.all(np.isinf(cap)):
            cap = None
        assignment = p.assignment if np.ndim(cap) else None
        vr = violation_report(key, fleet, p.m_sel, p.alloc, deadline, dist=dist,
                              edge_capacity_s=cap, assignment=assignment)
        return vr, deadline


@dataclass
class TwoTierDeployment(_DeploymentBase):
    cfg: ModelConfig
    num_devices: int = 12
    num_blocks: int = 8
    batch: int = 1
    seq_len: int = 256
    bandwidth_hz: float = 50e6
    deadline_s: float = 1.0
    eps: float = 0.05
    device: TierProfile = DEVICE_TIER
    edge: TierProfile = EDGE_TIER
    f_min_hz: float = 0.2e9
    f_max_hz: float = 1.4e9
    kappa: float = 2.8e-27
    area_m: float = 400.0
    seed: int = 0
    #: the paper assumes one dedicated VM per device (§III-B). With a
    #: *shared* edge accelerator contention is priced as a real capacity
    #: constraint Σ t̄_vm ≤ ``edge_capacity_s`` with its own dual price μ
    #: (DESIGN.md §edge) — this is what makes interior splits pay off for
    #: transformers (whose boundary activations, unlike CNN features,
    #: never shrink).
    dedicated_vm: bool = True
    #: shared-edge VM-time budget per round; None → ``deadline_s`` when
    #: the edge is shared (see ``edge_capacity``). A sequence gives
    #: per-node capacities (DESIGN.md §placement).
    edge_capacity_s: Optional[Union[float, Sequence[float]]] = None
    #: split the (scalar) edge budget into this many equal placement
    #: nodes; ignored when ``edge_capacity_s`` is already per-node
    edge_nodes: int = 1
    #: DEPRECATED pre-capacity approximation: bake ``vm_time_scale = N``
    #: into the chain instead of pricing the shared edge. Kept only as a
    #: comparison baseline (see ``benchmarks/bench_edge.py``).
    legacy_vm_scale: bool = False
    #: PCCP inner-barrier path (DESIGN.md §solver): ``"structured"``
    #: (closed-form KKT, the default) or ``"dense"`` (autodiff reference).
    solver: str = "structured"

    def spec(self) -> FleetSpec:
        legacy = self.legacy_vm_scale and not self.dedicated_vm
        ds = DeviceSpec.from_model(
            self.cfg, count=self.num_devices, num_blocks=self.num_blocks,
            batch=self.batch, seq_len=self.seq_len, device=self.device,
            edge=self.edge, kappa=self.kappa, f_min_hz=self.f_min_hz,
            f_max_hz=self.f_max_hz, seed=self.seed,
            vm_time_scale=float(self.num_devices) if legacy else 1.0,
        )
        return FleetSpec((ds,), area_m=self.area_m)

    def device_names(self) -> list:
        return [getattr(self.cfg, "name", "device")] * self.num_devices


@dataclass(frozen=True)
class Population:
    """One slice of a mixed deployment: ``fraction`` of the devices run
    ``cfg`` on the given device tier/platform (each population may have
    its own DVFS range, κ, block count and sequence length)."""

    cfg: ModelConfig
    fraction: float
    device: TierProfile = DEVICE_TIER
    edge: TierProfile = EDGE_TIER
    num_blocks: int = 8
    batch: int = 1
    seq_len: int = 256
    f_min_hz: float = 0.2e9
    f_max_hz: float = 1.4e9
    kappa: float = 2.8e-27
    p_tx_w: float = 1.0
    name: str = ""

    def __post_init__(self):
        if not 0.0 < self.fraction <= 1.0:
            raise ValueError(
                f"Population.fraction must be in (0, 1], got {self.fraction}")


@dataclass
class MixedTwoTierDeployment(_DeploymentBase):
    """A mixed population sharing one edge and ONE bandwidth budget.

    Fractions are apportioned to device counts by largest remainder (so
    counts sum to ``num_devices`` and every population keeps ≥ 1 device).
    The resulting fleet is ragged — per-device models, platforms and
    partition-point counts — and plans as one compiled program through
    every ``_DeploymentBase`` entry point.
    """

    populations: Sequence[Population] = field(default_factory=tuple)
    num_devices: int = 12
    bandwidth_hz: float = 50e6
    deadline_s: float = 1.0
    eps: float = 0.05
    area_m: float = 400.0
    seed: int = 0
    dedicated_vm: bool = True
    edge_capacity_s: Optional[Union[float, Sequence[float]]] = None
    edge_nodes: int = 1  # split the scalar budget into E equal nodes
    legacy_vm_scale: bool = False  # DEPRECATED static N-scaling fallback
    solver: str = "structured"  # PCCP inner-barrier path (DESIGN.md §solver)

    def __post_init__(self):
        self.populations = tuple(self.populations)
        if not self.populations:
            raise ValueError("MixedTwoTierDeployment needs >= 1 Population")
        if self.num_devices < len(self.populations):
            raise ValueError(
                f"{self.num_devices} devices cannot host "
                f"{len(self.populations)} populations (each needs >= 1)")
        total = sum(p.fraction for p in self.populations)
        if abs(total - 1.0) > 1e-6:
            raise ValueError(f"population fractions must sum to 1, got {total}")

    def counts(self) -> list:
        """Largest-remainder apportionment of fractions to device counts,
        with every population floored at one device.

        Tie-breaking is explicit and deterministic: equal fractional
        remainders hand out the extra device to the lower population
        index, and equal over-quota scores shrink the higher-count /
        lower-index group first — so ``counts`` is a pure function of
        ``(fractions, num_devices)`` and permutation-equivariant up to
        those ties.
        """
        quotas = [p.fraction * self.num_devices for p in self.populations]
        counts = [max(int(q), 1) for q in quotas]
        # distribute leftovers by largest remainder, index as tiebreak
        order = sorted(range(len(quotas)),
                       key=lambda i: (-(quotas[i] - int(quotas[i])), i))
        i = 0
        while sum(counts) < self.num_devices:
            counts[order[i % len(order)]] += 1
            i += 1
        while sum(counts) > self.num_devices:  # floors may overshoot
            # shrink the most over-quota group that can still spare a device
            cand = [k for k in range(len(counts)) if counts[k] > 1]
            if not cand:  # every group at its 1-device floor yet Σ > N
                raise RuntimeError(
                    f"cannot apportion {self.num_devices} devices over "
                    f"{len(self.populations)} populations: every group is at "
                    "its 1-device floor but the floors exceed num_devices "
                    "(validated in __post_init__ — this indicates a bug)")
            j = max(cand, key=lambda k: (counts[k] - quotas[k], counts[k], -k))
            counts[j] -= 1
        return counts

    def spec(self) -> FleetSpec:
        legacy = self.legacy_vm_scale and not self.dedicated_vm
        scale = float(self.num_devices) if legacy else 1.0
        groups = []
        for idx, (pop, count) in enumerate(zip(self.populations, self.counts(),
                                               strict=True)):
            groups.append(DeviceSpec.from_model(
                pop.cfg, count=count, num_blocks=pop.num_blocks,
                batch=pop.batch, seq_len=pop.seq_len, device=pop.device,
                edge=pop.edge, kappa=pop.kappa, f_min_hz=pop.f_min_hz,
                f_max_hz=pop.f_max_hz, p_tx_w=pop.p_tx_w,
                seed=self.seed + idx, vm_time_scale=scale,
                name=self._pop_name(pop, idx),
            ))
        return FleetSpec(tuple(groups), area_m=self.area_m)

    @staticmethod
    def _pop_name(pop: Population, idx: int) -> str:
        return pop.name or getattr(pop.cfg, "name", f"pop{idx}")

    def device_names(self) -> list:
        """Per-device labels without running the cost model (cheap —
        ``validate_per_device`` calls this on every report)."""
        return [self._pop_name(pop, idx)
                for idx, (pop, count) in enumerate(
                    zip(self.populations, self.counts(), strict=True))
                for _ in range(count)]

    def plan_sharded(self, policy: str = "robust_exact", *, mesh=None, **kw):
        """Plan the default scenario through the group decomposition
        (``core.decompose``): one compiled program per population at its
        native partition-point count, coordinated only through the scalar
        bandwidth/edge prices — no cross-population padding, so a few
        huge homogeneous populations plan in O(largest group) memory.

        Gains come from the deployment seed — the same draw
        ``self.fleet()`` uses — so ``validate(plan, self.fleet())``
        scores exactly the planned links. Returns ``(plan, spec)``; the
        padded monolithic fleet is never materialized here.
        """
        spec = self.spec()
        plan = self.planner(policy, **kw).plan_sharded(
            spec, self.scenario(), key=jax.random.PRNGKey(self.seed),
            mesh=mesh)
        return plan, spec


def measured_chain(base: BlockChain, decode_stats: Dict[str, float],  # analyze: ok(TRC001): decode_stats is EngineStats.summary()'s host dict by contract
                   blocks_scale: Optional[np.ndarray] = None) -> BlockChain:
    """Fold online engine measurements into a chain (paper §IV online path).

    decode_stats from ``ServingEngine.stats.summary()``: the measured
    per-step mean/variance rescale the edge-tier time model. The chain's
    full-offload point (m = 0, the last axis' first entry) is pinned to
    the measured mean; interior points keep their relative shape, so
    folding the *same* stats twice is idempotent. Works on a single
    ``(M+1,)`` chain or a batched/ragged ``(N, M+1)`` fleet chain — the
    anchor is per-device, not the first row.

    Raises ``ValueError`` on empty/non-finite stats (``summary()``
    reports NaN for empty engines; re-planning against a fake
    zero-variance chain would silently void the ε guarantee).
    """
    mean = float(decode_stats.get("decode_mean_s", float("nan")))
    var = float(decode_stats.get("decode_var_s2", float("nan")))
    if not (np.isfinite(mean) and mean > 0.0):
        raise ValueError(
            f"measured_chain needs a positive finite decode_mean_s, got "
            f"{mean!r} (empty engine stats report NaN — serve more than "
            "one decode step before re-fitting)")
    if not (np.isfinite(var) and var >= 0.0):
        raise ValueError(
            f"measured_chain needs a finite decode_var_s2 >= 0, got {var!r}")
    anchor = jnp.maximum(base.t_vm[..., :1], 1e-12)
    t_vm = base.t_vm / anchor * mean
    rel_var = var / max(mean**2, 1e-18)
    v_vm = (t_vm**2) * rel_var
    return base._replace(t_vm=t_vm, v_vm=v_vm)
