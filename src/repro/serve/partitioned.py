"""Two-tier partitioned serving — the paper's technique as a framework
feature.

A weak "device" tier (DVFS-scalable, battery-powered) and a strong "edge"
tier serve the same model. For a population of devices (heterogeneous
radio links), the robust planner picks per-device:

  * the partition point m (how many transformer blocks run on-device),
  * the device clock f, and the uplink bandwidth share b,

minimizing total device energy subject to P{latency ≤ D} ≥ 1−ε with only
(mean, variance) knowledge of block times — uncertain inference time is a
measured reality on shared serving tiers (batching jitter, stragglers).

Planning goes through the first-class Scenario/Planner API
(``repro.core.api``): ``plan`` is the deployment's default scenario,
``plan_grid`` a cartesian SLO sweep, and ``plan_many`` a zipped batch of
arbitrary scenarios (heterogeneous per-device deadlines/risk levels) in
one compiled program. All registry policies — including ``"optimal"`` —
dispatch through every entry point.

The per-block (FLOPs, boundary bytes) come from ``models.costmodel``; the
(mean, variance) time statistics either from the analytic tier profiles or
from ``ServingEngine`` measurements (``measured_chain``).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import violation_report
from repro.core.api import Planner, PlannerConfig, Scenario
from repro.core.blocks import BlockChain, Fleet, Link, Platform
from repro.core.channel import pathloss_gain
from repro.models.costmodel import DEVICE_TIER, EDGE_TIER, TierProfile, block_chain_from_config


@dataclass
class TwoTierDeployment:
    cfg: ModelConfig
    num_devices: int = 12
    num_blocks: int = 8
    batch: int = 1
    seq_len: int = 256
    bandwidth_hz: float = 50e6
    deadline_s: float = 1.0
    eps: float = 0.05
    device: TierProfile = DEVICE_TIER
    edge: TierProfile = EDGE_TIER
    f_min_hz: float = 0.2e9
    f_max_hz: float = 1.4e9
    kappa: float = 2.8e-27
    area_m: float = 400.0
    seed: int = 0
    #: the paper assumes one dedicated VM per device (§III-B). With a
    #: *shared* edge accelerator the effective VM time scales with the
    #: fleet — this is what makes interior splits pay off for transformers
    #: (whose boundary activations, unlike CNN features, never shrink).
    dedicated_vm: bool = True

    def fleet(self) -> Fleet:
        chain = block_chain_from_config(
            self.cfg, batch=self.batch, seq_len=self.seq_len,
            num_blocks=self.num_blocks, device=self.device, edge=self.edge,
            f_mid_hz=0.5 * (self.f_min_hz + self.f_max_hz), seed=self.seed,
        )
        if not self.dedicated_vm:
            scale = float(self.num_devices)
            chain = chain._replace(t_vm=chain.t_vm * scale,
                                   v_vm=chain.v_vm * scale**2)
        key = jax.random.PRNGKey(self.seed)
        xy = jax.random.uniform(key, (self.num_devices, 2), jnp.float64,
                                -self.area_m / 2, self.area_m / 2)
        r = jnp.maximum(jnp.linalg.norm(xy, axis=-1), 5.0)
        n = self.num_devices
        tile = lambda a: jnp.broadcast_to(jnp.asarray(a, jnp.float64), (n,) + jnp.shape(a))
        return Fleet(
            chain=BlockChain(*[tile(x) for x in chain]),
            platform=Platform(kappa=tile(self.kappa), f_min=tile(self.f_min_hz),
                              f_max=tile(self.f_max_hz)),
            link=Link(p_tx=tile(1.0), gain=pathloss_gain(r)),
        )

    def scenario(self) -> Scenario:
        """The deployment's configured default scenario."""
        return Scenario(self.deadline_s, self.eps, self.bandwidth_hz)

    def planner(self, policy: str = "robust_exact", **kw) -> Planner:
        """A ``Planner`` for this deployment (kw → ``PlannerConfig``)."""
        return Planner(PlannerConfig(policy=policy, **kw))

    def plan(self, policy: str = "robust_exact", **kw):
        """Plan the deployment's default scenario."""
        fleet = self.fleet()
        return self.planner(policy, **kw).plan(fleet, self.scenario()), fleet

    def plan_grid(self, deadlines=None, epss=None, Bs=None,
                  policy: str = "robust_exact", **kw):
        """Plan a deadline×ε×B scenario grid in one compiled program.

        Axes default to the deployment's configured scalars; pass any
        combination of sweeps (e.g. SLO tiers as ``deadlines``, per-tenant
        risk levels as ``epss``) — the returned ``Plan`` has leading axes
        (len(deadlines), len(epss), len(Bs)).
        """
        fleet = self.fleet()
        plans = self.planner(policy, **kw).grid(
            fleet,
            self.deadline_s if deadlines is None else deadlines,
            self.eps if epss is None else epss,
            self.bandwidth_hz if Bs is None else Bs,
        )
        return plans, fleet

    def plan_many(self, scenarios: Union[Scenario, Sequence[Scenario]],
                  policy: str = "robust_exact", **kw):
        """Plan K zipped scenarios (arbitrary mixes — heterogeneous
        per-device SLOs, what-if bandwidths) as one compiled program.
        Returns a ``Plan`` with leading axis K on every leaf."""
        fleet = self.fleet()
        return self.planner(policy, **kw).plan_many(fleet, scenarios), fleet

    def validate(self, p, fleet, key=None, dist: str = "gamma",
                 deadline=None) -> Dict[str, float]:
        """Monte-Carlo validation of a plan against its own scenario.

        ``deadline`` (scalar or per-device ``(N,)``) defaults to the
        deployment's configured scalar — pass the cell's deadline when
        validating plans from a grid/batch sweep, otherwise the report
        would silently score every cell against ``self.deadline_s``.
        """
        key = jax.random.PRNGKey(self.seed + 1) if key is None else key
        deadline = self.deadline_s if deadline is None else deadline
        deadline = jnp.broadcast_to(jnp.asarray(deadline, jnp.float64),
                                    (fleet.num_devices,))
        vr = violation_report(key, fleet, p.m_sel, p.alloc, deadline, dist=dist)
        return {
            "total_energy_j": float(p.total_energy),
            "max_violation": float(vr.rate.max()),
            "eps": self.eps,
            "mean_latency_s": float(vr.mean_time.mean()),
            "p95_latency_s": float(vr.p95_time.max()),
        }


def measured_chain(base: BlockChain, decode_stats: Dict[str, float],
                   blocks_scale: Optional[np.ndarray] = None) -> BlockChain:
    """Fold online engine measurements into a chain (paper §IV online path).

    decode_stats from ``ServingEngine.stats.summary()``: the measured
    per-step mean/variance rescale the edge-tier time model.
    """
    mean = decode_stats.get("decode_mean_s", 0.0)
    var = decode_stats.get("decode_var_s2", 0.0)
    t_vm = base.t_vm / jnp.maximum(base.t_vm[0], 1e-12) * mean
    rel_var = var / max(mean**2, 1e-18)
    v_vm = (t_vm**2) * rel_var
    return base._replace(t_vm=t_vm, v_vm=v_vm)
