"""Batched serving engine: prefill + decode with ring-buffer caches.

A deliberately small but real engine: requests arrive with prompts and
token budgets, a batcher groups them, ``prefill`` builds the caches, and
``decode_loop`` steps the whole batch. Per-block wall-clock times are
recorded so the robust planner can consume *measured* (mean, variance)
statistics exactly as the paper prescribes (§IV: online measurement).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import transformer as T


@dataclass
class Request:
    uid: int
    prompt: np.ndarray  # (S,) int32
    max_new_tokens: int = 16
    deadline_s: float = 1.0
    output: List[int] = field(default_factory=list)


@dataclass
class EngineStats:
    prefill_times: List[float] = field(default_factory=list)
    decode_times: List[float] = field(default_factory=list)

    def summary(self) -> Dict[str, float]:
        d = np.asarray(self.decode_times[1:] or [0.0])
        p = np.asarray(self.prefill_times or [0.0])
        return {
            "prefill_mean_s": float(p.mean()),
            "decode_mean_s": float(d.mean()),
            "decode_var_s2": float(d.var()),
        }


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, max_batch: int = 8, window: int = 1024,
                 dtype=jnp.float32):
        self.cfg, self.params = cfg, params
        self.max_batch, self.window, self.dtype = max_batch, window, dtype
        self.stats = EngineStats()
        self._decode = jax.jit(lambda p, t, c, pos: T.decode_step(p, cfg, t, c, pos))
        self._prefill_cache: Dict[int, Any] = {}

    # -- batching ----------------------------------------------------------
    def schedule(self, queue: List[Request]) -> List[List[Request]]:
        """Greedy deadline-aware batching (EDF order, fixed max batch).

        Deadline ties break by ``uid`` so batch composition is a function
        of the queue's *contents*, not its arrival order (Python's sort is
        stable, so equal deadlines would otherwise keep insertion order).
        """
        ordered = sorted(queue, key=lambda r: (r.deadline_s, r.uid))
        return [ordered[i : i + self.max_batch] for i in range(0, len(ordered), self.max_batch)]

    # -- execution ---------------------------------------------------------
    def _pad_prompts(self, batch: List[Request]) -> np.ndarray:
        s = max(len(r.prompt) for r in batch)
        out = np.zeros((len(batch), s), np.int32)
        for i, r in enumerate(batch):
            out[i, s - len(r.prompt):] = r.prompt  # left-pad
        return out

    def prefill(self, batch: List[Request]):
        tokens = jnp.asarray(self._pad_prompts(batch))
        b, s = tokens.shape
        cache = T.init_decode_cache(self.cfg, b, self.window, enc_len=max(s // 4, 1),
                                    dtype=self.dtype)
        t0 = time.perf_counter()
        # teacher-forced prefill via repeated decode steps (cache warmup);
        # a fused full-sequence prefill is the flash-kernel path on TPU.
        logits = None
        for pos in range(s):
            logits, cache = self._decode(self.params, tokens[:, pos : pos + 1], cache,
                                         jnp.int32(pos))
        jax.block_until_ready(logits)
        self.stats.prefill_times.append(time.perf_counter() - t0)
        return logits, cache, s

    def decode_loop(self, batch: List[Request], logits, cache, start_pos: int,
                    steps: Optional[int] = None):
        steps = steps or max(r.max_new_tokens for r in batch)
        tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        for i in range(steps):
            t0 = time.perf_counter()
            logits, cache = self._decode(self.params, tok, cache, jnp.int32(start_pos + i))
            tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
            jax.block_until_ready(tok)
            self.stats.decode_times.append(time.perf_counter() - t0)
            for j, r in enumerate(batch):
                if i < r.max_new_tokens:
                    r.output.append(int(tok[j, 0]))
        return batch

    def run(self, queue: List[Request]) -> Tuple[List[Request], Dict[str, float]]:
        done: List[Request] = []
        for group in self.schedule(queue):
            logits, cache, s = self.prefill(group)
            done += self.decode_loop(group, logits, cache, s)
        return done, self.stats.summary()
