"""Batched serving engine: prefill + decode with ring-buffer caches.

A deliberately small but real engine: requests arrive with prompts and
token budgets, a batcher groups them, ``prefill`` builds the caches, and
``decode_loop`` steps the whole batch. Per-block wall-clock times are
recorded so the robust planner can consume *measured* (mean, variance)
statistics exactly as the paper prescribes (§IV: online measurement).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import transformer as T


@dataclass
class Request:
    uid: int
    prompt: np.ndarray  # (S,) int32
    max_new_tokens: int = 16
    deadline_s: float = 1.0
    #: arrival time on the serving clock (workload replay stamps it;
    #: ad-hoc queues default to 0, preserving pure EDF-then-uid order)
    arrival_s: float = 0.0
    output: List[int] = field(default_factory=list)


@dataclass
class EngineStats:
    prefill_times: List[float] = field(default_factory=list)
    decode_times: List[float] = field(default_factory=list)
    #: per-request outcome stream — the violation sentinel's input signal
    #: (DESIGN.md §robustness): request uid, wall-clock completion time
    #: (group prefill start → the request's last token), deadline met?
    request_uids: List[int] = field(default_factory=list)
    completion_times: List[float] = field(default_factory=list)
    deadline_flags: List[bool] = field(default_factory=list)
    #: completion-stream index of the current observation window's start
    #: (``mark_window`` advances it; replay marks one window per epoch)
    window_start: int = 0

    def record_completion(self, uid: int, elapsed_s: float,
                          deadline_s: float) -> None:
        self.request_uids.append(uid)
        self.completion_times.append(elapsed_s)
        self.deadline_flags.append(elapsed_s <= deadline_s)

    def mark_window(self) -> None:
        """Start a new observation window at the current stream position
        — the per-window violation counts in :meth:`summary` (and
        :meth:`window_counts`) cover completions recorded after the most
        recent mark. The workload replay marks once per epoch so the
        sentinel feed is an explicit engine-side count, not a host-side
        re-derivation from the raw stream."""
        self.window_start = len(self.deadline_flags)

    def window_counts(self) -> Tuple[int, int]:
        """(violations, total) over the current window — exactly the
        shape ``ViolationSentinel.observe`` consumes."""
        flags = self.deadline_flags[self.window_start:]
        return sum(1 for f in flags if not f), len(flags)

    def summary(self) -> Dict[str, float]:
        # The first decode step is the warmup drop (jit dispatch +
        # cache-layout effects); empty stats report NaN, never a fake
        # zero-variance chain a downstream re-fit could ingest.
        warm = np.asarray(self.decode_times[1:], float)
        p = np.asarray(self.prefill_times, float)
        met = np.asarray(self.deadline_flags, bool)
        done = np.asarray(self.completion_times, float)
        nan = float("nan")
        q50, q95, q99 = (np.percentile(done, (50.0, 95.0, 99.0))
                         if done.size else (nan, nan, nan))
        win_viol, win_total = self.window_counts()
        return {
            "prefill_mean_s": float(p.mean()) if p.size else nan,
            "decode_mean_s": float(warm.mean()) if warm.size else nan,
            "decode_var_s2": float(warm.var()) if warm.size else nan,
            "decode_samples": int(warm.size),
            "prefill_samples": int(p.size),
            "requests_completed": len(self.completion_times),
            "deadline_met_rate": float(met.mean()) if met.size else nan,
            "completion_p50_s": float(q50),
            "completion_p95_s": float(q95),
            "completion_p99_s": float(q99),
            "deadline_violations": int(met.size - met.sum()),
            "window_violations": win_viol,
            "window_requests": win_total,
        }


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, max_batch: int = 8, window: int = 1024,
                 dtype=jnp.float32):
        self.cfg, self.params = cfg, params
        self.max_batch, self.window, self.dtype = max_batch, window, dtype
        self.stats = EngineStats()
        self._decode = jax.jit(lambda p, t, c, pos: T.decode_step(p, cfg, t, c, pos))
        self._prefill_cache: Dict[int, Any] = {}

    # -- batching ----------------------------------------------------------
    def schedule(self, queue: List[Request]) -> List[List[Request]]:
        """Greedy deadline-aware batching (EDF order, fixed max batch).

        Deadline ties break by **arrival time first** (FIFO — a replayed
        burst of equal-deadline requests must not starve early arrivals
        behind later ones that happen to carry smaller uids), then by
        ``uid`` so batch composition is a function of the queue's
        *contents*, not its Python insertion order (Python's sort is
        stable, so equal (deadline, arrival) pairs would otherwise keep
        insertion order).
        """
        ordered = sorted(queue, key=lambda r: (r.deadline_s, r.arrival_s, r.uid))
        return [ordered[i : i + self.max_batch] for i in range(0, len(ordered), self.max_batch)]

    # -- execution ---------------------------------------------------------
    def _pad_prompts(self, batch: List[Request]) -> np.ndarray:  # analyze: ok(TRC002): prompts are host int32 arrays by Request contract
        s = max(len(r.prompt) for r in batch)
        out = np.zeros((len(batch), s), np.int32)
        for i, r in enumerate(batch):
            out[i, s - len(r.prompt):] = r.prompt  # left-pad
        return out

    def prefill(self, batch: List[Request]):
        tokens = jnp.asarray(self._pad_prompts(batch))
        b, s = tokens.shape
        cache = T.init_decode_cache(self.cfg, b, self.window, enc_len=max(s // 4, 1),
                                    dtype=self.dtype)
        t0 = time.perf_counter()
        # teacher-forced prefill via repeated decode steps (cache warmup);
        # a fused full-sequence prefill is the flash-kernel path on TPU.
        logits = None
        for pos in range(s):
            logits, cache = self._decode(self.params, tokens[:, pos : pos + 1], cache,
                                         jnp.int32(pos))
        jax.block_until_ready(logits)
        self.stats.prefill_times.append(time.perf_counter() - t0)
        return logits, cache, s

    def decode_loop(self, batch: List[Request], logits, cache, start_pos: int,  # analyze: ok(TRC001,TRC003): host serving loop — tokens are materialized per step by design (block_until_ready)
                    steps: Optional[int] = None,
                    t_start: Optional[float] = None):
        """``t_start`` is the group's wall-clock origin (its prefill
        start); a request completes — and its deadline is scored — when
        its own last token lands, not when the whole batch drains."""
        steps = steps or max(r.max_new_tokens for r in batch)
        if t_start is None:
            t_start = time.perf_counter()
        tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        for i in range(steps):
            t0 = time.perf_counter()
            logits, cache = self._decode(self.params, tok, cache, jnp.int32(start_pos + i))
            tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
            jax.block_until_ready(tok)
            now = time.perf_counter()
            self.stats.decode_times.append(now - t0)
            for j, r in enumerate(batch):
                if i < r.max_new_tokens:
                    r.output.append(int(tok[j, 0]))
                    if i == r.max_new_tokens - 1:
                        self.stats.record_completion(
                            r.uid, now - t_start, r.deadline_s)
        return batch

    def _validate_queue(self, queue: List[Request]) -> None:  # analyze: ok(TRC003): host-side request validation; Request fields are python/np by contract
        if not queue:
            raise ValueError("empty request queue — nothing to serve")
        for r in queue:
            if r.max_new_tokens <= 0:
                raise ValueError(
                    f"request {r.uid}: max_new_tokens must be positive, "
                    f"got {r.max_new_tokens}")
            if len(r.prompt) == 0:
                raise ValueError(f"request {r.uid}: empty prompt")
            if len(r.prompt) > self.window:
                raise ValueError(
                    f"request {r.uid}: prompt of {len(r.prompt)} tokens "
                    f"exceeds the engine window ({self.window})")

    def run(self, queue: List[Request]) -> Tuple[List[Request], Dict[str, float]]:
        self._validate_queue(queue)
        done: List[Request] = []
        for group in self.schedule(queue):
            t_start = time.perf_counter()
            logits, cache, s = self.prefill(group)
            done += self.decode_loop(group, logits, cache, s, t_start=t_start)
        return done, self.stats.summary()
