"""Batched scenario-grid planning (DESIGN.md §planner).

The fused planner traces deadline, ε and B (only fleet *shape*, policy and
iteration counts are static), so whole scenario sweeps — Fig. 13/14's
deadline×ε grids, per-request planning in the two-tier engine, bandwidth
what-ifs — vmap over one compiled program instead of re-dispatching
``plan()`` per scenario.

``plan_grid`` evaluates the full cartesian product

    deadlines (D,) × epss (E,) × Bs (K,)

and returns a ``Plan`` whose every leaf carries leading axes (D, E, K):
``out.m_sel[i, j, k]`` is the plan for ``(deadlines[i], epss[j], Bs[k])``.
Scalars are treated as length-1 axes, so ``plan_grid(fleet, 0.2, eps_grid,
B)`` sweeps ε only. Each scenario is planned exactly as ``plan()`` would
(including the vmapped multi-start sweep and its feasibility-then-energy
selection), so ``plan_grid(...)[i, j, k] == plan(...)`` leaf-for-leaf.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.blocks import Fleet
from repro.core.planner import (
    Plan,
    _POLICIES,
    _alternation,
    _multi_start,
    initial_points,
)

_STATICS = ("policy", "outer_iters", "pccp_iters", "channel_cv", "multi_start")


@partial(jax.jit, static_argnames=_STATICS)
def _grid_impl(fleet, deadlines, epss, Bs, m0, *, policy, outer_iters,
               pccp_iters, channel_cv, multi_start):
    dd, ee, bb = jnp.meshgrid(deadlines, epss, Bs, indexing="ij")
    shape = dd.shape

    if multi_start:
        run = lambda d, e, b: _multi_start(
            fleet, d, e, b, m0, policy, outer_iters, pccp_iters, channel_cv)
    else:
        run = lambda d, e, b: _alternation(
            fleet, d, e, b, m0, policy, outer_iters, pccp_iters, channel_cv)

    plans = jax.vmap(run)(dd.ravel(), ee.ravel(), bb.ravel())
    return jax.tree_util.tree_map(
        lambda x: x.reshape(shape + x.shape[1:]), plans)


def plan_grid(
    fleet: Fleet,
    deadlines,
    epss,
    Bs,
    policy: str = "robust",
    outer_iters: int = 6,
    init_m: Optional[jnp.ndarray] = None,
    pccp_iters: int = 10,
    multi_start: bool = True,
    channel_cv: float = 0.0,
) -> Plan:
    """Plan every scenario in deadlines × epss × Bs as ONE XLA program.

    Returns a ``Plan`` with leading grid axes (len(deadlines), len(epss),
    len(Bs)) on every leaf. See module docstring for semantics.
    """
    if policy not in _POLICIES or policy == "optimal":
        raise ValueError(
            f"policy must be one of {_POLICIES[:-1]} for grid planning, got {policy!r}")
    if outer_iters < 1:
        raise ValueError("outer_iters must be >= 1")

    as_axis = lambda v: jnp.atleast_1d(jnp.asarray(v, jnp.float64))
    deadlines, epss, Bs = as_axis(deadlines), as_axis(epss), as_axis(Bs)

    m0, use_multi = initial_points(fleet, init_m, multi_start)
    return _grid_impl(
        fleet, deadlines, epss, Bs, m0,
        policy=policy, outer_iters=int(outer_iters), pccp_iters=int(pccp_iters),
        channel_cv=float(channel_cv), multi_start=use_multi,
    )


def plan_at(plans: Plan, i: int, j: int = 0, k: int = 0) -> Plan:
    """Extract the single-scenario ``Plan`` at grid index (i, j, k)."""
    return jax.tree_util.tree_map(lambda x: x[i, j, k], plans)
