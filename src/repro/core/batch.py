"""Batched scenario-grid planning — deprecated delegating wrappers.

``plan_grid``/``plan_at`` predate the first-class Scenario/Planner API
(``repro.core.api``) and now delegate to it: ``plan_grid`` is
``Planner(...).grid(...)`` (cartesian sugar over the zipped
``plan_many``), so every policy in the registry — **including
"optimal"**, which the old grid path rejected — batch-dispatches through
one compiled program. Kept because the grid shape contract
(``out.m_sel[i, j, k]`` is the plan for ``(deadlines[i], epss[j],
Bs[k])``, leaf-identical to ``plan()``) is pinned by tests and used by
the figure benchmarks.

New code should call ``api.Planner.grid`` / ``api.Planner.plan_many``
directly — zipped batches of arbitrary scenarios (heterogeneous
per-device SLOs) are strictly more general than cartesian grids.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.blocks import Fleet
from repro.core.planner import Plan


def plan_grid(
    fleet: Fleet,
    deadlines,
    epss,
    Bs,
    policy: str = "robust",
    outer_iters: int = 6,
    init_m: Optional[jnp.ndarray] = None,
    pccp_iters: int = 10,
    multi_start: bool = True,
    channel_cv: float = 0.0,
) -> Plan:
    """Plan every scenario in deadlines × epss × Bs as ONE XLA program.

    .. deprecated::
        Delegates to ``api.Planner.grid``. Returns a ``Plan`` with leading
        grid axes (len(deadlines), len(epss), len(Bs)) on every leaf; each
        cell equals the corresponding single ``plan()`` leaf-for-leaf.
    """
    import warnings

    from repro.core.api import Planner, PlannerConfig

    warnings.warn(
        "repro.core.plan_grid is deprecated; use "
        "api.Planner(PlannerConfig(...)).grid(...) or .plan_many(...)",
        DeprecationWarning, stacklevel=2)
    cfg = PlannerConfig(policy=policy, outer_iters=outer_iters,
                        pccp_iters=pccp_iters, multi_start=multi_start,
                        channel_cv=channel_cv)
    return Planner(cfg).grid(fleet, deadlines, epss, Bs, init_m=init_m)


def plan_at(plans: Plan, i: int, j: int = 0, k: int = 0) -> Plan:
    """Extract the single-scenario ``Plan`` at grid index (i, j, k).

    Only grid plans (leading ``(D, E, K)`` axes from ``plan_grid`` /
    ``Planner.grid``) are indexable here; single plans need no indexing
    and zipped ``plan_many`` batches use ``api.scenario_at``.
    """
    lead = jnp.shape(plans.total_energy)
    if len(lead) != 3:
        kind = ("a single plan" if len(lead) == 0 else
                "a plan_many batch (use api.scenario_at)" if len(lead) == 1 else
                f"a Plan with {len(lead)} leading axes")
        raise ValueError(
            "plan_at expects a grid Plan with (deadline, eps, B) leading "
            f"axes on every leaf; got {kind} (total_energy shape {lead})")
    for name, idx, dim in (("i", i, lead[0]), ("j", j, lead[1]), ("k", k, lead[2])):
        if not -dim <= idx < dim:
            raise IndexError(
                f"grid index {name}={idx} out of range for axis of length "
                f"{dim} (grid shape {lead})")
    return jax.tree_util.tree_map(lambda x: x[i, j, k], plans)
