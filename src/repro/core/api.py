"""First-class Scenario/Planner API (DESIGN.md §api).

Three types carve the planning surface at its natural joint — *what is
traced* vs *what is static*:

- :class:`Scenario` — the traced leaves of one planning problem:
  ``deadline``, ``eps`` (each scalar or per-device ``(N,)``) and the
  bandwidth budget ``B``. A ``Scenario`` is a pytree; changing its values
  never recompiles.
- :class:`PlannerConfig` — the statics: policy, iteration counts,
  multi-start, channel robustness. Changing any of these is a new XLA
  program.
- :class:`Planner` — one compiled entry point over both:
  ``plan(fleet, scenario)`` for a single scenario,
  ``plan_many(fleet, scenarios)`` for a **zipped** batch of K arbitrary
  scenarios (heterogeneous per-device SLOs, arbitrary mixes — not just
  cartesian grids) vmapped over one program, and
  ``grid(fleet, deadlines, epss, Bs)`` as cartesian sugar over
  ``plan_many``.

Policies dispatch through the :class:`repro.core.planner.Policy` registry,
so ``"optimal"`` batches like any other policy and new policies are a
``register_policy`` call away.

Fleets may be **ragged** (mixed models with different partition-point
counts ``M_n`` — DESIGN.md §fleet): the ``valid`` mask and per-device
``num_points`` are ordinary *traced* pytree leaves of ``Fleet``, so two
mixed fleets with the same padded shapes share one compiled program, and
mask values never appear in the jit cache key.

The legacy ``core.plan`` / ``core.batch.plan_grid`` functions are
deprecated delegating wrappers over this module.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import NamedTuple, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp

from repro.core.blocks import Fleet
from repro.core.pccp import SOLVERS
from repro.core.planner import (
    PLAN_FALLBACK_DENSE,
    PLAN_FALLBACK_INCUMBENT,
    Plan,
    Policy,
    _alternation,
    _multi_start,
    _solve_entry,
    available_policies,
    get_policy,
    initial_points,
    pccp_partition_step,
    plan_health,
    plan_multi_jit,
    plan_single_jit,
    plan_solve_jit,
    register_policy,
)

__all__ = [
    "Scenario", "PlannerConfig", "Planner", "Policy",
    "register_policy", "get_policy", "available_policies",
    "plan_many_jit", "scenario_at",
]


class Scenario(NamedTuple):
    """One planning problem's traced parameters (a pytree).

    ``deadline`` / ``eps`` may be scalars or per-device ``(N,)`` arrays —
    heterogeneous SLOs and risk levels per device are first-class. ``B``
    is the fleet's total uplink bandwidth budget (scalar; it couples the
    devices through Σ b_n ≤ B, so a per-device B has no meaning).

    ``edge_capacity_s`` is the shared-edge VM-time budget per inference
    round (scalar seconds; DESIGN.md §edge): the planner prices
    Σ_n t̄_vm(m_n) ≤ C_edge with a second dual price μ next to the
    bandwidth λ. ``None`` (the default) means a dedicated VM per device —
    the paper's §III-B assumption — and normalizes to ∞, under which the
    edge pricing is a numerical no-op. It is a *traced leaf*, so capacity
    sweeps batch through ``plan_many``/``grid`` without recompiling.

    An ``(E,)`` vector of per-node capacities (E ≥ 2) turns the single
    shared edge into E placement nodes (DESIGN.md §placement): the
    planner then also picks a device→node assignment and clears one
    price μ_e per node. A 0 entry marks an absent node (never assigned),
    which keeps node-count what-ifs on one traced shape; a ``(1,)``
    vector collapses to the scalar path so E=1 stays leaf-identical to
    the scalar goldens.
    """

    deadline: jnp.ndarray  # s — scalar or (N,)
    eps: jnp.ndarray  # risk level in (0, 1) — scalar or (N,)
    B: jnp.ndarray  # Hz — scalar bandwidth budget
    edge_capacity_s: Optional[jnp.ndarray] = None  # s — scalar or (E,); None → ∞

    def normalized(self, num_devices: int) -> "Scenario":
        """Broadcast deadline/eps to ``(N,)``, B/edge capacity to scalars."""
        f64 = lambda v: jnp.asarray(v, jnp.float64)

        def per_device(v, name):
            a = f64(v)
            # size-1 arrays broadcast like scalars (legacy plan() accepted them)
            if a.ndim > 1 or (a.ndim == 1 and a.shape[0] not in (1, num_devices)):
                raise ValueError(
                    f"Scenario.{name} must be a scalar or a per-device "
                    f"({num_devices},) array, got shape {a.shape}")
            return jnp.broadcast_to(a, (num_devices,))

        b = f64(self.B)
        if b.size != 1:
            raise ValueError(
                "Scenario.B is the fleet-wide bandwidth budget and must be "
                f"a scalar, got shape {b.shape}")
        cap = f64(jnp.inf if self.edge_capacity_s is None
                  else self.edge_capacity_s)
        if cap.ndim >= 2:
            raise ValueError(
                "Scenario.edge_capacity_s must be a scalar (one shared "
                "edge) or a per-node (E,) capacity vector, got shape "
                f"{cap.shape}")
        if cap.size == 1:
            # E=1 reduction policy (DESIGN.md §placement): a 1-node vector
            # IS the scalar shared edge — collapse it so E=1 plans stay
            # leaf-identical to the scalar-path goldens by construction.
            cap = jnp.reshape(cap, ())
        return Scenario(
            deadline=per_device(self.deadline, "deadline"),
            eps=per_device(self.eps, "eps"),
            B=jnp.reshape(b, ()),
            edge_capacity_s=cap,
        )


def stack_scenarios(
    scenarios: Union["Scenario", Sequence["Scenario"]], num_devices: int
) -> Scenario:
    """Zip K scenarios into one ``Scenario`` with leading axis K.

    Accepts a sequence of ``Scenario`` (each normalized to per-device
    form, then stacked → leaves ``(K, N)``, ``(K, N)``, ``(K,)``) or an
    already-stacked ``Scenario`` whose leaves carry a leading K axis.
    """
    if isinstance(scenarios, Scenario):
        f64 = lambda v: jnp.asarray(v, jnp.float64)
        d, e, b = f64(scenarios.deadline), f64(scenarios.eps), f64(scenarios.B)
        if b.ndim != 1:
            raise ValueError(
                "a pre-stacked Scenario batch needs leaves with a leading "
                f"scenario axis K: B must be (K,), got shape {b.shape}")
        k = b.shape[0]

        def fix(a, name):
            if a.ndim == 0:  # same value for every scenario
                return jnp.broadcast_to(a, (k,))
            if a.ndim not in (1, 2) or a.shape[0] != k or (
                    a.ndim == 2 and a.shape[1] != num_devices):
                raise ValueError(
                    f"scenario batch leaf {name!r} must be (K,) or (K, N) "
                    f"with K={k}, N={num_devices}, got shape {a.shape}")
            return a

        cap = f64(jnp.inf if scenarios.edge_capacity_s is None
                  else scenarios.edge_capacity_s)
        if cap.ndim == 0:
            cap = jnp.broadcast_to(cap, (k,))
        elif cap.ndim == 2 and cap.shape[1] == 1:
            cap = cap[:, 0]  # (K, 1) rows ARE the scalar edge (E=1 policy)
        if (cap.ndim not in (1, 2) or cap.shape[0] != k):
            raise ValueError(
                "scenario batch leaf 'edge_capacity_s' must be a scalar, "
                f"(K,) of scalar capacities, or (K, E) per-node capacity "
                f"rows with K={k}, got shape {cap.shape}")
        return Scenario(fix(d, "deadline"), fix(e, "eps"), b, cap)
    if len(scenarios) == 0:
        raise ValueError("plan_many needs at least one scenario")
    norm = [Scenario(*s).normalized(num_devices) for s in scenarios]
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *norm)


@dataclass(frozen=True)
class PlannerConfig:
    """The planner's static knobs.

    ``policy``, the iteration counts, ``multi_start`` and ``channel_cv``
    are jit cache keys — changing any of them compiles a new program.
    ``init_m`` is the exception: it must be hashable here (an int start,
    or None for the default) but is *resolved to a traced start array*,
    so varying it — or passing array warm starts via the ``init_m=``
    argument of ``Planner.plan*`` — never recompiles. ``policy`` is a
    registry name (or a ``Policy`` record directly).

    ``edge_capacity_s`` is a *default* for scenarios that leave their own
    ``edge_capacity_s`` unset (``None`` here means no default → dedicated
    VMs). Despite living on the config it is resolved into the scenario's
    traced leaf, so varying it never recompiles either.

    ``solver`` selects the PCCP inner-barrier path (DESIGN.md §solver):
    ``"structured"`` (default) is the structure-exploiting closed-form
    KKT solver, ``"dense"`` the generic autodiff A/B reference — both
    golden-pinned to the same plans. ``pccp_gated`` swaps the PCCP outer
    scan for the early-exiting while_loop (Algorithm 1's θ_err stopping
    rule); keep the default ``False`` for grid/batch planning, where the
    vmapped while_loop runs to the slowest lane anyway and the gated
    fixed point is not bit-comparable to the golden scan path.
    """

    policy: Union[str, Policy] = "robust"
    outer_iters: int = 6
    pccp_iters: int = 10
    multi_start: bool = True
    init_m: Optional[int] = None
    channel_cv: float = 0.0
    #: scalar shared-edge budget, or a tuple of per-node capacities
    #: (DESIGN.md §placement) — resolved into the scenario's traced leaf.
    edge_capacity_s: Optional[Union[float, Tuple[float, ...]]] = None
    #: Cantelli edge-occupancy risk: with ``edge_eps=ε`` the capacity rows
    #: tighten to P{Σ t_vm > C_e} ≤ ε (DESIGN.md §placement). A jit cache
    #: key (it scales a variance term inside the trace); ``None`` keeps
    #: the mean occupancy row bit-for-bit.
    edge_eps: Optional[float] = None
    solver: str = "structured"
    pccp_gated: bool = False
    #: solver fail-soft (DESIGN.md §robustness): after ``plan()``, check
    #: the result's health on the host (finite leaves, no DEGRADED stamp,
    #: PCCP not stuck-and-infeasible) and, when unhealthy, fall back —
    #: dense inner solver first, then the caller's ``incumbent=`` plan —
    #: instead of returning garbage. A healthy solve is returned
    #: unchanged (leaf-identical to ``fail_soft=False``); the fallbacks
    #: announce themselves via ``Plan.status`` and a warning.
    #: ``plan_many``/``grid`` skip the check (batched plans stay on
    #: device; score them with ``plan_health`` per scenario if needed).
    fail_soft: bool = True

    def __post_init__(self):
        if self.outer_iters < 1:
            raise ValueError("outer_iters must be >= 1")
        if self.pccp_iters < 1:
            raise ValueError("pccp_iters must be >= 1")
        if isinstance(self.edge_capacity_s, (list, tuple)):
            caps = tuple(float(c) for c in self.edge_capacity_s)
            object.__setattr__(self, "edge_capacity_s", caps)
            if len(caps) == 0 or any(c < 0 for c in caps) \
                    or not any(c > 0 for c in caps):
                raise ValueError(
                    "a per-node edge_capacity_s tuple needs entries >= 0 "
                    "with at least one node > 0 (0 marks an absent node)")
        elif self.edge_capacity_s is not None and not self.edge_capacity_s > 0:
            raise ValueError("edge_capacity_s must be positive (or None)")
        if self.edge_eps is not None and not 0.0 < self.edge_eps < 1.0:
            raise ValueError("edge_eps must lie in (0, 1) (or None)")
        if self.solver not in SOLVERS:
            raise ValueError(
                f"solver must be one of {SOLVERS}, got {self.solver!r}")
        get_policy(self.policy)  # fail fast on unknown policies

    def resolved_policy(self) -> Policy:
        return get_policy(self.policy)


_BATCH_STATICS = ("policy", "outer_iters", "pccp_iters", "channel_cv",
                  "multi_start", "solver", "pccp_gated", "edge_eps")


@partial(jax.jit, static_argnames=_BATCH_STATICS)
def _plan_many_impl(fleet, scenarios: Scenario, m0, *, policy: Policy,
                    outer_iters, pccp_iters, channel_cv, multi_start,
                    solver, pccp_gated, edge_eps=None):
    """K zipped scenarios vmapped over ONE compiled program.

    Each scenario is planned exactly as the single-scenario entry would
    (including the vmapped multi-start sweep and its
    feasibility-then-energy selection), so ``plan_many(...)[k]`` equals
    ``plan(...)`` leaf-for-leaf.
    """
    if policy.solve is not None:
        run = lambda d, e, b, cap: _solve_entry(
            fleet, d, e, b, cap, policy, outer_iters, pccp_iters, channel_cv,
            solver, pccp_gated, edge_eps)
    elif multi_start:
        run = lambda d, e, b, cap: _multi_start(
            fleet, d, e, b, cap, m0, policy, outer_iters, pccp_iters,
            channel_cv, solver, pccp_gated, edge_eps)
    else:
        run = lambda d, e, b, cap: _alternation(
            fleet, d, e, b, cap, m0, policy, outer_iters, pccp_iters,
            channel_cv, solver, pccp_gated, edge_eps)
    return jax.vmap(run)(scenarios.deadline, scenarios.eps, scenarios.B,
                         scenarios.edge_capacity_s)


#: Public alias — tests assert jit-cache behaviour via ``_cache_size()``.
plan_many_jit = _plan_many_impl


@dataclass(frozen=True)
class Planner:
    """One compiled planning entry point for a fixed :class:`PlannerConfig`.

    All three methods share the same traced building blocks and jit
    caches, so mixing ``plan`` / ``plan_many`` / ``grid`` calls on
    same-shaped fleets never retraces.
    """

    config: PlannerConfig = PlannerConfig()

    @property
    def policy(self) -> Policy:
        return self.config.resolved_policy()

    def _statics(self):
        c = self.config
        return dict(policy=self.policy, outer_iters=int(c.outer_iters),
                    pccp_iters=int(c.pccp_iters),
                    channel_cv=float(c.channel_cv), solver=str(c.solver),
                    pccp_gated=bool(c.pccp_gated),
                    edge_eps=None if c.edge_eps is None else float(c.edge_eps))

    def _starts(self, fleet: Fleet, init_m):
        if init_m is None:
            init_m = self.config.init_m
        return initial_points(fleet, init_m, self.config.multi_start)

    def _apply_edge_default(self, sc: Scenario) -> Scenario:
        """Fill the config's ``edge_capacity_s`` default into scenarios
        that leave their own unset (the scenario leaf always wins)."""
        if sc.edge_capacity_s is None and self.config.edge_capacity_s is not None:
            return sc._replace(edge_capacity_s=self.config.edge_capacity_s)
        return sc

    def _dispatch(self, fleet: Fleet, init_m):
        """Shared host-side dispatch: resolve (statics, m0, use_multi).

        The single place that decides how a policy enters the compiled
        program — solve overrides take a placeholder start (they never
        alternate, so an explicit warm start is a caller error), everything
        else resolves ``initial_points``. Both ``plan`` and ``plan_many``
        go through here so they cannot diverge from the
        ``plan_many(...)[k] == plan(...)`` contract.
        """
        statics = self._statics()
        if statics["policy"].solve is not None:
            if init_m is not None or self.config.init_m is not None:
                raise ValueError(
                    f"policy {statics['policy'].name!r} solves exactly "
                    "(no alternation), so init_m warm starts have no effect "
                    "— drop init_m or pick an alternating policy")
            return statics, jnp.zeros((fleet.num_devices,), jnp.int32), False
        m0, use_multi = self._starts(fleet, init_m)
        return statics, m0, use_multi

    def plan(self, fleet: Fleet, scenario: Scenario, init_m=None,
             incumbent: Optional[Plan] = None) -> Plan:
        """Plan one scenario. ``init_m`` (scalar or (N,) array) overrides
        the config's static start — it is traced, not a cache key.

        ``incumbent`` is the fail-soft safety net (DESIGN.md
        §robustness): a known-good plan to return — stamped
        ``PLAN_FALLBACK_INCUMBENT`` — if the solve *and* the dense-solver
        retry both come back unhealthy. It never influences a healthy
        solve (pass it via ``init_m`` to warm-start instead).
        """
        sc = self._apply_edge_default(Scenario(*scenario))
        sc = sc.normalized(fleet.num_devices)
        statics, m0, use_multi = self._dispatch(fleet, init_m)
        if statics["policy"].solve is not None:
            p = plan_solve_jit(fleet, sc.deadline, sc.eps, sc.B,
                               sc.edge_capacity_s, **statics)
            entry = None
        else:
            entry = plan_multi_jit if use_multi else plan_single_jit  # analyze: ok(TRC003): host dispatch on static config/multi-start shape
            p = entry(fleet, sc.deadline, sc.eps, sc.B, sc.edge_capacity_s,
                      m0, **statics)
        if not self.config.fail_soft or isinstance(p.total_energy,
                                                   jax.core.Tracer):
            return p  # disabled, or called under tracing (no host syncs)
        cap = (int(self.config.pccp_iters)
               if statics["policy"].partition is pccp_partition_step else None)
        ok, reason = plan_health(p, pccp_iter_cap=cap)
        if ok:  # analyze: ok(TRC003): host fail-soft verdict; tracing returned above
            return p
        import warnings

        if entry is not None and statics["solver"] != "dense":  # analyze: ok(TRC003): host fail-soft ladder on static config
            warnings.warn(f"plan fail-soft: {reason}; retrying with the "
                          "dense inner solver", RuntimeWarning, stacklevel=2)
            dense = dict(statics, solver="dense")
            p_dense = entry(fleet, sc.deadline, sc.eps, sc.B,
                            sc.edge_capacity_s, m0, **dense)
            if plan_health(p_dense, pccp_iter_cap=cap)[0]:  # analyze: ok(TRC003): host fail-soft verdict on the dense retry
                return p_dense._replace(
                    status=jnp.asarray(PLAN_FALLBACK_DENSE, jnp.int32))
        if incumbent is not None:
            warnings.warn(f"plan fail-soft: {reason}; returning the incumbent "
                          "plan", RuntimeWarning, stacklevel=2)
            return incumbent._replace(
                status=jnp.asarray(PLAN_FALLBACK_INCUMBENT, jnp.int32))
        warnings.warn(f"plan fail-soft: {reason}; no incumbent to fall back "
                      "to — returning the degraded plan", RuntimeWarning,
                      stacklevel=2)
        return p

    def plan_many(self, fleet: Fleet,
                  scenarios: Union[Scenario, Sequence[Scenario]],
                  init_m=None) -> Plan:
        """Plan K zipped scenarios as ONE XLA program.

        ``scenarios`` is a sequence of :class:`Scenario` (heterogeneous
        mixes welcome — per-device deadlines/eps in some, scalars in
        others) or a pre-stacked ``Scenario`` with leading axis K on every
        leaf. Returns a ``Plan`` whose every leaf has leading axis K;
        ``plan_many(...)[k] == plan(fleet, scenarios[k])`` leaf-for-leaf.
        """
        if isinstance(scenarios, Scenario):
            scenarios = self._apply_edge_default(scenarios)
        else:
            scenarios = [self._apply_edge_default(Scenario(*s))
                         for s in scenarios]
        batch = stack_scenarios(scenarios, fleet.num_devices)
        statics, m0, use_multi = self._dispatch(fleet, init_m)
        return plan_many_jit(fleet, batch, m0, multi_start=use_multi, **statics)

    def plan_sharded(self, spec, scenario: Scenario, *, key=None, gains=None,
                     mesh=None, init_m: Optional[int] = None) -> Plan:
        """Plan a mixed fleet through the group decomposition
        (``core.decompose``; DESIGN.md §scale).

        Takes the :class:`~repro.core.fleet.FleetSpec` — the grouping
        truth — instead of a built ``Fleet``: each homogeneous population
        runs its own compiled program at native ``(n_g, M_g+1)`` shape and
        the populations are coordinated only through the scalar dual
        prices (λ, μ) in a host-level outer bisection. Plans match
        ``plan(spec.build(key), scenario)`` leaf-wise at rtol ≤ 1e-6 for
        the exact-enumeration policies; use it when the padded monolithic
        program is too wide (mixed 8-vs-64-block fleets) or too big
        (10⁵–10⁶ devices) to compile as one.

        ``key``/``gains`` fix the link gains exactly as ``FleetSpec.build``
        would; ``mesh`` is a ``parallel.sharding.planner_mesh`` to shard
        device lanes across (defaults to all local devices); ``init_m``
        must be a scalar (per-device warm-start arrays stay on the
        monolithic path). No fail-soft ladder — ``Plan.status`` still
        carries the traced health stamp.
        """
        from repro.core.decompose import plan_sharded as _plan_sharded

        sc = self._apply_edge_default(Scenario(*scenario))
        return _plan_sharded(spec, sc, self.config, key=key, gains=gains,
                             mesh=mesh, init_m=init_m)

    def grid(self, fleet: Fleet, deadlines, epss, Bs, edge_capacities=None,
             init_m=None) -> Plan:
        """Cartesian sugar over ``plan_many``: every scenario in
        deadlines × epss × Bs (× edge_capacities), one compiled program.

        Returns a ``Plan`` with leading axes (len(deadlines), len(epss),
        len(Bs)) on every leaf; scalars are length-1 axes, so
        ``grid(fleet, 0.2, eps_grid, B)`` sweeps ε only. Passing
        ``edge_capacities`` appends a fourth shared-edge-capacity axis
        (DESIGN.md §edge) — left at ``None`` the config default (or ∞)
        applies to every cell and the grid keeps its three axes.

        ``edge_capacities`` may also be a (K, E) array of per-node
        capacity rows (DESIGN.md §placement): the fourth axis then sweeps
        placement what-ifs — "add one edge node vs upgrade two" as rows
        of one compiled sweep (0 marks an absent node, so node-count
        variants share the traced shape E).
        """
        as_axis = lambda v: jnp.atleast_1d(jnp.asarray(v, jnp.float64))
        axes = [as_axis(deadlines), as_axis(epss), as_axis(Bs)]
        cap_rows = None
        if edge_capacities is not None:
            caps = jnp.asarray(edge_capacities, jnp.float64)
            if caps.ndim == 2 and caps.shape[1] == 1:
                caps = caps[:, 0]  # (K, 1) rows ARE the scalar edge
            if caps.ndim == 2:
                cap_rows = caps  # (K, E): sweep rows via a float index axis
                axes.append(jnp.arange(caps.shape[0], dtype=jnp.float64))
            else:
                axes.append(as_axis(caps))
        mesh = jnp.meshgrid(*axes, indexing="ij")
        shape = mesh[0].shape
        leaves = [a.ravel() for a in mesh]
        if cap_rows is not None:
            idx = leaves[3].astype(jnp.int32)
            batch = Scenario(leaves[0], leaves[1], leaves[2], cap_rows[idx])
        else:
            batch = Scenario(*leaves)
        plans = self.plan_many(fleet, batch, init_m=init_m)
        return jax.tree_util.tree_map(
            lambda x: x.reshape(shape + x.shape[1:]), plans)


def scenario_at(plans: Plan, k: int) -> Plan:
    """Extract scenario ``k`` from a ``plan_many`` batch (leading axis K)."""
    lead = jnp.shape(plans.total_energy)
    if len(lead) != 1:
        raise ValueError(
            "scenario_at expects a plan_many batch (every leaf with one "
            f"leading scenario axis); got total_energy shape {lead}. For "
            "grid plans use plan_at(plans, i, j, k).")
    if not -lead[0] <= k < lead[0]:
        raise IndexError(f"scenario index {k} out of range for batch of {lead[0]}")
    return jax.tree_util.tree_map(lambda x: x[k], plans)
