"""Resource-allocation subproblem (paper §V-B, problems (16) → (23)).

Given a partition decision m_n per device, jointly allocate uplink
bandwidth b_n (Σ b_n ≤ B) and DVFS frequency f_n ∈ [f_min, f_max] to
minimize expected energy under the ECR-deterministic deadline (22).

Two solvers:

- ``allocate`` (primary): Lagrangian dual on the single coupling
  constraint Σ b_n ≤ B. For a bandwidth price λ the problem separates per
  device; the inner 1-D problem over b is convex (partial minimization
  over f is closed-form), solved by grid+golden section; λ is found by
  bisection on Σ b*(λ) − B. Strong duality holds (convex + Slater), so
  this matches the paper's interior-point optimum.
- ``allocate_ipm`` (cross-check): the paper-faithful joint interior-point
  solve of (23) in scaled variables, used in tests to certify ``allocate``.

Shared-edge capacity (DESIGN.md §edge): beyond the paper's dedicated-VM
assumption (§III-B), the edge accelerator may be a *shared* resource with
a per-round VM-time budget  Σ_n occ_n(m_n) ≤ C_edge, where
occ_n = t̄_vm at device n's selected point. At a fixed partition the
occupancies are constants, so ``allocate`` only *checks* the capacity
(feasibility flags) and records the operative edge price μ; the price
itself is discovered where the partition is chosen — the (λ, μ) two-price
search in ``planner.plan_optimal`` and the per-step clearing price of the
Algorithm-2 alternation — both built on this module's price-bracket
helpers.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ccp, channel, energy, placement
from repro.core.blocks import Fleet
from repro.solvers.scalar import bisect, golden_section
from repro.solvers.ipm import BarrierSpec, barrier_solve

_BIG = 1e9
_TINY_B = 1e-3  # Hz floor for allocated bandwidth

#: Dual-price searches run in log10 space. The seed bracket top (λ = 10²)
#: is right for paper-scale scenarios; when the market-clearing price is
#: higher (extreme bandwidth/capacity starvation) the bracket is expanded
#: adaptively up to 10¹⁸ — beyond that the constraint cannot be priced
#: out (the λ-invariant feasibility floors alone overrun the budget) and
#: the caller flags infeasibility instead of silently rescaling.
_LOG_PRICE_LO = -16.0
_LOG_PRICE_HI0 = 2.0
_LOG_PRICE_HI_MAX = 18.0
_LOG_PRICE_STEP = 4.0
#: relative tolerance of the Σ occ ≤ C_edge capacity check
_EDGE_CAP_RTOL = 1e-9


def _expand_log_bracket(excess_fn, hi_start=None):
    """Adaptively raise the log-price bracket top until the excess changes
    sign. Returns ``(hi, f_hi)``; ``f_hi > 0`` after expansion means even
    the max price cannot clear the constraint (⇒ infeasible). The common
    case (``excess(HI0) ≤ 0``) costs one extra evaluation and leaves the
    seed bracket — and therefore the bisection trajectory — unchanged.

    ``hi_start`` (traced scalar, optional) warm-starts the search from a
    prior bracket top — e.g. the previous Algorithm-2 step's result. It is
    snapped to the expansion grid ``HI0 + k·STEP`` (the only values a cold
    expansion can produce; all grid points are exact in float64), then
    *contracted* while the next-lower grid point still clears and expanded
    as usual. Because the excess is monotone non-increasing in the price,
    both directions terminate at the same grid point a cold expansion
    finds, so the warm path is **value-identical** to cold-start — it just
    spends its evaluations near the answer instead of walking up from HI0.
    """
    hi0 = jnp.asarray(_LOG_PRICE_HI0, jnp.float64)

    if hi_start is None:
        start, f_start = hi0, excess_fn(hi0)
    else:
        k = jnp.round((jnp.asarray(hi_start, jnp.float64) - hi0)
                      / _LOG_PRICE_STEP)
        k_max = (_LOG_PRICE_HI_MAX - _LOG_PRICE_HI0) // _LOG_PRICE_STEP
        start = hi0 + jnp.clip(k, 0.0, k_max) * _LOG_PRICE_STEP
        f_start = excess_fn(start)

        # Contract: while the grid point one step down still clears
        # (excess ≤ 0), move down. Carries (hi, f_hi, f_dn) where f_dn is
        # the excess one step below hi (a sentinel +1 at the grid floor).
        def probe_down(hi):
            return jnp.where(hi > hi0 + 1e-9, excess_fn(hi - _LOG_PRICE_STEP),
                             1.0)

        def c_cond(state):
            hi, _, f_dn = state
            return (hi > hi0 + 1e-9) & (f_dn <= 0.0)

        def c_body(state):
            hi, _, f_dn = state
            hi = hi - _LOG_PRICE_STEP
            return hi, f_dn, probe_down(hi)

        start, f_start, _ = jax.lax.while_loop(
            c_cond, c_body, (start, f_start, probe_down(start)))

    def cond(state):
        hi, f_hi = state
        return (f_hi > 0.0) & (hi < _LOG_PRICE_HI_MAX - 1e-9)

    def body(state):
        hi, _ = state
        hi = hi + _LOG_PRICE_STEP
        return hi, excess_fn(hi)

    return jax.lax.while_loop(cond, body, (start, f_start))


class Selected(NamedTuple):
    """Per-device chain quantities at the chosen partition point."""

    d_bits: jnp.ndarray
    w_flops: jnp.ndarray
    g_eff: jnp.ndarray
    v_loc: jnp.ndarray
    t_vm: jnp.ndarray
    v_vm: jnp.ndarray


class Allocation(NamedTuple):
    b: jnp.ndarray  # (N,) Hz
    f: jnp.ndarray  # (N,) Hz
    e_loc: jnp.ndarray  # (N,) J (expected)
    e_off: jnp.ndarray  # (N,) J
    feasible: jnp.ndarray  # (N,) bool
    lam: jnp.ndarray  # scalar dual price of bandwidth
    mu: jnp.ndarray = 0.0  # scalar dual price of shared-edge VM capacity

    @property
    def energy(self):
        return self.e_loc + self.e_off


def select_point(fleet: Fleet, m_sel: jnp.ndarray) -> Selected:
    """Gather chain columns at per-device partition points (N,).

    On ragged fleets the gather index is clamped to each device's own
    chain (``m ≤ M_n``), so a padded point can never be selected — every
    consumer of a partition decision (``allocate``, the final plan
    summary, ``montecarlo.violation_report``) inherits the guarantee.
    """
    c = fleet.chain
    if fleet.num_points is not None:
        m_sel = jnp.minimum(m_sel, fleet.num_points - 1)
    take = lambda a: jnp.take_along_axis(a, m_sel[:, None], axis=-1)[:, 0]
    return Selected(
        d_bits=take(c.d_bits),
        w_flops=take(c.w_flops),
        g_eff=take(c.g_eff),
        v_loc=take(c.v_loc),
        t_vm=take(c.t_vm),
        v_vm=take(c.v_vm),
    )


def deadline_budget(sel: Selected, deadline, eps, sigma_model="cantelli", ub_k=0.0):
    """D' = D − t̄_vm − σ(ε)·√(v_loc+v_vm) − ub_k·(√v_loc+√v_vm).

    The local+offload time must fit inside D'. ``ub_k`` > 0 implements the
    worst-case baseline (§VI: "upper bound of t_loc and t_vm"): means are
    replaced by mean + ub_k·std and no probabilistic slack is taken.
    """
    sig = ccp.SIGMA_FNS[sigma_model](eps)
    return (
        deadline
        - sel.t_vm
        - sig * jnp.sqrt(jnp.maximum(sel.v_loc + sel.v_vm, 0.0))
        - ub_k * (jnp.sqrt(jnp.maximum(sel.v_loc, 0.0)) + jnp.sqrt(jnp.maximum(sel.v_vm, 0.0)))
    )


def _budget_eff(b, budget, d, p_tx, gain, sigma, v_base, channel_cv):
    """Effective ECR budget at bandwidth b (paper footnote 2).

    With channel uncertainty (``channel_cv`` > 0) the offload time is
    random too: Var[T] = v_base + v_off(b) and the budget shrinks by
    σ·(√(v_base+v_off(b)) − √v_base). ``channel_cv`` is a static Python
    float, so the branch resolves at trace time.
    """
    if channel_cv <= 0.0:
        return budget
    std_off = channel.offload_time_std(d, b, p_tx, gain, channel_cv)
    return budget - sigma * (
        jnp.sqrt(jnp.maximum(v_base + std_off**2, 0.0))
        - jnp.sqrt(jnp.maximum(v_base, 0.0))
    )


def _device_invariants(budget, d, w, g, f_max, p_tx, gain, B):
    """λ-invariant per-device quantities of the dual inner problem.

    The feasible-bandwidth bracket and the feasibility flag depend only on
    (budget, chain, link) — not on the bandwidth price λ — so they are
    computed once per ``allocate`` call and reused across all ~60 dual
    bisection steps (the λ search then only re-runs the golden section).
    """
    # Smallest feasible b: R(b) ≥ d / (budget − w/(g·f_max)).
    slack_at_fmax = budget - w / (jnp.maximum(g, 1e-30) * f_max)
    need_rate = d / jnp.maximum(slack_at_fmax, 1e-12)
    rate_fn = lambda b: channel.uplink_rate(b, p_tx, gain) - need_rate
    b_feas = bisect(rate_fn, _TINY_B, B)
    feasible = (slack_at_fmax > 0.0) & (channel.uplink_rate(B, p_tx, gain) >= need_rate)
    b_lo = jnp.where(feasible, jnp.minimum(b_feas * (1.0 + 1e-9) + _TINY_B, B), B * 0.5)
    return b_lo, feasible


def _device_best_b_at(lam, budget, d, w, g, kappa, f_min, f_max, p_tx, gain, B,
                      b_lo, feas0, sigma=0.0, v_base=0.0, channel_cv=0.0):
    """Optimal (b, f, feasible) for one device at bandwidth price λ, given
    the precomputed λ-invariants from ``_device_invariants``.

    For fixed b: t_off = d/R(b); the deadline forces
    f ≥ f_req(b) = w / (g·(budget_eff(b) − t_off)); energy rises with f, so
    f*(b) = clip(f_req, f_min, f_max). The remaining 1-D problem in b is
    convex (1/R is convex); we restrict to the feasible interval
    [b_lo, B]. The golden search handles the (quasi-convex) extra term
    that channel uncertainty adds to budget_eff.
    """
    beff = lambda b: _budget_eff(b, budget, d, p_tx, gain, sigma, v_base, channel_cv)

    def cost_fn(b):
        t_off = channel.offload_time(d, b, p_tx, gain)
        local_slack = jnp.maximum(beff(b) - t_off, 1e-12)
        f_req = w / (jnp.maximum(g, 1e-30) * local_slack)
        f = jnp.clip(f_req, f_min, f_max)
        e = energy.expected_local_energy(kappa, w, g, f) + channel.offload_energy(
            d, b, p_tx, gain
        )
        return e + lam * b

    b_star = golden_section(cost_fn, b_lo, B)
    t_off = channel.offload_time(d, b_star, p_tx, gain)
    local_slack = jnp.maximum(beff(b_star) - t_off, 1e-12)
    f_req = w / (jnp.maximum(g, 1e-30) * local_slack)
    f_star = jnp.clip(f_req, f_min, f_max)
    t_loc = energy.mean_local_time(w, g, f_star)
    feasible = feas0 & (t_loc + t_off <= beff(b_star) + 1e-9)
    return b_star, f_star, feasible


class AllocPrep(NamedTuple):
    """λ-invariant per-device state of the dual inner problem — everything
    downstream of the partition gather that does not depend on the price.

    Self-contained on purpose (platform/link columns ride along): the
    per-λ solve and the finalize step read *only* this record, so the
    group-sharded path (``core.decompose``) can concatenate per-group
    preps into fleet order and run the identical global finalize without
    ever materializing a cross-group padded ``Fleet``.
    """

    sel: Selected  # (N,) chain columns at the partition point
    budget: jnp.ndarray  # (N,) deadline budget D'
    sigma: jnp.ndarray  # (N,) σ(ε) of the ambiguity model
    v_base: jnp.ndarray  # (N,) inference-time variance at the point
    b_lo: jnp.ndarray  # (N,) feasibility floor on b
    feas0: jnp.ndarray  # (N,) λ-invariant feasibility
    kappa: jnp.ndarray  # (N,) platform/link columns
    f_min: jnp.ndarray
    f_max: jnp.ndarray
    p_tx: jnp.ndarray
    gain: jnp.ndarray


def _alloc_prep(fleet: Fleet, m_sel, deadline, eps, B,
                sigma_model: str = "cantelli", ub_k: float = 0.0,
                channel_cv: float = 0.0) -> AllocPrep:
    """λ-invariant work (point gather, deadline budget, b_feas bisection,
    feasibility flags) — once per allocation, not once per dual-bisection
    step."""
    del channel_cv  # prep is channel-model independent (budget_eff is per-λ)
    sel = select_point(fleet, m_sel)
    budget = deadline_budget(sel, deadline, eps, sigma_model, ub_k)
    sigma = ccp.SIGMA_FNS[sigma_model](jnp.broadcast_to(
        jnp.asarray(eps, jnp.float64), (fleet.num_devices,)))
    v_base = jnp.maximum(sel.v_loc + sel.v_vm, 0.0)
    plat, link = fleet.platform, fleet.link
    b_lo, feas0 = jax.vmap(
        lambda bud, d, w, g, fmax, p, h: _device_invariants(bud, d, w, g, fmax, p, h, B)
    )(budget, sel.d_bits, sel.w_flops, sel.g_eff, plat.f_max, link.p_tx, link.gain)
    return AllocPrep(sel=sel, budget=budget, sigma=sigma, v_base=v_base,
                     b_lo=b_lo, feas0=feas0, kappa=plat.kappa,
                     f_min=plat.f_min, f_max=plat.f_max, p_tx=link.p_tx,
                     gain=link.gain)


def _alloc_solve_at(prep: AllocPrep, B, lam, channel_cv: float = 0.0):
    """Per-device optimal ``(b, f, feasible)`` at bandwidth price λ."""
    per_device = jax.vmap(
        lambda lam_, bud, d, w, g, k, fmin, fmax, p, h, blo, fe, sg, vb: _device_best_b_at(
            lam_, bud, d, w, g, k, fmin, fmax, p, h, B, blo, fe,
            sigma=sg, v_base=vb, channel_cv=channel_cv,
        ),
        in_axes=(None, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0),
    )
    sel = prep.sel
    return per_device(
        lam,
        prep.budget,
        sel.d_bits,
        sel.w_flops,
        sel.g_eff,
        prep.kappa,
        prep.f_min,
        prep.f_max,
        prep.p_tx,
        prep.gain,
        prep.b_lo,
        prep.feas0,
        prep.sigma,
        prep.v_base,
    )


def _alloc_finalize(prep: AllocPrep, b, f, feas, B, lam, need_price,
                    channel_cv: float = 0.0, edge_capacity_s=None,
                    edge_price=None, assignment=None,
                    edge_eps=None) -> Allocation:
    """Global post-solve: floor-respecting rescale to Σb ≤ B, deadline
    recheck, edge-capacity check, energies. Shared verbatim by the
    monolithic ``allocate`` and the group-sharded path (which calls it on
    fleet-order concatenations of per-group solves)."""
    sel = prep.sel
    # If the price was active, rescale residual slack to exactly meet B
    # (bisection leaves O(1e-14 B) slack; harmless but keep Σb ≤ B exact).
    # The rescale must not push a device below its λ-invariant feasibility
    # floor b_lo: clamp to the floor and redistribute the shortfall to the
    # unclamped devices (the final _deadline_ok recheck stays the
    # authority on ``feasible``).
    total = jnp.sum(b)
    b = jnp.where(need_price & (total > B),
                  _rescale_with_floor(b, prep.b_lo, B), b)
    # The rescale shrinks b, which lengthens t_off — recheck the deadline
    # at the final (b, f) so ``feasible`` reflects what is returned.
    feas = feas & _deadline_ok(
        b, f, sel, prep.budget, prep.p_tx, prep.gain, prep.sigma,
        prep.v_base, channel_cv)

    # Shared-edge capacity: Σ occupancy at the (fixed) selected points.
    # ``edge_eps`` (static float, DESIGN.md §placement) turns the mean row
    # into the Cantelli chance-constrained row  Σ t̄ + σ_e·√(Σ v_vm) ≤ C
    # with σ_e = √((1−ε)/ε); at ``None`` the trace is untouched.
    if edge_capacity_s is not None:
        cap = jnp.asarray(edge_capacity_s, jnp.float64)
        sig_edge = placement.edge_sigma(edge_eps)
        if cap.ndim == 0:  # one shared edge (scalar path — the PR 4 goldens)
            occ = jnp.sum(sel.t_vm)
            if sig_edge > 0.0:
                occ = occ + sig_edge * jnp.sqrt(
                    jnp.maximum(jnp.sum(sel.v_vm), 0.0))
            feas = feas & (occ <= cap * (1.0 + _EDGE_CAP_RTOL))
        else:  # per-node capacity rows Σ_{n: a_n=e} t̄_vm,n ≤ C_e
            if assignment is None:
                raise ValueError(
                    "a per-node edge_capacity_s vector needs the device→node "
                    "assignment (core.placement.assign_devices)")
            e_count = cap.shape[0]
            occ_e = placement.node_loads(sel.t_vm, assignment, e_count)
            if sig_edge > 0.0:
                var_e = placement.node_loads(sel.v_vm, assignment, e_count)
                occ_e = occ_e + sig_edge * jnp.sqrt(jnp.maximum(var_e, 0.0))
            node_ok = occ_e <= cap * (1.0 + _EDGE_CAP_RTOL)
            feas = feas & node_ok[assignment]
    mu = jnp.asarray(0.0 if edge_price is None else edge_price, jnp.float64)

    e_loc = energy.expected_local_energy(prep.kappa, sel.w_flops, sel.g_eff, f)
    e_off = channel.offload_energy(sel.d_bits, b, prep.p_tx, prep.gain)
    return Allocation(b=b, f=f, e_loc=e_loc, e_off=e_off, feasible=feas,
                      lam=lam, mu=mu)


def _allocate_impl(fleet, m_sel, deadline, eps, B, sigma_model, ub_k,
                   channel_cv, edge_capacity_s, edge_price, prior_log_hi,
                   assignment=None, edge_eps=None):
    prep = _alloc_prep(fleet, m_sel, deadline, eps, B, sigma_model, ub_k,
                       channel_cv)

    def solve_at(lam):
        return _alloc_solve_at(prep, B, lam, channel_cv)

    b0, _, _ = solve_at(jnp.asarray(0.0, jnp.float64))
    need_price = jnp.sum(b0) > B

    def excess(log_lam):
        b, _, _ = solve_at(10.0**log_lam)
        return jnp.sum(b) - B

    # Expand the bracket top until the excess changes sign: the seed's
    # fixed [1e-16, 1e2] bracket silently pinned λ at 100 on bandwidth-
    # starved scenarios and let the rescale mask the unmet budget.
    log_hi, _ = _expand_log_bracket(excess, hi_start=prior_log_hi)
    log_lam = bisect(excess, _LOG_PRICE_LO, log_hi, iters=60)
    lam = jnp.where(need_price, 10.0**log_lam, 0.0)
    b, f, feas = solve_at(lam)
    alloc = _alloc_finalize(prep, b, f, feas, B, lam, need_price, channel_cv,
                            edge_capacity_s, edge_price, assignment, edge_eps)
    return alloc, log_hi


@partial(jax.jit, static_argnames=("sigma_model", "channel_cv", "edge_eps"))
def allocate(
    fleet: Fleet,
    m_sel: jnp.ndarray,
    deadline: jnp.ndarray,
    eps: jnp.ndarray,
    B: float,
    sigma_model: str = "cantelli",
    ub_k: float = 0.0,
    channel_cv: float = 0.0,
    edge_capacity_s=None,
    edge_price=None,
    prior_log_hi=None,
    assignment=None,
    edge_eps=None,
) -> Allocation:
    """Solve problem (23) by dual decomposition over Σ b_n ≤ B.

    ``channel_cv`` > 0 enables the joint inference-time + channel-state
    robustness extension (paper footnote 2).

    ``edge_capacity_s`` (traced scalar; ``None``/∞ ⇒ dedicated VMs) adds
    the shared-edge capacity check Σ_n t̄_vm(m_n) ≤ C_edge to the
    feasibility flags. At a *fixed* partition the occupancies are
    constants, so there is nothing to optimize here — the edge price μ
    that shaped the partition decision is passed in as ``edge_price``
    and recorded on the returned :class:`Allocation` next to λ.

    ``prior_log_hi`` (traced scalar, optional) warm-starts the λ-bracket
    expansion from a prior solve's bracket top — value-identical to a
    cold start (see ``_expand_log_bracket``). Use ``allocate_with_bracket``
    to also get the bracket top back for threading.

    ``edge_capacity_s`` may also be a per-node ``(E,)`` capacity vector
    (DESIGN.md §placement), in which case the traced ``assignment``
    (device→node, ``(N,)`` int32) selects which row each device's
    occupancy lands on and ``mu`` records the per-node price vector.
    ``edge_eps`` (static float) swaps the mean occupancy row for the
    Cantelli chance-constrained row (see ``placement.edge_sigma``).
    """
    return _allocate_impl(fleet, m_sel, deadline, eps, B, sigma_model, ub_k,
                          channel_cv, edge_capacity_s, edge_price,
                          prior_log_hi, assignment, edge_eps)[0]


@partial(jax.jit, static_argnames=("sigma_model", "channel_cv", "edge_eps"))
def allocate_with_bracket(
    fleet: Fleet,
    m_sel: jnp.ndarray,
    deadline: jnp.ndarray,
    eps: jnp.ndarray,
    B: float,
    sigma_model: str = "cantelli",
    ub_k: float = 0.0,
    channel_cv: float = 0.0,
    edge_capacity_s=None,
    edge_price=None,
    prior_log_hi=None,
    assignment=None,
    edge_eps=None,
):
    """``allocate`` that also returns the expanded λ-bracket top (log10),
    for threading across repeated solves (the Algorithm-2 alternation
    carries it through its scan so step k+1 starts at step k's bracket).
    The bracket is returned *next to* the :class:`Allocation` — not on it —
    because ``Allocation``'s flattening is a pinned pytree contract
    (``analysis.contracts.ALLOCATION_LEAVES``)."""
    return _allocate_impl(fleet, m_sel, deadline, eps, B, sigma_model, ub_k,
                          channel_cv, edge_capacity_s, edge_price,
                          prior_log_hi, assignment, edge_eps)


def _rescale_with_floor(b, b_lo, B):
    """Scale Σb down to B without crossing the feasibility floors.

    A plain ``b · (B/Σb)`` can push devices below their λ-invariant floor
    ``b_lo`` (and in principle below ``_TINY_B``). Devices that would dip
    are clamped to their floor and the remaining budget is redistributed
    pro-rata over the unclamped ones (two fixed rounds + a final scale
    recompute so Σb = Σ floors + leftover budget exactly). When no device
    dips — every healthy scenario, since the bisection leaves only
    O(1e-14·B) excess — this reduces bit-exactly to the plain rescale.
    """
    plain = b * (B / jnp.sum(b))
    floor = jnp.maximum(jnp.minimum(b_lo, b), _TINY_B)
    low = plain < floor
    for _ in range(2):
        avail = jnp.maximum(B - jnp.sum(jnp.where(low, floor, 0.0)), 0.0)
        denom = jnp.sum(jnp.where(low, 0.0, b))
        low = low | (b * (avail / jnp.maximum(denom, _TINY_B)) < floor)
    avail = jnp.maximum(B - jnp.sum(jnp.where(low, floor, 0.0)), 0.0)
    denom = jnp.sum(jnp.where(low, 0.0, b))
    out = jnp.where(low, floor, b * (avail / jnp.maximum(denom, _TINY_B)))
    # The floors themselves may overrun B (over-subscribed scenario: not
    # every device can meet its deadline at once). Σb ≤ B is the hard
    # physical constraint, so fall back to the plain proportional rescale
    # and let the deadline recheck flag the casualties.
    floors_fit = jnp.sum(jnp.where(low, floor, 0.0)) <= B
    return jnp.where(floors_fit, out, plain)


def _deadline_ok(b, f, sel: Selected, budget, p_tx, gain, sigma, v_base,
                 channel_cv=0.0, tol=1e-9):
    """ECR deadline check t_loc(f) + t_off(b) ≤ budget_eff(b) at given (b, f)."""
    t_off = channel.offload_time(sel.d_bits, b, p_tx, gain)
    t_loc = energy.mean_local_time(sel.w_flops, sel.g_eff, f)
    beff = _budget_eff(b, budget, sel.d_bits, p_tx, gain, sigma, v_base, channel_cv)
    return t_loc + t_off <= beff + tol


def allocate_ipm(  # analyze: ok(TRC001,TRC002,TRC003): host cross-check utility (barrier reference path), never jitted
    fleet: Fleet,
    m_sel: jnp.ndarray,
    deadline: jnp.ndarray,
    eps: jnp.ndarray,
    B: float,
    sigma_model: str = "cantelli",
    init: Allocation | None = None,
    edge_capacity_s=None,
    assignment=None,
    edge_eps: float | None = None,
) -> Allocation:
    """Paper-faithful joint interior-point solve of (23) (for cross-checks).

    Variables are scaled: β = b/B ∈ (0,1], φ = f/f_max ∈ [f_min/f_max, 1].

    This rides the *dense* autodiff barrier on purpose: unlike the PCCP
    inner problem (36), problem (23) is not of the structured family
    ``fi = C z + c0 + q(z)`` — its deadline rows contain t_off = d/R(b)
    with the log-rate R, non-affine and non-quadratic in b — so the
    closed-form path of ``solvers/ipm.py`` does not apply. It still gets
    the shared solver improvements: scale-aware Tikhonov regularization
    and the Newton-decrement early exit (``gate_tol``), which cuts the
    12×20 fixed Newton-step budget down to the steps that actually move
    the iterate.

    ``edge_capacity_s`` (concrete host float or per-node array — this is a
    test/cross-check utility) appends the shared-edge capacity row
    Σ t̄_vm(m_n) − C ≤ 0 — one row per finite node when a capacity vector
    and its ``assignment`` are given, with the Cantelli variance term
    σ_edge·√(Σ v_vm) added under ``edge_eps``. At fixed m each row is a
    constant: strictly satisfied it is inert in the barrier (certifying
    that the capacity does not distort the (b, f) optimum); violated it
    poisons the barrier, so it is validated here and raised as an error
    instead.
    """
    sel = select_point(fleet, m_sel)
    budget = deadline_budget(sel, deadline, eps, sigma_model)
    plat, link = fleet.platform, fleet.link
    n = fleet.num_devices
    sig_edge = placement.edge_sigma(edge_eps)

    def _eff_occ(occ_sum, var_sum):
        return occ_sum + sig_edge * np.sqrt(max(var_sum, 0.0))

    cap = None  # scalar capacity row
    cap_vec = a_host = None  # per-node capacity rows
    occ_host = np.asarray(sel.t_vm, np.float64)
    var_host = np.asarray(sel.v_vm, np.float64)
    if edge_capacity_s is not None:
        cap_arr = np.asarray(edge_capacity_s, np.float64)
        if cap_arr.ndim == 0:
            if np.isfinite(cap_arr):
                cap = float(cap_arr)
                occ_total = _eff_occ(float(np.sum(occ_host)),
                                     float(np.sum(var_host)))
                if occ_total > cap * (1.0 + _EDGE_CAP_RTOL):
                    raise ValueError(
                        f"allocate_ipm: partition occupies {occ_total:.6g} s of the "
                        f"shared edge but edge_capacity_s={cap:.6g} s — the capacity "
                        "constraint is violated at this fixed m_sel (the occupancy "
                        "row would poison the barrier); re-plan with the edge price "
                        "before cross-checking")
        else:
            if assignment is None:
                raise ValueError(
                    "allocate_ipm: a per-node edge_capacity_s vector needs "
                    "the device→node assignment (pass plan.assignment)")
            cap_vec = cap_arr
            a_host = np.asarray(assignment, np.int64)
            for e in range(cap_vec.shape[0]):
                if not np.isfinite(cap_vec[e]):
                    continue
                mask = a_host == e
                occ_e = _eff_occ(float(np.sum(occ_host[mask])),
                                 float(np.sum(var_host[mask])))
                if occ_e > cap_vec[e] * (1.0 + _EDGE_CAP_RTOL):
                    raise ValueError(
                        f"allocate_ipm: node {e} occupies {occ_e:.6g} s but its "
                        f"edge capacity is {cap_vec[e]:.6g} s — the capacity "
                        "constraint is violated at this fixed (m_sel, assignment); "
                        "re-plan with the per-node prices before cross-checking")

    if init is None:
        init = allocate(fleet, m_sel, deadline, eps, B, sigma_model,
                        edge_capacity_s=edge_capacity_s,
                        assignment=assignment, edge_eps=edge_eps)

    def unpack(z):
        return z[:n] * B, z[n:] * plat.f_max  # b, f

    def objective(z):
        b, f = unpack(z)
        e_loc = energy.expected_local_energy(plat.kappa, sel.w_flops, sel.g_eff, f)
        e_off = channel.offload_energy(sel.d_bits, b, link.p_tx, link.gain)
        return jnp.sum(e_loc + e_off)

    def inequalities(z):
        b, f = unpack(z)
        t_loc = energy.mean_local_time(sel.w_flops, sel.g_eff, f)
        t_off = channel.offload_time(sel.d_bits, b, link.p_tx, link.gain)
        ddl = t_loc + t_off - budget  # ≤ 0
        rows = [
            ddl,
            (jnp.sum(b) - B)[None],
            _TINY_B - b,
            plat.f_min - f,
            f - plat.f_max,
        ]
        if cap is not None:
            # Shared-edge capacity row: constant at fixed m, hence inert
            # in the barrier. The barrier needs it STRICTLY negative, but
            # the validation above tolerates occ up to cap·(1+rtol) (the
            # same tolerance the planner's primal check uses), so the row
            # is written against cap·(1+2·rtol): any occupancy that
            # passed the guard sits strictly inside it.
            cap_eff = cap * (1.0 + 2.0 * _EDGE_CAP_RTOL)
            occ_row = jnp.sum(sel.t_vm)
            if sig_edge > 0.0:
                occ_row = occ_row + sig_edge * jnp.sqrt(
                    jnp.maximum(jnp.sum(sel.v_vm), 0.0))
            rows.append((occ_row - cap_eff)[None])
        if cap_vec is not None:
            # One constant row per finite node (same 2·rtol headroom).
            for e in range(cap_vec.shape[0]):
                if not np.isfinite(cap_vec[e]):
                    continue
                mask = jnp.asarray(a_host == e)
                occ_row = jnp.sum(jnp.where(mask, sel.t_vm, 0.0))
                if sig_edge > 0.0:
                    occ_row = occ_row + sig_edge * jnp.sqrt(jnp.maximum(
                        jnp.sum(jnp.where(mask, sel.v_vm, 0.0)), 0.0))
                cap_eff = cap_vec[e] * (1.0 + 2.0 * _EDGE_CAP_RTOL)
                rows.append((occ_row - cap_eff)[None])
        return jnp.concatenate(rows)

    # Strictly feasible start: nudge the dual solution into the interior.
    b0 = jnp.clip(init.b, _TINY_B * 2, B)
    b0 = b0 * jnp.minimum(1.0, 0.999 * B / jnp.sum(b0))
    f0 = jnp.clip(init.f * 1.02, plat.f_min * 1.0001, plat.f_max * 0.9999)
    z0 = jnp.concatenate([b0 / B, f0 / plat.f_max])

    res = barrier_solve(
        BarrierSpec(objective=objective, inequalities=inequalities),
        z0,
        t0=1e2,
        mu=10.0,
        outer_iters=12,
        newton_iters=20,
        gate_tol=1e-13,
    )
    b, f = unpack(res.z)
    e_loc = energy.expected_local_energy(plat.kappa, sel.w_flops, sel.g_eff, f)
    e_off = channel.offload_energy(sel.d_bits, b, link.p_tx, link.gain)
    return Allocation(b=b, f=f, e_loc=e_loc, e_off=e_off,
                      feasible=init.feasible, lam=init.lam, mu=init.mu)
