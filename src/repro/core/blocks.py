"""Block-chain abstraction of a partitionable DNN (paper §III-A).

A model is a serial chain of ``M`` blocks with partition points
``m ∈ {0, …, M}``: blocks ``1..m`` run on the device, the boundary
activation is uplinked, blocks ``m+1..M`` run on the edge VM.

All quantities are SI (bits, FLOPs, FLOPs/cycle, seconds, seconds²).
The per-point arrays have length ``M+1`` (index = partition point):

- ``d_bits[m]``   — uplink payload at point m (raw input at 0, result at M)
- ``w_flops[m]``  — cumulative local FLOPs of blocks 1..m (0 at m=0)
- ``g_eff[m]``    — fitted effective FLOPs/cycle for the 1..m prefix
                    (paper eq. (10); fitted by NLS, Fig. 6)
- ``v_loc[m]``    — variance of local inference time, max over the DVFS
                    range (paper eq. (11)) — seconds²
- ``t_vm[m]``     — mean edge (VM) time for blocks m+1..M (0 at m=M)
- ``v_vm[m]``     — variance of the edge time — seconds²

A ``Fleet`` stacks N devices (leading axis N) plus per-device platform and
radio-link parameters; it is the single input bundle the planner consumes.

Fleets may be **ragged** (DESIGN.md §fleet): devices can run different
models with different numbers of partition points ``M_n``. Chains are
padded to the fleet-wide ``max(M_n)+1`` width and two extra leaves mark
the padding:

- ``valid``      — (N, max_points) bool; True where the point is a real
                   partition point of device n's chain, False on padding.
- ``num_points`` — (N,) int32; ``M_n + 1`` valid points per device.

Both are *traced pytree leaves* (not statics), so two mixed fleets with
the same padded shapes share one compiled program. ``None`` (the default)
means "all points valid" and is the homogeneous fast path: every consumer
gates its masking on ``valid is None`` at trace time, and an all-valid
mask is a numerical no-op (pure ``where``-selects — bit-identical to the
unmasked program; pinned by ``tests/golden/seed_plans.json``).

``repro.core.fleet`` (``DeviceSpec``/``FleetSpec``) is the builder layer
that composes heterogeneous device groups into padded fleets.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax.numpy as jnp
from jax import Array


class BlockChain(NamedTuple):
    d_bits: Array
    w_flops: Array
    g_eff: Array
    v_loc: Array
    t_vm: Array
    v_vm: Array

    @property
    def num_points(self) -> int:
        return self.d_bits.shape[-1]


def pad_chain(chain: BlockChain, to_points: int) -> BlockChain:  # analyze: ok(TRC003): builder-time shape validation; chains are concrete at build
    """Pad a single chain to ``to_points`` by repeating the terminal point.

    The duplicated full-local points are *placeholders*: builders mark them
    invalid in ``Fleet.valid`` and the planner masks them out. Repeating
    the terminal point (rather than padding zeros/inf) keeps every padded
    entry finite and physically plausible, so masked tables stay
    well-conditioned inside the PCCP barrier solves.
    """
    pad = to_points - chain.num_points
    if pad < 0:
        raise ValueError(
            f"cannot pad a {chain.num_points}-point chain down to {to_points}")
    if pad == 0:
        return chain
    rep = lambda a: jnp.concatenate([a, jnp.repeat(a[-1:], pad, axis=0)])
    return BlockChain(*[rep(x) for x in chain])


class Platform(NamedTuple):
    """Local compute platform (paper Table II + κ measurements)."""

    kappa: Array  # W / (cycle/s)^3
    f_min: Array  # Hz
    f_max: Array  # Hz


class Link(NamedTuple):
    """Radio link parameters of one device (paper §VI-A)."""

    p_tx: Array  # W
    gain: Array  # linear channel gain (10^(-PL_dB/10))


class Fleet(NamedTuple):
    """N devices: chains (N, max_points), platforms (N,), links (N,).

    ``valid``/``num_points`` mark ragged per-device chains (module
    docstring); ``None`` means every point is valid on every device.
    """

    chain: BlockChain
    platform: Platform
    link: Link
    valid: Optional[Array] = None  # (N, max_points) bool, or None
    num_points: Optional[Array] = None  # (N,) int32 = M_n + 1, or None

    @property
    def num_devices(self) -> int:
        return self.chain.d_bits.shape[0]

    @property
    def max_points(self) -> int:
        """Padded point-table width max(M_n) + 1 (a static shape)."""
        return self.chain.d_bits.shape[-1]

    @property
    def points_per_device(self) -> Array:
        """(N,) int32 valid-point counts (materialized when ``None``)."""
        if self.num_points is not None:
            return self.num_points
        return jnp.full((self.num_devices,), self.max_points, jnp.int32)

    @property
    def valid_mask(self) -> Array:
        """(N, max_points) bool mask (materialized when ``None``)."""
        if self.valid is not None:
            return self.valid
        return jnp.ones((self.num_devices, self.max_points), bool)


def broadcast_fleet(chain: BlockChain, platform: Platform, link_p: Array, link_gain: Array) -> Fleet:
    """Tile a single chain/platform across N devices with per-device links.

    Delegates to the ``FleetSpec`` builder (``repro.core.fleet``) — one
    homogeneous group, explicit link gains.
    """
    from repro.core.fleet import DeviceSpec, FleetSpec

    gain = jnp.asarray(link_gain, jnp.float64)
    spec = FleetSpec((DeviceSpec(chain=chain, kappa=platform.kappa,
                                 f_min_hz=platform.f_min,
                                 f_max_hz=platform.f_max,
                                 count=int(gain.shape[0])),))
    return spec.build(gains=gain, p_tx=jnp.asarray(link_p, jnp.float64))


def covariance(chain: BlockChain, rho: float = 0.9) -> Array:
    """Full covariance matrix W_n of eq. (27).

    Diagonals are the measured variances (v_loc + v_vm, the independent
    local/VM components of eq. (21)); off-diagonals follow the paper's
    observation that "the covariance curve closely matches the variance
    curve" — we model w_{m,m'} = rho·√(w_mm·w_m'm'). Only the diagonal
    enters the deterministic reformulation (28).
    """
    diag = chain.v_loc + chain.v_vm
    sq = jnp.sqrt(jnp.maximum(diag, 0.0))
    full = rho * sq[..., :, None] * sq[..., None, :]
    m = diag.shape[-1]
    eye = jnp.eye(m, dtype=full.dtype)
    return full * (1.0 - eye) + diag[..., None] * eye
