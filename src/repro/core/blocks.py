"""Block-chain abstraction of a partitionable DNN (paper §III-A).

A model is a serial chain of ``M`` blocks with partition points
``m ∈ {0, …, M}``: blocks ``1..m`` run on the device, the boundary
activation is uplinked, blocks ``m+1..M`` run on the edge VM.

All quantities are SI (bits, FLOPs, FLOPs/cycle, seconds, seconds²).
The per-point arrays have length ``M+1`` (index = partition point):

- ``d_bits[m]``   — uplink payload at point m (raw input at 0, result at M)
- ``w_flops[m]``  — cumulative local FLOPs of blocks 1..m (0 at m=0)
- ``g_eff[m]``    — fitted effective FLOPs/cycle for the 1..m prefix
                    (paper eq. (10); fitted by NLS, Fig. 6)
- ``v_loc[m]``    — variance of local inference time, max over the DVFS
                    range (paper eq. (11)) — seconds²
- ``t_vm[m]``     — mean edge (VM) time for blocks m+1..M (0 at m=M)
- ``v_vm[m]``     — variance of the edge time — seconds²

A ``Fleet`` stacks N devices (leading axis N) plus per-device platform and
radio-link parameters; it is the single input bundle the planner consumes.
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp
from jax import Array


class BlockChain(NamedTuple):
    d_bits: Array
    w_flops: Array
    g_eff: Array
    v_loc: Array
    t_vm: Array
    v_vm: Array

    @property
    def num_points(self) -> int:
        return self.d_bits.shape[-1]


class Platform(NamedTuple):
    """Local compute platform (paper Table II + κ measurements)."""

    kappa: Array  # W / (cycle/s)^3
    f_min: Array  # Hz
    f_max: Array  # Hz


class Link(NamedTuple):
    """Radio link parameters of one device (paper §VI-A)."""

    p_tx: Array  # W
    gain: Array  # linear channel gain (10^(-PL_dB/10))


class Fleet(NamedTuple):
    """N devices: chains (N, M+1), platforms (N,), links (N,)."""

    chain: BlockChain
    platform: Platform
    link: Link

    @property
    def num_devices(self) -> int:
        return self.chain.d_bits.shape[0]

    @property
    def num_points(self) -> int:
        return self.chain.d_bits.shape[-1]


def broadcast_fleet(chain: BlockChain, platform: Platform, link_p: Array, link_gain: Array) -> Fleet:
    """Tile a single chain/platform across N devices with per-device links."""
    n = jnp.asarray(link_gain).shape[0]

    def tile(a):
        a = jnp.asarray(a, jnp.float64)
        return jnp.broadcast_to(a, (n,) + a.shape)

    return Fleet(
        chain=BlockChain(*[tile(x) for x in chain]),
        platform=Platform(*[tile(jnp.asarray(x, jnp.float64)) for x in platform]),
        link=Link(p_tx=jnp.broadcast_to(jnp.asarray(link_p, jnp.float64), (n,)),
                  gain=jnp.asarray(link_gain, jnp.float64)),
    )


def covariance(chain: BlockChain, rho: float = 0.9) -> Array:
    """Full covariance matrix W_n of eq. (27).

    Diagonals are the measured variances (v_loc + v_vm, the independent
    local/VM components of eq. (21)); off-diagonals follow the paper's
    observation that "the covariance curve closely matches the variance
    curve" — we model w_{m,m'} = rho·√(w_mm·w_m'm'). Only the diagonal
    enters the deterministic reformulation (28).
    """
    diag = chain.v_loc + chain.v_vm
    sq = jnp.sqrt(jnp.maximum(diag, 0.0))
    full = rho * sq[..., :, None] * sq[..., None, :]
    m = diag.shape[-1]
    eye = jnp.eye(m, dtype=full.dtype)
    return full * (1.0 - eye) + diag[..., None] * eye
