"""Chance-constrained programming reformulations (paper §V, Theorem 1).

The paper's Exact Conic Reformulation (ECR) for the mean–covariance
ambiguity set (distribution-free, one-sided Chebyshev/Cantelli):

    P{aᵀλ ≤ z} ≥ 1-ε   ⟺   aᵀλ̄ + √((1-ε)/ε) · √(aᵀCa) ≤ z

We expose the safety multiplier σ(ε) for three ambiguity models:

- ``cantelli``  — the paper's σ = √((1-ε)/ε). Exact for "any distribution
  with this mean and covariance" — robust but conservative.
- ``gaussian``  — σ = Φ⁻¹(1-ε). Valid if times are (approximately) normal
  (the paper's ref. [16] reports near-Gaussian times on the A11 SoC).
  Beyond-paper comparison point: quantifies Cantelli's conservatism.
- ``hard``      — σ = 0 (deterministic constraint on the supplied times;
  used by the worst-case baseline which plugs in upper bounds instead).
"""
from __future__ import annotations

import jax.numpy as jnp
from jax.scipy.special import ndtri


def sigma_cantelli(eps):
    """Paper's multiplier: σ = √((1-ε)/ε)."""
    eps = jnp.asarray(eps, jnp.float64)
    return jnp.sqrt((1.0 - eps) / jnp.maximum(eps, 1e-12))


def sigma_gaussian(eps):
    """Gaussian quantile multiplier: σ = Φ⁻¹(1-ε)."""
    eps = jnp.asarray(eps, jnp.float64)
    return ndtri(1.0 - eps)


def sigma_hard(eps):
    return jnp.zeros_like(jnp.asarray(eps, jnp.float64))


SIGMA_FNS = {
    "cantelli": sigma_cantelli,
    "gaussian": sigma_gaussian,
    "hard": sigma_hard,
}


def deterministic_deadline_margin(mean_total, var_total, eps, deadline, model="cantelli"):
    """LHS − RHS of the ECR constraint (22)/(28): ≤ 0 means satisfied.

    mean_total — E[T] (local + offload + VM), var_total — Var[T]
    (independent local and VM components per eq. (21)).
    """
    sig = SIGMA_FNS[model](eps)
    return mean_total + sig * jnp.sqrt(jnp.maximum(var_total, 0.0)) - deadline
