"""Penalty convex–concave procedure for the partitioning subproblem.

Implements Algorithm 1: problem (24) → ECR (28) → DC lift (33) with
auxiliary y_n and slack-penalized linearization (36). Because
Σ_m x_{n,m} = 1 makes the bandwidth coupling (24d) equal to Σ_n b_n ≤ B
*independently of x*, the inner convex programs decouple per device — we
solve all N of them with one vmapped barrier IPM per PCCP iteration.

Shared-edge pricing (DESIGN.md §edge): when the scenario carries an edge
capacity, the alternation hands this module an energy table already
charged with μ·t̄_vm per candidate point — a linear per-point offset,
exactly the shape the inner objective (e_vec) already has, so the
barrier solves are unchanged and edge contention steers the relaxed x
like any other energy term.

Deviations from the paper (documented in DESIGN.md):
- a slack δ with a high penalty is added to the deadline constraint (33c)
  so every inner problem is strictly feasible even when a device has no
  deadline-feasible partition point (the solver then reports the least
  violating point instead of failing);
- after convergence the relaxed x is rounded (argmax) and repaired to the
  cheapest *feasible* point if rounding landed on an infeasible one.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.solvers.ipm import BarrierSpec, barrier_solve

_Y_MIN = 1e-9

#: Barrier schedule of the inner solves: (t0, mu, stages, newton_per_stage,
#: ls_candidates). Every Newton step costs a batched Cholesky + line
#: search, so the step COUNT is the planner's wall-clock; this is the
#: fewest stages/steps that keep the golden seed plans
#: (tests/golden/seed_plans.json) and the PCCP stationarity property intact
#: (final gap bound: n_ineq / (t0·mu^(stages−1)) ≈ 2e-6 for M+1 = 10).
#: The seed used (1.0, 8.0, 12, 14, 40) — 168 Newton steps per inner solve
#: against 24 here; ``benchmarks/bench_runtime.py`` times that schedule via
#: ``SEED_SCHEDULE`` for the speedup trajectory.
DEFAULT_SCHEDULE = (1.0, 30.0, 6, 4, 24)
SEED_SCHEDULE = (1.0, 8.0, 12, 14, 40)


class PCCPResult(NamedTuple):
    m_sel: jnp.ndarray  # (N,) int32 chosen partition points
    x_relaxed: jnp.ndarray  # (N, M+1) final relaxed solution
    iters_to_converge: jnp.ndarray  # (N,) Algorithm-1 iterations (Fig. 9)
    step_norms: jnp.ndarray  # (K, N) ‖x_i − x_{i−1}‖ trajectory
    feasible: jnp.ndarray  # (N,) bool — chosen point satisfies (28)


def _inner_problem(e_vec, t_vec, var_vec, sigma, deadline, rho, x_prev, y_prev,
                   schedule=DEFAULT_SCHEDULE):
    """Build problem (36) for one device and solve it with the barrier IPM.

    z = [x (M1), y, α, β, δ, γ (M1)] — dim 2·M1 + 4.

    All constraints are affine except the two DC rows ((36c): Σ var·x²,
    (36d): y²), so the system is assembled ONCE per PCCP iteration as
    fi(z) = C z + c0 + q(z) with a constant (per-iterate) matrix C and a
    two-entry quadratic correction q. Every barrier/Newton/line-search
    evaluation is then a single matvec instead of a dozen concatenated
    ops — the inner solve is where the whole planner's wall-clock goes.
    """
    m1 = e_vec.shape[0]
    dim = 2 * m1 + 4

    ix = slice(0, m1)
    iy, ia, ib, idl = m1, m1 + 1, m1 + 2, m1 + 3
    ig = slice(m1 + 4, dim)

    rho_dl = 50.0 * rho

    c_obj = (
        jnp.zeros((dim,), jnp.float64)
        .at[ix].set(e_vec)
        .at[ia].set(rho)
        .at[ib].set(rho)
        .at[idl].set(rho_dl)
        .at[ig].set(rho)
    )

    def objective(z):
        return jnp.dot(c_obj, z)

    # Row layout (same order as the paper's constraint list):
    #   [0, m1)        −x ≤ 0
    #   [m1, 2m1)      x − 1 ≤ 0
    #   2m1            (33c)+δ:  tᵀx + σy − D − δ ≤ 0
    #   2m1+1          (36c):    Σ var x² − 2y_prev y + y_prev² − α ≤ 0
    #   2m1+2          (36d):    y² − 2(var⊙x_prev)ᵀx + Σ var x_prev² − β ≤ 0
    #   [2m1+3, 3m1+3) (36e):    (1−2x_prev)⊙x + x_prev² − γ ≤ 0
    #   3m1+3          y ≥ _Y_MIN
    #   3m1+4..6       α, β, δ ≥ 0
    #   [3m1+7, 4m1+7) γ ≥ 0
    n_ineq = 4 * m1 + 7
    r_ddl, r_c, r_d, r_e = 2 * m1, 2 * m1 + 1, 2 * m1 + 2, 2 * m1 + 3
    r_y, r_a = 3 * m1 + 3, 3 * m1 + 4
    r_g = 3 * m1 + 7
    eye = jnp.eye(m1, dtype=jnp.float64)
    ar = jnp.arange(m1)

    C = (
        jnp.zeros((n_ineq, dim), jnp.float64)
        .at[0:m1, ix].set(-eye)
        .at[m1:2 * m1, ix].set(eye)
        .at[r_ddl, ix].set(t_vec)
        .at[r_ddl, iy].set(sigma)
        .at[r_ddl, idl].set(-1.0)
        .at[r_c, iy].set(-2.0 * y_prev)
        .at[r_c, ia].set(-1.0)
        .at[r_d, ix].set(-2.0 * var_vec * x_prev)
        .at[r_d, ib].set(-1.0)
        .at[r_e + ar, ix].set(jnp.diag(1.0 - 2.0 * x_prev))
        .at[r_e + ar, ig].add(-eye)
        .at[r_y, iy].set(-1.0)
        .at[r_a, ia].set(-1.0)
        .at[r_a + 1, ib].set(-1.0)
        .at[r_a + 2, idl].set(-1.0)
        .at[r_g + ar, ig].set(-eye)
    )
    c0 = (
        jnp.zeros((n_ineq,), jnp.float64)
        .at[m1:2 * m1].set(-1.0)
        .at[r_ddl].set(-deadline)
        .at[r_c].set(y_prev**2)
        .at[r_d].set(jnp.dot(var_vec, x_prev**2))
        .at[r_e + ar].set(x_prev**2)
        .at[r_y].set(_Y_MIN)
    )

    def inequalities(z):
        x, y = z[ix], z[iy]
        fi = C @ z + c0
        return fi.at[r_c].add(jnp.dot(var_vec, x * x)).at[r_d].add(y * y)

    A = jnp.zeros((1, dim), jnp.float64).at[0, ix].set(1.0)

    # Strictly feasible start around the previous iterate.
    x0 = 0.8 * x_prev + 0.2 / m1
    y0 = jnp.maximum(jnp.sqrt(jnp.dot(var_vec, x0 * x0)), 2.0 * _Y_MIN)
    pad = lambda v: jnp.maximum(v, 0.0) + 1e-4 * (1.0 + jnp.abs(v))
    alpha0 = pad(jnp.dot(var_vec, x0 * x0) - (2.0 * y_prev * y0 - y_prev**2))
    beta0 = pad(y0 * y0 - jnp.dot(var_vec, x_prev * (2.0 * x0 - x_prev)))
    delta0 = pad(jnp.dot(x0, t_vec) + sigma * y0 - deadline)
    gamma0 = pad(x0 * (1.0 - 2.0 * x_prev) + x_prev**2)
    z0 = jnp.concatenate(
        [x0, y0[None], alpha0[None], beta0[None], delta0[None], gamma0]
    )

    t0, mu, stages, newton, ls = schedule
    res = barrier_solve(
        BarrierSpec(objective=objective, inequalities=inequalities, eq_matrix=A, eq_rhs=jnp.ones((1,))),
        z0,
        t0=t0,
        mu=mu,
        outer_iters=stages,
        newton_iters=newton,
        ls_iters=ls,
    )
    return res.z[ix], res.z[iy]


@partial(jax.jit, static_argnames=("num_iters", "schedule"))
def pccp_partition(
    e_table: jnp.ndarray,  # (N, M+1) energy of each point at current (b, f)
    t_table: jnp.ndarray,  # (N, M+1) mean total time of each point
    var_table: jnp.ndarray,  # (N, M+1) diag of W_n (eq. 27/28)
    sigma: jnp.ndarray,  # (N,) σ(ε_n)
    deadline: jnp.ndarray,  # (N,)
    x_init: jnp.ndarray,  # (N, M+1) initial relaxed point
    num_iters: int = 10,
    rho0: float = 5.0,
    nu: float = 3.0,
    rho_max: float = 1e5,
    theta_err: float = 1e-3,
    schedule: tuple = DEFAULT_SCHEDULE,  # inner barrier (t0, mu, stages, newton, ls)
) -> PCCPResult:
    n, m1 = e_table.shape

    inner = jax.vmap(
        lambda e, t, v, s, d, rho, xp, yp: _inner_problem(
            e, t, v, s, d, rho, xp, yp, schedule),
        in_axes=(0, 0, 0, 0, 0, None, 0, 0))

    def step(carry, _):
        x_prev, y_prev, rho = carry
        x_new, y_new = inner(
            e_table, t_table, var_table, sigma, deadline, rho, x_prev, y_prev
        )
        dx = jnp.linalg.norm(x_new - x_prev, axis=-1)
        rho = jnp.minimum(nu * rho, rho_max)
        return (x_new, y_new, rho), dx

    y0 = jnp.sqrt(jnp.maximum(jnp.sum(var_table * x_init**2, -1), 4.0 * _Y_MIN**2))
    (x_fin, _, _), dxs = jax.lax.scan(
        step, (x_init, y0, jnp.asarray(rho0, jnp.float64)), None, length=num_iters
    )

    # Algorithm-1 iteration count: first i with ‖x_i − x_{i−1}‖ < θ_err.
    converged = dxs < theta_err  # (K, N)
    first = jnp.argmax(converged, axis=0)
    never = ~jnp.any(converged, axis=0)
    iters = jnp.where(never, num_iters, first + 1)

    # Round + feasibility repair against the ECR constraint (28).
    margin = t_table + sigma[:, None] * jnp.sqrt(var_table) - deadline[:, None]
    feas_mask = margin <= 1e-9  # tolerance: incumbent sits exactly on the deadline
    m_round = jnp.argmax(x_fin, axis=-1)
    round_ok = jnp.take_along_axis(feas_mask, m_round[:, None], -1)[:, 0]
    e_masked = jnp.where(feas_mask, e_table, jnp.inf)
    m_repair = jnp.argmin(e_masked, axis=-1)
    any_feas = jnp.any(feas_mask, axis=-1)
    m_least_bad = jnp.argmin(margin, axis=-1)
    m_sel = jnp.where(round_ok, m_round, jnp.where(any_feas, m_repair, m_least_bad))
    feasible = jnp.take_along_axis(feas_mask, m_sel[:, None], -1)[:, 0]
    return PCCPResult(
        m_sel=m_sel.astype(jnp.int32),
        x_relaxed=x_fin,
        iters_to_converge=iters,
        step_norms=dxs,
        feasible=feasible,
    )
