"""Penalty convex–concave procedure for the partitioning subproblem.

Implements Algorithm 1: problem (24) → ECR (28) → DC lift (33) with
auxiliary y_n and slack-penalized linearization (36). Because
Σ_m x_{n,m} = 1 makes the bandwidth coupling (24d) equal to Σ_n b_n ≤ B
*independently of x*, the inner convex programs decouple per device — we
solve all N of them with one vmapped barrier IPM per PCCP iteration.

Shared-edge pricing (DESIGN.md §edge): when the scenario carries an edge
capacity, the alternation hands this module an energy table already
charged with μ·t̄_vm per candidate point — a linear per-point offset,
exactly the shape the inner objective (e_vec) already has, so the
barrier solves are unchanged and edge contention steers the relaxed x
like any other energy term.

Solver paths (DESIGN.md §solver): the inner problem (36) is assembled
ONCE as a :class:`repro.solvers.ipm.StructuredSpec` — affine matrix ``C``
plus the two DC quadratic rows — and solved either by the
structure-exploiting barrier (``solver="structured"``, the default:
closed-form derivatives, pair-elimination + Woodbury KKT, analytic line
search) or by the dense autodiff barrier (``solver="dense"``, the A/B
reference, numerically equivalent and golden-pinned against it).

Deviations from the paper (documented in DESIGN.md):
- a slack δ with a high penalty is added to the deadline constraint (33c)
  so every inner problem is strictly feasible even when a device has no
  deadline-feasible partition point (the solver then reports the least
  violating point instead of failing);
- after convergence the relaxed x is rounded (argmax) and repaired to the
  cheapest *feasible* point if rounding landed on an infeasible one.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.solvers.ipm import (
    BarrierSpec,
    StructuredSpec,
    barrier_solve,
    structured_barrier_solve,
    structured_inequalities,
)

_Y_MIN = 1e-9

#: Barrier schedule of the inner solves: (t0, mu, stages, newton_per_stage,
#: ls_candidates). Every Newton step costs a batched KKT solve + line
#: search, so the step COUNT is the planner's wall-clock; this is the
#: fewest stages/steps that keep the golden seed plans
#: (tests/golden/seed_plans.json) and the PCCP stationarity property intact
#: (final gap bound: n_ineq / (t0·mu^(stages−1)) ≈ 2e-6 for M+1 = 10).
#: The seed used (1.0, 8.0, 12, 14, 40) — 168 Newton steps per inner solve
#: against 24 here; ``benchmarks/bench_runtime.py`` times that schedule via
#: ``SEED_SCHEDULE`` for the speedup trajectory.
DEFAULT_SCHEDULE = (1.0, 30.0, 6, 4, 24)
SEED_SCHEDULE = (1.0, 8.0, 12, 14, 40)

#: Valid values of the ``solver`` static of :func:`pccp_partition` (and of
#: ``PlannerConfig.solver``): the structure-exploiting barrier vs the
#: dense-autodiff A/B reference.
SOLVERS = ("structured", "dense")


class PCCPResult(NamedTuple):
    m_sel: jnp.ndarray  # (N,) int32 chosen partition points
    x_relaxed: jnp.ndarray  # (N, M+1) final relaxed solution
    iters_to_converge: jnp.ndarray  # (N,) Algorithm-1 iterations (Fig. 9)
    step_norms: jnp.ndarray  # (K, N) ‖x_i − x_{i−1}‖ trajectory (gated
    # runs leave +inf in the rows the early exit never executed)
    feasible: jnp.ndarray  # (N,) bool — chosen point satisfies (28)


def _inner_spec(e_vec, t_vec, var_vec, sigma, deadline, rho, x_prev, y_prev):
    """Assemble problem (36) for one device as a ``StructuredSpec``.

    z = [x (M1), y, α, β, δ, γ (M1)] — dim 2·M1 + 4.

    All constraints are affine except the two DC rows ((36c): Σ var·x²,
    (36d): y²), so the system is assembled ONCE per PCCP iteration as
    fi(z) = C z + c0 + q(z) with a constant (per-iterate) matrix C and a
    two-entry diagonal quadratic correction q. Every barrier/Newton/
    line-search evaluation is then a single matvec instead of a dozen
    concatenated ops — the inner solve is where the whole planner's
    wall-clock goes.

    Row classification for the structured Hessian ``D + pairs + U S Uᵀ``
    (DESIGN.md §solver): the box rows on x, the y/α/β/δ/γ positivity rows
    are single-nonzero (pure diagonal); each (36e) row couples exactly
    (x_j, γ_j) with γ_j appearing nowhere else (pair-eliminable); only
    the deadline row (33c) and the two DC rows (36c)/(36d) are dense —
    a rank-3 Woodbury term.
    """
    m1 = e_vec.shape[0]
    dim = 2 * m1 + 4

    ix = slice(0, m1)
    iy, ia, ib, idl = m1, m1 + 1, m1 + 2, m1 + 3
    ig = slice(m1 + 4, dim)

    rho_dl = 50.0 * rho

    c_obj = (
        jnp.zeros((dim,), jnp.float64)
        .at[ix].set(e_vec)
        .at[ia].set(rho)
        .at[ib].set(rho)
        .at[idl].set(rho_dl)
        .at[ig].set(rho)
    )

    # Row layout (same order as the paper's constraint list):
    #   [0, m1)        −x ≤ 0
    #   [m1, 2m1)      x − 1 ≤ 0
    #   2m1            (33c)+δ:  tᵀx + σy − D − δ ≤ 0
    #   2m1+1          (36c):    Σ var x² − 2y_prev y + y_prev² − α ≤ 0
    #   2m1+2          (36d):    y² − 2(var⊙x_prev)ᵀx + Σ var x_prev² − β ≤ 0
    #   [2m1+3, 3m1+3) (36e):    (1−2x_prev)⊙x + x_prev² − γ ≤ 0
    #   3m1+3          y ≥ _Y_MIN
    #   3m1+4..6       α, β, δ ≥ 0
    #   [3m1+7, 4m1+7) γ ≥ 0
    n_ineq = 4 * m1 + 7
    r_ddl, r_c, r_d, r_e = 2 * m1, 2 * m1 + 1, 2 * m1 + 2, 2 * m1 + 3
    r_y, r_a = 3 * m1 + 3, 3 * m1 + 4
    r_g = 3 * m1 + 7
    eye = jnp.eye(m1, dtype=jnp.float64)
    ar = jnp.arange(m1)

    C = (
        jnp.zeros((n_ineq, dim), jnp.float64)
        .at[0:m1, ix].set(-eye)
        .at[m1:2 * m1, ix].set(eye)
        .at[r_ddl, ix].set(t_vec)
        .at[r_ddl, iy].set(sigma)
        .at[r_ddl, idl].set(-1.0)
        .at[r_c, iy].set(-2.0 * y_prev)
        .at[r_c, ia].set(-1.0)
        .at[r_d, ix].set(-2.0 * var_vec * x_prev)
        .at[r_d, ib].set(-1.0)
        .at[r_e + ar, ix].set(jnp.diag(1.0 - 2.0 * x_prev))
        .at[r_e + ar, ig].add(-eye)
        .at[r_y, iy].set(-1.0)
        .at[r_a, ia].set(-1.0)
        .at[r_a + 1, ib].set(-1.0)
        .at[r_a + 2, idl].set(-1.0)
        .at[r_g + ar, ig].set(-eye)
    )
    c0 = (
        jnp.zeros((n_ineq,), jnp.float64)
        .at[m1:2 * m1].set(-1.0)
        .at[r_ddl].set(-deadline)
        .at[r_c].set(y_prev**2)
        .at[r_d].set(jnp.dot(var_vec, x_prev**2))
        .at[r_e + ar].set(x_prev**2)
        .at[r_y].set(_Y_MIN)
    )
    quad_diag = (
        jnp.zeros((2, dim), jnp.float64).at[0, ix].set(var_vec).at[1, iy].set(1.0)
    )

    # Static row classification (concrete numpy — fixed by m1, not traced).
    j = np.arange(m1)
    spec = StructuredSpec(
        c_obj=c_obj,
        C=C,
        c0=c0,
        quad_diag=quad_diag,
        eq_vec=jnp.zeros((dim,), jnp.float64).at[ix].set(1.0),
        eq_rhs=jnp.asarray(1.0, jnp.float64),
        quad_rows=np.array([r_c, r_d]),
        diag_rows=np.concatenate([j, m1 + j, [r_y, r_a, r_a + 1, r_a + 2], r_g + j]),
        diag_cols=np.concatenate([j, j, [iy, ia, ib, idl], m1 + 4 + j]),
        pair_rows=r_e + j,
        pair_x=j,
        pair_elim=m1 + 4 + j,
        dense_rows=np.array([r_ddl, r_c, r_d]),
    )

    # Strictly feasible start around the previous iterate.
    x0 = 0.8 * x_prev + 0.2 / m1
    y0 = jnp.maximum(jnp.sqrt(jnp.dot(var_vec, x0 * x0)), 2.0 * _Y_MIN)
    pad = lambda v: jnp.maximum(v, 0.0) + 1e-4 * (1.0 + jnp.abs(v))
    alpha0 = pad(jnp.dot(var_vec, x0 * x0) - (2.0 * y_prev * y0 - y_prev**2))
    beta0 = pad(y0 * y0 - jnp.dot(var_vec, x_prev * (2.0 * x0 - x_prev)))
    delta0 = pad(jnp.dot(x0, t_vec) + sigma * y0 - deadline)
    gamma0 = pad(x0 * (1.0 - 2.0 * x_prev) + x_prev**2)
    z0 = jnp.concatenate(
        [x0, y0[None], alpha0[None], beta0[None], delta0[None], gamma0]
    )
    return spec, z0


def _inner_problem(e_vec, t_vec, var_vec, sigma, deadline, rho, x_prev, y_prev,
                   schedule=DEFAULT_SCHEDULE, solver: str = "structured"):
    """Build problem (36) for one device and solve it with the barrier IPM.

    ``solver="structured"`` (default) runs the structure-exploiting
    barrier of ``solvers/ipm.py`` — closed-form derivatives, O(dim) KKT
    solves, analytic line search. ``solver="dense"`` wraps the same
    assembled program in a :class:`BarrierSpec` and solves it with the
    generic autodiff path (the golden-pinned A/B reference).
    """
    spec, z0 = _inner_spec(
        e_vec, t_vec, var_vec, sigma, deadline, rho, x_prev, y_prev)
    m1 = e_vec.shape[0]
    t0, mu, stages, newton, ls = schedule
    if solver == "structured":
        res = structured_barrier_solve(
            spec, z0, t0=t0, mu=mu, outer_iters=stages, newton_iters=newton,
            ls_iters=ls)
    elif solver == "dense":
        res = barrier_solve(
            BarrierSpec(
                objective=lambda z: jnp.dot(spec.c_obj, z),
                inequalities=lambda z: structured_inequalities(spec, z),
                eq_matrix=spec.eq_vec[None, :],
                eq_rhs=jnp.ones((1,)),
            ),
            z0, t0=t0, mu=mu, outer_iters=stages, newton_iters=newton,
            ls_iters=ls)
    else:
        raise ValueError(f"solver must be one of {SOLVERS}, got {solver!r}")
    return res.z[0:m1], res.z[m1]


@partial(jax.jit, static_argnames=("num_iters", "schedule", "solver", "gated"))
def pccp_partition(
    e_table: jnp.ndarray,  # (N, M+1) energy of each point at current (b, f)
    t_table: jnp.ndarray,  # (N, M+1) mean total time of each point
    var_table: jnp.ndarray,  # (N, M+1) diag of W_n (eq. 27/28)
    sigma: jnp.ndarray,  # (N,) σ(ε_n)
    deadline: jnp.ndarray,  # (N,)
    x_init: jnp.ndarray,  # (N, M+1) initial relaxed point
    num_iters: int = 10,
    rho0: float = 5.0,
    nu: float = 3.0,
    rho_max: float = 1e5,
    theta_err: float = 1e-3,
    schedule: tuple = DEFAULT_SCHEDULE,  # inner barrier (t0, mu, stages, newton, ls)
    solver: str = "structured",  # inner barrier path: structured | dense
    gated: bool = False,  # while_loop outer: stop when all devices converge
) -> PCCPResult:
    """Run Algorithm 1 on the whole fleet (one vmapped inner IPM per step).

    ``gated=True`` swaps the fixed-trip ``lax.scan`` outer loop for a
    ``lax.while_loop`` that stops as soon as EVERY device satisfies
    ‖x_i − x_{i−1}‖ < θ_err — the Algorithm-1 stopping rule, saving the
    remaining iterations' wall-clock. The scan path stays the default
    because (a) under outer ``vmap`` (multi-start spread, zipped scenario
    batches) a while_loop runs until the *slowest lane* finishes anyway,
    and (b) stopping early yields a (slightly) different fixed point than
    running the full ρ-ramp, so the gated path is not bit-comparable to
    the golden-pinned scan path (DESIGN.md §solver).
    """
    n, m1 = e_table.shape

    inner = jax.vmap(
        lambda e, t, v, s, d, rho, xp, yp: _inner_problem(
            e, t, v, s, d, rho, xp, yp, schedule, solver),
        in_axes=(0, 0, 0, 0, 0, None, 0, 0))

    def run_step(x_prev, y_prev, rho):
        x_new, y_new = inner(
            e_table, t_table, var_table, sigma, deadline, rho, x_prev, y_prev
        )
        dx = jnp.linalg.norm(x_new - x_prev, axis=-1)
        return x_new, y_new, jnp.minimum(nu * rho, rho_max), dx

    y0 = jnp.sqrt(jnp.maximum(jnp.sum(var_table * x_init**2, -1), 4.0 * _Y_MIN**2))
    rho_init = jnp.asarray(rho0, jnp.float64)

    if gated:
        def cond(state):
            i, _, _, _, _, done = state
            return (i < num_iters) & ~done

        def body(state):
            i, x_prev, y_prev, rho, dxs, _ = state
            x_new, y_new, rho, dx = run_step(x_prev, y_prev, rho)
            dxs = dxs.at[i].set(dx)
            return i + 1, x_new, y_new, rho, dxs, jnp.all(dx < theta_err)

        # +inf in unvisited rows: they never count as converged below.
        dx_buf = jnp.full((num_iters, n), jnp.inf, jnp.float64)
        _, x_fin, _, _, dxs, _ = jax.lax.while_loop(
            cond, body, (jnp.asarray(0), x_init, y0, rho_init, dx_buf, False))
    else:
        def step(carry, _):
            x_prev, y_prev, rho = carry
            x_new, y_new, rho, dx = run_step(x_prev, y_prev, rho)
            return (x_new, y_new, rho), dx

        (x_fin, _, _), dxs = jax.lax.scan(
            step, (x_init, y0, rho_init), None, length=num_iters
        )

    # Algorithm-1 iteration count: first i with ‖x_i − x_{i−1}‖ < θ_err.
    converged = dxs < theta_err  # (K, N)
    first = jnp.argmax(converged, axis=0)
    never = ~jnp.any(converged, axis=0)
    # int32, not the x64-default int64: Plan.pccp_iters must have one
    # dtype across policies (the exact/optimal paths emit int32) or the
    # pytree contract — and any scan/cond over plans — flips per policy.
    iters = jnp.where(never, num_iters, first + 1).astype(jnp.int32)

    # Round + feasibility repair against the ECR constraint (28).
    margin = t_table + sigma[:, None] * jnp.sqrt(var_table) - deadline[:, None]
    feas_mask = margin <= 1e-9  # tolerance: incumbent sits exactly on the deadline
    m_round = jnp.argmax(x_fin, axis=-1)
    round_ok = jnp.take_along_axis(feas_mask, m_round[:, None], -1)[:, 0]
    e_masked = jnp.where(feas_mask, e_table, jnp.inf)
    m_repair = jnp.argmin(e_masked, axis=-1)
    any_feas = jnp.any(feas_mask, axis=-1)
    m_least_bad = jnp.argmin(margin, axis=-1)
    m_sel = jnp.where(round_ok, m_round, jnp.where(any_feas, m_repair, m_least_bad))
    feasible = jnp.take_along_axis(feas_mask, m_sel[:, None], -1)[:, 0]
    return PCCPResult(
        m_sel=m_sel.astype(jnp.int32),
        x_relaxed=x_fin,
        iters_to_converge=iters,
        step_norms=dxs,
        feasible=feasible,
    )
