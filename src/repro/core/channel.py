"""Wireless uplink model (paper §III-B and §VI-A).

FDMA uplink: device n gets bandwidth ``b_n`` (Hz) of the shared budget B.
Spectral efficiency  η_n = log2(1 + p_n·h_n / (b_n·N0))  — note the noise
power grows with the allocated band, so the *rate* R(b) = b·η(b) is
increasing and concave in b, and 1/R(b) is convex (this is what makes the
resource subproblem convex).
"""
from __future__ import annotations

import jax.numpy as jnp

# 3GPP TR 36.931 pico-cell path loss (paper eq. in §VI-A):
#   PL(dB) = 38 + 30·log10(r/m)
N0_DBM_PER_HZ = -174.0


def noise_psd_watt_per_hz(n0_dbm_per_hz: float = N0_DBM_PER_HZ) -> float:
    return 10.0 ** ((n0_dbm_per_hz - 30.0) / 10.0)


def pathloss_gain(r_m):
    """Linear channel gain from the 3GPP pico path-loss model."""
    pl_db = 38.0 + 30.0 * jnp.log10(jnp.asarray(r_m, jnp.float64))
    return 10.0 ** (-pl_db / 10.0)


def spectral_efficiency(b, p_tx, gain, n0=None):
    """η(b) = log2(1 + p·h/(b·N0)) in bit/s/Hz; safe at b→0⁺."""
    n0 = noise_psd_watt_per_hz() if n0 is None else n0
    b = jnp.maximum(b, 1e-3)  # numerical floor: 1 mHz
    return jnp.log2(1.0 + p_tx * gain / (b * n0))


def uplink_rate(b, p_tx, gain, n0=None):
    """R(b) = b·η(b) in bit/s — increasing, concave, R(0)=0."""
    return jnp.maximum(b, 0.0) * spectral_efficiency(b, p_tx, gain, n0)


def offload_time(d_bits, b, p_tx, gain, n0=None):
    """t_off = d / R(b)  (paper eq. (3))."""
    return d_bits / jnp.maximum(uplink_rate(b, p_tx, gain, n0), 1e-12)


def offload_energy(d_bits, b, p_tx, gain, n0=None):
    """e_off = p·t_off  (paper eq. (4))."""
    return p_tx * offload_time(d_bits, b, p_tx, gain, n0)


def offload_time_std(d_bits, b, p_tx, gain_mean, gain_cv, n0=None):
    """Std of t_off under channel-gain uncertainty (paper footnote 2).

    Delta method around h̄: t_off(h) = d/(b·log2(1+p·h/(b·N0))), so
      ∂t/∂h = −t_off · [p/(ln2·(b·N0+p·h))] / η(b)
    and std[t_off] ≈ t_off · (h̄·|∂logt/∂h|) · cv_h. Exact for small cv;
    validated by Monte-Carlo in tests/test_channel_robust.py.
    """
    n0 = noise_psd_watt_per_hz() if n0 is None else n0
    b = jnp.maximum(b, 1e-3)
    eta = spectral_efficiency(b, p_tx, gain_mean, n0)
    t = offload_time(d_bits, b, p_tx, gain_mean, n0)
    snr_term = p_tx * gain_mean / (b * n0 + p_tx * gain_mean)
    rel_sens = snr_term / (jnp.log(2.0) * jnp.maximum(eta, 1e-9))
    return t * rel_sens * gain_cv
