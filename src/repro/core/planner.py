"""Algorithm 2 — alternating robust partitioning + resource allocation.

Policies are **strategy records** in a registry (``Policy`` /
``register_policy``), not string if-chains: each policy bundles its
ambiguity-set σ model, its worst-case time inflation, its partition step
(PCCP vs exact enumeration), and — for baselines that bypass the
alternation entirely, like ``"optimal"`` — a full-plan ``solve`` override.
``_alternation`` dispatches through the record, so a new policy is a
``register_policy`` call, not an edit to the solver. Built-ins:

- ``"robust"``      — the paper: CCP margins (Cantelli σ) + PCCP partitioning.
- ``"robust_exact"``— beyond-paper: CCP margins + *exact per-device
                      enumeration* of the partition point (the decoupling
                      observation in DESIGN.md §2); certifies PCCP.
- ``"gaussian"``    — beyond-paper: Gaussian quantile σ instead of Cantelli
                      (tighter margins when times are near-normal).
- ``"worst_case"``  — §VI baseline: upper-bound times (mean + 3σ), no
                      probabilistic slack (hard deadline).
- ``"optimal"``     — §VI baseline: joint exhaustive search implemented as
                      price-based exact enumeration over (m, b, f)
                      (optimal because the problem decouples at a fixed
                      bandwidth price; see DESIGN.md). Registered with a
                      ``solve`` override, so it batch-dispatches through
                      ``api.Planner.plan_many``/``grid`` like any policy.

The whole planner is ONE compiled XLA program (DESIGN.md §planner): the
outer Algorithm-2 alternation is a ``lax.scan``, the multi-start spread is
a ``vmap`` over initial partition points with a traced
feasibility-then-energy argmin, and all scenario parameters
(deadline, ε, B) are traced — so repeated calls on same-shaped fleets hit
the jit cache, and ``core.api.Planner.plan_many`` can vmap whole zipped
scenario batches over the same trace.

``plan`` below is the deprecated-but-working functional wrapper; new code
should use ``repro.core.api`` (``Scenario`` / ``PlannerConfig`` /
``Planner``).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ccp, channel, energy, placement
from repro.core.blocks import Fleet
from repro.core.pccp import pccp_partition
from repro.core.resource import (
    _EDGE_CAP_RTOL,
    _LOG_PRICE_HI0,
    _LOG_PRICE_LO,
    Allocation,
    _device_best_b_at,
    _device_invariants,
    _expand_log_bracket,
    allocate,
    allocate_with_bracket,
    select_point,
)
from repro.solvers.scalar import bisect


#: ``Plan.status`` codes (DESIGN.md §robustness — the solver fail-soft
#: contract). The traced solve stamps OK/DEGRADED; the host-side ladder
#: in ``api.Planner.plan`` overwrites with the fallback codes when it
#: had to re-solve or reuse the incumbent.
PLAN_OK = 0  # healthy solve
PLAN_DEGRADED = 1  # non-finite leaves detected at solve time
PLAN_FALLBACK_DENSE = 2  # re-solved with the dense inner barrier
PLAN_FALLBACK_INCUMBENT = 3  # caller's incumbent plan returned instead

PLAN_STATUS_NAMES = {
    PLAN_OK: "ok",
    PLAN_DEGRADED: "degraded",
    PLAN_FALLBACK_DENSE: "fallback_dense",
    PLAN_FALLBACK_INCUMBENT: "fallback_incumbent",
}


class Plan(NamedTuple):
    m_sel: jnp.ndarray  # (N,) partition points
    alloc: Allocation  # bandwidth / frequency allocation
    total_energy: jnp.ndarray  # scalar objective (9a)
    feasible: jnp.ndarray  # (N,) chance/hard constraint satisfied
    objective_trace: jnp.ndarray  # (outer_iters,) Algorithm-2 trajectory (Fig. 10)
    pccp_iters: jnp.ndarray  # (outer_iters, N) Algorithm-1 iterations (Fig. 9)
    margins: jnp.ndarray  # (N,) deadline margin (≤0 ⇒ guaranteed)
    status: jnp.ndarray = jnp.int32(PLAN_OK)  # scalar PLAN_* code  # analyze: ok(TRC005): tiny scalar NamedTuple default; a concrete int32 stamp is the contract
    #: device→edge-node map a ∈ {0..E−1}^N (DESIGN.md §placement). All
    #: zeros on the scalar-capacity path (one shared edge ⇒ node 0).
    assignment: jnp.ndarray = jnp.int32(0)  # analyze: ok(TRC005): tiny scalar NamedTuple default; traced solves stamp the (N,) map


# ---------------------------------------------------------------------------
# Policy strategy registry
# ---------------------------------------------------------------------------

#: Worst-case baseline upper bound: mean + UB_K·std. Fig. 1/5 show
#: heavy-tailed outliers (spikes ≫ mean); the empirical max of the paper's
#: 500-sample campaigns corresponds to ≈ mean + 8·std for such tails.
WORST_CASE_UB_K = 8.0

#: Masking constants for ragged fleets (DESIGN.md §fleet): padded points
#: get this energy/time in the per-point tables, so no argmin — feasible,
#: least-bad, or PCCP-rounded — can ever select them (real times are
#: ≪ 1e6 s, real energies ≪ 1e6 J), while staying finite so the PCCP
#: inner barrier stays well-conditioned (∞ would poison its residuals).
MASK_ENERGY_J = 1e6
MASK_TIME_S = 1e6

#: One-sided safety factor on a discovered edge clearing price. The
#: occupancy excess is a step function of μ; the bisection's upper
#: endpoint sits within ~1 ulp of a jump, where re-evaluating the priced
#: argmin across an XLA fusion boundary can round to the *other* side of
#: the threshold. Over-pricing by 1e-9 relative is decisively past the
#: jump and is the safe direction (occupancy only shrinks as μ grows).
_MU_SAFETY = 1.0 + 1e-9


@dataclass(frozen=True)
class Policy:
    """Strategy record for one planning policy.

    Instances are hashable statics: they ride through ``jax.jit`` as
    ``static_argnames`` entries, and the registry hands out singletons so
    repeated lookups hit the same jit-cache key.

    ``partition`` runs inside the Algorithm-2 alternation with signature
    ``(m, e_table, t_table, var_table, sigma, deadline, pccp_iters,
    solver, gated) -> (m_new, feasible, iters)`` — for edge-aware policies
    the energy table arrives already μ-priced (``e + μ·t̄_vm``); ``solver``
    / ``gated`` are the inner-barrier statics of DESIGN.md §solver
    (partition steps that do not run the PCCP ignore them). ``solve``,
    when set,
    replaces the whole alternation (signature ``(fleet, deadline, eps, B,
    edge_cap, policy, outer_iters, pccp_iters, channel_cv, edge_eps)
    -> Plan``) — used by ``"optimal"``.
    """

    name: str
    sigma_model: str = "cantelli"  # key into ccp.SIGMA_FNS
    ub_k: float = 0.0  # worst-case time inflation (mean + ub_k·std)
    partition: Optional[Callable] = None
    solve: Optional[Callable] = None
    #: charge the shared-edge clearing price μ·t̄_vm on every candidate
    #: point of the partition subproblem (DESIGN.md §edge). With an
    #: infinite edge capacity μ = 0 and this is a numerical no-op; set
    #: False to register a policy that ignores edge contention when
    #: partitioning (the capacity check still gates feasibility).
    edge_aware: bool = True
    #: device→node assignment strategy under a per-node capacity vector
    #: (key into ``placement.ASSIGN_FNS``; DESIGN.md §placement). Ignored
    #: on the scalar-capacity path.
    assign: str = "hybrid"

    def __post_init__(self):
        if self.sigma_model not in ccp.SIGMA_FNS:
            raise ValueError(
                f"sigma_model must be one of {tuple(ccp.SIGMA_FNS)}, "
                f"got {self.sigma_model!r}")
        if self.partition is None and self.solve is None:
            raise ValueError("a Policy needs a partition step or a solve override")
        if self.assign not in placement.ASSIGN_FNS:
            raise ValueError(
                f"assign must be one of {placement.available_assignments()}, "
                f"got {self.assign!r}")


_REGISTRY: dict[str, Policy] = {}


def register_policy(policy: Policy, *, overwrite: bool = False) -> Policy:
    """Add ``policy`` to the registry (returns it, for assignment)."""
    if policy.name in _REGISTRY and not overwrite:
        raise ValueError(f"policy {policy.name!r} already registered "
                         "(pass overwrite=True to replace)")
    _REGISTRY[policy.name] = policy
    return policy


def get_policy(policy) -> Policy:
    """Resolve a policy name (or pass through a ``Policy`` instance)."""
    if isinstance(policy, Policy):
        return policy
    try:
        return _REGISTRY[policy]
    except KeyError:
        raise ValueError(
            f"unknown policy {policy!r}; registered: {available_policies()}"
        ) from None


def available_policies() -> tuple[str, ...]:
    return tuple(_REGISTRY)


def _point_tables(fleet: Fleet, b, f, channel_cv: float = 0.0):
    """Per-(device, point) energy/time/variance tables at fixed ``(b, f)``
    (the per-device allocation vectors — pass ``alloc.b, alloc.f``).

    For ragged fleets the padded points are masked here — the one place
    every partition step (exact enumeration AND the PCCP barrier) reads
    its tables from — with finite sentinel energy/time and zero variance,
    so downstream selections can never land on padding. An all-valid mask
    is a numerical no-op (pure selects).
    """
    c, plat, link = fleet.chain, fleet.platform, fleet.link
    f = f[:, None]
    b = b[:, None]
    e_loc = energy.expected_local_energy(plat.kappa[:, None], c.w_flops, c.g_eff, f)
    t_loc = energy.mean_local_time(c.w_flops, c.g_eff, f)
    t_off = channel.offload_time(c.d_bits, b, link.p_tx[:, None], link.gain[:, None])
    e_off = link.p_tx[:, None] * t_off
    e_table = e_loc + e_off
    t_table = t_loc + t_off + c.t_vm
    var_table = c.v_loc + c.v_vm
    if channel_cv > 0.0:  # joint channel robustness (paper footnote 2)
        std_off = channel.offload_time_std(
            c.d_bits, b, link.p_tx[:, None], link.gain[:, None], channel_cv)
        var_table = var_table + std_off**2
    if fleet.valid is not None:  # ragged fleet: mask padded points
        e_table = jnp.where(fleet.valid, e_table, MASK_ENERGY_J)
        t_table = jnp.where(fleet.valid, t_table, MASK_TIME_S)
        var_table = jnp.where(fleet.valid, var_table, 0.0)
    return e_table, t_table, var_table


def policy_point_tables(fleet: Fleet, b, f, policy: Policy,
                        channel_cv: float = 0.0):
    """``_point_tables`` with the policy's worst-case time inflation
    applied (mean + ub_k·std, variance dropped — §VI baseline). The ONE
    implementation of the policy-conditioned tables: the alternation, the
    group-sharded decomposition, the straight-line reference port and the
    phase-breakdown bench all read their partition subproblem from here,
    so they cannot drift apart. Takes the raw ``(b, f)`` vectors (not an
    ``Allocation``) so per-group programs can call it on sliced batches.
    """
    e_table, t_table, var_table = _point_tables(fleet, b, f, channel_cv)
    if policy.ub_k > 0.0:  # worst-case inflation: mean + ub_k·std, no variance
        t_table = t_table + policy.ub_k * (
            jnp.sqrt(jnp.maximum(fleet.chain.v_loc, 0.0))
            + jnp.sqrt(jnp.maximum(fleet.chain.v_vm, 0.0))
        )
        var_table = jnp.zeros_like(var_table)
    return e_table, t_table, var_table


def _traced_status(alloc: Allocation, total_energy, margins) -> jnp.ndarray:
    """OK/DEGRADED stamp computed inside the trace (no host syncs): a
    healthy plan has finite allocation, energy and margins. Transient
    NaNs inside rejected line-search candidates are fine — this checks
    the *outputs* the caller is about to act on."""
    healthy = (jnp.all(jnp.isfinite(alloc.b)) & jnp.all(jnp.isfinite(alloc.f))
               & jnp.isfinite(total_energy) & jnp.all(jnp.isfinite(margins)))
    return jnp.where(healthy, PLAN_OK, PLAN_DEGRADED).astype(jnp.int32)


def _exact_partition(e_table, t_table, var_table, sigma, deadline):
    """Exact per-device enumeration under the ECR constraint (28)."""
    margin = t_table + sigma[:, None] * jnp.sqrt(jnp.maximum(var_table, 0.0)) - deadline[:, None]
    # Tolerance: allocate() drives f to meet the deadline *exactly*, so the
    # incumbent point sits at margin ≈ +ulp; treat it as feasible.
    feas = margin <= 1e-9
    e_masked = jnp.where(feas, e_table, jnp.inf)
    m_feas = jnp.argmin(e_masked, axis=-1)
    any_feas = jnp.any(feas, axis=-1)
    m_least_bad = jnp.argmin(margin, axis=-1)
    m_sel = jnp.where(any_feas, m_feas, m_least_bad).astype(jnp.int32)
    return m_sel, jnp.take_along_axis(feas, m_sel[:, None], -1)[:, 0]


def _clearing_price(occ_at, edge_cap, prior_log_hi=None):
    """Smallest price μ ≥ 0 with ``occ_at(μ) ≤ edge_cap``; returns
    ``(μ, log_hi)`` where ``log_hi`` is the expanded bracket top (for
    warm-starting the next clearing — value-identical, see
    ``resource._expand_log_bracket``).

    ``occ_at`` must be a non-increasing step function of μ (a priced
    argmin's selected occupancy). The search is a log-space bisection
    with the adaptively expanded bracket of ``resource``; the *upper*
    bracket endpoint ×``_MU_SAFETY`` is returned so the discovered price
    sits on the feasible side of the step. Complementary slackness:
    μ = 0 when the unpriced selection already fits.
    """
    need = occ_at(jnp.asarray(0.0, jnp.float64)) > edge_cap

    def occ_excess(log_mu):
        return occ_at(10.0**log_mu) - edge_cap

    log_hi, _ = _expand_log_bracket(occ_excess, hi_start=prior_log_hi)
    log_mu = bisect(occ_excess, _LOG_PRICE_LO, log_hi, iters=60, endpoint="hi")
    return jnp.where(need, 10.0**log_mu * _MU_SAFETY, 0.0), log_hi


def _edge_occ_prep(t_table, var_table, sigma, deadline):
    """μ-invariant pieces of the priced partition argmin: per-point
    feasibility, any-feasible flags, least-bad fallback points. Split out
    so the group-sharded path can hoist them out of the μ bisection."""
    margin = (t_table + sigma[:, None] * jnp.sqrt(jnp.maximum(var_table, 0.0))
              - deadline[:, None])
    feas = margin <= 1e-9
    any_feas = jnp.any(feas, axis=-1)
    m_least_bad = jnp.argmin(margin, axis=-1)
    return feas, any_feas, m_least_bad


def _edge_clearing_price(e_table, t_table, var_table, sigma, deadline,
                         occ_table, edge_cap, prior_log_hi=None,
                         occ_var=None, edge_sigma: float = 0.0):
    """Market-clearing price μ of the shared-edge capacity at fixed (b, f)
    — returns ``(μ, log_hi)`` like ``_clearing_price``.

    The partition subproblem decouples per device at a given μ (each
    device argmins its priced table ``e + μ·occ`` over feasible points),
    so the fleet's total occupancy Σ occ(m*(μ)) is a non-increasing step
    function of μ — priced by ``_clearing_price`` over the *tables*
    (no golden sections: ~60 cheap argmins).

    ``edge_sigma`` > 0 (static — from ``placement.edge_sigma(edge_eps)``)
    clears against the Cantelli chance-constrained occupancy
    Σ occ + σ_e·√(Σ var) instead of the mean (``occ_var`` is the per-point
    VM variance table); at 0.0 the trace is untouched.
    """
    feas, any_feas, m_least_bad = _edge_occ_prep(t_table, var_table, sigma,
                                                 deadline)

    def occ_at(mu):
        cost = jnp.where(feas, e_table + mu * occ_table, jnp.inf)
        m = jnp.where(any_feas, jnp.argmin(cost, axis=-1), m_least_bad)
        occ = jnp.sum(jnp.take_along_axis(occ_table, m[:, None], -1)[:, 0])
        if edge_sigma > 0.0:
            var = jnp.sum(jnp.take_along_axis(occ_var, m[:, None], -1)[:, 0])
            occ = occ + edge_sigma * jnp.sqrt(jnp.maximum(var, 0.0))
        return occ

    return _clearing_price(occ_at, edge_cap, prior_log_hi=prior_log_hi)


def _node_clearing_prices(e_table, t_table, var_table, sigma, deadline,
                          occ_table, assignment, caps, prior_log_hi=None,
                          occ_var=None, edge_sigma: float = 0.0):
    """Per-node clearing prices μ ∈ R^E at a fixed assignment — the
    transport subproblem's continuous half (DESIGN.md §placement).

    Each node clears independently: all devices argmin their table priced
    at the node's trial μ, and only the occupancy of the devices *assigned
    to that node* is summed against its capacity C_e — the same
    ``_clearing_price`` log-space bracket arithmetic as the scalar edge,
    vmapped over nodes (so ``plan_sharded``'s host loop can replay each
    node's bisection IEEE-identically). Returns ``(μ_vec, log_hi_vec)``,
    both ``(E,)``; ``prior_log_hi`` warm-starts per node.
    """
    feas, any_feas, m_least_bad = _edge_occ_prep(t_table, var_table, sigma,
                                                 deadline)
    e_count = caps.shape[0]
    masks = assignment[None, :] == jnp.arange(e_count)[:, None]  # (E, N)

    def occ_at_node(mask, mu):
        cost = jnp.where(feas, e_table + mu * occ_table, jnp.inf)
        m = jnp.where(any_feas, jnp.argmin(cost, axis=-1), m_least_bad)
        occ_sel = jnp.take_along_axis(occ_table, m[:, None], -1)[:, 0]
        occ = jnp.sum(jnp.where(mask, occ_sel, 0.0))
        if edge_sigma > 0.0:
            var_sel = jnp.take_along_axis(occ_var, m[:, None], -1)[:, 0]
            occ = occ + edge_sigma * jnp.sqrt(jnp.maximum(
                jnp.sum(jnp.where(mask, var_sel, 0.0)), 0.0))
        return occ

    def one(mask, cap, hi):
        return _clearing_price(lambda mu: occ_at_node(mask, mu), cap,
                               prior_log_hi=hi)

    if prior_log_hi is None:
        prior_log_hi = jnp.full((e_count,), _LOG_PRICE_HI0, jnp.float64)
    return jax.vmap(one)(masks, caps, prior_log_hi)


def exact_partition_step(m, e_table, t_table, var_table, sigma, deadline,
                         pccp_iters, solver="structured", gated=False):
    """Partition strategy: exact per-device enumeration (DESIGN.md §2)."""
    del m, pccp_iters, solver, gated  # no inner barrier to configure
    m_new, feas = _exact_partition(e_table, t_table, var_table, sigma, deadline)
    return m_new, feas, jnp.ones(m_new.shape, jnp.int32)


def pccp_partition_step(m, e_table, t_table, var_table, sigma, deadline,
                        pccp_iters, solver="structured", gated=False):
    """Partition strategy: the paper's penalty CCP (Algorithm 1)."""
    x_init = jax.nn.one_hot(m, e_table.shape[-1], dtype=jnp.float64)
    res = pccp_partition(
        e_table, t_table, var_table, sigma, deadline, x_init,
        num_iters=pccp_iters, solver=solver, gated=gated
    )
    return res.m_sel, res.feasible, res.iters_to_converge


def default_starts(num_points: int) -> list[int]:
    """Multi-start spread of initial partition points (Fig. 10)."""
    m1 = num_points
    return sorted({1, m1 // 2, (3 * m1) // 4, max(m1 - 2, 1), m1 - 1})


def initial_points(fleet: Fleet, init_m, multi_start: bool):
    """Resolve the planner's initial partition points → (m0, use_multi).

    Shared by every planning entry point (``api.Planner``, the legacy
    ``plan``/``plan_grid`` wrappers) so all resolve starts identically
    (the batch contract is ``plan_many(...)[k] == plan(...)``).

    With ``multi_start`` and no explicit ``init_m``: the Fig. 10 spread as
    an (S, N) batch. Otherwise a single (N,) start — ``init_m`` broadcast,
    or full local inference (m = M). The alternation is sensitive to its
    start (paper Fig. 10 uses interior points): m = 0 pins f at f_min
    which makes every local prefix look deadline-infeasible in the
    partitioning step, while full-local allocates a high frequency from
    which all prefixes are reachable.

    On ragged fleets every start is clamped to the device's own chain
    (``m ≤ M_n``); the spread is derived from the padded width, so devices
    with short chains see a denser spread near their terminal point.
    """
    n, m1 = fleet.num_devices, fleet.max_points

    def clamp(m0):
        if fleet.num_points is None:
            return m0
        return jnp.minimum(m0, fleet.num_points - 1)

    if multi_start and init_m is None:
        starts = default_starts(m1)
        m0 = jnp.broadcast_to(
            jnp.asarray(starts, jnp.int32)[:, None], (len(starts), n))
        return clamp(m0), True
    if init_m is None:
        return clamp(jnp.full((n,), m1 - 1, jnp.int32)), False
    if not isinstance(init_m, jax.core.Tracer):  # bounds-check concrete starts
        arr = np.asarray(init_m)  # analyze: ok(TRC002): concrete by the Tracer guard above
        if arr.size and (arr.min() < 0 or arr.max() > m1 - 1):  # analyze: ok(TRC003): host bounds check on a concrete start
            raise ValueError(
                f"init_m must lie in [0, {m1 - 1}] (partition points 0..M for "
                f"a {m1 - 1}-block chain); got {init_m!r}")
    return clamp(jnp.broadcast_to(jnp.asarray(init_m, jnp.int32), (n,))), False


def _plan_tail(fleet: Fleet, m, alloc, deadline, eps, sig_model, feasible,
               traces, pccp_trace, assignment) -> Plan:
    """Shared plan assembly: margins + status at the final (m, alloc).
    Pure function of its inputs — the scalar and vector alternation
    branches (and only they) both end here, so the scalar path's ops are
    unchanged from the pre-placement goldens."""
    sel = select_point(fleet, m)
    t_mean = (
        energy.mean_local_time(sel.w_flops, sel.g_eff, alloc.f)
        + channel.offload_time(sel.d_bits, alloc.b, fleet.link.p_tx, fleet.link.gain)
        + sel.t_vm
    )
    margins = ccp.deterministic_deadline_margin(
        t_mean, sel.v_loc + sel.v_vm, eps, deadline, sig_model
    )
    total_energy = jnp.sum(alloc.energy)
    return Plan(
        m_sel=m,
        alloc=alloc,
        total_energy=total_energy,
        feasible=feasible & alloc.feasible,
        objective_trace=traces,
        pccp_iters=pccp_trace,
        margins=margins,
        status=_traced_status(alloc, total_energy, margins),
        assignment=assignment,
    )


def _alternation(fleet: Fleet, deadline, eps, B, edge_cap, m0, policy: Policy,
                 outer_iters: int, pccp_iters: int, channel_cv: float,
                 solver: str = "structured", pccp_gated: bool = False,
                 edge_eps=None) -> Plan:
    """One Algorithm-2 alternation from initial points ``m0`` — fully traced.

    The outer loop is a ``lax.scan`` carrying the partition decision; each
    step re-allocates (b, f) at the current m and re-partitions at the new
    (b, f). No host syncs, so the whole alternation stays one XLA program.
    Policy behaviour (σ model, time inflation, partition step) comes from
    the ``Policy`` record — no per-policy branches live here.

    ``edge_cap`` is the shared-edge VM-time budget (traced; ∞ ⇒ dedicated
    VMs): each step discovers the clearing price μ on the current tables
    and charges μ·t̄_vm per candidate point, so the partition internalizes
    edge contention; with ∞ capacity μ = 0 and the step is numerically
    identical to the uncoupled planner.

    A **per-node ``(E,)`` capacity vector** (DESIGN.md §placement) routes
    to the placement branch: each step assigns devices to nodes with the
    policy's ``assign`` strategy at the current occupancies, clears a
    per-node price vector μ ∈ R^E (``_node_clearing_prices``, warm-started
    per node through the scan), and charges each device its *own* node's
    price μ_{a_n}·t̄_vm in the partition tables. The capacity's *shape* is
    static, so the scalar path's jaxpr is untouched (E=1 vectors are
    collapsed to scalars by ``Scenario.normalized`` — goldens stay
    leaf-identical). ``edge_eps`` (static) swaps the mean occupancy rows
    for Cantelli chance-constrained rows everywhere the capacity is
    checked or cleared.
    """
    n = fleet.num_devices
    deadline = jnp.broadcast_to(jnp.asarray(deadline, jnp.float64), (n,))
    eps = jnp.broadcast_to(jnp.asarray(eps, jnp.float64), (n,))
    edge_cap = jnp.asarray(edge_cap, jnp.float64)
    sig_model, ub_k = policy.sigma_model, policy.ub_k
    sigma = ccp.SIGMA_FNS[sig_model](eps)
    occ_table = fleet.chain.t_vm  # (N, M+1) edge occupancy per point
    occ_var = fleet.chain.v_vm  # (N, M+1) VM variance (Cantelli row)
    edge_sig = placement.edge_sigma(edge_eps)
    m = jnp.broadcast_to(jnp.asarray(m0, jnp.int32), (n,))
    hi0 = jnp.asarray(_LOG_PRICE_HI0, jnp.float64)

    if edge_cap.ndim == 0:  # one shared edge (scalar μ — the seed goldens)
        def step(carry, _):
            m, lam_hi, mu_hi = carry
            alloc, lam_hi = allocate_with_bracket(
                fleet, m, deadline, eps, B, sig_model, ub_k, channel_cv,
                edge_capacity_s=edge_cap, prior_log_hi=lam_hi,
                edge_eps=edge_eps)
            e_table, t_table, var_table = policy_point_tables(
                fleet, alloc.b, alloc.f, policy, channel_cv)
            if policy.edge_aware:
                mu, mu_hi = _edge_clearing_price(e_table, t_table, var_table,
                                                 sigma, deadline, occ_table,
                                                 edge_cap, prior_log_hi=mu_hi,
                                                 occ_var=occ_var,
                                                 edge_sigma=edge_sig)
            else:
                mu = jnp.asarray(0.0, jnp.float64)
            m_new, feas, pc = policy.partition(
                m, e_table + mu * occ_table, t_table, var_table, sigma, deadline,
                pccp_iters, solver, pccp_gated)
            # the trace records true energy, not the μ-priced surrogate
            obj = jnp.sum(jnp.take_along_axis(e_table, m_new[:, None], -1)[:, 0])
            return (m_new, lam_hi, mu_hi), (obj, pc, feas, mu)

        carry, (traces, pccp_trace, feas_seq, mu_seq) = jax.lax.scan(
            step, (m, hi0, hi0), None, length=outer_iters)
        m, lam_hi, _ = carry
        alloc, _ = allocate_with_bracket(
            fleet, m, deadline, eps, B, sig_model, ub_k, channel_cv,
            edge_capacity_s=edge_cap, edge_price=mu_seq[-1],
            prior_log_hi=lam_hi, edge_eps=edge_eps)
        assignment = jnp.zeros((n,), jnp.int32)
    else:  # per-node capacities: assignment + per-node prices
        e_count = edge_cap.shape[0]

        def step(carry, _):
            m, lam_hi, mu_hi = carry
            occ_now = jnp.take_along_axis(occ_table, m[:, None], -1)[:, 0]
            assign = placement.assign_devices(occ_now, edge_cap, policy.assign)
            alloc, lam_hi = allocate_with_bracket(
                fleet, m, deadline, eps, B, sig_model, ub_k, channel_cv,
                edge_capacity_s=edge_cap, prior_log_hi=lam_hi,
                assignment=assign, edge_eps=edge_eps)
            e_table, t_table, var_table = policy_point_tables(
                fleet, alloc.b, alloc.f, policy, channel_cv)
            if policy.edge_aware:
                mu_vec, mu_hi = _node_clearing_prices(
                    e_table, t_table, var_table, sigma, deadline, occ_table,
                    assign, edge_cap, prior_log_hi=mu_hi, occ_var=occ_var,
                    edge_sigma=edge_sig)
            else:
                mu_vec = jnp.zeros((e_count,), jnp.float64)
            mu_dev = mu_vec[assign]  # each device pays its own node's price
            m_new, feas, pc = policy.partition(
                m, e_table + mu_dev[:, None] * occ_table, t_table, var_table,
                sigma, deadline, pccp_iters, solver, pccp_gated)
            obj = jnp.sum(jnp.take_along_axis(e_table, m_new[:, None], -1)[:, 0])
            return (m_new, lam_hi, mu_hi), (obj, pc, feas, mu_vec)

        mu_hi0 = jnp.full((e_count,), _LOG_PRICE_HI0, jnp.float64)
        carry, (traces, pccp_trace, feas_seq, mu_seq) = jax.lax.scan(
            step, (m, hi0, mu_hi0), None, length=outer_iters)
        m, lam_hi, _ = carry
        occ_final = jnp.take_along_axis(occ_table, m[:, None], -1)[:, 0]
        assignment = placement.assign_devices(occ_final, edge_cap,
                                              policy.assign)
        alloc, _ = allocate_with_bracket(
            fleet, m, deadline, eps, B, sig_model, ub_k, channel_cv,
            edge_capacity_s=edge_cap, edge_price=mu_seq[-1],
            prior_log_hi=lam_hi, assignment=assignment, edge_eps=edge_eps)

    return _plan_tail(fleet, m, alloc, deadline, eps, sig_model, feas_seq[-1],
                      traces, pccp_trace, assignment)


def _select_best(plans: Plan) -> jnp.ndarray:
    """Traced multi-start selection: feasible plans first, then lowest
    energy — the same lexicographic key as the seed's
    ``min(plans, key=(num_infeasible, energy))``, with first-occurrence
    tie-breaking matching Python ``min`` over ascending starts.

    Fail-soft guard: a lane whose energy went non-finite is ranked worse
    than every finite lane (NaNs would otherwise poison the argmin), so a
    single diverged start can never shadow a healthy one. With all lanes
    finite this is bit-identical to the unguarded selection."""
    finite = jnp.isfinite(plans.total_energy)
    n_dev = plans.feasible.shape[-1]
    n_bad = jnp.where(jnp.asarray(finite),
                      jnp.sum(~plans.feasible, axis=-1), n_dev + 1)
    best_bad = jnp.min(n_bad)
    e_masked = jnp.where((n_bad == best_bad) & finite,
                         plans.total_energy, jnp.inf)
    return jnp.argmin(e_masked)


def _multi_start(fleet: Fleet, deadline, eps, B, edge_cap, m0_batch,
                 policy: Policy, outer_iters: int, pccp_iters: int,
                 channel_cv: float, solver: str = "structured",
                 pccp_gated: bool = False, edge_eps=None) -> Plan:
    """vmapped multi-start alternation + traced best-plan selection."""
    plans = jax.vmap(
        lambda m0: _alternation(fleet, deadline, eps, B, edge_cap, m0, policy,
                                outer_iters, pccp_iters, channel_cv, solver,
                                pccp_gated, edge_eps)
    )(m0_batch)
    idx = _select_best(plans)
    return jax.tree_util.tree_map(lambda x: x[idx], plans)


def _solve_entry(fleet: Fleet, deadline, eps, B, edge_cap, policy: Policy,
                 outer_iters: int, pccp_iters: int, channel_cv: float,
                 solver: str = "structured", pccp_gated: bool = False,
                 edge_eps=None) -> Plan:
    """Entry for solve-override policies (no alternation, no starts; the
    inner-barrier statics do not apply to exact solves)."""
    del solver, pccp_gated
    return policy.solve(fleet, deadline, eps, B, edge_cap, policy,
                        outer_iters, pccp_iters, channel_cv, edge_eps)


_STATICS = ("policy", "outer_iters", "pccp_iters", "channel_cv", "solver",
            "pccp_gated", "edge_eps")

#: Jitted entry points. Exposed at module level (not hidden in ``plan``) so
#: tests can assert cache behaviour via ``_cache_size()``. ``policy`` is a
#: static ``Policy`` record; the registry hands out singletons so the cache
#: key is stable across calls.
plan_single_jit = partial(jax.jit, static_argnames=_STATICS)(_alternation)
plan_multi_jit = partial(jax.jit, static_argnames=_STATICS)(_multi_start)
plan_solve_jit = partial(jax.jit, static_argnames=_STATICS)(_solve_entry)


def plan(
    fleet: Fleet,
    deadline: jnp.ndarray,
    eps: jnp.ndarray,
    B: float,
    policy: str = "robust",
    outer_iters: int = 6,
    init_m: Optional[jnp.ndarray] = None,
    pccp_iters: int = 10,
    multi_start: bool = True,
    channel_cv: float = 0.0,
) -> Plan:
    """Run Algorithm 2 (or a baseline policy) and return the plan.

    .. deprecated::
        Thin delegating wrapper over :class:`repro.core.api.Planner` —
        prefer ``Planner(PlannerConfig(...)).plan(fleet, Scenario(...))``,
        which also exposes zipped scenario batches (``plan_many``) and
        grids. This wrapper is kept leaf-identical to the seed goldens
        (``tests/golden/seed_plans.json``).

    ``multi_start`` follows Fig. 10: the alternation converges to a
    stationary point that depends on the initial partition point, so we run
    it from a small spread of starts (vmapped) and keep the best feasible
    plan. The whole call — including the multi-start sweep — is a single
    compiled XLA program; scenario parameters (deadline, ε, B) are traced,
    so only a new fleet *shape* or new static (policy, iteration counts)
    triggers recompilation.
    """
    import warnings

    from repro.core.api import Planner, PlannerConfig, Scenario

    warnings.warn(
        "repro.core.plan is deprecated; use "
        "api.Planner(PlannerConfig(...)).plan(fleet, Scenario(...))",
        DeprecationWarning, stacklevel=2)
    cfg = PlannerConfig(policy=policy, outer_iters=outer_iters,
                        pccp_iters=pccp_iters, multi_start=multi_start,
                        channel_cv=channel_cv)
    return Planner(cfg).plan(fleet, Scenario(deadline, eps, B), init_m=init_m)


def _optimal_prep(fleet: Fleet, deadline, sigma, B):
    """λ-invariant tables of the optimal joint search: per-(device, point)
    deadline budgets and the feasibility bracket of ``_device_invariants``.
    Shared by ``plan_optimal`` and the per-group programs of
    ``core.decompose`` (which runs the same search at native group width)."""
    c, plat, link = fleet.chain, fleet.platform, fleet.link
    budget_all = (
        deadline[:, None]
        - c.t_vm
        - sigma[:, None] * jnp.sqrt(jnp.maximum(c.v_loc + c.v_vm, 0.0))
    )  # (N, M+1)
    if fleet.valid is not None:  # ragged fleet: padded points are never
        # feasible (negative budget ⇒ feas=False ⇒ cost=∞) nor the
        # least-bad fallback (argmax over budgets)
        budget_all = jnp.where(fleet.valid, budget_all, -MASK_TIME_S)
    inv_points = jax.vmap(
        lambda bud, d, w, g, fmax, p, h: _device_invariants(bud, d, w, g, fmax, p, h, B),
        in_axes=(0, 0, 0, 0, None, None, None),
    )
    inv_devices = jax.vmap(inv_points, in_axes=(0, 0, 0, 0, 0, 0, 0))
    b_lo_all, feas0_all = inv_devices(
        budget_all, c.d_bits, c.w_flops, c.g_eff, plat.f_max, link.p_tx, link.gain
    )  # (N, M+1) each
    return budget_all, b_lo_all, feas0_all


def _optimal_point_solve(fleet: Fleet, budget_all, b_lo_all, feas0_all, lam, B):
    """Solve the 1-D convex bandwidth problem for every (device, point) at
    price λ → ``(cost, b, f, e, feas)`` tables, cost ∞ on infeasible points."""
    c, plat, link = fleet.chain, fleet.platform, fleet.link

    def per_point(lam, bud, d, w, g, k, fmin, fmax, p, h, blo, fe):
        b, f, feas = _device_best_b_at(lam, bud, d, w, g, k, fmin, fmax, p, h, B, blo, fe)
        e = energy.expected_local_energy(k, w, g, f) + channel.offload_energy(d, b, p, h)
        cost = jnp.where(feas, e + lam * b, jnp.inf)
        return cost, b, f, e, feas

    vm_points = jax.vmap(
        per_point, in_axes=(None, 0, 0, 0, 0, None, None, None, None, None, 0, 0))
    vm_devices = jax.vmap(vm_points, in_axes=(None, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0))
    return vm_devices(
        lam, budget_all, c.d_bits, c.w_flops, c.g_eff,
        plat.kappa, plat.f_min, plat.f_max, link.p_tx, link.gain,
        b_lo_all, feas0_all,
    )


def _optimal_select(cost, feas, budget_all, occ_all, mu):
    """Per-device argmin of the (λ, μ)-priced point scores (cost already ∞
    on infeasible points; fallback = largest-budget point)."""
    priced = cost + mu * occ_all
    any_feas = jnp.any(feas, axis=-1)
    m_sel = jnp.where(any_feas, jnp.argmin(priced, -1),
                      jnp.argmax(budget_all, -1))
    return m_sel.astype(jnp.int32), any_feas


def plan_optimal(fleet: Fleet, deadline, eps, B, sigma_model: str = "cantelli",
                 edge_capacity_s=None, assign: str = "hybrid",
                 edge_eps=None) -> Plan:
    """§VI "Optimal policy": joint exact search over (m, b, f).

    At a fixed bandwidth price λ the joint problem separates per device
    *and* per candidate point: solve the 1-D convex bandwidth problem for
    every (n, m), take the per-device argmin over m, then bisect λ until
    Σ b ≤ B. Complexity O(N·M·log) — equivalent to the paper's exhaustive
    baseline (which is exponential only because it enumerates x jointly).
    The λ-invariant feasibility bracket per (n, m) is hoisted out of the
    price bisection (same hoist as ``resource.allocate``).

    ``edge_capacity_s`` turns this into the **two-price dual
    decomposition** over (λ, μ) of DESIGN.md §edge: the per-point score
    gains μ·t̄_vm and the outer search nests — for every λ step the edge
    price μ*(λ) is cleared by a *cheap* inner bisection over the already-
    solved point tables (the per-point (b, f) solutions depend on λ only,
    so no golden sections re-run), and the λ bisection proceeds on
    Σ b(λ, μ*(λ)) − B, which stays monotone because partial maximization
    over μ preserves the dual's concavity. With ∞ capacity μ*(λ) ≡ 0 and
    the search degenerates to the single-price seed path bit-for-bit.

    Fully traced (fixed-iteration bisection), so the ``"optimal"`` policy
    vmaps over zipped scenario batches like any other registry entry.

    A per-node ``(E,)`` capacity vector (DESIGN.md §placement) runs the
    placement variant: at each λ the assignment is built from the
    unpriced selection's occupancies (strategy ``assign``), per-node
    prices μ ∈ R^E are cleared over the same point tables, and the final
    selection is priced per device at its own node's μ_{a_n}. ``edge_eps``
    (static) makes every occupancy row/clearing Cantelli
    chance-constrained.
    """
    n = fleet.num_devices
    deadline = jnp.broadcast_to(jnp.asarray(deadline, jnp.float64), (n,))
    eps = jnp.broadcast_to(jnp.asarray(eps, jnp.float64), (n,))
    edge_cap = jnp.asarray(
        jnp.inf if edge_capacity_s is None else edge_capacity_s, jnp.float64)
    c, plat, link = fleet.chain, fleet.platform, fleet.link
    sigma = ccp.SIGMA_FNS[sigma_model](eps)
    occ_all = c.t_vm  # (N, M+1) shared-edge occupancy of each point
    var_all = c.v_vm  # (N, M+1) VM variance (Cantelli row)
    edge_sig = placement.edge_sigma(edge_eps)

    budget_all, b_lo_all, feas0_all = _optimal_prep(fleet, deadline, sigma, B)

    def select(cost, feas, mu):
        return _optimal_select(cost, feas, budget_all, occ_all, mu)

    def occ_dev(m_sel):
        return jnp.take_along_axis(occ_all, m_sel[:, None], -1)[:, 0]

    def var_dev(m_sel):
        return jnp.take_along_axis(var_all, m_sel[:, None], -1)[:, 0]

    if edge_cap.ndim == 0:  # one shared edge (scalar μ — the seed goldens)
        def occ_of(m_sel):
            occ = jnp.sum(occ_dev(m_sel))
            if edge_sig > 0.0:
                occ = occ + edge_sig * jnp.sqrt(
                    jnp.maximum(jnp.sum(var_dev(m_sel)), 0.0))
            return occ

        def mu_star(cost, feas):
            """Clearing price of the edge capacity at fixed λ — a cheap
            ``_clearing_price`` search over the point tables (no golden
            sections re-run; the per-point (b, f) depend on λ only)."""
            return _clearing_price(
                lambda mu: occ_of(select(cost, feas, mu)[0]), edge_cap)[0]

        def solve_at(lam):
            cost, b, f, e, feas = _optimal_point_solve(
                fleet, budget_all, b_lo_all, feas0_all, lam, B)
            mu = mu_star(cost, feas)
            m_sel, any_feas = select(cost, feas, mu)
            pick = lambda a: jnp.take_along_axis(a, m_sel[:, None], -1)[:, 0]
            return (m_sel, pick(b), pick(f), pick(e), pick(feas) & any_feas,
                    mu, jnp.zeros((n,), jnp.int32))
    else:  # per-node capacities: assignment + per-node prices
        e_count = edge_cap.shape[0]
        node_ids = jnp.arange(e_count)

        def eff_node_occ(m_sel, mask):
            occ = jnp.sum(jnp.where(mask, occ_dev(m_sel), 0.0))
            if edge_sig > 0.0:
                occ = occ + edge_sig * jnp.sqrt(jnp.maximum(
                    jnp.sum(jnp.where(mask, var_dev(m_sel), 0.0)), 0.0))
            return occ

        def solve_at(lam):
            cost, b, f, e, feas = _optimal_point_solve(
                fleet, budget_all, b_lo_all, feas0_all, lam, B)
            m0_sel, _ = select(cost, feas, jnp.asarray(0.0, jnp.float64))
            a = placement.assign_devices(occ_dev(m0_sel), edge_cap, assign)
            masks = a[None, :] == node_ids[:, None]  # (E, N)

            def one(mask, cap):
                return _clearing_price(
                    lambda mu: eff_node_occ(select(cost, feas, mu)[0], mask),
                    cap)[0]

            mu_vec = jax.vmap(one)(masks, edge_cap)
            m_sel, any_feas = select(cost, feas, mu_vec[a][:, None])
            pick = lambda arr: jnp.take_along_axis(arr, m_sel[:, None], -1)[:, 0]
            return (m_sel, pick(b), pick(f), pick(e), pick(feas) & any_feas,
                    mu_vec, a)

    _, b0, *_ = solve_at(jnp.asarray(0.0, jnp.float64))
    need_price = jnp.sum(b0) > B

    def excess(log_lam):
        _, b, *_ = solve_at(10.0**log_lam)
        return jnp.sum(b) - B

    log_hi, _ = _expand_log_bracket(excess)
    log_lam = bisect(excess, _LOG_PRICE_LO, log_hi, iters=60)
    lam = jnp.where(need_price, 10.0**log_lam, 0.0)
    m_sel, b, f, e, feas, mu, assignment = solve_at(lam)
    # primal capacity check at the rounded discrete selection
    if edge_cap.ndim == 0:
        feas = feas & (occ_of(m_sel) <= edge_cap * (1.0 + _EDGE_CAP_RTOL))
    else:
        occ_nodes = jax.vmap(
            lambda mask: eff_node_occ(m_sel, mask)
        )(assignment[None, :] == node_ids[:, None])
        node_ok = occ_nodes <= edge_cap * (1.0 + _EDGE_CAP_RTOL)
        feas = feas & node_ok[assignment]

    sel = select_point(fleet, m_sel)
    e_loc = energy.expected_local_energy(plat.kappa, sel.w_flops, sel.g_eff, f)
    e_off = channel.offload_energy(sel.d_bits, b, link.p_tx, link.gain)
    alloc = Allocation(b=b, f=f, e_loc=e_loc, e_off=e_off, feasible=feas,
                       lam=lam, mu=mu)
    t_mean = (
        energy.mean_local_time(sel.w_flops, sel.g_eff, f)
        + channel.offload_time(sel.d_bits, b, link.p_tx, link.gain)
        + sel.t_vm
    )
    margins = ccp.deterministic_deadline_margin(
        t_mean, sel.v_loc + sel.v_vm, eps, deadline, sigma_model
    )
    total_energy = jnp.sum(alloc.energy)
    return Plan(
        m_sel=m_sel,
        alloc=alloc,
        total_energy=total_energy,
        feasible=feas,
        objective_trace=total_energy[None],
        pccp_iters=jnp.ones((1, fleet.num_devices), jnp.int32),
        margins=margins,
        status=_traced_status(alloc, total_energy, margins),
        assignment=assignment,
    )


def _optimal_solve(fleet, deadline, eps, B, edge_cap, policy: Policy,
                   outer_iters, pccp_iters, channel_cv, edge_eps=None) -> Plan:
    """Registry ``solve`` adapter for the optimal baseline (iteration
    counts and channel_cv do not apply to the exact search)."""
    del outer_iters, pccp_iters, channel_cv
    return plan_optimal(fleet, deadline, eps, B, sigma_model=policy.sigma_model,
                        edge_capacity_s=edge_cap, assign=policy.assign,
                        edge_eps=edge_eps)


@partial(jax.jit, static_argnames=("sigma_model", "assign", "edge_eps"))
def plan_fixed_partition(fleet: Fleet, m_sel, deadline, eps, B,
                         edge_capacity_s=None,
                         sigma_model: str = "cantelli",
                         assign: str = "hybrid", edge_eps=None) -> Plan:
    """A full :class:`Plan` at a *forced* partition: allocate (b, f) by
    the dual decomposition at the given ``m_sel`` and score it — no
    partitioning loop, no PCCP.

    This is the cheap "λ/μ price-step" rung of the degradation ladder
    (DESIGN.md §robustness): re-clear the bandwidth/edge prices against
    re-fit moments while keeping the incumbent split, at the cost of one
    allocation solve. It is also how the precomputed contingency plans
    (local-only m = M_n, full-offload m = 0) are built at plan time.

    ``m_sel`` is broadcast to ``(N,)`` and clamped to each device's own
    chain on ragged fleets.

    A per-node ``(E,)`` ``edge_capacity_s`` vector computes the
    device→node assignment at the forced partition with the ``assign``
    strategy (DESIGN.md §placement) and checks per-node occupancy;
    ``edge_eps`` makes the rows Cantelli chance-constrained.
    """
    n = fleet.num_devices
    deadline = jnp.broadcast_to(jnp.asarray(deadline, jnp.float64), (n,))
    eps = jnp.broadcast_to(jnp.asarray(eps, jnp.float64), (n,))
    edge_cap = jnp.asarray(
        jnp.inf if edge_capacity_s is None else edge_capacity_s, jnp.float64)
    m = jnp.broadcast_to(jnp.asarray(m_sel, jnp.int32), (n,))
    m = jnp.minimum(m, fleet.points_per_device - 1)
    if edge_cap.ndim == 0:
        assignment = jnp.zeros((n,), jnp.int32)
        alloc = allocate(fleet, m, deadline, eps, B, sigma_model,
                         edge_capacity_s=edge_cap, edge_eps=edge_eps)
    else:
        assignment = placement.assign_devices(
            select_point(fleet, m).t_vm, edge_cap, assign)
        alloc = allocate(fleet, m, deadline, eps, B, sigma_model,
                         edge_capacity_s=edge_cap, assignment=assignment,
                         edge_price=jnp.zeros(edge_cap.shape, jnp.float64),
                         edge_eps=edge_eps)
    sel = select_point(fleet, m)
    t_mean = (
        energy.mean_local_time(sel.w_flops, sel.g_eff, alloc.f)
        + channel.offload_time(sel.d_bits, alloc.b, fleet.link.p_tx,
                               fleet.link.gain)
        + sel.t_vm
    )
    margins = ccp.deterministic_deadline_margin(
        t_mean, sel.v_loc + sel.v_vm, eps, deadline, sigma_model)
    total_energy = jnp.sum(alloc.energy)
    return Plan(
        m_sel=m,
        alloc=alloc,
        total_energy=total_energy,
        feasible=alloc.feasible & (margins <= 1e-9),
        objective_trace=total_energy[None],
        pccp_iters=jnp.ones((1, n), jnp.int32),
        margins=margins,
        status=_traced_status(alloc, total_energy, margins),
        assignment=assignment,
    )


def plan_health(plan: Plan, pccp_iter_cap: Optional[int] = None):  # analyze: ok(TRC001,TRC002,TRC003): host-side verdict; the fail-soft caller skips it under tracing
    """Host-side health verdict on a single (unbatched) plan.

    Returns ``(ok, reason)``. Unhealthy when any actionable leaf
    (energy, allocation, margins) is non-finite, when the traced solve
    stamped ``PLAN_DEGRADED``, or — with ``pccp_iter_cap`` given — when
    the PCCP is *stuck*: every device burned the full iteration budget in
    the final outer step yet the plan is still infeasible (θ_err never
    met the stopping rule). Fallback statuses count as healthy: they are
    deliberate, usable plans.
    """
    e = np.asarray(plan.total_energy)
    if e.ndim != 0:
        raise ValueError(
            "plan_health scores a single plan; index batched plans with "
            "scenario_at/plan_at first")
    for name, leaf in (("total_energy", plan.total_energy),
                       ("alloc.b", plan.alloc.b), ("alloc.f", plan.alloc.f),
                       ("margins", plan.margins)):
        if not np.all(np.isfinite(np.asarray(leaf))):
            return False, f"non-finite {name}"
    status = int(np.asarray(plan.status))
    if status == PLAN_DEGRADED:
        return False, "solver stamped PLAN_DEGRADED"
    if pccp_iter_cap is not None:
        iters = np.asarray(plan.pccp_iters)
        if (iters.size and np.all(iters[-1] >= pccp_iter_cap)
                and not np.any(np.asarray(plan.feasible))):
            return False, (f"PCCP stuck at the {pccp_iter_cap}-iteration cap "
                           "with no feasible device")
    return True, PLAN_STATUS_NAMES.get(status, f"status={status}")


ROBUST = register_policy(Policy("robust", partition=pccp_partition_step))
ROBUST_EXACT = register_policy(Policy("robust_exact", partition=exact_partition_step))
GAUSSIAN = register_policy(
    Policy("gaussian", sigma_model="gaussian", partition=exact_partition_step))
WORST_CASE = register_policy(
    Policy("worst_case", sigma_model="hard", ub_k=WORST_CASE_UB_K,
           partition=exact_partition_step))
OPTIMAL = register_policy(Policy("optimal", solve=_optimal_solve))
