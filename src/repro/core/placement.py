"""Multi-edge placement: device→node assignment + price certificates.

DESIGN.md §placement. The shared edge is E heterogeneous nodes with a
per-round VM-time capacity vector C ∈ R^E (``Scenario.edge_capacity_s``
as an ``(E,)`` array); each device must be *placed* on exactly one node,
``a ∈ {0..E−1}^N``. The assignment is the discrete half of a
transport-style subproblem: the continuous half (per-node clearing
prices μ_e, bisected inside the planner's dual loop) certifies and
refines it — ``duality_gap`` reports the certificate.

The assignment strategies are the AccaSim-style allocator family
(Balanced / Weighted / Hybrid, plus round-robin and greedy-load
baselines), registered in ``ASSIGN_FNS`` and selected per policy via
``Policy.assign``. All strategies are **traced** (``lax.scan`` over the
devices, one argmin over the E nodes per step) so they live inside the
planner's compiled program, and each has a numpy **host mirror**
(``assign_devices_host``) with the *identical* float64 op order, so the
group-sharded host loop of ``core.decompose`` replays the same
assignments bit-for-bit (the same contract ``_host_bisect`` keeps with
``solvers.scalar.bisect``). Decision arithmetic deliberately avoids
cross-node sum reductions (order-ambiguous between numpy and XLA);
``max``/elementwise ops only.

Capacity conventions: ∞ ⇒ uncapacitated node; **0 ⇒ absent node** — no
strategy ever places on a zero-capacity node, which is what lets
"3 nodes vs 2" be value-varied (not shape-varied) axes of one compiled
``Planner.grid`` sweep.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "ASSIGN_FNS", "assign_devices", "assign_devices_host",
    "available_assignments", "assignment_churn", "migration_energy",
    "node_loads", "duality_gap", "plan_duality_gap", "edge_sigma",
]

#: Stand-in capacity for uncapacitated (∞) nodes inside utilization
#: arithmetic — dominates any real occupancy while keeping ratios finite
#: and ordered.
_CAP_BIG = 1e9
#: Denominator floor: a zero-capacity (absent) node gets utilization
#: ~1e30 per occupancy second, so it is never chosen while any present
#: node exists.
_CAP_TINY = 1e-30
#: Additive penalty for placing a device on a node it does not fit on —
#: larger than any scarcity-weighted load of a fitting node.
_OVERFLOW = 1e12


def edge_sigma(edge_eps) -> float:
    """Cantelli multiplier √((1−ε)/ε) of the chance-constrained occupancy
    row P{Σ t_vm > C_e} ≤ ε_edge (the paper's own CCP treatment applied
    to the shared resource): mean occupancy is charged an extra
    σ_edge·√(Σ v_vm). ``edge_eps`` is a *static* float (or ``None`` ⇒ the
    mean-only row, multiplier 0.0 — the returned value gates the extra
    term out of the trace entirely)."""
    if edge_eps is None:
        return 0.0
    eps = float(edge_eps)
    if not 0.0 < eps < 1.0:
        raise ValueError(f"edge_eps must lie in (0, 1), got {edge_eps!r}")
    return math.sqrt((1.0 - eps) / eps)


def _caps_eff(caps):
    return jnp.where(jnp.isfinite(caps), caps, _CAP_BIG)


def _assign_round_robin(occ, caps):
    """a_n = n mod E over *present* nodes — the interleaving baseline
    (load- and capacity-magnitude-blind, but it never lands a device on
    an absent C_e = 0 node, so node-count what-ifs stay meaningful)."""
    present = caps > 0.0
    count = jnp.maximum(jnp.sum(present.astype(jnp.int32)), 1)
    # present node ids first, in ascending order (stable argsort on ~present)
    order = jnp.argsort(jnp.logical_not(present), stable=True)
    r = jnp.arange(occ.shape[0], dtype=jnp.int32) % count
    return order[r].astype(jnp.int32)


def _assign_greedy_load(occ, caps):
    """Devices in natural order onto the least-loaded node (absolute
    load, capacity-blind apart from skipping absent nodes)."""
    e_count = caps.shape[0]

    def step(load, n):
        score = jnp.where(caps > 0.0, load, jnp.inf)
        e = jnp.argmin(score)
        return load.at[e].add(occ[n]), e.astype(jnp.int32)

    _, a = jax.lax.scan(step, jnp.zeros((e_count,), jnp.float64),
                        jnp.arange(occ.shape[0]))
    return a


def _balanced_scan(occ, caps):
    """Balanced core: heaviest devices first, each onto the node with the
    lowest *post-placement utilization* (load+occ)/C_e. Returns the
    assignment AND the final per-node loads (accumulated in processing
    order — the host mirror replays the identical add sequence)."""
    e_count = caps.shape[0]
    denom = jnp.maximum(_caps_eff(caps), _CAP_TINY)
    order = jnp.argsort(-occ, stable=True)

    def step(load, n):
        util = (load + occ[n]) / denom
        util = jnp.where(caps > 0.0, util, jnp.inf)
        e = jnp.argmin(util)
        return load.at[e].add(occ[n]), e.astype(jnp.int32)

    load, es = jax.lax.scan(step, jnp.zeros((e_count,), jnp.float64), order)
    a = jnp.zeros(occ.shape, jnp.int32).at[order].set(es)
    return a, load


def _assign_balanced(occ, caps):
    return _balanced_scan(occ, caps)[0]


def _assign_weighted(occ, caps):
    """Heaviest first onto the node minimizing the *scarcity-weighted*
    post-load w_e·(load+occ) + load/C_e, w_e = C_max/C_e: scarce nodes
    cost proportionally more, so abundant nodes fill first and scarce
    accelerators are not fragmented by bulk load. Devices that do not
    fit anywhere land on the least-overflowed node."""
    e_count = caps.shape[0]
    ceff = _caps_eff(caps)
    denom = jnp.maximum(ceff, _CAP_TINY)
    w = jnp.max(ceff) / denom  # max, not mean: order-exact on host + device
    order = jnp.argsort(-occ, stable=True)

    def step(load, n):
        post = load + occ[n]
        fits = post <= ceff
        score = jnp.where(fits, w * post + load / denom, _OVERFLOW + w * post)
        score = jnp.where(caps > 0.0, score, jnp.inf)
        e = jnp.argmin(score)
        return load.at[e].add(occ[n]), e.astype(jnp.int32)

    _, es = jax.lax.scan(step, jnp.zeros((e_count,), jnp.float64), order)
    return jnp.zeros(occ.shape, jnp.int32).at[order].set(es)


def _assign_hybrid(occ, caps):
    """Balanced placement + a migration pass off the scarcest node: its
    devices (lightest first) move to the best-fitting other node while
    the move still fits. Migration only ever *removes* load from the
    scarcest node, so Hybrid fragments it no worse than Balanced — by
    construction, for every input (the hypothesis-tested invariant)."""
    a, load = _balanced_scan(occ, caps)
    e_count = caps.shape[0]
    if e_count == 1:
        return a
    ceff = jnp.maximum(_caps_eff(caps), _CAP_TINY)
    # scarcest *present* node class (absent C=0 nodes hold no load)
    e_star = jnp.argmin(jnp.where(caps > 0.0, ceff, jnp.inf)).astype(jnp.int32)
    node_ids = jnp.arange(e_count)
    order = jnp.argsort(occ, stable=True)  # cheapest-to-move first

    def step(carry, n):
        a_arr, load = carry
        on_star = a_arr[n] == e_star
        util = (load + occ[n]) / ceff
        util = jnp.where((node_ids == e_star) | (caps <= 0.0), jnp.inf, util)
        tgt = jnp.argmin(util).astype(jnp.int32)
        move = on_star & (load[tgt] + occ[n] <= ceff[tgt])
        delta = jnp.where(move, occ[n], 0.0)
        load = load.at[e_star].add(-delta).at[tgt].add(delta)
        a_arr = a_arr.at[n].set(jnp.where(move, tgt, a_arr[n]))
        return (a_arr, load), None

    (a, _), _ = jax.lax.scan(step, (a, load), order)
    return a


#: Strategy registry: name → traced ``(occ (N,), caps (E,)) → (N,) int32``.
ASSIGN_FNS = {
    "round_robin": _assign_round_robin,
    "greedy_load": _assign_greedy_load,
    "balanced": _assign_balanced,
    "weighted": _assign_weighted,
    "hybrid": _assign_hybrid,
}


def available_assignments() -> tuple[str, ...]:
    return tuple(ASSIGN_FNS)


def assign_devices(occ, caps, strategy: str = "hybrid") -> jnp.ndarray:
    """Assign every device to exactly one edge node (traced).

    ``occ`` is the per-device edge occupancy at the current partition
    (t̄_vm at the selected point, ``(N,)``), ``caps`` the per-node
    capacity vector ``(E,)`` (∞ ⇒ uncapacitated, 0 ⇒ absent node);
    ``strategy`` is a static key into :data:`ASSIGN_FNS`.
    """
    try:
        fn = ASSIGN_FNS[strategy]
    except KeyError:
        raise ValueError(
            f"unknown assignment strategy {strategy!r}; registered: "
            f"{available_assignments()}") from None
    occ = jnp.asarray(occ, jnp.float64)
    caps = jnp.asarray(caps, jnp.float64)
    if caps.ndim != 1:
        raise ValueError(
            f"assign_devices needs an (E,) capacity vector, got shape {caps.shape}")
    return fn(occ, caps)


# ---------------------------------------------------------------------------
# Host mirrors (numpy, identical float64 op order) — for core.decompose's
# host-level price loop. Pinned bit-identical to the traced strategies in
# tests/test_placement.py.
# ---------------------------------------------------------------------------


def _host_caps_eff(caps):
    return np.where(np.isfinite(caps), caps, _CAP_BIG)


def _host_greedy_load(occ, caps):
    load = np.zeros(caps.shape[0])
    a = np.zeros(occ.shape[0], np.int32)
    for n in range(occ.shape[0]):
        score = np.where(caps > 0.0, load, np.inf)
        e = int(np.argmin(score))
        load[e] += occ[n]
        a[n] = e
    return a


def _host_balanced_scan(occ, caps):
    denom = np.maximum(_host_caps_eff(caps), _CAP_TINY)
    order = np.argsort(-occ, kind="stable")
    load = np.zeros(caps.shape[0])
    a = np.zeros(occ.shape[0], np.int32)
    for n in order:
        util = (load + occ[n]) / denom
        util = np.where(caps > 0.0, util, np.inf)
        e = int(np.argmin(util))
        load[e] += occ[n]
        a[n] = e
    return a, load


def _host_weighted(occ, caps):
    ceff = _host_caps_eff(caps)
    denom = np.maximum(ceff, _CAP_TINY)
    w = np.max(ceff) / denom
    order = np.argsort(-occ, kind="stable")
    load = np.zeros(caps.shape[0])
    a = np.zeros(occ.shape[0], np.int32)
    for n in order:
        post = load + occ[n]
        fits = post <= ceff
        score = np.where(fits, w * post + load / denom, _OVERFLOW + w * post)
        score = np.where(caps > 0.0, score, np.inf)
        e = int(np.argmin(score))
        load[e] += occ[n]
        a[n] = e
    return a


def _host_hybrid(occ, caps):
    a, load = _host_balanced_scan(occ, caps)
    e_count = caps.shape[0]
    if e_count == 1:
        return a
    ceff = np.maximum(_host_caps_eff(caps), _CAP_TINY)
    e_star = int(np.argmin(np.where(caps > 0.0, ceff, np.inf)))
    node_ids = np.arange(e_count)
    order = np.argsort(occ, kind="stable")
    for n in order:
        if a[n] != e_star:
            continue
        util = (load + occ[n]) / ceff
        util = np.where((node_ids == e_star) | (caps <= 0.0), np.inf, util)
        tgt = int(np.argmin(util))
        if load[tgt] + occ[n] <= ceff[tgt]:
            load[e_star] -= occ[n]
            load[tgt] += occ[n]
            a[n] = tgt
    return a


def _host_round_robin(occ, caps):
    present = caps > 0.0
    count = max(int(np.sum(present)), 1)
    order = np.argsort(~present, kind="stable")
    return order[np.arange(occ.shape[0]) % count].astype(np.int32)


_HOST_ASSIGN_FNS = {
    "round_robin": _host_round_robin,
    "greedy_load": _host_greedy_load,
    "balanced": lambda occ, caps: _host_balanced_scan(occ, caps)[0],
    "weighted": _host_weighted,
    "hybrid": _host_hybrid,
}


def assign_devices_host(occ, caps, strategy: str = "hybrid") -> np.ndarray:
    """Numpy mirror of :func:`assign_devices` — same strategies, identical
    float64 op order, bit-identical assignments (pinned in tests)."""
    try:
        fn = _HOST_ASSIGN_FNS[strategy]
    except KeyError:
        raise ValueError(
            f"unknown assignment strategy {strategy!r}; registered: "
            f"{available_assignments()}") from None
    occ = np.asarray(occ, np.float64)  # analyze: ok(TRC002): deliberate host mirror — decompose's host price loop runs on materialized lanes
    caps = np.asarray(caps, np.float64)  # analyze: ok(TRC002): deliberate host mirror — decompose's host price loop runs on materialized lanes
    if caps.ndim != 1:
        raise ValueError(
            f"assign_devices_host needs an (E,) capacity vector, got shape {caps.shape}")
    return fn(occ, caps)


def node_loads(occ, assignment, num_nodes: int):
    """Per-node total occupancy Σ_{n: a_n=e} occ_n (traced)."""
    return jax.ops.segment_sum(jnp.asarray(occ, jnp.float64),
                               jnp.asarray(assignment, jnp.int32),
                               num_segments=num_nodes)


# ---------------------------------------------------------------------------
# Migration accounting (workload replay: DESIGN.md §robustness)
# ---------------------------------------------------------------------------


def assignment_churn(a_old, a_new) -> jnp.ndarray:
    """Number of devices whose node changed between two assignments
    (traced, int32 scalar). The replay's ladder charges each such move —
    a migrated device's session state must be re-established on the new
    node before it serves again."""
    a_old = jnp.asarray(a_old, jnp.int32)
    a_new = jnp.asarray(a_new, jnp.int32)
    if a_old.shape != a_new.shape:
        raise ValueError(
            f"assignment shapes differ: {a_old.shape} vs {a_new.shape}")
    return jnp.sum((a_old != a_new).astype(jnp.int32))


def migration_energy(a_old, a_new, e_migrate) -> jnp.ndarray:
    """Total energy of a re-plan's migrations: Σ over moved devices of
    ``e_migrate[n]`` (traced, float64 scalar).

    ``e_migrate`` is the per-device cost of re-establishing its session
    on a new node — the replay uses one extra upload of the offload
    payload, t_off·p_tx at the incumbent partition, so a device with a
    bigger activation payload or a worse channel is costlier to move."""
    a_old = jnp.asarray(a_old, jnp.int32)
    a_new = jnp.asarray(a_new, jnp.int32)
    cost = jnp.asarray(e_migrate, jnp.float64)
    return jnp.sum(jnp.where(a_old != a_new, cost, 0.0))


# ---------------------------------------------------------------------------
# Duality-gap certificate
# ---------------------------------------------------------------------------


def duality_gap(e_table, occ_table, feas, m_sel, mu, caps):
    """Certificate gap between the returned discrete plan and the
    per-node-price dual lower bound.

    The Lagrangian relaxation lets every device pick *any* node, so each
    pays the cheapest price μ_min = min_e μ_e; the dual value at the
    returned prices is

        g(μ) = Σ_n min_{m feasible} (e_nm + μ_min·occ_nm) − Σ_e μ_e·C_e

    and ``gap = primal − g(μ) ≥ 0`` bounds the discrete assignment's
    suboptimality (0 ⇔ the heuristic placement is price-certified
    optimal). Devices with no feasible point contribute their selected
    point to both sides (they price out identically).
    """
    e_table = jnp.asarray(e_table, jnp.float64)
    occ_table = jnp.asarray(occ_table, jnp.float64)
    m_sel = jnp.asarray(m_sel, jnp.int32)
    mu = jnp.atleast_1d(jnp.asarray(mu, jnp.float64))
    caps = jnp.atleast_1d(jnp.asarray(caps, jnp.float64))
    take = lambda a: jnp.take_along_axis(a, m_sel[:, None], -1)[:, 0]
    e_sel, occ_sel = take(e_table), take(occ_table)
    primal = jnp.sum(e_sel)
    mu_min = jnp.min(mu)
    priced = jnp.where(feas, e_table + mu_min * occ_table, jnp.inf)
    best = jnp.min(priced, axis=-1)
    any_feas = jnp.any(feas, axis=-1)
    dev_dual = jnp.where(any_feas, best, e_sel + mu_min * occ_sel)
    # μ_e·C_e with C_e = ∞ only ever pairs with μ_e = 0 (an uncapacitated
    # node never needs a price) — gate the 0·∞ = NaN out explicitly.
    pay = jnp.sum(jnp.where(mu > 0.0, mu * caps, 0.0))
    return primal - (jnp.sum(dev_dual) - pay)


def plan_duality_gap(fleet, plan, deadline, eps, caps, policy="robust_exact",
                     channel_cv: float = 0.0):
    """Duality gap of a returned :class:`~repro.core.planner.Plan` —
    rebuilds the priced point tables at the plan's allocation and scores
    :func:`duality_gap` at the plan's recorded prices ``alloc.mu``."""
    from repro.core import ccp  # deferred: placement must not import planner at module load
    from repro.core.planner import _edge_occ_prep, get_policy, policy_point_tables

    pol = get_policy(policy)
    n = fleet.num_devices
    deadline = jnp.broadcast_to(jnp.asarray(deadline, jnp.float64), (n,))
    eps = jnp.broadcast_to(jnp.asarray(eps, jnp.float64), (n,))
    sigma = ccp.SIGMA_FNS[pol.sigma_model](eps)
    e_table, t_table, var_table = policy_point_tables(
        fleet, plan.alloc.b, plan.alloc.f, pol, channel_cv)
    feas, _, _ = _edge_occ_prep(t_table, var_table, sigma, deadline)
    return duality_gap(e_table, fleet.chain.t_vm, feas, plan.m_sel,
                       plan.alloc.mu, caps)
