"""Monte-Carlo validation of the probabilistic deadline guarantee.

The planner only uses (mean, variance). The guarantee must therefore hold
for *any* distribution with those moments. We validate empirically against
three plausible families (gamma, lognormal, truncated normal), matching
moments, and report the deadline-violation rate per device (Fig. 13c/14c).

``var_scale`` < 1 emulates the paper's observation that the max-over-
frequency variance (eq. 11) is conservative w.r.t. the actual operating
frequency's variance.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import channel, energy
from repro.core.blocks import Fleet
from repro.core.resource import Allocation, select_point


class ViolationReport(NamedTuple):
    rate: jnp.ndarray  # (N,) empirical P{T > D}
    mean_time: jnp.ndarray  # (N,) empirical E[T]
    p95_time: jnp.ndarray  # (N,)
    #: per-tier observed means — what a partitioned stack measures on
    #: each tier separately (device-side compute vs server-side VM time,
    #: §IV online measurement); the closed-loop moment re-fit needs them
    #: to *attribute* a latency shift to a tier instead of guessing from
    #: totals (straggler/congestion extra lands in ``mean_vm``)
    mean_local: jnp.ndarray = jnp.nan  # (N,) empirical E[t_loc]
    mean_vm: jnp.ndarray = jnp.nan  # (N,) empirical E[t_vm + extras]


def _weibull_shape_from_cv2(cv2, iters: int = 60):
    """Solve Γ(1+2/k)/Γ(1+1/k)² = 1+cv² for the Weibull shape k by
    bisection (the left side is strictly decreasing in k)."""
    target = jnp.log1p(cv2)

    def excess(k):
        return (jax.scipy.special.gammaln(1.0 + 2.0 / k)
                - 2.0 * jax.scipy.special.gammaln(1.0 + 1.0 / k) - target)

    lo = jnp.full_like(target, 0.05)
    hi = jnp.full_like(target, 50.0)

    def body(_, state):
        lo, hi = state
        mid = 0.5 * (lo + hi)
        high = excess(mid) > 0  # cv too large at mid ⇒ true k is larger
        return jnp.where(high, mid, lo), jnp.where(high, hi, mid)

    lo, hi = jax.lax.fori_loop(0, iters, body, (lo, hi))
    return 0.5 * (lo + hi)


def _sample_matched(key, dist: str, mean, var, shape):
    """Sample ``shape`` values with the given mean/variance (per element).

    ``"pareto"`` / ``"weibull"`` are the heavy-tailed families used by the
    robustness layer's straggler injection (DESIGN.md §robustness): both
    are moment-matched, Pareto with tail index α = 1 + √(1 + mean²/var)
    (always > 2, so the matched variance exists), Weibull with the shape
    solved from the cv by bisection on the log-Γ moment identity.
    """
    mean = jnp.maximum(mean, 1e-12)
    var = jnp.maximum(var, 1e-18)
    if dist == "gamma":
        k = mean**2 / var
        theta = var / mean
        return jax.random.gamma(key, k, shape=shape) * theta
    if dist == "lognormal":
        s2 = jnp.log1p(var / mean**2)
        mu = jnp.log(mean) - 0.5 * s2
        return jnp.exp(mu + jnp.sqrt(s2) * jax.random.normal(key, shape))
    if dist == "truncnorm":
        x = mean + jnp.sqrt(var) * jax.random.normal(key, shape)
        return jnp.maximum(x, 0.0)
    if dist == "pareto":
        alpha = 1.0 + jnp.sqrt(1.0 + mean**2 / var)
        xm = mean * (alpha - 1.0) / alpha
        u = jax.random.uniform(key, shape, minval=1e-12)
        return xm * u ** (-1.0 / alpha)
    if dist == "weibull":
        k = _weibull_shape_from_cv2(var / mean**2)
        lam = mean * jnp.exp(-jax.scipy.special.gammaln(1.0 + 1.0 / k))
        u = jax.random.uniform(key, shape, minval=1e-12)
        return lam * (-jnp.log(u)) ** (1.0 / k)
    raise ValueError(f"unknown dist {dist!r}")


@partial(jax.jit, static_argnames=("dist", "num_samples", "channel_cv"))
def violation_report(
    key,
    fleet: Fleet,
    m_sel: jnp.ndarray,
    alloc: Allocation,
    deadline: jnp.ndarray,
    dist: str = "gamma",
    num_samples: int = 20000,
    var_scale: float = 0.8,
    channel_cv: float = 0.0,
    edge_capacity_s=None,
    faults=None,
    assignment=None,
) -> ViolationReport:
    """Empirical per-device P{T > D} under moment-matched sampling.

    ``faults`` (optional) is a ``serve.faults.FaultState``-shaped pytree
    (duck-typed — this module never imports ``serve``) injecting the
    robustness layer's fault taxonomy into the ground truth: moment
    drift scales the sampled local/VM moments, a channel fade scales the
    gain, a brownout scales the shared-edge capacity, and straggler
    bursts add a Bernoulli(``straggler_prob``) × moment-matched-Pareto
    extra to each VM execution. ``faults=None`` (the default) is gated at
    trace time, so the no-fault program is bit-identical to the
    pre-robustness one (golden-pinned).

    Ragged fleets validate per device: the mask/``num_points`` leaves ride
    in through ``fleet`` (traced, not static), ``select_point`` clamps
    ``m_sel`` to each device's own chain so padded points are never
    sampled, and ``deadline`` may be per-device ``(N,)`` so mixed
    populations score against their own SLOs.

    ``edge_capacity_s`` (traced scalar; ``None``/∞ ⇒ dedicated VMs)
    enables the shared-edge ground-truth model (DESIGN.md §edge): the
    edge is a processor-sharing accelerator with a VM-time budget C per
    round, so when the plan's total occupancy Σ t̄_vm exceeds C every
    VM time stretches by the congestion factor max(1, Σ t̄_vm / C). A
    plan that keeps Σ t̄_vm ≤ C is validated unchanged — this is what
    lets the capacity-priced planner be scored against plans made under
    the dedicated or statically-scaled assumptions on equal terms.

    A per-node ``(E,)`` capacity vector congests per node (DESIGN.md
    §placement): pass the plan's device→node map via ``assignment``
    (traced ``(N,)`` int32, e.g. ``plan.assignment``) and each node e
    processor-shares among its own devices — slow_e = max(1, occ_e/C_e)
    applied to the devices assigned there.
    """
    sel = select_point(fleet, m_sel)
    gain = fleet.link.gain
    if faults is not None:
        sel = sel._replace(
            t_vm=sel.t_vm * faults.vm_mean_scale,
            v_vm=sel.v_vm * faults.vm_var_scale,
            g_eff=sel.g_eff / jnp.maximum(faults.loc_mean_scale, 1e-12),
            v_loc=sel.v_loc * faults.loc_var_scale,
        )
        gain = gain * faults.gain_scale
    if edge_capacity_s is not None:
        cap = jnp.asarray(edge_capacity_s, jnp.float64)
        if faults is not None:
            cap = cap * faults.cap_scale
        if cap.ndim == 0:
            slow = jnp.maximum(1.0, jnp.sum(sel.t_vm) / cap)
        else:
            if assignment is None:
                raise ValueError(
                    "a per-node edge_capacity_s vector needs the plan's "
                    "device→node assignment (pass assignment=plan.assignment)")
            a = jnp.asarray(assignment, jnp.int32)
            occ_e = jax.ops.segment_sum(sel.t_vm, a, num_segments=cap.shape[0])
            slow_e = jnp.maximum(1.0, occ_e / jnp.maximum(cap, 1e-30))
            slow = slow_e[a]
        sel = sel._replace(t_vm=sel.t_vm * slow, v_vm=sel.v_vm * slow**2)
    n = m_sel.shape[0]
    mean_loc = energy.mean_local_time(sel.w_flops, sel.g_eff, alloc.f)

    k_loc, k_vm, k_ch = jax.random.split(key, 3)
    if channel_cv > 0.0:
        # lognormal channel gain with the given cv (paper footnote 2)
        s2 = jnp.log1p(channel_cv**2)
        gains = gain[None, :] * jnp.exp(
            jnp.sqrt(s2) * jax.random.normal(k_ch, (num_samples, n)) - 0.5 * s2)
        t_off = channel.offload_time(sel.d_bits[None, :], alloc.b[None, :],
                                     fleet.link.p_tx[None, :], gains)
    else:
        t_off = channel.offload_time(sel.d_bits, alloc.b, fleet.link.p_tx,
                                     gain)[None, :]
    shape = (num_samples, n)
    t_loc = jnp.where(
        sel.w_flops[None, :] > 0,
        _sample_matched(k_loc, dist, mean_loc, var_scale * sel.v_loc, shape),
        0.0,
    )
    t_vm = jnp.where(
        sel.t_vm[None, :] > 0,
        _sample_matched(k_vm, dist, sel.t_vm, var_scale * sel.v_vm, shape),
        0.0,
    )
    if faults is not None:
        # Straggler bursts: keys derived by fold_in so the 3-way split
        # above (and hence the no-fault sample stream) stays unchanged.
        k_hit, k_extra = jax.random.split(jax.random.fold_in(key, 0x57), 2)
        p_straggle = jnp.clip(faults.straggler_prob, 0.0, 1.0)
        hit = jax.random.bernoulli(k_hit, p_straggle, shape)
        extra_mean = jnp.maximum(faults.straggler_extra_s, 1e-9)
        extra_var = (jnp.maximum(faults.straggler_cv, 1e-3) * extra_mean) ** 2
        extra = _sample_matched(k_extra, "pareto", extra_mean, extra_var, shape)
        t_vm = t_vm + jnp.where(hit & (sel.t_vm[None, :] > 0), extra, 0.0)
    total = t_loc + t_off + t_vm
    deadline = jnp.broadcast_to(jnp.asarray(deadline, jnp.float64), (n,))
    return ViolationReport(
        # dtype pinned: jnp.mean over bool otherwise lands on float32
        # even inside the x64 island (analysis contract: float64 outputs)
        rate=jnp.mean(total > deadline[None, :], axis=0, dtype=jnp.float64),
        mean_time=jnp.mean(total, axis=0),
        p95_time=jnp.percentile(total, 95.0, axis=0),
        mean_local=jnp.mean(t_loc, axis=0),
        mean_vm=jnp.mean(t_vm, axis=0),
    )
