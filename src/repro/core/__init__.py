"""Core paper contribution: robust DNN partitioning + resource allocation.

The optimization stack runs in float64 (see repro.solvers).
"""
import jax

jax.config.update("jax_enable_x64", True)

from repro.core.blocks import BlockChain, Fleet, Link, Platform, broadcast_fleet, covariance, pad_chain  # noqa: E402,F401
from repro.core.fleet import DeviceSpec, FleetSpec  # noqa: E402,F401
from repro.core.ccp import SIGMA_FNS, sigma_cantelli, sigma_gaussian  # noqa: E402,F401
from repro.core.planner import (  # noqa: E402,F401
    PLAN_DEGRADED,
    PLAN_FALLBACK_DENSE,
    PLAN_FALLBACK_INCUMBENT,
    PLAN_OK,
    PLAN_STATUS_NAMES,
    Plan,
    Policy,
    available_policies,
    get_policy,
    plan,
    plan_fixed_partition,
    plan_health,
    plan_optimal,
    register_policy,
)
from repro.core.api import Planner, PlannerConfig, Scenario, scenario_at  # noqa: E402,F401
from repro.core.decompose import bucket_size, build_groups, plan_sharded  # noqa: E402,F401
from repro.core.batch import plan_at, plan_grid  # noqa: E402,F401
from repro.core.resource import Allocation, allocate, allocate_ipm  # noqa: E402,F401
from repro.core.pccp import pccp_partition  # noqa: E402,F401
from repro.core.montecarlo import violation_report  # noqa: E402,F401
from repro.core.placement import (  # noqa: E402,F401
    assign_devices,
    assign_devices_host,
    available_assignments,
    duality_gap,
    edge_sigma,
    node_loads,
    plan_duality_gap,
)

__all__ = [
    "BlockChain", "Fleet", "Link", "Platform", "broadcast_fleet", "covariance",
    "pad_chain", "DeviceSpec", "FleetSpec",
    "SIGMA_FNS", "sigma_cantelli", "sigma_gaussian",
    "Plan", "plan", "plan_optimal", "plan_grid", "plan_at",
    "plan_fixed_partition", "plan_health",
    "PLAN_OK", "PLAN_DEGRADED", "PLAN_FALLBACK_DENSE",
    "PLAN_FALLBACK_INCUMBENT", "PLAN_STATUS_NAMES",
    "Scenario", "PlannerConfig", "Planner", "scenario_at",
    "plan_sharded", "build_groups", "bucket_size",
    "Policy", "register_policy", "get_policy", "available_policies",
    "Allocation", "allocate", "allocate_ipm",
    "pccp_partition", "violation_report",
    "assign_devices", "assign_devices_host", "available_assignments",
    "duality_gap", "edge_sigma", "node_loads", "plan_duality_gap",
]
