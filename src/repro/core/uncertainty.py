"""Inference-time statistics under DVFS (paper §IV).

- Mean model: t̄(f) = w / (g·f), with g fitted per (model, block,
  platform) by nonlinear least squares (Fig. 6).
- Variance: irregular in f, so the paper takes the max over the DVFS
  range (eq. (11)); covariance likewise (eq. (12)).
- ``measure_profile`` turns raw (frequency, samples) measurements into the
  (g, v_loc) entries a BlockChain needs — this is the online-profiling
  path a deployment would run, and what our serving engine feeds back.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.solvers.nls import LMResult, fit_inverse_frequency


class ProfiledPoint(NamedTuple):
    g_eff: jnp.ndarray  # fitted FLOPs/cycle
    v_loc: jnp.ndarray  # max-over-frequency variance (s²)
    fit_residual_sq: jnp.ndarray  # ‖residual‖² of the NLS fit (paper's metric)


def fit_g(freqs_hz: jnp.ndarray, mean_times_s: jnp.ndarray, w_flops) -> LMResult:
    """Fit g in t̄ = w/(g·f) from mean times at several frequencies."""
    res = fit_inverse_frequency(freqs_hz, mean_times_s)
    a = res.params[0]  # a = w/g
    g = w_flops / jnp.maximum(a, 1e-30)
    return LMResult(params=jnp.array([g]), residual_norm_sq=res.residual_norm_sq,
                    iterations=res.iterations)


def max_variance(per_freq_samples: jnp.ndarray) -> jnp.ndarray:
    """eq. (11): v = max_f Var[t(f)] over the scaling range.

    per_freq_samples: (num_freqs, num_samples) of measured times (s).
    """
    v = jnp.var(per_freq_samples, axis=-1, ddof=1)
    return jnp.max(v)


def max_covariance(samples_a: jnp.ndarray, samples_b: jnp.ndarray) -> jnp.ndarray:
    """eq. (12): w_{m,m'} = max_f Cov[t_m(f), t_m'(f)]."""
    a = samples_a - samples_a.mean(-1, keepdims=True)
    b = samples_b - samples_b.mean(-1, keepdims=True)
    cov = (a * b).sum(-1) / (a.shape[-1] - 1)
    return jnp.max(cov)


def measure_profile(freqs_hz, samples, w_flops) -> ProfiledPoint:
    """Full profiling pipeline for one partition point.

    samples: (num_freqs, num_samples) measured local times at each
    frequency. Returns the fitted g and the conservative variance.
    """
    mean_t = samples.mean(-1)
    fit = fit_g(freqs_hz, mean_t, w_flops)
    return ProfiledPoint(
        g_eff=fit.params[0],
        v_loc=max_variance(samples),
        fit_residual_sq=fit.residual_norm_sq,
    )


def synth_samples(key, freqs_hz, w_flops, g_true, cv=0.08, num_samples=500):
    """Synthesize per-frequency time measurements with gamma noise.

    Mirrors the paper's 500-trial measurement campaign: mean w/(g·f),
    coefficient of variation ``cv`` (inference-time jitter).
    """
    mean = w_flops / (g_true * freqs_hz)  # (F,)
    k = 1.0 / cv**2
    g = jax.random.gamma(key, k, shape=(freqs_hz.shape[0], num_samples))
    return mean[:, None] * (g / k)
