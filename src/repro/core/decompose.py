"""Group-sharded dual decomposition (DESIGN.md §scale).

``Planner.plan`` compiles ONE padded program over the whole fleet: every
device carries ``max_points`` columns, so a mixed fleet of 8-block and
64-block populations pays 65-wide tables on every device, and a new
population mix is a new (N, M+1) shape → a fresh XLA compile of the whole
planner. That is fine at paper scale (N ≤ 50) and wrong at serving scale
(10⁵–10⁶ devices).

This module re-derives Algorithm 2 as a **global-price / local-enforcer
split**. Problem P2 couples devices through exactly two scalars — the
bandwidth price λ (Σ b_n ≤ B) and the shared-edge price μ
(Σ t̄_vm(m_n) ≤ C_edge). At fixed prices the problem separates per
device, hence per *homogeneous population*: each ``FleetSpec`` group gets
its own compiled program at its **native** shape ``(n_g, M_g + 1)`` (no
cross-group padding), and the groups are coordinated only by a cheap
host-level outer bisection whose excess functions are sums of per-group
excess at the same price:

    excess(λ)  =  Σ_g  [ Σ_{n ∈ g} b_n*(λ) ]  −  B
    occ(μ)     =  Σ_g  [ Σ_{n ∈ g} t̄_vm(m_n*(μ)) ]  −  C_edge

Both are monotone in the price, so the host loop replays the *exact*
bisection/bracket-expansion semantics of ``resource`` / ``solvers.scalar``
in numpy float64 (IEEE-identical arithmetic), with the per-group partial
sums evaluated on device. All price exponentiation (``10**log_price``)
happens **inside** the compiled programs via ``jnp.where(need, 10**lp, 0)``
— the same XLA pow the monolithic trace uses — so the two paths cannot
diverge by a host/device pow ulp.

Parity: leaf-wise agreement with ``Planner.plan`` at rtol ≤ 1e-6 is pinned
by ``tests/test_decompose.py`` for the exact-enumeration policies. The two
paths differ only in reduction *grouping* (per-group partials summed on
the host vs one (N,)-reduction), which perturbs the bisected prices by
O(ulp); everything downstream is price-Lipschitz. The PCCP policy also
runs through here, but its inner barrier sees native-width (M_g+1)
variables instead of padded (max_points+1) ones, so its iterates are not
bit-comparable — that width cut is precisely the perf win.

Compile model: one XLA program per distinct ``(M_g, n_bucket)`` group
shape per statics tuple — NOT per group and NOT per fleet. Group device
counts are bucketed (≤ 16 exact, then power-of-two quanta with ≤ ~12.5 %
lane waste, padded lanes weighted out of every sum by a 0/1 mask), so a
group growing 1000 → 1001 devices reuses the 1024-lane program. Device
batches within a group are sharded over the 1-D ``("devices",)`` mesh of
``parallel.sharding.planner_mesh`` via ``shard_map`` (the λ-solve path —
the ~60-probe hot loop — with per-shard partial sums psummed); groups are
processed one at a time, so peak *table* memory is O(largest group), not
O(fleet).
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from functools import lru_cache, partial
from math import gcd
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.core import ccp, channel, energy
from repro.core.blocks import Fleet
from repro.core.fleet import FleetSpec
from repro.core.placement import assign_devices_host
from repro.core.planner import (
    _MU_SAFETY,
    Plan,
    Policy,
    _edge_occ_prep,
    _optimal_point_solve,
    _optimal_prep,
    _optimal_select,
    _select_best,
    _traced_status,
    default_starts,
    get_policy,
    policy_point_tables,
)
from repro.core.resource import (
    _EDGE_CAP_RTOL,
    _LOG_PRICE_HI0,
    _LOG_PRICE_HI_MAX,
    _LOG_PRICE_LO,
    _LOG_PRICE_STEP,
    Allocation,
    _alloc_finalize,
    _alloc_prep,
    _alloc_solve_at,
    _rescale_with_floor,
    select_point,
)
from repro.parallel.sharding import planner_mesh

__all__ = ["ShardedGroup", "build_groups", "bucket_size", "plan_sharded",
           "program_cache_sizes"]


# ---------------------------------------------------------------------------
# Group construction: native-width fleets + lane bucketing
# ---------------------------------------------------------------------------

#: below this count a group compiles at its exact width (small groups are
#: cheap to compile and waste-sensitive); above it, counts are rounded up
#: to a power-of-two quantum ~n/16 so the worst-case lane waste is ~12.5 %
#: and a slowly growing population keeps hitting the same compiled shape.
_EXACT_BUCKET_MAX = 16


def bucket_size(n: int, multiple_of: int = 1) -> int:  # analyze: ok(TRC003): lane bucketing on concrete host ints (group counts, mesh size)
    """Padded lane count for a group of ``n`` devices (see module doc),
    additionally rounded to a multiple of ``multiple_of`` (the mesh size,
    so ``shard_map`` shards evenly)."""
    if n <= _EXACT_BUCKET_MAX:
        q = 1
    else:
        q = 1 << max((n - 1).bit_length() - 4, 0)
    q = q * multiple_of // gcd(q, multiple_of)
    return -(-n // q) * q


@dataclass(frozen=True)
class ShardedGroup:
    """One homogeneous population, materialized at native table width.

    ``fleet`` is a single-group ``FleetSpec`` build of ``n_pad`` lanes
    (bucketed count): its tables are ``(n_pad, M_g + 1)`` with an all-valid
    mask, real devices in lanes ``[:n]`` carrying the fleet-order gains
    slice, pad lanes repeating the last real device (finite, physically
    plausible — they run the full solve and are weighted out of every
    cross-device sum by ``w`` and sliced away on the host).
    """

    fleet: Fleet
    n: int  # real device count
    n_pad: int  # bucketed lane count (== fleet.num_devices)
    start: int  # fleet-order slice [start, stop) of the real lanes
    stop: int
    name: str
    w: jnp.ndarray  # (n_pad,) lane mask: 1.0 real, 0.0 pad


def build_groups(spec: FleetSpec, gains, mesh) -> list:  # analyze: ok(TRC002): gains are concretized once at group-build time (host-side spec surgery)
    """Materialize per-group native-width fleets from a ``FleetSpec`` and
    the fleet-order ``(N,)`` gains vector (``FleetSpec.sample_gains`` —
    the same sequence ``spec.build(key)`` would bake into the monolithic
    fleet, which is what makes the two paths comparable at a key)."""
    gains = np.asarray(jnp.asarray(gains, jnp.float64))
    if gains.shape != (spec.num_devices,):
        raise ValueError(
            f"gains must be ({spec.num_devices},) for this spec, "
            f"got shape {gains.shape}")
    mesh_size = int(mesh.devices.size)
    groups = []
    for g, (start, stop) in zip(spec.groups, spec.group_slices(), strict=True):
        n = g.count
        n_pad = bucket_size(n, mesh_size)
        gg = np.concatenate(
            [gains[start:stop], np.repeat(gains[stop - 1:stop], n_pad - n)])
        sub = FleetSpec((replace(g, count=n_pad),), area_m=spec.area_m,
                        min_dist_m=spec.min_dist_m)
        w = np.zeros(n_pad)
        w[:n] = 1.0
        groups.append(ShardedGroup(
            fleet=sub.build(gains=jnp.asarray(gg)), n=n, n_pad=n_pad,
            start=start, stop=stop, name=g.name, w=jnp.asarray(w)))
    return groups


def _pad_lanes(a: np.ndarray, n_pad: int) -> np.ndarray:  # analyze: ok(TRC002): host-side numpy padding of concrete scenario slices
    """Edge-repeat a (n,) host vector to (n_pad,)."""
    return np.concatenate([a, np.repeat(a[-1:], n_pad - a.shape[0])])


def _repad(x: jnp.ndarray, n_pad: int) -> jnp.ndarray:  # analyze: ok(TRC003): pad width is concrete host shape arithmetic
    """Edge-repeat the lane axis of a (S, n) device array back to (S, n_pad)
    after a global step touched only the real lanes."""
    k = n_pad - x.shape[1]
    if k == 0:
        return x
    return jnp.concatenate([x, jnp.repeat(x[:, -1:], k, axis=1)], axis=1)


def _cat_real(parts, groups):
    """Concatenate per-group (S, n_pad) leaves into fleet order (S, N)."""
    return jnp.concatenate(
        [x[:, :g.n] for x, g in zip(parts, groups, strict=True)], axis=1)


# ---------------------------------------------------------------------------
# Compiled per-group programs
# ---------------------------------------------------------------------------

#: every jitted program ever built, for cache introspection in the
#: recompile drill: (name, jitted fn) — ``program_cache_sizes`` sums
#: ``_cache_size()`` per name so tests can pin "one compile per distinct
#: group shape" without scraping compiler logs.
_PROGRAM_REGISTRY: list = []


def _register(name: str, fn):
    _PROGRAM_REGISTRY.append((name, fn))
    return fn


def program_cache_sizes() -> dict:
    """{program name: total jit-cache entries} across all program sets."""
    out: dict = {}
    for name, fn in _PROGRAM_REGISTRY:
        out[name] = out.get(name, 0) + fn._cache_size()
    return out


def _lane_specs(tree):
    """Lane-sharded PartitionSpecs for a pytree of per-device leaves
    (axis 0 = device lane, trailing axes replicated)."""
    return jax.tree_util.tree_map(
        lambda x: P("devices", *([None] * (x.ndim - 1))), tree)


class GroupPrograms(NamedTuple):
    """The compiled per-group programs of one statics tuple (see factory)."""

    prep: object  # (fleet, m (S,n), deadline, eps, B) -> AllocPrep (S,n)
    bsum: object  # (prep, w, B, log_lam (S,), need (S,)) -> (S,) Σ w·b
    solve: object  # (prep, B, log_lam, need) -> (b, f, feas) (S,n)
    edge_state: object  # (fleet, b, f, deadline, eps) -> μ-invariant tables
    occ_sum: object  # (occ, state…, w, log_mu, need) -> (S,) Σ w·occ[m*]
    partition: object  # (fleet, m, b, f, log_mu, mu_need, dl, eps, w) -> step
    occ_sum_node: object  # (occ, mask (S,n), state…, w, log_mu, need) -> (S,)
    partition_nodes: object  # per-device (S,n) μ variant of ``partition``


@lru_cache(maxsize=None)
def _group_programs(mesh, policy: Policy, pccp_iters: int, solver: str,
                    pccp_gated: bool, channel_cv: float) -> GroupPrograms:
    """Build (once per mesh + statics) the jitted per-group programs.

    The lru_cache keeps the *function objects* stable across
    ``plan_sharded`` calls, so jax's jit cache keys on (shape, dtype) only
    — one XLA compile per distinct ``(M_g, n_bucket)`` group shape, zero
    on value-varied repeats. ``shard_map`` wrappers are constructed inside
    the jitted trace (specs depend on leaf ranks), which costs nothing at
    steady state.

    Prices enter every program as ``(log_price, need)`` and are
    exponentiated in-trace — ``jnp.where(need, 10.0**log_price, 0.0)``,
    with the final μ additionally scaled by ``_MU_SAFETY`` exactly where
    the monolithic path does — so the sharded path shares the monolithic
    trace's pow/rounding behaviour bit-for-bit.
    """
    sig_model, ub_k = policy.sigma_model, policy.ub_k
    svec = P(None, "devices")  # (S, n) start-vectorized per-lane leaves

    # ---- λ path (the hot loop): lane-sharded over the planner mesh ----

    def prep_raw(fleet, m, deadline, eps, B):
        return jax.vmap(
            lambda mm: _alloc_prep(fleet, mm, deadline, eps, B, sig_model,
                                   ub_k, channel_cv))(m)

    @jax.jit
    def prep(fleet, m, deadline, eps, B):
        fn = shard_map(
            prep_raw, mesh=mesh,
            in_specs=(_lane_specs(fleet), svec, P("devices"), P("devices"),
                      P()),
            out_specs=svec)
        return fn(fleet, m, deadline, eps, B)

    def bsum_raw(prep_v, w, B, log_lam, need):
        lam = jnp.where(need, 10.0 ** log_lam, 0.0)  # (S,) in-trace pow
        b = jax.vmap(
            lambda p, l: _alloc_solve_at(p, B, l, channel_cv)[0])(prep_v, lam)
        return jax.lax.psum(jnp.sum(w[None, :] * b, axis=-1), "devices")

    @jax.jit
    def bsum(prep_v, w, B, log_lam, need):
        fn = shard_map(
            bsum_raw, mesh=mesh,
            in_specs=(svec, P("devices"), P(), P(None), P(None)),
            out_specs=P(None))
        return fn(prep_v, w, B, log_lam, need)

    def solve_raw(prep_v, B, log_lam, need):
        lam = jnp.where(need, 10.0 ** log_lam, 0.0)
        return jax.vmap(
            lambda p, l: _alloc_solve_at(p, B, l, channel_cv))(prep_v, lam)

    @jax.jit
    def solve(prep_v, B, log_lam, need):
        fn = shard_map(
            solve_raw, mesh=mesh,
            in_specs=(svec, P(), P(None), P(None)),
            out_specs=svec)
        return fn(prep_v, B, log_lam, need)

    # ---- μ path + partition: per-group tables, once per outer step ----
    # (not lane-sharded: these run once per step vs ~60 λ probes, and the
    # PCCP inner barrier is kept off shard_map on purpose — its iterates
    # are already native-width, which is where the win is)

    @jax.jit
    def edge_state(fleet, b, f, deadline, eps):
        sigma = ccp.SIGMA_FNS[sig_model](eps)

        def one(b1, f1):
            e_t, t_t, v_t = policy_point_tables(fleet, b1, f1, policy,
                                                channel_cv)
            feas, any_feas, mlb = _edge_occ_prep(t_t, v_t, sigma, deadline)
            return e_t, feas, any_feas, mlb

        return jax.vmap(one)(b, f)

    @jax.jit
    def occ_sum(occ, e_t, feas, any_feas, mlb, w, log_mu, need):
        def one(e1, fe1, af1, mlb1, lm, nd):
            mu = jnp.where(nd, 10.0 ** lm, 0.0)  # probes: no safety factor
            cost = jnp.where(fe1, e1 + mu * occ, jnp.inf)
            m = jnp.where(af1, jnp.argmin(cost, axis=-1), mlb1)
            return jnp.sum(w * jnp.take_along_axis(occ, m[:, None], -1)[:, 0])

        return jax.vmap(one)(e_t, feas, any_feas, mlb, log_mu, need)

    @jax.jit
    def partition(fleet, m, b, f, log_mu, mu_need, deadline, eps, w):
        sigma = ccp.SIGMA_FNS[sig_model](eps)
        occ = fleet.chain.t_vm

        def one(m1, b1, f1, lm, mn):
            mu = jnp.where(mn, 10.0 ** lm * _MU_SAFETY, 0.0)
            e_t, t_t, v_t = policy_point_tables(fleet, b1, f1, policy,
                                                channel_cv)
            m_new, feas, iters = policy.partition(
                m1, e_t + mu * occ, t_t, v_t, sigma, deadline, pccp_iters,
                solver, pccp_gated)
            # the trace records true energy, not the μ-priced surrogate
            obj = jnp.sum(
                w * jnp.take_along_axis(e_t, m_new[:, None], -1)[:, 0])
            return m_new, feas, iters, obj

        return jax.vmap(one)(m, b, f, log_mu, mu_need)

    # ---- placement path (per-node capacity vectors, DESIGN.md §placement):
    # compiled only when a vector capacity is planned, so the scalar path's
    # program_cache_sizes pins are untouched ----

    @jax.jit
    def occ_sum_node(occ, mask, e_t, feas, any_feas, mlb, w, log_mu, need):
        """One node's occupancy partial: every lane argmins the full priced
        table at the node's trial μ (exactly ``_node_clearing_prices``) and
        only the lanes *assigned to the node* count toward the sum."""
        def one(mk1, e1, fe1, af1, mlb1, lm, nd):
            mu = jnp.where(nd, 10.0 ** lm, 0.0)  # probes: no safety factor
            cost = jnp.where(fe1, e1 + mu * occ, jnp.inf)
            m = jnp.where(af1, jnp.argmin(cost, axis=-1), mlb1)
            occ_sel = jnp.take_along_axis(occ, m[:, None], -1)[:, 0]
            return jnp.sum(jnp.where(mk1, w * occ_sel, 0.0))

        return jax.vmap(one)(mask, e_t, feas, any_feas, mlb, log_mu, need)

    @jax.jit
    def partition_nodes(fleet, m, b, f, log_mu_dev, mu_need_dev, deadline,
                        eps, w):
        """``partition`` with a per-device price row: each lane pays its
        own node's μ_{a_n}·occ in the priced table."""
        sigma = ccp.SIGMA_FNS[sig_model](eps)
        occ = fleet.chain.t_vm

        def one(m1, b1, f1, lmd, mnd):
            mu_dev = jnp.where(mnd, 10.0 ** lmd * _MU_SAFETY, 0.0)
            e_t, t_t, v_t = policy_point_tables(fleet, b1, f1, policy,
                                                channel_cv)
            m_new, feas, iters = policy.partition(
                m1, e_t + mu_dev[:, None] * occ, t_t, v_t, sigma, deadline,
                pccp_iters, solver, pccp_gated)
            obj = jnp.sum(
                w * jnp.take_along_axis(e_t, m_new[:, None], -1)[:, 0])
            return m_new, feas, iters, obj

        return jax.vmap(one)(m, b, f, log_mu_dev, mu_need_dev)

    for name, fn in (("group_prep", prep), ("group_bsum", bsum),
                     ("group_solve", solve), ("group_edge_state", edge_state),
                     ("group_occ_sum", occ_sum),
                     ("group_partition", partition),
                     ("group_occ_sum_node", occ_sum_node),
                     ("group_partition_nodes", partition_nodes)):
        _register(name, fn)
    return GroupPrograms(prep=prep, bsum=bsum, solve=solve,
                         edge_state=edge_state, occ_sum=occ_sum,
                         partition=partition, occ_sum_node=occ_sum_node,
                         partition_nodes=partition_nodes)


# ---------------------------------------------------------------------------
# Global programs: the only cross-group compiled steps
# ---------------------------------------------------------------------------

@jax.jit
def _global_rescale(b, b_lo, need, B):
    """The Σb ≤ B floor-respecting rescale of ``_alloc_finalize``, applied
    to the fleet-order (S, N) concatenation mid-alternation (the partition
    step reads the post-rescale b, exactly as the monolithic step does)."""

    def one(b1, blo1, nd):
        return jnp.where(nd & (jnp.sum(b1) > B),
                         _rescale_with_floor(b1, blo1, B), b1)

    return jax.vmap(one)(b, b_lo, need)


@partial(jax.jit, static_argnames=("sigma_model", "channel_cv"))
def _global_finish(prep_v, b, f, feas, part_feas, B, log_lam, need, edge_cap,
                   log_mu, mu_need, deadline, eps, sigma_model="cantelli",
                   channel_cv=0.0):
    """Final fleet-order scoring on the concatenated per-group solves:
    the identical ``_alloc_finalize`` + margins the monolithic alternation
    ends with, vmapped over starts."""

    def one(p, b1, f1, fe1, pf1, ll, nd, lm, mn):
        lam = jnp.where(nd, 10.0 ** ll, 0.0)
        mu = jnp.where(mn, 10.0 ** lm * _MU_SAFETY, 0.0)
        alloc = _alloc_finalize(p, b1, f1, fe1, B, lam, nd, channel_cv,
                                edge_capacity_s=edge_cap, edge_price=mu)
        sel = p.sel
        t_mean = (energy.mean_local_time(sel.w_flops, sel.g_eff, alloc.f)
                  + channel.offload_time(sel.d_bits, alloc.b, p.p_tx, p.gain)
                  + sel.t_vm)
        margins = ccp.deterministic_deadline_margin(
            t_mean, sel.v_loc + sel.v_vm, eps, deadline, sigma_model)
        total = jnp.sum(alloc.energy)
        return (alloc, total, pf1 & alloc.feasible, margins,
                _traced_status(alloc, total, margins))

    return jax.vmap(one)(prep_v, b, f, feas, part_feas, log_lam, need,
                         log_mu, mu_need)


@partial(jax.jit, static_argnames=("sigma_model", "channel_cv"))
def _global_finish_nodes(prep_v, b, f, feas, part_feas, B, log_lam, need,
                         edge_cap, log_mu_node, mu_need_node, assignment,
                         deadline, eps, sigma_model="cantelli",
                         channel_cv=0.0):
    """Per-node-price variant of ``_global_finish`` (DESIGN.md §placement):
    ``_alloc_finalize`` checks each node's occupancy against its own C_e at
    the device→node assignment and stamps the (E,) price vector into
    ``alloc.mu``. ``log_mu_node``/``mu_need_node`` are (S, E),
    ``assignment`` is (S, N) int32."""

    def one(p, b1, f1, fe1, pf1, ll, nd, lmn, mnn, a1):
        lam = jnp.where(nd, 10.0 ** ll, 0.0)
        mu_node = jnp.where(mnn, 10.0 ** lmn * _MU_SAFETY, 0.0)
        alloc = _alloc_finalize(p, b1, f1, fe1, B, lam, nd, channel_cv,
                                edge_capacity_s=edge_cap, edge_price=mu_node,
                                assignment=a1)
        sel = p.sel
        t_mean = (energy.mean_local_time(sel.w_flops, sel.g_eff, alloc.f)
                  + channel.offload_time(sel.d_bits, alloc.b, p.p_tx, p.gain)
                  + sel.t_vm)
        margins = ccp.deterministic_deadline_margin(
            t_mean, sel.v_loc + sel.v_vm, eps, deadline, sigma_model)
        total = jnp.sum(alloc.energy)
        return (alloc, total, pf1 & alloc.feasible, margins,
                _traced_status(alloc, total, margins))

    return jax.vmap(one)(prep_v, b, f, feas, part_feas, log_lam, need,
                         log_mu_node, mu_need_node, assignment)


_register("global_rescale", _global_rescale)
_register("global_finish", _global_finish)
_register("global_finish_nodes", _global_finish_nodes)


# ---------------------------------------------------------------------------
# Host-level price loops (numpy float64 replicas of the traced searches)
# ---------------------------------------------------------------------------

def _host_bisect(fn, lo, hi, iters=60, endpoint="mid"):  # analyze: ok(TRC001,TRC002,TRC003): host-level global price loop by design (numpy replica of solvers.scalar.bisect)
    """Per-lane ``solvers.scalar.bisect`` in numpy float64.

    Vectorized over the multi-start lanes with masked per-lane updates —
    exactly what ``vmap(bisect)`` lowers to — and IEEE-identical midpoint
    arithmetic, so the host search visits the same points the traced
    search would at the same excess values.
    """
    lo = np.asarray(lo, np.float64).copy()
    hi = np.asarray(hi, np.float64).copy()
    f_lo = fn(lo)
    for _ in range(iters):
        mid = 0.5 * (lo + hi)
        f_mid = fn(mid)
        go_right = np.sign(f_mid) == np.sign(f_lo)
        lo = np.where(go_right, mid, lo)
        f_lo = np.where(go_right, f_mid, f_lo)
        hi = np.where(go_right, hi, mid)
    return hi if endpoint == "hi" else 0.5 * (lo + hi)


def _host_expand(fn, hi_start=None, size=1):  # analyze: ok(TRC001,TRC002,TRC003): host-level global price loop by design (numpy replica of resource._expand_log_bracket)
    """Per-lane ``resource._expand_log_bracket`` in numpy float64:
    warm-start snap to the expansion grid, contract while the next-lower
    grid point clears, then the standard upward expansion. Masked per-lane
    updates replicate the vmapped while_loop batching rule (inactive lanes
    freeze their carry; every lane's excess is still evaluated, as the
    batched trace does). ``hi_start=None`` is the cold start (no
    contraction pass), matching the traced cold path."""
    hi0 = _LOG_PRICE_HI0
    if hi_start is None:
        hi = np.full(size, hi0)
        f_hi = fn(hi)
    else:
        k = np.round((np.asarray(hi_start, np.float64) - hi0)
                     / _LOG_PRICE_STEP)
        k_max = (_LOG_PRICE_HI_MAX - _LOG_PRICE_HI0) // _LOG_PRICE_STEP
        hi = hi0 + np.clip(k, 0.0, k_max) * _LOG_PRICE_STEP
        f_hi = fn(hi)

        def probe_down(h):
            f = fn(h - _LOG_PRICE_STEP)
            return np.where(h > hi0 + 1e-9, f, 1.0)

        f_dn = probe_down(hi)
        while True:
            active = (hi > hi0 + 1e-9) & (f_dn <= 0.0)
            if not active.any():
                break
            hi_new = np.where(active, hi - _LOG_PRICE_STEP, hi)
            f_hi = np.where(active, f_dn, f_hi)
            f_dn = np.where(active, probe_down(hi_new), f_dn)
            hi = hi_new
    while True:
        active = (f_hi > 0.0) & (hi < _LOG_PRICE_HI_MAX - 1e-9)
        if not active.any():
            break
        hi = np.where(active, hi + _LOG_PRICE_STEP, hi)
        f_hi = np.where(active, fn(hi), f_hi)
    return hi, f_hi


def _lam_clear(programs, groups, preps, B_dev, B_host, S, lam_hi):  # analyze: ok(TRC001,TRC002,TRC003): host-level global price loop by design
    """Clear the bandwidth price λ across groups: the global excess is the
    sum of per-group device-evaluated partials at the same price. Returns
    ``(log_lam, need, lam_hi)`` with the expanded bracket top threaded for
    the next alternation step (warm-start is value-invariant, see
    ``resource._expand_log_bracket``). When no start lane needs pricing
    (Σ b(0) ≤ B everywhere) the search is skipped outright — λ = 0
    regardless, exactly what the monolithic ``where(need, …, 0)`` yields.
    """

    def excess(log_lam, need):
        ll, nd = jnp.asarray(log_lam, jnp.float64), jnp.asarray(need)
        tot = None
        for g, p in zip(groups, preps, strict=True):
            part = programs.bsum(p, g.w, B_dev, ll, nd)
            tot = part if tot is None else tot + part
        return np.asarray(tot) - B_host

    all_on = np.ones(S, bool)
    need = excess(np.zeros(S), np.zeros(S, bool)) > 0.0
    if not need.any():
        return np.zeros(S), need, lam_hi
    fn = lambda x: excess(x, all_on)
    hi, _ = _host_expand(fn, hi_start=lam_hi)
    log_lam = _host_bisect(fn, np.full(S, _LOG_PRICE_LO), hi, iters=60)
    return log_lam, need, hi


def _mu_clear(programs, groups, states, cap_host, S, mu_hi):  # analyze: ok(TRC001,TRC002,TRC003): host-level global price loop by design
    """Clear the shared-edge price μ across groups on the held per-group
    μ-invariant tables (``edge_state``): Σ_g Σ_n occ[m*(μ)] vs C_edge.
    Same skip/warm-start discipline as ``_lam_clear``; the bisection keeps
    the ``endpoint="hi"`` step-function semantics of
    ``planner._clearing_price``."""

    def occ_excess(log_mu, need):
        lm, nd = jnp.asarray(log_mu, jnp.float64), jnp.asarray(need)
        tot = None
        for g, st in zip(groups, states, strict=True):
            part = programs.occ_sum(g.fleet.chain.t_vm, *st, g.w, lm, nd)
            tot = part if tot is None else tot + part
        return np.asarray(tot) - cap_host

    all_on = np.ones(S, bool)
    need = occ_excess(np.zeros(S), np.zeros(S, bool)) > 0.0
    if not need.any():
        return np.zeros(S), need, mu_hi
    fn = lambda x: occ_excess(x, all_on)
    hi, _ = _host_expand(fn, hi_start=mu_hi)
    log_mu = _host_bisect(fn, np.full(S, _LOG_PRICE_LO), hi, iters=60,
                          endpoint="hi")
    return log_mu, need, hi


def _mu_clear_nodes(programs, groups, states, masks, caps_host, S, mu_hi):  # analyze: ok(TRC001,TRC002,TRC003): host-level global price loop by design
    """Per-node μ clearing at a fixed device→node assignment (DESIGN.md
    §placement): node e's occupancy sums masked per-group partials
    (``occ_sum_node``) against its own C_e — E independent replicas of
    ``_mu_clear`` with per-node warm brackets. ``masks`` is a per-group
    list of (E, S, n_pad) lane masks; ``mu_hi`` is (E, S). Returns
    ``(log_mu (E, S), need (E, S), mu_hi)`` — absent (C_e = 0) and
    unconstrained (C_e = ∞) nodes never clear (occupancy 0 resp. excess
    −∞ keeps ``need`` False)."""
    e_count = caps_host.shape[0]
    log_mu = np.zeros((e_count, S))
    mu_need = np.zeros((e_count, S), bool)
    hi_out = np.array(mu_hi, np.float64, copy=True)
    all_on = np.ones(S, bool)
    for e in range(e_count):
        def occ_excess(lm_s, need_s, e=e):
            ll, nd = jnp.asarray(lm_s, jnp.float64), jnp.asarray(need_s)
            tot = None
            for g, st, mk in zip(groups, states, masks, strict=True):
                part = programs.occ_sum_node(g.fleet.chain.t_vm, mk[e], *st,
                                             g.w, ll, nd)
                tot = part if tot is None else tot + part
            return np.asarray(tot) - caps_host[e]

        need_e = occ_excess(np.zeros(S), np.zeros(S, bool)) > 0.0
        if not need_e.any():
            continue
        fn = lambda x: occ_excess(x, all_on)
        hi, _ = _host_expand(fn, hi_start=mu_hi[e])
        log_mu[e] = _host_bisect(fn, np.full(S, _LOG_PRICE_LO), hi, iters=60,
                                 endpoint="hi")
        mu_need[e] = need_e
        hi_out[e] = hi
    return log_mu, mu_need, hi_out


# ---------------------------------------------------------------------------
# The decomposed Algorithm-2 alternation
# ---------------------------------------------------------------------------

def _plan_groups(groups, sc, policy: Policy, outer_iters, m0_groups, S,  # analyze: ok(TRC001,TRC002,TRC003): host-level orchestrator of compiled per-group programs by design
                 programs, channel_cv, mesh):
    """Run the start-vectorized alternation over the group programs.

    Per step: per-group λ-invariant prep → global λ clearing → per-group
    solve at λ → global Σb ≤ B rescale → (finite capacity only) global μ
    clearing on held per-group tables → per-group partition at the priced
    tables. After ``outer_iters`` steps: one more λ clearing at the final
    partition, then the global finish (finalize + margins) on the
    fleet-order concatenation, then the standard multi-start selection.
    """
    deadline_np = np.asarray(sc.deadline)
    eps_np = np.asarray(sc.eps)
    B_dev, cap_dev = sc.B, sc.edge_capacity_s
    B_host = float(np.asarray(sc.B))
    cap_np = np.asarray(cap_dev, np.float64)
    multi_node = cap_np.ndim == 1  # per-node capacity vector (§placement)
    if multi_node:
        caps_host = cap_np
        e_count = int(caps_host.shape[0])
        price_edge = policy.edge_aware
    else:
        cap_host = float(cap_np)
        price_edge = np.isfinite(cap_host) and policy.edge_aware

    dls = [jnp.asarray(_pad_lanes(deadline_np[g.start:g.stop], g.n_pad))
           for g in groups]
    epss = [jnp.asarray(_pad_lanes(eps_np[g.start:g.stop], g.n_pad))
            for g in groups]
    t_vm_np = [np.asarray(g.fleet.chain.t_vm) for g in groups]

    def host_assignment(m_gs):
        """Fleet-order (S, N) device→node map at the current partitions —
        the host replay of the monolithic per-step ``assign_devices`` (the
        numpy mirror is pinned bit-identical in ``tests/test_placement``)."""
        occ_parts = []
        for g, m_g, tv in zip(groups, m_gs, t_vm_np, strict=True):
            m_np = np.asarray(m_g)[:, :g.n]  # (S, n) real lanes
            occ_parts.append(np.take_along_axis(
                tv[None, :g.n, :], m_np[:, :, None], axis=2)[:, :, 0])
        occ = np.concatenate(occ_parts, axis=1)  # (S, N)
        return np.stack([
            assign_devices_host(occ[s], caps_host, policy.assign)
            for s in range(occ.shape[0])]).astype(np.int32)

    def node_masks(a):
        """Per-group (E, S, n_pad) lane masks from a fleet-order (S, N)
        assignment (pad lanes match no node → zero partials)."""
        out = []
        for g in groups:
            a_g = a[:, g.start:g.stop]
            pad = np.full((a.shape[0], g.n_pad - g.n), -1, a_g.dtype)
            a_p = np.concatenate([a_g, pad], axis=1)
            out.append(jnp.asarray(
                a_p[None, :, :] == np.arange(e_count)[:, None, None]))
        return out

    def per_device_prices(a, log_mu_e, mu_need_e):
        """Per-group (S, n_pad) price rows: lane n pays its node's
        μ_{a_n} (pad lanes priced 0 via need=False)."""
        rows = np.arange(a.shape[0])[:, None]
        lm_dev = log_mu_e.T[rows, a]  # (S, N)
        nd_dev = mu_need_e.T[rows, a]
        lms, nds = [], []
        for g in groups:
            k = g.n_pad - g.n
            lm_g = np.concatenate(
                [lm_dev[:, g.start:g.stop],
                 np.zeros((a.shape[0], k))], axis=1)
            nd_g = np.concatenate(
                [nd_dev[:, g.start:g.stop],
                 np.zeros((a.shape[0], k), bool)], axis=1)
            lms.append(jnp.asarray(lm_g))
            nds.append(jnp.asarray(nd_g))
        return lms, nds
    # The initial starts are committed with the replicated mesh sharding
    # the program outputs carry: from iteration 2 on, m is a loop-carried
    # program output, and an uncommitted first m would re-key the
    # prep/partition jit caches — two compiles per group instead of one.
    rep = NamedSharding(mesh, P())
    m_gs = [jax.device_put(np.broadcast_to(m0[:, None], (S, g.n_pad)), rep)
            for m0, g in zip(m0_groups, groups, strict=True)]

    lam_hi = np.full(S, _LOG_PRICE_HI0)
    mu_hi = np.full(S, _LOG_PRICE_HI0)
    log_mu, mu_need = np.zeros(S), np.zeros(S, bool)
    if multi_node:
        mu_hi_e = np.full((e_count, S), _LOG_PRICE_HI0)
        log_mu_e = np.zeros((e_count, S))
        mu_need_e = np.zeros((e_count, S), bool)
    objs, iters_steps = [], []
    part_feas = None

    def lam_solve(m_gs):
        """prep → λ clearing → per-group (b, f, feas) at the cleared λ."""
        preps = [programs.prep(g.fleet, m, dl, ep, B_dev)
                 for g, m, dl, ep in zip(groups, m_gs, dls, epss, strict=True)]
        log_lam, need, hi = _lam_clear(programs, groups, preps, B_dev, B_host,
                                       S, lam_hi)
        ll, nd = jnp.asarray(log_lam), jnp.asarray(need)
        sols = [programs.solve(p, B_dev, ll, nd) for p in preps]
        return preps, sols, log_lam, need, hi

    for _ in range(outer_iters):
        preps, sols, log_lam, lam_need, lam_hi = lam_solve(m_gs)
        nd = jnp.asarray(lam_need)
        b_cat = _global_rescale(
            _cat_real([s[0] for s in sols], groups),
            _cat_real([p.b_lo for p in preps], groups), nd, B_dev)
        b_gs = [_repad(b_cat[:, g.start:g.stop], g.n_pad) for g in groups]
        f_gs = [s[1] for s in sols]
        if multi_node:
            a_now = host_assignment(m_gs)
            if price_edge:
                states = [programs.edge_state(g.fleet, b, f, dl, ep)
                          for g, b, f, dl, ep in zip(groups, b_gs, f_gs, dls,
                                                     epss, strict=True)]
                log_mu_e, mu_need_e, mu_hi_e = _mu_clear_nodes(
                    programs, groups, states, node_masks(a_now), caps_host,
                    S, mu_hi_e)
            lms, nds = per_device_prices(a_now, log_mu_e, mu_need_e)
            parts = [programs.partition_nodes(g.fleet, m, b, f, lmd, ndd,
                                              dl, ep, g.w)
                     for g, m, b, f, lmd, ndd, dl, ep in zip(
                         groups, m_gs, b_gs, f_gs, lms, nds, dls, epss,
                         strict=True)]
        else:
            if price_edge:
                states = [programs.edge_state(g.fleet, b, f, dl, ep)
                          for g, b, f, dl, ep in zip(groups, b_gs, f_gs, dls,
                                                     epss, strict=True)]
                log_mu, mu_need, mu_hi = _mu_clear(programs, groups, states,
                                                   cap_host, S, mu_hi)
            lm, mn = jnp.asarray(log_mu), jnp.asarray(mu_need)
            parts = [programs.partition(g.fleet, m, b, f, lm, mn, dl, ep, g.w)
                     for g, m, b, f, dl, ep in zip(groups, m_gs, b_gs, f_gs,
                                                   dls, epss, strict=True)]
        m_gs = [pt[0] for pt in parts]
        part_feas = _cat_real([pt[1] for pt in parts], groups)
        iters_steps.append(_cat_real([pt[2] for pt in parts], groups))
        objs.append(sum(np.asarray(pt[3]) for pt in parts))

    preps, sols, log_lam, lam_need, lam_hi = lam_solve(m_gs)
    prep_cat = jax.tree_util.tree_map(
        lambda *xs: _cat_real(xs, groups), *preps)
    b_cat = _cat_real([s[0] for s in sols], groups)
    f_cat = _cat_real([s[1] for s in sols], groups)
    feas_cat = _cat_real([s[2] for s in sols], groups)
    if multi_node:
        # like the monolithic tail: assignment recomputed at the final m,
        # priced with the last step's node prices
        assignment_s = jnp.asarray(host_assignment(m_gs))
        alloc_s, total_s, feas_s, margins_s, status_s = _global_finish_nodes(
            prep_cat, b_cat, f_cat, feas_cat, part_feas, B_dev,
            jnp.asarray(log_lam), jnp.asarray(lam_need), cap_dev,
            jnp.asarray(log_mu_e.T), jnp.asarray(mu_need_e.T), assignment_s,
            sc.deadline, sc.eps, sigma_model=policy.sigma_model,
            channel_cv=channel_cv)
    else:
        assignment_s = jnp.zeros(
            (S, int(b_cat.shape[1])), jnp.int32)
        alloc_s, total_s, feas_s, margins_s, status_s = _global_finish(
            prep_cat, b_cat, f_cat, feas_cat, part_feas, B_dev,
            jnp.asarray(log_lam), jnp.asarray(lam_need), cap_dev,
            jnp.asarray(log_mu), jnp.asarray(mu_need), sc.deadline, sc.eps,
            sigma_model=policy.sigma_model, channel_cv=channel_cv)

    plans = Plan(
        m_sel=_cat_real(m_gs, groups),
        alloc=alloc_s,
        total_energy=total_s,
        feasible=feas_s,
        objective_trace=jnp.swapaxes(
            jnp.asarray(np.stack(objs, axis=0)), 0, 1),  # (S, outer)
        pccp_iters=jnp.stack(iters_steps, axis=1),  # (S, outer, N)
        margins=margins_s,
        status=status_s,
        assignment=assignment_s,
    )
    idx = int(_select_best(plans))
    return jax.tree_util.tree_map(lambda x: x[idx], plans)


# ---------------------------------------------------------------------------
# Optimal baseline: group-sharded (λ, μ) two-price exact search
# ---------------------------------------------------------------------------

class OptimalPrograms(NamedTuple):
    prep: object  # (fleet, deadline, eps, B) -> λ-invariant tables
    tables: object  # (fleet, prep…, B, log_lam, need) -> per-λ point tables
    occ: object  # (fleet, cost, feas, budget, w, log_mu, need) -> Σ occ[m*]
    eval: object  # final per-lane selection + Σ w·b / Σ w·occ partials


@lru_cache(maxsize=None)
def _optimal_programs(mesh, sigma_model: str) -> OptimalPrograms:
    """Per-group programs of the exact joint search (``plan_optimal``) at
    native width, sharing ``planner._optimal_*`` so the two paths cannot
    drift. No start axis: the exact search has no alternation."""

    def prep_raw(fleet, deadline, eps, B):
        sigma = ccp.SIGMA_FNS[sigma_model](eps)
        return _optimal_prep(fleet, deadline, sigma, B)

    @jax.jit
    def prep(fleet, deadline, eps, B):
        fn = shard_map(
            prep_raw, mesh=mesh,
            in_specs=(_lane_specs(fleet), P("devices"), P("devices"), P()),
            out_specs=P("devices", None))
        return fn(fleet, deadline, eps, B)

    def tables_raw(fleet, budget_all, b_lo_all, feas0_all, B, log_lam, need):
        lam = jnp.where(need, 10.0 ** log_lam, 0.0)
        return _optimal_point_solve(fleet, budget_all, b_lo_all, feas0_all,
                                    lam, B)

    @jax.jit
    def tables(fleet, budget_all, b_lo_all, feas0_all, B, log_lam, need):
        fn = shard_map(
            tables_raw, mesh=mesh,
            in_specs=(_lane_specs(fleet), P("devices", None),
                      P("devices", None), P("devices", None), P(), P(), P()),
            out_specs=P("devices", None))
        return fn(fleet, budget_all, b_lo_all, feas0_all, B, log_lam, need)

    def occ_raw(fleet, cost, feas, budget_all, w, log_mu, need):
        mu = jnp.where(need, 10.0 ** log_mu, 0.0)  # probes: no safety factor
        m_sel, _ = _optimal_select(cost, feas, budget_all, fleet.chain.t_vm,
                                   mu)
        occ_sel = jnp.take_along_axis(
            fleet.chain.t_vm, m_sel[:, None], -1)[:, 0]
        return jax.lax.psum(jnp.sum(w * occ_sel), "devices")

    @jax.jit
    def occ(fleet, cost, feas, budget_all, w, log_mu, need):
        fn = shard_map(
            occ_raw, mesh=mesh,
            in_specs=(_lane_specs(fleet), P("devices", None),
                      P("devices", None), P("devices", None), P("devices"),
                      P(), P()),
            out_specs=P())
        return fn(fleet, cost, feas, budget_all, w, log_mu, need)

    def eval_raw(fleet, cost, b, f, feas, budget_all, w, deadline, eps,
                 log_mu, need):
        mu = jnp.where(need, 10.0 ** log_mu * _MU_SAFETY, 0.0)
        m_sel, any_feas = _optimal_select(cost, feas, budget_all,
                                          fleet.chain.t_vm, mu)
        pick = lambda a: jnp.take_along_axis(a, m_sel[:, None], -1)[:, 0]
        b_sel, f_sel = pick(b), pick(f)
        sel = select_point(fleet, m_sel)
        e_loc = energy.expected_local_energy(
            fleet.platform.kappa, sel.w_flops, sel.g_eff, f_sel)
        e_off = channel.offload_energy(sel.d_bits, b_sel, fleet.link.p_tx,
                                       fleet.link.gain)
        t_mean = (energy.mean_local_time(sel.w_flops, sel.g_eff, f_sel)
                  + channel.offload_time(sel.d_bits, b_sel, fleet.link.p_tx,
                                         fleet.link.gain)
                  + sel.t_vm)
        margins = ccp.deterministic_deadline_margin(
            t_mean, sel.v_loc + sel.v_vm, eps, deadline, sigma_model)
        b_part = jax.lax.psum(jnp.sum(w * b_sel), "devices")
        occ_part = jax.lax.psum(jnp.sum(w * sel.t_vm), "devices")
        return (m_sel, b_sel, f_sel, e_loc, e_off, pick(feas) & any_feas,
                margins, b_part, occ_part)

    @jax.jit
    def eval_(fleet, cost, b, f, feas, budget_all, w, deadline, eps, log_mu,
              need):
        fn = shard_map(
            eval_raw, mesh=mesh,
            in_specs=(_lane_specs(fleet), P("devices", None),
                      P("devices", None), P("devices", None),
                      P("devices", None), P("devices", None), P("devices"),
                      P("devices"), P("devices"), P(), P()),
            out_specs=(P("devices"), P("devices"), P("devices"),
                       P("devices"), P("devices"), P("devices"),
                       P("devices"), P(), P()))
        return fn(fleet, cost, b, f, feas, budget_all, w, deadline, eps,
                  log_mu, need)

    for name, fn in (("opt_prep", prep), ("opt_tables", tables),
                     ("opt_occ", occ), ("opt_eval", eval_)):
        _register(name, fn)
    return OptimalPrograms(prep=prep, tables=tables, occ=occ, eval=eval_)


def _plan_optimal_sharded(groups, sc, policy: Policy, mesh) -> Plan:  # analyze: ok(TRC001,TRC002,TRC003): host-level orchestrator of compiled per-group programs by design
    """Group-decomposed ``plan_optimal``: the nested (λ, μ) exact search
    with per-group native-width point tables. The λ excess and the inner
    μ clearing both sum per-group device partials on the host; the μ
    search at each λ probe is cold (matching ``plan_optimal.mu_star``)
    and skipped entirely when the unpriced selection already fits."""
    progs = _optimal_programs(mesh, policy.sigma_model)
    deadline_np = np.asarray(sc.deadline)
    eps_np = np.asarray(sc.eps)
    B_dev, cap_dev = sc.B, sc.edge_capacity_s
    B_host = float(np.asarray(sc.B))
    cap_np = np.asarray(cap_dev)
    if cap_np.ndim:
        raise NotImplementedError(
            "plan_sharded with a per-node edge_capacity_s vector needs an "
            "alternating policy (the exact solve-override path is "
            "monolithic-only — use Planner.plan, or policy='robust')")
    cap_host = float(cap_np)
    finite_cap = np.isfinite(cap_host)

    dls = [jnp.asarray(_pad_lanes(deadline_np[g.start:g.stop], g.n_pad))
           for g in groups]
    epss = [jnp.asarray(_pad_lanes(eps_np[g.start:g.stop], g.n_pad))
            for g in groups]
    preps = [progs.prep(g.fleet, dl, ep, B_dev)
             for g, dl, ep in zip(groups, dls, epss, strict=True)]

    def solve_at(log_lam, lam_need):
        """Full (λ, μ*(λ)) solve: per-group tables at λ, μ cleared on the
        held tables, then the final per-lane selection. Returns the λ
        excess, the per-group eval outputs, and (log_mu, mu_need)."""
        ll = jnp.asarray(log_lam, jnp.float64)
        nd = jnp.asarray(bool(lam_need))
        tabs = [progs.tables(g.fleet, *p, B_dev, ll, nd)
                for g, p in zip(groups, preps, strict=True)]

        log_mu, mu_need = 0.0, False
        if finite_cap:
            def occ_excess(lms):
                tot = 0.0
                for g, p, t in zip(groups, preps, tabs, strict=True):
                    tot += float(progs.occ(
                        g.fleet, t[0], t[4], p[0], g.w,
                        jnp.asarray(float(lms[0]), jnp.float64),
                        jnp.asarray(lms[1])))
                return np.asarray([tot - cap_host])

            if occ_excess((0.0, False))[0] > 0.0:
                fn = lambda x: occ_excess((x[0], True))
                hi, _ = _host_expand(fn, hi_start=None, size=1)
                log_mu = float(_host_bisect(
                    fn, np.full(1, _LOG_PRICE_LO), hi, iters=60,
                    endpoint="hi")[0])
                mu_need = True

        lm = jnp.asarray(log_mu, jnp.float64)
        mn = jnp.asarray(mu_need)
        evals = [progs.eval(g.fleet, t[0], t[1], t[2], t[4], p[0], g.w, dl,
                            ep, lm, mn)
                 for g, t, p, dl, ep in zip(groups, tabs, preps, dls, epss,
                                            strict=True)]
        b_total = sum(float(ev[7]) for ev in evals)
        return b_total - B_host, evals, (log_mu, mu_need)

    need_price = solve_at(0.0, False)[0] > 0.0
    fn = lambda x: np.asarray([solve_at(float(x[0]), True)[0]])
    hi, _ = _host_expand(fn, hi_start=None, size=1)  # cold, as plan_optimal
    log_lam = float(_host_bisect(fn, np.full(1, _LOG_PRICE_LO), hi,
                                 iters=60)[0])
    _, evals, (log_mu, mu_need) = solve_at(log_lam, need_price)

    cat = lambda i: jnp.concatenate(
        [ev[i][:g.n] for ev, g in zip(evals, groups, strict=True)])
    m_sel, b, f = cat(0), cat(1), cat(2)
    e_loc, e_off, feas, margins = cat(3), cat(4), cat(5), cat(6)
    occ_total = sum(float(ev[8]) for ev in evals)
    # primal capacity check at the rounded discrete selection
    feas = feas & (occ_total <= cap_host * (1.0 + _EDGE_CAP_RTOL))

    lam = jnp.where(jnp.asarray(bool(need_price)),
                    10.0 ** jnp.asarray(log_lam, jnp.float64), 0.0)
    mu = jnp.where(jnp.asarray(mu_need),
                   10.0 ** jnp.asarray(log_mu, jnp.float64) * _MU_SAFETY, 0.0)
    alloc = Allocation(b=b, f=f, e_loc=e_loc, e_off=e_off, feasible=feas,
                       lam=lam, mu=mu)
    total_energy = jnp.sum(alloc.energy)
    n = int(m_sel.shape[0])
    return Plan(
        m_sel=m_sel,
        alloc=alloc,
        total_energy=total_energy,
        feasible=feas,
        objective_trace=total_energy[None],
        pccp_iters=jnp.ones((1, n), jnp.int32),
        margins=margins,
        status=_traced_status(alloc, total_energy, margins),
        assignment=jnp.zeros((n,), jnp.int32),
    )


# ---------------------------------------------------------------------------
# Entry point
# ---------------------------------------------------------------------------

def _resolve_starts(spec: FleetSpec, init_m, multi_start: bool):  # analyze: ok(TRC001,TRC002,TRC003): scalar start resolution on concrete host ints
    """Per-group (S,) start vectors replicating ``planner.initial_points``
    on the monolithic padded fleet: the spread is derived from the padded
    width ``spec.max_points`` and clamped to each group's own chain."""
    m1 = spec.max_points
    if multi_start and init_m is None:
        starts = default_starts(m1)
    elif init_m is None:
        starts = [m1 - 1]
    else:
        if not isinstance(init_m, (int, np.integer)):
            raise TypeError(
                "plan_sharded resolves starts per group and supports only "
                f"scalar init_m (or None), got {type(init_m).__name__}; use "
                "Planner.plan for per-device warm-start arrays")
        if not 0 <= int(init_m) <= m1 - 1:
            raise ValueError(
                f"init_m must lie in [0, {m1 - 1}] (partition points 0..M "
                f"for a {m1 - 1}-block chain); got {init_m!r}")
        starts = [int(init_m)]
    starts = np.asarray(starts, np.int32)
    return [np.minimum(starts, g.chain.num_points - 1) for g in spec.groups]


def plan_sharded(spec: FleetSpec, scenario, config, *, key=None, gains=None,  # analyze: ok(TRC001,TRC002,TRC003): host-level orchestrator entry point by design
                 mesh=None, init_m: Optional[int] = None) -> Plan:
    """Plan a (possibly huge) mixed fleet through the group decomposition.

    Takes the :class:`FleetSpec` — the grouping truth — rather than a
    built ``Fleet``: the padded monolithic fleet is never materialized.
    Gains are sampled once fleet-wide (``spec.sample_gains(key)``, the
    same sequence ``spec.build(key)`` would use) or passed explicitly as
    a fleet-order ``(N,)`` array, then sliced per group.

    ``config`` is a ``PlannerConfig``; its statics select the compiled
    per-group programs. Differences from ``Planner.plan``: ``init_m``
    must be a scalar (per-device warm-start arrays stay on the monolithic
    path), and there is no host fail-soft ladder — ``Plan.status`` still
    carries the traced OK/DEGRADED stamp for the caller to act on.
    """
    policy = get_policy(config.policy)
    if getattr(config, "edge_eps", None) is not None:
        raise NotImplementedError(
            "plan_sharded does not support the Cantelli edge_eps occupancy "
            "row yet — plan monolithically (Planner.plan) for "
            "chance-constrained edge capacity")
    if mesh is None:
        mesh = planner_mesh()
    if gains is None:
        if key is None:
            raise ValueError("plan_sharded needs a PRNG key (to place "
                             "devices) or explicit link gains")
        gains = spec.sample_gains(key)
    sc = scenario.normalized(spec.num_devices)
    groups = build_groups(spec, gains, mesh)

    if policy.solve is not None:
        if init_m is not None or config.init_m is not None:
            raise ValueError(
                f"policy {policy.name!r} solves exactly (no alternation), "
                "so init_m warm starts have no effect — drop init_m or pick "
                "an alternating policy")
        return _plan_optimal_sharded(groups, sc, policy, mesh)

    if init_m is None:
        init_m = config.init_m
    m0_groups = _resolve_starts(spec, init_m, config.multi_start)
    S = int(m0_groups[0].shape[0])
    programs = _group_programs(
        mesh, policy, int(config.pccp_iters), str(config.solver),
        bool(config.pccp_gated), float(config.channel_cv))
    return _plan_groups(groups, sc, policy, int(config.outer_iters),
                        m0_groups, S, programs, float(config.channel_cv),
                        mesh)
