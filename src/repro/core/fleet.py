"""Fleet builder layer: compose heterogeneous device groups into one
padded :class:`~repro.core.blocks.Fleet` (DESIGN.md §fleet).

The paper plans one DNN over N identical devices; the production regime
is *mixed* populations — different models, different numbers of partition
points ``M_n``, different compute platforms — sharing one uplink
bandwidth budget. :class:`DeviceSpec` describes one homogeneous group
(a chain — hand-measured or derived from a zoo ``ModelConfig`` via
``DeviceSpec.from_model`` — plus DVFS platform and radio parameters);
:class:`FleetSpec` stacks groups, pads every chain to the fleet-wide
``max(M_n)+1`` points, and emits the ragged ``Fleet`` with its ``valid``
mask and per-device ``num_points``.

This is the single tiling implementation: ``blocks.broadcast_fleet`` and
``serve.partitioned`` deployments both route through it.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.blocks import BlockChain, Fleet, Link, Platform, pad_chain
from repro.core.channel import pathloss_gain

__all__ = ["DeviceSpec", "FleetSpec"]


def _f64(v):
    return jnp.asarray(v, jnp.float64)


@dataclass(frozen=True, eq=False)
class DeviceSpec:
    """One homogeneous device group: ``count`` devices running the same
    chain on the same platform class.

    ``chain`` leaves are per-point ``(M_g+1,)`` arrays; link gains are
    per-device and supplied (or sampled) by ``FleetSpec.build``.
    """

    chain: BlockChain
    kappa: float = 2.8e-27  # W / (cycle/s)^3
    f_min_hz: float = 0.2e9
    f_max_hz: float = 1.4e9
    p_tx_w: float = 1.0
    count: int = 1
    name: str = "device"

    def __post_init__(self):
        if self.count < 1:
            raise ValueError(f"DeviceSpec.count must be >= 1, got {self.count}")

    @classmethod
    def from_model(
        cls,
        cfg,
        *,
        count: int = 1,
        num_blocks: int = 8,
        batch: int = 1,
        seq_len: int = 256,
        device=None,
        edge=None,
        kappa: float = 2.8e-27,
        f_min_hz: float = 0.2e9,
        f_max_hz: float = 1.4e9,
        p_tx_w: float = 1.0,
        seed: int = 0,
        vm_time_scale: float = 1.0,
        name: Optional[str] = None,
    ) -> "DeviceSpec":
        """Build a group from a zoo ``ModelConfig`` via the analytic cost
        model (``models.costmodel``). ``device``/``edge`` are
        ``TierProfile``s (defaulting to the costmodel tiers).

        .. deprecated::
            ``vm_time_scale`` statically bakes shared-edge contention into
            the chain (mean × s, variance × s²) — it overcharges lightly
            loaded plans and ignores that occupancy depends on the chosen
            partition points. Price the shared edge instead with
            ``Scenario.edge_capacity_s`` (DESIGN.md §edge); the scale is
            kept only as a comparison baseline for static provisioning.
        """
        # deferred import: core.fleet is imported by repro.core's __init__,
        # models.costmodel imports core.blocks — keep the layering acyclic.
        from repro.models.costmodel import (
            DEVICE_TIER,
            EDGE_TIER,
            block_chain_from_config,
        )

        chain = block_chain_from_config(
            cfg, batch=batch, seq_len=seq_len, num_blocks=num_blocks,
            device=DEVICE_TIER if device is None else device,
            edge=EDGE_TIER if edge is None else edge,
            f_mid_hz=0.5 * (f_min_hz + f_max_hz), seed=seed,
        )
        if vm_time_scale != 1.0:  # analyze: ok(TRC003): builder-time deprecation check on a concrete float
            import warnings

            warnings.warn(
                "vm_time_scale is deprecated: it statically scales VM time "
                "instead of pricing the shared edge — use "
                "Scenario.edge_capacity_s (DESIGN.md §edge)",
                DeprecationWarning, stacklevel=2)
            chain = chain._replace(t_vm=chain.t_vm * vm_time_scale,
                                   v_vm=chain.v_vm * vm_time_scale**2)
        return cls(chain=chain, kappa=kappa, f_min_hz=f_min_hz,
                   f_max_hz=f_max_hz, p_tx_w=p_tx_w, count=count,
                   name=name if name is not None else getattr(cfg, "name", "device"))


@dataclass(frozen=True, eq=False)
class FleetSpec:
    """An ordered composition of :class:`DeviceSpec` groups.

    ``build`` emits the padded ragged ``Fleet``: group g's devices occupy
    the contiguous index range ``slice(*group_slices[g])``, chains are
    padded to ``max_points`` with the terminal-point repeat of
    ``blocks.pad_chain``, and ``valid``/``num_points`` record the real
    per-device widths. A single-group spec builds a homogeneous fleet
    whose mask is all-valid — leaf-identical to the legacy tiling.
    """

    groups: Tuple[DeviceSpec, ...]
    area_m: float = 400.0  # device positions uniform in a square (§VI-A)
    min_dist_m: float = 5.0

    def __post_init__(self):
        if not self.groups:
            raise ValueError("FleetSpec needs at least one DeviceSpec group")
        object.__setattr__(self, "groups", tuple(self.groups))

    @property
    def num_devices(self) -> int:
        return sum(g.count for g in self.groups)

    @property
    def max_points(self) -> int:
        return max(g.chain.num_points for g in self.groups)

    def group_slices(self) -> list:
        """Per-group (start, stop) device-index ranges."""
        out, start = [], 0
        for g in self.groups:
            out.append((start, start + g.count))
            start += g.count
        return out

    def device_names(self) -> list:
        """(N,) group name per device (reporting/validation labels)."""
        return [g.name for g in self.groups for _ in range(g.count)]

    def sample_gains(self, key) -> jnp.ndarray:
        """(N,) link gains from device positions sampled uniformly in the
        ``area_m`` square (the §VI-A scenario; distance floored at
        ``min_dist_m``).

        The ONE sampling implementation: ``build`` routes through it, and
        the group-sharded planner (``core.decompose``) calls it up front
        and *slices* the result per group — so a sharded plan sees exactly
        the gains the monolithic ``build(key)`` fleet would, which is what
        makes the two paths value-comparable at the same key.
        """
        n = self.num_devices
        xy = jax.random.uniform(key, (n, 2), jnp.float64,
                                -self.area_m / 2, self.area_m / 2)
        r = jnp.maximum(jnp.linalg.norm(xy, axis=-1), self.min_dist_m)
        return pathloss_gain(r)

    def build(self, key=None, *, gains=None, p_tx=None) -> Fleet:
        """Materialize the padded ``Fleet``.

        Link gains come from ``gains`` (explicit per-device array) or from
        device positions sampled with ``key`` (``sample_gains``). ``p_tx``
        optionally overrides the per-group transmit powers with a
        per-device array.
        """
        n, mp = self.num_devices, self.max_points
        if gains is None:
            if key is None:
                raise ValueError("FleetSpec.build needs a PRNG key (to place "
                                 "devices) or explicit link gains")
            gains = self.sample_gains(key)
        else:
            gains = _f64(gains)
            if gains.shape != (n,):
                raise ValueError(
                    f"gains must be ({n},) for this {n}-device spec, "
                    f"got shape {gains.shape}")

        def tile(a, count):
            a = _f64(a)
            return jnp.broadcast_to(a, (count,) + a.shape)

        chains, plats, ptxs, valid, npts = [], [], [], [], []
        for g in self.groups:
            padded = pad_chain(g.chain, mp)
            chains.append(BlockChain(*[tile(x, g.count) for x in padded]))
            plats.append(Platform(kappa=tile(g.kappa, g.count),
                                  f_min=tile(g.f_min_hz, g.count),
                                  f_max=tile(g.f_max_hz, g.count)))
            ptxs.append(tile(g.p_tx_w, g.count))
            row = np.zeros(mp, bool)
            row[: g.chain.num_points] = True
            valid.append(np.broadcast_to(row, (g.count, mp)))
            npts.append(np.full(g.count, g.chain.num_points, np.int32))

        cat = lambda parts: jnp.concatenate(parts, axis=0)
        chain = BlockChain(*[cat(xs) for xs in zip(*chains, strict=True)])
        platform = Platform(*[cat(xs) for xs in zip(*plats, strict=True)])
        p_tx = cat(ptxs) if p_tx is None else jnp.broadcast_to(_f64(p_tx), (n,))
        return Fleet(
            chain=chain,
            platform=platform,
            link=Link(p_tx=p_tx, gain=gains),
            valid=jnp.asarray(np.concatenate(valid, axis=0)),
            num_points=jnp.asarray(np.concatenate(npts, axis=0)),
        )
