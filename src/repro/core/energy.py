"""Device energy models (paper §III-B, eqs. (2), (4), (15)).

Local compute: CMOS dynamic power  α·c·V²·f with V ∝ f in the non-low
frequency range gives  P = κ·f³, so the energy of the local prefix is
``e_loc = κ·f³·t_loc``. With the mean time model t̄_loc = w/(g·f) (eq. 10),
the *expected* local energy is  κ·(w/g)·f²  — eq. (15).
"""
from __future__ import annotations

import jax.numpy as jnp


def local_power(kappa, f):
    return kappa * f**3


def local_energy(kappa, f, t_loc):
    """e_loc = κ f³ t_loc (eq. (2))."""
    return kappa * f**3 * t_loc


def expected_local_energy(kappa, w_flops, g_eff, f):
    """E[e_loc] = κ (w/g) f² (the first term of eq. (15))."""
    return kappa * (w_flops / jnp.maximum(g_eff, 1e-30)) * f**2


def mean_local_time(w_flops, g_eff, f):
    """t̄_loc = w/(g·f) (eq. (10))."""
    return w_flops / (jnp.maximum(g_eff, 1e-30) * jnp.maximum(f, 1.0))
