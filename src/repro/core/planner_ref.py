"""Straight-line port of the seed Algorithm-2 planner loop.

This is the *unfused* reference: the outer alternation is a Python loop
with per-iteration jit dispatches, the multi-start spread is sequential
with ``float(...)`` host syncs in the scoring — exactly the structure the
seed ``plan()`` had before the scan/vmap fusion (DESIGN.md §planner).

It exists for two reasons:

1. **Golden pinning** — ``tests/test_plan_golden.py`` asserts the fused
   planner reproduces this loop's ``m_sel`` exactly and its energies to
   1e-8 rtol across policies and paper-table configs.
2. **Speedup accounting** — ``benchmarks/bench_runtime.py`` times it
   against the fused path so the dispatch-overhead win is tracked across
   PRs (Fig. 11 runtime claim).

It shares every numerical building block (``allocate``, ``pccp_partition``,
``policy_point_tables``, ``_exact_partition``) with the fused planner, so
any divergence isolates the fusion restructuring itself.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import ccp, channel, energy
from repro.core.blocks import Fleet
from repro.core.pccp import pccp_partition
from repro.core.planner import (
    Plan,
    _exact_partition,
    _traced_status,
    default_starts,
    get_policy,
    policy_point_tables,
)
from repro.core.resource import allocate, select_point


def plan_reference(
    fleet: Fleet,
    deadline: jnp.ndarray,
    eps: jnp.ndarray,
    B: float,
    policy: str = "robust",
    outer_iters: int = 6,
    init_m: Optional[jnp.ndarray] = None,
    pccp_iters: int = 10,
    multi_start: bool = True,
    channel_cv: float = 0.0,
    pccp_schedule: tuple | None = None,
    solver: str = "structured",
) -> Plan:
    """Seed-loop Algorithm 2: Python outer loop, sequential multi-start.

    ``pccp_schedule`` overrides the inner barrier schedule — pass
    ``pccp.SEED_SCHEDULE`` to reproduce the seed's full inner-solver cost
    (the default shares the tuned schedule with the fused planner so
    golden comparisons are bit-exact). ``solver`` picks the inner barrier
    path; pass ``"dense"`` (with the seed schedule) to reproduce the
    seed's generic autodiff solver for speedup accounting.
    """
    if multi_start and init_m is None:
        plans = [
            plan_reference(fleet, deadline, eps, B, policy, outer_iters,
                           jnp.int32(s), pccp_iters, multi_start=False,
                           channel_cv=channel_cv, pccp_schedule=pccp_schedule,
                           solver=solver)
            for s in default_starts(fleet.max_points)
        ]

        def score(p: Plan):
            # feasible plans first, then lowest energy
            return (float(jnp.sum(~p.feasible)), float(p.total_energy))

        return min(plans, key=score)

    n, m1 = fleet.num_devices, fleet.max_points
    deadline = jnp.broadcast_to(jnp.asarray(deadline, jnp.float64), (n,))
    eps = jnp.broadcast_to(jnp.asarray(eps, jnp.float64), (n,))
    pol = get_policy(policy)
    sig_model, ub_k = pol.sigma_model, pol.ub_k
    sigma = ccp.SIGMA_FNS[sig_model](eps)

    m = (
        jnp.full((n,), m1 - 1, jnp.int32)
        if init_m is None
        else jnp.broadcast_to(jnp.asarray(init_m, jnp.int32), (n,))
    )
    if fleet.num_points is not None:  # ragged fleet: clamp starts to M_n
        m = jnp.minimum(m, fleet.num_points - 1)

    traces, pccp_trace = [], []
    feasible = jnp.ones((n,), bool)
    alloc = None
    for _ in range(outer_iters):
        alloc = allocate(fleet, m, deadline, eps, B, sig_model, ub_k, channel_cv)
        e_table, t_table, var_table = policy_point_tables(
            fleet, alloc.b, alloc.f, pol, channel_cv)
        if policy == "robust":
            x_init = jax.nn.one_hot(m, m1, dtype=jnp.float64)
            pccp_kw = {} if pccp_schedule is None else {"schedule": pccp_schedule}
            res = pccp_partition(
                e_table, t_table, var_table, sigma, deadline, x_init,
                num_iters=pccp_iters, solver=solver, **pccp_kw
            )
            m, feasible = res.m_sel, res.feasible
            pccp_trace.append(res.iters_to_converge)
        else:  # robust_exact / gaussian / worst_case → exact enumeration
            m, feasible = _exact_partition(e_table, t_table, var_table, sigma, deadline)
            pccp_trace.append(jnp.ones((n,), jnp.int32))
        obj = jnp.sum(jnp.take_along_axis(e_table, m[:, None], -1)[:, 0])
        traces.append(obj)

    alloc = allocate(fleet, m, deadline, eps, B, sig_model, ub_k, channel_cv)
    sel = select_point(fleet, m)
    t_mean = (
        energy.mean_local_time(sel.w_flops, sel.g_eff, alloc.f)
        + channel.offload_time(sel.d_bits, alloc.b, fleet.link.p_tx, fleet.link.gain)
        + sel.t_vm
    )
    margins = ccp.deterministic_deadline_margin(
        t_mean, sel.v_loc + sel.v_vm, eps, deadline, sig_model
    )
    total_energy = jnp.sum(alloc.energy)
    return Plan(
        m_sel=m,
        alloc=alloc,
        total_energy=total_energy,
        feasible=feasible & alloc.feasible,
        objective_trace=jnp.stack(traces),
        pccp_iters=jnp.stack(pccp_trace),
        margins=margins,
        status=_traced_status(alloc, total_energy, margins),
    )
