"""Synthetic sharded token pipeline.

Deterministic Zipf-ish token stream generated on the fly (offline
container: no downloads) with a structure that gives a learnable
next-token signal: Markov bigram chains with a per-document seed, so a
~100M model visibly drops below the unigram entropy within a few hundred
steps (examples/train_small.py).

Batches are dicts matching ``repro.models.transformer`` conventions:
tokens (B, S) int32, labels (B, S) int32 (next token, −100-style masking
uses label −1), plus modality-stub embeddings for audio/vlm configs.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig


@dataclass
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    markov_states: int = 64


class SyntheticTokens:
    """Deterministic, stateless-indexable synthetic corpus."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        v, m = cfg.vocab_size, cfg.markov_states
        # sparse bigram transition structure: each "state" prefers a few tokens
        self._emit = rng.integers(0, v, size=(m, 8), dtype=np.int64)
        self._next_state = rng.integers(0, m, size=(m, 8), dtype=np.int64)

    def batch(self, step: int) -> np.ndarray:
        cfg = self.cfg
        rng = np.random.default_rng(cfg.seed * 1_000_003 + step)
        b, s = cfg.global_batch, cfg.seq_len
        state = rng.integers(0, cfg.markov_states, size=(b,))
        out = np.empty((b, s + 1), dtype=np.int32)
        for t in range(s + 1):
            choice = rng.integers(0, 8, size=(b,))
            out[:, t] = self._emit[state, choice]
            state = self._next_state[state, choice]
        return out


def make_batch(cfg: ModelConfig, data: SyntheticTokens, step: int,
               dtype=jnp.float32) -> Dict[str, jnp.ndarray]:
    raw = data.batch(step)
    tokens = jnp.asarray(raw[:, :-1] % cfg.vocab_size, jnp.int32)
    labels = jnp.asarray(raw[:, 1:] % cfg.vocab_size, jnp.int32)
    batch = {"tokens": tokens, "labels": labels}
    b, s = tokens.shape
    key = jax.random.PRNGKey(step)
    if cfg.audio_stub:
        batch["frames"] = jax.random.normal(key, (b, max(s // 4, 1), cfg.d_model), dtype)
    if cfg.vlm_stub:
        batch["patches"] = jax.random.normal(key, (b, cfg.num_patches, cfg.vision_dim), dtype)
    return batch


def data_iterator(cfg: ModelConfig, dcfg: DataConfig, start_step: int = 0) -> Iterator[Dict]:
    data = SyntheticTokens(dcfg)
    step = start_step
    while True:
        yield make_batch(cfg, data, step)
        step += 1
