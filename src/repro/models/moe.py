"""Mixture-of-Experts layer (DeepSeek-style: shared + routed, top-k).

Dispatch is capacity-based (GShard/Switch lineage) and implemented with a
sort → padded per-expert blocks → batched matmul pipeline, which shards
cleanly over an expert axis and keeps HLO FLOPs ≈ active FLOPs
(overprovisioned by ``capacity_factor``). Tokens overflowing an expert's
capacity are dropped (standard); the router carries a load-balance loss.

An alternative ``dispatch="dense"`` path (one-hot einsum over all experts)
exists for tiny smoke configs and as the naive baseline in the §Perf
hillclimb; it is O(E) compute and must not be used at scale.
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import _act, dense_init, mlp_apply, mlp_init
from repro.parallel.sharding import constrain


def constrain_expert_batched(x):
    """(B, E, C, D) dispatch blocks — mirror the *weight* expert sharding
    (§Perf iteration B1): when E divides the full (fsdp×model) product the
    weights are 256-way expert-parallel, so the blocks must be too (B
    replicated → GSPMD emits the canonical MoE all-to-all); otherwise E
    rides the model axis and B keeps fsdp."""
    from repro.parallel.sharding import activation_mesh, fsdp_axes

    mesh = activation_mesh()
    if mesh is None:
        return x
    fs = fsdp_axes(mesh)
    full = 1
    for a in tuple(fs) + ("model",):
        full *= mesh.shape[a]
    e = x.shape[1]
    if e % full == 0 and e >= full:
        import jax
        from jax.sharding import NamedSharding, PartitionSpec as P

        spec = P(None, tuple(fs) + ("model",), None, None)
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
    return constrain(x, ("fsdp", "model", None, None))


def moe_init(key, d_model, num_experts, d_ff_expert, num_shared, d_ff_shared, dtype) -> Dict:
    kr, k1, k2, k3, ks = jax.random.split(key, 5)
    s_in = d_model**-0.5
    s_out = d_ff_expert**-0.5
    p = {
        "router": dense_init(kr, (d_model, num_experts), dtype=jnp.float32),
        "w1": (jax.random.normal(k1, (num_experts, d_model, d_ff_expert)) * s_in).astype(dtype),
        "w2": (jax.random.normal(k2, (num_experts, d_ff_expert, d_model)) * s_out).astype(dtype),
        "w3": (jax.random.normal(k3, (num_experts, d_model, d_ff_expert)) * s_in).astype(dtype),
    }
    if num_shared > 0:
        p["shared"] = mlp_init(ks, d_model, d_ff_shared, gated=True, dtype=dtype)
    return p


def router_probs(p, x):
    logits = (x.astype(jnp.float32) @ p["router"]).astype(jnp.float32)
    return jax.nn.softmax(logits, axis=-1)


def load_balance_loss(probs, top_idx, num_experts):
    """Switch-style aux loss: E · Σ_e f_e · P_e."""
    t = probs.shape[0]
    onehot = jax.nn.one_hot(top_idx, num_experts, dtype=jnp.float32)  # (t, k, E)
    f = onehot.sum(axis=(0, 1)) / jnp.maximum(top_idx.size, 1)
    pbar = probs.mean(axis=0)
    return num_experts * jnp.sum(f * pbar)


def _capacity(tokens: int, top_k: int, num_experts: int, factor: float) -> int:
    c = int(math.ceil(tokens * top_k / num_experts * factor))
    return max(c, 4)


def _local_dispatch(xt, top_i, top_w, e: int, cap: int):
    """Capacity scatter of one device's tokens into (E·cap+1, D) slots.

    Returns (buf, dest, tok, w_sorted, keep) — shared by the GSPMD row-wise
    path (vmapped over rows) and the shard_map a2a path (per device).
    """
    t, k = top_i.shape
    d = xt.shape[-1]
    sk = t * k
    flat_e = top_i.reshape(sk)
    flat_w = top_w.reshape(sk)
    order = jnp.argsort(flat_e)
    sorted_e = flat_e[order]
    first = jnp.searchsorted(sorted_e, sorted_e, side="left")
    pos = jnp.arange(sk) - first
    keep = pos < cap
    dest = jnp.where(keep, sorted_e * cap + pos, e * cap)
    tok = order // k
    buf = jnp.zeros((e * cap + 1, d), xt.dtype).at[dest].set(xt[tok])
    w_sorted = (flat_w[order] * keep)
    return buf, dest, tok, w_sorted


def moe_apply_a2a(p: Dict, x, *, top_k: int, activation: str,
                  capacity_factor: float):
    """Expert-parallel MoE with an explicit all-to-all (shard_map).

    §Perf iteration B2: GSPMD cannot infer token-exchange from a scatter
    formulation — it either reshards the expert weights every layer
    (baseline) or replicates the token batch (B1, refuted). This is the
    production pattern: tokens stay sharded (batch over fsdp, sequence
    over model), each device scatters its own tokens into per-expert-home
    capacity slots, ONE all-to-all ships them to the expert homes, dense
    local matmuls run, one all-to-all ships results back.

    Returns None when the layout prerequisites don't hold (caller falls
    back to the GSPMD row-wise path) — e.g. decode steps with seq 1.
    """
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    from repro.parallel.sharding import (
        activation_mesh, expert_axis_candidates, fsdp_axes)

    mesh = activation_mesh()
    if mesh is None or x.ndim != 3:
        return None
    bsz, s, d = x.shape
    e = p["w1"].shape[0]
    fs = fsdp_axes(mesh)
    fsdp_sz = 1
    for a in fs:
        fsdp_sz *= mesh.shape[a]
    model_sz = mesh.shape["model"]
    ex_axes = None
    for cand in expert_axis_candidates(mesh):
        sz = 1
        for a in cand:
            sz *= mesh.shape[a]
        if sz > 1 and e % sz == 0:
            ex_axes = cand
            g = sz
            break
    if ex_axes is None or bsz % fsdp_sz or s % model_sz:
        return None
    eph = e // g
    t_local = (bsz // fsdp_sz) * (s // model_sz)
    cap = _capacity(t_local, top_k, e, capacity_factor)
    act = _act(activation)
    fsdp_entry = fs if len(fs) > 1 else fs[0]
    ex_entry = ex_axes if len(ex_axes) > 1 else ex_axes[0]
    all_axes = tuple(mesh.axis_names)

    def local_fn(xl, router, w1, w2, w3):
        xt = xl.reshape(t_local, d)
        probs = jax.nn.softmax((xt.astype(jnp.float32) @ router), axis=-1)
        top_w, top_i = jax.lax.top_k(probs, top_k)
        top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)
        aux = load_balance_loss(probs, top_i, e)
        aux = jax.lax.pmean(aux, all_axes)

        buf, dest, tok, w_sorted = _local_dispatch(xt, top_i, top_w, e, cap)
        send = buf[: e * cap].reshape(g, eph * cap, d)
        recv = jax.lax.all_to_all(send, ex_axes, split_axis=0, concat_axis=0,
                                  tiled=True)  # (g_src, eph·cap, d)
        blocks = recv.reshape(g, eph, cap, d).transpose(1, 0, 2, 3)
        blocks = blocks.reshape(eph, g * cap, d)
        h = jnp.einsum("egd,edf->egf", blocks, w1)
        h = act(h) * jnp.einsum("egd,edf->egf", blocks, w3)
        y = jnp.einsum("egf,efd->egd", h, w2)
        y = y.reshape(eph, g, cap, d).transpose(1, 0, 2, 3).reshape(g, eph * cap, d)
        back = jax.lax.all_to_all(y, ex_axes, split_axis=0, concat_axis=0,
                                  tiled=True).reshape(e * cap, d)
        back = jnp.concatenate([back, jnp.zeros((1, d), back.dtype)], axis=0)
        contrib = back[dest] * w_sorted[:, None].astype(back.dtype)
        out = jnp.zeros((t_local, d), xl.dtype).at[tok].add(contrib)
        return out.reshape(xl.shape), aux

    out, aux = shard_map(
        local_fn,
        mesh=mesh,
        in_specs=(P(fsdp_entry, "model", None), P(None, None),
                  P(ex_entry, None, None), P(ex_entry, None, None),
                  P(ex_entry, None, None)),
        out_specs=(P(fsdp_entry, "model", None), P()),
    )(x, p["router"], p["w1"], p["w2"], p["w3"])
    return out, aux


def moe_apply(
    p: Dict,
    x,  # (B, S, D) or (T, D)
    *,
    top_k: int,
    activation: str = "swiglu",
    capacity_factor: float = 1.25,
    dispatch: str = "auto",
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (output, aux_loss). dispatch: auto | capacity | a2a | dense."""
    if dispatch in ("auto", "a2a"):
        routed = moe_apply_a2a(p, x, top_k=top_k, activation=activation,
                               capacity_factor=capacity_factor)
        if routed is not None:
            out, aux = routed
            if "shared" in p:
                out = out + mlp_apply(p["shared"], x, activation)
            return out, aux
        if dispatch == "a2a":
            raise ValueError("a2a dispatch prerequisites not met")
        dispatch = "capacity"
    shape = x.shape
    d = shape[-1]
    xt = x.reshape(-1, d)
    t = xt.shape[0]
    e = p["w1"].shape[0]

    probs = router_probs(p, xt)  # (T, E) f32
    top_w, top_i = jax.lax.top_k(probs, top_k)  # (T, k)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)
    aux = load_balance_loss(probs, top_i, e)

    if dispatch == "dense":
        gates = jnp.zeros((t, e), jnp.float32)
        gates = gates.at[jnp.arange(t)[:, None], top_i].set(top_w)
        h = jnp.einsum("td,edf->tef", xt, p["w1"])
        h = _act(activation)(h) * jnp.einsum("td,edf->tef", xt, p["w3"])
        y = jnp.einsum("tef,efd->ted", h, p["w2"])
        out = jnp.einsum("ted,te->td", y, gates.astype(y.dtype))
        out = out.reshape(shape)
    else:
        # Row-wise (per-sequence) capacity dispatch: every op below is
        # batched over the (sharded) batch axis — no global sort, so GSPMD
        # never gathers the full token set. Expert blocks are (B, E, C, D)
        # with B on fsdp and E on the model axis.
        bsz = shape[0] if len(shape) == 3 else 1
        s = t // bsz
        xb = xt.reshape(bsz, s, d)
        k = top_k
        sk = s * k
        cap = _capacity(s, k, e, capacity_factor)
        flat_e = top_i.reshape(bsz, sk)
        flat_w = top_w.reshape(bsz, sk)
        order = jnp.argsort(flat_e, axis=1)
        sorted_e = jnp.take_along_axis(flat_e, order, axis=1)
        # position within each expert's block: index − first occurrence
        first = jax.vmap(lambda a: jnp.searchsorted(a, a, side="left"))(sorted_e)
        pos = jnp.arange(sk)[None, :] - first
        keep = pos < cap
        dest = jnp.where(keep, sorted_e * cap + pos, e * cap)  # overflow slot
        tok = order // k  # (B, Sk) source token within the row
        xg = jnp.take_along_axis(xb, tok[..., None], axis=1)  # (B, Sk, D)
        buf = jnp.zeros((bsz, e * cap + 1, d), x.dtype)
        buf = jax.vmap(lambda b, dd, v: b.at[dd].set(v))(buf, dest, xg)
        blocks = buf[:, : e * cap].reshape(bsz, e, cap, d)
        blocks = constrain_expert_batched(blocks)
        h = jnp.einsum("becd,edf->becf", blocks, p["w1"])
        h = _act(activation)(h) * jnp.einsum("becd,edf->becf", blocks, p["w3"])
        y = jnp.einsum("becf,efd->becd", h, p["w2"]).reshape(bsz, e * cap, d)
        y = jnp.concatenate([y, jnp.zeros((bsz, 1, d), y.dtype)], axis=1)
        gathered = jnp.take_along_axis(y, dest[..., None], axis=1)  # (B, Sk, D)
        w_sorted = (jnp.take_along_axis(flat_w, order, axis=1) * keep).astype(y.dtype)
        contrib = gathered * w_sorted[..., None]
        out = jnp.zeros((bsz, s, d), x.dtype)
        out = jax.vmap(lambda o, tt, c: o.at[tt].add(c))(out, tok, contrib)
        out = out.reshape(shape)

    if "shared" in p:
        out = out + mlp_apply(p["shared"], x, activation)
    return out, aux
