"""Shared building blocks: norms, activations, RoPE, MLPs, init helpers."""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp


def rms_norm(x, scale, eps: float = 1e-5):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(dtype)


def _act(name: str):
    if name == "swiglu" or name == "silu":
        return jax.nn.silu
    if name == "gelu":
        return jax.nn.gelu
    if name == "sqrelu":  # squared ReLU (Nemotron-4 / Minitron)
        return lambda x: jnp.square(jax.nn.relu(x))
    raise ValueError(f"unknown activation {name!r}")


def mlp_apply(p: Dict, x, activation: str):
    """Gated (swiglu) or plain 2-matrix MLP depending on params present."""
    act = _act(activation)
    if "w3" in p:  # gated: act(x@w1) * (x@w3) @ w2
        h = act(x @ p["w1"]) * (x @ p["w3"])
    else:
        h = act(x @ p["w1"])
    return h @ p["w2"]


def mlp_init(key, d_model: int, d_ff: int, gated: bool, dtype) -> Dict:
    k1, k2, k3 = jax.random.split(key, 3)
    s_in = d_model**-0.5
    s_out = d_ff**-0.5
    p = {
        "w1": (jax.random.normal(k1, (d_model, d_ff)) * s_in).astype(dtype),
        "w2": (jax.random.normal(k2, (d_ff, d_model)) * s_out).astype(dtype),
    }
    if gated:
        p["w3"] = (jax.random.normal(k3, (d_model, d_ff)) * s_in).astype(dtype)
    return p


def rope_freqs(head_dim: int, theta: float):
    return theta ** (-jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)


def apply_rope(x, positions, theta: float = 1e4):
    """x: (..., S, H, Dh) rotated pairwise; positions: (..., S)."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)  # (Dh/2,)
    angles = positions[..., :, None, None].astype(jnp.float32) * freqs  # (...,S,1,Dh/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def dense_init(key, shape, fan_in=None, dtype=jnp.float32):
    fan_in = shape[0] if fan_in is None else fan_in
    return (jax.random.normal(key, shape) * fan_in**-0.5).astype(dtype)


def embed_init(key, vocab: int, d_model: int, dtype):
    return (jax.random.normal(key, (vocab, d_model)) * 0.02).astype(dtype)
