"""Generic multi-family transformer: init / train / prefill / decode.

One code path covers all 10 assigned architectures through ModelConfig
flags: dense GQA, MoE(+MLA), SSM (Mamba-2), hybrid (attn‖SSM), encoder-
decoder (audio stub) and VLM (vision stub). Layers are stacked and applied
with ``jax.lax.scan`` so HLO size / compile time stay bounded at 61 layers.

Conventions
-----------
- Parameters: a pytree of dicts; per-layer leaves carry a leading L axis.
- ``batch`` dicts: {"tokens", "labels"} (+"frames" for audio, "patches"
  for vlm). Labels < 0 are masked out of the loss.
- Decode uses ring-buffer caches (see attention.py / mla.py / ssm.py)
  stacked over layers.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import mla as mla_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import dense_init, embed_init, mlp_apply, mlp_init, rms_norm

Params = Dict[str, Any]


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------

def _gated(cfg: ModelConfig) -> bool:
    return cfg.activation == "swiglu"


def _init_layer(cfg: ModelConfig, key, dtype, kind: str = "decoder") -> Params:
    """kind: decoder | encoder | xdecoder (decoder with cross-attention)."""
    keys = jax.random.split(key, 8)
    p: Params = {"ln1": jnp.zeros((cfg.d_model,), dtype)}
    hd = cfg.resolved_head_dim
    if cfg.family == "ssm":
        p["ssm"] = ssm_mod.ssm_init(
            keys[0], cfg.d_model, cfg.ssm_state, cfg.ssm_head_dim, cfg.ssm_expand, dtype=dtype
        )
        return p  # Mamba-2 block: norm + SSD only
    if cfg.hybrid:
        p["ssm"] = ssm_mod.ssm_init(
            keys[0], cfg.d_model, cfg.ssm_state, cfg.ssm_head_dim, cfg.ssm_expand, dtype=dtype
        )
    if cfg.mla:
        p["attn"] = mla_mod.mla_init(
            keys[1], cfg.d_model, cfg.num_heads, hd, cfg.kv_lora_rank,
            cfg.q_lora_rank, cfg.rope_head_dim, dtype,
        )
    else:
        p["attn"] = attn.gqa_init(keys[1], cfg.d_model, cfg.num_heads, cfg.num_kv_heads, hd, dtype)
    if kind == "xdecoder":
        p["cross"] = attn.gqa_init(keys[2], cfg.d_model, cfg.num_heads, cfg.num_heads, hd, dtype)
        p["ln_cross"] = jnp.zeros((cfg.d_model,), dtype)
    p["ln2"] = jnp.zeros((cfg.d_model,), dtype)
    if cfg.moe:
        p["ff"] = moe_mod.moe_init(
            keys[3], cfg.d_model, cfg.num_experts, cfg.d_ff_expert,
            cfg.num_shared_experts, cfg.d_ff, dtype,
        )
    elif cfg.d_ff > 0:
        p["ff"] = mlp_init(keys[3], cfg.d_model, cfg.d_ff, _gated(cfg), dtype)
    return p


def _stacked_layers(cfg: ModelConfig, key, n_layers: int, dtype, kind: str) -> Params:
    keys = jax.random.split(key, n_layers)
    return jax.vmap(lambda k: _init_layer(cfg, k, dtype, kind))(keys)


def init_params(cfg: ModelConfig, key, dtype=jnp.float32) -> Params:
    k_embed, k_layers, k_head, k_extra, k_enc = jax.random.split(key, 5)
    p: Params = {"embed": embed_init(k_embed, cfg.vocab_size, cfg.d_model, dtype)}
    kind = "xdecoder" if cfg.encoder_decoder else "decoder"
    p["layers"] = _stacked_layers(cfg, k_layers, cfg.num_layers, dtype, kind)
    p["final_norm"] = jnp.zeros((cfg.d_model,), dtype)
    p["lm_head"] = dense_init(k_head, (cfg.d_model, cfg.vocab_size), dtype=dtype)
    if cfg.encoder_decoder:
        p["enc_layers"] = _stacked_layers(cfg, k_enc, cfg.num_encoder_layers, dtype, "encoder")
        p["enc_norm"] = jnp.zeros((cfg.d_model,), dtype)
    if cfg.vlm_stub:
        ka, kb = jax.random.split(k_extra)
        p["projector"] = {
            "w1": dense_init(ka, (cfg.vision_dim, cfg.d_model), dtype=dtype),
            "w2": dense_init(kb, (cfg.d_model, cfg.d_model), dtype=dtype),
        }
    if cfg.mtp:
        km1, km2 = jax.random.split(k_extra)
        p["mtp"] = {
            "proj": dense_init(km1, (2 * cfg.d_model, cfg.d_model), dtype=dtype),
            "layer": _init_layer(
                # MTP block is a dense layer even in MoE models
                _dense_like(cfg), km2, dtype, "decoder",
            ),
            "norm": jnp.zeros((cfg.d_model,), dtype),
        }
    return p


def _dense_like(cfg: ModelConfig) -> ModelConfig:
    import dataclasses

    return dataclasses.replace(cfg, moe=False, hybrid=False, d_ff=cfg.d_ff or cfg.d_ff_expert)


def abstract_params(cfg: ModelConfig, dtype=jnp.float32) -> Params:
    """Parameter ShapeDtypeStructs without allocating (dry-run path)."""
    return jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0), dtype))


def param_count(cfg: ModelConfig) -> int:
    tree = abstract_params(cfg)
    import numpy as np

    return int(sum(np.prod(l.shape) for l in jax.tree.leaves(tree)))


def active_param_count(cfg: ModelConfig) -> int:
    """Parameters touched per token (MoE: top-k + shared experts only)."""
    if not cfg.moe:
        return param_count(cfg)
    total = param_count(cfg)
    tree = abstract_params(cfg)
    import numpy as np

    routed = sum(
        int(np.prod(l.shape))
        for name in ("w1", "w2", "w3")
        for l in [tree["layers"]["ff"][name]]
    )
    active = routed * cfg.top_k / cfg.num_experts
    return int(total - routed + active)


# --------------------------------------------------------------------------
# layer application (full sequence)
# --------------------------------------------------------------------------

def _mix_seq(cfg: ModelConfig, p: Params, h, positions, mask):
    """Sequence mixer: attention / SSD / both (hybrid)."""
    outs = []
    if cfg.family == "ssm" or cfg.hybrid:
        outs.append(
            ssm_mod.ssm_apply(
                p["ssm"], h, ssm_state=cfg.ssm_state, head_dim=cfg.ssm_head_dim,
                expand=cfg.ssm_expand, chunk=cfg.ssm_chunk,
            )
        )
    if cfg.family != "ssm":
        hd = cfg.resolved_head_dim
        if cfg.mla:
            a, _ = mla_mod.mla_apply(
                p["attn"], h, num_heads=cfg.num_heads, head_dim=hd,
                rope_head_dim=cfg.rope_head_dim, positions=positions, mask=mask,
                rope_theta=cfg.rope_theta, causal=True, window=cfg.sliding_window,
            )
        else:
            a, _ = attn.gqa_apply(
                p["attn"], h, num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
                head_dim=hd, positions=positions, mask=mask, rope_theta=cfg.rope_theta,
                causal=True, window=cfg.sliding_window,
            )
        outs.append(a)
    return sum(outs) / len(outs)


def _layer_seq(cfg: ModelConfig, p: Params, x, positions, mask, enc_out=None,
               encoder: bool = False):
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    if encoder:  # bidirectional self-attention (whisper encoder)
        a, _ = attn.gqa_apply(
            p["attn"], h, num_heads=cfg.num_heads, num_kv_heads=cfg.num_kv_heads,
            head_dim=cfg.resolved_head_dim, positions=positions, mask=mask,
            rope_theta=cfg.rope_theta, causal=False,
        )
        x = x + a
    else:
        x = x + _mix_seq(cfg, p, h, positions, mask)
    aux = jnp.zeros((), jnp.float32)
    if "cross" in p and enc_out is not None:
        hc = rms_norm(x, p["ln_cross"], cfg.norm_eps)
        t = enc_out.shape[1]
        k = enc_out @ p["cross"]["wk"]
        v = enc_out @ p["cross"]["wv"]
        hd = cfg.resolved_head_dim
        k = k.reshape(k.shape[:2] + (cfg.num_heads, hd))
        v = v.reshape(v.shape[:2] + (cfg.num_heads, hd))
        c, _ = attn.gqa_apply(
            p["cross"], hc, num_heads=cfg.num_heads, num_kv_heads=cfg.num_heads,
            head_dim=hd, positions=positions,
            mask=attn.full_mask(hc.shape[1], t), kv_override=(k, v, None),
            causal=False,
        )
        x = x + c
    if "ff" in p:
        h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
        if cfg.moe:
            ff, aux = moe_mod.moe_apply(p["ff"], h2, top_k=cfg.top_k, activation=cfg.activation,
                                        capacity_factor=cfg.moe_capacity_factor)
        else:
            ff = mlp_apply(p["ff"], h2, cfg.activation)
        x = x + ff
    return x, aux


def _run_stack(cfg: ModelConfig, layers: Params, x, positions, mask, enc_out=None,
               remat: bool = False, encoder: bool = False):
    def body(carry, lp):
        x, aux = carry
        x, a = _layer_seq(cfg, lp, x, positions, mask, enc_out, encoder)
        return (x, aux + a), None

    fn = jax.checkpoint(body) if remat else body
    (x, aux), _ = jax.lax.scan(fn, (x, jnp.zeros((), jnp.float32)), layers)
    return x, aux


# --------------------------------------------------------------------------
# full-sequence forward (train / prefill)
# --------------------------------------------------------------------------

def _embed_tokens(params, tokens):
    return params["embed"][tokens]


def encode_frames(params, cfg: ModelConfig, frames):
    """Whisper encoder over stub frame embeddings (B, S_enc, D)."""
    s = frames.shape[1]
    pos = jnp.arange(s)[None, :]
    x, _ = _run_stack(cfg, params["enc_layers"], frames, pos, attn.full_mask(s, s),
                      encoder=True)
    return rms_norm(x, params["enc_norm"], cfg.norm_eps)


def hidden_states(params, cfg: ModelConfig, batch: Dict, remat: bool = False):
    """Returns (hidden (B,S,D), aux_loss, token_positions)."""
    tokens = batch["tokens"]
    x = _embed_tokens(params, tokens)
    enc_out = None
    if cfg.encoder_decoder:
        enc_out = encode_frames(params, cfg, batch["frames"])
    if cfg.vlm_stub:
        pre = jax.nn.gelu(batch["patches"] @ params["projector"]["w1"]) @ params["projector"]["w2"]
        x = jnp.concatenate([pre.astype(x.dtype), x], axis=1)
    s = x.shape[1]
    positions = jnp.arange(s)[None, :]
    mask = attn.causal_mask(s, cfg.sliding_window)
    x, aux = _run_stack(cfg, params["layers"], x, positions, mask, enc_out, remat)
    if cfg.vlm_stub:
        x = x[:, -tokens.shape[1]:]  # drop image-prefix positions for the LM loss
    return x, aux, positions


def _cross_entropy(logits, labels):
    mask = labels >= 0
    labels = jnp.maximum(labels, 0)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    return -jnp.sum(ll * mask) / jnp.maximum(jnp.sum(mask), 1)


def loss_fn(params, cfg: ModelConfig, batch: Dict, aux_weight: float = 0.01,
            mtp_weight: float = 0.3, remat: bool = False):
    h, aux, _ = hidden_states(params, cfg, batch, remat)
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = h @ params["lm_head"]
    loss = _cross_entropy(logits, batch["labels"])
    metrics = {"ce": loss, "aux": aux}
    if cfg.moe:
        loss = loss + aux_weight * aux
    if cfg.mtp:
        # Depth-1 MTP (DeepSeek-V3): predict token t+2 from (h_t, emb_{t+1}).
        tokens = batch["tokens"]
        hm = jnp.concatenate([h[:, :-1], _embed_tokens(params, tokens[:, 1:])], axis=-1)
        hm = hm @ params["mtp"]["proj"]
        s = hm.shape[1]
        pos = jnp.arange(s)[None, :]
        hm, _ = _layer_seq(_dense_like(cfg), params["mtp"]["layer"], hm, pos, attn.causal_mask(s))
        hm = rms_norm(hm, params["mtp"]["norm"], cfg.norm_eps)
        mtp_logits = hm @ params["lm_head"]
        mtp_loss = _cross_entropy(mtp_logits, batch["labels"][:, 1:])
        metrics["mtp"] = mtp_loss
        loss = loss + mtp_weight * mtp_loss
    return loss, metrics


def prefill_logits(params, cfg: ModelConfig, batch: Dict):
    """Full-sequence forward returning last-token logits (inference prefill)."""
    h, _, _ = hidden_states(params, cfg, batch)
    h = rms_norm(h[:, -1:], params["final_norm"], cfg.norm_eps)
    return h @ params["lm_head"]


# --------------------------------------------------------------------------
# decode (single token against caches)
# --------------------------------------------------------------------------

class DecodeCache(NamedTuple):
    """Stacked-over-layers caches; unused fields are None."""

    kv: Optional[Any] = None  # attention KVCache / MLACache, leaves (L, ...)
    ssm: Optional[Any] = None  # SSMCache, leaves (L, ...)
    cross: Optional[Any] = None  # whisper (k, v): (L, B, S_enc, H, Dh)


def init_decode_cache(cfg: ModelConfig, batch: int, window: int, enc_len: int = 0,
                      dtype=jnp.bfloat16) -> DecodeCache:
    l = cfg.num_layers
    stack = lambda tree: jax.tree.map(lambda x: jnp.broadcast_to(x[None], (l,) + x.shape), tree)
    kv = ssm_cache = cross = None
    hd = cfg.resolved_head_dim
    if cfg.family != "ssm":
        if cfg.mla:
            kv = stack(mla_mod.init_mla_cache(batch, window, cfg.kv_lora_rank, cfg.rope_head_dim, dtype))
        else:
            kv = stack(attn.init_kv_cache(batch, window, cfg.num_kv_heads, hd, dtype))
    if cfg.family == "ssm" or cfg.hybrid:
        ssm_cache = stack(
            ssm_mod.init_ssm_cache(batch, cfg.d_model, cfg.ssm_state, cfg.ssm_head_dim, cfg.ssm_expand, dtype=dtype)
        )
    if cfg.encoder_decoder:
        cross = (
            jnp.zeros((l, batch, enc_len, cfg.num_heads, hd), dtype),
            jnp.zeros((l, batch, enc_len, cfg.num_heads, hd), dtype),
        )
    return DecodeCache(kv=kv, ssm=ssm_cache, cross=cross)


def _mix_decode(cfg: ModelConfig, p: Params, h, kv, ssm_cache, pos):
    outs, new_kv, new_ssm = [], kv, ssm_cache
    if cfg.family == "ssm" or cfg.hybrid:
        o, new_ssm = ssm_mod.ssm_decode(
            p["ssm"], h, ssm_cache, ssm_state=cfg.ssm_state,
            head_dim=cfg.ssm_head_dim, expand=cfg.ssm_expand,
        )
        outs.append(o)
    if cfg.family != "ssm":
        hd = cfg.resolved_head_dim
        if cfg.mla:
            o, new_kv = mla_mod.mla_decode(
                p["attn"], h, kv, pos, num_heads=cfg.num_heads, head_dim=hd,
                rope_head_dim=cfg.rope_head_dim, rope_theta=cfg.rope_theta,
            )
        else:
            o, new_kv = attn.decode_attend(
                p["attn"], h, kv, pos, num_heads=cfg.num_heads,
                num_kv_heads=cfg.num_kv_heads, head_dim=hd, rope_theta=cfg.rope_theta,
            )
        outs.append(o)
    return sum(outs) / len(outs), new_kv, new_ssm


def _layer_decode(cfg: ModelConfig, p: Params, x, kv, ssm_cache, cross, pos):
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    mix, new_kv, new_ssm = _mix_decode(cfg, p, h, kv, ssm_cache, pos)
    x = x + mix
    if "cross" in p and cross is not None:
        hc = rms_norm(x, p["ln_cross"], cfg.norm_eps)
        ck, cv = cross
        hd = cfg.resolved_head_dim
        c, _ = attn.gqa_apply(
            p["cross"], hc, num_heads=cfg.num_heads, num_kv_heads=cfg.num_heads,
            head_dim=hd, positions=jnp.full((hc.shape[0], 1), pos, jnp.int32),
            mask=attn.full_mask(1, ck.shape[1]), kv_override=(ck, cv, None),
        )
        x = x + c
    if "ff" in p:
        h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
        if cfg.moe:
            ff, _ = moe_mod.moe_apply(p["ff"], h2, top_k=cfg.top_k, activation=cfg.activation,
                                      capacity_factor=cfg.moe_capacity_factor)
        else:
            ff = mlp_apply(p["ff"], h2, cfg.activation)
        x = x + ff
    return x, new_kv, new_ssm


def decode_step(params, cfg: ModelConfig, tokens, cache: DecodeCache, pos):
    """One token for the whole batch. tokens: (B, 1) int32; pos: scalar."""
    x = _embed_tokens(params, tokens)

    def body(x, scanned):
        lp, kv, ssm_cache, cross = scanned
        x, new_kv, new_ssm = _layer_decode(cfg, lp, x, kv, ssm_cache, cross, pos)
        return x, (new_kv, new_ssm)

    xs = (params["layers"], cache.kv, cache.ssm, cache.cross)
    x, (new_kv, new_ssm) = jax.lax.scan(body, x, xs)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = x @ params["lm_head"]
    return logits, DecodeCache(kv=new_kv, ssm=new_ssm, cross=cache.cross)
