"""Mamba-2 (SSD — state-space duality, arXiv:2405.21060).

Chunked SSD: within a chunk the recurrence is computed in its dual
"attention" (matmul) form; chunk boundary states are carried by an
associative scan. This is the TPU-friendly formulation (MXU matmuls over
chunks instead of a length-S sequential scan) and is exactly what the
Pallas kernel in ``repro.kernels.ssd_scan`` implements per block.

Layer layout follows Mamba-2: in_proj → [z | x | B | C | dt], depthwise
causal conv over (x, B, C), SSD core, gated RMSNorm, out_proj.
"""
from __future__ import annotations

from typing import Dict, NamedTuple

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, rms_norm
from repro.parallel.sharding import constrain


class SSMCache(NamedTuple):
    """conv_state: (B, d_conv-1, conv_dim); ssd_state: (B, H, P, N)."""

    conv_state: jnp.ndarray
    ssd_state: jnp.ndarray


def ssm_dims(d_model: int, ssm_state: int, head_dim: int = 64, expand: int = 2):
    d_inner = expand * d_model
    num_heads = d_inner // head_dim
    conv_dim = d_inner + 2 * ssm_state  # x, B, C share the conv
    return d_inner, num_heads, conv_dim


def ssm_init(key, d_model, ssm_state, head_dim=64, expand=2, d_conv=4, dtype=jnp.float32) -> Dict:
    d_inner, nh, conv_dim = ssm_dims(d_model, ssm_state, head_dim, expand)
    ks = jax.random.split(key, 5)
    in_dim = 2 * d_inner + 2 * ssm_state + nh  # z, x, B, C, dt
    return {
        "in_proj": dense_init(ks[0], (d_model, in_dim), dtype=dtype),
        "conv_w": (jax.random.normal(ks[1], (d_conv, conv_dim)) * 0.2).astype(dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, nh)).astype(jnp.float32),
        "d_skip": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "norm": jnp.zeros((d_inner,), dtype),
        "out_proj": dense_init(ks[4], (d_inner, d_model), fan_in=d_inner, dtype=dtype),
    }


def _causal_conv(x, w, b):
    """Depthwise causal conv. x: (B,S,C); w: (K,C)."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(xp[:, i : i + x.shape[1], :] * w[i] for i in range(k))
    return jax.nn.silu(out + b)


def segsum(dA):
    """Cumulative within-chunk decay matrix: L[i,j] = exp(Σ_{j<r≤i} dA_r), j≤i.

    dA: (..., cs). Returns (..., cs, cs) lower-triangular (inclusive of
    the diagonal, which is exp(0)·decay contribution of position itself).
    """
    cs = dA.shape[-1]
    cum = jnp.cumsum(dA, axis=-1)
    diff = cum[..., :, None] - cum[..., None, :]  # Σ_{r≤i} − Σ_{r≤j}
    mask = jnp.tril(jnp.ones((cs, cs), bool))
    # mask BEFORE exp: exp of masked (positive, unbounded) entries would be
    # inf and poison the backward pass through where (0·∞ = NaN cotangent).
    diff = jnp.where(mask, diff, -jnp.inf)
    return jnp.exp(diff)


def ssd_reference(x, dt, a, b_mat, c_mat, chunk: int, initial_state=None):
    """Chunked SSD scan (pure jnp oracle; mirrors the Pallas kernel).

    x:     (B, S, H, P)   inputs per head
    dt:    (B, S, H)      positive step sizes (softplus already applied)
    a:     (H,)           negative decay rates (−exp(a_log))
    b_mat: (B, S, N)      input projection  (single group, broadcast to H)
    c_mat: (B, S, N)      output projection
    Returns (y: (B,S,H,P), final_state: (B,H,P,N)).
    """
    bsz, s, h, p = x.shape
    n = b_mat.shape[-1]
    nc = s // chunk
    # keep everything in x's dtype: mixed f32/f64 inputs (x64 mode) would
    # otherwise break the scan carry dtype below
    dt = dt.astype(x.dtype)
    a = a.astype(x.dtype)
    b_mat = b_mat.astype(x.dtype)
    c_mat = c_mat.astype(x.dtype)
    xc = x.reshape(bsz, nc, chunk, h, p)
    dtc = dt.reshape(bsz, nc, chunk, h)
    bc = b_mat.reshape(bsz, nc, chunk, n)
    cc = c_mat.reshape(bsz, nc, chunk, n)

    dA = dtc * a  # (B,nc,cs,H) negative
    dA_h = jnp.moveaxis(dA, -1, 2)  # (B,nc,H,cs)
    L = segsum(dA_h)  # (B,nc,H,cs,cs)

    # Intra-chunk (dual attention form): Y[i] = Σ_{j≤i} (C_i·B_j) L[i,j] dt_j x_j
    cb = jnp.einsum("bzin,bzjn->bzij", cc, bc)  # (B,nc,cs,cs)
    m = cb[:, :, None] * L  # (B,nc,H,cs,cs)
    y_intra = jnp.einsum("bzhij,bzjh,bzjhp->bzihp", m, dtc, xc)

    # Chunk-final states: S_z = Σ_j exp(Σ_{r>j} dA_r) dt_j B_j ⊗ x_j
    cum = jnp.cumsum(dA_h, axis=-1)
    decay_to_end = jnp.exp(cum[..., -1:] - cum)  # (B,nc,H,cs)
    sdec = jnp.einsum("bzhj,bzjh,bzjn,bzjhp->bzhpn", decay_to_end, dtc, bc, xc)

    # Inter-chunk recurrence over chunk states.
    chunk_decay = jnp.exp(cum[..., -1])  # (B,nc,H)

    def scan_fn(state, inp):
        dec, s_new = inp  # (B,H), (B,H,P,N)
        state = state * dec[..., None, None] + s_new
        return state, state

    init = (
        jnp.zeros((bsz, h, p, n), x.dtype)
        if initial_state is None
        else initial_state
    )
    dec_t = jnp.moveaxis(chunk_decay, 1, 0)  # (nc,B,H)
    s_t = jnp.moveaxis(sdec, 1, 0)  # (nc,B,H,P,N)
    final, states_after = jax.lax.scan(scan_fn, init, (dec_t, s_t))
    # State *entering* chunk z is the state after chunk z-1.
    states_in = jnp.concatenate([init[None], states_after[:-1]], axis=0)
    states_in = jnp.moveaxis(states_in, 0, 1)  # (B,nc,H,P,N)

    # Inter-chunk output: Y_inter[i] = C_i · state_in · exp(Σ_{r≤i} dA_r)
    decay_from_start = jnp.exp(cum)  # (B,nc,H,cs)
    y_inter = jnp.einsum("bzin,bzhpn,bzhi->bzihp", cc, states_in, decay_from_start)

    y = (y_intra + y_inter).reshape(bsz, s, h, p)
    return y, final


def ssm_apply(p: Dict, x, *, ssm_state: int, head_dim=64, expand=2, chunk=128,
              return_state: bool = False):
    """Full-sequence SSD block. x: (B, S, D) → (B, S, D)."""
    bsz, s, d_model = x.shape
    d_inner, nh, conv_dim = ssm_dims(d_model, ssm_state, head_dim, expand)
    proj = x @ p["in_proj"]
    # layout: [z (d_inner) | x+B+C (conv_dim) | dt (H)]
    z = proj[..., :d_inner]
    xbc = proj[..., d_inner : d_inner + conv_dim]
    dt_raw = proj[..., d_inner + conv_dim :]
    xbc = _causal_conv(xbc, p["conv_w"], p["conv_b"])
    xs = constrain(xbc[..., :d_inner].reshape(bsz, s, nh, head_dim),
                   ("fsdp", None, "model", "model"))
    b_mat = xbc[..., d_inner : d_inner + ssm_state]
    c_mat = xbc[..., d_inner + ssm_state :]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["a_log"])
    y, final = ssd_reference(
        xs.astype(jnp.float32), dt, a, b_mat.astype(jnp.float32), c_mat.astype(jnp.float32), chunk
    )
    y = y + xs.astype(jnp.float32) * p["d_skip"][None, None, :, None]
    y = y.reshape(bsz, s, d_inner).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm"])
    out = y @ p["out_proj"]
    if return_state:
        return out, final
    return out


def init_ssm_cache(batch, d_model, ssm_state, head_dim=64, expand=2, d_conv=4, dtype=jnp.float32):
    d_inner, nh, conv_dim = ssm_dims(d_model, ssm_state, head_dim, expand)
    return SSMCache(
        conv_state=jnp.zeros((batch, d_conv - 1, conv_dim), dtype),
        ssd_state=jnp.zeros((batch, nh, head_dim, ssm_state), jnp.float32),
    )


def ssm_decode(p: Dict, x, cache: SSMCache, *, ssm_state: int, head_dim=64, expand=2):
    """One-token recurrent step. x: (B, 1, D)."""
    bsz, _, d_model = x.shape
    d_inner, nh, conv_dim = ssm_dims(d_model, ssm_state, head_dim, expand)
    proj = (x @ p["in_proj"])[:, 0]  # (B, in_dim)
    z = proj[..., :d_inner]
    xbc_new = proj[..., d_inner : d_inner + conv_dim]
    dt_raw = proj[..., d_inner + conv_dim :]

    window = jnp.concatenate([cache.conv_state, xbc_new[:, None, :]], axis=1)  # (B,K,C)
    conv = jax.nn.silu(jnp.einsum("bkc,kc->bc", window, p["conv_w"]) + p["conv_b"])
    new_conv_state = window[:, 1:, :]

    xs = conv[..., :d_inner].reshape(bsz, nh, head_dim)
    b_mat = conv[..., d_inner : d_inner + ssm_state]
    c_mat = conv[..., d_inner + ssm_state :]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # (B,H)
    a = -jnp.exp(p["a_log"])
    decay = jnp.exp(dt * a)  # (B,H)
    upd = dt[..., None, None] * b_mat[:, None, None, :] * xs[..., :, None].astype(jnp.float32)
    state = cache.ssd_state * decay[..., None, None] + upd
    y = jnp.einsum("bhpn,bn->bhp", state, c_mat.astype(jnp.float32))
    y = y + xs.astype(jnp.float32) * p["d_skip"][None, :, None]
    y = y.reshape(bsz, d_inner).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm"])
    out = (y @ p["out_proj"])[:, None, :]
    return out, SSMCache(conv_state=new_conv_state, ssd_state=state)
