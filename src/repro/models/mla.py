"""Multi-head Latent Attention (DeepSeek-V2/V3, arXiv:2405.04434 §2.1).

K/V are compressed into a low-rank latent c_kv (kv_lora_rank) plus a
shared decoupled-RoPE key k_R (rope_head_dim); the decode cache stores
only (c_kv, k_R) — the MLA memory saving. Queries optionally go through
their own low-rank path (q_lora_rank, used by V3).

This is the reference (non-absorbed) formulation: at attention time the
latent is up-projected to per-head K_C/V. Weight absorption is a §Perf
optimization tracked in EXPERIMENTS.md.
"""
from __future__ import annotations

from typing import Dict, NamedTuple

import jax
import jax.numpy as jnp

from repro.models.attention import sdpa
from repro.models.layers import apply_rope, dense_init


class MLACache(NamedTuple):
    """c_kv: (B, W, R); k_rope: (B, W, Dr)."""

    c_kv: jnp.ndarray
    k_rope: jnp.ndarray


def mla_init(key, d_model, num_heads, head_dim, kv_lora_rank, q_lora_rank, rope_head_dim, dtype) -> Dict:
    ks = jax.random.split(key, 7)
    p = {
        "wdkv": dense_init(ks[0], (d_model, kv_lora_rank), dtype=dtype),
        "wkr": dense_init(ks[1], (d_model, rope_head_dim), dtype=dtype),
        "wuk": dense_init(ks[2], (kv_lora_rank, num_heads * head_dim), fan_in=kv_lora_rank, dtype=dtype),
        "wuv": dense_init(ks[3], (kv_lora_rank, num_heads * head_dim), fan_in=kv_lora_rank, dtype=dtype),
        "wo": dense_init(ks[4], (num_heads * head_dim, d_model), fan_in=num_heads * head_dim, dtype=dtype),
    }
    q_out = num_heads * (head_dim + rope_head_dim)
    if q_lora_rank > 0:
        p["wdq"] = dense_init(ks[5], (d_model, q_lora_rank), dtype=dtype)
        p["wuq"] = dense_init(ks[6], (q_lora_rank, q_out), fan_in=q_lora_rank, dtype=dtype)
    else:
        p["wq"] = dense_init(ks[5], (d_model, q_out), dtype=dtype)
    return p


def _queries(p, x, num_heads, head_dim, rope_head_dim, positions, rope_theta):
    b, s, _ = x.shape
    if "wdq" in p:
        q = (x @ p["wdq"]) @ p["wuq"]
    else:
        q = x @ p["wq"]
    q = q.reshape(b, s, num_heads, head_dim + rope_head_dim)
    q_c, q_r = q[..., :head_dim], q[..., head_dim:]
    q_r = apply_rope(q_r, positions, rope_theta)
    return jnp.concatenate([q_c, q_r], axis=-1)


def _expand_kv(p, c_kv, k_rope, num_heads, head_dim):
    """Up-project latents to per-head K (with shared RoPE part) and V."""
    b, t, _ = c_kv.shape
    k_c = (c_kv @ p["wuk"]).reshape(b, t, num_heads, head_dim)
    v = (c_kv @ p["wuv"]).reshape(b, t, num_heads, head_dim)
    k_r = jnp.broadcast_to(k_rope[:, :, None, :], (b, t, num_heads, k_rope.shape[-1]))
    k = jnp.concatenate([k_c, k_r], axis=-1)
    return k, v


def mla_apply(p, x, *, num_heads, head_dim, rope_head_dim, positions, mask,
              rope_theta=1e4, causal=None, window: int = 0):
    """Full-sequence MLA (train / prefill). Returns (out, (c_kv, k_rope))."""
    from repro.models.attention import BLOCKWISE_CHUNK, BLOCKWISE_THRESHOLD, sdpa_blockwise

    q = _queries(p, x, num_heads, head_dim, rope_head_dim, positions, rope_theta)
    c_kv = x @ p["wdkv"]
    k_rope = apply_rope((x @ p["wkr"])[:, :, None, :], positions, rope_theta)[:, :, 0, :]
    k, v = _expand_kv(p, c_kv, k_rope, num_heads, head_dim)
    s = q.shape[1]
    if causal is not None and s >= BLOCKWISE_THRESHOLD and s % BLOCKWISE_CHUNK == 0:
        out = sdpa_blockwise(q, k, v, causal=causal, window=window)
    else:
        out = sdpa(q, k, v, mask)  # q/k have head_dim + rope_head_dim; v has head_dim
    return out @ p["wo"], (c_kv, k_rope)


def init_mla_cache(batch, window, kv_lora_rank, rope_head_dim, dtype) -> MLACache:
    return MLACache(
        c_kv=jnp.zeros((batch, window, kv_lora_rank), dtype),
        k_rope=jnp.zeros((batch, window, rope_head_dim), dtype),
    )


def mla_decode(p, x, cache: MLACache, pos, *, num_heads, head_dim, rope_head_dim,
               rope_theta=1e4, absorbed: bool = True):
    """One decode step. ``absorbed=True`` (default) runs attention in the
    latent space — DeepSeek's serving optimization (§Perf D1): the query
    is projected through W_uk once (q̃ = W_ukᵀ q_c, H·dh·R flops) and
    scores/context are latent dot products with the *compressed* cache, so
    the per-step cost drops from O(W·R·H·dh) (expanding K/V) to O(W·R·H).
    Mathematically identical to the non-absorbed path
    (tests/test_mla_absorbed.py)."""
    b = x.shape[0]
    w = cache.c_kv.shape[1]
    r = cache.c_kv.shape[-1]
    posv = jnp.full((b, 1), pos, jnp.int32)
    q = _queries(p, x, num_heads, head_dim, rope_head_dim, posv, rope_theta)
    c_new = x @ p["wdkv"]
    kr_new = apply_rope((x @ p["wkr"])[:, :, None, :], posv, rope_theta)[:, :, 0, :]
    slot = jnp.mod(pos, w).astype(jnp.int32)
    zero = jnp.zeros((), jnp.int32)
    c_kv = jax.lax.dynamic_update_slice(cache.c_kv, c_new, (zero, slot, zero))
    k_rope = jax.lax.dynamic_update_slice(cache.k_rope, kr_new, (zero, slot, zero))
    idx = jnp.arange(w)
    valid = jnp.where(pos >= w, jnp.ones((w,), bool), idx <= jnp.minimum(pos, w - 1))

    if not absorbed:
        k, v = _expand_kv(p, c_kv, k_rope, num_heads, head_dim)
        out = sdpa(q, k, v, jnp.broadcast_to(valid[None, None, :], (b, 1, w)))
        return out @ p["wo"], MLACache(c_kv=c_kv, k_rope=k_rope)

    q_c, q_r = q[..., :head_dim], q[..., head_dim:]
    wuk = p["wuk"].reshape(r, num_heads, head_dim)
    wuv = p["wuv"].reshape(r, num_heads, head_dim)
    q_lat = jnp.einsum("bshd,rhd->bshr", q_c, wuk)  # absorbed query
    scores = jnp.einsum("bshr,bwr->bhsw", q_lat, c_kv) + jnp.einsum(
        "bshd,bwd->bhsw", q_r, k_rope
    )
    scores = scores.astype(jnp.float32) * (head_dim + rope_head_dim) ** -0.5
    scores = jnp.where(valid[None, None, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(c_kv.dtype)
    ctx = jnp.einsum("bhsw,bwr->bshr", probs, c_kv)  # latent context
    out = jnp.einsum("bshr,rhd->bshd", ctx, wuv).reshape(b, 1, num_heads * head_dim)
    return out @ p["wo"], MLACache(c_kv=c_kv, k_rope=k_rope)
