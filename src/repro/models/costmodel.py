"""Analytic cost model: ModelConfig → per-block FLOPs / boundary bytes →
the paper planner's ``BlockChain``.

This is how the paper's technique becomes a first-class framework feature:
any architecture in the zoo can be partitioned between a weak tier
("device", DVFS-scalable) and a strong tier ("edge" VM) by the robust
planner, with w_{n,m} (GFLOPs), d_{n,m} (boundary activation bytes) and
the (mean, variance) time model derived from the real config instead of
hand-measured tables.

FLOP counts are inference (fwd) MACs×2 per token; the attention score
term is per-sequence quadratic. Training cost ≈ 3× fwd (bwd ≈ 2×) — the
planner partitions inference, so fwd is what matters here.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.blocks import BlockChain
from repro.models.ssm import ssm_dims


def layer_flops_per_token(cfg: ModelConfig, seq_len: int) -> float:
    """Forward FLOPs per token for one decoder layer at context seq_len."""
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    fl = 0.0
    if cfg.family != "ssm":
        if cfg.mla:
            r, dr = cfg.kv_lora_rank, cfg.rope_head_dim
            q_in = cfg.q_lora_rank or d
            q_proj = (d * cfg.q_lora_rank + cfg.q_lora_rank * cfg.num_heads * (hd + dr)) if cfg.q_lora_rank else d * cfg.num_heads * (hd + dr)
            kv_proj = d * r + d * dr + r * cfg.num_heads * hd * 2
            o_proj = cfg.num_heads * hd * d
            fl += 2 * (q_proj + kv_proj + o_proj)
            fl += 2 * 2 * seq_len * cfg.num_heads * (hd + dr) / 2  # scores+values (avg causal)
        else:
            qkv = d * (cfg.num_heads + 2 * cfg.num_kv_heads) * hd
            o = cfg.num_heads * hd * d
            fl += 2 * (qkv + o)
            fl += 2 * 2 * seq_len * cfg.num_heads * hd / 2  # causal avg
    if cfg.family == "ssm" or cfg.hybrid:
        d_inner, nh, conv_dim = ssm_dims(d, cfg.ssm_state, cfg.ssm_head_dim, cfg.ssm_expand)
        in_dim = 2 * d_inner + 2 * cfg.ssm_state + nh
        fl += 2 * (d * in_dim + d_inner * d)  # in/out proj
        fl += 2 * 4 * conv_dim  # depthwise conv (k=4)
        cs = cfg.ssm_chunk
        n = cfg.ssm_state
        p = cfg.ssm_head_dim
        # SSD chunk matmuls per token: CB^T (cs·N), intra (cs·P), state out/in (N·P)
        fl += 2 * nh * (cs * n / nh + cs * p + 2 * n * p)
    if cfg.moe:
        mult = 3 if cfg.activation == "swiglu" else 2
        fl += 2 * d * cfg.num_experts  # router
        fl += 2 * mult * d * cfg.d_ff_expert * (cfg.top_k + cfg.num_shared_experts)
    elif cfg.d_ff > 0:
        mult = 3 if cfg.activation == "swiglu" else 2
        fl += 2 * mult * d * cfg.d_ff
    return float(fl)


def model_flops_per_token(cfg: ModelConfig, seq_len: int, include_head: bool = True) -> float:
    fl = cfg.num_layers * layer_flops_per_token(cfg, seq_len)
    if cfg.encoder_decoder:
        # encoder processes seq_len/4 frames with bidirectional attention
        enc_s = max(seq_len // 4, 1)
        fl += cfg.num_encoder_layers * layer_flops_per_token(cfg, enc_s) * enc_s / seq_len
    if include_head:
        fl += 2 * cfg.d_model * cfg.vocab_size
    return float(fl)


@dataclass(frozen=True)
class TierProfile:
    """Throughput/uncertainty profile of a serving tier.

    ``flops_per_cycle`` plays the paper's g role (the per-block fitted
    efficiency); ``cv`` is the inference-time coefficient of variation
    (Fig. 5-style jitter), ``eff_jitter`` models per-block efficiency
    spread (g varies per block, as the paper measures).
    """

    flops_per_cycle: float
    cv: float = 0.08
    eff_jitter: float = 0.15
    # edge tier only: fixed clock (Hz) — the VM's frequency is constant.
    clock_hz: float = 1.0e9


# A Jetson-class device tier and an RTX/TPU-class edge tier (defaults used
# by examples/tests; launch scripts may override). PHONE_TIER is a weaker,
# jitterier smartphone-class NPU for mixed-population deployments.
DEVICE_TIER = TierProfile(flops_per_cycle=220.0, cv=0.10, eff_jitter=0.15)
PHONE_TIER = TierProfile(flops_per_cycle=60.0, cv=0.18, eff_jitter=0.25)
EDGE_TIER = TierProfile(flops_per_cycle=40_000.0, cv=0.03, eff_jitter=0.05, clock_hz=2.0e9)


def block_chain_from_config(
    cfg: ModelConfig,
    *,
    batch: int = 1,
    seq_len: int = 512,
    num_blocks: int = 8,
    device: TierProfile = DEVICE_TIER,
    edge: TierProfile = EDGE_TIER,
    f_mid_hz: float = 0.8e9,
    seed: int = 0,
) -> BlockChain:
    """Partition the layer stack into ``num_blocks`` contiguous blocks.

    Point m=0: everything on the edge (upload raw tokens ≈ S·4 bytes·B).
    Point m=k: blocks 1..k local; boundary payload = B·S·d_model·2 bytes
    (bf16 activations). Point m=M: upload only the result logits' argmax
    (a few bytes) — modeled as 1 KB.
    """
    rng = np.random.default_rng(seed)
    tokens = batch * seq_len
    per_layer = layer_flops_per_token(cfg, seq_len) * tokens
    head_fl = 2 * cfg.d_model * cfg.vocab_size * tokens

    # distribute layers over blocks as evenly as possible
    counts = np.full(num_blocks, cfg.num_layers // num_blocks)
    counts[: cfg.num_layers % num_blocks] += 1
    w = np.concatenate([[0.0], np.cumsum(counts * per_layer)])
    w[-1] += head_fl  # final block carries the LM head

    act_bits = batch * seq_len * cfg.d_model * 2 * 8.0
    if cfg.family in ("ssm", "hybrid"):
        d_inner, nh, _ = ssm_dims(cfg.d_model, cfg.ssm_state, cfg.ssm_head_dim, cfg.ssm_expand)
        act_bits += batch * nh * cfg.ssm_head_dim * cfg.ssm_state * 2 * 8.0  # boundary SSM state
    raw_bits = batch * seq_len * 4 * 8.0  # int32 tokens
    if cfg.vlm_stub:
        raw_bits += batch * cfg.num_patches * cfg.vision_dim * 2 * 8.0
    if cfg.audio_stub:
        raw_bits += batch * (seq_len // 4) * cfg.d_model * 2 * 8.0
    d = np.full(num_blocks + 1, act_bits)
    d[0] = raw_bits
    d[-1] = 8.0 * 1024  # result payload

    # per-block efficiency (the paper's per-block g): jittered around the tier value
    g_blocks = device.flops_per_cycle * np.exp(
        rng.normal(0.0, device.eff_jitter, num_blocks)
    )
    # prefix-effective g: harmonic-style combination (time-additive)
    t_unit = counts * per_layer / g_blocks  # time·f of each block
    g_prefix = np.concatenate([[1.0], np.cumsum(counts * per_layer) / np.cumsum(t_unit)])
    g_prefix[-1] = w[-1] / (np.sum(t_unit) + head_fl / g_blocks[-1])

    # variance: (cv · mean time at a mid frequency)², max-over-range per (11)
    mean_t_mid = w / (np.maximum(g_prefix, 1e-9) * f_mid_hz)
    v_loc = (device.cv * mean_t_mid) ** 2
    v_loc[0] = 0.0

    # edge tier: remaining work at fixed clock
    w_left = w[-1] - w
    t_vm = w_left / (edge.flops_per_cycle * edge.clock_hz)
    v_vm = (edge.cv * t_vm) ** 2

    f64 = lambda a: jnp.asarray(a, jnp.float64)
    return BlockChain(
        d_bits=f64(d), w_flops=f64(w), g_eff=f64(g_prefix),
        v_loc=f64(v_loc), t_vm=f64(t_vm), v_vm=f64(v_vm),
    )
