"""GQA attention with causal / bidirectional / sliding-window masking and
ring-buffer KV caches for decode.

The einsum implementation here is the XLA reference path (used for
lowering, dry-runs and CPU tests); the Pallas flash kernel in
``repro.kernels`` is numerically validated against ``repro.kernels.ref``
which mirrors this math.
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.layers import apply_rope, dense_init
from repro.parallel.sharding import constrain

NEG_INF = -1e30

#: full-sequence attention switches to the blockwise (online-softmax) path
#: above this length — the XLA analogue of the Pallas flash kernel; keeps
#: the live logits buffer at (B, H, CHUNK, T) instead of (B, H, S, T).
BLOCKWISE_THRESHOLD = 2048
BLOCKWISE_CHUNK = 256


class KVCache(NamedTuple):
    """Ring-buffer cache. k/v: (B, W, Hkv, Dh); pos: scalar step count."""

    k: jnp.ndarray
    v: jnp.ndarray


def gqa_init(key, d_model, num_heads, num_kv_heads, head_dim, dtype) -> Dict:
    kq, kk, kv, ko = jax.random.split(key, 4)
    return {
        "wq": dense_init(kq, (d_model, num_heads * head_dim), dtype=dtype),
        "wk": dense_init(kk, (d_model, num_kv_heads * head_dim), dtype=dtype),
        "wv": dense_init(kv, (d_model, num_kv_heads * head_dim), dtype=dtype),
        "wo": dense_init(ko, (num_heads * head_dim, d_model), fan_in=num_heads * head_dim, dtype=dtype),
    }


def _split_heads(x, n, dh):
    return x.reshape(x.shape[:-1] + (n, dh))


def sdpa(q, k, v, mask):
    """q: (B,S,H,Dh), k/v: (B,T,Hkv,Dh), mask: (B,S,T) or (S,T) bool."""
    b, s, h, dh = q.shape
    hkv = k.shape[2]
    group = h // hkv
    qg = q.reshape(b, s, hkv, group, dh)
    logits = jnp.einsum("bshgd,bthd->bhgst", qg, k).astype(jnp.float32)
    logits = logits * (dh**-0.5)
    if mask.ndim == 2:
        mask = mask[None]
    logits = jnp.where(mask[:, None, None, :, :], logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhgst,bthd->bshgd", w, v)
    return out.reshape(b, s, h * v.shape[-1])  # v head dim may differ (MLA)


def sdpa_blockwise(q, k, v, *, causal: bool = True, window: int = 0,
                   chunk: int = BLOCKWISE_CHUNK):
    """Online-softmax-free blockwise attention (memory-bounded reference).

    q: (B,S,H,Dq), k: (B,T,Hkv,Dq), v: (B,T,Hkv,Dv) → (B,S,H·Dv).
    Processes queries in CHUNK-row blocks via lax.map; each block sees the
    full K/V (softmax per row is exact, no online rescaling needed). KV
    heads are repeated to H and head-sharded ("model", first-fit).
    """
    b, s, h, dq = q.shape
    t, hkv = k.shape[1], k.shape[2]
    if h != hkv:
        k = jnp.repeat(k, h // hkv, axis=2)
        v = jnp.repeat(v, h // hkv, axis=2)
    spec = ("fsdp", None, "model", None)
    q = constrain(q, spec)
    k = constrain(k, spec)
    v = constrain(v, spec)
    scale = dq**-0.5
    nq = s // chunk
    assert s % chunk == 0, (s, chunk)
    qb = q.reshape(b, nq, chunk, h, dq)

    # Sliding window: slice K/V to the (window + chunk) span each q-block
    # can actually see. Masking alone leaves the full S·T matmul in the
    # HLO (§Perf iteration C1, refuted); slicing removes the compute.
    windowed = causal and window > 0 and window + chunk < t

    def block(qi):
        qq = qb[:, qi]  # (b, chunk, h, dq)
        rows = qi * chunk + jnp.arange(chunk)[:, None]
        if windowed:
            span = window + chunk
            start = jnp.maximum(qi * chunk - window, 0)
            kk = jax.lax.dynamic_slice_in_dim(k, start, span, 1)
            vv = jax.lax.dynamic_slice_in_dim(v, start, span, 1)
            cols = start + jnp.arange(span)[None, :]
        else:
            kk, vv = k, v
            cols = jnp.arange(t)[None, :]
        logits = jnp.einsum("bchd,bthd->bhct", qq, kk).astype(jnp.float32) * scale
        logits = constrain(logits, ("fsdp", "model", None, None))
        mask = jnp.ones((chunk, cols.shape[1]), bool)
        if causal:
            mask &= cols <= rows
        if window > 0:
            mask &= cols > rows - window
        logits = jnp.where(mask[None, None], logits, NEG_INF)
        w = jax.nn.softmax(logits, axis=-1).astype(vv.dtype)
        return jnp.einsum("bhct,bthd->bchd", w, vv)  # (b, chunk, h, dv)

    out = jax.lax.map(block, jnp.arange(nq))  # (nq, b, chunk, h, dv)
    out = jnp.moveaxis(out, 0, 1).reshape(b, s, h, v.shape[-1])
    return out.reshape(b, s, h * v.shape[-1])


def causal_mask(s: int, window: int = 0):
    i = jnp.arange(s)[:, None]
    j = jnp.arange(s)[None, :]
    m = j <= i
    if window > 0:
        m &= j > i - window
    return m


def full_mask(s: int, t: int):
    return jnp.ones((s, t), bool)


def gqa_apply(
    p: Dict,
    x,
    *,
    num_heads: int,
    num_kv_heads: int,
    head_dim: int,
    positions,
    mask,
    rope_theta: float = 1e4,
    kv_override: Optional[tuple] = None,
    causal: Optional[bool] = None,
    window: int = 0,
):
    """Full-sequence attention (train / prefill / encoder).

    kv_override — (k, v, kv_positions) for cross-attention (keys from the
    encoder memory; no RoPE on decoder cross-queries by convention here).
    When ``causal`` is given and the sequence is long, the blockwise
    memory-bounded path is used instead of the dense-mask path.
    """
    q = _split_heads(x @ p["wq"], num_heads, head_dim)
    if kv_override is None:
        k = _split_heads(x @ p["wk"], num_kv_heads, head_dim)
        v = _split_heads(x @ p["wv"], num_kv_heads, head_dim)
        q = apply_rope(q, positions, rope_theta)
        k = apply_rope(k, positions, rope_theta)
    else:
        k, v, _ = kv_override
    s = q.shape[1]
    if causal is not None and s >= BLOCKWISE_THRESHOLD and s % BLOCKWISE_CHUNK == 0:
        out = sdpa_blockwise(q, k, v, causal=causal, window=window)
    else:
        out = sdpa(q, k, v, mask)
    return out @ p["wo"], (k, v)


def init_kv_cache(batch: int, window: int, num_kv_heads: int, head_dim: int, dtype) -> KVCache:
    shape = (batch, window, num_kv_heads, head_dim)
    return KVCache(k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype))


def decode_attend(
    p: Dict,
    x,  # (B, 1, D)
    cache: KVCache,
    pos,  # scalar int32 — absolute position of the new token
    *,
    num_heads: int,
    num_kv_heads: int,
    head_dim: int,
    rope_theta: float = 1e4,
):
    """One decode step against a ring-buffer cache of width W.

    The new K/V overwrite slot ``pos % W``; attention is masked to the
    ``min(pos+1, W)`` valid slots. For a full (non-windowed) cache W is the
    max sequence length and the ring never wraps.
    """
    b = x.shape[0]
    w = cache.k.shape[1]
    posv = jnp.full((b, 1), pos, jnp.int32)
    q = _split_heads(x @ p["wq"], num_heads, head_dim)
    k_new = _split_heads(x @ p["wk"], num_kv_heads, head_dim)
    v_new = _split_heads(x @ p["wv"], num_kv_heads, head_dim)
    q = apply_rope(q, posv, rope_theta)
    k_new = apply_rope(k_new, posv, rope_theta)

    slot = jnp.mod(pos, w).astype(jnp.int32)
    zero = jnp.zeros((), jnp.int32)
    k = jax.lax.dynamic_update_slice(cache.k, k_new, (zero, slot, zero, zero))
    v = jax.lax.dynamic_update_slice(cache.v, v_new, (zero, slot, zero, zero))

    # Valid slots: ring positions holding tokens in (pos-W, pos].
    idx = jnp.arange(w)
    valid = idx <= jnp.minimum(pos, w - 1)
    wrapped = jnp.where(pos >= w, jnp.ones((w,), bool), valid)
    mask = wrapped[None, None, :]  # (1, 1, W) broadcast over batch
    out = sdpa(q, k, v, jnp.broadcast_to(mask, (b, 1, w)))
    return out @ p["wo"], KVCache(k=k, v=v)
