"""Model configuration schema and the input-shape suite.

Every assigned architecture gets a ``configs/<id>.py`` exporting CONFIG
(exact assignment) and SMOKE (reduced same-family variant: ≤2 layers,
d_model ≤ 512, ≤4 experts) built via ``reduced()``.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 → d_model // num_heads
    activation: str = "swiglu"  # swiglu | gelu | sqrelu
    rope_theta: float = 1e4
    norm_eps: float = 1e-5
    # --- MoE ---
    moe: bool = False
    num_experts: int = 0
    num_shared_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    moe_capacity_factor: float = 1.25
    # --- MLA ---
    mla: bool = False
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    rope_head_dim: int = 64
    # --- SSM / hybrid ---
    ssm: bool = False
    hybrid: bool = False
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 128
    # --- encoder-decoder (audio) ---
    encoder_decoder: bool = False
    num_encoder_layers: int = 0
    # --- modality stubs ---
    audio_stub: bool = False
    vlm_stub: bool = False
    num_patches: int = 0
    vision_dim: int = 0
    # --- extras ---
    mtp: bool = False  # multi-token prediction head (DeepSeek-V3)
    sliding_window: int = 0  # 0 = full attention (decode may override)
    source: str = ""  # citation

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // max(self.num_heads, 1))

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def subquadratic(self) -> bool:
        """Can natively run very long decode (SSM state or windowed attn)."""
        return self.family in ("ssm", "hybrid") or self.sliding_window > 0


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Build the smoke-test variant: same family, tiny dims."""
    small = dict(
        name=cfg.name + "-smoke",
        num_layers=2,
        d_model=min(cfg.d_model, 256),
        num_heads=4,
        num_kv_heads=2 if cfg.num_kv_heads < cfg.num_heads else 4,
        head_dim=64,
        d_ff=min(cfg.d_ff, 512) if cfg.d_ff else 0,
        vocab_size=min(cfg.vocab_size, 512),
    )
    if cfg.moe:
        small.update(num_experts=4, top_k=2, d_ff_expert=128,
                     num_shared_experts=min(cfg.num_shared_experts, 1))
    if cfg.mla:
        small.update(kv_lora_rank=64, q_lora_rank=0, rope_head_dim=32)
    if cfg.ssm or cfg.hybrid:
        small.update(ssm_state=min(cfg.ssm_state, 16), ssm_head_dim=32, ssm_chunk=32)
    if cfg.encoder_decoder:
        small.update(num_encoder_layers=2)
    if cfg.vlm_stub:
        small.update(num_patches=16, vision_dim=64)
    small.update(overrides)
    return dataclasses.replace(cfg, **small)


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"
    window: int = 0  # sliding-window override for decode on dense archs


TRAIN_4K = InputShape("train_4k", 4_096, 256, "train")
PREFILL_32K = InputShape("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = InputShape("decode_32k", 32_768, 128, "decode")
LONG_500K = InputShape("long_500k", 524_288, 1, "decode", window=8_192)

INPUT_SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}
