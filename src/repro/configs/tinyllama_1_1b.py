"""tinyllama-1.1b [dense] — Llama-2 architecture, GQA kv=4. [arXiv:2401.02385]"""
from repro.configs.base import ModelConfig, reduced

CONFIG = ModelConfig(
    name="tinyllama-1.1b",
    family="dense",
    num_layers=22,
    d_model=2048,
    num_heads=32,
    num_kv_heads=4,
    d_ff=5632,
    vocab_size=32_000,
    activation="swiglu",
    source="arXiv:2401.02385",
)

SMOKE = reduced(CONFIG)
