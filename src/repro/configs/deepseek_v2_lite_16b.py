"""deepseek-v2-lite-16b [moe] — MLA + fine-grained MoE. [arXiv:2405.04434]

Assignment: 27L d_model=2048 16H (GQA kv=16) d_ff=1408 vocab=102400,
MoE 64e top-6, MLA kv_lora=512, 2 shared experts.
(The assignment bracket note says "160 routed"; the header and the
published model card both say 64 routed — we follow 64. See DESIGN.md §5.)
"""
from repro.configs.base import ModelConfig, reduced

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    num_layers=27,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1408,  # dense-MLP width (unused in homogeneous-MoE stack; shared expert width)
    d_ff_expert=1408,
    vocab_size=102_400,
    head_dim=128,
    moe=True,
    num_experts=64,
    num_shared_experts=2,
    top_k=6,
    mla=True,
    kv_lora_rank=512,
    q_lora_rank=0,
    rope_head_dim=64,
    activation="swiglu",
    source="arXiv:2405.04434",
)

SMOKE = reduced(CONFIG)
