"""deepseek-v3-671b [moe] — MLA + 256-expert MoE + MTP. [arXiv:2412.19437]"""
from repro.configs.base import ModelConfig, reduced

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=128,
    num_kv_heads=128,
    d_ff=2048,  # shared-expert width
    d_ff_expert=2048,
    vocab_size=129_280,
    head_dim=128,
    moe=True,
    num_experts=256,
    num_shared_experts=1,
    top_k=8,
    mla=True,
    kv_lora_rank=512,
    q_lora_rank=1536,
    rope_head_dim=64,
    mtp=True,
    activation="swiglu",
    source="arXiv:2412.19437",
)

SMOKE = reduced(CONFIG)
