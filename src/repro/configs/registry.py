"""Architecture registry: ``--arch <id>`` → ModelConfig."""
from __future__ import annotations

import importlib
from typing import Dict

from repro.configs.base import ModelConfig

_MODULES = {
    "deepseek-v2-lite-16b": "repro.configs.deepseek_v2_lite_16b",
    "nemotron-4-15b": "repro.configs.nemotron_4_15b",
    "minitron-4b": "repro.configs.minitron_4b",
    "hymba-1.5b": "repro.configs.hymba_1_5b",
    "deepseek-v3-671b": "repro.configs.deepseek_v3_671b",
    "whisper-medium": "repro.configs.whisper_medium",
    "mamba2-130m": "repro.configs.mamba2_130m",
    "internvl2-2b": "repro.configs.internvl2_2b",
    "stablelm-1.6b": "repro.configs.stablelm_1_6b",
    "tinyllama-1.1b": "repro.configs.tinyllama_1_1b",
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch_id: str, smoke: bool = False) -> ModelConfig:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(_MODULES[arch_id])
    return mod.SMOKE if smoke else mod.CONFIG


def all_configs(smoke: bool = False) -> Dict[str, ModelConfig]:
    return {a: get_config(a, smoke) for a in ARCH_IDS}
