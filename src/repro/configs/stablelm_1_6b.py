"""stablelm-1.6b [dense] — MHA (kv=heads=32). [hf:stabilityai/stablelm-2-1_6b]"""
from repro.configs.base import ModelConfig, reduced

CONFIG = ModelConfig(
    name="stablelm-1.6b",
    family="dense",
    num_layers=24,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    d_ff=5632,
    vocab_size=100_352,
    activation="swiglu",
    source="hf:stabilityai/stablelm-2-1_6b",
)

SMOKE = reduced(CONFIG)
