"""whisper-medium [audio] — encoder-decoder; conv frontend is a stub
(``input_specs`` supplies precomputed frame embeddings). [arXiv:2212.04356]

long_500k is SKIPPED for this arch (enc-dec with full attention; no 500k
decode analogue) — see DESIGN.md §5.
"""
from repro.configs.base import ModelConfig, reduced

CONFIG = ModelConfig(
    name="whisper-medium",
    family="audio",
    num_layers=24,  # decoder layers
    num_encoder_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=4096,
    vocab_size=51_865,
    activation="gelu",
    encoder_decoder=True,
    audio_stub=True,
    source="arXiv:2212.04356",
)

SMOKE = reduced(CONFIG)
