"""mamba2-130m [ssm] — attention-free SSD. [arXiv:2405.21060]"""
from repro.configs.base import ModelConfig, reduced

CONFIG = ModelConfig(
    name="mamba2-130m",
    family="ssm",
    num_layers=24,
    d_model=768,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,  # attention-free, MLP-free: SSD blocks only (Mamba-2 design)
    vocab_size=50_280,
    ssm=True,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_chunk=128,
    activation="swiglu",
    source="arXiv:2405.21060",
)

SMOKE = reduced(CONFIG, num_heads=0, num_kv_heads=0)
