"""minitron-4b [dense] — pruned Nemotron-4 (GQA, squared-ReLU). [arXiv:2407.14679]"""
from repro.configs.base import ModelConfig, reduced

CONFIG = ModelConfig(
    name="minitron-4b",
    family="dense",
    num_layers=32,
    d_model=3072,
    num_heads=24,
    num_kv_heads=8,
    d_ff=9216,
    vocab_size=256_000,
    activation="sqrelu",
    source="arXiv:2407.14679",
)

SMOKE = reduced(CONFIG)
