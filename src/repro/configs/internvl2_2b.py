"""internvl2-2b [vlm] — InternViT (stub) + InternLM2 LM backbone.
[arXiv:2404.16821]

The vision encoder is a stub: ``input_specs`` supplies precomputed patch
embeddings (B, 256, 1024); the framework implements the projector and the
language decoder that consume them.
"""
from repro.configs.base import ModelConfig, reduced

CONFIG = ModelConfig(
    name="internvl2-2b",
    family="vlm",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=92_553,
    vlm_stub=True,
    num_patches=256,
    vision_dim=1024,
    activation="swiglu",
    source="arXiv:2404.16821",
)

SMOKE = reduced(CONFIG)
