"""Paper measurement tables (Tables III & IV) and scenario builders (§VI-A).

AlexNet: 8 blocks / 9 partition points, Jetson Xavier NX **CPU**
(f ∈ [0.1, 1.2] GHz, κ = 0.8e-27).
ResNet152: 9 blocks / 10 partition points, Jetson Xavier NX **GPU**
(f ∈ [0.2, 0.8] GHz, κ = 2.8e-27).
VM: GeForce RTX 4080. The paper does not print the VM-side time table;
we synthesize it from the remaining-work fraction with a full-model edge
inference time calibrated to the RTX 4080 class (see DESIGN.md §2), and a
10% coefficient of variation for its (small) variance — consistent with
Fig. 5's "significantly reduced" variation on the 4080.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.blocks import BlockChain, Fleet, Link, Platform
from repro.core.channel import pathloss_gain

MB_TO_BITS = 8.0e6
GHZ = 1.0e9
MS2_TO_S2 = 1.0e-6


class PaperScenario(NamedTuple):
    name: str
    fleet_fn: object  # (key, n_devices) -> Fleet
    bandwidth_hz: float
    deadline_s: float
    eps: float


# ---------------------------------------------------------------- AlexNet
# Table III — Jetson Xavier NX CPU. Index = partition point m ∈ {0..8}.
ALEXNET_D_MB = [0.574, 0.74, 0.18, 0.53, 0.12, 0.25, 0.17, 0.04, 0.001]
ALEXNET_W_GFLOPS = [0.0, 0.1407, 0.1411, 0.5891, 0.5894, 0.8137, 1.3122, 1.3123, 1.4214]
ALEXNET_G = [1.0, 6.8994, 6.3283, 13.6064, 13.1861, 14.6624, 16.4237, 16.1219, 7.1037]
ALEXNET_VLOC_MS2 = [0.0, 37.341, 43.084, 59.616, 63.942, 74.801, 95.073, 98.876, 105.886]
ALEXNET_PLATFORM = dict(kappa=0.8e-27, f_min=0.1 * GHZ, f_max=1.2 * GHZ)
ALEXNET_VM_FULL_S = 6.0e-3  # full-model edge inference on RTX 4080

# --------------------------------------------------------------- ResNet152
# Table IV — Jetson Xavier NX GPU. Index = partition point m ∈ {0..9}.
RESNET152_D_MB = [0.574, 3.06, 0.77, 1.53, 0.38, 0.19, 0.19, 0.19, 0.1, 0.001]
RESNET152_W_GFLOPS = [0.0, 0.2392, 1.4864, 3.6585, 5.3099, 9.9984, 13.9389, 17.8794, 21.9228, 23.1064]
RESNET152_G = [1.0, 315.4525, 309.6695, 323.764, 329.809, 325.6815, 324.1615, 322.734, 318.6457, 307.6753]
RESNET152_VLOC_MS2 = [0.0, 0.097, 1.31, 5.677, 13.934, 14.076, 15.881, 23.408, 32.256, 32.727]
RESNET152_PLATFORM = dict(kappa=2.8e-27, f_min=0.2 * GHZ, f_max=0.8 * GHZ)
RESNET152_VM_FULL_S = 12.0e-3

TX_POWER_W = 1.0
AREA_M = 400.0
VM_CV = 0.10  # RTX-4080 time coefficient of variation


def build_chain(d_mb, w_gflops, g_eff, v_loc_ms2, vm_full_s, vm_cv=VM_CV) -> BlockChain:
    d = jnp.asarray(d_mb, jnp.float64) * MB_TO_BITS
    w = jnp.asarray(w_gflops, jnp.float64) * 1e9
    g = jnp.asarray(g_eff, jnp.float64)
    v = jnp.asarray(v_loc_ms2, jnp.float64) * MS2_TO_S2
    frac_left = (w[-1] - w) / jnp.maximum(w[-1], 1.0)
    t_vm = vm_full_s * frac_left  # mean edge time of blocks m+1..M
    v_vm = (vm_cv * t_vm) ** 2
    return BlockChain(d_bits=d, w_flops=w, g_eff=g, v_loc=v, t_vm=t_vm, v_vm=v_vm)


def alexnet_chain() -> BlockChain:
    return build_chain(ALEXNET_D_MB, ALEXNET_W_GFLOPS, ALEXNET_G, ALEXNET_VLOC_MS2, ALEXNET_VM_FULL_S)


def resnet152_chain() -> BlockChain:
    return build_chain(
        RESNET152_D_MB, RESNET152_W_GFLOPS, RESNET152_G, RESNET152_VLOC_MS2, RESNET152_VM_FULL_S
    )


def _fleet(chain: BlockChain, platform: dict, key, n_devices: int) -> Fleet:
    """Devices uniform in a 400 m × 400 m square, edge node at the center."""
    xy = jax.random.uniform(key, (n_devices, 2), jnp.float64, -AREA_M / 2, AREA_M / 2)
    r = jnp.maximum(jnp.linalg.norm(xy, axis=-1), 5.0)  # ≥ 5 m
    gain = pathloss_gain(r)
    tile = lambda a: jnp.broadcast_to(jnp.asarray(a, jnp.float64), (n_devices,) + jnp.shape(a))
    return Fleet(
        chain=BlockChain(*[tile(x) for x in chain]),
        platform=Platform(
            kappa=tile(platform["kappa"]),
            f_min=tile(platform["f_min"]),
            f_max=tile(platform["f_max"]),
        ),
        link=Link(p_tx=tile(TX_POWER_W), gain=gain),
    )


def alexnet_fleet(key, n_devices: int) -> Fleet:
    return _fleet(alexnet_chain(), ALEXNET_PLATFORM, key, n_devices)


def resnet152_fleet(key, n_devices: int) -> Fleet:
    return _fleet(resnet152_chain(), RESNET152_PLATFORM, key, n_devices)


# §VI defaults (Figs. 13/14): N=12; AlexNet B=10 MHz, D=180 ms;
# ResNet152 B=30 MHz, D=120 ms.
ALEXNET_SCENARIO = PaperScenario("alexnet", alexnet_fleet, 10e6, 0.180, 0.02)
RESNET152_SCENARIO = PaperScenario("resnet152", resnet152_fleet, 30e6, 0.120, 0.04)


def _pad_chain(chain: BlockChain, to_points: int) -> BlockChain:
    """Pad a chain to ``to_points`` by repeating the terminal point (a
    duplicate full-local partition point — harmless for the planner)."""
    pad = to_points - chain.num_points
    if pad <= 0:
        return chain
    rep = lambda a: jnp.concatenate([a, jnp.repeat(a[-1:], pad, axis=0)])
    return BlockChain(*[rep(x) for x in chain])


def mixed_fleet(key, n_devices: int) -> Fleet:
    """Heterogeneous fleet: even devices run AlexNet on the NX CPU, odd
    devices ResNet152 on the NX GPU (the paper's fleets are homogeneous;
    the planner handles per-device chains/platforms natively)."""
    a_chain = _pad_chain(alexnet_chain(), 10)
    r_chain = resnet152_chain()
    xy = jax.random.uniform(key, (n_devices, 2), jnp.float64, -AREA_M / 2, AREA_M / 2)
    r = jnp.maximum(jnp.linalg.norm(xy, axis=-1), 5.0)
    is_alex = (jnp.arange(n_devices) % 2) == 0

    def pick(a_val, r_val):
        a = jnp.broadcast_to(jnp.asarray(a_val, jnp.float64),
                             (n_devices,) + jnp.shape(a_val))
        b = jnp.broadcast_to(jnp.asarray(r_val, jnp.float64),
                             (n_devices,) + jnp.shape(r_val))
        mask = is_alex.reshape((n_devices,) + (1,) * (a.ndim - 1))
        return jnp.where(mask, a, b)

    chain = BlockChain(*[pick(a, b) for a, b in zip(a_chain, r_chain)])
    plat = Platform(
        kappa=pick(ALEXNET_PLATFORM["kappa"], RESNET152_PLATFORM["kappa"]),
        f_min=pick(ALEXNET_PLATFORM["f_min"], RESNET152_PLATFORM["f_min"]),
        f_max=pick(ALEXNET_PLATFORM["f_max"], RESNET152_PLATFORM["f_max"]),
    )
    return Fleet(chain=chain, platform=plat,
                 link=Link(p_tx=jnp.full((n_devices,), TX_POWER_W, jnp.float64),
                           gain=pathloss_gain(r)))
