"""Paper measurement tables (Tables III & IV) and scenario builders (§VI-A).

AlexNet: 8 blocks / 9 partition points, Jetson Xavier NX **CPU**
(f ∈ [0.1, 1.2] GHz, κ = 0.8e-27).
ResNet152: 9 blocks / 10 partition points, Jetson Xavier NX **GPU**
(f ∈ [0.2, 0.8] GHz, κ = 2.8e-27).
VM: GeForce RTX 4080. The paper does not print the VM-side time table;
we synthesize it from the remaining-work fraction with a full-model edge
inference time calibrated to the RTX 4080 class (see DESIGN.md §2), and a
10% coefficient of variation for its (small) variance — consistent with
Fig. 5's "significantly reduced" variation on the 4080.
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

from repro.core.blocks import BlockChain, Fleet
from repro.core.fleet import DeviceSpec, FleetSpec

MB_TO_BITS = 8.0e6
GHZ = 1.0e9
MS2_TO_S2 = 1.0e-6


class PaperScenario(NamedTuple):
    name: str
    fleet_fn: object  # (key, n_devices) -> Fleet
    bandwidth_hz: float
    deadline_s: float
    eps: float


# ---------------------------------------------------------------- AlexNet
# Table III — Jetson Xavier NX CPU. Index = partition point m ∈ {0..8}.
ALEXNET_D_MB = [0.574, 0.74, 0.18, 0.53, 0.12, 0.25, 0.17, 0.04, 0.001]
ALEXNET_W_GFLOPS = [0.0, 0.1407, 0.1411, 0.5891, 0.5894, 0.8137, 1.3122, 1.3123, 1.4214]
ALEXNET_G = [1.0, 6.8994, 6.3283, 13.6064, 13.1861, 14.6624, 16.4237, 16.1219, 7.1037]
ALEXNET_VLOC_MS2 = [0.0, 37.341, 43.084, 59.616, 63.942, 74.801, 95.073, 98.876, 105.886]
ALEXNET_PLATFORM = dict(kappa=0.8e-27, f_min=0.1 * GHZ, f_max=1.2 * GHZ)
ALEXNET_VM_FULL_S = 6.0e-3  # full-model edge inference on RTX 4080

# --------------------------------------------------------------- ResNet152
# Table IV — Jetson Xavier NX GPU. Index = partition point m ∈ {0..9}.
RESNET152_D_MB = [0.574, 3.06, 0.77, 1.53, 0.38, 0.19, 0.19, 0.19, 0.1, 0.001]
RESNET152_W_GFLOPS = [0.0, 0.2392, 1.4864, 3.6585, 5.3099, 9.9984, 13.9389, 17.8794, 21.9228, 23.1064]
RESNET152_G = [1.0, 315.4525, 309.6695, 323.764, 329.809, 325.6815, 324.1615, 322.734, 318.6457, 307.6753]
RESNET152_VLOC_MS2 = [0.0, 0.097, 1.31, 5.677, 13.934, 14.076, 15.881, 23.408, 32.256, 32.727]
RESNET152_PLATFORM = dict(kappa=2.8e-27, f_min=0.2 * GHZ, f_max=0.8 * GHZ)
RESNET152_VM_FULL_S = 12.0e-3

TX_POWER_W = 1.0
AREA_M = 400.0
VM_CV = 0.10  # RTX-4080 time coefficient of variation


def build_chain(d_mb, w_gflops, g_eff, v_loc_ms2, vm_full_s, vm_cv=VM_CV) -> BlockChain:
    d = jnp.asarray(d_mb, jnp.float64) * MB_TO_BITS
    w = jnp.asarray(w_gflops, jnp.float64) * 1e9
    g = jnp.asarray(g_eff, jnp.float64)
    v = jnp.asarray(v_loc_ms2, jnp.float64) * MS2_TO_S2
    frac_left = (w[-1] - w) / jnp.maximum(w[-1], 1.0)
    t_vm = vm_full_s * frac_left  # mean edge time of blocks m+1..M
    v_vm = (vm_cv * t_vm) ** 2
    return BlockChain(d_bits=d, w_flops=w, g_eff=g, v_loc=v, t_vm=t_vm, v_vm=v_vm)


def alexnet_chain() -> BlockChain:
    return build_chain(ALEXNET_D_MB, ALEXNET_W_GFLOPS, ALEXNET_G, ALEXNET_VLOC_MS2, ALEXNET_VM_FULL_S)


def resnet152_chain() -> BlockChain:
    return build_chain(
        RESNET152_D_MB, RESNET152_W_GFLOPS, RESNET152_G, RESNET152_VLOC_MS2, RESNET152_VM_FULL_S
    )


def _spec(chain: BlockChain, platform: dict, n_devices: int, name: str) -> DeviceSpec:
    return DeviceSpec(chain=chain, kappa=platform["kappa"],
                      f_min_hz=platform["f_min"], f_max_hz=platform["f_max"],
                      p_tx_w=TX_POWER_W, count=n_devices, name=name)


def _fleet(chain: BlockChain, platform: dict, key, n_devices: int) -> Fleet:
    """Devices uniform in a 400 m × 400 m square, edge node at the center."""
    return FleetSpec((_spec(chain, platform, n_devices, "paper"),),
                     area_m=AREA_M).build(key)


def alexnet_fleet(key, n_devices: int) -> Fleet:
    return _fleet(alexnet_chain(), ALEXNET_PLATFORM, key, n_devices)


def resnet152_fleet(key, n_devices: int) -> Fleet:
    return _fleet(resnet152_chain(), RESNET152_PLATFORM, key, n_devices)


# §VI defaults (Figs. 13/14): N=12; AlexNet B=10 MHz, D=180 ms;
# ResNet152 B=30 MHz, D=120 ms.
ALEXNET_SCENARIO = PaperScenario("alexnet", alexnet_fleet, 10e6, 0.180, 0.02)
RESNET152_SCENARIO = PaperScenario("resnet152", resnet152_fleet, 30e6, 0.120, 0.04)


def mixed_spec(n_devices: int) -> FleetSpec:
    """Heterogeneous spec: AlexNet on the NX CPU (9 points) and ResNet152
    on the NX GPU (10 points) sharing one bandwidth budget. A genuinely
    *ragged* fleet — the AlexNet rows are padded to 10 points with a
    ``valid`` mask (the paper's fleets are homogeneous; the planner
    handles per-device chains/platforms/M_n natively)."""
    n_alex = (n_devices + 1) // 2
    return FleetSpec(
        (_spec(alexnet_chain(), ALEXNET_PLATFORM, n_alex, "alexnet"),
         _spec(resnet152_chain(), RESNET152_PLATFORM, n_devices - n_alex,
               "resnet152")),
        area_m=AREA_M)


def mixed_fleet(key, n_devices: int) -> Fleet:
    """Padded ragged two-model fleet (see ``mixed_spec``)."""
    return mixed_spec(n_devices).build(key)
