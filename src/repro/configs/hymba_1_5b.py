"""hymba-1.5b [hybrid] — parallel attention ‖ Mamba heads in each layer.
[arXiv:2411.13676]

25 attention heads (kv=5) run in parallel with SSM heads on the same
input; outputs are mean-fused (the paper's hybrid-head module).
"""
from repro.configs.base import ModelConfig, reduced

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    num_layers=32,
    d_model=1600,
    num_heads=25,
    num_kv_heads=5,
    d_ff=5504,
    vocab_size=32_001,
    head_dim=64,
    hybrid=True,
    ssm_state=16,
    ssm_head_dim=50,  # d_inner = 2·1600 = 3200 = 64 heads × 50
    # Hymba uses sliding-window attention in all but three layers (the SSM
    # heads carry the global context); we model the stack as fully windowed.
    # Added in §Perf iteration C1 — also what makes long_500k native here.
    sliding_window=1024,
    activation="swiglu",
    source="arXiv:2411.13676",
)

SMOKE = reduced(CONFIG, num_heads=4, num_kv_heads=2, ssm_head_dim=32)
