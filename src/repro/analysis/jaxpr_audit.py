"""Layer 2: jaxpr/compile audit (DESIGN.md §analysis).

Where Layer 1 reads source, this layer checks the *actually traced*
programs: it runs ``jax.make_jaxpr`` over the real entry points at tiny
sizes and asserts graph-level invariants —

- **no host callbacks** (``pure_callback``/``io_callback``/debug
  prints): a callback in the planner hot path means a device→host sync
  per call;
- **no weak-type leaks** on outputs, and only contract dtypes
  (float64/int32/bool — the planner is an x64 precision island; a
  float32 output means an accidental downcast, an int64 output an
  unstable integer leaf);
- **no giant baked-in constants**: closures must capture only small
  index/schedule tables (≤ ``contracts.CONST_BYTE_BUDGET``), never a
  fleet or profile table that should be an argument;
- **pytree contracts**: ``Scenario``/``Plan``/``Allocation``/
  ``FaultState`` flatten to the declared leaf paths and dtypes, in
  order — what golden files and any scan/cond over plans assume;
- **recompile counting**: :class:`CompileCounter` hooks jax's
  compile-event monitoring so tests (and the CI drill) can pin "this
  K-scenario sweep compiled exactly once".
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import contracts

__all__ = [
    "AuditProblem", "EntryAudit", "CompileCounter", "audit_jaxpr",
    "check_pytree_contract", "run_audit", "tiny_fleet",
]

#: substrings of primitive names that imply a host round-trip
_CALLBACK_MARKERS = ("callback", "infeed", "outfeed", "python_callback")


@dataclass(frozen=True)
class AuditProblem:
    entry: str
    kind: str  # "callback" | "weak_type" | "dtype" | "const_budget" | "pytree"
    detail: str

    def render(self) -> str:
        return f"{self.entry}: [{self.kind}] {self.detail}"


@dataclass
class EntryAudit:
    entry: str
    problems: List[AuditProblem] = field(default_factory=list)
    num_eqns: int = 0
    const_bytes: int = 0
    out_dtypes: Tuple[str, ...] = ()

    @property
    def ok(self) -> bool:
        return not self.problems


def _iter_jaxprs(jaxpr):
    """Yield `jaxpr` and every sub-jaxpr reachable through eqn params
    (scan/while/cond bodies, custom_jvp closures, pjit calls, ...)."""
    seen = set()
    stack = [jaxpr]
    while stack:
        j = stack.pop()
        if id(j) in seen:
            continue
        seen.add(id(j))
        yield j
        for eqn in j.eqns:
            for val in eqn.params.values():
                for sub in _sub_jaxprs(val):
                    stack.append(sub)


def _sub_jaxprs(val):
    if isinstance(val, jax.core.ClosedJaxpr):
        yield val.jaxpr
    elif isinstance(val, jax.core.Jaxpr):
        yield val
    elif isinstance(val, (list, tuple)):
        for v in val:
            yield from _sub_jaxprs(v)


_ALLOWED_OUT = tuple(sorted(contracts.ALLOWED_OUTPUT_DTYPES))


def audit_jaxpr(closed: jax.core.ClosedJaxpr, *, entry: str,
                const_budget: int = contracts.CONST_BYTE_BUDGET,
                allowed_out_dtypes: Sequence[str] = _ALLOWED_OUT,
                ) -> EntryAudit:
    """Graph-level invariants on one traced program."""
    audit = EntryAudit(entry=entry)
    num_eqns = 0
    for j in _iter_jaxprs(closed.jaxpr):
        for eqn in j.eqns:
            num_eqns += 1
            name = eqn.primitive.name
            if any(m in name for m in _CALLBACK_MARKERS):
                audit.problems.append(AuditProblem(
                    entry, "callback",
                    f"primitive `{name}` — host round-trip inside the "
                    "compiled program"))
    audit.num_eqns = num_eqns

    const_bytes = 0
    for c in closed.consts:
        arr = np.asarray(c)
        const_bytes += arr.nbytes
    audit.const_bytes = const_bytes
    if const_bytes > const_budget:
        audit.problems.append(AuditProblem(
            entry, "const_budget",
            f"{const_bytes} bytes of baked-in constants exceed the "
            f"{const_budget}-byte budget — a fleet/profile table leaked "
            "into a closure instead of being an argument"))

    out: List[str] = []
    for av in closed.jaxpr.outvars:
        aval = av.aval
        dt = str(getattr(aval, "dtype", ""))
        out.append(dt)
        if getattr(aval, "weak_type", False):
            audit.problems.append(AuditProblem(
                entry, "weak_type",
                f"output aval {aval} is weakly typed — a Python scalar "
                "leaked into the output dtype lattice"))
        if dt and dt not in allowed_out_dtypes:
            audit.problems.append(AuditProblem(
                entry, "dtype",
                f"output dtype {dt} is outside the contract "
                f"{tuple(allowed_out_dtypes)} (float64 island, stable "
                "int32/bool integer leaves)"))
    audit.out_dtypes = tuple(out)
    return audit


# ---------------------------------------------------------------------------
# Pytree contracts
# ---------------------------------------------------------------------------


def check_pytree_contract(name: str, tree: Any) -> List[AuditProblem]:
    """Flattened (path, dtype) pairs must match ``contracts.PYTREE_CONTRACTS``
    exactly — count, order, and dtype."""
    from jax.tree_util import keystr, tree_flatten_with_path

    expected = contracts.PYTREE_CONTRACTS[name]
    leaves, _ = tree_flatten_with_path(tree)
    got = tuple((keystr(path), str(jnp.asarray(leaf).dtype))
                for path, leaf in leaves)
    problems: List[AuditProblem] = []
    if len(got) != len(expected):
        problems.append(AuditProblem(
            name, "pytree",
            f"{len(got)} leaves, contract declares {len(expected)} — "
            "a leaf was added/removed; golden files and scans assume the "
            "declared flattening"))
    for i, ((gp, gd), (ep, ed)) in enumerate(zip(got, expected, strict=False)):
        if gp != ep:
            problems.append(AuditProblem(
                name, "pytree",
                f"leaf {i} is {gp}, contract says {ep} (order/rename drift)"))
        elif gd != ed:
            problems.append(AuditProblem(
                name, "pytree", f"leaf {gp} has dtype {gd}, contract says {ed}"))
    weak = [(keystr(p), leaf) for p, leaf in leaves
            if getattr(jnp.asarray(leaf), "weak_type", False)]
    for path, _ in weak:
        problems.append(AuditProblem(
            name, "pytree", f"leaf {path} is weakly typed"))
    return problems


# ---------------------------------------------------------------------------
# Recompile counting
# ---------------------------------------------------------------------------


class CompileCounter:
    """Counts real XLA backend compiles via ``jax.monitoring``.

    jax has no listener-unregister API, so one module-level listener is
    installed on first use and forwards to whichever counters are
    active (re-entrant: nested counters both see the event).

    Usage::

        with CompileCounter() as c:
            plan_many_jit(...)   # first call compiles
            plan_many_jit(...)   # same shapes/statics: cache hit
        assert c.count == 1
    """

    _lock = threading.Lock()
    _installed = False
    _active: List["CompileCounter"] = []

    def __init__(self) -> None:
        self.count = 0

    @classmethod
    def _listener(cls, event: str, duration: float, **kwargs) -> None:
        if "backend_compile" not in event:
            return
        with cls._lock:
            for c in cls._active:
                c.count += 1

    @classmethod
    def _install(cls) -> None:
        with cls._lock:
            if not cls._installed:
                jax.monitoring.register_event_duration_secs_listener(
                    cls._listener)
                cls._installed = True

    def __enter__(self) -> "CompileCounter":
        self._install()
        with self._lock:
            self._active.append(self)
        return self

    def __exit__(self, *exc) -> None:
        with self._lock:
            self._active.remove(self)


# ---------------------------------------------------------------------------
# Entry-point sweep
# ---------------------------------------------------------------------------


def tiny_fleet(n: int = 3):
    """Smallest representative fleet (AlexNet tables, n devices)."""
    from repro.configs.paper_tables import alexnet_fleet

    return alexnet_fleet(jax.random.PRNGKey(0), n)


def _trace_entries(n: int = 3) -> List[Tuple[str, jax.core.ClosedJaxpr]]:
    """make_jaxpr over the real public entry points at tiny sizes."""
    from repro.core.api import Planner, PlannerConfig, Scenario, stack_scenarios
    from repro.core.ccp import sigma_cantelli
    from repro.core.montecarlo import violation_report
    from repro.core.pccp import _inner_spec
    from repro.core.planner import plan_fixed_partition
    from repro.serve.faults import FaultState
    from repro.solvers.ipm import structured_barrier_solve

    fleet = tiny_fleet(n)
    sc = Scenario(deadline=0.18, eps=0.02, B=10e6).normalized(n)
    planner = Planner(PlannerConfig(policy="robust", multi_start=2))
    key = jax.random.PRNGKey(7)
    m0 = jnp.zeros((n,), jnp.int32)
    faults = FaultState.identity()._replace(
        vm_mean_scale=jnp.asarray(3.0, jnp.float64))

    entries: List[Tuple[str, jax.core.ClosedJaxpr]] = []

    def add(name, fn, *args, **kwargs):
        entries.append((name, jax.make_jaxpr(fn, **kwargs)(*args)))

    add("Planner.plan", lambda f, s: planner.plan(f, s), fleet, sc)
    scs = stack_scenarios([sc, sc._replace(deadline=sc.deadline * 1.1)], n)
    add("Planner.plan_many", lambda f, s: planner.plan_many(f, s), fleet, scs)
    add("Planner.grid",
        lambda f, d, e: planner.grid(f, d, e, 10e6),
        fleet, jnp.asarray([0.15, 0.18]), jnp.asarray([0.02, 0.05]))
    # a PCCP inner problem — the exact spec the planner hot loop solves
    m1 = 7
    e_tab = jnp.linspace(0.05, 0.9, m1)
    t_tab = jnp.linspace(0.01, 0.12, m1)
    v_tab = jnp.linspace(1e-6, 2e-4, m1)
    x_prev = jnp.full((m1,), 1.0 / m1)
    y_prev = jnp.sqrt(jnp.dot(v_tab, x_prev**2))
    spec, z0 = _inner_spec(e_tab, t_tab, v_tab, sigma_cantelli(jnp.asarray(0.05)),
                           jnp.asarray(0.12), 10.0, x_prev, y_prev)
    # spec is closed over, not passed: its index metadata is trace-time
    # static by construction (the planner builds it inside the jit)
    add("structured_barrier_solve",
        lambda z: structured_barrier_solve(spec, z), z0)
    add("violation_report",
        lambda k, f, m: violation_report(
            k, f, m, plan_fixed_partition(f, m, sc.deadline, sc.eps,
                                          sc.B).alloc,
            sc.deadline, num_samples=8),
        key, fleet, m0)
    add("violation_report+faults",
        lambda k, f, m, st: violation_report(
            k, f, m, plan_fixed_partition(f, m, sc.deadline, sc.eps,
                                          sc.B).alloc,
            sc.deadline, num_samples=8, faults=st),
        key, fleet, m0, faults)
    add("closedloop.step(plan_fixed_partition)",
        lambda f, m, d, e, b: plan_fixed_partition(f, m, d, e, b),
        fleet, m0, sc.deadline, sc.eps, sc.B)
    return entries


def run_audit(n: int = 3) -> Dict[str, Any]:
    """Full Layer-2 sweep; returns a JSON-ready report dict."""
    from repro.core.api import Scenario
    from repro.core.planner import Plan
    from repro.serve.faults import FaultState

    report: Dict[str, Any] = {"entries": {}, "pytrees": {}, "problems": []}
    for name, closed in _trace_entries(n):
        audit = audit_jaxpr(closed, entry=name)
        report["entries"][name] = {
            "ok": audit.ok,
            "num_eqns": audit.num_eqns,
            "const_bytes": audit.const_bytes,
            "out_dtypes": sorted(set(audit.out_dtypes)),
            "problems": [p.render() for p in audit.problems],
        }
        report["problems"] += [p.render() for p in audit.problems]

    fleet = tiny_fleet(n)
    sc = Scenario(deadline=0.18, eps=0.02, B=10e6).normalized(n)
    from repro.core.api import Planner, PlannerConfig

    examples = {
        "Scenario": sc,
        "Plan": Planner(PlannerConfig(policy="robust")).plan(fleet, sc),
        "FaultState": FaultState.identity(),
    }
    examples["Allocation"] = examples["Plan"].alloc
    assert isinstance(examples["Plan"], Plan)
    for name, tree in examples.items():
        probs = check_pytree_contract(name, tree)
        report["pytrees"][name] = {
            "ok": not probs, "problems": [p.render() for p in probs]}
        report["problems"] += [p.render() for p in probs]

    # recompile drill: a 4-scenario sweep reuses one compiled program —
    # the second (value-varied) call must not trigger any backend compile
    from repro.core.api import plan_many_jit, stack_scenarios, _BATCH_STATICS  # noqa: F401
    planner = Planner(PlannerConfig(policy="robust"))
    scs = stack_scenarios([
        sc._replace(deadline=jnp.full_like(sc.deadline, 0.15 + 0.01 * i))
        for i in range(4)], n)
    planner.plan_many(fleet, scs)  # warm the cache
    with CompileCounter() as c:
        varied = stack_scenarios([
            sc._replace(deadline=jnp.full_like(sc.deadline, 0.16 + 0.01 * i))
            for i in range(4)], n)
        jax.block_until_ready(planner.plan_many(fleet, varied).total_energy)
    report["recompile_drill"] = {
        "ok": c.count == 0,
        "backend_compiles_on_value_varied_repeat": c.count,
    }
    if c.count:
        report["problems"].append(
            f"recompile_drill: {c.count} backend compiles on a value-varied "
            "plan_many repeat — a scenario knob became static")

    # group-sharded drill: the decomposed planner compiles one program per
    # distinct (M_g, n_bucket) group shape; a value-varied repeat (new
    # scenario values AND new gains, same group shapes) must compile zero
    # times per group — prices/gains are traced operands, never baked in.
    from repro.configs.paper_tables import mixed_spec

    spec = mixed_spec(8)
    sharded = Planner(PlannerConfig(policy="robust_exact", outer_iters=2))
    k1, k2 = jax.random.split(jax.random.PRNGKey(3))
    sharded.plan_sharded(spec, Scenario(deadline=0.2, eps=0.04, B=30e6),
                         key=k1)  # warm
    with CompileCounter() as cs:
        varied = sharded.plan_sharded(
            spec, Scenario(deadline=0.21, eps=0.05, B=28e6), key=k2)
        jax.block_until_ready(varied.total_energy)
    report["sharded_recompile_drill"] = {
        "ok": cs.count == 0,
        "backend_compiles_on_value_varied_repeat": cs.count,
    }
    if cs.count:
        report["problems"].append(
            f"sharded_recompile_drill: {cs.count} backend compiles on a "
            "value-varied plan_sharded repeat — a per-group program is "
            "recompiling on scenario/gain values")

    # placement drill: per-node capacity vectors are traced operands of
    # the same compiled program — a value-varied (E,) capacity repeat
    # (same E, different node budgets) must trigger zero backend compiles.
    caps0 = jnp.asarray([0.08, 0.05, 0.03], jnp.float64)
    planner.plan(fleet, sc._replace(edge_capacity_s=caps0))  # warm
    with CompileCounter() as cp:
        shifted = sc._replace(
            edge_capacity_s=jnp.asarray([0.06, 0.07, 0.02], jnp.float64))
        jax.block_until_ready(planner.plan(fleet, shifted).total_energy)
    report["placement_recompile_drill"] = {
        "ok": cp.count == 0,
        "backend_compiles_on_value_varied_repeat": cp.count,
    }
    if cp.count:
        report["problems"].append(
            f"placement_recompile_drill: {cp.count} backend compiles on a "
            "value-varied per-node capacity repeat — the capacity vector "
            "or assignment leaked into a static")

    # replay drill: the trace-driven epoch sampler pads request batches
    # to a static capacity, so a value-varied epoch — different request
    # count/devices, different key, different (E,) fault state — must
    # reuse the one compiled program.
    from repro.serve.faults import brownout, state_at
    from repro.serve.replay import sample_epoch

    rplan = planner.plan(fleet, sc._replace(edge_capacity_s=caps0))
    rsched = brownout(4, start=1, length=2, depth=0.5, node=1, num_nodes=3)
    dev = jnp.asarray([0, 1, 2, 0, 1, 2, 0, 1], jnp.int32)
    valid = jnp.arange(8) < 6
    key = jax.random.PRNGKey(9)
    # the value-varied operands are built eagerly BEFORE the counter —
    # the drill pins the epoch program, not jnp.roll's dispatch cache
    key2 = jax.random.fold_in(key, 1)
    dev2 = jnp.roll(dev, 1)
    valid2 = jnp.arange(8) < 4
    caps2 = caps0 * 0.7
    state0, state1 = state_at(rsched, 0), state_at(rsched, 1)
    sample_epoch(key, fleet, rplan.m_sel, rplan.alloc, sc.deadline, dev,
                 valid, 2.0, edge_capacity_s=caps0, faults=state0,
                 assignment=rplan.assignment)  # warm
    with CompileCounter() as cr:
        out = sample_epoch(
            key2, fleet, rplan.m_sel, rplan.alloc, sc.deadline, dev2,
            valid2, 3.0, edge_capacity_s=caps2, faults=state1,
            assignment=rplan.assignment)
        jax.block_until_ready(out.total_s)
    report["replay_recompile_drill"] = {
        "ok": cr.count == 0,
        "backend_compiles_on_value_varied_repeat": cr.count,
    }
    if cr.count:
        report["problems"].append(
            f"replay_recompile_drill: {cr.count} backend compiles on a "
            "value-varied replay epoch — a trace batch leaf (device_ids/"
            "valid/rounds) or fault state leaked into a static")

    report["ok"] = not report["problems"]
    return report
