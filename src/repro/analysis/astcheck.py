"""Layer 1: AST trace-discipline lint (DESIGN.md §analysis).

A package-level pass over ``repro.{core,solvers,serve,configs}`` that
flags source patterns breaking the one-compiled-program invariant. The
pass is deliberately heuristic — it runs a *syntactic taint analysis*
(function parameters are potentially-traced unless the declared static
contract says otherwise; ``.shape``/``len()``/``isinstance()``/``is
None`` projections untaint) over every *jit-reachable* function
(jit-wrapped, passed to a jax transform, called — by name — from a
reachable function, or listed in ``contracts.ANALYSIS_SURFACE``).

False positives are expected at the host/device boundary and are the
point: each one must carry an explicit ``# analyze: ok(RULE): reason``
annotation, turning implicit host-side escapes into reviewed,
documented ones.
"""
from __future__ import annotations

import ast
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, FrozenSet, List, Optional, Sequence, Set, Tuple

from repro.analysis import contracts
from repro.analysis.rules import Finding, Suppressions, parse_suppressions

__all__ = ["analyze_files", "analyze_repo", "DEFAULT_SUBPACKAGES"]

DEFAULT_SUBPACKAGES = ("core", "solvers", "serve", "configs")

#: builtin casts that force a host sync on a tracer
_CAST_BUILTINS = frozenset({"float", "int", "bool", "complex"})
#: attribute projections of an array that are static under tracing
_STATIC_ATTRS = frozenset(
    {"shape", "ndim", "dtype", "size", "itemsize"}
) | contracts.STATIC_PROPERTY_NAMES
#: calls whose result is always host-static
_STATIC_CALLS = frozenset({
    "len", "isinstance", "issubclass", "hasattr", "getattr", "type", "id",
    "repr", "str", "callable",
})
#: methods that materialize a tracer on the host
_MATERIALIZE_METHODS = frozenset({"item", "tolist", "block_until_ready"})
#: dotted prefixes that mean "this call builds/uses a jax array"
_JNP_PREFIXES = ("jax.numpy.", "jax.random.", "jax.nn.", "jax.scipy.")
#: jax transforms that take callables worth marking as trace roots
_TRANSFORMS = frozenset({
    "jax.jit", "jax.vmap", "jax.pmap", "jax.grad", "jax.value_and_grad",
    "jax.jacfwd", "jax.jacrev", "jax.hessian", "jax.checkpoint",
    "jax.remat", "jax.make_jaxpr", "jax.custom_jvp", "jax.custom_vjp",
    "jax.lax.scan", "jax.lax.while_loop", "jax.lax.fori_loop",
    "jax.lax.cond", "jax.lax.switch", "jax.lax.map",
    "jax.lax.associative_scan", "jax.tree_util.tree_map", "jax.tree.map",
})


# ---------------------------------------------------------------------------
# Per-module collection
# ---------------------------------------------------------------------------


@dataclass
class FuncInfo:
    qualname: str
    modname: str
    path: str
    node: ast.AST  # FunctionDef | AsyncFunctionDef
    params: Tuple[str, ...]
    has_var_kwargs: bool
    calls: Set[str] = field(default_factory=set)
    declared_statics: FrozenSet[str] = frozenset()
    is_root: bool = False
    reachable: bool = False
    parent: Optional["FuncInfo"] = None
    children: List["FuncInfo"] = field(default_factory=list)
    suppressed: FrozenSet[str] = frozenset()  # def-level escape hatch

    @property
    def bare_name(self) -> str:
        return self.qualname.rsplit(".", 1)[-1]

    def allows(self, rule: str) -> bool:
        if rule in self.suppressed:
            return True
        return self.parent.allows(rule) if self.parent is not None else False


@dataclass
class ModuleInfo:
    path: Path
    modname: str
    tree: ast.Module
    sup: Suppressions
    #: local name -> dotted origin ("numpy", "jax.numpy", "functools.partial")
    origins: Dict[str, str] = field(default_factory=dict)
    #: module-level `NAME = ("a", "b")` string tuples (static_argnames refs)
    str_tuples: Dict[str, Tuple[str, ...]] = field(default_factory=dict)
    functions: List[FuncInfo] = field(default_factory=list)

    def resolve(self, dotted: Optional[str]) -> Optional[str]:
        """Map a local dotted name to its import origin (np.x -> numpy.x)."""
        if dotted is None:
            return None
        head, _, rest = dotted.partition(".")
        origin = self.origins.get(head, head)
        return f"{origin}.{rest}" if rest else origin


def _dotted(node: ast.AST) -> Optional[str]:
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _collect_imports(mod: ModuleInfo) -> None:
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.Import):
            for alias in node.names:
                mod.origins[alias.asname or alias.name.split(".")[0]] = (
                    alias.name if alias.asname else alias.name.split(".")[0])
        elif isinstance(node, ast.ImportFrom) and node.module and node.level == 0:
            for alias in node.names:
                mod.origins[alias.asname or alias.name] = (
                    f"{node.module}.{alias.name}")


def _str_tuple(node: ast.AST, mod: ModuleInfo) -> Optional[Tuple[str, ...]]:
    """Evaluate a static_argnames expression: str / tuple of str / module
    constant / `+` concatenation thereof."""
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return (node.value,)
    if isinstance(node, (ast.Tuple, ast.List)):
        out: List[str] = []
        for elt in node.elts:
            if not (isinstance(elt, ast.Constant) and isinstance(elt.value, str)):
                return None
            out.append(elt.value)
        return tuple(out)
    if isinstance(node, ast.Name):
        return mod.str_tuples.get(node.id)
    if isinstance(node, ast.BinOp) and isinstance(node.op, ast.Add):
        left, right = _str_tuple(node.left, mod), _str_tuple(node.right, mod)
        if left is not None and right is not None:
            return left + right
    return None


def _def_suppressions(node: ast.AST, sup: Suppressions) -> FrozenSet[str]:
    """Escape hatches on the `def` line or a decorator line cover the
    whole function body."""
    lines = [node.lineno] + [d.lineno for d in getattr(node, "decorator_list", [])]
    out: Set[str] = set()
    for ln in lines:
        out |= sup.by_line.get(ln, frozenset())
    return frozenset(out)


class _Collector(ast.NodeVisitor):
    """Builds FuncInfos (incl. methods/nested defs), call-graph edges,
    jit-root marks and module-level findings for one module."""

    def __init__(self, mod: ModuleInfo, findings: List[Finding]):
        self.mod = mod
        self.findings = findings
        self.stack: List[FuncInfo] = []
        self.class_stack: List[str] = []
        self.jit_decls: List[Tuple[FuncInfo, FrozenSet[str], int]] = []

    # -- helpers ---------------------------------------------------------
    def _qual(self, name: str) -> str:
        scope = [f.qualname.rsplit(".", 1)[-1] for f in self.stack]
        return ".".join(self.class_stack + scope + [name])

    def _emit(self, rule: str, node: ast.AST, msg: str) -> None:
        func = self.stack[-1].qualname if self.stack else "<module>"
        line = getattr(node, "lineno", 1)
        if self.mod.sup.allows(line, rule):
            return
        if self.stack and self.stack[-1].allows(rule):
            return
        self.findings.append(Finding(
            rule=rule, path=str(self.mod.path), line=line,
            col=getattr(node, "col_offset", 0), message=msg, func=func))

    def _jit_static_argnames(self, call: ast.Call) -> Optional[FrozenSet[str]]:
        """If `call` is jax.jit(...) or partial(jax.jit, ...), return its
        static_argnames (possibly empty); else None."""
        fn = self.mod.resolve(_dotted(call.func))
        inner = call
        if fn == "functools.partial" and call.args \
                and self.mod.resolve(_dotted(call.args[0])) == "jax.jit":
            pass  # kwargs live on the partial call itself
        elif fn != "jax.jit":
            return None
        statics: FrozenSet[str] = frozenset()
        for kw in inner.keywords:
            if kw.arg in ("static_argnames", "static_argnums"):
                if kw.arg == "static_argnums":
                    self._emit("TRC006", call,
                               "use static_argnames, not positional "
                               "static_argnums — positions drift silently")
                    continue
                tup = _str_tuple(kw.value, self.mod)
                if tup is None:
                    self._emit("TRC006", kw.value,
                               "static_argnames is not resolvable to a "
                               "literal tuple of names — the analyzer (and "
                               "the reader) cannot check the contract")
                    return frozenset()
                statics = frozenset(tup)
        return statics

    # -- module-level statements ----------------------------------------
    def _module_level_scan(self, stmts: Sequence[ast.stmt]) -> None:
        for stmt in stmts:
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            if isinstance(stmt, ast.ClassDef):
                self._module_level_scan(stmt.body)
                continue
            for node in ast.walk(stmt):
                if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                     ast.Lambda)):
                    break
                if isinstance(node, ast.Call):
                    fn = self.mod.resolve(_dotted(node.func))
                    if fn and (fn.startswith(_JNP_PREFIXES)
                               or fn in ("jax.numpy", "jax.random")):
                        self._emit("TRC005", node,
                                   f"`{_dotted(node.func)}(...)` runs at "
                                   "import time — device work before "
                                   "config/flags are settled, and a baked "
                                   "constant in any trace that closes over it")

    def _module_assigns(self) -> None:
        for stmt in self.mod.tree.body:
            if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1 \
                    and isinstance(stmt.targets[0], ast.Name):
                tup = _str_tuple(stmt.value, self.mod)
                if tup is not None:
                    self.mod.str_tuples[stmt.targets[0].id] = tup

    # -- visitors --------------------------------------------------------
    def visit_ClassDef(self, node: ast.ClassDef) -> None:
        self.class_stack.append(node.name)
        self.generic_visit(node)
        self.class_stack.pop()

    def _visit_func(self, node) -> None:
        args = node.args
        params = tuple(
            a.arg for a in [*args.posonlyargs, *args.args, *args.kwonlyargs]
            if a.arg not in ("self", "cls"))
        info = FuncInfo(
            qualname=self._qual(node.name), modname=self.mod.modname,
            path=str(self.mod.path), node=node, params=params,
            has_var_kwargs=args.kwarg is not None,
            parent=self.stack[-1] if self.stack else None,
            suppressed=_def_suppressions(node, self.mod.sup))
        if info.parent is not None:
            info.parent.children.append(info)
        self.mod.functions.append(info)

        self._check_defaults(node, info)

        # decorator-declared jit
        for dec in node.decorator_list:
            fn = self.mod.resolve(_dotted(dec))
            if fn == "jax.jit":
                info.is_root = True
                self.jit_decls.append((info, frozenset(), dec.lineno))
            elif isinstance(dec, ast.Call):
                statics = self._jit_static_argnames(dec)
                if statics is not None:
                    info.is_root = True
                    info.declared_statics = statics
                    self.jit_decls.append((info, statics, dec.lineno))

        self.stack.append(info)
        for stmt in node.body:
            self.visit(stmt)
        self.stack.pop()

    visit_FunctionDef = _visit_func
    visit_AsyncFunctionDef = _visit_func

    def _check_defaults(self, node, info: FuncInfo) -> None:
        defaults = list(node.args.defaults) + [
            d for d in node.args.kw_defaults if d is not None]
        for d in defaults:
            if isinstance(d, (ast.List, ast.Dict, ast.Set, ast.ListComp,
                              ast.SetComp, ast.DictComp, ast.GeneratorExp)):
                kind = "mutable literal"
            elif isinstance(d, ast.Call):
                fn = self.mod.resolve(_dotted(d.func))
                if fn in ("tuple", "frozenset") and not d.args:
                    continue
                kind = "function call"
            else:
                continue
            line = getattr(d, "lineno", node.lineno)
            if self.mod.sup.allows(line, "TRC004") or info.allows("TRC004"):
                continue
            self.findings.append(Finding(
                rule="TRC004", path=str(self.mod.path), line=line,
                col=getattr(d, "col_offset", 0), func=info.qualname,
                message=f"{kind} default for a parameter of "
                        f"`{info.qualname}` is evaluated once at import "
                        "and shared across calls"))

    def visit_Call(self, node: ast.Call) -> None:
        fn = self.mod.resolve(_dotted(node.func))
        # call-graph edge by bare callee name
        dotted = _dotted(node.func)
        if self.stack is not None and self.stack:
            if dotted:
                self.stack[-1].calls.add(dotted.rsplit(".", 1)[-1])
            elif isinstance(node.func, ast.Attribute):
                self.stack[-1].calls.add(node.func.attr)
        # callables handed to jax transforms become trace roots
        if fn in _TRANSFORMS:
            for arg in node.args:
                name = _dotted(arg)
                if name:
                    self._mark_root_by_name(name.rsplit(".", 1)[-1])
        self.generic_visit(node)

    def _mark_root_by_name(self, bare: str) -> None:
        for f in self.mod.functions:
            if f.bare_name == bare:
                f.is_root = True

    def run(self) -> None:
        _collect_imports(self.mod)
        self._module_assigns()
        self._module_level_scan(self.mod.tree.body)
        self.visit(self.mod.tree)
        self._module_jit_assigns()
        if self.mod.sup.unjustified:
            for line in self.mod.sup.unjustified:
                self.findings.append(Finding(
                    rule="TRC000", path=str(self.mod.path), line=line, col=0,
                    message="escape hatch without a `: reason` tail"))

    def _module_jit_assigns(self) -> None:
        """`name = partial(jax.jit, static_argnames=S)(fn)` and
        `name = jax.jit(fn, static_argnames=S)` module-level wrappings."""
        by_name = {f.bare_name: f for f in self.mod.functions
                   if f.parent is None}
        for stmt in self.mod.tree.body:
            value = stmt.value if isinstance(stmt, (ast.Assign, ast.AnnAssign)) \
                else None
            if not isinstance(value, ast.Call):
                continue
            statics: Optional[FrozenSet[str]] = None
            wrapped: Optional[ast.AST] = None
            if isinstance(value.func, ast.Call):  # partial(jax.jit, ...)(fn)
                statics = self._jit_static_argnames(value.func)
                wrapped = value.args[0] if value.args else None
            else:  # jax.jit(fn, ...)
                statics = self._jit_static_argnames(value)
                wrapped = value.args[0] if value.args else None
            if statics is None:
                continue
            name = _dotted(wrapped) if wrapped is not None else None
            target = by_name.get(name.rsplit(".", 1)[-1]) if name else None
            if target is not None:
                target.is_root = True
                target.declared_statics = statics
                self.jit_decls.append((target, statics, stmt.lineno))


# ---------------------------------------------------------------------------
# TRC006: static/traced contract drift
# ---------------------------------------------------------------------------


def _check_static_contract(mod: ModuleInfo, info: FuncInfo,
                           statics: FrozenSet[str], line: int,
                           findings: List[Finding]) -> None:
    def emit(msg: str) -> None:
        if mod.sup.allows(line, "TRC006") or info.allows("TRC006"):
            return
        findings.append(Finding(
            rule="TRC006", path=str(mod.path), line=line, col=0,
            message=msg, func=info.qualname))

    params = set(info.params)
    for s in sorted(statics):
        if s not in params and not info.has_var_kwargs:
            emit(f"static_argnames names `{s}`, which is not a parameter "
                 f"of `{info.qualname}` — dead static, or a rename drifted")
        if s in contracts.TRACED_PARAM_NAMES:
            emit(f"`{s}` is a traced scenario knob by contract but is "
                 "declared static here — every distinct value recompiles")
    for p in sorted(params & contracts.STATIC_PARAM_NAMES - statics):
        emit(f"`{p}` is static by contract (code-path/shape selector) but "
             f"is not in static_argnames of `{info.qualname}`")


# ---------------------------------------------------------------------------
# Taint walk over reachable functions (TRC001/TRC002/TRC003)
# ---------------------------------------------------------------------------


class _TaintChecker:
    def __init__(self, mod: ModuleInfo, info: FuncInfo,
                 findings: List[Finding]):
        self.mod = mod
        self.info = info
        self.findings = findings
        self.env: Dict[str, bool] = {}
        for p in info.params:
            self.env[p] = (p not in contracts.STATIC_PARAM_NAMES
                           and p not in info.declared_statics)

    def _emit(self, rule: str, node: ast.AST, msg: str) -> None:
        line = getattr(node, "lineno", self.info.node.lineno)
        if self.mod.sup.allows(line, rule) or self.info.allows(rule):
            return
        self.findings.append(Finding(
            rule=rule, path=str(self.mod.path), line=line,
            col=getattr(node, "col_offset", 0), message=msg,
            func=self.info.qualname))

    # -- expressions -----------------------------------------------------
    def expr(self, node: Optional[ast.AST]) -> bool:
        """Emit findings inside `node` and return its taint."""
        if node is None or isinstance(node, ast.Constant):
            return False
        if isinstance(node, ast.Name):
            return self.env.get(node.id, False)
        if isinstance(node, ast.Attribute):
            if node.attr in _STATIC_ATTRS:
                self.expr(node.value)
                return False
            return self.expr(node.value)
        if isinstance(node, ast.Call):
            return self._call(node)
        if isinstance(node, ast.Compare):
            tainted = self.expr(node.left)
            for c in node.comparators:
                tainted |= self.expr(c)
            if all(isinstance(op, (ast.Is, ast.IsNot)) for op in node.ops):
                return False  # `x is None` is a trace-time gate
            return tainted
        if isinstance(node, ast.BoolOp):
            return any([self.expr(v) for v in node.values])
        if isinstance(node, ast.BinOp):
            return self.expr(node.left) | self.expr(node.right)
        if isinstance(node, ast.UnaryOp):
            return self.expr(node.operand)
        if isinstance(node, ast.IfExp):
            if self.expr(node.test):
                self._emit("TRC003", node,
                           "ternary on a potentially-traced value — use "
                           "jnp.where/lax.cond")
            return self.expr(node.body) | self.expr(node.orelse)
        if isinstance(node, ast.Subscript):
            return self.expr(node.value) | self.expr(node.slice)
        if isinstance(node, (ast.Tuple, ast.List, ast.Set)):
            return any([self.expr(e) for e in node.elts])
        if isinstance(node, ast.Dict):
            return any([self.expr(k) for k in node.keys if k is not None]) \
                | any([self.expr(v) for v in node.values])
        if isinstance(node, ast.Slice):
            return any([self.expr(s) for s in
                        (node.lower, node.upper, node.step)])
        if isinstance(node, ast.Starred):
            return self.expr(node.value)
        if isinstance(node, ast.Lambda):
            saved = dict(self.env)
            for a in node.args.args:
                self.env[a.arg] = False
            self.expr(node.body)  # findings only; opaque value
            self.env = saved
            return False
        if isinstance(node, (ast.ListComp, ast.SetComp, ast.GeneratorExp,
                             ast.DictComp)):
            return self._comp(node)
        if isinstance(node, ast.JoinedStr):
            for v in node.values:
                self.expr(v)
            return False
        if isinstance(node, ast.FormattedValue):
            self.expr(node.value)
            return False
        if isinstance(node, (ast.Await, ast.YieldFrom)):
            return self.expr(node.value)
        if isinstance(node, ast.Yield):
            return self.expr(node.value) if node.value else False
        return False

    def _comp(self, node) -> bool:
        saved = dict(self.env)
        tainted_iter = False
        for gen in node.generators:
            t = self.expr(gen.iter)
            tainted_iter |= t
            self._bind(gen.target, t)
            for cond in gen.ifs:
                self.expr(cond)
        if isinstance(node, ast.DictComp):
            out = self.expr(node.key) | self.expr(node.value)
        else:
            out = self.expr(node.elt)
        self.env = saved
        return out | tainted_iter

    def _call(self, node: ast.Call) -> bool:
        fn = self.mod.resolve(_dotted(node.func))
        bare = fn.rsplit(".", 1)[-1] if fn else None
        arg_taints = [self.expr(a) for a in node.args]
        arg_taints += [self.expr(kw.value) for kw in node.keywords]
        any_tainted = any(arg_taints)

        if bare in _CAST_BUILTINS and fn == bare:
            if any_tainted:
                self._emit("TRC001", node,
                           f"`{bare}()` on a potentially-traced value — "
                           "host sync; ConcretizationTypeError under jit")
            return False  # result is a host scalar
        if fn in _STATIC_CALLS:
            return False
        if isinstance(node.func, ast.Attribute) \
                and node.func.attr in _MATERIALIZE_METHODS:
            if self.expr(node.func.value):
                self._emit("TRC002", node,
                           f"`.{node.func.attr}()` on a potentially-traced "
                           "value — host materialization")
            return False
        if fn and (fn == "numpy" or fn.startswith("numpy.")):
            if any_tainted:
                self._emit("TRC002", node,
                           f"`{_dotted(node.func)}(...)` on a potentially-"
                           "traced value — silent host-numpy fallback")
            return False  # np results are host arrays
        if fn and (fn.startswith(_JNP_PREFIXES) or fn in ("jax.numpy",)):
            return True  # jnp results are (potential) tracers regardless
        func_taint = self.expr(node.func) if isinstance(
            node.func, (ast.Attribute, ast.Subscript, ast.Call)) else False
        return any_tainted or func_taint

    # -- statements ------------------------------------------------------
    def _bind(self, target: ast.AST, taint: bool) -> None:
        if isinstance(target, ast.Name):
            self.env[target.id] = taint
        elif isinstance(target, (ast.Tuple, ast.List)):
            for e in target.elts:
                self._bind(e, taint)
        elif isinstance(target, ast.Starred):
            self._bind(target.value, taint)
        # attribute/subscript targets: no local binding to track

    def stmt(self, node: ast.stmt) -> None:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            return  # nested defs are separate FuncInfos
        if isinstance(node, ast.Assign):
            t = self.expr(node.value)
            for tgt in node.targets:
                self._bind(tgt, t)
        elif isinstance(node, ast.AnnAssign):
            if node.value is not None:
                self._bind(node.target, self.expr(node.value))
        elif isinstance(node, ast.AugAssign):
            t = self.expr(node.value)
            if isinstance(node.target, ast.Name):
                self.env[node.target.id] = self.env.get(node.target.id,
                                                        False) | t
        elif isinstance(node, ast.If):
            if self.expr(node.test):
                self._emit("TRC003", node,
                           "`if` on a potentially-traced value — use "
                           "jnp.where/lax.cond so the branch stays traced")
            self._body(node.body)
            self._body(node.orelse)
        elif isinstance(node, ast.While):
            if self.expr(node.test):
                self._emit("TRC003", node,
                           "`while` on a potentially-traced value — use "
                           "lax.while_loop")
            self._body(node.body)
            self._body(node.orelse)
        elif isinstance(node, ast.Assert):
            if self.expr(node.test):
                self._emit("TRC003", node,
                           "`assert` on a potentially-traced value — "
                           "fails under jit; use checkify or a sentinel")
            if node.msg is not None:
                self.expr(node.msg)
        elif isinstance(node, ast.For):
            t = self.expr(node.iter)
            self._bind(node.target, t)
            self._body(node.body)
            self._body(node.orelse)
        elif isinstance(node, ast.With):
            for item in node.items:
                t = self.expr(item.context_expr)
                if item.optional_vars is not None:
                    self._bind(item.optional_vars, t)
            self._body(node.body)
        elif isinstance(node, ast.Try):
            self._body(node.body)
            for h in node.handlers:
                self._body(h.body)
            self._body(node.orelse)
            self._body(node.finalbody)
        elif isinstance(node, (ast.Return, ast.Expr)):
            self.expr(node.value)
        elif isinstance(node, ast.Raise):
            self.expr(node.exc)
            self.expr(node.cause)
        elif isinstance(node, ast.Delete):
            for tgt in node.targets:
                if isinstance(tgt, ast.Name):
                    self.env.pop(tgt.id, None)

    def _body(self, stmts: Sequence[ast.stmt]) -> None:
        for s in stmts:
            self.stmt(s)

    def run(self) -> None:
        self._body(self.info.node.body)


# ---------------------------------------------------------------------------
# Driver
# ---------------------------------------------------------------------------


def _matches_surface(info: FuncInfo) -> bool:
    for mod_suffix, qual in contracts.ANALYSIS_SURFACE:
        if info.qualname == qual and (
                not mod_suffix or info.modname.endswith(mod_suffix)):
            return True
    return False


def analyze_files(paths: Sequence[Path], src_root: Optional[Path] = None,
                  surface: bool = True) -> List[Finding]:
    """Run Layer 1 over `paths` (one shared call graph). With
    ``surface=False`` only jit-wrapped/transform-passed functions are
    reachability roots (fixture mode)."""
    findings: List[Finding] = []
    mods: List[ModuleInfo] = []
    collectors: List[_Collector] = []
    for path in paths:
        path = Path(path)
        source = path.read_text()
        sup = parse_suppressions(source)
        if sup.skip_file:
            continue
        if src_root is not None:
            rel = path.relative_to(src_root).with_suffix("")
            modname = ".".join(rel.parts)
        else:
            modname = path.stem
        mod = ModuleInfo(path=path, modname=modname,
                         tree=ast.parse(source, filename=str(path)), sup=sup)
        mods.append(mod)
        c = _Collector(mod, findings)
        c.run()
        collectors.append(c)

    # TRC006 on every jit declaration
    for mod, c in zip(mods, collectors, strict=True):
        for info, statics, line in c.jit_decls:
            _check_static_contract(mod, info, statics, line, findings)

    # reachability: roots -> named callees -> nested defs
    by_bare: Dict[str, List[FuncInfo]] = {}
    all_funcs: List[FuncInfo] = []
    for mod in mods:
        for f in mod.functions:
            all_funcs.append(f)
            by_bare.setdefault(f.bare_name, []).append(f)
    queue = [f for f in all_funcs
             if f.is_root or (surface and _matches_surface(f))]
    for f in queue:
        f.reachable = True
    while queue:
        f = queue.pop()
        nxt = list(f.children)
        for callee in f.calls:
            nxt.extend(by_bare.get(callee, ()))
        for g in nxt:
            if not g.reachable:
                g.reachable = True
                queue.append(g)

    func_of = {id(f): mod for mod in mods for f in mod.functions}
    for f in all_funcs:
        if f.reachable:
            _TaintChecker(func_of[id(f)], f, findings).run()

    uniq = {(f.rule, f.path, f.line, f.col, f.message): f for f in findings}
    return sorted(uniq.values(), key=lambda f: (f.path, f.line, f.col, f.rule))


def analyze_repo(src_root: Optional[Path] = None,
                 subpackages: Sequence[str] = DEFAULT_SUBPACKAGES
                 ) -> List[Finding]:
    """Layer 1 over the repo's compiled surface: repro.{core,solvers,serve,configs}."""
    if src_root is None:
        src_root = Path(__file__).resolve().parents[2]
    pkg = src_root / "repro"
    paths = sorted(p for sub in subpackages for p in (pkg / sub).rglob("*.py"))
    return analyze_files(paths, src_root=src_root)
