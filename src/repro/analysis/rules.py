"""Rule catalog + escape-hatch grammar for the trace-discipline analyzer.

The analyzer (DESIGN.md §analysis) enforces the one invariant the whole
performance story rests on: every scenario knob is a *traced leaf* of a
single compiled program, so sweeps and closed-loop re-plans never
recompile. Layer 1 (``astcheck``) flags source patterns that silently
break that invariant; Layer 2 (``jaxpr_audit``) checks the traced
programs themselves.

Escape hatch
------------
A finding is suppressed by an inline comment carrying an explicit rule
list *and* a one-line justification::

    x = float(best_energy)  # analyze: ok(TRC001): host fail-soft path, never traced

Placed on a ``def`` line (or its decorator) the suppression covers the
whole function body. A first-lines comment::

    # analyze: skip-file: deliberate host-loop reference port

skips the entire file. An ``ok(...)`` without the ``: reason`` tail is
itself reported (TRC000) — silent exemptions are not allowed.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, FrozenSet, List, Tuple

__all__ = [
    "RULES", "Finding", "Suppressions", "parse_suppressions", "render",
]

#: rule id -> (title, what it catches)
RULES: Dict[str, Tuple[str, str]] = {
    "TRC000": (
        "unjustified escape hatch",
        "an `# analyze: ok(...)` comment without a `: reason` tail — "
        "suppressions must say why the host-side op is safe",
    ),
    "TRC001": (
        "host cast of a traced value",
        "float()/int()/bool()/complex() applied to a potentially-traced "
        "value inside jit-reachable code — forces a device sync and a "
        "ConcretizationTypeError under jit",
    ),
    "TRC002": (
        "host materialization of a traced value",
        ".item()/.tolist()/np.* applied to a potentially-traced value "
        "inside jit-reachable code — silently falls back to host numpy "
        "and breaks tracing",
    ),
    "TRC003": (
        "Python control flow on a traced value",
        "if/while/assert/ternary whose test depends on a potentially-"
        "traced value inside jit-reachable code — branch decisions must "
        "use jnp.where/lax.cond so they stay in the program",
    ),
    "TRC004": (
        "mutable or call default argument",
        "a list/dict/set or function-call default — evaluated once at "
        "import, shared across calls, and (for array defaults) baked "
        "into every trace",
    ),
    "TRC005": (
        "jnp computation at module import time",
        "a jax.numpy/jax.random call executed at module (or class-body) "
        "import time — allocates device buffers before config/flags are "
        "settled and bakes constants into unrelated traces",
    ),
    "TRC006": (
        "static/traced contract drift",
        "a jit declaration whose static_argnames disagree with the "
        "declared contract: a traced scenario knob marked static (one "
        "compile per value), a known-static knob left traced, or a "
        "static name that is not a parameter of the wrapped function",
    ),
}

_OK_RE = re.compile(
    r"#\s*analyze:\s*ok\(\s*([A-Z]{3}\d{3}(?:\s*,\s*[A-Z]{3}\d{3})*)\s*\)"
    r"(?P<reason>\s*:\s*\S.*)?"
)
_SKIP_RE = re.compile(r"#\s*analyze:\s*skip-file\s*(?P<reason>:\s*\S.*)?")


@dataclass(frozen=True)
class Finding:
    """One analyzer hit, pointing at a source location."""

    rule: str
    path: str
    line: int
    col: int
    message: str
    func: str = "<module>"

    def render(self) -> str:
        title = RULES[self.rule][0]
        return (f"{self.path}:{self.line}:{self.col}: {self.rule} "
                f"[{title}] in {self.func}: {self.message}")


@dataclass
class Suppressions:
    """Per-file escape hatches parsed from comments.

    ``by_line`` maps a 1-based source line to the rule ids suppressed on
    that line; ``def``-line placement is widened to the whole function by
    the AST layer (which knows body extents). ``skip_file`` covers the
    entire file.
    """

    by_line: Dict[int, FrozenSet[str]] = field(default_factory=dict)
    skip_file: bool = False
    #: `ok(...)` comments missing the `: reason` tail -> TRC000
    unjustified: List[int] = field(default_factory=list)

    def allows(self, line: int, rule: str) -> bool:
        return rule in self.by_line.get(line, frozenset())


def parse_suppressions(source: str) -> Suppressions:
    """Scan raw source for escape-hatch comments (regex over lines: the
    marker never legitimately appears inside string literals)."""
    sup = Suppressions()
    for lineno, text in enumerate(source.splitlines(), start=1):
        if "analyze:" not in text:
            continue
        m = _SKIP_RE.search(text)
        if m:
            if m.group("reason"):
                sup.skip_file = True
            else:
                sup.unjustified.append(lineno)
            continue
        m = _OK_RE.search(text)
        if m:
            rules = frozenset(r.strip() for r in m.group(1).split(","))
            if m.group("reason"):
                sup.by_line[lineno] = sup.by_line.get(lineno, frozenset()) | rules
            else:
                sup.unjustified.append(lineno)
    return sup


def render(findings: List[Finding]) -> str:
    lines = [f.render() for f in findings]
    lines.append(f"{len(findings)} finding(s)")
    return "\n".join(lines)
