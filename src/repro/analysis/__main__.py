"""``python -m repro.analysis`` — run both analyzer layers, write the
CI artifact, exit nonzero on any finding.

Options::

    --report PATH   write the JSON report (default ANALYZE_report.json)
    --ast-only      skip the jaxpr/compile layer (no jax import)
    --devices N     tiny-fleet size for the jaxpr layer (default 3)
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.analysis")
    ap.add_argument("--report", default="ANALYZE_report.json")
    ap.add_argument("--ast-only", action="store_true")
    ap.add_argument("--devices", type=int, default=3)
    args = ap.parse_args(argv)

    from repro.analysis.astcheck import analyze_repo
    from repro.analysis.rules import render

    findings = analyze_repo()
    report = {
        "ast": {
            "ok": not findings,
            "findings": [f.render() for f in findings],
        },
    }
    print(f"[analyze] layer 1 (AST): {len(findings)} finding(s)")
    if findings:
        print(render(findings))

    ok = not findings
    if not args.ast_only:
        from repro.analysis.jaxpr_audit import run_audit

        audit = run_audit(n=args.devices)
        report["jaxpr"] = audit
        ok = ok and audit["ok"]
        print(f"[analyze] layer 2 (jaxpr): "
              f"{'ok' if audit['ok'] else 'FAIL'} — "
              f"{len(audit['problems'])} problem(s), recompile drill "
              f"{'ok' if audit['recompile_drill']['ok'] else 'FAIL'}")
        for p in audit["problems"]:
            print("  " + p)

    report["ok"] = ok
    Path(args.report).write_text(json.dumps(report, indent=1, sort_keys=True))
    print(f"[analyze] report -> {args.report}")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
