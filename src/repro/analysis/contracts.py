"""The declared traced-vs-static contract (DESIGN.md §analysis).

This module is the single source of truth both analyzer layers check
*against*. It intentionally duplicates knowledge that lives implicitly
in ``core.api``/``core.planner`` — the whole point is that drift between
this declaration and the code is an analyzer finding, not a silent
recompile regression.
"""
from __future__ import annotations

# --------------------------------------------------------------- Layer 1
# Parameter names that are, by contract, TRACED leaves wherever they
# appear on the compiled surface: per-scenario knobs (so sweeps reuse
# one program) and array operands. Marking one of these static in a
# `static_argnames` declaration means one XLA compile per value — TRC006.
TRACED_PARAM_NAMES = frozenset({
    # Scenario leaves (api.Scenario)
    "deadline", "eps", "B", "edge_capacity_s",
    # array operands of the jitted entry points
    "fleet", "scenarios", "scenario", "m0", "m_sel", "init_m", "x_init",
    "key", "alloc", "faults", "e_table", "t_table", "var_table", "sigma",
    "edge_cap",
    # group-sharded planner operands (core.decompose): fleet-order link
    # gains and the in-trace (log-price, need) lanes of the host loops
    "gains", "log_lam", "log_mu",
    # multi-edge placement operands (core.placement): device→node
    # assignment vectors, per-device occupancy and per-node capacities
    "assignment", "occ", "caps",
    # trace-replay epoch operands (serve.replay): padded request batches
    # and the demand normalizer — value-varied per epoch, one program
    "device_ids", "valid", "rounds",
})

# Parameter names that are, by contract, STATIC wherever they appear on
# the compiled surface: they select code paths / shapes (PlannerConfig
# fields and solver/sampler selectors), so leaving one traced either
# fails to trace (Python branching on it) or silently bloats the
# program. A jitted function taking one of these without declaring it
# in `static_argnames` is TRC006.
STATIC_PARAM_NAMES = frozenset({
    # PlannerConfig statics (api._BATCH_STATICS / planner._STATICS)
    "policy", "outer_iters", "pccp_iters", "channel_cv", "multi_start",
    "solver", "pccp_gated",
    # per-function statics on other entry points
    "sigma_model", "dist", "num_samples", "num_iters", "schedule", "gated",
    "endpoint",
    # placement statics: allocator-strategy selector and the
    # chance-constraint level (both pick code paths, not values)
    "strategy", "assign", "edge_eps",
})

# Shape-derived int properties on the pytree containers (BlockChain /
# Fleet): static under tracing, so projecting them does not taint.
STATIC_PROPERTY_NAMES = frozenset({
    "num_devices", "max_points", "points_per_device",
})

# Entry points treated as jit-reachability roots even though they are
# not themselves jit-wrapped: the public surface whose bodies feed
# values into (or host-orchestrate) the compiled programs. Matched as
# (module-suffix, qualname) pairs; module-suffix "" matches any module.
ANALYSIS_SURFACE = (
    ("core.api", "Planner.plan"),
    ("core.api", "Planner.plan_many"),
    ("core.api", "Planner.grid"),
    ("core.api", "Planner.plan_sharded"),
    ("core.api", "plan_many"),
    ("core.decompose", "plan_sharded"),
    ("core.decompose", "build_groups"),
    ("core.planner", "plan_health"),
    ("core.planner", "initial_points"),
    ("core.placement", "assign_devices"),
    ("core.placement", "node_loads"),
    ("core.placement", "duality_gap"),
    ("core.placement", "plan_duality_gap"),
    ("core.resource", "allocate_ipm"),
    ("serve.closedloop", "run_closed_loop"),
    ("serve.replay", "replay"),
    ("serve.replay", "replay_engine"),
    ("serve.replay", "regret_curves"),
    ("serve.guard", "contingency_plans"),
    ("serve.guard", "pick_contingency"),
    ("serve.guard", "plan_margin"),
    ("serve.partitioned", "_DeploymentBase.plan"),
    ("serve.partitioned", "_DeploymentBase.validate"),
    ("serve.partitioned", "MixedTwoTierDeployment.plan_sharded"),
)

# --------------------------------------------------------------- Layer 2
#: total bytes of constants allowed to be baked into one traced program.
#: The planner's closures legitimately capture small index/schedule
#: tables (~1.5 KiB today); a fleet or profile table leaking in as a
#: constant (instead of an argument) is orders of magnitude bigger.
CONST_BYTE_BUDGET = 1 << 16  # 64 KiB

#: dtypes allowed on *outputs* of the compiled surface. The planner is a
#: float64 precision island (x64 flipped on at `repro.core` import —
#: goldens pin 1e-8 agreement with the paper tables); float32 on an
#: output means an accidental downcast mixed in, int64 means an
#: unstable integer leaf (cf. the Plan.pccp_iters int64 fix).
ALLOWED_OUTPUT_DTYPES = frozenset({"float64", "int32", "bool"})

# Pytree leaf contracts: (path, dtype) in flattening order — exactly
# what the golden files and any scan/cond over these trees assume.
# `jax.tree_util.keystr` paths.
SCENARIO_LEAVES = (
    (".deadline", "float64"),
    (".eps", "float64"),
    (".B", "float64"),
    (".edge_capacity_s", "float64"),
)

PLAN_LEAVES = (
    (".m_sel", "int32"),
    (".alloc.b", "float64"),
    (".alloc.f", "float64"),
    (".alloc.e_loc", "float64"),
    (".alloc.e_off", "float64"),
    (".alloc.feasible", "bool"),
    (".alloc.lam", "float64"),
    (".alloc.mu", "float64"),
    (".total_energy", "float64"),
    (".feasible", "bool"),
    (".objective_trace", "float64"),
    (".pccp_iters", "int32"),
    (".margins", "float64"),
    (".status", "int32"),
    (".assignment", "int32"),
)

ALLOCATION_LEAVES = tuple(
    (path[len(".alloc"):], dt) for path, dt in PLAN_LEAVES
    if path.startswith(".alloc.")
)

FAULTSTATE_LEAVES = (
    (".loc_mean_scale", "float64"),
    (".loc_var_scale", "float64"),
    (".vm_mean_scale", "float64"),
    (".vm_var_scale", "float64"),
    (".gain_scale", "float64"),
    (".cap_scale", "float64"),
    (".straggler_prob", "float64"),
    (".straggler_extra_s", "float64"),
    (".straggler_cv", "float64"),
)

PYTREE_CONTRACTS = {
    "Scenario": SCENARIO_LEAVES,
    "Plan": PLAN_LEAVES,
    "Allocation": ALLOCATION_LEAVES,
    "FaultState": FAULTSTATE_LEAVES,
}
