"""Trace-discipline analyzer (DESIGN.md §analysis).

Two layers guard the one-compiled-program invariant:

- :mod:`repro.analysis.astcheck` — Layer 1, an AST lint over the
  compiled surface (``repro.{core,solvers,serve,configs}``);
- :mod:`repro.analysis.jaxpr_audit` — Layer 2, graph-level checks on
  the actually-traced entry points plus a recompile counter.

Run both with ``make analyze`` (= ``python -m repro.analysis``).

This package is host-side tooling: importing it must stay cheap and
must not pull in jax (Layer 2 imports lazily) so the AST layer can run
in a bare CI job.
"""
from repro.analysis.rules import RULES, Finding  # noqa: F401
