# Developer entry points. PYTHONPATH is set so the src layout works
# without an editable install.
PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test lint analyze bench-smoke bench dev-install

# Tier-1 verification (ROADMAP.md). No -x: a first failure must not hide
# the rest of the suite (PR 4 made the two long-standing seed failures
# pass, so a red test is always new breakage).
test:
	$(PY) -m pytest -q

# Static checks (config in pyproject.toml). CI installs ruff; locally:
#   pip install ruff
lint:
	$(PY) -m ruff check src tests benchmarks examples

# Trace-discipline analyzer (DESIGN.md §analysis): Layer 1 AST lint over
# the compiled surface + Layer 2 jaxpr/compile audit (host callbacks,
# dtype/weak-type leaks, const budget, pytree contracts, recompile
# drill). Writes ANALYZE_report.json; exits nonzero on any finding.
analyze:
	$(PY) -m repro.analysis

# Quick perf smoke: planner runtime + structured-vs-dense solver A/B +
# PCCP convergence + scenario batching + heterogeneous fleets +
# shared-edge capacity pricing + the group-sharded device-scaling
# ladder + the trace-driven replay drill. bench_runtime (runtime +
# solver sections), bench_plan_grid, bench_hetero, bench_edge,
# bench_replay and bench_devices (devices section) write their sections
# of the BENCH_planner.json artifact (ratio metrics). CI runs this and
# uploads the artifact per PR. ``--only solver`` alone runs just the
# solver A/B section (see benchmarks/run.py).
bench-smoke:
	$(PY) -m benchmarks.run --only runtime,solver,convergence,plan_grid,hetero,edge,placement,faults,replay,devices

# Full paper-figure benchmark sweep
bench:
	$(PY) -m benchmarks.run

dev-install:
	pip install -r requirements-dev.txt
