# Developer entry points. PYTHONPATH is set so the src layout works
# without an editable install.
PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test bench-smoke bench dev-install

# Tier-1 verification (ROADMAP.md)
test:
	$(PY) -m pytest -x -q

# Quick perf smoke: planner runtime + PCCP convergence only.
# bench_runtime writes the BENCH_planner.json artifact.
bench-smoke:
	$(PY) -m benchmarks.run --only runtime,convergence

# Full paper-figure benchmark sweep
bench:
	$(PY) -m benchmarks.run

dev-install:
	pip install -r requirements-dev.txt
