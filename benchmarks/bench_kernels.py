"""Kernel micro-bench: Pallas (interpret) vs jnp reference wall time and
allclose deltas. On CPU the interpret-mode time is NOT a TPU projection —
this bench exists to pin numerics and give a stable call-cost baseline."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import Row, timed
from repro.kernels.flash_attention import flash_attention
from repro.kernels.ref import flash_attention_ref, ssd_scan_ref
from repro.kernels.ssd_scan import ssd_scan


def run() -> list[Row]:
    rows: list[Row] = []
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 5)

    f32 = jnp.float32
    q = jax.random.normal(ks[0], (1, 4, 512, 64), f32)
    k = jax.random.normal(ks[1], (1, 2, 512, 64), f32)
    v = jax.random.normal(ks[2], (1, 2, 512, 64), f32)
    out, us_k = timed(lambda: jax.block_until_ready(flash_attention(q, k, v)))
    ref, us_r = timed(lambda: jax.block_until_ready(flash_attention_ref(q, k, v)))
    err = float(jnp.max(jnp.abs(out - ref)))
    rows.append(("kernel_flash_attn_512", us_k, f"ref_us={us_r:.0f};maxerr={err:.1e}"))

    x = jax.random.normal(ks[0], (1, 256, 4, 64), f32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (1, 256, 4), f32))
    a = -jnp.exp(jax.random.normal(ks[2], (4,), f32) * 0.5)
    bm = jax.random.normal(ks[3], (1, 256, 32), f32)
    cm = jax.random.normal(ks[4], (1, 256, 32), f32)
    out, us_k = timed(lambda: jax.block_until_ready(ssd_scan(x, dt, a, bm, cm, chunk=64)))
    ref, us_r = timed(lambda: jax.block_until_ready(ssd_scan_ref(x, dt, a, bm, cm, 64)))
    err = float(jnp.max(jnp.abs(out - ref)))
    rows.append(("kernel_ssd_scan_256", us_k, f"ref_us={us_r:.0f};maxerr={err:.1e}"))
    return rows
