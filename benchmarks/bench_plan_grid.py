"""Zipped scenario batching (``Planner.plan_many``) vs sequential planning.

The ROADMAP north-star workload is multi-scenario traffic: SLO tiers,
per-tenant risk levels, bandwidth what-ifs, heterogeneous per-device
deadlines. ``plan_many`` vmaps K *arbitrary* zipped scenarios over ONE
compiled program; this bench pits a 9-scenario zipped batch against

  * 9 sequential warmed ``Planner.plan`` calls (same compiled solver,
    9 dispatches) — recorded as ``batched_vs_sequential_ratio`` (+ a
    ``meets_2x`` flag) in the artifact. The ≥2× target is dispatch
    amortization and needs a dispatch-bound host; on this compute-bound
    2-core CPU the honest ratio is ~1× (DESIGN.md §api), and
  * 3 sequential *seed-loop* calls (``plan_reference`` with the seed's
    inner barrier schedule) — continuity with the PR-1 trajectory.

Ratios — not raw wall-clock — go into the ``plan_grid`` section of
``BENCH_planner.json`` (memory: planner perf is tracked as ratios).
"""
from __future__ import annotations

import jax

from benchmarks.common import Row, timed, update_artifact
from repro.configs.paper_tables import alexnet_fleet
from repro.core import Planner, PlannerConfig, Scenario
from repro.core.pccp import SEED_SCHEDULE
from repro.core.planner_ref import plan_reference

DEADLINES = (0.18, 0.20, 0.22)
EPSS = (0.02, 0.04, 0.06)
B = 10e6
KW = dict(outer_iters=2, pccp_iters=6)
#: The zipped batch: all 9 (deadline, ε) combinations as K=9 scenarios.
SCENARIOS = [Scenario(d, e, B) for d in DEADLINES for e in EPSS]


def run() -> list[Row]:
    rows: list[Row] = []
    fleet = alexnet_fleet(jax.random.PRNGKey(0), 12)
    k = len(SCENARIOS)
    section = {"k_scenarios": k, "config": KW, "policies": {}}

    for policy in ("robust_exact", "robust"):
        planner = Planner(PlannerConfig(policy=policy, **KW))
        _, many_us = timed(lambda: planner.plan_many(fleet, SCENARIOS))
        _, seq_us = timed(
            lambda: [planner.plan(fleet, sc) for sc in SCENARIOS])
        ratio = seq_us / many_us
        section["policies"][policy] = {
            "batched_us": many_us, "sequential_us": seq_us,
            "batched_vs_sequential_ratio": ratio,
        }
        rows.append((
            f"plan_many_{k}zip_{policy}_alexnet", many_us,
            f"per_scenario_us={many_us / k:.0f};seq{k}_us={seq_us:.0f};"
            f"batched_vs_sequential={ratio:.2f}x"))

    # Target: the zipped batch beats sequential dispatch ≥ 2× steady-state.
    # That win is dispatch amortization, so it materializes on
    # accelerator-class hosts; on this 2-core CPU the solve is
    # compute-bound (see DESIGN.md §api — transcendental-heavy
    # golden-section/bisection chains dominate, and vmap width adds
    # proportional compute), so the honest ratio here is ~1×. Recorded,
    # not asserted: faking the baseline would poison the trajectory.
    headline = section["policies"]["robust_exact"]["batched_vs_sequential_ratio"]
    section["batched_vs_sequential_ratio"] = headline
    section["meets_2x"] = headline >= 2.0
    if headline < 2.0:
        rows.append((f"plan_many_{k}zip_ratio_below_target", 0.0,
                     f"batched_vs_sequential={headline:.2f}x;target=2x;"
                     "compute_bound_cpu=see DESIGN.md §api"))

    # PR-1 continuity: the 3×3 batch vs 3 sequential seed-loop plans
    planner = Planner(PlannerConfig(policy="robust", **KW))
    _, many_us = timed(lambda: planner.plan_many(fleet, SCENARIOS), repeats=1)
    _, seed3_us = timed(
        lambda: [plan_reference(fleet, d, 0.04, B, policy="robust",
                                pccp_schedule=SEED_SCHEDULE, **KW)
                 for d in DEADLINES],
        repeats=1)
    section["seed_3seq_vs_batch9_ratio"] = seed3_us / many_us
    rows.append((f"plan_many_{k}zip_vs_seed3seq_alexnet", many_us,
                 f"seed_3seq_us={seed3_us:.0f};"
                 f"grid9_vs_seed3seq={seed3_us / many_us:.2f}x"))

    update_artifact("plan_grid", section)
    return rows
