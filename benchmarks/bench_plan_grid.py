"""Batched scenario-grid planning vs sequential seed planning.

The ROADMAP north-star workload is multi-scenario traffic: deadline/ε/B
sweeps (Fig. 13/14) and per-request planning in the two-tier engine. This
bench pits a 3×3 deadline×ε ``plan_grid`` (9 scenarios, one compiled
program) against sequential seed ``plan()`` calls — the seed Python loop
with the seed's inner barrier schedule, via ``plan_reference`` — on the
paper's robust (PCCP) policy. The acceptance bar is the 9-scenario grid
beating just 3 sequential seed calls."""
from __future__ import annotations

import jax

from benchmarks.common import Row, timed, timed_compile
from repro.configs.paper_tables import alexnet_fleet
from repro.core import plan_grid
from repro.core.pccp import SEED_SCHEDULE
from repro.core.planner_ref import plan_reference

DEADLINES = (0.18, 0.20, 0.22)
EPSS = (0.02, 0.04, 0.06)
KW = dict(policy="robust", outer_iters=2, pccp_iters=6)


def run() -> list[Row]:
    rows: list[Row] = []
    fleet = alexnet_fleet(jax.random.PRNGKey(0), 12)

    t = timed_compile(lambda: plan_grid(fleet, DEADLINES, EPSS, 10e6, **KW),
                      repeats=2)
    _, seq3_us = timed(
        lambda: [plan_reference(fleet, d, 0.04, 10e6,
                                pccp_schedule=SEED_SCHEDULE, **KW)
                 for d in DEADLINES],
        repeats=1)
    n_cells = len(DEADLINES) * len(EPSS)
    rows.append((
        f"plan_grid_{len(DEADLINES)}x{len(EPSS)}_alexnet", t.us,
        f"per_scenario_us={t.us / n_cells:.0f};compile_us={t.compile_us:.0f};"
        f"seed_3seq_us={seq3_us:.0f};grid9_vs_seed3seq={seq3_us / t.us:.2f}x"))
    return rows
