"""Fig. 11 — average runtime of Algorithm 2 vs number of devices.

The paper reports near-linear scaling in N (MATLAB, i7-8700). Our PCCP
inner problems are vmapped across devices, so wall time should grow
sub-linearly after jit warmup; we report both cold and warm times.
"""
from __future__ import annotations

import jax

from benchmarks.common import Row, timed
from repro.configs.paper_tables import alexnet_fleet, resnet152_fleet
from repro.core import plan


def run() -> list[Row]:
    rows: list[Row] = []
    for name, fleet_fn, D, B in (("alexnet", alexnet_fleet, 0.22, 10e6),
                                 ("resnet152", resnet152_fleet, 0.16, 30e6)):
        for n in (4, 8, 16, 24):
            fleet = fleet_fn(jax.random.PRNGKey(n), n)
            solve = lambda: plan(fleet, D, 0.04, B, policy="robust",
                                 outer_iters=2, pccp_iters=6, multi_start=False)
            _, us_cold = timed(solve)
            p, us_warm = timed(solve)
            rows.append((f"fig11_runtime_{name}_N{n}", us_warm,
                         f"cold_us={us_cold:.0f};energy={float(p.total_energy):.4f}"))
    return rows
