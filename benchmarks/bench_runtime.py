"""Fig. 11 — average runtime of Algorithm 2 vs number of devices.

The paper reports near-linear scaling in N (MATLAB, i7-8700). The fused
planner (DESIGN.md §planner) is one XLA program — scanned outer loop,
vmapped multi-start — so steady-state wall time is solver math, not
dispatch. We report:

  * steady-state (post-warmup, device-synced) µs/call,
  * jit compile time separately (the cold first call), and
  * at N=50 the speedup over the straight-line seed-loop port
    (``planner_ref.plan_reference``), which shares every numerical
    building block and differs only in the Python-loop structure.

Writes the ``planner_runtime`` section of ``BENCH_planner.json`` so the
perf trajectory is tracked across PRs as ratios (memory: wall-clock is
machine-dependent; the seed-speedup ratio is not).
"""
from __future__ import annotations

import jax

from benchmarks.common import Row, timed, timed_compile, update_artifact
from repro.configs.paper_tables import alexnet_fleet, resnet152_fleet
from repro.core import Planner, PlannerConfig, Scenario
from repro.core.pccp import SEED_SCHEDULE
from repro.core.planner_ref import plan_reference

_CFG = dict(policy="robust", outer_iters=2, pccp_iters=6, multi_start=False)
PLANNER = Planner(PlannerConfig(**_CFG))


def run() -> list[Row]:
    rows: list[Row] = []
    artifact = {"config": _CFG, "rows": []}
    for name, fleet_fn, D, B in (("alexnet", alexnet_fleet, 0.22, 10e6),
                                 ("resnet152", resnet152_fleet, 0.16, 30e6)):
        for n in (4, 8, 16, 24, 50):
            fleet = fleet_fn(jax.random.PRNGKey(n), n)
            scenario = Scenario(D, 0.04, B)
            t = timed_compile(lambda: PLANNER.plan(fleet, scenario))
            derived = (f"compile_us={t.compile_us:.0f};"
                       f"energy={float(t.out.total_energy):.4f}")
            entry = {"model": name, "n_devices": n, "us": t.us,
                     "compile_us": t.compile_us}
            if n == 50:  # seed comparison at the headline size: the seed's
                # Python outer loop AND its 168-Newton-step inner barrier
                _, ref_us = timed(
                    lambda: plan_reference(fleet, D, 0.04, B,
                                           pccp_schedule=SEED_SCHEDULE, **_CFG),
                    repeats=2)
                entry["seed_us"] = ref_us
                entry["seed_speedup_ratio"] = ref_us / t.us
                derived += f";seed_us={ref_us:.0f};speedup={ref_us / t.us:.2f}x"
            artifact["rows"].append(entry)
            rows.append((f"fig11_runtime_{name}_N{n}", t.us, derived))
    update_artifact("planner_runtime", artifact)
    return rows
