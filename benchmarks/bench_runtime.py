"""Fig. 11 — average runtime of Algorithm 2 vs number of devices.

The paper reports near-linear scaling in N (MATLAB, i7-8700). The fused
planner (DESIGN.md §planner) is one XLA program — scanned outer loop,
vmapped multi-start — so steady-state wall time is solver math, not
dispatch. Two sections (``--only runtime`` / ``--only solver`` via
``benchmarks.run``):

``runtime``
  * steady-state (post-warmup, device-synced) µs/call and jit compile
    time separately (the cold first call) per fleet size,
  * at N=50 the speedup over the straight-line seed-loop port
    (``planner_ref.plan_reference`` with the seed barrier schedule AND
    the dense autodiff solver — the seed's full inner-solver cost), and
  * a per-phase breakdown at N=50: one PCCP inner solve vs one resource
    allocation vs everything else (edge pricing, argmins, dispatch),
    estimated against the alternation's phase count.

``solver``
  A/B of the PCCP inner-barrier paths (DESIGN.md §solver) on the
  ``robust`` (PCCP-dominated) policy: ``structured_vs_dense_ratio``
  (steady-state) and ``compile_ratio``. Ratio metrics only, per the
  established bench policy on this noisy 2-core host; fail-soft — a
  ratio < 1 prints a warning instead of failing the run.

Writes the ``planner_runtime`` and ``solver`` sections of
``BENCH_planner.json`` so the perf trajectory is tracked across PRs as
ratios (memory: wall-clock is machine-dependent; the ratios are not).
"""
from __future__ import annotations

import sys

import jax
import jax.numpy as jnp

from benchmarks.common import Row, timed, timed_compile, update_artifact
from repro.configs.paper_tables import alexnet_fleet, resnet152_fleet
from repro.core import Planner, PlannerConfig, Scenario
from repro.core.ccp import SIGMA_FNS
from repro.core.pccp import SEED_SCHEDULE, pccp_partition
from repro.core.planner import get_policy, policy_point_tables
from repro.core.planner_ref import plan_reference
from repro.core.resource import allocate

_CFG = dict(policy="robust", outer_iters=2, pccp_iters=6, multi_start=False)
PLANNER = Planner(PlannerConfig(**_CFG))

#: solver A/B size: big enough that the PCCP dominates, small enough for
#: the CI smoke (two full compiles). Deliberately disjoint from the
#: Fig.-11 sweep sizes (4/8/16/24/50): a shared fleet *shape* would let
#: one path's "cold" call hit the jit cache populated by ``run_runtime``
#: and report a fictitious compile_ratio when the sections run together.
_SOLVER_N = 20


def _phase_breakdown(fleet, D, eps, B, plan_us: float) -> dict:
    """Per-phase µs at one alternation step: PCCP inner solve vs resource
    allocation vs the remainder (edge pricing, argmins, dispatch).

    The σ model and time inflation come from the configured policy's
    registry record, so the timed subproblem tracks the policy the plan
    actually runs. The full plan runs ``outer_iters`` steps of
    (allocate → tables → partition) plus one final allocate, so the
    overhead estimate is ``plan − outer·(alloc + pccp) − alloc`` — an
    *estimate* (the per-step tables drift with m), good enough to show
    where the wall-clock goes.
    """
    n = fleet.num_devices
    deadline = jnp.full((n,), D, jnp.float64)
    epsv = jnp.full((n,), eps, jnp.float64)
    m0 = jnp.full((n,), fleet.max_points - 1, jnp.int32)
    pol = get_policy(_CFG["policy"])

    alloc, alloc_us = timed(
        lambda: allocate(fleet, m0, deadline, epsv, B, pol.sigma_model,
                         pol.ub_k),
        repeats=3)
    e_t, t_t, v_t = policy_point_tables(fleet, alloc.b, alloc.f, pol)
    sigma = SIGMA_FNS[pol.sigma_model](epsv)
    x_init = jax.nn.one_hot(m0, fleet.max_points, dtype=jnp.float64)
    _, pccp_us = timed(
        lambda: pccp_partition(e_t, t_t, v_t, sigma, deadline, x_init,
                               num_iters=_CFG["pccp_iters"]),
        repeats=3)
    outer = _CFG["outer_iters"]
    overhead_us = plan_us - outer * (alloc_us + pccp_us) - alloc_us
    return {
        "pccp_us": pccp_us,
        "alloc_us": alloc_us,
        "overhead_us_est": overhead_us,
        "pccp_share_est": outer * pccp_us / plan_us,
    }


def run_runtime() -> list[Row]:
    rows: list[Row] = []
    artifact = {"config": _CFG, "rows": []}
    for name, fleet_fn, D, B in (("alexnet", alexnet_fleet, 0.22, 10e6),
                                 ("resnet152", resnet152_fleet, 0.16, 30e6)):
        for n in (4, 8, 16, 24, 50):
            fleet = fleet_fn(jax.random.PRNGKey(n), n)
            scenario = Scenario(D, 0.04, B)
            t = timed_compile(lambda: PLANNER.plan(fleet, scenario))
            derived = (f"compile_us={t.compile_us:.0f};"
                       f"energy={float(t.out.total_energy):.4f}")
            entry = {"model": name, "n_devices": n, "us": t.us,
                     "compile_us": t.compile_us}
            if n == 50:  # seed comparison at the headline size: the seed's
                # Python outer loop, 168-Newton-step schedule AND dense
                # autodiff inner solver
                _, ref_us = timed(
                    lambda D=D, B=B: plan_reference(fleet, D, 0.04, B,
                                           pccp_schedule=SEED_SCHEDULE,
                                           solver="dense", **_CFG),
                    repeats=2)
                entry["seed_us"] = ref_us
                entry["seed_speedup_ratio"] = ref_us / t.us
                derived += f";seed_us={ref_us:.0f};speedup={ref_us / t.us:.2f}x"
                phases = _phase_breakdown(fleet, D, 0.04, B, t.us)
                entry["phases"] = phases
                derived += (f";pccp_us={phases['pccp_us']:.0f}"
                            f";alloc_us={phases['alloc_us']:.0f}")
            artifact["rows"].append(entry)
            rows.append((f"fig11_runtime_{name}_N{n}", t.us, derived))
    update_artifact("planner_runtime", artifact)
    return rows


def run_solver() -> list[Row]:
    """A/B the structured vs dense PCCP inner barrier (ratio metrics)."""
    fleet = alexnet_fleet(jax.random.PRNGKey(_SOLVER_N), _SOLVER_N)
    scenario = Scenario(0.22, 0.04, 10e6)
    # Warm the process-shared machinery (XLA backend, builders) on a
    # throwaway size so neither timed compile pays first-call-in-process
    # costs (~2 s on this host, enough to flip the compile ratio).
    warm = alexnet_fleet(jax.random.PRNGKey(4), 4)
    jax.block_until_ready(
        Planner(PlannerConfig(**_CFG)).plan(warm, scenario))

    rows: list[Row] = []
    timings = {}
    for solver in ("structured", "dense"):
        pl = Planner(PlannerConfig(solver=solver, **_CFG))
        t = timed_compile(lambda: pl.plan(fleet, scenario))
        timings[solver] = t
        rows.append((
            f"solver_{solver}_robust_N{_SOLVER_N}", t.us,
            f"compile_us={t.compile_us:.0f};"
            f"energy={float(t.out.total_energy):.4f}"))

    ratio = timings["dense"].us / timings["structured"].us
    compile_ratio = timings["dense"].compile_us / timings["structured"].compile_us
    same_plan = bool(
        jnp.all(timings["dense"].out.m_sel == timings["structured"].out.m_sel))
    update_artifact("solver", {
        "n_devices": _SOLVER_N,
        "config": _CFG,
        "structured": {"us": timings["structured"].us,
                       "compile_us": timings["structured"].compile_us},
        "dense": {"us": timings["dense"].us,
                  "compile_us": timings["dense"].compile_us},
        "structured_vs_dense_ratio": ratio,
        "compile_ratio": compile_ratio,
        "same_m_sel": same_plan,
        "meets_1p5x": ratio >= 1.5,
    })
    if ratio < 1.0:  # fail-soft: warn, never fail the bench run
        print(f"WARNING: structured_vs_dense_ratio={ratio:.2f} < 1 — the "
              "structured barrier is slower than the dense reference on "
              "this host", file=sys.stderr)
    rows.append((f"solver_structured_vs_dense_N{_SOLVER_N}", 0.0,
                 f"ratio={ratio:.2f}x;compile_ratio={compile_ratio:.2f}x;"
                 f"same_m_sel={same_plan}"))
    return rows


SECTIONS = {"runtime": run_runtime, "solver": run_solver}

# ``benchmarks.run`` selects sections without importing excluded modules,
# so it keeps its own declaration — fail loudly if the two drift.
from benchmarks.run import MODULE_SECTIONS as _DECLARED  # noqa: E402

assert tuple(SECTIONS) == _DECLARED["bench_runtime"], (
    "benchmarks/run.py MODULE_SECTIONS is out of sync with "
    "bench_runtime.SECTIONS")


def run() -> list[Row]:
    return run_runtime() + run_solver()
