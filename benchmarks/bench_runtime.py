"""Fig. 11 — average runtime of Algorithm 2 vs number of devices.

The paper reports near-linear scaling in N (MATLAB, i7-8700). The fused
planner (DESIGN.md §planner) is one XLA program — scanned outer loop,
vmapped multi-start — so steady-state wall time is solver math, not
dispatch. We report:

  * steady-state (post-warmup, device-synced) µs/call,
  * jit compile time separately (the cold first call), and
  * at N=50 the speedup over the straight-line seed-loop port
    (``planner_ref.plan_reference``), which shares every numerical
    building block and differs only in the Python-loop structure.

Emits a ``BENCH_planner.json`` artifact so the perf trajectory is
tracked across PRs.
"""
from __future__ import annotations

import json
import os

import jax

from benchmarks.common import Row, timed, timed_compile
from repro.configs.paper_tables import alexnet_fleet, resnet152_fleet
from repro.core import plan
from repro.core.pccp import SEED_SCHEDULE
from repro.core.planner_ref import plan_reference

#: Where the machine-readable artifact lands (repo root by default).
ARTIFACT = os.environ.get("BENCH_PLANNER_JSON", "BENCH_planner.json")

_KW = dict(policy="robust", outer_iters=2, pccp_iters=6, multi_start=False)


def run() -> list[Row]:
    rows: list[Row] = []
    artifact = {"bench": "planner_runtime", "config": _KW, "rows": []}
    for name, fleet_fn, D, B in (("alexnet", alexnet_fleet, 0.22, 10e6),
                                 ("resnet152", resnet152_fleet, 0.16, 30e6)):
        for n in (4, 8, 16, 24, 50):
            fleet = fleet_fn(jax.random.PRNGKey(n), n)
            solve = lambda: plan(fleet, D, 0.04, B, **_KW)
            t = timed_compile(solve)
            derived = (f"compile_us={t.compile_us:.0f};"
                       f"energy={float(t.out.total_energy):.4f}")
            entry = {"model": name, "n_devices": n, "us": t.us,
                     "compile_us": t.compile_us}
            if n == 50:  # seed comparison at the headline size: the seed's
                # Python outer loop AND its 168-Newton-step inner barrier
                _, ref_us = timed(
                    lambda: plan_reference(fleet, D, 0.04, B,
                                           pccp_schedule=SEED_SCHEDULE, **_KW),
                    repeats=2)
                derived += f";seed_us={ref_us:.0f};speedup={ref_us / t.us:.2f}x"
                entry["seed_us"] = ref_us
            artifact["rows"].append(entry)
            rows.append((f"fig11_runtime_{name}_N{n}", t.us, derived))
    with open(ARTIFACT, "w") as f:
        json.dump(artifact, f, indent=1)
    return rows
