"""Heterogeneous-fleet planning: one compiled mixed-fleet plan vs
per-model-group sequential plans.

The ragged Fleet core (DESIGN.md §fleet) plans a mixed two-model
population — different chains, different M_n, different platforms — as
ONE compiled program over one shared bandwidth budget. The baseline is
what you'd do without it: slice the population into homogeneous
per-model groups, give each group a pro-rata bandwidth share, and plan
them sequentially.

Two ratio metrics go into the ``hetero`` section of
``BENCH_planner.json`` (ratios, not absolute µs — the bench convention):

- ``mixed_vs_per_group_ratio`` — sequential-groups wall-clock over the
  one-program mixed plan (dispatch amortization, like bench_plan_grid).
- ``per_group_energy_overhead`` — grouped-plan energy over mixed-plan
  energy. Under the **"optimal"** policy (exact price-based search) the
  mixed plan prices the SHARED budget globally, so in exact arithmetic a
  pro-rata split can never beat it (the split restricts the feasible
  set). In practice the fixed-iteration golden-section bandwidth solve
  has resolution ∝ its bracket width (the full B for the mixed fleet,
  B/groups for the splits), so the measured overhead sits within ~1% of
  1 rather than exactly ≥ 1. The alternation policies are multi-start
  heuristics on top — the joint fleet can land on a different stationary
  point than per-group runs. All ratios recorded, none asserted.
"""
from __future__ import annotations

import jax

from benchmarks.common import Row, timed, update_artifact
from repro.configs.paper_tables import mixed_fleet, mixed_spec
from repro.core import Planner, PlannerConfig, Scenario

N_DEVICES = 12
B = 30e6
DEADLINE, EPS = 0.2, 0.04
KW = dict(outer_iters=2, pccp_iters=6)


def run() -> list[Row]:
    rows: list[Row] = []
    fleet = mixed_fleet(jax.random.PRNGKey(1), N_DEVICES)
    spec = mixed_spec(N_DEVICES)
    slices = spec.group_slices()

    # homogeneous per-group sub-fleets sharing the SAME device positions;
    # each gets a pro-rata share of the bandwidth budget
    subfleets = [
        (jax.tree_util.tree_map(lambda x, lo=lo, hi=hi: x[lo:hi], fleet),
         B * (hi - lo) / N_DEVICES)
        for lo, hi in slices
    ]

    section = {"n_devices": N_DEVICES, "config": KW,
               "groups": [g.name for g in spec.groups], "policies": {}}
    for policy in ("optimal", "robust_exact", "robust"):
        planner = Planner(PlannerConfig(policy=policy, **KW))
        p_mixed, mixed_us = timed(
            lambda: planner.plan(fleet, Scenario(DEADLINE, EPS, B)))
        group_plans, seq_us = timed(
            lambda: [planner.plan(sub, Scenario(DEADLINE, EPS, b_share))
                     for sub, b_share in subfleets])
        mixed_j = float(p_mixed.total_energy)
        group_j = sum(float(p.total_energy) for p in group_plans)
        ratio = seq_us / mixed_us
        overhead = group_j / mixed_j
        section["policies"][policy] = {
            "mixed_us": mixed_us, "per_group_us": seq_us,
            "mixed_vs_per_group_ratio": ratio,
            "mixed_energy_j": mixed_j, "per_group_energy_j": group_j,
            "per_group_energy_overhead": overhead,
        }
        rows.append((
            f"hetero_mixed_{policy}_n{N_DEVICES}", mixed_us,
            f"per_group_us={seq_us:.0f};mixed_vs_per_group={ratio:.2f}x;"
            f"energy_overhead={overhead:.3f}x;"
            f"feas={bool(p_mixed.feasible.all())}"))

    # headline ratios: wall-clock from the paper's robust pipeline, the
    # energy-coupling overhead from the exact policy (where ≥ 1 is a theorem)
    section["mixed_vs_per_group_ratio"] = (
        section["policies"]["robust_exact"]["mixed_vs_per_group_ratio"])
    section["per_group_energy_overhead"] = (
        section["policies"]["optimal"]["per_group_energy_overhead"])
    update_artifact("hetero", section)
    return rows
