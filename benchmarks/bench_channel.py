"""Beyond-paper: joint inference-time + channel-state uncertainty
(the extension the paper's footnote 2 leaves open), plus a heterogeneous
fleet (mixed AlexNet/ResNet devices — the paper's fleets are homogeneous).
"""
from __future__ import annotations

import jax

from benchmarks.common import Row, timed
from repro.configs.paper_tables import alexnet_fleet, mixed_fleet
from repro.core import Planner, PlannerConfig, Scenario, violation_report


def run() -> list[Row]:
    rows: list[Row] = []
    fleet = alexnet_fleet(jax.random.PRNGKey(0), 12)
    for cv in (0.0, 0.2, 0.4):
        planner = Planner(PlannerConfig(policy="robust_exact", outer_iters=3,
                                        channel_cv=cv))
        p, us = timed(lambda: planner.plan(fleet, Scenario(0.2, 0.04, 10e6)))
        vr = violation_report(jax.random.PRNGKey(9), fleet, p.m_sel, p.alloc, 0.2,
                              num_samples=20000, var_scale=1.0,
                              channel_cv=max(cv, 0.4))  # stress at cv=0.4
        rows.append((f"channel_robust_cv{cv}", us,
                     f"J={float(p.total_energy):.4f};"
                     f"viol_at_cv0.4={float(vr.rate.max()):.4f}"))

    fleet = mixed_fleet(jax.random.PRNGKey(1), 12)
    planner = Planner(PlannerConfig(policy="robust_exact", outer_iters=3))
    p, us = timed(lambda: planner.plan(fleet, Scenario(0.2, 0.04, 30e6)))
    vr = violation_report(jax.random.PRNGKey(2), fleet, p.m_sel, p.alloc, 0.2,
                          var_scale=1.0)
    rows.append(("hetero_fleet_mixed", us,
                 f"J={float(p.total_energy):.4f};feas={bool(p.feasible.all())};"
                 f"viol={float(vr.rate.max()):.4f};m={list(map(int, p.m_sel))}"))
    return rows
