"""Figs. 13c & 14c — empirical deadline-violation probability vs risk
level, across deadlines and time distributions. The paper's claim: the
violation probability always stays below the risk level ε.

All deadline×ε plans per scenario come from ONE ``Planner.grid`` call;
the Monte-Carlo validation then runs per grid cell."""
from __future__ import annotations

import jax

from benchmarks.common import Row, timed
from repro.configs.paper_tables import alexnet_fleet, resnet152_fleet
from repro.core import Planner, PlannerConfig, plan_at, violation_report

EPSS = (0.02, 0.04, 0.06, 0.08)

PLANNER = Planner(PlannerConfig(policy="robust_exact", outer_iters=3))


def run() -> list[Row]:
    rows: list[Row] = []
    scen = (("alexnet", alexnet_fleet, (0.18, 0.22), 10e6),
            ("resnet152", resnet152_fleet, (0.12, 0.15), 30e6))
    key = jax.random.PRNGKey(11)
    for name, fleet_fn, deadlines, B in scen:
        fleet = fleet_fn(jax.random.PRNGKey(0), 12)
        grid, grid_us = timed(
            lambda deadlines=deadlines, B=B:
            PLANNER.grid(fleet, deadlines, EPSS, B), repeats=1)
        warmed = set()
        for i, D in enumerate(deadlines):
            for j, eps in enumerate(EPSS):
                p = plan_at(grid, i, j, 0)
                worst, us = 0.0, 0.0
                for dist in ("gamma", "lognormal", "truncnorm"):
                    # one compile-warmup per dist; shapes are identical
                    # across grid cells, so later cells are already warm
                    warm = 1 if dist not in warmed else 0
                    warmed.add(dist)
                    vr, us = timed(lambda p=p, D=D, dist=dist: violation_report(
                        key, fleet, p.m_sel, p.alloc, D, dist=dist,
                        num_samples=20000, var_scale=1.0),
                        repeats=1, warmup=warm)
                    worst = max(worst, float(vr.rate.max()))
                ok = "PASS" if worst <= eps + 0.005 else "FAIL"
                rows.append((f"fig13c_violation_{name}_D{int(D*1e3)}_eps{eps}", us,
                             f"max_violation={worst:.4f};eps={eps};{ok};"
                             f"plan_grid_us={grid_us:.0f}"))
    return rows
