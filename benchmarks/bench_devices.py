"""Fig. 12 — total energy vs number of devices; PCCP vs optimal policy.

Paper settings: AlexNet D=200 ms, B=5 MHz; ResNet152 D=150 ms, B=15 MHz.
Both policies dispatch through the same registry/Planner entry point —
``"optimal"`` is an ordinary policy with a ``solve`` override.
"""
from __future__ import annotations

import jax

from benchmarks.common import Row, timed
from repro.configs.paper_tables import alexnet_fleet, resnet152_fleet
from repro.core import Planner, PlannerConfig, Scenario

ROBUST = Planner(PlannerConfig(policy="robust", outer_iters=3, pccp_iters=6))
OPTIMAL = Planner(PlannerConfig(policy="optimal"))


def run() -> list[Row]:
    rows: list[Row] = []
    for name, fleet_fn, D, B in (("alexnet", alexnet_fleet, 0.200, 5e6),
                                 ("resnet152", resnet152_fleet, 0.150, 15e6)):
        for n in (4, 8, 12):
            fleet = fleet_fn(jax.random.PRNGKey(1), n)
            scenario = Scenario(D, 0.04, B)
            p, us = timed(lambda: ROBUST.plan(fleet, scenario))
            po, _ = timed(lambda: OPTIMAL.plan(fleet, scenario))
            gap = (float(p.total_energy) - float(po.total_energy)) / max(
                float(po.total_energy), 1e-12)
            rows.append((f"fig12_energy_{name}_N{n}", us,
                         f"pccp_J={float(p.total_energy):.4f};"
                         f"optimal_J={float(po.total_energy):.4f};gap={gap:.3f}"))
    return rows
