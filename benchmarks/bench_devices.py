"""Fig. 12 (energy vs N) + the group-sharded device-scaling ladder.

Sections (``--only fig12`` / ``--only devices``):

- ``fig12`` — total energy vs number of devices; PCCP vs optimal policy.
  Paper settings: AlexNet D=200 ms, B=5 MHz; ResNet152 D=150 ms, B=15 MHz.
  Both policies dispatch through the same registry/Planner entry point —
  ``"optimal"`` is an ordinary policy with a ``solve`` override.

- ``devices`` — the group-decomposed planner (``Planner.plan_sharded``,
  DESIGN.md §scale) at fleet scale: a wall-clock ladder over
  N ∈ {10³, 10⁴, 10⁵} devices (per-device bandwidth held constant, so
  the scenario physics does not drift with N), a sharded-vs-monolithic
  A/B on a mixed 8-vs-64-block fleet (where the monolithic path pays
  65-point padding on every 8-block row), and analytic peak-table-memory
  estimates. Ratio metrics land in ``BENCH_planner.json`` under
  ``devices``.
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import Row, timed, update_artifact
from repro.configs.paper_tables import (
    ALEXNET_D_MB,
    ALEXNET_G,
    ALEXNET_PLATFORM,
    ALEXNET_VLOC_MS2,
    ALEXNET_VM_FULL_S,
    ALEXNET_W_GFLOPS,
    AREA_M,
    TX_POWER_W,
    alexnet_chain,
    alexnet_fleet,
    build_chain,
    resnet152_fleet,
)
from repro.core import Planner, PlannerConfig, Scenario
from repro.core.decompose import bucket_size
from repro.core.fleet import DeviceSpec, FleetSpec


def run_fig12() -> list[Row]:
    # planners are built inside the runner: module import must not touch
    # jax (TRC005 — import-time planner construction warms jit state the
    # analyzer cannot attribute)
    robust = Planner(PlannerConfig(policy="robust", outer_iters=3,
                                   pccp_iters=6))
    optimal = Planner(PlannerConfig(policy="optimal"))
    rows: list[Row] = []
    for name, fleet_fn, D, B in (("alexnet", alexnet_fleet, 0.200, 5e6),
                                 ("resnet152", resnet152_fleet, 0.150, 15e6)):
        for n in (4, 8, 12):
            fleet = fleet_fn(jax.random.PRNGKey(1), n)
            scenario = Scenario(D, 0.04, B)
            p, us = timed(lambda: robust.plan(fleet, scenario))
            po, _ = timed(lambda: optimal.plan(fleet, scenario))
            gap = (float(p.total_energy) - float(po.total_energy)) / max(
                float(po.total_energy), 1e-12)
            rows.append((f"fig12_energy_{name}_N{n}", us,
                         f"pccp_J={float(p.total_energy):.4f};"
                         f"optimal_J={float(po.total_energy):.4f};gap={gap:.3f}"))
    return rows


# ------------------------------------------------------------- devices
# Per-device bandwidth share held constant across the ladder (the N=50
# runtime-bench operating point), so every rung is the same per-device
# problem and wall-clock differences are purely planner scaling.
_PER_DEVICE_B_HZ = 200e3
_LADDER = (1_000, 10_000, 100_000)
_DEADLINE_S, _EPS = 0.22, 0.04

_CHAIN_TABLES = 6  # BlockChain float64 leaves per device row


def _alexnet_device(count: int, chain=None, name: str = "alexnet") -> DeviceSpec:
    return DeviceSpec(chain=alexnet_chain() if chain is None else chain,
                      kappa=ALEXNET_PLATFORM["kappa"],
                      f_min_hz=ALEXNET_PLATFORM["f_min"],
                      f_max_hz=ALEXNET_PLATFORM["f_max"],
                      p_tx_w=TX_POWER_W, count=count, name=name)


def _chain64():
    """The AlexNet profile resampled onto 64 blocks / 65 partition points
    (monotone in cumulative work/data, same endpoints): a deep-chain
    population for the padding A/B below."""
    m = np.linspace(0.0, 8.0, 65)
    src = np.arange(9.0)

    def rs(vals):
        return np.interp(m, src, np.asarray(vals, np.float64))

    return build_chain(rs(ALEXNET_D_MB), rs(ALEXNET_W_GFLOPS), rs(ALEXNET_G),
                       rs(ALEXNET_VLOC_MS2), ALEXNET_VM_FULL_S)


def _mixed_8v64_spec(n: int) -> FleetSpec:
    n8 = (3 * n) // 4
    return FleetSpec((_alexnet_device(n8, name="alexnet8"),
                      _alexnet_device(n - n8, chain=_chain64(),
                                      name="alexnet64")),
                     area_m=AREA_M)


def _table_bytes_monolithic(spec: FleetSpec) -> int:
    """Chain-table bytes of the padded monolithic fleet: every row at the
    fleet-wide maximum point count."""
    return _CHAIN_TABLES * 8 * spec.num_devices * spec.max_points


def _table_bytes_sharded_peak(spec: FleetSpec) -> int:
    """Peak chain-table bytes of the streamed group decomposition: the
    largest single group at its native width and bucketed lane count."""
    return max(_CHAIN_TABLES * 8 * bucket_size(g.count) * g.chain.num_points
               for g in spec.groups)


def run_devices() -> list[Row]:
    rows: list[Row] = []
    planner = Planner(PlannerConfig(policy="robust_exact", outer_iters=2,
                                    multi_start=False))

    # -- wall-clock ladder: one homogeneous population per rung ----------
    ladder = []
    for n in _LADDER:
        spec = FleetSpec((_alexnet_device(n),), area_m=AREA_M)
        gains = spec.sample_gains(jax.random.PRNGKey(1))
        sc = Scenario(_DEADLINE_S, _EPS, _PER_DEVICE_B_HZ * n)
        plan, us = timed(lambda: planner.plan_sharded(spec, sc, gains=gains),
                         repeats=1, warmup=1)
        entry = {"n_devices": n, "us": us, "n_pad": bucket_size(n),
                 "feasible": bool(np.asarray(plan.feasible).all()),
                 "energy_j": float(plan.total_energy)}
        ladder.append(entry)
        rows.append((f"devices_sharded_N{n}", us,
                     f"n_pad={entry['n_pad']};feasible={entry['feasible']};"
                     f"energy_J={entry['energy_j']:.2f}"))
    t_us = {e["n_devices"]: e["us"] for e in ladder}
    n_lo, n_hi = min(_LADDER), max(_LADDER)
    scaling_vs_linear = (t_us[n_hi] / t_us[n_lo]) / (n_hi / n_lo)

    # -- sharded vs monolithic on a mixed 8-vs-64-block fleet ------------
    # The PCCP policy iterates over the full table width, so the 65-point
    # padding the monolithic path forces onto the 8-block rows is paid on
    # every inner iteration; the per-group programs run at native width.
    ab_n = 128
    ab_spec = _mixed_8v64_spec(ab_n)
    ab_gains = ab_spec.sample_gains(jax.random.PRNGKey(5))
    ab_fleet = ab_spec.build(gains=ab_gains)
    ab_sc = Scenario(_DEADLINE_S, _EPS, _PER_DEVICE_B_HZ * ab_n)
    ab_planner = Planner(PlannerConfig(policy="robust", outer_iters=2,
                                       pccp_iters=4, multi_start=False))
    mono, mono_us = timed(lambda: ab_planner.plan(ab_fleet, ab_sc),
                          repeats=2, warmup=1)
    shard, shard_us = timed(
        lambda: ab_planner.plan_sharded(ab_spec, ab_sc, gains=ab_gains),
        repeats=2, warmup=1)
    ratio = mono_us / shard_us
    energy_rel_diff = abs(float(shard.total_energy) - float(mono.total_energy)
                          ) / max(float(mono.total_energy), 1e-12)
    rows.append((f"devices_mixed8v64_N{ab_n}_sharded", shard_us,
                 f"mono_us={mono_us:.0f};ratio={ratio:.2f}x;"
                 f"energy_rel_diff={energy_rel_diff:.2e}"))

    # -- analytic peak memory (chain tables, the per-device state) -------
    mem = {
        "mixed_8v64": {
            "monolithic_bytes": _table_bytes_monolithic(ab_spec),
            "sharded_peak_bytes": _table_bytes_sharded_peak(ab_spec),
        },
        "ladder_max": {
            "monolithic_bytes": _table_bytes_monolithic(
                FleetSpec((_alexnet_device(n_hi),), area_m=AREA_M)),
            "sharded_peak_bytes": _table_bytes_sharded_peak(
                FleetSpec((_alexnet_device(n_hi),), area_m=AREA_M)),
        },
    }
    for k in mem:
        mem[k]["ratio"] = (mem[k]["monolithic_bytes"]
                           / max(mem[k]["sharded_peak_bytes"], 1))

    update_artifact("devices", {
        "config": {"policy": "robust_exact", "outer_iters": 2,
                   "multi_start": False, "deadline_s": _DEADLINE_S,
                   "eps": _EPS, "per_device_b_hz": _PER_DEVICE_B_HZ},
        "scaling": ladder,
        "scaling_vs_linear": scaling_vs_linear,
        "meets_1p3x_linear": scaling_vs_linear <= 1.3,
        "max_n_devices": n_hi,
        "feasible_at_max": ladder[-1]["feasible"],
        "mixed_8v64": {
            "n_devices": ab_n,
            "config": {"policy": "robust", "outer_iters": 2, "pccp_iters": 4,
                       "multi_start": False},
            "monolithic_us": mono_us,
            "sharded_us": shard_us,
            "sharded_vs_monolithic_ratio": ratio,
            "energy_rel_diff": energy_rel_diff,
        },
        "peak_table_bytes": mem,
    })
    rows.append((f"devices_scaling_N{n_lo}_to_N{n_hi}", 0.0,
                 f"vs_linear={scaling_vs_linear:.2f}x;"
                 f"mixed8v64_ratio={ratio:.2f}x"))
    return rows


SECTIONS = {"fig12": run_fig12, "devices": run_devices}

# ``benchmarks.run`` selects sections without importing excluded modules,
# so it keeps its own declaration — fail loudly if the two drift.
from benchmarks.run import MODULE_SECTIONS as _DECLARED  # noqa: E402

assert tuple(SECTIONS) == _DECLARED["bench_devices"], (
    "benchmarks/run.py MODULE_SECTIONS is out of sync with "
    "bench_devices.SECTIONS")


def run() -> list[Row]:
    return run_fig12() + run_devices()
