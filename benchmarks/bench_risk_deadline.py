"""Figs. 13a/b & 14a/b — energy vs risk level ε and vs task deadline,
robust policy vs worst-case baseline (+ Gaussian-σ beyond-paper variant).

Paper settings: N=12; AlexNet B=10 MHz (D=180 ms for the ε sweep);
ResNet152 B=30 MHz (D=120 ms).
"""
from __future__ import annotations

import jax

from benchmarks.common import Row, timed
from repro.configs.paper_tables import alexnet_fleet, resnet152_fleet
from repro.core import plan


def run() -> list[Row]:
    rows: list[Row] = []
    scen = (("alexnet", alexnet_fleet, 0.180, 10e6, (0.16, 0.20, 0.24, 0.28)),
            ("resnet152", resnet152_fleet, 0.120, 30e6, (0.12, 0.14, 0.16, 0.18)))
    for name, fleet_fn, D, B, deadlines in scen:
        fleet = fleet_fn(jax.random.PRNGKey(0), 12)
        pw, _ = timed(lambda: plan(fleet, D, 0.02, B, policy="worst_case", outer_iters=3))
        ew = float(pw.total_energy)
        for eps in (0.02, 0.04, 0.06, 0.08):
            p, us = timed(lambda: plan(fleet, D, eps, B, policy="robust_exact",
                                       outer_iters=3))
            pg, _ = timed(lambda: plan(fleet, D, eps, B, policy="gaussian",
                                       outer_iters=3))
            e = float(p.total_energy)
            save = 100.0 * (ew - e) / max(ew, 1e-12)
            rows.append((f"fig13a_energy_{name}_eps{eps}", us,
                         f"robust_J={e:.4f};worst_J={ew:.4f};saving={save:.1f}%;"
                         f"gaussian_J={float(pg.total_energy):.4f}"))
        for D2 in deadlines:
            p, us = timed(lambda: plan(fleet, D2, 0.02 if name == "alexnet" else 0.04,
                                       B, policy="robust_exact", outer_iters=3))
            pw2, _ = timed(lambda: plan(fleet, D2, 0.02, B, policy="worst_case",
                                        outer_iters=3))
            rows.append((f"fig13b_energy_{name}_D{int(D2*1e3)}ms", us,
                         f"robust_J={float(p.total_energy):.4f};"
                         f"worst_J={float(pw2.total_energy):.4f}"))
    return rows
