"""Figs. 13a/b & 14a/b — energy vs risk level ε and vs task deadline,
robust policy vs worst-case baseline (+ Gaussian-σ beyond-paper variant).

Paper settings: N=12; AlexNet B=10 MHz (D=180 ms for the ε sweep);
ResNet152 B=30 MHz (D=120 ms).

Each sweep is ONE ``Planner.grid`` call (cartesian sugar over the zipped
``plan_many`` batch), so the reported µs/call is the whole figure's
sweep, not a single scenario.
"""
from __future__ import annotations

import jax

from benchmarks.common import Row, timed
from repro.configs.paper_tables import alexnet_fleet, resnet152_fleet
from repro.core import Planner, PlannerConfig

EPSS = (0.02, 0.04, 0.06, 0.08)

PLANNERS = {pol: Planner(PlannerConfig(policy=pol, outer_iters=3))
            for pol in ("robust_exact", "gaussian", "worst_case")}


def run() -> list[Row]:
    rows: list[Row] = []
    scen = (("alexnet", alexnet_fleet, 0.180, 10e6, (0.16, 0.20, 0.24, 0.28)),
            ("resnet152", resnet152_fleet, 0.120, 30e6, (0.12, 0.14, 0.16, 0.18)))
    for name, fleet_fn, D, B, deadlines in scen:
        fleet = fleet_fn(jax.random.PRNGKey(0), 12)
        # worst_case uses σ_hard ≡ 0, so ε never enters — one plan suffices.
        # Untimed calls (discarded `_`) skip the warmup: no point solving twice.
        pw, _ = timed(lambda D=D, B=B: PLANNERS["worst_case"].grid(fleet, D, EPSS[0], B),
                      repeats=1, warmup=0)
        ew = float(pw.total_energy[0, 0, 0])
        pr, us = timed(lambda D=D, B=B: PLANNERS["robust_exact"].grid(fleet, D, EPSS, B),
                       repeats=1)
        pg, _ = timed(lambda D=D, B=B: PLANNERS["gaussian"].grid(fleet, D, EPSS, B),
                      repeats=1, warmup=0)
        for j, eps in enumerate(EPSS):
            e = float(pr.total_energy[0, j, 0])
            save = 100.0 * (ew - e) / max(ew, 1e-12)
            rows.append((f"fig13a_energy_{name}_eps{eps}", us / len(EPSS),
                         f"robust_J={e:.4f};worst_J={ew:.4f};saving={save:.1f}%;"
                         f"gaussian_J={float(pg.total_energy[0, j, 0]):.4f}"))

        eps_d = 0.02 if name == "alexnet" else 0.04
        pd, us = timed(
            lambda deadlines=deadlines, B=B:
                PLANNERS["robust_exact"].grid(fleet, deadlines, eps_d, B),
            repeats=1)
        pwd, _ = timed(
            lambda deadlines=deadlines, B=B:
                PLANNERS["worst_case"].grid(fleet, deadlines, 0.02, B),
            repeats=1, warmup=0)
        for i, D2 in enumerate(deadlines):
            rows.append((f"fig13b_energy_{name}_D{int(D2*1e3)}ms", us / len(deadlines),
                         f"robust_J={float(pd.total_energy[i, 0, 0]):.4f};"
                         f"worst_J={float(pwd.total_energy[i, 0, 0]):.4f}"))
    return rows
