"""Beyond-paper framework bench: the robust planner driving two-tier
serving of zoo architectures, in two regimes.

(i) "abundant edge" (paper-like dedicated VMs): full offload m=0 is
    provably optimal for token-input transformers — raw tokens are ~KB
    while boundary activations are ~MB and, unlike CNN feature maps
    (Fig. 3 of the paper), never shrink with depth. A structural finding
    about how the paper's premise transfers (DESIGN.md §5).
(ii) "congested edge" (shared accelerator, VM time and variance scale
    with the fleet): the chance constraint pushes work on-device; the
    robust policy still saves 30%+ energy vs worst-case by running lower
    clocks under the same probabilistic deadline.
"""
from __future__ import annotations

from benchmarks.common import Row, timed
from repro.configs.registry import get_config
from repro.models.costmodel import TierProfile
from repro.serve.partitioned import TwoTierDeployment

ARCHS = ("tinyllama-1.1b", "internvl2-2b", "mamba2-130m", "deepseek-v2-lite-16b")
_FAST_DEV = TierProfile(flops_per_cycle=4000.0, cv=0.10, eff_jitter=0.10)
_SLOW_EDGE = TierProfile(flops_per_cycle=8000.0, cv=0.08, eff_jitter=0.05, clock_hz=1.5e9)
_DEADLINES = {"tinyllama-1.1b": 0.45, "internvl2-2b": 0.75,
              "mamba2-130m": 0.075, "deepseek-v2-lite-16b": 1.2}


def run() -> list[Row]:
    rows: list[Row] = []
    for arch in ARCHS:
        # regime (i): dedicated VMs — full offload wins
        dep = TwoTierDeployment(get_config(arch), num_devices=8, deadline_s=1.5,
                                eps=0.05, bandwidth_hz=100e6)
        (p, fleet), us = timed(lambda: dep.plan())
        rep = dep.validate(p, fleet)
        rows.append((f"twotier_abundant_{arch}", us,
                     f"J={rep['total_energy_j']:.4f};viol={rep['max_violation']:.4f};"
                     f"m={list(map(int, p.m_sel))}"))

        # regime (ii): congested shared edge — robust on-device scaling
        dep = TwoTierDeployment(get_config(arch), num_devices=8,
                                deadline_s=_DEADLINES[arch], eps=0.05,
                                bandwidth_hz=60e6, seq_len=512,
                                dedicated_vm=False, device=_FAST_DEV,
                                edge=_SLOW_EDGE, f_max_hz=2.5e9)
        (p, fleet), us = timed(lambda: dep.plan())
        (pw, _), _ = timed(lambda: dep.plan(policy="worst_case"))
        rep = dep.validate(p, fleet)
        save = 100 * (float(pw.total_energy) - rep["total_energy_j"]) / max(
            float(pw.total_energy), 1e-12)
        rows.append((f"twotier_congested_{arch}", us,
                     f"J={rep['total_energy_j']:.4f};worst_J={float(pw.total_energy):.4f};"
                     f"saving={save:.1f}%;viol={rep['max_violation']:.4f};"
                     f"m={list(map(int, p.m_sel))}"))
    return rows
