# One function per paper table/figure. Prints ``name,us_per_call,derived`` CSV.
#
#   PYTHONPATH=src python -m benchmarks.run [--only runtime,solver,...]
#
# ``--only`` entries match bench *module* names (substring) as before, and
# additionally the named *sections* a module exposes via a ``SECTIONS``
# dict (section name → zero-arg runner, declared in ``MODULE_SECTIONS``
# below so excluded modules are never imported) — so ``--only solver``
# runs just the solver A/B section of bench_runtime without the Fig.-11
# sweep, and ``--only runtime`` just the sweep without the A/B. For a
# module that declares sections, section matches take priority over a
# module-substring match (otherwise ``runtime`` could never select its
# section — it always substring-matches ``bench_runtime``); use the full
# module name (``--only bench_runtime``) to run such a module whole.
#
# Benches:
#   bench_fit           — Fig. 6   (NLS fit of t̄ = w/(g·f))
#   bench_convergence   — Fig. 9/10 (PCCP iterations; Alg.-2 trajectories)
#   bench_runtime       — Fig. 11  (runtime vs N; steady-state + compile,
#                         seed-loop speedup at N=50 → BENCH_planner.json)
#   bench_devices       — Fig. 12  (energy vs N; PCCP vs optimal) + the
#                         group-sharded scaling ladder to N=10⁵ devices
#                         (sharded-vs-monolithic ratio → BENCH_planner.json)
#   bench_risk_deadline — Fig. 13a/b, 14a/b (energy vs ε / deadline,
#                         one plan_grid call per sweep)
#   bench_violation     — Fig. 13c/14c (violation probability ≤ ε)
#   bench_plan_grid     — zipped 9-scenario plan_many vs sequential plans
#                         (+ seed-loop continuity ratio → BENCH_planner.json)
#   bench_hetero        — ragged mixed-model fleet: one compiled plan vs
#                         per-group sequential (ratios → BENCH_planner.json)
#   bench_edge          — shared-edge capacity pricing vs static N-scaling
#                         vs dedicated-VM (DESIGN.md §edge; energy at
#                         matched MC violation → BENCH_planner.json) + the
#                         E=3 multi-node placement A/B (priced Hybrid vs
#                         round-robin/greedy baselines + Cantelli ε_edge
#                         sweep → BENCH_planner.json §placement)
#   bench_faults        — closed-loop fault drill: guarded vs unguarded
#                         serving through an injected incident (DESIGN.md
#                         §robustness; recovery/churn → BENCH_planner.json)
#   bench_replay        — trace-driven replay: event-driven serving under
#                         a per-node brownout on the E=3 placement, with
#                         sentinel-triggered migration + regret vs a
#                         schedule-aware oracle (→ BENCH_planner.json
#                         §replay)
#   bench_two_tier      — beyond-paper: planner over zoo architectures
#   bench_channel       — beyond-paper: channel uncertainty + hetero fleet
#   bench_kernels       — Pallas kernels vs references
#   bench_roofline      — §Roofline terms from dry-run artifacts
from __future__ import annotations

import argparse
import sys
import traceback

from benchmarks.common import emit

MODULES = [
    "bench_fit",
    "bench_convergence",
    "bench_runtime",
    "bench_devices",
    "bench_risk_deadline",
    "bench_violation",
    "bench_plan_grid",
    "bench_hetero",
    "bench_edge",
    "bench_faults",
    "bench_replay",
    "bench_two_tier",
    "bench_channel",
    "bench_kernels",
    "bench_roofline",
]

#: Named sections (module → section names) selectable via ``--only``
#: without running the whole module. Declared here — not discovered by
#: importing — so a filtered run never imports (and never fails on)
#: modules it was asked to exclude. Keep in sync with each module's
#: ``SECTIONS`` dict; bench_runtime asserts the two agree.
MODULE_SECTIONS = {
    "bench_runtime": ("runtime", "solver"),
    "bench_devices": ("fig12", "devices"),
    "bench_edge": ("edge", "placement"),
    "bench_replay": ("replay",),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated substrings of bench module names")
    args = ap.parse_args()
    wanted = args.only.split(",") if args.only else None

    print("name,us_per_call,derived")
    failures = 0
    for mod_name in MODULES:
        module_match = wanted is None or any(w in mod_name for w in wanted)
        section_match = [] if wanted is None else [
            s for s in MODULE_SECTIONS.get(mod_name, ())
            if any(w in s for w in wanted)]
        if not module_match and not section_match:
            continue  # excluded modules are never imported
        try:
            mod = __import__(f"benchmarks.{mod_name}", fromlist=["run"])
            if section_match:  # sections shadow module-substring matches
                for sec_name in section_match:
                    emit(mod.SECTIONS[sec_name]())
                continue
            emit(mod.run())
        except Exception:
            failures += 1
            print(f"{mod_name},0,ERROR", file=sys.stderr)
            traceback.print_exc()
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
