"""Closed-loop fault drill: guarded vs unguarded serving under an
injected incident (DESIGN.md §robustness).

One reproducible incident against the AlexNet fleet: a VM-side moment
drift (mean ramps to 4× over 8 steps, then holds — a co-tenant that
stays) composed with a sustained straggler burst (from step 14 to the
horizon, 35% of VM executions pick up a heavy-tailed ~0.15 s extra).
Two deployments serve through it:

- ``unguarded`` — the plan solved at t=0 is never touched. Its window
  violation rate climbs past ε when the incident lands and *stays*
  there: the nominal-moment guarantee is simply void.
- ``guarded``   — the violation sentinel trips (exact binomial tail,
  α=1e-3) and the degradation ladder escalates: price re-step → warm
  re-plan on re-fit moments → precomputed contingency. The contingency
  (local-only, σ inflated 1.5×) side-steps the faulted tier entirely, so
  the window rate returns ≤ ε within a bounded recovery window — at a
  visible energy cost (that is the trade: energy for the SLO).

Headline (``faults`` section of ``BENCH_planner.json``):
``unguarded.final_window_rate`` > ε while ``guarded.final_window_rate``
≤ ε with ``guarded.recovery_steps`` bounded and plan churn reported.
"""
from __future__ import annotations

import time
import warnings

import jax

from benchmarks.common import update_artifact
from repro.configs.paper_tables import alexnet_fleet
from repro.core.api import Planner, PlannerConfig, Scenario
from repro.serve.closedloop import GuardConfig, run_closed_loop
from repro.serve.faults import compose, moment_drift, straggler_burst
from repro.serve.guard import SentinelConfig

N_DEVICES = 8
DEADLINE, EPS, BANDWIDTH = 0.25, 0.05, 10e6
STEPS = 40
REQUESTS_PER_STEP = 64
DRIFT = dict(onset=8, vm_ramp=3.0, ramp_steps=8)
BURST = dict(start=14, prob=0.35, extra_s=0.15)


def _incident():
    return compose(
        moment_drift(STEPS, **DRIFT),
        straggler_burst(STEPS, length=STEPS - BURST["start"], **BURST),
    )


def run() -> list:
    fleet = alexnet_fleet(jax.random.PRNGKey(0), N_DEVICES)
    scenario = Scenario(deadline=DEADLINE, eps=EPS, B=BANDWIDTH)
    planner = Planner(PlannerConfig(policy="robust_exact"))
    guard = GuardConfig(
        sentinel=SentinelConfig(window=1024, alpha=1e-3, min_count=128))
    schedule = _incident()
    key = jax.random.PRNGKey(42)

    rows: list = []
    results = {}
    for name, guarded in (("unguarded", False), ("guarded", True)):
        t0 = time.perf_counter()
        r = run_closed_loop(
            fleet, scenario, schedule, planner, key,
            requests_per_step=REQUESTS_PER_STEP, guarded=guarded, guard=guard)
        us = (time.perf_counter() - t0) * 1e6 / STEPS
        results[name] = r
        rows.append((
            f"faults/{name}", us,
            f"final_rate={r.final_window_rate:.4f};"
            f"peak_rate={r.peak_window_rate:.4f};replans={r.replans};"
            f"churn={r.churn};recovery={r.recovery_steps}"))

    ung, grd = results["unguarded"], results["guarded"]
    # mean planned energy over the post-incident half: what the guarded
    # loop pays (the contingency burns more energy) for restoring the SLO
    tail = slice(STEPS // 2, STEPS)
    payload = {
        "steps": STEPS,
        "requests_per_step": REQUESTS_PER_STEP,
        "eps": EPS,
        "deadline_s": DEADLINE,
        "schedule": {"drift": DRIFT,
                     "burst": dict(BURST, length=STEPS - BURST["start"])},
        "unguarded": {
            "peak_window_rate": ung.peak_window_rate,
            "final_window_rate": ung.final_window_rate,
            "tail_energy_j": float(ung.energy[tail].mean()),
        },
        "guarded": {
            "peak_window_rate": grd.peak_window_rate,
            "final_window_rate": grd.final_window_rate,
            "replans": grd.replans,
            "churn": grd.churn,
            "first_trip_step": grd.first_trip_step,
            "recovery_steps": grd.recovery_steps,
            "tail_energy_j": float(grd.energy[tail].mean()),
        },
        "unguarded_final_gt_eps": bool(ung.final_window_rate > EPS),
        "guarded_final_leq_eps": bool(grd.final_window_rate <= EPS),
    }
    update_artifact("faults", payload)

    if not payload["guarded_final_leq_eps"]:
        warnings.warn(
            f"guarded closed loop ended above eps: "
            f"{grd.final_window_rate:.4f} > {EPS}", RuntimeWarning,
            stacklevel=2)
    if not payload["unguarded_final_gt_eps"]:
        warnings.warn(
            "incident too weak: unguarded loop ended back under eps "
            f"({ung.final_window_rate:.4f} <= {EPS})", RuntimeWarning,
            stacklevel=2)
    rows.append((
        "faults/headline", 0.0,
        f"unguarded_final={ung.final_window_rate:.4f}>"
        f"eps={EPS};guarded_final={grd.final_window_rate:.4f};"
        f"recovery_steps={grd.recovery_steps}"))
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit

    emit(run())
