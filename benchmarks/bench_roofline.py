"""§Roofline — three-term roofline per (arch × shape) from the dry-run
artifacts in results/dryrun/*.json (single-pod mesh).

  compute    = loop-aware HLO FLOPs / (chips × 197 TFLOP/s bf16)
  memory     = loop-aware dot traffic bytes / (chips × 819 GB/s)
  collective = Σ weighted collective bytes / (chips × 50 GB/s ICI)

All three are *per-device* seconds (the dry-run stores per-device
numbers). Also reports MODEL_FLOPS/HLO_FLOPs (useful-compute ratio) and
the HBM fit against 16 GiB.
"""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import Row

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9
HBM_CAP = 16 * 2**30

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")


def load_records(mesh: str = "16x16"):
    recs = []
    for path in sorted(glob.glob(os.path.join(RESULTS, f"*__{mesh}.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def terms(rec: dict) -> dict:
    n = rec["num_devices"]
    flops = rec.get("hlo_loop_aware_flops_per_dev", 0.0)
    dbytes = rec.get("hlo_loop_aware_dot_bytes_per_dev", 0.0)
    coll = sum(rec.get("collective_bytes_per_dev", {}).values())
    compute_s = flops / PEAK_FLOPS
    memory_s = dbytes / HBM_BW
    collective_s = coll / ICI_BW
    dom = max((("compute", compute_s), ("memory", memory_s),
               ("collective", collective_s)), key=lambda kv: kv[1])[0]
    hbm = (rec.get("arg_bytes_per_dev", 0) + rec.get("temp_bytes_per_dev", 0)
           + rec.get("out_bytes_per_dev", 0) - rec.get("alias_bytes_per_dev", 0))
    model_per_dev = rec.get("model_flops_total", 0.0) / n
    return {
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "dominant": dom,
        "useful_ratio": model_per_dev / flops if flops else 0.0,
        "hbm_gib": hbm / 2**30,
        "fits": hbm <= HBM_CAP,
    }


def run() -> list[Row]:
    rows: list[Row] = []
    for rec in load_records():
        name = f"roofline_{rec['arch']}_{rec['shape']}"
        if rec.get("status") == "skipped":
            rows.append((name, 0.0, "skipped"))
            continue
        if rec.get("status") != "ok":
            rows.append((name, 0.0, f"status={rec.get('status')}"))
            continue
        t = terms(rec)
        rows.append((name, 0.0,
                     f"compute_s={t['compute_s']:.4f};memory_s={t['memory_s']:.4f};"
                     f"collective_s={t['collective_s']:.4f};dom={t['dominant']};"
                     f"useful={t['useful_ratio']:.2f};hbm_GiB={t['hbm_gib']:.1f};"
                     f"fits={t['fits']}"))
    if not rows:
        rows.append(("roofline", 0.0, "no dry-run artifacts; run repro.launch.dryrun_all"))
    return rows


def markdown_table(mesh: str = "16x16") -> str:
    lines = [
        "| arch | shape | compute s | memory s | collective s | dominant | "
        "MODEL/HLO | HBM GiB | fits |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for rec in load_records(mesh):
        if rec.get("status") == "skipped":
            lines.append(f"| {rec['arch']} | {rec['shape']} | — | — | — | skipped | — | — | — |")
            continue
        if rec.get("status") != "ok":
            lines.append(f"| {rec['arch']} | {rec['shape']} | — | — | — | {rec['status']} | — | — | — |")
            continue
        t = terms(rec)
        lines.append(
            f"| {rec['arch']} | {rec['shape']} | {t['compute_s']:.4f} | "
            f"{t['memory_s']:.4f} | {t['collective_s']:.4f} | {t['dominant']} | "
            f"{t['useful_ratio']:.2f} | {t['hbm_gib']:.1f} | "
            f"{'✓' if t['fits'] else '✗'} |")
    return "\n".join(lines)
