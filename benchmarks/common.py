"""Shared benchmark utilities. Every bench emits CSV rows
``name,us_per_call,derived`` (derived = the paper figure's metric).

``timed`` reports *steady-state* µs/call: the first call (jit compile) is
excluded by a warmup, every call is synced with ``jax.block_until_ready``
so device work is actually finished when the clock stops, and the result
is averaged over ``repeats``. Use ``timed_compile`` when the compile time
itself is part of the story (e.g. Fig. 11 cold vs warm).
"""
from __future__ import annotations

import json
import os
import time
from typing import Callable, List, NamedTuple, Tuple

import jax

Row = Tuple[str, float, str]

#: Machine-readable planner-perf artifact (repo root by default). Multiple
#: benches contribute sections via ``update_artifact`` so the perf
#: trajectory (ratio metrics, not raw wall-clock) accumulates in one file.
PLANNER_ARTIFACT = os.environ.get("BENCH_PLANNER_JSON", "BENCH_planner.json")


def update_artifact(section: str, payload: dict, path: str = None) -> None:
    """Read-modify-write ``payload`` under ``section`` in the JSON artifact."""
    path = PLANNER_ARTIFACT if path is None else path
    data = {}
    if os.path.exists(path):
        try:
            with open(path) as f:
                data = json.load(f)
        except (OSError, ValueError):
            data = {}
    if not isinstance(data, dict) or "rows" in data:  # pre-PR2 flat layout
        data = {}
    data[section] = payload
    with open(path, "w") as f:
        json.dump(data, f, indent=1)


def _sync(out):
    """Block until every array in ``out`` is materialized on device."""
    try:
        return jax.block_until_ready(out)
    except Exception:  # non-pytree / host-only outputs
        return out


def timed(fn: Callable, repeats: int = 3, warmup: int = 1):
    """(out, steady_us): post-warmup, device-synced µs per call."""
    out = None
    for _ in range(max(warmup, 0)):
        out = _sync(fn())
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = _sync(fn())
    dt = (time.perf_counter() - t0) / repeats
    return out, dt * 1e6  # µs


class Timing(NamedTuple):
    out: object
    compile_us: float  # first (cold) call — dominated by jit compile
    us: float  # steady-state per call


def timed_compile(fn: Callable, repeats: int = 3) -> Timing:
    """Like ``timed`` but also reports the cold first call separately."""
    t0 = time.perf_counter()
    out = _sync(fn())
    compile_us = (time.perf_counter() - t0) * 1e6
    out, us = timed(fn, repeats=repeats, warmup=0)
    return Timing(out=out, compile_us=compile_us, us=us)


def emit(rows: List[Row]) -> None:
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
