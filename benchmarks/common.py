"""Shared benchmark utilities. Every bench emits CSV rows
``name,us_per_call,derived`` (derived = the paper figure's metric)."""
from __future__ import annotations

import time
from typing import Callable, List, Tuple

Row = Tuple[str, float, str]


def timed(fn: Callable, repeats: int = 1):
    t0 = time.perf_counter()
    out = None
    for _ in range(repeats):
        out = fn()
    dt = (time.perf_counter() - t0) / repeats
    return out, dt * 1e6  # µs


def emit(rows: List[Row]) -> None:
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
