"""Trace-driven replay drill: event-driven serving under a per-node
edge fault, with migration and regret-vs-oracle accounting (DESIGN.md
§robustness).

One reproducible scenario on the E=3 placement fleet (the bench_edge
setup): a seeded Poisson trace is replayed through the closed loop
while the node holding most of the plan's devices browns out to a few
percent of its capacity mid-trace and stays degraded. Three runs share
the trace and the sample key stream:

- ``unguarded`` — the t=0 plan is frozen; the faulted node congests and
  the final-window violation rate exceeds ε.
- ``guarded``   — the sentinel trips on the real request stream, the
  per-node capacity re-fit shrinks the degraded node's estimated
  budget, and the ladder's re-plan re-runs the ``hybrid`` allocator:
  the node's devices *migrate* (churn + per-migration energy metered)
  and the final window returns ≤ ε.
- ``oracle``    — re-plans against the true faulted fleet/capacity the
  moment the schedule moves (clairvoyant); the cumulative energy +
  violation gap to it is the regret the controller's reaction time
  costs.

The replay loop must also stay on one compiled epoch program: the
benchmark replays a value-varied tail (different key, different fault
depth) and records that ``sample_epoch`` compiled nothing new
(``replay_recompile_drill`` in ``make analyze`` enforces the same pin).
"""
from __future__ import annotations

import time
import warnings

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import update_artifact

N_DEVICES = 8
DEADLINE, EPS, BANDWIDTH = 0.2, 0.04, 30e6
#: per-node shares of the slack plan's occupancy (bench_edge's recipe)
SHARES = (0.2, 0.1, 0.05)
EPOCHS = 40
RATE_PER_EPOCH = 96.0
FAULT = dict(start=10, depth=0.03)  # brownout to 3% capacity, held to the end


def run_replay() -> list:
    from repro.configs.paper_tables import mixed_spec
    from repro.core import Planner, PlannerConfig, Scenario
    from repro.core.resource import select_point
    from repro.serve import replay as rp
    from repro.serve.closedloop import GuardConfig
    from repro.serve.faults import brownout
    from repro.serve.guard import SentinelConfig

    fleet = mixed_spec(N_DEVICES).build(jax.random.PRNGKey(11))
    planner = Planner(PlannerConfig(policy="robust_exact", outer_iters=3,
                                    pccp_iters=6))
    slack = planner.plan(fleet, Scenario(DEADLINE, EPS, BANDWIDTH))
    occ0 = float(select_point(fleet, slack.m_sel).t_vm.sum())
    caps = jnp.asarray(SHARES) * occ0
    sc = Scenario(DEADLINE, EPS, BANDWIDTH, caps)

    p0 = planner.plan(fleet, sc)
    a0 = np.asarray(p0.assignment)
    node = int(np.argmax(np.bincount(a0, minlength=caps.shape[0])))
    on_node = int((a0 == node).sum())
    sched = brownout(EPOCHS, start=FAULT["start"],
                     length=EPOCHS - FAULT["start"], depth=FAULT["depth"],
                     node=node, num_nodes=caps.shape[0])
    trace = rp.poisson_trace(rate_per_epoch=RATE_PER_EPOCH, epochs=EPOCHS,
                             epoch_s=1.0, num_devices=N_DEVICES, seed=7)
    guard = GuardConfig(
        sentinel=SentinelConfig(window=256, alpha=1e-3, min_count=48))
    key = jax.random.PRNGKey(5)

    rows: list = []
    results = {}
    for name, kw in (("unguarded", dict(guarded=False)),
                     ("guarded", dict(guarded=True)),
                     ("oracle", dict(oracle=True))):
        t0 = time.perf_counter()
        r = rp.replay(fleet, sc, sched, planner, trace, key, guard=guard,
                      **kw)
        us = (time.perf_counter() - t0) * 1e6 / EPOCHS
        results[name] = r
        rows.append((
            f"replay/{name}", us,
            f"final_rate={r.final_window_rate:.4f};"
            f"viol={r.total_violations};replans={r.replans};"
            f"migrations={r.migrations};"
            f"mig_energy_j={r.migration_energy_j:.4e}"))

    ung, grd, orc = results["unguarded"], results["guarded"], results["oracle"]
    regret = rp.regret_curves(grd, orc)

    # zero-recompile pin: replay a value-varied tail (new key, new depth)
    # — every traced program must already be compiled
    cache0 = rp.sample_epoch._cache_size()
    sched2 = brownout(EPOCHS, start=FAULT["start"],
                      length=EPOCHS - FAULT["start"], depth=0.5 * FAULT["depth"],
                      node=node, num_nodes=caps.shape[0])
    rp.replay(fleet, sc, sched2, planner, trace, jax.random.PRNGKey(6),
              guarded=False, guard=guard)
    zero_recompiles = rp.sample_epoch._cache_size() == cache0

    payload = {
        "epochs": EPOCHS,
        "rate_per_epoch": RATE_PER_EPOCH,
        "requests": trace.num_requests,
        "trace_capacity": trace.capacity,
        "eps": EPS,
        "deadline_s": DEADLINE,
        "fault": dict(FAULT, node=node, devices_on_node=on_node),
        "unguarded": {
            "final_window_rate": ung.final_window_rate,
            "violations": ung.total_violations,
            "energy_j": ung.total_energy_j,
        },
        "guarded": {
            "final_window_rate": grd.final_window_rate,
            "violations": grd.total_violations,
            "energy_j": grd.total_energy_j,
            "replans": grd.replans,
            "churn": grd.churn,
            "migrations": grd.migrations,
            "migration_energy_j": grd.migration_energy_j,
        },
        "oracle": {
            "violations": orc.total_violations,
            "energy_j": orc.total_energy_j,
            "replans": orc.replans,
            "migrations": orc.migrations,
        },
        "regret": {
            "final_energy_j": regret["final_energy_j"],
            "final_violations": regret["final_violations"],
            "energy_curve_j": regret["energy_j"].tolist(),
            "violation_curve": regret["violations"].tolist(),
        },
        "unguarded_final_gt_eps": bool(ung.final_window_rate > EPS),
        "guarded_final_leq_eps": bool(grd.final_window_rate <= EPS),
        "guarded_migrated": bool(grd.migrations > 0),
        "zero_recompiles": bool(zero_recompiles),
    }
    update_artifact("replay", payload)

    if not payload["guarded_final_leq_eps"]:
        warnings.warn(
            f"guarded replay ended above eps: "
            f"{grd.final_window_rate:.4f} > {EPS}", RuntimeWarning,
            stacklevel=2)
    if not payload["unguarded_final_gt_eps"]:
        warnings.warn(
            "fault too weak: unguarded replay ended back under eps "
            f"({ung.final_window_rate:.4f} <= {EPS})", RuntimeWarning,
            stacklevel=2)
    if not zero_recompiles:
        warnings.warn("replay recompiled on a value-varied tail",
                      RuntimeWarning, stacklevel=2)
    rows.append((
        "replay/headline", 0.0,
        f"unguarded_final={ung.final_window_rate:.4f}>eps={EPS};"
        f"guarded_final={grd.final_window_rate:.4f};"
        f"migrations={grd.migrations};"
        f"regret_viol={regret['final_violations']};"
        f"zero_recompiles={zero_recompiles}"))
    return rows


SECTIONS = {"replay": run_replay}


def run() -> list:
    return run_replay()


if __name__ == "__main__":
    from benchmarks.common import emit

    emit(run())
