"""Fig. 9 (Algorithm-1 iterations vs N) and Fig. 10 (Algorithm-2
convergence trajectories from different initial points), plus the
wall-clock saved by the convergence-gated PCCP outer loop
(``pccp_gated=True`` — the while_loop variant of DESIGN.md §solver that
stops once every device satisfies ‖x_i − x_{i−1}‖ < θ_err)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Row, timed
from repro.configs.paper_tables import alexnet_fleet, resnet152_fleet
from repro.core import Planner, PlannerConfig, Scenario


def _iters_hist(iters) -> str:
    """`k:count` histogram of per-device Algorithm-1 iteration counts."""
    counts = np.bincount(np.asarray(iters).ravel())
    return "|".join(f"{k}:{c}" for k, c in enumerate(counts) if c)


def run() -> list[Row]:
    rows: list[Row] = []
    # Fig. 9: average PCCP iterations vs number of devices
    planner = Planner(PlannerConfig(policy="robust", outer_iters=2,
                                    pccp_iters=8, multi_start=False))
    for name, fleet_fn, D, B in (("alexnet", alexnet_fleet, 0.22, 10e6),
                                 ("resnet152", resnet152_fleet, 0.16, 30e6)):
        for n in (6, 12, 18, 30):
            fleet = fleet_fn(jax.random.PRNGKey(n), n)
            p, us = timed(lambda D=D, B=B: planner.plan(fleet, Scenario(D, 0.04, B)))
            iters = float(jnp.mean(p.pccp_iters[-1]))
            rows.append((f"fig9_pccp_iters_{name}_N{n}", us, f"avg_iters={iters:.2f}"))

    # Fig. 9 follow-on: the gated while_loop outer PCCP stops at the
    # Algorithm-1 stopping rule instead of running the fixed trip count —
    # the iteration histogram shows how much of the pccp_iters budget the
    # fixed-trip scan wastes, and saved_ratio the wall-clock recovered.
    gated_cfg = dict(policy="robust", outer_iters=2, pccp_iters=8,
                     multi_start=False)
    gated = Planner(PlannerConfig(pccp_gated=True, **gated_cfg))
    scan = Planner(PlannerConfig(**gated_cfg))  # identical bar the gate
    for name, fleet_fn, D, B in (("alexnet", alexnet_fleet, 0.22, 10e6),
                                 ("resnet152", resnet152_fleet, 0.16, 30e6)):
        fleet = fleet_fn(jax.random.PRNGKey(12), 12)
        scenario = Scenario(D, 0.04, B)
        pg, gated_us = timed(lambda: gated.plan(fleet, scenario))
        _, scan_us = timed(lambda: scan.plan(fleet, scenario))
        rows.append((
            f"fig9_gated_{name}_N12", gated_us,
            f"scan_us={scan_us:.0f};saved_ratio={scan_us / gated_us:.2f}x;"
            f"iters_hist={_iters_hist(pg.pccp_iters)}"))

    # Fig. 10: Algorithm-2 objective trajectories from different inits
    # (init_m resolves to a traced start array, so the per-init configs
    # all share one compiled program)
    for name, fleet_fn, D, B, inits in (
        ("alexnet", alexnet_fleet, 0.22, 10e6, (3, 7, 8)),
        ("resnet152", resnet152_fleet, 0.16, 30e6, (1, 8, 9)),
    ):
        fleet = fleet_fn(jax.random.PRNGKey(0), 12)
        finals = []
        for init in inits:
            pl = Planner(PlannerConfig(policy="robust_exact", outer_iters=5,
                                       init_m=init, multi_start=False))
            p, us = timed(lambda D=D, B=B: pl.plan(fleet, Scenario(D, 0.04, B)))
            tr = [f"{float(v):.4f}" for v in p.objective_trace]
            finals.append(float(p.objective_trace[-1]))
            rows.append((f"fig10_traj_{name}_init{init}", us, "traj=" + "|".join(tr)))
        spread = (max(finals) - min(finals)) / max(min(finals), 1e-12)
        rows.append((f"fig10_final_spread_{name}", 0.0, f"rel_spread={spread:.3f}"))
    return rows
