"""Shared-edge capacity pricing vs the static N-scaling approximation
(DESIGN.md §edge).

One shared edge accelerator serves N devices. Three ways to plan it:

- ``dedicated``    — pretend every device has its own VM (the paper's
  §III-B assumption): ignores contention entirely. Cheapest on paper;
  overloads the real edge, so the congestion ground truth blows its
  deadline violations past ε.
- ``static_scale`` — the deprecated pre-capacity approximation: bake
  ``vm_time_scale = N`` into the chain, i.e. charge every device as if
  all N always contend. Safe but overcharges lightly loaded plans, so it
  drives far more work on-device than necessary and burns energy.
- ``coupled``      — the real coupling: Σ t̄_vm(m_n) ≤ C_edge priced by
  the dual μ next to the bandwidth λ. Offloads up to the capacity and no
  further.

All three are validated against the SAME ground truth: the physical
(unscaled) fleet with the processor-sharing congestion model of
``montecarlo.violation_report`` (VM times stretch by max(1, Σ t̄_vm/C)).

Headline ratios in the ``edge`` section of ``BENCH_planner.json``:
``coupled_vs_static_energy_ratio`` (< 1: the dual-priced plan dominates
the static approximation on energy) at ``coupled_minus_static_violation``
≤ 0 + MC noise (no robustness given up for it).

The ``placement`` section (DESIGN.md §placement) moves to E=3
heterogeneous edge nodes on a mixed fleet: the per-node-priced planner
with the Hybrid allocator vs the round-robin and greedy-load baselines
(same ε, same capacity vector), judged by planned energy + the per-node
congestion ground truth + the duality-gap certificate, plus a Cantelli
``edge_eps`` sweep showing the chance-constrained occupancy rows buy
monotone capacity headroom.
"""
from __future__ import annotations

import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Row, timed, update_artifact
from repro.configs.registry import get_config
from repro.core import violation_report
from repro.core.resource import select_point
from repro.models.costmodel import TierProfile
from repro.serve.partitioned import TwoTierDeployment

N_DEVICES = 8
BANDWIDTH = 60e6
DEADLINE, EPS = 0.45, 0.05
POLICY = "robust_exact"
KW = dict(outer_iters=3)

_DEV = TierProfile(flops_per_cycle=4000.0, cv=0.10, eff_jitter=0.10)
#: modest shared accelerator: full-model edge time ≈ 0.24 s, so 8 devices
#: all offloading demand ≈ 1.9 s of VM time per 0.45 s round — ignoring
#: the capacity is visibly fatal, pricing it is visibly cheaper than
#: statically scaling by N
_EDGE = TierProfile(flops_per_cycle=8000.0, cv=0.08, eff_jitter=0.05,
                    clock_hz=0.6e9)


def _dep(**kw):
    return TwoTierDeployment(
        get_config("tinyllama-1.1b"), num_devices=N_DEVICES,
        deadline_s=DEADLINE, eps=EPS, bandwidth_hz=BANDWIDTH, seq_len=512,
        device=_DEV, edge=_EDGE, f_max_hz=2.5e9, **kw)


def run_edge() -> list[Row]:
    coupled = _dep(dedicated_vm=False)  # real coupling, C = deadline
    naive = _dep(dedicated_vm=True)  # dedicated-VM assumption
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        legacy = _dep(dedicated_vm=False, legacy_vm_scale=True)
        legacy_fleet = legacy.fleet()

    cap = coupled.edge_capacity()
    fleet_true = coupled.fleet()  # the physical (unscaled) fleet
    key = jax.random.PRNGKey(1)

    rows: list[Row] = []
    res = {}
    for name, dep, fleet in (("coupled", coupled, fleet_true),
                             ("static_scale", legacy, legacy_fleet),
                             ("dedicated", naive, fleet_true)):
        planner = dep.planner(POLICY, **KW)
        p, us = timed(lambda fleet=fleet, dep=dep: planner.plan(fleet, dep.scenario()))
        # every plan's decisions are judged on the PHYSICAL fleet under
        # the congestion ground truth (energy is t_vm-independent, so the
        # plan's own figure carries over)
        occ = float(select_point(fleet_true, p.m_sel).t_vm.sum())
        vr = violation_report(key, fleet_true, p.m_sel, p.alloc,
                              np.full(N_DEVICES, DEADLINE),
                              edge_capacity_s=cap)
        res[name] = {
            "us": us,
            "energy_j": float(p.total_energy),
            "occupancy_s": occ,
            "max_violation": float(vr.rate.max()),
            "planner_feasible": bool(p.feasible.all()),
            "m_sel": np.asarray(p.m_sel).tolist(),
        }
        rows.append((
            f"edge_{name}_n{N_DEVICES}", us,
            f"E={res[name]['energy_j']:.4f}J;"
            f"viol={res[name]['max_violation']:.4f};"
            f"occ={occ:.3f}s/cap={cap:.3f}s"))

    section = {
        "n_devices": N_DEVICES,
        "policy": POLICY,
        "config": KW,
        "edge_capacity_s": cap,
        "eps": EPS,
        "plans": res,
        "coupled_vs_static_energy_ratio":
            res["coupled"]["energy_j"] / res["static_scale"]["energy_j"],
        "coupled_minus_static_violation":
            res["coupled"]["max_violation"]
            - res["static_scale"]["max_violation"],
        "dedicated_max_violation": res["dedicated"]["max_violation"],
    }
    update_artifact("edge", section)
    return rows


# ------------------------------------------------------------- placement

PLACE_N = 8
PLACE_SC = (0.2, 0.04, 30e6)  # deadline, eps, B — pricing has room to move
#: per-node shares of the slack plan's occupancy: total 0.35× — tight
#: enough that assignment quality decides how much pricing (and
#: therefore energy) each allocator pays, with the scarcest node barely
#: usable at all
PLACE_SHARES = (0.2, 0.1, 0.05)


def run_placement() -> list[Row]:
    from repro.configs.paper_tables import mixed_spec
    from repro.core import Planner, PlannerConfig, Scenario
    from repro.core.placement import node_loads, plan_duality_gap
    from repro.core.planner import get_policy

    d, eps, bw = PLACE_SC
    spec = mixed_spec(PLACE_N)
    fleet = spec.build(jax.random.PRNGKey(11))
    deadline_vec = np.full(PLACE_N, d)
    key = jax.random.PRNGKey(2)

    slack = Planner(PlannerConfig(policy=POLICY, **KW)).plan(
        fleet, Scenario(d, eps, bw))
    occ0 = float(select_point(fleet, slack.m_sel).t_vm.sum())
    caps = jnp.asarray(PLACE_SHARES) * occ0
    sc = Scenario(d, eps, bw, caps)

    rows: list[Row] = []
    res = {}
    for name in ("hybrid", "balanced", "weighted", "round_robin",
                 "greedy_load"):
        pol = dataclasses.replace(get_policy(POLICY), assign=name)
        planner = Planner(PlannerConfig(policy=pol, **KW))
        p, us = timed(lambda planner=planner: planner.plan(fleet, sc))
        vr = violation_report(key, fleet, p.m_sel, p.alloc, deadline_vec,
                              edge_capacity_s=caps, assignment=p.assignment)
        occ_e = np.asarray(node_loads(select_point(fleet, p.m_sel).t_vm,
                                      p.assignment, caps.shape[0]))
        res[name] = {
            "us": us,
            "energy_j": float(p.total_energy),
            "max_violation": float(vr.rate.max()),
            "planner_feasible": bool(p.feasible.all()),
            "node_occupancy_s": occ_e.tolist(),
            "mu": np.asarray(p.alloc.mu).tolist(),
            "duality_gap_j": float(plan_duality_gap(fleet, p, d, eps, caps)),
        }
        rows.append((
            f"placement_{name}_e{caps.shape[0]}", us,
            f"E={res[name]['energy_j']:.4f}J;"
            f"viol={res[name]['max_violation']:.4f};"
            f"gap={res[name]['duality_gap_j']:.2e}J"))

    # Cantelli chance-constrained occupancy rows: tightening ε_edge buys
    # monotone per-node headroom (occupancy backs off the capacity by the
    # σ_e·√(Σ v_vm) margin). The MC sweep drifts the true VM times to 3×
    # the profiled mean: the mean-row plan books zero headroom and
    # congests into deadline violations; the Cantelli plans' headroom
    # absorbs the drift — the violation gap the rows exist to close.
    from repro.serve.faults import FaultState

    drift = FaultState.identity()._replace(
        vm_mean_scale=jnp.asarray(3.0), vm_var_scale=jnp.asarray(9.0))
    cc = {}
    for edge_eps in (None, 0.2, 0.05):
        planner = Planner(PlannerConfig(policy=POLICY, edge_eps=edge_eps,
                                        **KW))
        p = planner.plan(fleet, sc)
        mc = lambda faults: float(violation_report(
            key, fleet, p.m_sel, p.alloc, deadline_vec, edge_capacity_s=caps,
            assignment=p.assignment, faults=faults).rate.max())
        occ_e = np.asarray(node_loads(select_point(fleet, p.m_sel).t_vm,
                                      p.assignment, caps.shape[0]))
        cc["mean" if edge_eps is None else f"{edge_eps:g}"] = {
            "energy_j": float(p.total_energy),
            "max_violation": mc(None),
            "max_violation_vm_drift_3x": mc(drift),
            "planner_feasible": bool(p.feasible.all()),
            "min_headroom_s": float(np.min(np.asarray(caps) - occ_e)),
        }

    section = {
        "n_devices": PLACE_N,
        "policy": POLICY,
        "config": KW,
        "scenario": {"deadline_s": d, "eps": eps, "bandwidth_hz": bw},
        "caps_s": np.asarray(caps).tolist(),
        "plans": res,
        "hybrid_vs_round_robin_energy_ratio":
            res["hybrid"]["energy_j"] / res["round_robin"]["energy_j"],
        "hybrid_minus_round_robin_violation":
            res["hybrid"]["max_violation"] - res["round_robin"]["max_violation"],
        "hybrid_duality_gap_j": res["hybrid"]["duality_gap_j"],
        "edge_eps_sweep": cc,
    }
    update_artifact("placement", section)
    return rows


#: --only-selectable sections (benchmarks/run.py MODULE_SECTIONS)
SECTIONS = {"edge": run_edge, "placement": run_placement}


def run() -> list[Row]:
    return run_edge() + run_placement()
