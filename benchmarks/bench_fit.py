"""Fig. 6 — NLS fit of the mean-time model t̄ = w/(g·f).

Synthesizes Jetson-style measurement campaigns per partition point from
Tables III/IV and reports the squared 2-norm of the fit residual — the
paper reports 2.0e-4 … 2.9e-3 s² for its fits; ours land in the same
decade for matched noise levels.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import Row, timed
from repro.configs import paper_tables as PT
from repro.core.uncertainty import measure_profile, synth_samples


def run() -> list[Row]:
    rows: list[Row] = []
    cases = [
        ("alexnet", PT.ALEXNET_W_GFLOPS, PT.ALEXNET_G, 0.1e9, 1.2e9),
        ("resnet152", PT.RESNET152_W_GFLOPS, PT.RESNET152_G, 0.2e9, 0.8e9),
    ]
    key = jax.random.PRNGKey(0)
    for name, ws, gs, fmin, fmax in cases:
        freqs = jnp.linspace(fmin, fmax, 12)
        for m in (1, len(ws) - 1):
            w = ws[m] * 1e9
            g = gs[m]
            key, sub = jax.random.split(key)
            samples = synth_samples(sub, freqs, w, g, cv=0.06, num_samples=500)
            prof, us = timed(lambda: jax.block_until_ready(
                measure_profile(freqs, samples, w)))
            rel = abs(float(prof.g_eff) - g) / g
            rows.append((f"fig6_fit_{name}_m{m}", us,
                         f"resid={float(prof.fit_residual_sq):.2e}s2;g_err={rel:.3f}"))
    return rows
