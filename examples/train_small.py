"""End-to-end training driver: train a ~100M-param dense model for a few
hundred steps on the synthetic Markov corpus and watch the loss drop
below the unigram floor.

Run:  PYTHONPATH=src python examples/train_small.py  (takes a few minutes on CPU)
"""

from repro.configs.base import ModelConfig
from repro.train.loop import train
from repro.train.optimizer import AdamWConfig

# ~100M params: 12L × d512 (GQA 8/4 heads), vocab 8192
CFG = ModelConfig(
    name="demo-100m", family="dense", num_layers=12, d_model=768,
    num_heads=12, num_kv_heads=4, d_ff=2048, vocab_size=8192,
    activation="swiglu",
)

if __name__ == "__main__":
    import argparse

    from repro.models.transformer import param_count

    ap = argparse.ArgumentParser()
    # full run: --steps 300 --batch 8 --seq 256 (≈47 s/step on one CPU
    # core — size the run to your box; the loss curve is visible by ~40)
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args()

    print(f"params: {param_count(CFG)/1e6:.1f}M")
    opt = AdamWConfig(lr=6e-4, warmup_steps=10, total_steps=args.steps)
    params, _, hist = train(CFG, opt, num_steps=args.steps,
                            global_batch=args.batch, seq_len=args.seq,
                            log_every=10)
    losses = [l for _, l in hist["loss"]]
    print(f"loss: {losses[0]:.3f} → {losses[-1]:.3f}")
    assert losses[-1] < losses[0], "training failed to reduce loss"
