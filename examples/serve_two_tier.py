"""End-to-end serving driver: batched requests through the engine, with
the engine's *measured* per-step statistics fed back into the robust
planner (the paper's §IV online-measurement path).

Run:  PYTHONPATH=src python examples/serve_two_tier.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config
from repro.core import Scenario
from repro.models import transformer as T
from repro.models.costmodel import block_chain_from_config
from repro.serve.engine import Request, ServingEngine
from repro.serve.partitioned import TwoTierDeployment, measured_chain

ARCH = "tinyllama-1.1b"
cfg = get_config(ARCH, smoke=True)  # CPU-sized model, real engine
params = T.init_params(cfg, jax.random.PRNGKey(0))

# 1. serve a batch of requests, measuring per-step times
engine = ServingEngine(cfg, params, max_batch=4, window=256)
rng = np.random.default_rng(0)
requests = [
    Request(uid=i, prompt=rng.integers(0, cfg.vocab_size, size=8),
            max_new_tokens=6, deadline_s=float(rng.uniform(0.3, 1.0)))
    for i in range(8)
]
done, stats = engine.run(requests)
print(f"served {len(done)} requests")
print(f"measured decode: mean {stats['decode_mean_s']*1e3:.2f} ms, "
      f"var {stats['decode_var_s2']:.2e} s²")

# 2. fold the measurements into the block chain (mean/variance only —
#    exactly the information the paper's planner needs)
chain = block_chain_from_config(get_config(ARCH), seq_len=256)
chain = measured_chain(chain, stats)

# 3. robust plan for a fleet of devices serving this model
dep = TwoTierDeployment(get_config(ARCH), num_devices=6, deadline_s=1.0,
                        eps=0.05, bandwidth_hz=80e6)
p, fleet = dep.plan(policy="robust_exact")
rep = dep.validate(p, fleet)
print("robust two-tier plan:", list(map(int, p.m_sel)))
print({k: round(v, 5) for k, v in rep.items()})

# 4. the request population has heterogeneous deadlines — plan against
#    per-device SLOs (Scenario leaves may be (N,)) in the same compiled
#    program, and validate each device against its own deadline.
dls = jnp.asarray(np.resize(sorted(r.deadline_s for r in done), dep.num_devices))
het = dep.planner("robust_exact").plan(fleet, Scenario(dls, dep.eps, dep.bandwidth_hz))
rep = dep.validate(het, fleet, deadline=dls)
print("per-device SLO plan:", list(map(int, het.m_sel)),
      f"deadlines={np.round(np.asarray(dls), 2).tolist()}")
print({k: round(v, 5) for k, v in rep.items()})
