"""Quickstart: the Scenario/Planner API on the paper's own AlexNet scenario.

A ``Scenario`` is *data* (deadline, risk level ε, bandwidth budget B —
scalars or per-device arrays); a ``Planner`` is one compiled entry point
for a fixed ``PlannerConfig``. Policies (the paper's robust CCP+PCCP, the
§VI baselines, beyond-paper variants) live in a registry, so they all
dispatch — and batch — the same way.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs.paper_tables import alexnet_fleet
from repro.core import Planner, PlannerConfig, Scenario, scenario_at, violation_report

N = 12
fleet = alexnet_fleet(jax.random.PRNGKey(0), N)
scenario = Scenario(deadline=0.200, eps=0.04, B=10e6)

# one compiled program per config; the scenario values are traced
robust = Planner(PlannerConfig(policy="robust")).plan(fleet, scenario)
worst = Planner(PlannerConfig(policy="worst_case")).plan(fleet, scenario)
optimal = Planner(PlannerConfig(policy="optimal")).plan(fleet, scenario)

print(f"robust  : E = {float(robust.total_energy):.4f} J, partition points {list(map(int, robust.m_sel))}")
print(f"worst   : E = {float(worst.total_energy):.4f} J")
print(f"optimal : E = {float(optimal.total_energy):.4f} J")
print(f"saving vs worst-case: "
      f"{100 * (float(worst.total_energy) - float(robust.total_energy)) / float(worst.total_energy):.1f}%")

# zipped scenario batches: K *arbitrary* scenarios (here: a tight fleet-wide
# SLO, a relaxed one, and heterogeneous per-device deadlines) planned as ONE
# XLA program — no cartesian grid required.
mix = [
    Scenario(0.180, 0.02, 10e6),
    Scenario(0.240, 0.08, 10e6),
    Scenario(jnp.linspace(0.17, 0.26, N), 0.04, 10e6),  # per-device SLOs
]
planner = Planner(PlannerConfig(policy="robust_exact"))
batch = planner.plan_many(fleet, mix)
for k, sc in enumerate(mix):
    p = scenario_at(batch, k)
    print(f"scenario {k}: E = {float(p.total_energy):.4f} J, "
          f"feasible = {bool(p.feasible.all())}")

vr = violation_report(jax.random.PRNGKey(1), fleet, robust.m_sel, robust.alloc,
                      scenario.deadline, dist="gamma", var_scale=1.0)
print(f"empirical violation probability: {float(vr.rate.max()):.4f}  (risk level ε = {scenario.eps})")
assert float(vr.rate.max()) <= scenario.eps + 0.01, "probabilistic guarantee broken!"
print("probabilistic deadline guarantee holds ✓")
