"""Quickstart: the paper's robust planner on its own AlexNet scenario.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.configs.paper_tables import alexnet_fleet
from repro.core import plan, plan_optimal, violation_report

N, D, EPS, B = 12, 0.200, 0.04, 10e6

fleet = alexnet_fleet(jax.random.PRNGKey(0), N)

robust = plan(fleet, D, EPS, B, policy="robust")          # paper: CCP + PCCP
worst = plan(fleet, D, EPS, B, policy="worst_case")        # §VI baseline
optimal = plan_optimal(fleet, D, EPS, B)                   # §VI baseline

print(f"robust  : E = {float(robust.total_energy):.4f} J, partition points {list(map(int, robust.m_sel))}")
print(f"worst   : E = {float(worst.total_energy):.4f} J")
print(f"optimal : E = {float(optimal.total_energy):.4f} J")
print(f"saving vs worst-case: "
      f"{100 * (float(worst.total_energy) - float(robust.total_energy)) / float(worst.total_energy):.1f}%")

vr = violation_report(jax.random.PRNGKey(1), fleet, robust.m_sel, robust.alloc, D,
                      dist="gamma", var_scale=1.0)
print(f"empirical violation probability: {float(vr.rate.max()):.4f}  (risk level ε = {EPS})")
assert float(vr.rate.max()) <= EPS + 0.01, "probabilistic guarantee broken!"
print("probabilistic deadline guarantee holds ✓")
