"""Multi-edge placement: a mixed fleet served by THREE heterogeneous
edge nodes (DESIGN.md §placement).

``Scenario.edge_capacity_s`` is a per-node ``(E,)`` capacity vector: the
planner places every device on exactly one node (``Plan.assignment``,
balance-aware Hybrid allocator by default), clears a per-node price
vector μ ∈ R^E inside the dual loop, and certifies the placement with a
duality gap. A 0 capacity marks a node *absent* — which makes
"add a node vs upgrade a node" a value-varied ``(K, E)`` grid axis of
ONE compiled program, not K recompiles.

Run:  PYTHONPATH=src python examples/multi_edge.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.paper_tables import mixed_spec
from repro.core import Planner, PlannerConfig, Scenario, violation_report
from repro.core.placement import node_loads, plan_duality_gap
from repro.core.resource import select_point

N = 8
D, EPS, BW = 0.2, 0.04, 30e6

spec = mixed_spec(N)  # 4 alexnet + 4 resnet152 devices: a ragged fleet
fleet = spec.build(jax.random.PRNGKey(11))
planner = Planner(PlannerConfig(policy="robust_exact", outer_iters=3))

# 1. size the nodes off the unconstrained plan's total edge demand
slack = planner.plan(fleet, Scenario(D, EPS, BW))
occ0 = float(select_point(fleet, slack.m_sel).t_vm.sum())
print(f"unconstrained plan: E = {float(slack.total_energy):.4f} J, "
      f"edge demand = {occ0 * 1e3:.2f} ms/round")

# three heterogeneous nodes: one decent GPU, one small card, one tiny —
# together only 35% of what the unconstrained plan would book
caps = jnp.asarray([0.20, 0.10, 0.05]) * occ0

# 2. one plan: placement + per-node prices + per-node capacity rows
p = planner.plan(fleet, Scenario(D, EPS, BW, caps))
occ_e = np.asarray(node_loads(select_point(fleet, p.m_sel).t_vm,
                              p.assignment, 3))
print(f"\n3-node plan: E = {float(p.total_energy):.4f} J, "
      f"feasible = {bool(p.feasible.all())}")
print("  device -> node:", np.asarray(p.assignment).tolist())
print("  per-node occupancy / capacity [ms]:",
      [f"{o * 1e3:.2f}/{c * 1e3:.2f}" for o, c in
       zip(occ_e, np.asarray(caps), strict=True)])
print("  per-node prices mu:", np.asarray(p.alloc.mu).round(4).tolist())
gap = float(plan_duality_gap(fleet, p, D, EPS, caps))
print(f"  duality gap = {gap:.2e} J "
      f"({gap / float(p.total_energy) * 100:.3f}% of primal)")

# 3. the per-node congestion ground truth (each node is its own
#    processor-sharing accelerator for the devices placed on it)
vr = violation_report(jax.random.PRNGKey(2), fleet, p.m_sel, p.alloc,
                      jnp.full((N,), D), edge_capacity_s=caps,
                      assignment=p.assignment)
print(f"  MC max violation = {float(vr.rate.max()):.4f} (eps = {EPS})")

# 4. add-a-node vs upgrade-a-node: (K, E) capacity rows on one program.
#    0 marks a node absent, so "two nodes today" and both expansion
#    options are value-varied rows of the SAME compiled sweep.
today = [0.20, 0.10, 0.00]  # the tiny third node not bought yet
add = [0.20, 0.10, 0.05]  # buy the tiny card
upgrade = [0.25, 0.10, 0.00]  # upgrade the big node instead
rows = jnp.asarray([today, add, upgrade]) * occ0
grid = planner.grid(fleet, D, EPS, BW, edge_capacities=rows)
print("\nwhat-if sweep (one compiled grid program):")
for name, k in (("today ", 0), ("add   ", 1), ("upgrade", 2)):
    cell = jax.tree_util.tree_map(lambda x: x[0, 0, 0, k], grid)
    nodes = int(np.count_nonzero(rows[k]))
    print(f"  {name} ({nodes} nodes): E = {float(cell.total_energy):.4f} J, "
          f"feasible = {bool(cell.feasible.all())}")
