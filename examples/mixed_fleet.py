"""Mixed-population deployment: 60% tinyllama on Jetson-class devices +
40% mamba2 on phone-class devices, sharing ONE edge and ONE uplink
bandwidth budget.

The fleet is *ragged* — different models, different partition-point
counts M_n, different DVFS platforms — and the robust planner solves the
whole population in one compiled program (DESIGN.md §fleet). Each device
is then Monte-Carlo validated against its own probabilistic deadline.

The edge is a *congested shared* accelerator (``dedicated_vm=False``:
VM occupancy is a real capacity constraint Σ t̄_vm ≤ C_edge with its own
dual price μ — DESIGN.md §edge), which is what makes the split decision
interesting — the planner offloads exactly up to the edge's capacity
(the weak phone population first) and keeps the rest of the strong
Jetson population local, all priced against the same bandwidth budget.

Run:  PYTHONPATH=src python examples/mixed_fleet.py
"""
import numpy as np

from repro.configs.registry import get_config
from repro.core import plan_at
from repro.models.costmodel import PHONE_TIER, TierProfile
from repro.serve.partitioned import MixedTwoTierDeployment, Population

JETSON = TierProfile(flops_per_cycle=4000.0, cv=0.10, eff_jitter=0.10)
SHARED_EDGE = TierProfile(flops_per_cycle=8000.0, cv=0.08, eff_jitter=0.05,
                          clock_hz=1.5e9)

dep = MixedTwoTierDeployment(
    populations=(
        Population(get_config("tinyllama-1.1b"), fraction=0.6,
                   device=JETSON, edge=SHARED_EDGE, seq_len=512,
                   f_max_hz=2.5e9, name="tinyllama-jetson"),
        Population(get_config("mamba2-130m"), fraction=0.4,
                   device=PHONE_TIER, edge=SHARED_EDGE, seq_len=512,
                   f_max_hz=1.0e9, name="mamba2-phone"),
    ),
    num_devices=10, bandwidth_hz=60e6, deadline_s=0.5, eps=0.05,
    dedicated_vm=False,
)
print("population counts:", dict(zip([p.name for p in dep.populations],
                                     dep.counts(), strict=True)))

# 1. one compiled plan for the whole mixed population
p, fleet = dep.plan(policy="robust_exact", outer_iters=3)
print(f"mixed plan: E = {float(p.total_energy):.4f} J, "
      f"feasible = {bool(p.feasible.all())}")

# 2. per-device Monte-Carlo validation — every device against its own SLO
per = dep.validate_per_device(p, fleet)
for n, (g, m, v) in enumerate(zip(per["group"], per["m"], per["violation"],
                                  strict=True)):
    print(f"  device {n}: {g:18s} m={m}  P(T>D)={float(v):.4f}  "
          f"{'ok' if per['ok'][n] else 'VIOLATED'}")
assert per["ok"].all()

# 3. an SLO sweep over the same ragged fleet — one compiled grid program
deadlines = (0.3, 0.4, 0.5)
grid, fleet = dep.plan_grid(deadlines=deadlines, policy="robust_exact",
                            outer_iters=3)
for i, d in enumerate(deadlines):
    cell = plan_at(grid, i, 0, 0)
    rep = dep.validate(cell, fleet, deadline=d)
    print(f"D={d:.1f}s  E={rep['total_energy_j']:.4f} J  "
          f"viol={rep['max_violation']:.4f}  m={np.asarray(cell.m_sel).tolist()}")
