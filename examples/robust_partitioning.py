"""Robust partitioning of a *zoo architecture* (the framework feature):
plan the device/edge split of InternVL2-2B under uncertain per-block
latency on a CONGESTED shared edge, sweep the risk level, and validate
the chance constraint.

The whole ε sweep is ONE compiled program (``plan_grid`` — cartesian
sugar over the zipped ``plan_many`` batch API); the worst-case baseline
uses σ_hard ≡ 0, so a single plan covers every ε.

(With an abundant dedicated edge, full offload m=0 is provably optimal
for token-input transformers — see DESIGN.md §5b. The congested regime is
where the paper's machinery earns its keep on transformers.)

Run:  PYTHONPATH=src python examples/robust_partitioning.py
"""
from repro.configs.registry import get_config
from repro.core import plan_at
from repro.models.costmodel import TierProfile
from repro.serve.partitioned import TwoTierDeployment

cfg = get_config("internvl2-2b")
print(f"arch: {cfg.name} ({cfg.num_layers}L, d_model={cfg.d_model}, "
      f"vlm_stub patches={cfg.num_patches})")

fast_dev = TierProfile(flops_per_cycle=4000.0, cv=0.10, eff_jitter=0.10)
shared_edge = TierProfile(flops_per_cycle=8000.0, cv=0.08, eff_jitter=0.05,
                          clock_hz=1.5e9)

EPSS = (0.02, 0.05, 0.10, 0.20)
dep = TwoTierDeployment(cfg, num_devices=8, deadline_s=0.75, eps=0.05,
                        bandwidth_hz=60e6, seq_len=512,
                        dedicated_vm=False, device=fast_dev,
                        edge=shared_edge, f_max_hz=2.5e9)

grid, fleet = dep.plan_grid(epss=EPSS, policy="robust_exact")  # one program
pw, _ = dep.plan(policy="worst_case")
ew = float(pw.total_energy)

for j, eps in enumerate(EPSS):
    p = plan_at(grid, 0, j, 0)
    rep = dep.validate(p, fleet)
    save = 100 * (ew - rep["total_energy_j"]) / ew
    print(f"ε={eps:4.2f}  E={rep['total_energy_j']:.4f} J  "
          f"(worst-case {ew:.4f} J, saving {save:4.1f}%)  "
          f"violation={rep['max_violation']:.4f}  "
          f"p95={rep['p95_latency_s']*1e3:.0f} ms  m={list(map(int, p.m_sel))}")

print("\nHigher ε → smaller Cantelli margin → lower clocks → less energy; "
      "the empirical violation stays below ε in every row.")
